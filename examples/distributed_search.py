"""Distributed SSH index via shard_map — the multi-pod serving path,
demonstrated on N host devices.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/distributed_search.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import SSHParams
from repro.core.index import SSHFunctions
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import TimeSeriesDB
from repro.distributed.dist_index import (build_sharded, index_shardings,
                                          make_query_fn)


def main() -> None:
    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    print(f"mesh: {n_dev} devices")

    stream = synthetic_ecg(6000, seed=1)
    series = extract_subsequences(stream, 128, stride=1, znorm=True)
    n = (series.shape[0] // n_dev) * n_dev
    series = jnp.asarray(series[:n])

    params = SSHParams(window=32, step=3, ngram=10, num_hashes=40,
                       num_tables=20)
    fns = SSHFunctions.create(params)
    # search knobs from the registry: one config drives the sharded query
    # fn below AND the facade route (no hand-plumbed top_c/band tuples);
    # the shard_map probe is single-probe by construction
    config = get_arch("ssh-ecg").search_config(length=128, topk=5,
                                               multiprobe_offsets=1)

    # shard the database, build signatures locally on every shard
    series_sh, sigs_sh = index_shardings(mesh)
    series = jax.device_put(series, series_sh)
    sigs = build_sharded(series, fns.filters, fns.cws._asdict(), params,
                         mesh)
    print(f"sharded signatures: {sigs.shape} on {n_dev} shards")

    # one query: local probe -> local DTW re-rank -> global top-k
    qfn = make_query_fn(params, mesh, length=128, config=config)
    ids, dists = qfn(series, sigs, fns.filters, fns.cws._asdict(),
                     series[4321])
    print(f"global top-5 ids: {ids}  (dists {jnp.round(dists, 4)})")
    assert int(ids[0]) == 4321, "self-match must rank first"

    # same answer through the facade: searcher="distributed" shards the
    # index over the mesh behind the TimeSeriesDB API
    db = TimeSeriesDB.build(series, spec=params.to_spec(),
                            config=config.replace(searcher="distributed"),
                            mesh=mesh)
    res = db.search(series[4321])
    assert int(res.ids[0]) == 4321
    print(f"facade (searcher='distributed') top-5: {res.ids}")
    print("distributed search OK")


if __name__ == "__main__":
    main()
