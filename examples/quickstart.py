"""Quickstart: build an SSH database over an ECG stream and search it.

Two frozen configs split the API (FAISS-style):

* ``repro.encoders.IndexSpec`` — what the index *is*: the encoder name
  (``"ssh"``, ``"srp"``, ``"ssh-multires"``, or any registered encoder)
  plus its stage params and seed.
* ``repro.db.SearchConfig``   — what a query *does*: topk, band,
  candidate width, searcher backend, kernel backend.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.configs import get_arch
from repro.core import brute_force_topk, precision_at_k
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import TimeSeriesDB
from repro.encoders import IndexSpec


def main() -> None:
    # 1. a long ECG stream, sliced into overlapping subsequences (paper §5.1)
    stream = synthetic_ecg(8000, seed=42)
    series = jnp.asarray(extract_subsequences(stream, 256, stride=1,
                                              znorm=True))
    print(f"database: {series.shape[0]} subsequences of length "
          f"{series.shape[1]}")

    # 2. index structure — one frozen IndexSpec names the encoder pipeline:
    #    Sketch (W=48, δ=3) → Shingle (n=12) → CWS Hash (K=40, L=20)
    #    search policy — from the arch registry, banded for length 256
    spec = IndexSpec(encoder="ssh",
                     params=dict(window=48, step=3, ngram=12,
                                 num_hashes=40, num_tables=20))
    config = get_arch("ssh-ecg").search_config(length=256)
    db = TimeSeriesDB.build(series, spec=spec, config=config)
    print(f"built {db!r}")

    # 3. query — hash, probe, DTW re-rank (paper Alg. 2)
    query = series[1234]
    result = db.search(query)
    print(f"top-10 ids: {result.ids}")
    print(f"pruned {result.pruned_total_frac:.1%} of the database; "
          f"only {result.dtw_evals} DTW evaluations")

    # 4. compare with the exact answer
    gold, _ = brute_force_topk(query, series, 10, band=config.band)
    print(f"precision@10 vs exact DTW: "
          f"{precision_at_k(result.ids, gold, 10):.2f}")

    # 5. streaming insert — data-independent hashing needs no retraining
    db.add(series[:3] * 1.01)
    print(f"after add: {len(db)} series indexed")

    # 6. encoder swap — same data, same search policy, different hashing:
    #    multi-resolution shingles carry short- AND long-motif statistics
    #    in one signature (spec.replace/with_params work too)
    mr_spec = IndexSpec(encoder="ssh-multires",
                        params=dict(window=48, step=3, ngrams=(8, 12),
                                    num_hashes=40, num_tables=20))
    mr_db = TimeSeriesDB.build(series, spec=mr_spec, config=config)
    mr_res = mr_db.search(query)
    print(f"ssh-multires precision@10: "
          f"{precision_at_k(mr_res.ids, gold, 10):.2f} "
          f"(swapping encoders is one IndexSpec away)")

    # 7. subsequence search (DESIGN.md §10) — index every sliding window
    #    of the raw stream in one rolling encode and ask WHERE a pattern
    #    occurs; offsets come back ≥ L//2 apart (UCR exclusion zone)
    sub_cfg = get_arch("ssh-ecg").search_config(
        length=128, subseq_window=128, subseq_hop=6)
    sdb = TimeSeriesDB.build_stream(stream, spec=spec, config=sub_cfg)
    pattern = jnp.asarray(stream[2400:2528])     # a raw window
    sres = sdb.search_subsequence(pattern)
    print(f"pattern planted at 2400 found at offsets "
          f"{sres.offsets[:3].tolist()} "
          f"({sres.n_windows} windows indexed)")
    sdb.extend_stream(stream[:300])              # rolls only new windows
    print(f"after extend: {sdb.subseq.num_windows} windows")


if __name__ == "__main__":
    main()
