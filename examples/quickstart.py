"""Quickstart: build an SSH index over an ECG stream and search it.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.core import (SSHParams, SSHIndex, brute_force_topk,
                        precision_at_k, ssh_search)
from repro.data.timeseries import extract_subsequences, synthetic_ecg


def main() -> None:
    # 1. a long ECG stream, sliced into overlapping subsequences (paper §5.1)
    stream = synthetic_ecg(8000, seed=42)
    db = jnp.asarray(extract_subsequences(stream, 256, stride=1, znorm=True))
    print(f"database: {db.shape[0]} subsequences of length {db.shape[1]}")

    # 2. build the index — Sketch (W=48, δ=3) → Shingle (n=12) → Hash (K=40)
    params = SSHParams(window=48, step=3, ngram=12, num_hashes=40,
                       num_tables=20)
    index = SSHIndex.build(db, params)
    print(f"signatures: {index.signatures.shape}")

    # 3. query — hash, probe, DTW re-rank (paper Alg. 2)
    query = db[1234]
    result = ssh_search(query, index, topk=10, top_c=256, band=12,
                        multiprobe_offsets=params.step)
    print(f"top-10 ids: {result.ids}")
    print(f"pruned {result.pruned_total_frac:.1%} of the database; "
          f"only {result.dtw_evals} DTW evaluations")

    # 4. compare with the exact answer
    gold, _ = brute_force_topk(query, db, 10, band=12)
    print(f"precision@10 vs exact DTW: "
          f"{precision_at_k(result.ids, gold, 10):.2f}")


if __name__ == "__main__":
    main()
