"""Train BST on synthetic behavior sequences, then use an SSH index over
user histories for similar-user retrieval — the paper's technique applied
to an assigned architecture (DESIGN.md §4).

    PYTHONPATH=src python examples/train_recsys_ssh.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import SSHParams
from repro.data.recsys_data import seq_batch
from repro.db import SearchConfig, TimeSeriesDB
from repro.launch import steps


def main() -> None:
    arch = get_arch("bst")
    cfg = arch.smoke_config
    params = steps.init_fn(arch, "train_batch", smoke=True)()
    opt = steps.make_optimizer("recsys")
    opt_state = opt.init(params)
    train = jax.jit(steps.make_step(arch, "train_batch", "train",
                                    smoke=True))

    for step_i in range(5):
        raw = seq_batch(64, cfg.seq_len, vocab=cfg.vocab, seed=step_i)
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        params, opt_state, metrics = train(params, opt_state, batch)
        print(f"step {step_i}: bce={float(metrics['loss']):.4f}")

    # SSH over user-history embedding *trajectories*: each user's history,
    # projected through the trained item table, is a time series
    raw = seq_batch(512, cfg.seq_len, vocab=cfg.vocab, seed=99)
    hist = jnp.asarray(raw["history"])
    emb = params["items"][hist % cfg.vocab]          # (B, S, d)
    traj = emb.mean(-1)                              # scalar series per user
    traj = (traj - traj.mean(1, keepdims=True)) / (traj.std(1, keepdims=True)
                                                   + 1e-6)
    ssh = SSHParams(window=8, step=1, ngram=6, num_hashes=20, num_tables=20)
    # short trajectories: a tight band; top_c defaults clamp to the 512
    # users, so only topk/band need setting
    db = TimeSeriesDB.build(traj, ssh, SearchConfig(topk=5, band=4))
    res = db.search(traj[7])
    print(f"users most similar to user 7 (by behavior trajectory): "
          f"{res.ids}")
    assert res.ids[0] == 7
    print("recsys + SSH retrieval OK")


if __name__ == "__main__":
    main()
