"""End-to-end driver: index a large stream, persist the database, run a
query batch, report the paper's Table-3/4 metrics (time + pruning +
accuracy).

Persistence replaces rebuild-on-restart: the first run builds the index
(paper Alg. 1) and saves it with ``TimeSeriesDB.save``; every later run
``TimeSeriesDB.load``-s it and answers bit-identical top-k without
paying the O(N) signature build again — the operational payoff of the
paper's retraining-free hashing.

    PYTHONPATH=src python examples/index_and_search.py [--points 40000]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.core import (brute_force_topk, ndcg_at_k, precision_at_k,
                        ucr_search)
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import TimeSeriesDB, is_database_dir


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--queries", type=int, default=3)
    ap.add_argument("--db-dir", type=str, default="/tmp/ssh_db_example")
    ap.add_argument("--rebuild", action="store_true",
                    help="ignore a saved database and rebuild")
    args = ap.parse_args()

    stream = synthetic_ecg(args.points, seed=7)
    series = jnp.asarray(extract_subsequences(stream, args.length, stride=1,
                                              znorm=True))
    arch = get_arch("ssh-ecg")
    config = arch.search_config(length=args.length)

    # --- build once, load forever ---
    t0 = time.time()
    if is_database_dir(args.db_dir) and not args.rebuild:
        db = TimeSeriesDB.load(args.db_dir)
        if len(db) != series.shape[0]:       # stale save (different --points)
            db = None
        else:
            print(f"loaded database from {args.db_dir} "
                  f"in {time.time() - t0:.1f}s")
    else:
        db = None
    if db is None:
        db = TimeSeriesDB.build(series, spec=arch.index_spec(),
                                config=config)
        db.save(args.db_dir)
        print(f"built + saved database ({len(db)} series) "
              f"in {time.time() - t0:.1f}s")

    # --- queries ---
    band = db.config.band
    rng = np.random.default_rng(0)
    for qi in rng.integers(0, series.shape[0], args.queries):
        q = series[int(qi)]
        t0 = time.time()
        res = db.search(q)
        t_ssh = time.time() - t0
        t0 = time.time()
        ucr = ucr_search(q, series, topk=10, band=band)
        t_ucr = time.time() - t0
        gold, _ = brute_force_topk(q, series, 10, band=band)
        print(f"q={qi}: ssh {t_ssh:.2f}s (pruned {res.pruned_total_frac:.1%},"
              f" prec {precision_at_k(res.ids, gold, 10):.2f},"
              f" ndcg {ndcg_at_k(res.ids, gold, 10):.2f}) | "
              f"ucr {t_ucr:.2f}s (pruned {ucr.pruned_total_frac:.1%}) | "
              f"speedup {t_ucr / t_ssh:.1f}x")


if __name__ == "__main__":
    main()
