"""End-to-end driver: index a large stream, run a query batch, report the
paper's Table-3/4 metrics (time + pruning + accuracy), with checkpointed
index build (fault-tolerant restart).

    PYTHONPATH=src python examples/index_and_search.py [--points 40000]
"""
import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core import (SSHParams, SSHIndex, brute_force_topk, ndcg_at_k,
                        precision_at_k, ssh_search, ucr_search)
from repro.core.index import SSHFunctions, band_keys, build_signatures
from repro.data.timeseries import extract_subsequences, synthetic_ecg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--queries", type=int, default=3)
    ap.add_argument("--ckpt-dir", type=str, default="/tmp/ssh_index_ckpt")
    args = ap.parse_args()

    stream = synthetic_ecg(args.points, seed=7)
    db = jnp.asarray(extract_subsequences(stream, args.length, stride=1,
                                          znorm=True))
    params = SSHParams(window=80, step=3, ngram=15, num_hashes=40,
                       num_tables=20)

    # --- index build with checkpoint/restart ---
    ck = Checkpointer(args.ckpt_dir, keep=2)
    fns = SSHFunctions.create(params)
    t0 = time.time()
    step, restored = ck.restore_latest({"sigs": jnp.zeros(
        (db.shape[0], params.num_hashes), jnp.int32)})
    if step is not None:
        print(f"restored index checkpoint at step {step}")
        sigs = restored["sigs"]
    else:
        sigs = build_signatures(db, fns)
        ck.save(1, {"sigs": sigs})
    index = SSHIndex(fns=fns, signatures=sigs,
                     keys=band_keys(sigs, params), series=db)
    print(f"index over {db.shape[0]} series ready in {time.time()-t0:.1f}s")

    # --- queries ---
    band = max(4, args.length // 20)
    rng = np.random.default_rng(0)
    for qi in rng.integers(0, db.shape[0], args.queries):
        q = db[int(qi)]
        t0 = time.time()
        res = ssh_search(q, index, topk=10, top_c=512, band=band,
                         multiprobe_offsets=params.step)
        t_ssh = time.time() - t0
        t0 = time.time()
        ucr = ucr_search(q, db, topk=10, band=band)
        t_ucr = time.time() - t0
        gold, _ = brute_force_topk(q, db, 10, band=band)
        print(f"q={qi}: ssh {t_ssh:.2f}s (pruned {res.pruned_total_frac:.1%},"
              f" prec {precision_at_k(res.ids, gold, 10):.2f},"
              f" ndcg {ndcg_at_k(res.ids, gold, 10):.2f}) | "
              f"ucr {t_ucr:.2f}s (pruned {ucr.pruned_total_frac:.1%}) | "
              f"speedup {t_ucr / t_ssh:.1f}x")


if __name__ == "__main__":
    main()
