"""Streaming ingest — sketch throughput, shard merge cost, memory.

Measures the DESIGN.md §9 subsystem end to end at bench scale:

* ``hotpath`` — one stage-instrumented sequential search through an
  ``"ssh-cs"`` database (full encode/probe/lb/dtw breakdown, so this
  module's BENCH json satisfies the trajectory contract).
* ``append/<encoder>`` — ``StreamIngestor.append`` µs/series for the
  count-sketch encoder vs exact ``"ssh"``; the sketch adds O(1)-per-
  shingle update work on top of the shared signature build.
* ``merge/shards<S>`` — ``merge_all`` wall time over S shard-local
  ingestors holding the same total workload.  The combine is segment
  concatenation + one sketch addition per merge, so cost grows with the
  shard count, never with the stream length.
* ``memory`` — ``SSHIndex.nbytes`` and encoder-state bytes for the same
  data under ``"ssh-cs"`` vs exact ``"ssh"``: the sketch bounds the
  shingle-indexed state at (rows·width) against the exact F·2^n.

CSV rows: ingest/<kind>/len<L>/<cell>, us_per_call, derived.
"""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PARAMS, case_for, dataset_cached,
                               percentile, report, search_config,
                               stage_mean_us, timed_search_samples,
                               tsdb_cached)
from repro.encoders import IndexSpec

KIND, LENGTH = "ecg", 128

# same SSH stage geometry as the exact "ecg" bench params so the memory
# and append rows compare like against like; the sketch knobs bound the
# shingle state at rows·width = 16384 vs the exact F·2^n = 32768
SKETCH = dict(rows=4, width=4096, base_bits=4)

N_APPEND = 64          # series per append-throughput cell
APPEND_BLOCK = 16      # series per append() call
N_MERGE_ROUNDS = 5     # merge is cheap; keep the best round
SHARD_COUNTS = (2, 4, 8)


def _sshcs_spec() -> IndexSpec:
    p = PARAMS[KIND]
    return IndexSpec(encoder="ssh-cs", params=dict(
        window=p.window, step=p.step, ngram=p.ngram,
        num_hashes=p.num_hashes, num_tables=p.num_tables, **SKETCH))


_CS_DB = None


def _sshcs_db():
    """One "ssh-cs" TimeSeriesDB over the shared bench dataset."""
    global _CS_DB
    if _CS_DB is None:
        from repro.db import TimeSeriesDB
        db, _ = dataset_cached(KIND, LENGTH)
        _CS_DB = TimeSeriesDB.build(
            db, spec=_sshcs_spec(),
            config=search_config(KIND, LENGTH, searcher="local"))
    return _CS_DB


def _encoder_state_bytes(enc) -> int:
    return sum(int(a.size) * a.dtype.itemsize for a in enc.state().values())


def _hotpath() -> None:
    _, queries = dataset_cached(KIND, LENGTH)
    tsdb = _sshcs_db()
    results, samples_us = timed_search_samples(tsdb.search, queries)
    report(f"ingest/{KIND}/len{LENGTH}/hotpath",
           float(np.mean(samples_us)),
           {"p50_us": round(percentile(samples_us, 50), 1),
            "p95_us": round(percentile(samples_us, 95), 1),
            "n_samples": len(samples_us)},
           stats=results[-1].stats,
           stage_us=stage_mean_us([r.stats for r in results]),
           samples_us=samples_us,
           case=case_for(KIND, LENGTH, len(tsdb), spec=tsdb.spec,
                         config=tsdb.config))


def _append_workload() -> jnp.ndarray:
    db, _ = dataset_cached(KIND, LENGTH)
    rng = np.random.default_rng(11)
    return db[jnp.asarray(rng.integers(0, db.shape[0], N_APPEND))]


def _time_append(encoder, series: jnp.ndarray, backend: str) -> float:
    """Warm seconds for appending ``series`` in APPEND_BLOCK blocks."""
    from repro.streaming import StreamIngestor
    blocks = [series[i:i + APPEND_BLOCK]
              for i in range(0, int(series.shape[0]), APPEND_BLOCK)]
    ing = StreamIngestor(encoder, backend=backend)     # compile warm-up
    ing.append(blocks[0])
    ing = StreamIngestor(encoder, backend=backend)
    t0 = time.perf_counter()
    for blk in blocks:
        ing.append(blk)
    if ing.sketch is not None:
        ing.sketch.block_until_ready()
    return time.perf_counter() - t0


def _append_rows() -> None:
    series = _append_workload()
    cs_db, exact_db = _sshcs_db(), tsdb_cached(KIND, LENGTH)
    for label, db in (("ssh-cs", cs_db), ("ssh", exact_db)):
        secs = _time_append(db.index.enc, series, db.index.build_backend)
        report(f"ingest/{KIND}/len{LENGTH}/append/{label}",
               secs / N_APPEND * 1e6,
               {"series_per_s": round(N_APPEND / secs, 1),
                "block": APPEND_BLOCK, "n_series": N_APPEND,
                "sketching": label == "ssh-cs"},
               case=case_for(KIND, LENGTH, N_APPEND, spec=db.spec,
                             config=db.config))


def _merge_rows() -> None:
    from repro.streaming import StreamIngestor
    series = _append_workload()
    db = _sshcs_db()
    enc, backend = db.index.enc, db.index.build_backend
    for shards in SHARD_COUNTS:
        per = int(series.shape[0]) // shards
        ingestors = []
        for s in range(shards):
            ing = StreamIngestor(enc, shard=f"shard{s}", backend=backend)
            ing.append(series[s * per:(s + 1) * per], seq=s)
            ingestors.append(ing)
        best = float("inf")
        for _ in range(N_MERGE_ROUNDS):
            t0 = time.perf_counter()
            merged = StreamIngestor.merge_all(ingestors)
            merged.sketch.block_until_ready()
            best = min(best, time.perf_counter() - t0)
        report(f"ingest/{KIND}/len{LENGTH}/merge/shards{shards}",
               best * 1e6,
               {"shards": shards, "n_series": len(merged),
                "us_per_merge": round(best * 1e6 / (shards - 1), 1)},
               case=case_for(KIND, LENGTH, len(merged), spec=db.spec,
                             config=db.config))


def _memory_row() -> None:
    cs_db, exact_db = _sshcs_db(), tsdb_cached(KIND, LENGTH)
    cs_bytes, exact_bytes = cs_db.index.nbytes(), exact_db.index.nbytes()
    report(f"ingest/{KIND}/len{LENGTH}/memory", 0.0,
           {"index_bytes_sshcs": cs_bytes,
            "index_bytes_ssh": exact_bytes,
            "encoder_bytes_sshcs": _encoder_state_bytes(cs_db.index.enc),
            "encoder_bytes_ssh": _encoder_state_bytes(exact_db.index.enc),
            "encoder_ratio": round(
                _encoder_state_bytes(exact_db.index.enc)
                / _encoder_state_bytes(cs_db.index.enc), 3)},
           case=case_for(KIND, LENGTH, len(cs_db), spec=cs_db.spec,
                         config=cs_db.config))


def run() -> None:
    _hotpath()
    _append_rows()
    _merge_rows()
    _memory_row()


if __name__ == "__main__":
    run()
