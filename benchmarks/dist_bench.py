"""Resilient distributed tier under failure — p99, recovery, zero loss.

The claim this bench gates (DESIGN.md §11): with one **dead** worker and
one **10×-slow** worker injected into a replicated fleet, queries still
answer **bit-identically** to the healthy run (ids AND distances — the
replicas restore the same published shard artifacts and the merge is a
stable sort), and tail latency degrades by a bounded factor (hedging
re-issues the slow shard's call to its replica instead of waiting the
full injected delay; failover re-issues the dead worker's calls
immediately).  A third scenario drains a worker out of a live
``ServingEngine`` mid-stream and counts lost queries — the drain
protocol must lose **zero**.

Rows (CSV: name, us_per_query, derived):

* ``dist/<kind>/len<L>/hotpath`` — stage-instrumented sequential row
  (shared hot-path breakdown every BENCH json carries);
* ``.../healthy``  — replicated fleet, no faults: p50/p99 baseline;
* ``.../faulty``   — one dead + one 10×-slow worker: p50/p99 under
  failure, ``p99_ratio`` vs healthy (gated by ``--max-p99-degradation``),
  ``hedged_total``/``failovers_total``, ``recovered_identical``;
* ``.../drain``    — live engine drain mid-stream: ``lost_queries``
  (must be 0), ``rebalanced_shards``.

Single-thread XLA pinning as in serving_bench: stable CPU timings.
"""
from __future__ import annotations

import os
import time

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PARAMS, case_for, dataset_cached as dataset,
                               hotpath_report, percentile, report,
                               search_config)
from repro.core import SSHIndex
from repro.fleet import FleetSearcher

KIND, LENGTH = "ecg", 128
N_INVOCATIONS = 50           # single-query fleet calls per scenario
REPLICATION, FLEET_WORKERS = 2, 4
TOP_C = 128
# hedge floor well under the injected 10x delay but above healthy shard
# time: the slow shard's call is re-issued to its replica almost
# immediately, so p99-under-failure rides the hedge, not the delay
HEDGE_MS = 5.0
DELAY_FACTOR = 10.0          # slow worker: 10x the healthy mean shard time


def _fleet_config(kind: str, length: int):
    return search_config(kind, length, top_c=TOP_C, multiprobe_offsets=1,
                         replication=REPLICATION,
                         fleet_workers=FLEET_WORKERS,
                         hedge_policy="adaptive", hedge_ms=HEDGE_MS)


def _run_queries(fleet, queries):
    """((ids, dists) stacked over calls, per-call latency samples µs)."""
    ids, dists, samples = [], [], []
    for i in range(N_INVOCATIONS):
        q = queries[i % len(queries)]
        t0 = time.perf_counter()
        res = fleet.search_batch(q[None, :])
        samples.append((time.perf_counter() - t0) * 1e6)
        ids.append(np.asarray(res.ids[0]))
        dists.append(np.asarray(res.dists[0]))
    return np.stack(ids), np.stack(dists), samples


def run() -> None:
    base = f"dist/{KIND}/len{LENGTH}"
    hotpath_report(f"{base}/hotpath", KIND, LENGTH)

    db, queries = dataset(KIND, LENGTH)
    cfg = _fleet_config(KIND, LENGTH)
    index = SSHIndex.build(db, spec=PARAMS[KIND].to_spec())
    case = case_for(KIND, LENGTH, int(db.shape[0]), config=cfg)

    fleet = FleetSearcher(index, cfg)
    try:
        for q in queries:                       # warm compiled shard shapes
            fleet.search_batch(q[None, :])      # (also seeds policy EWMAs)

        h_ids, h_dists, h_samples = _run_queries(fleet, queries)
        h_p50 = percentile(h_samples, 50)
        h_p99 = percentile(h_samples, 99)
        report(f"{base}/healthy", float(np.mean(h_samples)),
               {"p50_us": round(h_p50, 1), "p99_us": round(h_p99, 1),
                "replication": REPLICATION, "workers": FLEET_WORKERS,
                "n_shards": fleet.n_shards},
               samples_us=h_samples, case=case)

        # one dead worker + one worker slowed to DELAY_FACTOR x the
        # healthy mean shard time (from the straggler policy's EWMAs).
        # The dead worker exercises failover (its shards' primaries
        # error instantly); the slow one is a primary of *different*
        # shards, so its delay is absorbed by hedging, not failover
        mean_shard_s = float(np.mean(list(fleet.policy.ewma.values())))
        hedged0, failovers0 = fleet.hedged_total, fleet.failovers_total
        dead = fleet.plan.primary(0)
        slow = next((fleet.plan.primary(s) for s in range(fleet.n_shards)
                     if dead not in fleet.plan.replicas(s)),
                    next(w for w in sorted(fleet.workers) if w != dead))
        fleet.injector.kill(dead)
        fleet.injector.delay(slow, DELAY_FACTOR * mean_shard_s * 1e3)
        f_ids, f_dists, f_samples = _run_queries(fleet, queries)
        fleet.injector.clear()

        identical = bool(np.array_equal(f_ids, h_ids)
                         and np.array_equal(f_dists, h_dists))
        if not identical:
            raise AssertionError(
                "faulty-run top-k diverged from the healthy run — the "
                "fleet recovery path returned different ids/distances")
        f_p99 = percentile(f_samples, 99)
        report(f"{base}/faulty", float(np.mean(f_samples)),
               {"p50_us": round(percentile(f_samples, 50), 1),
                "p99_us": round(f_p99, 1),
                "p99_ratio": round(f_p99 / max(h_p99, 1e-9), 3),
                "hedged_total": fleet.hedged_total - hedged0,
                "failovers_total": fleet.failovers_total - failovers0,
                "recovered_identical": identical,
                "dead_worker": dead, "slow_worker": slow,
                "delay_ms": round(DELAY_FACTOR * mean_shard_s * 1e3, 2)},
               samples_us=f_samples, case=case)
    finally:
        fleet.close()

    _drain_scenario(index, cfg, queries, base, case)


def _drain_scenario(index, cfg, queries, base: str, case) -> None:
    """Drain a worker out of a live engine mid-stream; count lost
    queries (the acceptance criterion: zero)."""
    from repro.serving import ServingEngine
    n_requests = N_INVOCATIONS
    engine = ServingEngine(index, cfg.replace(batch_policy=cfg.batch_policy
                                          .replace(max_batch=4, max_wait_ms=1.0)))
    engine.search_batch(queries)                # warm outside the window
    t0 = time.perf_counter()
    with engine:
        futs = [engine.submit(queries[i % len(queries)])
                for i in range(n_requests)]
        moved = engine.drain(sorted(engine.searcher.workers)[0])
        results = [f.result(timeout=300) for f in futs]
    wall = time.perf_counter() - t0
    lost = n_requests - len(results)
    if lost:
        raise AssertionError(f"{lost} queries lost through engine drain")
    snap = engine.metrics.snapshot()
    report(f"{base}/drain", wall / n_requests * 1e6,
           {"lost_queries": lost, "rebalanced_shards": moved,
            "hedged_total": int(snap["hedged_total"]),
            "failovers_total": int(snap["failovers_total"]),
            "n_requests": n_requests},
           case=case)


if __name__ == "__main__":
    run()
