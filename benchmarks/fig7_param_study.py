"""Paper Figs 7-12: parameter studies — accuracy + preprocessing time as a
function of W (filter width), δ (stride), n (shingle length)."""
from __future__ import annotations

import dataclasses
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PARAMS, band_for, case_for,
                               dataset_cached as dataset,
                               gold_topk_cached, report, search_config,
                               stage_mean_us)
from repro.core import (SSHIndex, brute_force_topk, precision_at_k,
                        ssh_search)

from benchmarks.common import SCALE

LENGTH = 256
SWEEPS = {
    "W": [10, 20, 40, 80, 120],
    "delta": [1, 2, 3, 5, 8],
    "n": [4, 8, 12, 15, 18],
} if SCALE != "smoke" else {        # trimmed sweep keeps the shape of
    "W": [20, 80, 120],             # Figs 7-12 at tractable CPU cost
    "delta": [1, 3, 8],
    "n": [4, 12, 18],
}


def _study(kind: str, param: str, values) -> None:
    db, queries = dataset(kind, LENGTH)
    band = band_for(LENGTH)
    if SCALE == "smoke":
        db = db[: len(db) // 2]     # halve the index-build cost
        golds = [brute_force_topk(q, db, 10, band=band)[0]
                 for q in queries]  # gold must match the halved db
    else:
        golds = gold_topk_cached(kind, LENGTH, 10, band)
    base = PARAMS[kind]
    for v in values:
        if param == "W" and v >= LENGTH:
            continue
        params = dataclasses.replace(
            base,
            window=v if param == "W" else min(base.window, LENGTH // 2),
            step=v if param == "delta" else base.step,
            ngram=v if param == "n" else base.ngram)
        t0 = time.perf_counter()
        index = SSHIndex.build(db, spec=params.to_spec())
        jnp.asarray(index.signatures).block_until_ready()
        t_build = time.perf_counter() - t0
        # multiprobe tracks the *swept* stride (δ-residue classes)
        cfg = search_config(kind, LENGTH,
                            multiprobe_offsets=params.step)
        ssh_search(queries[0], index, config=cfg)    # warm compiles
        results = [ssh_search(q, index, config=cfg) for q in queries]
        precs = [precision_at_k(r.ids, g, 10)
                 for r, g in zip(results, golds)]
        report(f"fig_param/{kind}/{param}={v}",
               t_build / db.shape[0] * 1e6,
               {"precision_at10": round(float(np.mean(precs)), 3),
                "build_s": round(t_build, 3)},
               precision_at_k=float(np.mean(precs)),
               build_s=t_build,
               stats=results[-1].stats,
               stage_us=stage_mean_us([r.stats for r in results]),
               case=case_for(kind, LENGTH, int(db.shape[0]),
                             spec=params.to_spec(), config=cfg))


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        for param, values in SWEEPS.items():
            _study(kind, param, values)


if __name__ == "__main__":
    run()
