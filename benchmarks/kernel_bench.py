"""Kernel micro-benchmarks: Pallas(interpret)-vs-ref correctness timing is
meaningless on CPU, so this measures the REF path wall time (the CPU
production path) and reports the kernels' analytic TPU roofline instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, timed
from repro.kernels import ref


def run() -> None:
    rng = np.random.default_rng(0)
    # sketch_conv: paper ECG setting
    x = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    filt = jnp.asarray(rng.normal(size=(80, 1)), jnp.float32)
    _, t = timed(ref.sketch_conv_ref, x, filt, 3)
    flops = 2 * 256 * ((2048 - 80) // 3 + 1) * 80
    emit("kernel/sketch_conv/ref", t * 1e6,
         {"gflops": round(flops / t / 1e9, 2),
          "tpu_bound": "memory (AI≈27 FLOP/B at F=1)"})

    # dtw rerank: 1024 candidates x 2048, band 102
    q = jnp.asarray(rng.normal(size=512), jnp.float32)
    c = jnp.asarray(rng.normal(size=(128, 512)), jnp.float32)
    _, t = timed(lambda: ref.dtw_wavefront_ref(q, c, band=26))
    cells = 128 * 512 * 53
    emit("kernel/dtw_rerank/ref", t * 1e6,
         {"mcells_per_s": round(cells / t / 1e6, 1),
          "tpu_kernel": "wavefront: 2m steps x (band,128) VPU tiles"})

    # collision count: 1M x 40
    db = jnp.asarray(rng.integers(0, 1 << 30, (1_000_000, 40)), jnp.int32)
    qk = jnp.asarray(rng.integers(0, 1 << 30, (40,)), jnp.int32)
    _, t = timed(lambda: ref.collision_count_ref(qk, db))
    emit("kernel/collision_count/ref", t * 1e6,
         {"gB_per_s": round(db.nbytes / t / 1e9, 2),
          "tpu_bound": "HBM bandwidth"})


if __name__ == "__main__":
    run()
