"""Kernel micro-benchmarks: Pallas(interpret)-vs-ref correctness timing is
meaningless on CPU, so this measures the REF path wall time (the CPU
production path) and reports the kernels' analytic TPU roofline instead."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import hotpath_report, report, timed
from repro.core import lower_bounds as lb
from repro.core import rerank as rr
from repro.kernels import ref


def _bench_rerank_path(rng) -> None:
    """The wired re-rank pipeline (cascade thinning + survivor DTW) as the
    query path runs it — jnp backend, i.e. the CPU production path."""
    m, c, band, topk = 256, 512, 13, 10
    # candidate block as the hash probe delivers it: near-neighbours
    # ranked first (perturbed copies of the query's walk), the tail
    # unrelated walks — so the seed best-so-far is tight and the cascade
    # has something to prune, as on real traffic
    base = np.cumsum(rng.normal(size=m))
    near = base[None, :] + rng.normal(size=(16, m)) * 0.2
    far = np.cumsum(rng.normal(size=(c - 16, m)), axis=1)
    cands = jnp.asarray(np.concatenate([near, far]), jnp.float32)
    q = jnp.asarray(base + rng.normal(size=m) * 0.1, jnp.float32)
    cu, cl = lb.envelope(cands, band)

    def pipeline():
        seed = rr.dtw_candidates(q, cands[:topk], band, "jnp")
        best = jnp.max(seed)
        k1, k2, k3 = lb.cascade_staged(q, cands, band, best, cu, cl)
        keep = np.array(k1 & k2 & k3)      # writable copy
        keep[:topk] = True
        surv = cands[jnp.asarray(keep)]
        return rr.dtw_candidates(q, surv, band, "jnp")

    d, t = timed(pipeline)
    n_surv = int(d.shape[0])
    _, t_full = timed(lambda: rr.dtw_candidates(q, cands, band, "jnp"))
    report("kernel/rerank_pipeline/jnp", t * 1e6,
         {"survivors": n_surv, "of": c,
          "lb_pruned_frac": round(1 - n_surv / c, 3),
          "speedup_vs_no_cascade": round(t_full / t, 2),
          "tpu_kernel": "cascade gathers + dtw_wavefront, one backend knob"})

    # early-abandoning DTW: the same survivor block with the seed
    # threshold threaded into the kernel (PrunedDTW) vs without.
    # Hopeless lanes stop once their running anti-diagonal minimum
    # exceeds the threshold; results on the kept lanes are identical.
    seed = rr.dtw_candidates(q, cands[:topk], band, "jnp")
    thr = jnp.sort(seed)[topk - 1]
    d_off, t_off = timed(
        lambda: rr.dtw_candidates(q, cands, band, "jnp"))
    d_on, t_on = timed(
        lambda: rr.dtw_candidates(q, cands, band, "jnp", threshold=thr))
    abandoned = int(np.sum(np.asarray(d_on) >= 1e29))
    report("kernel/dtw_early_abandon/jnp", t_on * 1e6,
         {"abandoned_frac": round(abandoned / c, 3), "of": c,
          "speedup_vs_no_abandon": round(t_off / t_on, 2),
          "tpu_kernel": "wavefront while_loop exits a lane block once "
                        "min(prev two diagonals) > per-lane threshold"})

    # pair-flattened survivor DTW (the batched serving shape)
    qs = jnp.asarray(rng.normal(size=(256, m)), jnp.float32)
    cs = jnp.asarray(rng.normal(size=(256, m)), jnp.float32)
    _, t = timed(lambda: rr.dtw_pairs_chunked(qs, cs, band, "jnp"))
    cells = 256 * m * (2 * band + 1)
    report("kernel/dtw_pairs/ref", t * 1e6,
         {"mcells_per_s": round(cells / t / 1e6, 1),
          "tpu_kernel": "pairs wavefront: query per lane beside candidate"})


def _bench_signature_build(rng) -> None:
    """End-to-end signature build (sketch → shingle → CWS) through the
    encoder facade at the paper's ECG setting, jnp vs Pallas sketch
    stage.  Off-TPU the Pallas kernel runs in interpret mode, so the
    jnp row is the CPU production number and the pallas row is a
    correctness-path timing, not a speedup claim."""
    from repro.encoders import IndexSpec, make_encoder
    b, m = 64, 2048
    spec = IndexSpec(encoder="ssh",
                     params=dict(window=80, step=3, ngram=15,
                                 num_hashes=40, num_tables=20))
    enc = make_encoder(spec, length=m)
    xs = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
    for backend in ("jnp", "pallas"):
        _, t = timed(lambda: enc.encode_batch(xs, backend=backend),
                     warmup=1, iters=1 if backend == "pallas" else 3)
        report(f"kernel/signature_build/{backend}", t * 1e6,
             {"signatures_per_s": round(b / t, 1),
              "tpu_kernel": "sketch_conv strided-matvec feeds the MXU; "
                            "CWS scan keeps B shardable"})


def run() -> None:
    rng = np.random.default_rng(0)
    # sketch_conv: paper ECG setting
    x = jnp.asarray(rng.normal(size=(256, 2048)), jnp.float32)
    filt = jnp.asarray(rng.normal(size=(80, 1)), jnp.float32)
    _, t = timed(ref.sketch_conv_ref, x, filt, 3)
    flops = 2 * 256 * ((2048 - 80) // 3 + 1) * 80
    report("kernel/sketch_conv/ref", t * 1e6,
         {"gflops": round(flops / t / 1e9, 2),
          "tpu_bound": "memory (AI≈27 FLOP/B at F=1)"})

    # dtw rerank: 1024 candidates x 2048, band 102
    q = jnp.asarray(rng.normal(size=512), jnp.float32)
    c = jnp.asarray(rng.normal(size=(128, 512)), jnp.float32)
    _, t = timed(lambda: ref.dtw_wavefront_ref(q, c, band=26))
    cells = 128 * 512 * 53
    report("kernel/dtw_rerank/ref", t * 1e6,
         {"mcells_per_s": round(cells / t / 1e6, 1),
          "tpu_kernel": "wavefront: 2m steps x (band,128) VPU tiles"})

    # collision count: 1M x 40
    db = jnp.asarray(rng.integers(0, 1 << 30, (1_000_000, 40)), jnp.int32)
    qk = jnp.asarray(rng.integers(0, 1 << 30, (40,)), jnp.int32)
    _, t = timed(lambda: ref.collision_count_ref(qk, db))
    report("kernel/collision_count/ref", t * 1e6,
         {"gB_per_s": round(db.nbytes / t / 1e9, 2),
          "tpu_bound": "HBM bandwidth"})

    _bench_rerank_path(rng)
    _bench_signature_build(rng)
    # the end-to-end hot path with the per-stage breakdown the kernels
    # above feed (encode -> probe -> lb -> dtw, shared cached index)
    hotpath_report("kernel/hotpath/ecg/len128", "ecg", 128)


if __name__ == "__main__":
    run()
