"""Paper Table 3: query wall time — SSH vs the (vectorised) UCR suite vs
brute force.  Paper shows ~20x at length 2048; the ratio is what matters
(absolute times here are single-CPU jax, not the paper's C++)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LENGTHS, PARAMS, band_for,
                               dataset_cached as dataset, emit, timed,
                               search_config)
from repro.core import brute_force_topk, ucr_search
from repro.db import TimeSeriesDB


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        params = PARAMS[kind]
        for length in LENGTHS:
            db, queries = dataset(kind, length)
            band = band_for(length)
            # facade build precomputes the envelopes at config.band:
            # LB_Keogh2 needs no per-query candidate envelopes (§3);
            # the "local" searcher is the sequential path under timing
            cfg = search_config(kind, length, searcher="local")
            tsdb = TimeSeriesDB.build(db, spec=params.to_spec(), config=cfg)
            q = queries[0]
            res, t_ssh = timed(lambda: tsdb.search(q), warmup=1, iters=2)
            _, t_ucr = timed(
                lambda: ucr_search(q, db, topk=10, band=band),
                warmup=1, iters=2)
            _, t_brute = timed(
                lambda: brute_force_topk(q, db, 10, band=band),
                warmup=1, iters=1)
            emit(f"table3/{kind}/len{length}", t_ssh * 1e6,
                 {"ssh_s": round(t_ssh, 4), "ucr_s": round(t_ucr, 4),
                  "brute_s": round(t_brute, 4),
                  "speedup_vs_ucr": round(t_ucr / t_ssh, 2),
                  "speedup_vs_brute": round(t_brute / t_ssh, 2),
                  "lb_pruned_frac": round(res.stats.lb_pruned_frac, 3),
                  "rerank_backend": res.stats.backend})


if __name__ == "__main__":
    run()
