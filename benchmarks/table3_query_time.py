"""Paper Table 3: query wall time — SSH vs the (vectorised) UCR suite vs
brute force.  Paper shows ~20x at length 2048; the ratio is what matters
(absolute times here are single-CPU jax, not the paper's C++)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LENGTHS, band_for, case_for,
                               dataset_cached as dataset, percentile,
                               report, stage_mean_us, timed,
                               timed_search_samples, tsdb_cached)
from repro.core import brute_force_topk, ucr_search


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        for length in LENGTHS:
            db, queries = dataset(kind, length)
            band = band_for(length)
            # facade build precomputes the envelopes at config.band:
            # LB_Keogh2 needs no per-query candidate envelopes (§3);
            # the "local" searcher is the sequential path under timing
            tsdb = tsdb_cached(kind, length)
            results, samples_us = timed_search_samples(tsdb.search,
                                                       queries)
            t_ssh = float(np.mean(samples_us)) / 1e6
            q = queries[0]
            _, t_ucr = timed(
                lambda: ucr_search(q, db, topk=10, band=band),
                warmup=1, iters=2)
            _, t_brute = timed(
                lambda: brute_force_topk(q, db, 10, band=band),
                warmup=1, iters=1)
            res = results[-1]
            report(f"table3/{kind}/len{length}", t_ssh * 1e6,
                   {"ssh_s": round(t_ssh, 4), "ucr_s": round(t_ucr, 4),
                    "brute_s": round(t_brute, 4),
                    "speedup_vs_ucr": round(t_ucr / t_ssh, 2),
                    "speedup_vs_brute": round(t_brute / t_ssh, 2),
                    "ssh_p95_us": round(percentile(samples_us, 95), 1),
                    "lb_pruned_frac": round(res.stats.lb_pruned_frac, 3),
                    "rerank_backend": res.stats.backend},
                   stats=res.stats,
                   stage_us=stage_mean_us([r.stats for r in results]),
                   samples_us=samples_us,
                   case=case_for(kind, length, len(tsdb), spec=tsdb.spec,
                                 config=tsdb.config))


if __name__ == "__main__":
    run()
