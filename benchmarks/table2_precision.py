"""Paper Table 2 + Fig 6: precision/NDCG for top-k retrieval (gold = exact
DTW), reported per encoder through the one ``repro.encoders`` facade —
``ssh`` (the paper's pipeline), ``srp`` (the §5.2 no-alignment baseline,
formerly an ad-hoc branch), and ``ssh-multires`` (beyond-paper
concatenated shingle resolutions)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LENGTHS, PARAMS, band_for, case_for,
                               dataset_cached, gold_topk_cached, report,
                               search_config, stage_mean_us)
from repro.core import ndcg_at_k, precision_at_k
from repro.db import TimeSeriesDB
from repro.encoders import IndexSpec

KS = (5, 10, 20)


def _specs(kind: str) -> dict:
    p = PARAMS[kind]
    base = p.to_spec()
    return {
        "ssh": base,
        # SRP hashes the raw series; K matches the historical baseline
        "srp": IndexSpec(encoder="srp", seed=0),
        # second shingle resolution at 2/3 of the paper's n
        "ssh-multires": IndexSpec(
            encoder="ssh-multires",
            params={**{k: v for k, v in base.params.items()
                       if k != "ngram"},
                    "ngrams": (max(4, p.ngram * 2 // 3), p.ngram)}),
    }


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        for length in LENGTHS:
            db_series, queries = dataset_cached(kind, length)
            band = band_for(length)
            dbs = {}
            for name, spec in _specs(kind).items():
                # the facade clamps multiprobe for encoders without
                # shift-alignment classes ("srp"); the sequential
                # searcher surfaces per-query SearchStats (stage
                # telemetry for the BENCH trajectory)
                dbs[name] = TimeSeriesDB.build(
                    db_series, spec=spec,
                    config=search_config(kind, length, searcher="local"))
            for k in KS:
                golds = gold_topk_cached(kind, length, k, band)
                rows = {}
                ssh_prec, ssh_results = 0.0, []
                for name, db in dbs.items():
                    # srp keeps the paper's §5.2 semantics: top-k purely
                    # by Hamming ranking (top_c=k), DTW only ordering
                    # that set — not candidate recall at the arch top_c
                    db.reconfigure(topk=k,
                                   **({"top_c": k} if name == "srp"
                                      else {}))
                    prec, ndcg, results = [], [], []
                    db.search(queries[0])      # warm the compiled shapes
                    for q, gold in zip(queries, golds):
                        res = db.search(q)
                        results.append(res)
                        prec.append(precision_at_k(res.ids, gold, k))
                        ndcg.append(ndcg_at_k(res.ids, gold, k))
                    rows[f"{name}_precision"] = round(float(np.mean(prec)),
                                                      3)
                    if name == "ssh":
                        rows["ssh_ndcg"] = round(float(np.mean(ndcg)), 3)
                        ssh_prec, ssh_results = float(np.mean(prec)), \
                            results
                ssh_db = dbs["ssh"]
                report(f"table2/{kind}/len{length}/top{k}",
                       float(np.mean([r.wall_seconds
                                      for r in ssh_results])) * 1e6,
                       rows, precision_at_k=ssh_prec,
                       stats=ssh_results[-1].stats,
                       stage_us=stage_mean_us([r.stats
                                               for r in ssh_results]),
                       case=case_for(kind, length, len(ssh_db), spec=ssh_db.spec,
                                     config=ssh_db.config))


if __name__ == "__main__":
    run()
