"""Paper Table 2 + Fig 6: precision/NDCG of SSH vs SRP for top-k retrieval
(gold = exact DTW)."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import (LENGTHS, PARAMS, band_for,
                               dataset_cached, gold_topk_cached, emit,
                               search_config)
from repro.core import (SSHIndex, brute_force_topk, ndcg_at_k,
                        precision_at_k, srp_search, ssh_search)
from repro.core.srp import make_srp, srp_bits

KS = (5, 10, 20)


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        params = PARAMS[kind]
        for length in LENGTHS:
            db, queries = dataset_cached(kind, length)
            band = band_for(length)
            index = SSHIndex.build(db, params)
            planes = make_srp(jax.random.PRNGKey(0), 64, length)
            db_bits = srp_bits(db, planes)
            for k in KS:
                cfg = search_config(kind, length, topk=k)
                ssh_p, ssh_n, srp_p = [], [], []
                golds = gold_topk_cached(kind, length, k, band)
                for q, gold in zip(queries, golds):
                    res = ssh_search(q, index, config=cfg)
                    ssh_p.append(precision_at_k(res.ids, gold, k))
                    ssh_n.append(ndcg_at_k(res.ids, gold, k))
                    res2 = srp_search(q, db, planes, db_bits, topk=k)
                    srp_p.append(precision_at_k(res2.ids, gold, k))
                emit(f"table2/{kind}/len{length}/top{k}", 0.0,
                     {"ssh_precision": round(float(np.mean(ssh_p)), 3),
                      "ssh_ndcg": round(float(np.mean(ssh_n)), 3),
                      "srp_precision": round(float(np.mean(srp_p)), 3)})


if __name__ == "__main__":
    run()
