"""Subsequence search — rolling vs naive encode, query latency, extend.

Measures the DESIGN.md §10 subsystem end to end at bench scale:

* ``encode`` — the tentpole claim: one rolling pass (shared sketch grid
  + sparse CWS) over the stream vs naively encoding every materialised
  window through the dense per-series pipeline.  ``derived.speedup`` is
  gated in CI (``--min-encode-speedup``); the naive side is timed over a
  window subset and scaled per-window (its cost is per-window constant),
  keeping the smoke run bounded.
* ``build`` — SubsequenceIndex.build wall time, windows/second.
* ``query/n<N>`` — stage-instrumented ``search`` latency at two stream
  lengths (same query, 2x the windows), including the
  ``encode_amortized`` stage (per-query share of the build-side encode —
  what a per-window encoder would pay at query time).
* ``extend`` — ``extend_stream`` µs per appended window (suffix re-roll
  + streaming fold, bit-identical to a rebuild).

CSV rows: subseq/<kind>/len<L>/<cell>, us_per_call, derived.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (GENERATORS, N_POINTS, PARAMS, case_for,
                               percentile, report, search_config,
                               stage_mean_us, timed, timed_search_samples)
from repro.data.timeseries import extract_subsequences
from repro.subseq import SubsequenceIndex, rolling_signatures

KIND, LENGTH = "ecg", 128
HOP = 6                  # divisible by the ECG δ=3 → aligned rolling path
N_NAIVE_MAX = 256        # naive-encode timing subset (per-window constant)
TAIL_POINTS = 600        # extend_stream workload

_IDX_CACHE = {}


def _stream(n_points: int) -> np.ndarray:
    return np.asarray(GENERATORS[KIND](n_points, seed=3), np.float32)


def _config(**over):
    base = search_config(KIND, LENGTH, searcher="local")
    return dataclasses.replace(base, subseq_window=LENGTH, subseq_hop=HOP,
                               stage_timings=True, **over)


def _index(n_points: int) -> SubsequenceIndex:
    if n_points not in _IDX_CACHE:
        _IDX_CACHE[n_points] = SubsequenceIndex.build(
            _stream(n_points), PARAMS[KIND].to_spec(),
            length=LENGTH, hop=HOP)
    return _IDX_CACHE[n_points]


def _case(idx: SubsequenceIndex, cfg):
    return case_for(KIND, LENGTH, idx.num_windows,
                    spec=idx.encoder.spec, config=cfg)


def _build_row() -> None:
    _IDX_CACHE.pop(N_POINTS, None)      # cold cache, but warm compile
    SubsequenceIndex.build(_stream(N_POINTS), PARAMS[KIND].to_spec(),
                           length=LENGTH, hop=HOP)
    t0 = time.perf_counter()
    idx = SubsequenceIndex.build(_stream(N_POINTS), PARAMS[KIND].to_spec(),
                                 length=LENGTH, hop=HOP)
    build_s = time.perf_counter() - t0
    _IDX_CACHE[N_POINTS] = idx
    report(f"subseq/{KIND}/len{LENGTH}/build",
           build_s / idx.num_windows * 1e6,
           {"windows_per_s": round(idx.num_windows / build_s, 1),
            "n_windows": idx.num_windows, "hop": HOP,
            "stream_points": N_POINTS,
            "index_bytes": idx.nbytes()},
           build_s=build_s, case=_case(idx, _config()))


def _encode_row() -> None:
    """rolling_signatures over the stream vs per-window dense encode."""
    idx = _index(N_POINTS)
    enc, backend = idx.encoder, idx.build_backend
    stream = jnp.asarray(idx.stream)
    nw = idx.num_windows

    _, roll_s = timed(
        lambda: rolling_signatures(stream, enc, LENGTH, HOP,
                                   backend=backend),
        warmup=1, iters=3)
    roll_us_per_window = roll_s / nw * 1e6

    n_naive = min(nw, N_NAIVE_MAX)
    windows = jnp.asarray(extract_subsequences(
        idx.stream, LENGTH, stride=HOP, max_count=n_naive, znorm=False))
    _, naive_s = timed(
        lambda: enc.encode_chunked(windows, backend=backend),
        warmup=1, iters=1)
    naive_us_per_window = naive_s / n_naive * 1e6

    speedup = naive_us_per_window / roll_us_per_window
    report(f"subseq/{KIND}/len{LENGTH}/encode", roll_us_per_window,
           {"speedup": round(speedup, 2),
            "naive_us_per_window": round(naive_us_per_window, 2),
            "rolling_us_per_window": round(roll_us_per_window, 2),
            "n_windows": nw, "n_naive": n_naive, "hop": HOP},
           case=_case(idx, _config()))


def _query_rows() -> None:
    """Stage-instrumented search at two stream lengths."""
    cfg = _config()
    rng = np.random.default_rng(0)
    for n_points in (N_POINTS, 2 * N_POINTS):
        idx = _index(n_points)
        offs = rng.integers(0, idx.stream.shape[0] - LENGTH, 2)
        queries = [jnp.asarray(idx.stream[o:o + LENGTH]) for o in offs]
        results, samples_us = timed_search_samples(
            lambda q: idx.search(q, cfg), queries)
        report(f"subseq/{KIND}/len{LENGTH}/query/n{n_points}",
               float(np.mean(samples_us)),
               {"p50_us": round(percentile(samples_us, 50), 1),
                "p95_us": round(percentile(samples_us, 95), 1),
                "n_windows": idx.num_windows,
                "n_samples": len(samples_us)},
               stats=results[-1].stats,
               stage_us=stage_mean_us([r.stats for r in results]),
               samples_us=samples_us, case=_case(idx, cfg))


def _extend_row() -> None:
    # private index: extend_stream mutates, keep the shared ones pristine
    idx = SubsequenceIndex.build(_stream(N_POINTS), PARAMS[KIND].to_spec(),
                                 length=LENGTH, hop=HOP)
    full = _stream(N_POINTS + 2 * TAIL_POINTS)
    idx.extend_stream(full[N_POINTS:N_POINTS + TAIL_POINTS])  # compile warm
    tail = full[N_POINTS + TAIL_POINTS:]
    t0 = time.perf_counter()
    n_new = idx.extend_stream(tail)
    secs = time.perf_counter() - t0
    report(f"subseq/{KIND}/len{LENGTH}/extend",
           secs / max(n_new, 1) * 1e6,
           {"n_new_windows": n_new, "tail_points": TAIL_POINTS,
            "windows_per_s": round(n_new / secs, 1),
            "n_windows": idx.num_windows},
           case=_case(idx, _config()))


def run() -> None:
    _build_row()
    _encode_row()
    _query_rows()
    _extend_row()


if __name__ == "__main__":
    run()
