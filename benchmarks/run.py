"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table3] [BENCH_SCALE=small]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
"""
import argparse
import sys
import time


MODULES = [
    ("table1_lb_pruning", "Table 1: LB pruning collapse vs length"),
    ("table2_precision", "Table 2 + Fig 6: SSH vs SRP precision/NDCG"),
    ("table3_query_time", "Table 3: query time SSH vs UCR vs brute"),
    ("table4_pruning", "Table 4: candidates pruned"),
    ("fig7_param_study", "Figs 7-12: W / delta / n parameter studies"),
    ("kernel_bench", "kernel micro-benchmarks"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", type=str, default=None)
    args = ap.parse_args()
    t0 = time.time()
    for mod_name, desc in MODULES:
        if args.only and args.only not in mod_name:
            continue
        print(f"# === {mod_name}: {desc} ===", flush=True)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t = time.time()
        mod.run()
        print(f"# {mod_name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
