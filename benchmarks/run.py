"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table3] [--scale smoke]
      [--json] [--out DIR] [--baseline [DIR]] [--threshold F]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
With ``--json``, additionally writes one schema-validated
``BENCH_<module>.json`` trajectory file per module (repro.bench), each
carrying the per-stage encode/probe/lb/dtw hot-path breakdown; with
``--baseline`` the run is diffed against a committed baseline directory
and exits nonzero on perf regressions beyond the noise threshold
(the CI ``bench-smoke`` gate — DESIGN.md §8).
"""
import argparse
import os
import sys
import time

MODULES = [
    ("table1_lb_pruning", "Table 1: LB pruning collapse vs length"),
    ("table2_precision", "Table 2 + Fig 6: SSH vs SRP precision/NDCG"),
    ("table3_query_time", "Table 3: query time SSH vs UCR vs brute"),
    ("table4_pruning", "Table 4: candidates pruned"),
    ("fig7_param_study", "Figs 7-12: W / delta / n parameter studies"),
    ("kernel_bench", "kernel micro-benchmarks"),
    ("serving_bench", "serving throughput: batched engine vs sequential"),
    ("ingest_bench", "streaming ingest: sketch throughput, shard merge, "
                     "memory"),
]

#: Committed smoke-scale baseline (regenerate with
#: ``--json --scale smoke --out benchmarks/baselines/smoke``).
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "smoke")


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="SSH-repro benchmark harness (see module docstring)")
    ap.add_argument("--only", type=str, default=None,
                    help="run only modules whose name contains this "
                         "substring (errors if nothing matches)")
    ap.add_argument("--scale", choices=("smoke", "small", "full"),
                    default=None,
                    help="workload scale (overrides $BENCH_SCALE)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json trajectory files")
    ap.add_argument("--out", type=str, default=".",
                    help="directory for BENCH_*.json output (default: cwd)")
    ap.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="DIR",
                    help="diff this run against a baseline report dir "
                         f"(default when bare: {DEFAULT_BASELINE}); "
                         "exits nonzero on regression — implies --json")
    ap.add_argument("--threshold", type=float, default=None,
                    help="relative slowdown allowed before an entry is a "
                         "regression (1.0 = 2x baseline; default from "
                         "repro.bench.regression)")
    ap.add_argument("--min-us", type=float, default=None,
                    help="ignore timing entries under this many µs "
                         "(noise floor)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.scale:
        # must land before benchmarks.common is imported (it reads the
        # env at import time to size the datasets)
        if "benchmarks.common" in sys.modules \
                and sys.modules["benchmarks.common"].SCALE != args.scale:
            print("error: --scale given after benchmarks.common was "
                  "imported at a different scale", file=sys.stderr)
            return 2
        os.environ["BENCH_SCALE"] = args.scale
    if args.baseline is not None:
        args.json = True

    modules = MODULES
    if args.only:
        modules = [(m, d) for m, d in MODULES if args.only in m]
        if not modules:
            names = ", ".join(m for m, _ in MODULES)
            print(f"error: --only {args.only!r} matches no benchmark "
                  f"module; valid module names: {names}", file=sys.stderr)
            return 2

    from repro.bench import BenchRunner
    from benchmarks import common
    runner = BenchRunner(scale=common.SCALE, out_dir=args.out,
                         write_json=args.json)
    common.set_runner(runner)

    t0 = time.time()
    for mod_name, desc in modules:
        print(f"# === {mod_name}: {desc} ===", flush=True)
        runner.start_module(mod_name)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t = time.time()
        mod.run()
        path = runner.finish_module()
        if path is not None:
            print(f"# wrote {path}", flush=True)
        print(f"# {mod_name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time()-t0:.1f}s")

    if args.baseline is not None:
        return _gate(args, [m for m, _ in modules])
    return 0


def _gate(args, module_names) -> int:
    """Baseline diff over the modules that just ran; nonzero on failure."""
    from repro.bench import regression as reg
    from repro.bench import compare_dirs

    kw = {}
    if args.threshold is not None:
        kw["rel_threshold"] = args.threshold
    if args.min_us is not None:
        kw["min_us"] = args.min_us
    findings, missing = compare_dirs(args.out, args.baseline,
                                     modules=module_names, **kw)

    for f in findings:
        print(f"# baseline: {f}")
    for name in missing:
        print(f"# baseline: MISSING REPORT {name} (in baseline, not "
              "emitted by this run)")
    n_fail = len(reg.failures(findings)) + len(missing)
    if n_fail:
        print(f"# baseline: FAIL ({n_fail} regression(s)/missing "
              f"entr(ies) vs {args.baseline})")
        return 1
    print(f"# baseline: OK (no regressions vs {args.baseline})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
