"""Benchmark harness — one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only table3] [--scale smoke]
      [--json] [--out DIR] [--baseline [DIR]] [--threshold F]
      [--min-lb-pruned F] [--min-encode-speedup F]

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
With ``--json``, additionally writes one schema-validated
``BENCH_<module>.json`` trajectory file per module (repro.bench), each
carrying the per-stage encode/probe/lb/dtw hot-path breakdown; with
``--baseline`` the run is diffed against a committed baseline directory
and exits nonzero on perf regressions beyond the noise threshold
(the CI ``bench-smoke`` gate — DESIGN.md §8).
"""
import argparse
import os
import sys
import time

MODULES = [
    ("table1_lb_pruning", "Table 1: LB pruning collapse vs length"),
    ("table2_precision", "Table 2 + Fig 6: SSH vs SRP precision/NDCG"),
    ("table3_query_time", "Table 3: query time SSH vs UCR vs brute"),
    ("table4_pruning", "Table 4: candidates pruned"),
    ("fig7_param_study", "Figs 7-12: W / delta / n parameter studies"),
    ("kernel_bench", "kernel micro-benchmarks"),
    ("serving_bench", "serving throughput: batched engine vs sequential"),
    ("ingest_bench", "streaming ingest: sketch throughput, shard merge, "
                     "memory"),
    ("subseq_bench", "subsequence search: rolling vs naive encode, query "
                     "latency vs stream length"),
    ("dist_bench", "resilient fleet: p99 under dead+slow workers, "
                   "bit-identical recovery, zero-loss drain"),
    ("loadgen_bench", "closed-loop load sweep: max sustainable qps, "
                      "adaptive-vs-fixed batching at the knee"),
]

#: Committed smoke-scale baseline (regenerate with
#: ``--json --scale smoke --out benchmarks/baselines/smoke``).
DEFAULT_BASELINE = os.path.join(os.path.dirname(__file__), "baselines",
                                "smoke")


def _parse_args(argv):
    ap = argparse.ArgumentParser(
        prog="benchmarks.run",
        description="SSH-repro benchmark harness (see module docstring)")
    ap.add_argument("--only", type=str, default=None,
                    help="run only modules whose name contains this "
                         "substring (errors if nothing matches)")
    ap.add_argument("--scale", choices=("smoke", "small", "full"),
                    default=None,
                    help="workload scale (overrides $BENCH_SCALE)")
    ap.add_argument("--json", action="store_true",
                    help="write BENCH_<module>.json trajectory files")
    ap.add_argument("--out", type=str, default=".",
                    help="directory for BENCH_*.json output (default: cwd)")
    ap.add_argument("--baseline", nargs="?", const=DEFAULT_BASELINE,
                    default=None, metavar="DIR",
                    help="diff this run against a baseline report dir "
                         f"(default when bare: {DEFAULT_BASELINE}); "
                         "exits nonzero on regression — implies --json")
    ap.add_argument("--threshold", type=float, default=None,
                    help="relative slowdown allowed before an entry is a "
                         "regression (1.0 = 2x baseline; default from "
                         "repro.bench.regression)")
    ap.add_argument("--min-us", type=float, default=None,
                    help="ignore timing entries under this many µs "
                         "(noise floor)")
    ap.add_argument("--min-lb-pruned", type=float, default=None,
                    metavar="F",
                    help="fail unless every table3/ecg case pruned at "
                         "least this fraction of hash candidates before "
                         "full DTW (cascade + LB_Improved effectiveness "
                         "gate; implies --json)")
    ap.add_argument("--min-encode-speedup", type=float, default=None,
                    metavar="F",
                    help="fail unless the subseq rolling encode beat the "
                         "naive per-window encode by at least this factor "
                         "(DESIGN.md §10 tentpole gate; implies --json)")
    ap.add_argument("--max-p99-degradation", type=float, default=None,
                    metavar="F",
                    help="fail unless dist_bench's p99 with one dead and "
                         "one slow worker stayed within this factor of "
                         "the healthy p99, recovery was bit-identical, "
                         "and the engine drain lost zero queries "
                         "(DESIGN.md §11 tentpole gate; implies --json)")
    ap.add_argument("--min-sustainable-qps", type=float, default=None,
                    metavar="F",
                    help="fail unless loadgen_bench's offered-load sweep "
                         "sustained at least this many qps under its p99 "
                         "SLO, and the adaptive policy's answers at the "
                         "knee were bit-identical to fixed batching "
                         "(DESIGN.md §12 tentpole gate; implies --json)")
    return ap.parse_args(argv)


def main(argv=None) -> int:
    args = _parse_args(argv)
    if args.scale:
        # must land before benchmarks.common is imported (it reads the
        # env at import time to size the datasets)
        if "benchmarks.common" in sys.modules \
                and sys.modules["benchmarks.common"].SCALE != args.scale:
            print("error: --scale given after benchmarks.common was "
                  "imported at a different scale", file=sys.stderr)
            return 2
        os.environ["BENCH_SCALE"] = args.scale
    if args.baseline is not None or args.min_lb_pruned is not None \
            or args.min_encode_speedup is not None \
            or args.max_p99_degradation is not None \
            or args.min_sustainable_qps is not None:
        args.json = True

    modules = MODULES
    if args.only:
        modules = [(m, d) for m, d in MODULES if args.only in m]
        if not modules:
            names = ", ".join(m for m, _ in MODULES)
            print(f"error: --only {args.only!r} matches no benchmark "
                  f"module; valid module names: {names}", file=sys.stderr)
            return 2

    from repro.bench import BenchRunner
    from benchmarks import common
    runner = BenchRunner(scale=common.SCALE, out_dir=args.out,
                         write_json=args.json)
    common.set_runner(runner)

    t0 = time.time()
    for mod_name, desc in modules:
        print(f"# === {mod_name}: {desc} ===", flush=True)
        runner.start_module(mod_name)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t = time.time()
        mod.run()
        path = runner.finish_module()
        if path is not None:
            print(f"# wrote {path}", flush=True)
        print(f"# {mod_name} done in {time.time()-t:.1f}s", flush=True)
    print(f"# all benchmarks done in {time.time()-t0:.1f}s")

    rc = 0
    if args.baseline is not None:
        rc = _gate(args, [m for m, _ in modules])
    if args.min_lb_pruned is not None:
        rc = max(rc, _lb_gate(args))
    if args.min_encode_speedup is not None:
        rc = max(rc, _encode_gate(args))
    if args.max_p99_degradation is not None:
        rc = max(rc, _p99_gate(args))
    if args.min_sustainable_qps is not None:
        rc = max(rc, _sustainable_gate(args))
    return rc


def _gate(args, module_names) -> int:
    """Baseline diff over the modules that just ran; nonzero on failure."""
    from repro.bench import regression as reg
    from repro.bench import compare_dirs

    kw = {}
    if args.threshold is not None:
        kw["rel_threshold"] = args.threshold
    if args.min_us is not None:
        kw["min_us"] = args.min_us
    findings, missing = compare_dirs(args.out, args.baseline,
                                     modules=module_names, **kw)

    for f in findings:
        print(f"# baseline: {f}")
    for name in missing:
        print(f"# baseline: MISSING REPORT {name} (in baseline, not "
              "emitted by this run)")
    n_fail = len(reg.failures(findings)) + len(missing)
    if n_fail:
        print(f"# baseline: FAIL ({n_fail} regression(s)/missing "
              f"entr(ies) vs {args.baseline})")
        return 1
    print(f"# baseline: OK (no regressions vs {args.baseline})")
    return 0


def _lb_gate(args) -> int:
    """Pruning-effectiveness floor over the table3 ECG cases: the LB
    cascade + LB_Improved must spare at least ``--min-lb-pruned`` of the
    hash candidates from full DTW.  A drop below the floor means a bound
    or the seed threshold silently weakened (results would still be
    correct — the bounds are sound — but the latency claim would not
    hold)."""
    from repro.bench import load_report
    path = os.path.join(args.out, "BENCH_table3_query_time.json")
    if not os.path.exists(path):
        print("# lb-gate: SKIP (table3_query_time not in this run)")
        return 0
    checked, bad = 0, []
    for r in load_report(path).results:
        if not r.name.startswith("table3/ecg/"):
            continue
        checked += 1
        frac = r.lb_pruned_frac
        if frac is None or frac < args.min_lb_pruned:
            bad.append((r.name, frac))
        else:
            print(f"# lb-gate: {r.name} lb_pruned_frac={frac:.3f} "
                  f">= {args.min_lb_pruned}")
    for name, frac in bad:
        print(f"# lb-gate: FAIL {name} lb_pruned_frac={frac} < "
              f"{args.min_lb_pruned}")
    if bad or not checked:
        if not checked:
            print("# lb-gate: FAIL (no table3/ecg entries in report)")
        return 1
    print("# lb-gate: OK")
    return 0


def _encode_gate(args) -> int:
    """Rolling-encode advantage floor: the subseq build must amortise the
    sketch grid across overlapping windows — ``speedup`` (naive
    per-window µs / rolling per-window µs) dropping below the floor means
    the rolling path silently degraded to per-window work (e.g. the
    sparse CWS or shared-grid gather fell back to the dense pipeline)."""
    from repro.bench import load_report
    path = os.path.join(args.out, "BENCH_subseq_bench.json")
    if not os.path.exists(path):
        print("# encode-gate: SKIP (subseq_bench not in this run)")
        return 0
    checked, bad = 0, []
    for r in load_report(path).results:
        if not r.name.endswith("/encode"):
            continue
        checked += 1
        speedup = r.derived.get("speedup") if r.derived else None
        if speedup is None or float(speedup) < args.min_encode_speedup:
            bad.append((r.name, speedup))
        else:
            print(f"# encode-gate: {r.name} speedup={float(speedup):.1f}x "
                  f">= {args.min_encode_speedup}")
    for name, speedup in bad:
        print(f"# encode-gate: FAIL {name} speedup={speedup} < "
              f"{args.min_encode_speedup}")
    if bad or not checked:
        if not checked:
            print("# encode-gate: FAIL (no /encode entries in report)")
        return 1
    print("# encode-gate: OK")
    return 0


def _p99_gate(args) -> int:
    """Resilience-under-failure gate over the dist_bench scenarios: the
    p99 with one dead + one 10x-slow worker must stay within
    ``--max-p99-degradation`` of the healthy p99 (hedging/failover are
    doing their job), the recovered top-k must have been bit-identical,
    and the live engine drain must have lost zero queries."""
    from repro.bench import load_report
    path = os.path.join(args.out, "BENCH_dist_bench.json")
    if not os.path.exists(path):
        print("# p99-gate: SKIP (dist_bench not in this run)")
        return 0
    checked, bad = 0, []
    for r in load_report(path).results:
        d = r.derived or {}
        if r.name.endswith("/faulty"):
            checked += 1
            ratio = d.get("p99_ratio")
            if ratio is None or float(ratio) > args.max_p99_degradation:
                bad.append((r.name, f"p99_ratio={ratio} > "
                            f"{args.max_p99_degradation}"))
            elif not d.get("recovered_identical"):
                bad.append((r.name, "recovered_identical is false"))
            else:
                print(f"# p99-gate: {r.name} p99_ratio={float(ratio):.2f} "
                      f"<= {args.max_p99_degradation}, recovery "
                      "bit-identical")
        elif r.name.endswith("/drain"):
            checked += 1
            lost = d.get("lost_queries")
            if lost is None or int(lost) != 0:
                bad.append((r.name, f"lost_queries={lost} != 0"))
            else:
                print(f"# p99-gate: {r.name} lost_queries=0 over "
                      f"{d.get('n_requests')} requests")
    for name, why in bad:
        print(f"# p99-gate: FAIL {name} {why}")
    if bad or not checked:
        if not checked:
            print("# p99-gate: FAIL (no /faulty or /drain entries "
                  "in report)")
        return 1
    print("# p99-gate: OK")
    return 0


def _sustainable_gate(args) -> int:
    """Serving-capacity floor + answer-invariance over loadgen_bench:
    the offered-load sweep must have sustained ``--min-sustainable-qps``
    under its p99 SLO, and the adaptive policy at the knee must have
    returned bit-identical top-k to fixed batching (the adaptive control
    law is a scheduling change only — any answer drift is a bug, not a
    tuning issue)."""
    from repro.bench import load_report
    path = os.path.join(args.out, "BENCH_loadgen_bench.json")
    if not os.path.exists(path):
        print("# sustainable-gate: SKIP (loadgen_bench not in this run)")
        return 0
    checked, bad = 0, []
    for r in load_report(path).results:
        d = r.derived or {}
        if r.name.endswith("/max_sustainable"):
            checked += 1
            qps = d.get("max_sustainable_qps")
            if qps is None or float(qps) < args.min_sustainable_qps:
                bad.append((r.name, f"max_sustainable_qps={qps} < "
                            f"{args.min_sustainable_qps}"))
            else:
                print(f"# sustainable-gate: {r.name} "
                      f"max_sustainable_qps={float(qps):.1f} >= "
                      f"{args.min_sustainable_qps} (SLO p99 <= "
                      f"{d.get('slo_p99_ms')}ms)")
        elif r.name.endswith("/knee/adaptive"):
            checked += 1
            if not d.get("identical"):
                bad.append((r.name, "identical is false (adaptive "
                            "changed answers vs fixed)"))
            else:
                print(f"# sustainable-gate: {r.name} bit-identical to "
                      f"fixed, p99_ratio_vs_best_fixed="
                      f"{d.get('p99_ratio_vs_best_fixed')}")
    for name, why in bad:
        print(f"# sustainable-gate: FAIL {name} {why}")
    if bad or checked < 2:
        if checked < 2:
            print("# sustainable-gate: FAIL (missing /max_sustainable "
                  "or /knee/adaptive entries in report)")
        return 1
    print("# sustainable-gate: OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
