"""Shared benchmark scaffolding.

Scale knobs: BENCH_SCALE ∈ {"smoke", "small", "full"} via env var.  The
paper's 20M-series scale is exercised by the multi-pod dry-run; these
benchmarks validate the paper's *relative* claims at container scale.

Reporting: every module emits its historical ``name,us_per_call,derived``
CSV line through :func:`report`, which — when ``benchmarks.run`` has
installed a :class:`repro.bench.BenchRunner` (``--json``) — also records
a schema-versioned ``BenchResult`` (latency percentiles, per-stage
encode/probe/lb/dtw breakdown, pruning/quality, build time) into the
module's ``BENCH_<module>.json`` trajectory file (DESIGN.md §8).
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench import BenchCase, BenchResult, BenchRunner
from repro.core import SSHParams
from repro.data.timeseries import (extract_subsequences, random_walk,
                                   synthetic_ecg)

SCALE = os.environ.get("BENCH_SCALE", "smoke")

# points per stream / queries per (dataset, length) cell
_SCALES = {"smoke": (6_000, 2), "small": (20_000, 4), "full": (120_000, 8)}
N_POINTS, N_QUERIES = _SCALES[SCALE]

LENGTHS = {"smoke": [128, 256], "small": [128, 512, 1024],
           "full": [128, 512, 1024, 2048]}[SCALE]

PARAMS = {
    "ecg": SSHParams(window=80, step=3, ngram=15, num_hashes=40,
                     num_tables=20),
    "randomwalk": SSHParams(window=30, step=5, ngram=15, num_hashes=40,
                            num_tables=20),
}
GENERATORS = {"ecg": synthetic_ecg, "randomwalk": random_walk}


def dataset(kind: str, length: int, seed: int = 3):
    """(db (N, L) z-normed jnp array, query offsets)."""
    stream = GENERATORS[kind](N_POINTS, seed=seed)
    db = extract_subsequences(stream, length, stride=1, znorm=True)
    rng = np.random.default_rng(0)
    qoffs = rng.integers(0, len(stream) - length, N_QUERIES)
    queries = []
    for off in qoffs:
        q = stream[off:off + length].astype(np.float32)
        queries.append((q - q.mean()) / (q.std() + 1e-8))
    return jnp.asarray(db), [jnp.asarray(q) for q in queries]


_GOLD_CACHE = {}
_DS_CACHE = {}


def dataset_cached(kind: str, length: int):
    key = (kind, length)
    if key not in _DS_CACHE:
        _DS_CACHE[key] = dataset(kind, length)
    return _DS_CACHE[key]


def gold_topk_cached(kind: str, length: int, k: int, band: int):
    """Exact-DTW gold rankings, shared across benchmark modules (the
    dominant CPU cost — one brute-force pass per (dataset, length))."""
    from repro.core import brute_force_topk
    key = (kind, length, band)
    if key not in _GOLD_CACHE:
        db, queries = dataset_cached(kind, length)
        _GOLD_CACHE[key] = [brute_force_topk(q, db, 50, band=band)[0]
                            for q in queries]
    return [g[:k] for g in _GOLD_CACHE[key]]


def band_for(length: int) -> int:
    return max(4, length // 20)        # UCR-suite 5% convention


def search_config(kind: str, length: int, **overrides):
    """The arch registry's SearchConfig at the 5% band for ``length``.

    The single source of topk/top_c/band/multiprobe defaults for every
    benchmark (no hand-copied knob tuples); per-bench policy goes through
    ``overrides``.
    """
    from repro.configs import get_arch
    return get_arch(f"ssh-{kind}").search_config(length=length, **overrides)


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) \
        else None
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        leaves = jax.tree.leaves(out)
        if leaves and hasattr(leaves[0], "block_until_ready"):
            leaves[0].block_until_ready()
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: Dict) -> None:
    """CSV contract: name,us_per_call,derived"""
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{kv}", flush=True)


# ---------------------------------------------------------------------------
# structured reporting (repro.bench)
# ---------------------------------------------------------------------------

_RUNNER: Optional[BenchRunner] = None


def set_runner(runner: Optional[BenchRunner]) -> None:
    """Install the BenchRunner ``report`` records into (benchmarks.run)."""
    global _RUNNER
    _RUNNER = runner


def percentile(xs, p: float) -> float:
    """Nearest-rank percentile (matches ServingMetrics.LatencyTracker)."""
    xs = sorted(xs)
    rank = min(len(xs) - 1, max(0, round(p / 100.0 * (len(xs) - 1))))
    return float(xs[rank])


def report(name: str, us_per_query: float, derived: Dict, *,
           stats=None, case: Optional[BenchCase] = None,
           samples_us=None, stage_us: Optional[Dict[str, float]] = None,
           lb_pruned_frac: Optional[float] = None,
           precision_at_k: Optional[float] = None,
           build_s: Optional[float] = None) -> None:
    """Emit the CSV row AND record a BenchResult when a runner is live.

    ``stats`` (a ``SearchStats``) supplies the stage breakdown and
    pruning fraction unless given explicitly; ``samples_us`` (per-query
    latency samples, µs) supplies p50/p95.
    """
    emit(name, us_per_query, derived)
    if _RUNNER is None:
        return
    if stats is not None:
        if stage_us is None:
            stage_us = stats.stage_us
        if lb_pruned_frac is None:
            lb_pruned_frac = stats.lb_pruned_frac
    _RUNNER.record(BenchResult(
        name=name, us_per_query=float(us_per_query),
        us_p50=percentile(samples_us, 50) if samples_us else None,
        us_p95=percentile(samples_us, 95) if samples_us else None,
        stage_us=stage_us,
        lb_pruned_frac=(None if lb_pruned_frac is None
                        else float(lb_pruned_frac)),
        precision_at_k=(None if precision_at_k is None
                        else float(precision_at_k)),
        build_s=None if build_s is None else float(build_s),
        case=case,
        derived={str(k): v for k, v in derived.items()}))


def case_for(kind: str, length: int, n_database: int, *, batch: int = 1,
             spec=None, config=None) -> BenchCase:
    """Frozen workload identity for a benchmark row."""
    from repro.kernels import ops
    backend = "jnp"
    if config is not None:
        backend = ops.backend_name(ops.resolve_backend(config.backend))
    return BenchCase(
        dataset=kind, length=length, n_database=n_database, batch=batch,
        spec=None if spec is None else spec.to_dict(),
        config=None if config is None else config.to_dict(),
        backend=backend)


def stage_mean_us(stats_list) -> Optional[Dict[str, float]]:
    """Mean per-stage µs over SearchStats samples (None when empty or
    telemetry was off)."""
    dicts = [s.stage_us for s in stats_list
             if s is not None and s.stage_us is not None]
    if not dicts:
        return None
    keys = sorted({k for d in dicts for k in d})
    return {k: float(np.mean([d.get(k, 0.0) for d in dicts]))
            for k in keys}


_TSDB_CACHE = {}


def tsdb_cached(kind: str, length: int):
    """One sequential-searcher TimeSeriesDB per (dataset, length), shared
    across benchmark modules (amortises the index build)."""
    from repro.db import TimeSeriesDB
    key = (kind, length)
    if key not in _TSDB_CACHE:
        db, _ = dataset_cached(kind, length)
        _TSDB_CACHE[key] = TimeSeriesDB.build(
            db, spec=PARAMS[kind].to_spec(),
            config=search_config(kind, length, searcher="local"))
    return _TSDB_CACHE[key]


def timed_search_samples(search_fn: Callable, queries, *, warmup: int = 1,
                         iters: int = 2):
    """Warm per-query latency samples (µs) + the last pass's results.

    Runs ``search_fn(q)`` ``warmup`` times on the first query, then
    ``iters`` passes over all queries, keeping only the final pass —
    the sequential path has value-dependent intermediate shapes, so
    earlier passes pay per-query XLA compiles that would swamp the
    trajectory with compile noise.  Latencies come from
    ``SearchResult.wall_seconds`` (which brackets the synchronized
    stage timers).
    """
    for _ in range(warmup):
        search_fn(queries[0])
    samples_us, results = [], []
    for it in range(iters):
        samples_us, results = [], []
        for q in queries:
            res = search_fn(q)
            results.append(res)
            samples_us.append(res.wall_seconds * 1e6)
    return results, samples_us


def hotpath_report(name: str, kind: str, length: int) -> None:
    """One stage-instrumented sequential hot-path row (shared helper so
    every module's BENCH json carries an encode/probe/lb/dtw breakdown).
    """
    db, queries = dataset_cached(kind, length)
    tsdb = tsdb_cached(kind, length)
    results, samples_us = timed_search_samples(tsdb.search, queries)
    stage_us = stage_mean_us([r.stats for r in results])
    report(name, float(np.mean(samples_us)),
           {"p50_us": round(percentile(samples_us, 50), 1),
            "p95_us": round(percentile(samples_us, 95), 1),
            "n_samples": len(samples_us)},
           stats=results[-1].stats, stage_us=stage_us,
           samples_us=samples_us,
           case=case_for(kind, length, len(tsdb), spec=tsdb.spec,
                         config=tsdb.config))
