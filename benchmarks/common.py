"""Shared benchmark scaffolding.

Scale knobs: BENCH_SCALE ∈ {"smoke", "small", "full"} via env var.  The
paper's 20M-series scale is exercised by the multi-pod dry-run; these
benchmarks validate the paper's *relative* claims at container scale.
"""
from __future__ import annotations

import os
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import SSHParams
from repro.data.timeseries import (extract_subsequences, random_walk,
                                   synthetic_ecg)

SCALE = os.environ.get("BENCH_SCALE", "smoke")

# points per stream / queries per (dataset, length) cell
_SCALES = {"smoke": (6_000, 2), "small": (20_000, 4), "full": (120_000, 8)}
N_POINTS, N_QUERIES = _SCALES[SCALE]

LENGTHS = {"smoke": [128, 256], "small": [128, 512, 1024],
           "full": [128, 512, 1024, 2048]}[SCALE]

PARAMS = {
    "ecg": SSHParams(window=80, step=3, ngram=15, num_hashes=40,
                     num_tables=20),
    "randomwalk": SSHParams(window=30, step=5, ngram=15, num_hashes=40,
                            num_tables=20),
}
GENERATORS = {"ecg": synthetic_ecg, "randomwalk": random_walk}


def dataset(kind: str, length: int, seed: int = 3):
    """(db (N, L) z-normed jnp array, query offsets)."""
    stream = GENERATORS[kind](N_POINTS, seed=seed)
    db = extract_subsequences(stream, length, stride=1, znorm=True)
    rng = np.random.default_rng(0)
    qoffs = rng.integers(0, len(stream) - length, N_QUERIES)
    queries = []
    for off in qoffs:
        q = stream[off:off + length].astype(np.float32)
        queries.append((q - q.mean()) / (q.std() + 1e-8))
    return jnp.asarray(db), [jnp.asarray(q) for q in queries]


_GOLD_CACHE = {}
_DS_CACHE = {}


def dataset_cached(kind: str, length: int):
    key = (kind, length)
    if key not in _DS_CACHE:
        _DS_CACHE[key] = dataset(kind, length)
    return _DS_CACHE[key]


def gold_topk_cached(kind: str, length: int, k: int, band: int):
    """Exact-DTW gold rankings, shared across benchmark modules (the
    dominant CPU cost — one brute-force pass per (dataset, length))."""
    from repro.core import brute_force_topk
    key = (kind, length, band)
    if key not in _GOLD_CACHE:
        db, queries = dataset_cached(kind, length)
        _GOLD_CACHE[key] = [brute_force_topk(q, db, 50, band=band)[0]
                            for q in queries]
    return [g[:k] for g in _GOLD_CACHE[key]]


def band_for(length: int) -> int:
    return max(4, length // 20)        # UCR-suite 5% convention


def search_config(kind: str, length: int, **overrides):
    """The arch registry's SearchConfig at the 5% band for ``length``.

    The single source of topk/top_c/band/multiprobe defaults for every
    benchmark (no hand-copied knob tuples); per-bench policy goes through
    ``overrides``.
    """
    from repro.configs import get_arch
    return get_arch(f"ssh-{kind}").search_config(length=length, **overrides)


def timed(fn: Callable, *args, warmup: int = 1, iters: int = 3, **kw):
    for _ in range(warmup):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) \
        else None
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
        leaves = jax.tree.leaves(out)
        if leaves and hasattr(leaves[0], "block_until_ready"):
            leaves[0].block_until_ready()
    return out, (time.perf_counter() - t0) / iters


def emit(name: str, us_per_call: float, derived: Dict) -> None:
    """CSV contract: name,us_per_call,derived"""
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    print(f"{name},{us_per_call:.1f},{kv}", flush=True)
