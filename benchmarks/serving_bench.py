"""Serving throughput vs batch size — batched engine vs sequential Alg. 2.

For each batch size B the same 64-query workload is served in blocks of B
through ``ServingEngine.search_batch`` (no batcher-thread timing noise;
the compute path is what is measured).  The batched path amortises JIT
dispatch and host orchestration across the block, streams the signature
matrix once per block, and re-ranks the flattened survivor pairs in
fixed-shape DTW chunks — queries/sec rises monotonically with B.

Two sequential baselines are reported:

* ``sequential_cold`` — unseen queries.  ``ssh_search`` has value-
  dependent intermediate shapes (candidate/survivor counts), so live
  traffic pays XLA recompiles continuously; this is what a production
  loop would see.  The engine's bucketed shapes compile once, ever.
* ``sequential_warm`` — the same workload repeated, every per-query
  shape already compiled.  Only reachable for repeated identical
  traffic; included to show the compute-only gap.

Timing: the batch-size cells are interleaved round-robin at *block*
granularity and each block keeps its best time over ``N_ROUNDS``; a
cell's workload time is the sum of its block minima.  Single-pass CPU
timings are far too noisy to rank batch sizes; per-block minima only
need each block to hit one interference-free window across the rounds,
and round-robin spreads machine-wide slow periods across all cells.

On shared/throttled CPU hosts the XLA thread pool adds large run-to-run
variance (thread imbalance interacts with cpu-shares throttling); the
bench pins XLA to one CPU thread by default for stable rankings.  Export
``XLA_FLAGS`` yourself to override.

CSV rows: serving/<kind>/len<L>/<cell>, us_per_query, qps + speedup.
"""
from __future__ import annotations

import os
import time

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PARAMS, case_for, dataset_cached as dataset,
                               report, search_config, stage_mean_us)
from repro.core import SSHIndex, ssh_search
from repro.serving import ServingEngine
from repro.serving.metrics import ServingMetrics

BATCH_SIZES = (1, 2, 4, 8)
N_WORK_QUERIES = 64          # workload size (divisible by every batch size)
N_ROUNDS = 10                # round-robin passes; each cell keeps its best
# top_c=128: the DTW re-rank is batch-size-independent work, so the cell
# ranking rides on the amortized fixed costs (dispatch, signatures, probe);
# a leaner candidate set keeps that fraction above CPU timer noise.
# single-probe: the bench ranks batching, not recall
TOP_C = 128


def _bench_config(kind: str, length: int, **overrides):
    return search_config(kind, length, top_c=TOP_C,
                         multiprobe_offsets=1, **overrides)


def _workload(db, n: int) -> jnp.ndarray:
    """n queries drawn from the database (realistic overlapping traffic)."""
    rng = np.random.default_rng(7)
    return db[jnp.asarray(rng.integers(0, db.shape[0], n))]


def _time_sequential(queries, index, cfg):
    """(cold_seconds, warm_seconds, warm stage_us mean) over the workload."""
    t0 = time.perf_counter()
    for q in queries:
        ssh_search(q, index, config=cfg)
    cold = time.perf_counter() - t0
    warm, stage_us = float("inf"), None
    for _ in range(N_ROUNDS // 2):
        t0 = time.perf_counter()
        stats = [ssh_search(q, index, config=cfg).stats for q in queries]
        elapsed = time.perf_counter() - t0
        if elapsed < warm:
            warm, stage_us = elapsed, stage_mean_us(stats)
    return cold, warm, stage_us


def _time_batched(queries, index, base_cfg):
    """{batch: Σ per-block best seconds} measured round-robin."""
    cells = {}
    for batch in BATCH_SIZES:
        engine = ServingEngine(index, base_cfg.replace(
            batch_policy=base_cfg.batch_policy.replace(max_batch=batch)))
        blocks = [queries[i:i + batch]
                  for i in range(0, len(queries), batch)]
        for blk in blocks:                     # warm the compiled chunks
            engine.search_batch(blk)
        # metrics restart post-warmup: the snapshot's stage/pruning means
        # cover only the timed (compiled) rounds
        engine.metrics = ServingMetrics()
        cells[batch] = (engine, blocks, [float("inf")] * len(blocks))
    for _ in range(N_ROUNDS):
        for engine, blocks, best in cells.values():
            for i, blk in enumerate(blocks):
                t0 = time.perf_counter()
                engine.search_batch(blk)
                best[i] = min(best[i], time.perf_counter() - t0)
    times = {batch: sum(best) for batch, (_, _, best) in cells.items()}
    snaps = {batch: eng.metrics.snapshot()
             for batch, (eng, _, _) in cells.items()}
    return times, snaps


def run() -> None:
    for kind in ("ecg",):
        params = PARAMS[kind]
        length = 128
        db, _ = dataset(kind, length)
        cfg = _bench_config(kind, length)
        index = SSHIndex.build(db, spec=params.to_spec())
        queries = _workload(db, N_WORK_QUERIES)
        n = N_WORK_QUERIES

        t_cold, t_warm, seq_stage_us = _time_sequential(queries, index,
                                                        cfg)
        seq_case = case_for(kind, length, int(db.shape[0]),
                            spec=params.to_spec(), config=cfg)
        report(f"serving/{kind}/len{length}/sequential_cold",
               t_cold / n * 1e6,
               {"qps": round(n / t_cold, 2), "n_queries": n},
               case=seq_case)
        report(f"serving/{kind}/len{length}/sequential_warm",
               t_warm / n * 1e6,
               {"qps": round(n / t_warm, 2), "n_queries": n},
               stage_us=seq_stage_us, case=seq_case)

        times, snaps = _time_batched(queries, index, cfg)
        prev_qps = 0.0
        for batch in BATCH_SIZES:
            qps = n / times[batch]
            snap = snaps[batch]
            # per-query stage breakdown: the engine's per-batch stage
            # means divided by the cell's batch size
            stage_us = {s: snap[f"stage_{s}_us_per_batch_mean"] / batch
                        for s in ("encode", "probe", "lb", "dtw")
                        if f"stage_{s}_us_per_batch_mean" in snap}
            report(f"serving/{kind}/len{length}/batch{batch}",
                   times[batch] / n * 1e6,
                   {"qps": round(qps, 2),
                    "speedup_vs_cold": round(qps / (n / t_cold), 2),
                    "lb_pruned_frac": round(snap["lb_pruned_frac_mean"],
                                            3),
                    "index_bytes": int(snap["index_bytes"]),
                    "monotone": bool(qps >= prev_qps)},
                   stage_us=stage_us or None,
                   lb_pruned_frac=snap["lb_pruned_frac_mean"],
                   case=case_for(kind, length, int(db.shape[0]),
                                 batch=batch, spec=params.to_spec(),
                                 config=cfg.replace(
        batch_policy=cfg.batch_policy.replace(max_batch=batch))))
            prev_qps = qps


if __name__ == "__main__":
    run()
