"""Closed-loop load benchmark: offered-load sweep + adaptive-vs-fixed knee.

Two experiments over the synthetic-ECG index (DESIGN.md §12):

1. **Sweep** — replay the same Poisson workload shape at a ladder of
   offered loads (scaled off the measured full-batch service rate, so
   the ladder brackets the knee at any machine speed) through the
   default fixed-policy engine.  Each point reports coordinated-
   omission-safe p50/p95/p99, achieved QPS, queue-depth percentiles and
   the batch-size histogram; the summary row derives
   ``max_sustainable_qps`` — the highest offered load whose p99 met the
   SLO while throughput kept up.

2. **Knee** — at the measured knee load, replay one identical trace
   through fixed policies at several ``max_wait_ms`` settings and
   through the adaptive policy.  The adaptive row derives
   ``p99_ratio_vs_best_fixed`` (acceptance: ≤ 1.1) and ``identical``
   (bit-identical per-request top-k ids/distances vs fixed — batching
   never changes answers).

CSV rows: loadgen/ecg/len128/<cell>, us_per_query = p50 latency in µs.
"""
from __future__ import annotations

import os
import time

os.environ.setdefault(
    "XLA_FLAGS",
    "--xla_cpu_multi_thread_eigen=false intra_op_parallelism_threads=1")

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (PARAMS, case_for, dataset_cached as dataset,
                               report, search_config)
from repro.core import SSHIndex
from repro.db import BatchPolicy
from repro.loadgen import (Mixture, WorkloadSpec, generate_trace,
                           run_trace, sweep)
from repro.serving import ServingEngine

KIND, LENGTH = "ecg", 128
TOP_C = 128
MAX_BATCH = 8
# offered loads as fractions of the measured full-batch service rate —
# the last point sits past the knee on purpose (the sweep must observe
# saturation to certify the sustainable point below it)
LOAD_FRACS = (0.25, 0.5, 0.75, 1.1)
FIXED_WAITS_MS = (0.5, 2.0, 8.0)
KNEE_REPS = 2                    # best-of reps per knee policy
SWEEP_REPS = 2                   # best-of reps per sweep point
TRACE_SECONDS = 6.0              # per-point trace duration target
SLO_SERVICE_MULT = 4.0           # SLO: p99 <= this x one batch's service


def _bench_config(policy: BatchPolicy):
    return search_config(KIND, LENGTH, top_c=TOP_C, multiprobe_offsets=1,
                         searcher="engine", batch_policy=policy)


def _measure_service_s(index, db, cfg) -> float:
    """Best-of-5 seconds to serve one full batch (compiled)."""
    engine = ServingEngine(index, cfg)
    block = db[:MAX_BATCH]
    engine.searcher.search_batch(block)          # compile
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        engine.searcher.search_batch(block)
        best = min(best, time.perf_counter() - t0)
    return best


def _warm_engine_factory(index, cfg, db, pools, n_db):
    """Engine factory for the sweep; compiled shapes warm once, globally.

    Bucket warm-up alone is not enough: the first *live* replay of the
    process still pays residual one-time costs (allocator growth, lazy
    imports on the batcher thread, OS scheduler ramp) that can spiral a
    near-capacity run into a queue it never drains.  A short throwaway
    trace through the live submit path absorbs them off the record.
    """
    def make():
        return ServingEngine(index, cfg)
    warm = make()
    for s in cfg.buckets():
        warm.searcher.search_batch(db[:s])
    throwaway = WorkloadSpec(process="poisson", rate_qps=30.0,
                             n_requests=32, seed=1)
    with warm:
        run_trace(warm, generate_trace(throwaway, {LENGTH: n_db}), pools)
    return make


def run() -> None:
    db, _ = dataset(KIND, LENGTH)
    params = PARAMS[KIND]
    index = SSHIndex.build(db, spec=params.to_spec())
    n_db = int(db.shape[0])
    pools = {LENGTH: db}

    fixed_cfg = _bench_config(BatchPolicy(mode="fixed",
                                          max_batch=MAX_BATCH))
    service_s = _measure_service_s(index, db, fixed_cfg)
    capacity_qps = MAX_BATCH / service_s
    slo_p99_ms = max(100.0, SLO_SERVICE_MULT * service_s * 1e3)
    print(f"# loadgen: batch{MAX_BATCH} service {service_s*1e3:.1f}ms "
          f"-> capacity ~{capacity_qps:.1f} qps, SLO p99 <= "
          f"{slo_p99_ms:.0f}ms")

    # ---- experiment 1: offered-load sweep (fixed default policy) --------
    loads = [max(1.0, capacity_qps * f) for f in LOAD_FRACS]
    spec = WorkloadSpec(process="poisson", rate_qps=loads[0],
                        n_requests=1, seed=17,
                        topks=Mixture((5, 10), (0.5, 0.5)))

    def spec_at(load: float) -> WorkloadSpec:
        n = int(np.clip(load * TRACE_SECONDS, 16, 256))
        return spec.replace(rate_qps=load, n_requests=n)

    factory = _warm_engine_factory(index, fixed_cfg, db, pools, n_db)
    results = []
    for load in loads:
        pt_spec = spec_at(load)
        # best-of-SWEEP_REPS by p99: one scheduler hiccup on one point
        # must not redefine the knee the adaptive comparison runs at
        reps = []
        for _ in range(SWEEP_REPS):
            res, _ = sweep(factory, pt_spec, [load], pools,
                           slo_p99_ms=slo_p99_ms)
            reps.append(res[0])
        results.append(min(reps, key=lambda r: r.latency_p99_ms))
    _, max_sustainable = _derive_sustainable(results, slo_p99_ms)

    for res in results:
        report(f"loadgen/{KIND}/len{LENGTH}/sweep/"
               f"qps{res.offered_qps:.0f}",
               res.latency_p50_ms * 1e3,
               {"offered_qps": round(res.offered_qps, 2),
                "achieved_qps": round(res.achieved_qps, 2),
                "p99_ms": round(res.latency_p99_ms, 2),
                "queue_depth_p95": res.queue_depth_p95,
                "batch_occupancy_mean": round(res.batch_occupancy_mean, 3),
                "batch_histogram": _hist_str(res.batch_histogram),
                "n_requests": res.n_requests},
               stage_us=res.stage_us or None,
               case=case_for(KIND, LENGTH, n_db, batch=MAX_BATCH,
                             spec=params.to_spec(), config=fixed_cfg))

    report(f"loadgen/{KIND}/len{LENGTH}/max_sustainable",
           1e6 / max(max_sustainable, 1e-6),
           {"max_sustainable_qps": round(max_sustainable, 2),
            "slo_p99_ms": round(slo_p99_ms, 1),
            "capacity_qps_estimate": round(capacity_qps, 2),
            "n_sweep_points": len(results)},
           case=case_for(KIND, LENGTH, n_db, batch=MAX_BATCH,
                         spec=params.to_spec(), config=fixed_cfg))

    # ---- experiment 2: adaptive vs fixed at the knee --------------------
    knee_qps = max_sustainable if max_sustainable > 0 else loads[1]
    knee_spec = spec_at(knee_qps)
    trace = generate_trace(knee_spec, {LENGTH: n_db})

    def run_policy(policy: BatchPolicy):
        """Best-of-KNEE_REPS replays (min p99 — same scheduler-noise
        suppression as the best-of-5 service probe); answers must be
        identical across every rep, so bit-identity is checked over
        KNEE_REPS x KNEE_REPS policy pairs, not one lucky run."""
        reps = []
        for _ in range(KNEE_REPS):
            cfg = _bench_config(policy)
            engine = ServingEngine(index, cfg)
            with engine:
                for s in cfg.buckets():
                    engine.searcher.search_batch(db[:s])
                reps.append(run_trace(engine, trace, pools))
        best = min(reps, key=lambda r: r.latency_p99_ms)
        assert all(r.same_answers(reps[0]) for r in reps)
        return best

    fixed_runs = {w: run_policy(BatchPolicy(mode="fixed",
                                            max_batch=MAX_BATCH,
                                            max_wait_ms=w))
                  for w in FIXED_WAITS_MS}
    adaptive = run_policy(BatchPolicy(mode="adaptive",
                                      max_batch=MAX_BATCH))

    best_fixed_p99 = min(r.latency_p99_ms for r in fixed_runs.values())
    for w, res in fixed_runs.items():
        report(f"loadgen/{KIND}/len{LENGTH}/knee/fixed_w{w:g}",
               res.latency_p50_ms * 1e3,
               {"p99_ms": round(res.latency_p99_ms, 2),
                "achieved_qps": round(res.achieved_qps, 2),
                "batch_occupancy_mean": round(res.batch_occupancy_mean, 3),
                "knee_qps": round(knee_qps, 2)},
               case=case_for(KIND, LENGTH, n_db, batch=MAX_BATCH,
                             spec=params.to_spec(), config=fixed_cfg))
    identical = all(adaptive.same_answers(r) for r in fixed_runs.values())
    ratio = adaptive.latency_p99_ms / best_fixed_p99
    report(f"loadgen/{KIND}/len{LENGTH}/knee/adaptive",
           adaptive.latency_p50_ms * 1e3,
           {"p99_ms": round(adaptive.latency_p99_ms, 2),
            "best_fixed_p99_ms": round(best_fixed_p99, 2),
            "p99_ratio_vs_best_fixed": round(ratio, 3),
            "identical": identical,
            "achieved_qps": round(adaptive.achieved_qps, 2),
            "batch_occupancy_mean": round(adaptive.batch_occupancy_mean, 3),
            "knee_qps": round(knee_qps, 2)},
           case=case_for(KIND, LENGTH, n_db, batch=MAX_BATCH,
                         spec=params.to_spec(),
                         config=_bench_config(
                             BatchPolicy(mode="adaptive",
                                         max_batch=MAX_BATCH))))
    if not identical:
        raise AssertionError(
            "adaptive batching changed answers vs fixed batching")


def _derive_sustainable(results, slo_p99_ms: float):
    from repro.loadgen.harness import SUSTAINED_FRAC
    best = 0.0
    for res in results:
        if res.latency_p99_ms <= slo_p99_ms and \
                res.achieved_qps >= SUSTAINED_FRAC * res.offered_qps:
            best = max(best, res.offered_qps)
    return results, best


def _hist_str(hist) -> str:
    return "|".join(f"{k}:{v}" for k, v in sorted(hist.items()))


if __name__ == "__main__":
    run()
