"""Paper Table 4: % of the database pruned — SSH full / hashing alone /
UCR branch-and-bound, by series length."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (LENGTHS, PARAMS, band_for, case_for,
                               dataset_cached as dataset, report,
                               search_config, stage_mean_us)
from repro.core import SSHIndex, ssh_search, ucr_search


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        params = PARAMS[kind]
        for length in LENGTHS:
            db, queries = dataset(kind, length)
            band = band_for(length)
            index = SSHIndex.build(db, spec=params.to_spec())
            cfg = search_config(kind, length)   # cascade on by default
            hash_only, full, ucr, results = [], [], [], []
            ssh_search(queries[0], index, config=cfg)   # warm compiles
            for q in queries:
                res = ssh_search(q, index, config=cfg)
                results.append(res)
                hash_only.append(res.pruned_by_hash_frac)
                full.append(res.pruned_total_frac)
                ucr.append(ucr_search(q, db, topk=10,
                                      band=band).pruned_total_frac)
            report(f"table4/{kind}/len{length}",
                   float(np.mean([r.wall_seconds for r in results])) * 1e6,
                   {"ssh_full": round(float(np.mean(full)), 4),
                    "ssh_hash_alone": round(float(np.mean(hash_only)), 4),
                    "ucr_bnb": round(float(np.mean(ucr)), 4)},
                   stats=results[-1].stats,
                   stage_us=stage_mean_us([r.stats for r in results]),
                   case=case_for(kind, length, int(db.shape[0]),
                                 spec=params.to_spec(), config=cfg))


if __name__ == "__main__":
    run()
