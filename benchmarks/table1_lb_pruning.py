"""Paper Table 1: % candidates pruned by the UCR-suite lower bounds vs
series length — demonstrates the branch-and-bound collapse that motivates
SSH."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (LENGTHS, band_for, dataset_cached,
                               gold_topk_cached, emit)
from repro.core import brute_force_topk
from repro.core.lower_bounds import cascade_stats


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        for length in LENGTHS:
            db, queries = dataset_cached(kind, length)
            band = band_for(length)
            fracs = {"kim": [], "keogh": [], "keogh2": [], "combined": []}
            from repro.core.dtw import dtw_batch
            golds = gold_topk_cached(kind, length, 10, band)
            for q, gold in zip(queries, golds):
                d10 = dtw_batch(q, db[jnp.asarray(gold)], band=band)
                best = jnp.sort(d10)[-1]
                stats = cascade_stats(q, db, band, best)
                for k in fracs:
                    fracs[k].append(float(stats[k]))
            emit(f"table1/{kind}/len{length}", 0.0,
                 {k: round(float(np.mean(v)), 4) for k, v in fracs.items()})


if __name__ == "__main__":
    run()
