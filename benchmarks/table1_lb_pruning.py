"""Paper Table 1: % candidates pruned by the UCR-suite lower bounds vs
series length — demonstrates the branch-and-bound collapse that motivates
SSH."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (LENGTHS, band_for, case_for, dataset_cached,
                               gold_topk_cached, hotpath_report, report,
                               timed)
from repro.core import brute_force_topk
from repro.core.lower_bounds import cascade_stats


def run() -> None:
    for kind in ("ecg", "randomwalk"):
        for length in LENGTHS:
            db, queries = dataset_cached(kind, length)
            band = band_for(length)
            fracs = {"kim": [], "keogh": [], "keogh2": [], "combined": []}
            from repro.core.dtw import dtw_batch
            golds = gold_topk_cached(kind, length, 10, band)
            t_cascade = []
            for q, gold in zip(queries, golds):
                d10 = dtw_batch(q, db[jnp.asarray(gold)], band=band)
                best = jnp.sort(d10)[-1]
                stats, t = timed(cascade_stats, q, db, band, best,
                                 warmup=1, iters=2)
                t_cascade.append(t)
                for k in fracs:
                    fracs[k].append(float(stats[k]))
            report(f"table1/{kind}/len{length}", float(np.mean(t_cascade))
                   * 1e6,
                   {k: round(float(np.mean(v)), 4) for k, v in fracs.items()},
                   lb_pruned_frac=float(np.mean(fracs["combined"])),
                   case=case_for(kind, length, int(db.shape[0])))
            # the stage-instrumented hot path at the same setting (the
            # cascade above is the whole-database UCR bound; this is
            # where those bounds sit inside SSH's Alg. 2)
            hotpath_report(f"table1/{kind}/len{length}/hotpath", kind,
                           length)


if __name__ == "__main__":
    run()
