"""repro.loadgen: seeded arrival determinism, trace replay, adaptive ≡
fixed bit-identity through a live engine, adaptive convergence, and the
serving metrics gauges the harness reads."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSHIndex
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import BatchPolicy, SearchConfig
from repro.encoders import IndexSpec
from repro.loadgen import (ARRIVAL_PROCESSES, Mixture, WorkloadSpec,
                           diurnal_arrivals, generate_trace, make_arrivals,
                           mmpp_arrivals, poisson_arrivals, run_trace)
from repro.serving import ServingEngine, ServingMetrics

pytestmark = pytest.mark.loadgen

SPEC = IndexSpec(encoder="ssh", params=dict(
    window=24, step=3, ngram=8, num_hashes=40, num_tables=20))


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(2500, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))   # ~594 series


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, spec=SPEC)


def _engine(index, mode, max_batch=8, max_wait_ms=4.0):
    pol = BatchPolicy(mode=mode, max_batch=max_batch,
                      max_wait_ms=max_wait_ms)
    return ServingEngine(index, SearchConfig(topk=5, top_c=64, band=8,
                                             batch_policy=pol))


# ---------------------------------------------------------------------------
# arrival processes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fn", [poisson_arrivals, mmpp_arrivals,
                                diurnal_arrivals])
def test_arrivals_deterministic_sorted_and_rate_normalized(fn):
    a = fn(100.0, 2000, seed=7)
    b = fn(100.0, 2000, seed=7)
    np.testing.assert_array_equal(a, b)            # seeded → replayable
    assert np.all(np.diff(a) >= 0)                 # time moves forward
    assert a.shape == (2000,)
    rate = 2000 / a[-1]                            # mean-rate normalized
    assert 75.0 < rate < 130.0
    assert not np.array_equal(a, fn(100.0, 2000, seed=8))


def test_mmpp_burstier_than_poisson():
    """The MMPP's inter-arrival coefficient of variation exceeds the
    Poisson's (CV=1): that is the whole point of the bursty process."""
    gaps_p = np.diff(poisson_arrivals(100.0, 4000, seed=3))
    gaps_m = np.diff(mmpp_arrivals(100.0, 4000, seed=3, burst_factor=8.0))
    cv = lambda g: np.std(g) / np.mean(g)          # noqa: E731
    assert cv(gaps_m) > cv(gaps_p)


def test_make_arrivals_dispatch_and_rejects_unknown():
    np.testing.assert_array_equal(
        make_arrivals("poisson", 50.0, 10, seed=1),
        poisson_arrivals(50.0, 10, seed=1))
    assert set(ARRIVAL_PROCESSES) == {"poisson", "mmpp", "diurnal"}
    with pytest.raises(ValueError, match="unknown arrival process"):
        make_arrivals("tidal", 50.0, 10)


# ---------------------------------------------------------------------------
# workload → trace
# ---------------------------------------------------------------------------

def test_trace_deterministic_and_within_pool():
    spec = WorkloadSpec(process="mmpp", rate_qps=50, n_requests=500, seed=3,
                        topks=Mixture((5, 10), (0.5, 0.5)))
    t1 = generate_trace(spec, {128: 321})
    t2 = generate_trace(spec, {128: 321})
    np.testing.assert_array_equal(t1.arrivals_s, t2.arrivals_s)
    np.testing.assert_array_equal(t1.pool_ids, t2.pool_ids)
    np.testing.assert_array_equal(t1.topks, t2.topks)
    assert 0 <= t1.pool_ids.min() and t1.pool_ids.max() < 321
    assert set(np.unique(t1.topks)) <= {5, 10}
    assert len(t1) == 500 and t1.duration_s > 0


def test_workload_spec_validation():
    with pytest.raises(ValueError, match="arrival process"):
        WorkloadSpec(process="tidal").validate()
    with pytest.raises(ValueError, match="rate_qps"):
        WorkloadSpec(rate_qps=0).validate()
    with pytest.raises(ValueError, match="weights"):
        WorkloadSpec(lengths=Mixture((128,), (0.5, 0.5))).validate()
    with pytest.raises(ValueError, match="topk"):
        WorkloadSpec(topks=Mixture((0,))).validate()
    with pytest.raises(ValueError, match="pool"):
        generate_trace(WorkloadSpec(lengths=Mixture((64,))), {128: 10})


# ---------------------------------------------------------------------------
# closed-loop harness + adaptive batching
# ---------------------------------------------------------------------------

def _warm(engine, db):
    for s in engine.config.buckets():
        engine.searcher.search_batch(db[:s])


def test_adaptive_identical_to_fixed_over_same_trace(db, index):
    """Tentpole acceptance: identical request stream through a fixed and
    an adaptive engine → bit-identical per-request top-k ids/distances
    (batching only changes grouping and padding, never answers)."""
    spec = WorkloadSpec(rate_qps=200.0, n_requests=24, seed=11,
                        topks=Mixture((3, 5), (0.5, 0.5)))
    trace = generate_trace(spec, {128: len(db)})
    results = {}
    for mode in ("fixed", "adaptive"):
        engine = _engine(index, mode)
        with engine:
            _warm(engine, db)
            results[mode] = run_trace(engine, trace, {128: db},
                                      timeout_s=300)
    assert results["fixed"].same_answers(results["adaptive"])
    for res in results.values():
        assert res.n_requests == 24
        assert res.latency_p99_ms >= res.latency_p50_ms > 0
        assert sum(res.batch_histogram.values()) > 0
        assert res.achieved_qps > 0


def test_adaptive_occupancy_rises_under_step_load(db, index):
    """Step load (every request queued up front) → the adaptive batcher
    drains at full occupancy instead of waiting out its deadline."""
    engine = _engine(index, "adaptive", max_batch=4, max_wait_ms=50.0)
    # queue everything before the worker starts: the queue always covers
    # max_batch, so wait_budget_s must return 0 and batches run full
    futs = [engine.submit(db[i]) for i in range(16)]
    with engine:
        for f in futs:
            f.result(timeout=300)
    hist = engine.metrics.batch_histogram()
    assert hist == {4: 4}
    snap = engine.metrics.snapshot()
    assert snap["batch_occupancy_mean"] == pytest.approx(1.0)


def test_adaptive_wait_shrinks_under_light_load(db, index):
    """Once the engine has a service-time estimate, a lone request under
    light load waits less than the fixed deadline (the policy scales the
    budget by the EWMA instead of always burning max_wait_ms)."""
    engine = _engine(index, "adaptive", max_batch=8, max_wait_ms=500.0)
    with engine:
        _warm(engine, db)
        for i in range(4):                    # populate the service EWMA
            engine.search(db[i], timeout=300)
        ewma = engine.service_ewma_s
        assert ewma is not None and ewma > 0
        pol = engine.config.batch_policy
        light = pol.wait_budget_s(1, 0, ewma)
        assert light < pol.max_wait_ms / 1e3  # shrunk below the ceiling
        assert light >= pol.min_wait_ms / 1e3
        assert pol.wait_budget_s(1, pol.max_batch, ewma) == 0.0
        # the engine actually closes lone batches early: the batcher's
        # recorded wait for a lone query must sit far below the 500 ms
        # fixed deadline (service time is excluded — batch_wait covers
        # enqueue → dispatch only)
        wait0 = engine.metrics.batch_wait.total
        engine.search(db[5], timeout=300)
        lone_wait_s = engine.metrics.batch_wait.total - wait0
        assert lone_wait_s < 0.45


def test_metrics_gauges_surface():
    m = ServingMetrics()
    m.on_enqueue(3)
    m.on_batch(4, [0.01] * 4, [0.001] * 4, [0.9] * 4, [0.95] * 4,
               depth_after=1, batch_wait_s=0.002, batch_occupancy=0.5)
    m.on_batch(2, [0.01] * 2, [0.001] * 2, [0.9] * 2, [0.95] * 2,
               depth_after=0, batch_wait_s=0.004, batch_occupancy=0.25)
    s = m.snapshot()
    assert s["queue_depth_max"] == 3
    assert s["queue_depth_p50"] <= s["queue_depth_p95"] <= 3
    assert s["batch_wait_ms_mean"] == pytest.approx(3.0)
    assert s["batch_occupancy_mean"] == pytest.approx(0.375)
    assert m.batch_histogram() == {4: 1, 2: 1}
