"""NequIP: exact SE(3) equivariance (the paper's defining property),
permutation invariance, CG table validity, sampler shapes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.data.graph import (CSRGraph, molecule_batch, random_graph,
                              sample_neighbors, sampled_subgraph_shape)
from repro.models import nequip as NQ
from repro.models.equivariant import (allowed_paths, random_rotation,
                                      real_cg, real_sh, wigner_d)

CFG = NQ.NequIPConfig(n_layers=2, channels=8, d_feat=4)


@pytest.fixture(scope="module")
def setup():
    rng = np.random.default_rng(0)
    params = NQ.init_params(CFG, jax.random.PRNGKey(0))
    n, e = 14, 48
    batch = {
        "node_feat": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32),
        "positions": jnp.asarray(rng.normal(size=(n, 3)) * 2, jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e), jnp.int32),
        "edge_dst": jnp.asarray(rng.integers(0, n, e), jnp.int32),
    }
    return params, batch


if st is None:
    def test_wigner_d_is_representation():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=8, deadline=None)
    @given(st.integers(0, 10_000))
    def test_wigner_d_is_representation(seed):
        r1 = random_rotation(seed)
        r2 = random_rotation(seed + 1)
        for l in (1, 2):
            d12 = wigner_d(l, r1 @ r2)
            np.testing.assert_allclose(d12,
                                       wigner_d(l, r1) @ wigner_d(l, r2),
                                       atol=1e-10)


def test_cg_intertwiner_property():
    rot = random_rotation(3)
    for (l1, l2, l3) in allowed_paths(2):
        t = real_cg(l1, l2, l3)
        lhs = np.einsum("cab,ax,by->cxy", t, wigner_d(l1, rot),
                        wigner_d(l2, rot))
        rhs = np.einsum("cd,dxy->cxy", wigner_d(l3, rot), t)
        np.testing.assert_allclose(lhs, rhs, atol=1e-10)


def test_sh_jnp_matches_numpy(rng):
    pts = rng.normal(size=(10, 3))
    pts /= np.linalg.norm(pts, axis=-1, keepdims=True)
    for l in (0, 1, 2):
        np.testing.assert_allclose(
            np.asarray(NQ.sh_l(l, jnp.asarray(pts, jnp.float32))),
            real_sh(l, pts), atol=1e-5)


def test_energy_invariance(setup):
    params, batch = setup
    e1 = NQ.forward(params, batch, CFG)
    rot = jnp.asarray(random_rotation(7), jnp.float32)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] @ rot.T + 5.0
    e2 = NQ.forward(params, b2, CFG)
    assert float(jnp.abs(e1 - e2)[0]) < 5e-5 * (1 + abs(float(e1[0])))


def test_force_equivariance(setup):
    params, batch = setup
    rot = jnp.asarray(random_rotation(11), jnp.float32)
    f1 = NQ.forces(params, batch, CFG)
    b2 = dict(batch)
    b2["positions"] = batch["positions"] @ rot.T - 2.0
    f2 = NQ.forces(params, b2, CFG)
    np.testing.assert_allclose(np.asarray(f2), np.asarray(f1 @ rot.T),
                               atol=2e-5)


def test_permutation_invariance(setup):
    params, batch = setup
    n = batch["positions"].shape[0]
    perm = np.random.default_rng(5).permutation(n)
    inv = np.argsort(perm)
    b2 = {
        "node_feat": batch["node_feat"][perm],
        "positions": batch["positions"][perm],
        "edge_src": jnp.asarray(inv)[batch["edge_src"]],
        "edge_dst": jnp.asarray(inv)[batch["edge_dst"]],
    }
    e1 = NQ.forward(params, batch, CFG)
    e2 = NQ.forward(params, b2, CFG)
    assert float(jnp.abs(e1 - e2)[0]) < 1e-4


def test_self_loop_edges_are_inert(setup):
    """Padding edges (self-loops) must not change the energy — the padded
    sampled-subgraph contract."""
    params, batch = setup
    e1 = NQ.forward(params, batch, CFG)
    b2 = dict(batch)
    b2["edge_src"] = jnp.concatenate(
        [batch["edge_src"], jnp.zeros(16, jnp.int32)])
    b2["edge_dst"] = jnp.concatenate(
        [batch["edge_dst"], jnp.zeros(16, jnp.int32)])
    e2 = NQ.forward(params, b2, CFG)
    assert float(jnp.abs(e1 - e2)[0]) < 1e-5


def test_molecule_batch_readout():
    mb = molecule_batch(5, 6, 12, d_feat=4, seed=1)
    batch = {k: jnp.asarray(v) for k, v in mb.items()}
    params = NQ.init_params(CFG, jax.random.PRNGKey(0))
    e = NQ.forward(params, batch, CFG, n_graphs=5)
    assert e.shape == (5,)
    loss, _ = NQ.loss_fn(params, batch, CFG, n_graphs=5)
    assert np.isfinite(float(loss))


def test_node_targets_loss():
    g = random_graph(20, 60, 4, seed=2)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    params = NQ.init_params(CFG, jax.random.PRNGKey(0))
    loss, _ = NQ.loss_fn(params, batch, CFG)
    grads = jax.grad(lambda p: NQ.loss_fn(p, batch, CFG)[0])(params)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(grads))


def test_neighbor_sampler_shapes():
    rng = np.random.default_rng(0)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    g = CSRGraph.from_edges(src, dst, n)
    seeds = rng.integers(0, n, 16).astype(np.int32)
    sub = sample_neighbors(g, seeds, (5, 3), seed=1)
    want_nodes, want_edges = sampled_subgraph_shape(16, (5, 3))
    assert sub["nodes"].shape == (want_nodes,)
    assert sub["edge_src"].shape == (want_edges,)
    assert sub["edge_dst"].shape == (want_edges,)
    # edge indices point inside the node array
    assert sub["edge_src"].max() < want_nodes
    assert sub["edge_dst"].max() < want_nodes
