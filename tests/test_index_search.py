"""End-to-end SSH index + search behaviour (paper Alg. 1 + 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SSHParams, SSHIndex, brute_force_topk, ndcg_at_k,
                        precision_at_k, srp_search, ssh_search, ucr_search)
from repro.core.srp import make_srp, srp_bits
from repro.data import make_benchmark_db
from repro.data.timeseries import extract_subsequences, synthetic_ecg

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(4000, seed=5)
    d = extract_subsequences(stream, 128, stride=1, znorm=True)
    return jnp.asarray(d)


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS, with_host_buckets=True)


def test_self_query_returns_self(db, index):
    res = ssh_search(db[100], index, topk=5, top_c=128, band=8,
                     multiprobe_offsets=PARAMS.step)
    assert res.ids[0] == 100
    assert res.dists[0] == pytest.approx(0.0, abs=1e-4)


def test_precision_against_gold(db, index):
    precs = []
    for qid in (50, 500, 1500):
        res = ssh_search(db[qid], index, topk=10, top_c=256, band=8,
                         multiprobe_offsets=PARAMS.step)
        gold, _ = brute_force_topk(db[qid], db, 10, band=8)
        precs.append(precision_at_k(res.ids, gold, 10))
    assert np.mean(precs) >= 0.5      # small-db bound; benchmarks use full


def test_pruning_fraction(db, index):
    res = ssh_search(db[700], index, topk=10, top_c=256, band=8)
    assert res.pruned_by_hash_frac > 0.9          # paper Table 4 behaviour
    assert res.n_candidates <= 256


def test_host_buckets_agree_with_device_scan(db, index):
    r1 = ssh_search(db[42], index, topk=5, top_c=256, band=8,
                    use_host_buckets=True, rank_by_signature=False)
    r2 = ssh_search(db[42], index, topk=5, top_c=256, band=8,
                    rank_by_signature=False)
    assert r1.ids[0] == r2.ids[0] == 42


def test_streaming_insert(db, index):
    n0 = int(index.signatures.shape[0])
    new = db[:7] * 1.01
    index.insert(new)
    assert index.signatures.shape[0] == n0 + 7
    res = ssh_search(db[3], index, topk=3, top_c=64, band=8)
    assert res.n_database == n0 + 7


def test_streaming_insert_found_and_buckets_consistent(db):
    """Post-insert queries find the new series; host buckets track the
    device-side arrays exactly (ids, membership, table count)."""
    from repro.core import band_keys, build_signatures
    idx = SSHIndex.build(db[:400], PARAMS, with_host_buckets=True)
    novel = jnp.asarray(np.cos(np.linspace(0, 23, db.shape[1])) ** 2,
                        jnp.float32)
    novel = (novel - novel.mean()) / (novel.std() + 1e-8)
    idx.insert(novel[None, :])
    new_id = 400
    assert idx.signatures.shape[0] == idx.keys.shape[0] == 401
    assert idx.series.shape[0] == 401

    # device scan finds the inserted series as its own nearest neighbour
    res = ssh_search(novel, idx, topk=3, top_c=64, band=8)
    assert res.ids[0] == new_id
    assert res.dists[0] == pytest.approx(0.0, abs=1e-4)

    # host buckets agree: the new id is probeable and sits in exactly the
    # buckets named by its key row
    ranked = idx.host_buckets.probe(np.asarray(idx.query_keys(novel)))
    assert ranked[0] == new_id
    keys = np.asarray(idx.keys[new_id])
    for t in range(PARAMS.num_tables):
        assert new_id in idx.host_buckets.tables[t][int(keys[t])]
    total_members = sum(len(v) for tab in idx.host_buckets.tables
                        for v in tab.values())
    assert total_members == 401 * PARAMS.num_tables

    # inserted signatures match a from-scratch hash of the same series
    want = build_signatures(novel[None, :], idx.fns)
    np.testing.assert_array_equal(np.asarray(idx.signatures[new_id]),
                                  np.asarray(want[0]))
    np.testing.assert_array_equal(
        np.asarray(idx.keys[new_id]),
        np.asarray(band_keys(want, PARAMS)[0]))


def test_query_signatures_multiprobe(db):
    """Multiprobe returns one signature per δ-offset: row 0 is the plain
    query signature, row o hashes q[o:], and probing with them can only
    widen (never shrink) the candidate pool."""
    idx = SSHIndex.build(db[:400], PARAMS)
    q = db[37]
    sigs = idx.query_signatures_multiprobe(q, PARAMS.step)
    assert sigs.shape == (PARAMS.step, PARAMS.num_hashes)
    np.testing.assert_array_equal(np.asarray(sigs[0]),
                                  np.asarray(idx.query_signature(q)))
    for o in range(1, PARAMS.step):
        np.testing.assert_array_equal(np.asarray(sigs[o]),
                                      np.asarray(idx.query_signature(q[o:])))
    r1 = ssh_search(q, idx, topk=5, top_c=64, band=8, use_lb_cascade=False,
                    multiprobe_offsets=1)
    r3 = ssh_search(q, idx, topk=5, top_c=64, band=8, use_lb_cascade=False,
                    multiprobe_offsets=PARAMS.step)
    assert r3.n_candidates >= r1.n_candidates
    assert r1.ids[0] == r3.ids[0] == 37


def test_batched_signature_and_probe_match_single(db):
    """Batch-friendly probe APIs agree with the per-query paths."""
    from repro.core import probe_topc, probe_topc_batch
    idx = SSHIndex.build(db[:400], PARAMS)
    queries = db[jnp.asarray([5, 77, 200])]
    sigs = idx.query_signatures_batch(queries)
    for b, qid in enumerate([5, 77, 200]):
        np.testing.assert_array_equal(
            np.asarray(sigs[b]), np.asarray(idx.query_signature(db[qid])))
    ids_b, vals_b = probe_topc_batch(sigs, idx.signatures, 32)
    for b in range(3):
        ids1, vals1 = probe_topc(sigs[b], idx.signatures, 32)
        np.testing.assert_array_equal(np.asarray(ids_b[b]),
                                      np.asarray(ids1))
        np.testing.assert_array_equal(np.asarray(vals_b[b]),
                                      np.asarray(vals1))


def test_ucr_search_is_exact(db):
    q = db[321]
    res = ucr_search(q, db, topk=5, band=8)
    gold, gd = brute_force_topk(q, db, 5, band=8)
    assert precision_at_k(res.ids, gold, 5) == 1.0
    np.testing.assert_allclose(res.dists, gd, rtol=1e-4)


def test_ucr_search_unconstrained_is_exact(db):
    """band=None: envelope bounds at a finite radius are NOT sound for
    unconstrained DTW, so the cascade must fall back to LB_Kim only —
    survivors then provably contain the true top-k."""
    q = db[55]
    small = db[:400]
    res = ucr_search(q, small, topk=5, band=None)
    gold, gd = brute_force_topk(q, small, 5, band=None)
    assert precision_at_k(res.ids, gold, 5) == 1.0
    np.testing.assert_allclose(res.dists, gd, rtol=1e-4)


def test_srp_fails_on_warping(db):
    """Paper Table 2: SRP (no alignment) ranks far worse than SSH."""
    q = db[800]
    planes = make_srp(jax.random.PRNGKey(0), 64, db.shape[1])
    dbits = srp_bits(db, planes)
    res = srp_search(q, db, planes, dbits, topk=10)
    gold, _ = brute_force_topk(q, db, 10, band=8)
    # SRP finds the exact self-match but misses warped neighbours
    assert precision_at_k(res.ids, gold, 10) <= 0.9


def test_ndcg_metric():
    gold = np.arange(10)
    assert ndcg_at_k(gold, gold, 10) == pytest.approx(1.0)
    assert ndcg_at_k(gold[::-1], gold, 10) < 1.0
    assert ndcg_at_k(np.arange(100, 110), gold, 10) == 0.0
