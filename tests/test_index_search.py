"""End-to-end SSH index + search behaviour (paper Alg. 1 + 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SSHParams, SSHIndex, brute_force_topk, ndcg_at_k,
                        precision_at_k, srp_search, ssh_search, ucr_search)
from repro.core.srp import make_srp, srp_bits
from repro.data import make_benchmark_db
from repro.data.timeseries import extract_subsequences, synthetic_ecg

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(4000, seed=5)
    d = extract_subsequences(stream, 128, stride=1, znorm=True)
    return jnp.asarray(d)


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS, with_host_buckets=True)


def test_self_query_returns_self(db, index):
    res = ssh_search(db[100], index, topk=5, top_c=128, band=8,
                     multiprobe_offsets=PARAMS.step)
    assert res.ids[0] == 100
    assert res.dists[0] == pytest.approx(0.0, abs=1e-4)


def test_precision_against_gold(db, index):
    precs = []
    for qid in (50, 500, 1500):
        res = ssh_search(db[qid], index, topk=10, top_c=256, band=8,
                         multiprobe_offsets=PARAMS.step)
        gold, _ = brute_force_topk(db[qid], db, 10, band=8)
        precs.append(precision_at_k(res.ids, gold, 10))
    assert np.mean(precs) >= 0.5      # small-db bound; benchmarks use full


def test_pruning_fraction(db, index):
    res = ssh_search(db[700], index, topk=10, top_c=256, band=8)
    assert res.pruned_by_hash_frac > 0.9          # paper Table 4 behaviour
    assert res.n_candidates <= 256


def test_host_buckets_agree_with_device_scan(db, index):
    r1 = ssh_search(db[42], index, topk=5, top_c=256, band=8,
                    use_host_buckets=True, rank_by_signature=False)
    r2 = ssh_search(db[42], index, topk=5, top_c=256, band=8,
                    rank_by_signature=False)
    assert r1.ids[0] == r2.ids[0] == 42


def test_streaming_insert(db, index):
    n0 = int(index.signatures.shape[0])
    new = db[:7] * 1.01
    index.insert(new)
    assert index.signatures.shape[0] == n0 + 7
    res = ssh_search(db[3], index, topk=3, top_c=64, band=8)
    assert res.n_database == n0 + 7


def test_ucr_search_is_exact(db):
    q = db[321]
    res = ucr_search(q, db, topk=5, band=8)
    gold, gd = brute_force_topk(q, db, 5, band=8)
    assert precision_at_k(res.ids, gold, 5) == 1.0
    np.testing.assert_allclose(res.dists, gd, rtol=1e-4)


def test_srp_fails_on_warping(db):
    """Paper Table 2: SRP (no alignment) ranks far worse than SSH."""
    q = db[800]
    planes = make_srp(jax.random.PRNGKey(0), 64, db.shape[1])
    dbits = srp_bits(db, planes)
    res = srp_search(q, db, planes, dbits, topk=10)
    gold, _ = brute_force_topk(q, db, 10, band=8)
    # SRP finds the exact self-match but misses warped neighbours
    assert precision_at_k(res.ids, gold, 10) <= 0.9


def test_ndcg_metric():
    gold = np.arange(10)
    assert ndcg_at_k(gold, gold, 10) == pytest.approx(1.0)
    assert ndcg_at_k(gold[::-1], gold, 10) < 1.0
    assert ndcg_at_k(np.arange(100, 110), gold, 10) == 0.0
