"""repro.subseq (ISSUE 8, DESIGN.md §10): rolling sketch, sparse window
signatures, subsequence search, and the query-signature LRU.

The acceptance contract: the rolling encode is bit-identical to encoding
every materialised window separately (sketch bits AND signatures, any
length/hop/stride mix); ``search_subsequence`` top-1 equals brute-force
sliding-window DTW on a planted match; returned offsets respect the
exclusion zone; save/load answers bit-identically and keeps accepting
``extend_stream`` (== a from-scratch rebuild); repeated queries hit the
signature cache on every entry point.
"""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.core import shingle
from repro.core.search import brute_force_topk, ssh_search
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import SearchConfig, TimeSeriesDB
from repro.encoders import IndexSpec, make_encoder
from repro.kernels import ops
from repro.serving.batched import ssh_search_batch
from repro.serving.metrics import ServingMetrics
from repro.streaming import StreamIngestor
from repro.subseq import (SubsequenceIndex, delta_histograms,
                          global_shingle_ids, num_windows,
                          rolling_signatures, rolling_sketch_bits)
from repro.subseq.rolling import SPARSE_CHUNK

pytestmark = pytest.mark.subseq

SMOKE = dict(window=24, step=3, ngram=8, num_filters=2,
             num_hashes=40, num_tables=20)
SPEC = IndexSpec(encoder="ssh", params=SMOKE)
L, HOP = 128, 4
CFG = SearchConfig(topk=5, top_c=128, band=8, searcher="local",
                   subseq_window=L, subseq_hop=HOP)


def _windows(stream, length, hop):
    nw = num_windows(len(stream), length, hop)
    return np.stack([stream[j * hop:j * hop + length] for j in range(nw)])


@pytest.fixture(scope="module")
def stream():
    s = np.asarray(synthetic_ecg(3000, seed=3), np.float32)
    return s


@pytest.fixture(scope="module")
def sub(stream):
    return SubsequenceIndex.build(stream, SPEC, length=L, hop=HOP,
                                  backend="jnp")


# ---------------------------------------------------------------------------
# rolling == batch, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("hop", [3, 4, 5, 6, 1])
def test_rolling_signatures_match_batch(stream, hop):
    """Aligned (hop % δ == 0) and unaligned hops both reproduce the
    per-window ``encode_batch`` signatures exactly."""
    enc = make_encoder(SPEC, length=L)
    wins = jnp.asarray(_windows(stream[:1200], L, hop))
    want = np.asarray(enc.encode_batch(wins, backend="jnp"))
    got = np.asarray(rolling_signatures(jnp.asarray(stream[:1200]), enc,
                                        L, hop, backend="jnp"))
    np.testing.assert_array_equal(got, want)


def test_rolling_signatures_chunk_invariant(stream):
    """The chunked sparse program is a pure tiling: tiny chunks (edge
    padding exercised) equal one big chunk."""
    enc = make_encoder(SPEC, length=L)
    s = jnp.asarray(stream[:900])
    a = np.asarray(rolling_signatures(s, enc, L, HOP, chunk=7))
    b = np.asarray(rolling_signatures(s, enc, L, HOP, chunk=SPARSE_CHUNK))
    np.testing.assert_array_equal(a, b)


if st is None:
    def test_rolling_sketch_bits_property():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 4), st.integers(1, 7), st.integers(0, 40),
           st.integers(0, 150), st.sampled_from([1, 2, 3]),
           st.integers(0, 2 ** 31 - 1))
    def test_rolling_sketch_bits_property(step, hop, extra_len, extra_n,
                                          f, seed):
        """Any (δ, h, L, N, F) mix — including L not a multiple of the
        128-lane tile and odd gcd(h, δ) grids — yields window bit
        profiles identical to sketching each materialised window."""
        rng = np.random.default_rng(seed)
        w = 8
        length = w + extra_len          # >= one tap, arbitrary alignment
        n = length + extra_n
        stream = rng.standard_normal(n).astype(np.float32)
        filters = jnp.asarray(
            rng.standard_normal((w, f)).astype(np.float32))
        wins = jnp.asarray(_windows(stream, length, hop))
        want = np.asarray(ops.sketch_bits(wins, filters, step))
        got = np.asarray(rolling_sketch_bits(
            jnp.asarray(stream), filters, step, length, hop))
        np.testing.assert_array_equal(got, want)


def test_delta_histograms_match_dense(rng):
    """The delta-update invariant: the scan carrying one histogram
    through ±shift column updates equals per-window
    ``shingle_histogram`` — the histogram identity the sparse CWS path
    rests on."""
    w, step, ngram, f = 8, 2, 4, 2
    length, hop = 40, 6                    # hop % step == 0 (aligned)
    stream = rng.standard_normal(400).astype(np.float32)
    filters = jnp.asarray(rng.standard_normal((w, f)).astype(np.float32))
    n_b = (length - w) // step + 1
    s, shift = n_b - ngram + 1, hop // step
    dim = f << ngram
    nw = num_windows(len(stream), length, hop)

    gbits = ops.sketch_bits_stream(jnp.asarray(stream), filters, step)
    gids = global_shingle_ids(gbits, ngram)
    got = np.asarray(delta_histograms(gids, s, shift, nw, dim))

    wins = jnp.asarray(_windows(stream, length, hop))
    bits = ops.sketch_bits(wins, filters, step)       # (nw, N_B, F)
    want = np.stack([np.asarray(shingle.shingle_histogram(b, ngram))
                     for b in bits])
    np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# search: golden top-1, exclusion zone, telemetry
# ---------------------------------------------------------------------------

def test_golden_top1_matches_bruteforce_dtw(stream, sub):
    """Top-1 of the hash-pruned subsequence search equals exact DTW over
    every window — a planted exact copy must come back at distance 0."""
    q = jnp.asarray(stream[1200:1200 + L])
    res = sub.search(q, CFG)
    wins = jnp.asarray(_windows(stream, L, HOP))
    gold_ids, gold_d = brute_force_topk(q, wins, 1, band=CFG.band)
    assert int(res.ids[0]) == int(gold_ids[0])
    assert res.dists[0] == pytest.approx(float(gold_d[0]))
    assert int(res.offsets[0]) == 1200 and res.dists[0] == 0.0
    assert res.n_windows == num_windows(len(stream), L, HOP)
    assert res.stream_length == len(stream)


def test_exclusion_zone_separates_matches(stream, sub):
    """Returned offsets are pairwise >= the exclusion zone (default
    L//2), so near-duplicate shifted windows collapse to one match."""
    q = jnp.asarray(stream[800:800 + L])
    res = sub.search(q, CFG)
    offs = np.asarray(res.offsets)
    if len(offs) > 1:
        gap = np.abs(offs[:, None] - offs[None, :])
        gap[np.arange(len(offs)), np.arange(len(offs))] = 1 << 30
        assert gap.min() >= L // 2
    # explicit zone: 0 disables the dedup entirely
    res0 = sub.search(q, CFG.replace(exclusion_zone=0, topk=3))
    assert len(res0.ids) <= 3


def test_search_telemetry_carries_amortized_encode(stream, sub):
    q = jnp.asarray(stream[404:404 + L])
    res = sub.search(q, CFG)
    assert res.stats is not None
    assert "encode_amortized" in res.stats.stage_seconds
    assert res.stats.stage_seconds["encode_amortized"] >= 0.0
    assert res.stats.n_windows == sub.num_windows


def test_query_shape_and_window_mismatch_raise(stream, sub):
    with pytest.raises(ValueError, match="one window"):
        sub.search(jnp.zeros(L + 1), CFG)
    with pytest.raises(ValueError, match="subseq_window"):
        sub.search(jnp.zeros(L), CFG.replace(subseq_window=L * 2))


# ---------------------------------------------------------------------------
# growth + persistence: extend == rebuild, reload answers identically
# ---------------------------------------------------------------------------

def test_extend_stream_matches_full_rebuild(stream):
    sub = SubsequenceIndex.build(stream[:2000], SPEC, length=L, hop=HOP,
                                 backend="jnp")
    n_new = sub.extend_stream(stream[2000:2600])
    assert n_new == num_windows(2600, L, HOP) - num_windows(2000, L, HOP)
    rebuilt = SubsequenceIndex.build(stream[:2600], SPEC, length=L,
                                     hop=HOP, backend="jnp")
    np.testing.assert_array_equal(np.asarray(sub.inner.signatures),
                                  np.asarray(rebuilt.inner.signatures))
    np.testing.assert_array_equal(np.asarray(sub.inner.keys),
                                  np.asarray(rebuilt.inner.keys))
    # a too-short tail completes no window but still lands in the stream
    before = sub.num_windows
    assert sub.extend_stream(np.zeros(1, np.float32)) in (0, 1)
    assert sub.num_windows >= before


def test_save_load_roundtrip_and_extend(tmp_path, stream, sub):
    q = jnp.asarray(stream[1200:1200 + L])
    want = sub.search(q, CFG)
    sub.save(tmp_path / "db", CFG)
    loaded, cfg = SubsequenceIndex.load(tmp_path / "db")
    assert cfg == CFG
    np.testing.assert_array_equal(np.asarray(loaded.inner.signatures),
                                  np.asarray(sub.inner.signatures))
    got = loaded.search(q, CFG)
    np.testing.assert_array_equal(got.ids, want.ids)
    np.testing.assert_allclose(got.dists, want.dists)
    # the reload keeps growing, bit-identical to growing the original
    tail = np.asarray(synthetic_ecg(400, seed=9), np.float32)
    twin = SubsequenceIndex.build(stream, SPEC, length=L, hop=HOP,
                                  backend="jnp")
    assert loaded.extend_stream(tail) == twin.extend_stream(tail) > 0
    np.testing.assert_array_equal(np.asarray(loaded.inner.signatures),
                                  np.asarray(twin.inner.signatures))


def test_facade_build_stream_routing(tmp_path, stream):
    db = TimeSeriesDB.build_stream(stream, spec=SPEC, config=CFG)
    q = jnp.asarray(stream[1200:1200 + L])
    res = db.search_subsequence(q)
    assert int(res.offsets[0]) == 1200
    assert db.length == L and len(db) == db.subseq.num_windows
    with pytest.raises(ValueError, match="search_subsequence"):
        db.search(q)
    with pytest.raises(ValueError, match="extend_stream"):
        db.add(np.zeros((2, L), np.float32))
    assert db.extend_stream(np.asarray(synthetic_ecg(300, seed=2),
                                       np.float32)) > 0
    db.save(tmp_path / "facade_db")
    db2 = TimeSeriesDB.load(tmp_path / "facade_db")
    np.testing.assert_array_equal(db2.search_subsequence(q).ids,
                                  db.search_subsequence(q).ids)
    # fixed-length databases reject the stream verbs
    series = jnp.asarray(extract_subsequences(
        np.asarray(synthetic_ecg(1500, seed=1)), L, stride=16))
    db3 = TimeSeriesDB.build(series, spec=SPEC,
                             config=SearchConfig(searcher="local"))
    with pytest.raises(ValueError, match="build_stream"):
        db3.search_subsequence(q)
    with pytest.raises(ValueError, match="build_stream"):
        db3.extend_stream(np.zeros(10, np.float32))


def test_build_stream_requires_window():
    with pytest.raises(ValueError, match="subseq_window"):
        TimeSeriesDB.build_stream(np.zeros(500, np.float32), spec=SPEC,
                                  config=SearchConfig())


# ---------------------------------------------------------------------------
# signature LRU: every entry point reports hits
# ---------------------------------------------------------------------------

def test_sig_cache_hits_subseq(stream, sub):
    q = jnp.asarray(stream[640:640 + L])
    first = sub.search(q, CFG)
    second = sub.search(q, CFG)
    assert first.stats.sig_cache_hit == 0
    assert second.stats.sig_cache_hit == 1
    np.testing.assert_array_equal(first.ids, second.ids)


def test_sig_cache_hits_sequential_and_batched(stream):
    series = jnp.asarray(extract_subsequences(stream, L, stride=8))
    index = TimeSeriesDB.build(series, spec=SPEC,
                               config=SearchConfig(searcher="local")).index
    q = series[7]
    cfg = SearchConfig(topk=5, top_c=64, band=8)
    assert ssh_search(q, index, cfg).stats.sig_cache_hit == 0
    hit = ssh_search(q, index, cfg)
    assert hit.stats.sig_cache_hit == 1
    qs = series[10:14]
    miss_b = ssh_search_batch(qs, index, cfg)
    hit_b = ssh_search_batch(qs, index, cfg)
    assert miss_b.stats.sig_cache_hit == 0
    assert hit_b.stats.sig_cache_hit == int(qs.shape[0])
    for b in range(int(qs.shape[0])):        # cached rows change nothing
        np.testing.assert_array_equal(hit_b.per_query(b).ids,
                                      miss_b.per_query(b).ids)


def test_serving_metrics_sig_cache_counter():
    m = ServingMetrics()
    m.on_batch(batch_size=2, latencies_s=[0.01], queue_waits_s=[0.0],
               pruned_by_hash_frac=[0.5], pruned_total_frac=[0.6],
               depth_after=0, sig_cache_hits=2)
    m.on_batch(batch_size=1, latencies_s=[0.01], queue_waits_s=[0.0],
               pruned_by_hash_frac=[0.5], pruned_total_frac=[0.6],
               depth_after=0, sig_cache_hits=1)
    assert m.snapshot()["sig_cache_hits_total"] == 3


# ---------------------------------------------------------------------------
# streaming fold: pre-encoded, series-less appends
# ---------------------------------------------------------------------------

def test_append_encoded_validates_and_folds(stream):
    enc = make_encoder(SPEC, length=L)
    wins = jnp.asarray(_windows(stream[:600], L, HOP))
    sigs = np.asarray(enc.encode_batch(wins, backend="jnp"))
    keys = np.asarray(enc.band_keys(jnp.asarray(sigs)))
    ing = StreamIngestor(enc, backend="jnp")
    ing.append_encoded(sigs, keys)
    arts = ing.artifacts()
    assert arts.series is None
    np.testing.assert_array_equal(arts.signatures, sigs)
    with pytest.raises(ValueError, match="equal rows"):
        ing.append_encoded(sigs, keys[:-1])
    with pytest.raises(ValueError, match="do not match"):
        ing.append_encoded(sigs[:, :-1], keys)
