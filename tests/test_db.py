"""repro.db facade: SearchConfig validation, searcher routing, index
persistence (build → save → load → bit-identical answers), and the
legacy-kwarg deprecation shims.

Acceptance (ISSUE 3): one round-trip test proves
``TimeSeriesDB.load(dir).search(q)`` returns bit-identical ids/dists to
the pre-save index for all four searcher backends.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSHParams, ssh_search
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import (BatchPolicy, SearchConfig, TimeSeriesDB,
                      available_searchers)
from repro.serving import ssh_search_batch

pytestmark = pytest.mark.api

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)
QIDS = [3, 100, 250, 444, 512]


@pytest.fixture(scope="module")
def series():
    stream = synthetic_ecg(2500, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))   # ~594 series


@pytest.fixture(scope="module")
def db(series):
    return TimeSeriesDB.build(series, PARAMS,
                              SearchConfig(topk=5, band=8).replace(top_c=64))


@pytest.fixture(scope="module")
def saved_dir(db, tmp_path_factory):
    out = tmp_path_factory.mktemp("ssh_db")
    db.save(out)
    return out


# ---------------------------------------------------------------------------
# SearchConfig
# ---------------------------------------------------------------------------

def test_config_validate_rejects_bad_knobs():
    with pytest.raises(ValueError, match="topk"):
        SearchConfig(topk=0).validate()
    with pytest.raises(ValueError, match="top_c"):
        SearchConfig(topk=20, top_c=10).validate()
    with pytest.raises(ValueError, match="band"):
        SearchConfig(band=0).validate()
    with pytest.raises(ValueError, match="backend"):
        SearchConfig(backend="cuda").validate()
    with pytest.raises(ValueError, match="multiprobe"):
        SearchConfig(multiprobe_offsets=0).validate()
    with pytest.raises(ValueError, match="host_buckets"):
        SearchConfig(use_host_buckets=True, searcher="batched").validate()
    with pytest.raises(ValueError, match="max_batch"):
        SearchConfig(batch_policy=BatchPolicy(max_batch=0)).validate()
    # replace() validates too
    with pytest.raises(ValueError, match="seed_size"):
        SearchConfig().replace(seed_size=-1)
    # a seed smaller than topk would make the cascade threshold unsound
    with pytest.raises(ValueError, match="seed_size"):
        SearchConfig(topk=10, seed_size=4).validate()


def test_config_validate_rejects_impossible_fleet():
    with pytest.raises(ValueError, match="replication"):
        SearchConfig(replication=0).validate()
    with pytest.raises(ValueError, match="fleet_workers"):
        SearchConfig(fleet_workers=0).validate()
    # R replicas need R distinct workers (no co-location)
    with pytest.raises(ValueError, match="replication"):
        SearchConfig(replication=3, fleet_workers=2).validate()
    with pytest.raises(ValueError, match="hedge_policy"):
        SearchConfig(hedge_policy="eventually").validate()
    with pytest.raises(ValueError, match="hedge_ms"):
        SearchConfig(hedge_ms=0.0).validate()
    # the legal corner: R == fleet size is allowed (every worker holds
    # every shard — full mirroring)
    SearchConfig(replication=3, fleet_workers=3, band=8).validate()


def test_config_replace_and_roundtrip_dict():
    cfg = SearchConfig(band=8).replace(topk=7, searcher="local")
    assert cfg.topk == 7 and cfg.band == 8
    again = SearchConfig.from_dict(cfg.to_dict())
    assert again == cfg
    with pytest.warns(RuntimeWarning, match="unknown"):
        got = SearchConfig.from_dict({**cfg.to_dict(), "new_knob": 1})
    assert got == cfg


def test_unknown_searcher_rejected(db):
    with pytest.raises(ValueError, match="unknown searcher"):
        db.with_config(db.config.replace(searcher="warp-drive")).search(
            db.index.series[0])
    assert set(available_searchers()) >= {"local", "batched",
                                          "distributed", "engine"}


# ---------------------------------------------------------------------------
# persistence: build -> save -> load -> identical answers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_save_load_bit_identical_per_kernel_backend(series, db, saved_dir,
                                                    backend):
    """Loaded index answers bit-identical top-k on both kernel backends
    (pallas runs in interpret mode off-TPU)."""
    cfg = db.config.replace(backend=backend)
    loaded = TimeSeriesDB.load(saved_dir, cfg)
    for qid in QIDS:
        want = db.with_config(cfg).search(series[qid])
        got = loaded.search(series[qid])
        np.testing.assert_array_equal(want.ids, got.ids)
        np.testing.assert_array_equal(np.asarray(want.dists),
                                      np.asarray(got.dists))


def test_roundtrip_all_searcher_backends(series, db, saved_dir):
    """Acceptance: pre-save vs load()ed answers are bit-identical for all
    four searcher backends (distributed runs on the 1-device mesh)."""
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_usable = (len(db) // jax.device_count()) * jax.device_count()
    assert n_usable == len(db), "fixture must shard evenly for distributed"
    for searcher in ("local", "batched", "distributed", "engine"):
        cfg = db.config.replace(searcher=searcher, multiprobe_offsets=1)
        with db.with_config(cfg) as before, \
                TimeSeriesDB.load(saved_dir, cfg, mesh=mesh) as after:
            before.mesh = mesh
            for qid in QIDS[:3]:
                want = before.search(series[qid])
                got = after.search(series[qid])
                np.testing.assert_array_equal(
                    want.ids, got.ids,
                    err_msg=f"searcher={searcher} qid={qid}")
                np.testing.assert_array_equal(
                    np.asarray(want.dists), np.asarray(got.dists),
                    err_msg=f"searcher={searcher} qid={qid}")


def test_loaded_index_arrays_and_fns_bit_identical(db, saved_dir):
    loaded = TimeSeriesDB.load(saved_dir)
    for name in ("signatures", "keys", "series"):
        np.testing.assert_array_equal(
            np.asarray(getattr(db.index, name)),
            np.asarray(getattr(loaded.index, name)), err_msg=name)
    np.testing.assert_array_equal(np.asarray(db.index.fns.filters),
                                  np.asarray(loaded.index.fns.filters))
    for f in db.index.fns.cws._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(db.index.fns.cws, f)),
            np.asarray(getattr(loaded.index.fns.cws, f)), err_msg=f)
    # envelope cache persisted (build precomputed it at config.band)
    assert loaded.index.env_radius == db.index.env_radius == 8
    np.testing.assert_array_equal(np.asarray(db.index.env_upper),
                                  np.asarray(loaded.index.env_upper))
    # saved search policy travels with the index
    assert loaded.config == db.config


def test_add_after_load_consistent_with_never_saved(series, tmp_path):
    """Streaming add() into a loaded database matches an identically-grown
    index that was never saved (same hash functions, same answers)."""
    base, extra = series[:400], series[400:420]
    cfg = SearchConfig(topk=5, band=8).replace(top_c=64)
    never_saved = TimeSeriesDB.build(base, PARAMS, cfg)
    saved = TimeSeriesDB.build(base, PARAMS, cfg)
    saved.save(tmp_path / "db")
    loaded = TimeSeriesDB.load(tmp_path / "db")

    never_saved.add(extra)
    loaded.add(extra)
    np.testing.assert_array_equal(np.asarray(never_saved.index.signatures),
                                  np.asarray(loaded.index.signatures))
    np.testing.assert_array_equal(np.asarray(never_saved.index.keys),
                                  np.asarray(loaded.index.keys))
    for qid in (405, 10):        # a new series and an old one
        want = never_saved.search(series[qid])
        got = loaded.search(series[qid])
        np.testing.assert_array_equal(want.ids, got.ids)
        np.testing.assert_array_equal(np.asarray(want.dists),
                                      np.asarray(got.dists))
    # the added series is its own nearest neighbour in both
    assert never_saved.search(series[405]).ids[0] == 5 + 400


def test_load_missing_dir_raises(tmp_path):
    with pytest.raises(FileNotFoundError, match="no SSH database"):
        TimeSeriesDB.load(tmp_path / "nope")


def test_resave_same_directory_after_add(series, tmp_path):
    """save → add → save into the SAME directory: the second save
    publishes a new checkpoint step (monotonic, keep=2 — re-saving never
    deletes the arrays the live meta points at) and load sees the grown
    index."""
    cfg = SearchConfig(topk=3, band=8).replace(top_c=64)
    d = TimeSeriesDB.build(series[:300], PARAMS, cfg)
    out = tmp_path / "db"
    d.save(out)
    d.add(series[300:305])
    d.save(out)
    loaded = TimeSeriesDB.load(out)
    assert len(loaded) == 305
    np.testing.assert_array_equal(np.asarray(d.index.signatures),
                                  np.asarray(loaded.index.signatures))
    # both checkpoint steps exist until the next save (crash safety)
    from repro.checkpoint import all_steps
    from repro.db.persistence import ARRAYS_SUBDIR
    assert len(all_steps(out / ARRAYS_SUBDIR)) == 2


def test_save_flushes_pending_engine_inserts(series, tmp_path):
    """An add() queued by a running engine (drained only between batches)
    must land in the snapshot a following save() writes."""
    cfg = SearchConfig(topk=3, band=8, searcher="engine").replace(top_c=64)
    with TimeSeriesDB.build(series[:400], PARAMS, cfg) as db_:
        db_.search(series[0])            # starts the batcher thread
        db_.add(series[400:402])         # enqueued, not yet drained
        db_.save(tmp_path / "db")
        loaded = TimeSeriesDB.load(tmp_path / "db")
        assert len(loaded) == 402
        got = loaded.with_config(cfg.replace(searcher="batched")) \
            .search(series[401])
        assert got.ids[0] == 401


# ---------------------------------------------------------------------------
# facade routing & misc
# ---------------------------------------------------------------------------

def test_searchers_agree_with_each_other(series, db):
    """local / batched / engine answer identically (equality contract)."""
    got = {}
    for searcher in ("local", "batched", "engine"):
        with db.with_config(db.config.replace(searcher=searcher)) as d:
            got[searcher] = d.search_batch(series[jnp.asarray(QIDS[:3])])
    for searcher in ("batched", "engine"):
        for a, b in zip(got["local"], got[searcher]):
            np.testing.assert_array_equal(a.ids, b.ids)
            np.testing.assert_allclose(np.asarray(a.dists),
                                       np.asarray(b.dists),
                                       rtol=1e-5, atol=1e-5)


def test_seed_size_widening_keeps_topk(series, db):
    """A widened seed (tighter cascade threshold) never changes the
    answer, sequentially and batched — the threshold stays a valid upper
    bound on the k-th distance."""
    wide = db.config.replace(seed_size=4 * db.config.topk)
    for qid in QIDS[:3]:
        want = ssh_search(series[qid], db.index, config=db.config)
        got = ssh_search(series[qid], db.index, config=wide)
        np.testing.assert_array_equal(want.ids, got.ids)
    bw = ssh_search_batch(series[jnp.asarray(QIDS[:3])], db.index,
                          config=wide)
    for i, qid in enumerate(QIDS[:3]):
        want = ssh_search(series[qid], db.index, config=db.config)
        pq = bw.per_query(i)
        np.testing.assert_array_equal(pq.ids, want.ids)


def test_reconfigure_swaps_policy_in_place(series, db):
    d = TimeSeriesDB(db.index, db.config)
    r1 = d.search(series[3])
    d.reconfigure(topk=3, searcher="local")
    r2 = d.search(series[3])
    assert len(r2.ids) == 3
    np.testing.assert_array_equal(r1.ids[:3], r2.ids)


# ---------------------------------------------------------------------------
# deprecation shims: legacy kwargs == facade config, with a warning
# ---------------------------------------------------------------------------

def test_ssh_search_legacy_kwargs_shim(series, db):
    cfg = db.config
    for qid in QIDS[:3]:
        want = ssh_search(series[qid], db.index, config=cfg)
        with pytest.warns(DeprecationWarning, match="deprecated"):
            got = ssh_search(series[qid], db.index, topk=cfg.topk,
                             top_c=cfg.top_c, band=cfg.band)
        np.testing.assert_array_equal(want.ids, got.ids)
        np.testing.assert_array_equal(np.asarray(want.dists),
                                      np.asarray(got.dists))
    with pytest.raises(TypeError, match="not both"):
        ssh_search(series[3], db.index, config=cfg, topk=5)
    with pytest.raises(TypeError, match="unexpected"):
        ssh_search(series[3], db.index, topc=64)        # typo'd knob
    # historical *positional* topk still binds through the shim
    with pytest.warns(DeprecationWarning, match="deprecated"):
        got = ssh_search(series[3], db.index, 3)
    want = ssh_search(series[3], db.index, config=SearchConfig(topk=3))
    np.testing.assert_array_equal(want.ids, got.ids)


def test_ssh_search_batch_legacy_kwargs_shim(series, db):
    cfg = db.config
    queries = series[jnp.asarray(QIDS[:3])]
    want = ssh_search_batch(queries, db.index, config=cfg)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        got = ssh_search_batch(queries, db.index, topk=cfg.topk,
                               top_c=cfg.top_c, band=cfg.band)
    np.testing.assert_array_equal(want.ids, got.ids)
    np.testing.assert_array_equal(want.dists, got.dists)


def test_engine_config_alias_retired():
    """The PR-3 deprecation alias is gone: constructing it raises with
    migration guidance, and repro.serving no longer exports it."""
    import repro.serving
    from repro.serving.engine import EngineConfig
    with pytest.raises(TypeError, match="batch_policy"):
        EngineConfig(topk=5)
    assert not hasattr(repro.serving, "EngineConfig")


# ---------------------------------------------------------------------------
# BatchPolicy
# ---------------------------------------------------------------------------

def test_batch_policy_validate_and_roundtrip():
    pol = BatchPolicy(mode="adaptive", max_batch=16, max_wait_ms=4.0,
                      min_wait_ms=0.1, gain=0.7, ewma_alpha=0.2)
    assert pol.validate() is pol
    assert BatchPolicy.from_dict(pol.to_dict()) == pol
    assert pol.replace(max_batch=8).max_batch == 8
    assert pol.buckets() == [1, 2, 4, 8, 16]
    for bad in (dict(mode="magic"), dict(max_batch=0),
                dict(max_wait_ms=-1.0), dict(min_wait_ms=-0.1),
                dict(min_wait_ms=5.0, max_wait_ms=2.0), dict(gain=0.0),
                dict(ewma_alpha=0.0), dict(ewma_alpha=1.5)):
        with pytest.raises(ValueError):
            BatchPolicy(**bad).validate()


def test_batch_policy_busy_engine_drains_at_min_wait():
    """The adaptive budget stretches only from an idle engine; a batch
    opened while work was already queued drains at the min-wait floor
    (waiting can't raise throughput when the engine is the bottleneck)."""
    pol = BatchPolicy(mode="adaptive", max_batch=8, max_wait_ms=10.0)
    s = 0.1
    assert pol.wait_budget_s(2, 6, s) == 0.0          # covered: drain
    busy = pol.wait_budget_s(2, 1, s, engine_idle=False)
    assert busy == pol.min_wait_ms / 1e3
    assert pol.wait_budget_s(2, 1, s, engine_idle=True) >= busy
    # busy beats the no-telemetry fallback too
    assert pol.wait_budget_s(2, 1, None, engine_idle=False) == busy
    # arrivals sparser than the justified wait: nothing to coalesce
    stretch = pol.wait_budget_s(2, 1, s)
    assert pol.wait_budget_s(2, 1, s, arrival_gap_s=2 * stretch) == busy
    assert pol.wait_budget_s(2, 1, s,
                             arrival_gap_s=stretch / 2) == stretch
    fixed = BatchPolicy(mode="fixed", max_batch=8, max_wait_ms=10.0)
    assert fixed.wait_budget_s(2, 1, s, engine_idle=False) == 10.0 / 1e3


def test_batch_policy_nested_in_search_config():
    pol = BatchPolicy(mode="adaptive", max_batch=4)
    cfg = SearchConfig(batch_policy=pol).validate()
    assert cfg.batch_policy == pol
    assert cfg.buckets() == [1, 2, 4]
    rt = SearchConfig.from_dict(cfg.to_dict())
    assert rt.batch_policy == pol


def test_flat_batcher_kwargs_deprecated_but_equivalent(series, db):
    """SearchConfig(max_batch=..., max_wait_ms=...) still works for one
    release, warns, and produces the identical nested policy (and the
    identical answers through the engine)."""
    from repro.serving import ServingEngine
    with pytest.warns(DeprecationWarning, match="batch_policy"):
        legacy = SearchConfig(topk=5, top_c=64, band=8, max_batch=4,
                              max_wait_ms=3.0)
    modern = SearchConfig(topk=5, top_c=64, band=8,
                          batch_policy=BatchPolicy(max_batch=4,
                                                   max_wait_ms=3.0))
    assert legacy == modern
    r1 = ServingEngine(db.index, legacy).search_batch(
        series[jnp.asarray(QIDS[:2])])
    r2 = ServingEngine(db.index, modern).search_batch(
        series[jnp.asarray(QIDS[:2])])
    for a, b in zip(r1, r2):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.dists, b.dists)


def test_flat_batcher_keys_load_from_saved_config():
    """Databases persisted before BatchPolicy carry flat max_batch /
    max_wait_ms keys in meta — from_dict folds them silently."""
    d = SearchConfig().to_dict()
    pol = d.pop("batch_policy")
    d["max_batch"], d["max_wait_ms"] = 16, 9.0
    import warnings as _w
    with _w.catch_warnings():
        _w.simplefilter("error")              # silence is part of the API
        cfg = SearchConfig.from_dict(d)
    assert cfg.batch_policy == BatchPolicy.from_dict(pol).replace(
        max_batch=16, max_wait_ms=9.0)


def test_make_query_fn_legacy_kwargs_shim(series):
    from repro.core.index import SSHFunctions
    from repro.distributed.dist_index import make_query_fn
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    sub = series[: (int(series.shape[0]) // jax.device_count())
                 * jax.device_count()]
    fns = SSHFunctions.create(PARAMS)
    from repro.core.index import build_signatures
    sigs = build_signatures(sub, fns)
    cfg = SearchConfig(topk=5, band=8).replace(top_c=64)
    qfn_new = make_query_fn(PARAMS, mesh, length=128, config=cfg)
    with pytest.warns(DeprecationWarning, match="deprecated"):
        qfn_old = make_query_fn(PARAMS, mesh, top_c=64, band=8, topk=5,
                                length=128)
    args = (sub, sigs, fns.filters, fns.cws._asdict(), sub[37])
    ids_new, d_new = qfn_new(*args)
    ids_old, d_old = qfn_old(*args)
    np.testing.assert_array_equal(np.asarray(ids_new), np.asarray(ids_old))
    np.testing.assert_array_equal(np.asarray(d_new), np.asarray(d_old))
    assert int(ids_new[0]) == 37
    with pytest.raises(ValueError, match="band"):
        make_query_fn(PARAMS, mesh, length=128,
                      config=SearchConfig(band=None))
