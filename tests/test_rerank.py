"""Unified re-rank subsystem (repro.core.rerank): backend equivalence,
LB-cascade soundness, envelope precompute, and the Table-2 recall guard.

Property-based tests (hypothesis) hold the Pallas wavefront kernels
(interpret mode — same kernel body as TPU) value-equal to the ``dtw``
scan oracle over random lengths, band radii and candidate counts
(including non-multiple-of-128 lane counts), and every lower bound below
banded DTW.  Kernel-equivalence tests are marked ``kernels`` so CI can
run them as a dedicated interpret-mode job.
"""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.core import (SSHParams, SSHIndex, SearchStats, brute_force_topk,
                        precision_at_k, ssh_search)
from repro.core import lower_bounds as lb
from repro.core import rerank as rr
from repro.core.dtw import dtw, dtw_batch
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.kernels import ops, ref
from repro.kernels.dtw_wavefront import dtw_wavefront, dtw_wavefront_pairs
from repro.serving import ssh_search_batch

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(4200, seed=5)
    d = extract_subsequences(stream, 128, stride=4, znorm=True)
    return jnp.asarray(d)                     # ~1k series


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS, envelope_band=8)


QIDS = [3, 100, 250, 444, 512, 700, 801, 999]


# ---------------------------------------------------------------------------
# golden equivalence: jnp vs pallas(interpret), sequential vs batched
# ---------------------------------------------------------------------------

@pytest.mark.kernels
def test_golden_backend_equivalence_sequential(db, index):
    """ssh_search backend="jnp" vs backend="pallas" (interpret on CPU):
    identical top-k ids on the synthetic-ECG database.  Distances agree
    to float32 DP-reordering tolerance (the scan oracle and the wavefront
    accumulate in different orders)."""
    for qid in QIDS[:4]:
        r_jnp = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                           backend="jnp")
        r_pal = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                           backend="pallas")
        np.testing.assert_array_equal(r_jnp.ids, r_pal.ids)
        np.testing.assert_allclose(r_jnp.dists, r_pal.dists,
                                   rtol=2e-3, atol=1e-3)
        assert r_jnp.stats.backend == "jnp"
        assert r_pal.stats.backend == "pallas"


@pytest.mark.kernels
def test_golden_backend_equivalence_batched(db, index):
    """Sequential vs batched AND jnp vs pallas: all four paths return the
    same top-k ids per query."""
    queries = db[jnp.asarray(QIDS)]
    res = {be: ssh_search_batch(queries, index, topk=10, top_c=128,
                                band=8, backend=be)
           for be in ("jnp", "pallas")}
    for b, qid in enumerate(QIDS):
        seq = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                         backend="jnp")
        for be in ("jnp", "pallas"):
            pq = res[be].per_query(b)
            np.testing.assert_array_equal(pq.ids, seq.ids)
            np.testing.assert_allclose(pq.dists, seq.dists,
                                       rtol=2e-3, atol=1e-3)
            assert pq.n_candidates == seq.n_candidates


def test_batched_equals_sequential_within_backend(db, index):
    """Within one backend the batch/sequential contract stays *tight*
    (bit-identical DTW values, not just rank-identical)."""
    queries = db[jnp.asarray(QIDS[:4])]
    res = ssh_search_batch(queries, index, topk=10, top_c=128, band=8,
                           backend="jnp")
    for b, qid in enumerate(QIDS[:4]):
        seq = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                         backend="jnp")
        pq = res.per_query(b)
        np.testing.assert_array_equal(pq.ids, seq.ids)
        np.testing.assert_allclose(pq.dists, seq.dists, rtol=1e-5,
                                   atol=1e-5)


# ---------------------------------------------------------------------------
# early-abandoning DTW: golden equivalence + kernel soundness
# ---------------------------------------------------------------------------

def test_abandon_on_off_identical_across_searchers(db):
    """Top-k must be bit-identical with early abandoning on or off, for
    every registered searcher.  local/batched/engine additionally agree
    with each other; distributed probes differently (shard-local
    collision scan) so it is held to its own on ≡ off contract."""
    from repro.db import SearchConfig, TimeSeriesDB
    base = SearchConfig(topk=10, top_c=128, band=8, searcher="local")
    dbi = TimeSeriesDB.build(db, spec=PARAMS.to_spec(), config=base)
    queries = db[jnp.asarray(QIDS[:4])]

    shared = None
    for searcher in ("local", "batched", "engine"):
        for ea in (False, True):
            cfg = base.replace(searcher=searcher, early_abandon=ea)
            res = dbi.with_config(cfg).search_batch(queries)
            out = ([np.asarray(r.ids) for r in res],
                   [np.asarray(r.dists) for r in res])
            if shared is None:
                shared = out
            else:
                for a, b in zip(out[0], shared[0]):
                    np.testing.assert_array_equal(a, b)
                for a, b in zip(out[1], shared[1]):
                    np.testing.assert_array_equal(a, b)

    dist = {}
    for ea in (False, True):
        cfg = base.replace(searcher="distributed", early_abandon=ea)
        res = dbi.with_config(cfg).search_batch(queries)
        dist[ea] = ([np.asarray(r.ids) for r in res],
                    [np.asarray(r.dists) for r in res])
    for a, b in zip(dist[True][0], dist[False][0]):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(dist[True][1], dist[False][1]):
        np.testing.assert_array_equal(a, b)


@pytest.mark.kernels
def test_abandon_on_off_identical_both_backends(db, index):
    """Within each kernel backend (jnp ref and the Pallas wavefront in
    interpret mode), early_abandon=True returns bit-identical top-k to
    early_abandon=False."""
    for qid in QIDS[:3]:
        for be in ("jnp", "pallas"):
            on = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                            backend=be, early_abandon=True)
            off = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                             backend=be, early_abandon=False)
            np.testing.assert_array_equal(on.ids, off.ids)
            np.testing.assert_array_equal(on.dists, off.dists)


@pytest.mark.kernels
def test_threshold_inf_reduces_to_plain_output(rng):
    """threshold=+inf must reproduce today's no-threshold output exactly
    — bit-for-bit — on the wavefront kernel (the threshold build shares
    its per-diagonal step with the plain build) and on the jnp ref."""
    inf = jnp.float32(np.inf)
    q = jnp.asarray(rng.normal(size=128).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(37, 128)).astype(np.float32))
    plain = np.asarray(dtw_wavefront(q, c, 8, interpret=True))
    thr = np.asarray(dtw_wavefront(q, c, 8, interpret=True, threshold=inf))
    np.testing.assert_array_equal(plain, thr)
    plain = np.asarray(ref.dtw_wavefront_ref(q, c, band=8))
    thr = np.asarray(ref.dtw_wavefront_ref(q, c, band=8, threshold=inf))
    np.testing.assert_array_equal(plain, thr)
    qs = jnp.asarray(rng.normal(size=(21, 64)).astype(np.float32))
    cs = jnp.asarray(rng.normal(size=(21, 64)).astype(np.float32))
    plain = np.asarray(dtw_wavefront_pairs(qs, cs, 6, interpret=True))
    thr = np.asarray(dtw_wavefront_pairs(qs, cs, 6, interpret=True,
                                         threshold=inf))
    np.testing.assert_array_equal(plain, thr)
    plain = np.asarray(ref.dtw_pairs_ref(qs, cs, band=6))
    thr = np.asarray(ref.dtw_pairs_ref(qs, cs, band=6, threshold=inf))
    np.testing.assert_array_equal(plain, thr)


@pytest.mark.kernels
@pytest.mark.parametrize("impl", ["pallas", "jnp"])
def test_abandon_soundness_adversarial_thresholds(rng, impl):
    """The kernel must never abandon a lane that would finish under its
    threshold.  Per-lane thresholds are set adversarially relative to
    each lane's exact cost: exactly equal (the tie must SURVIVE — the
    contract masks on strict >, and an off-by-one in the wavefront's
    early-exit condition, e.g. testing the bound one diagonal late,
    breaks exactly this case), one ulp below (must be masked to BIG),
    and far above (must stay exact)."""
    def run(q, c, threshold=None):
        if impl == "pallas":
            return np.asarray(dtw_wavefront(q, c, 6, interpret=True,
                                            threshold=threshold))
        return np.asarray(ref.dtw_wavefront_ref(q, c, band=6,
                                                threshold=threshold))

    big = np.float32(1e30)
    q = jnp.asarray(rng.normal(size=64).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(50, 64)).astype(np.float32))
    exact = run(q, c)

    # tie at the threshold: every lane finishes, values exact
    got = run(q, c, threshold=jnp.asarray(exact))
    np.testing.assert_array_equal(got, exact)

    # one ulp below each lane's cost: every lane must be masked
    below = np.nextafter(exact, np.float32(-np.inf)).astype(np.float32)
    got = run(q, c, threshold=jnp.asarray(below))
    assert np.all(got >= big * 0.5)

    # mixed per-lane: alternate tie / one-ulp-below — survivors exact,
    # the rest masked, no cross-lane leakage inside a block
    thr = np.where(np.arange(50) % 2 == 0, exact, below).astype(np.float32)
    got = run(q, c, threshold=jnp.asarray(thr))
    keep = np.arange(50) % 2 == 0
    np.testing.assert_array_equal(got[keep], exact[keep])
    assert np.all(got[~keep] >= big * 0.5)

    # far above: nothing abandons, values exact
    got = run(q, c, threshold=jnp.asarray(exact * 4 + 1))
    np.testing.assert_array_equal(got, exact)


# ---------------------------------------------------------------------------
# recall regression (paper Table 2 guard)
# ---------------------------------------------------------------------------

def test_recall_regression_ssh_ecg(db):
    """precision@10 vs exact DTW must not drop below the pinned seed value
    on the ssh_ecg config — cascade/threshold changes can never silently
    destroy recall.  The fixture is fully deterministic (seeded data,
    seeded hashes); the measured value at pin time was 0.725."""
    from repro.configs.ssh_ecg import SMOKE
    idx = SSHIndex.build(db, SMOKE, envelope_band=8)
    precs = []
    for qid in QIDS:
        res = ssh_search(db[qid], idx, topk=10, top_c=256, band=8,
                         multiprobe_offsets=SMOKE.step, backend="jnp")
        gold, _ = brute_force_topk(db[qid], db, 10, band=8)
        precs.append(precision_at_k(res.ids, gold, 10))
    assert float(np.mean(precs)) >= 0.7


# ---------------------------------------------------------------------------
# envelope precompute on the index
# ---------------------------------------------------------------------------

def test_candidate_envelopes_match_direct(db, index):
    u, low = index.candidate_envelopes(8)
    u2, l2 = lb.envelope(db[:64], 8)
    np.testing.assert_allclose(np.asarray(u[:64]), np.asarray(u2),
                               rtol=1e-6)
    np.testing.assert_allclose(np.asarray(low[:64]), np.asarray(l2),
                               rtol=1e-6)
    assert index.env_radius == 8
    assert u.shape == db.shape


def test_candidate_envelopes_track_inserts_and_radius(db):
    idx = SSHIndex.build(db[:100], PARAMS, envelope_band=4)
    assert idx.env_upper.shape == (100, db.shape[1])
    idx.insert(db[100:110])
    assert idx.env_upper.shape == (110, db.shape[1])
    u, low = lb.envelope(db[100:110], 4)
    np.testing.assert_allclose(np.asarray(idx.env_upper[100:]),
                               np.asarray(u), rtol=1e-6)
    # radius change recomputes
    u2, _ = idx.candidate_envelopes(6)
    assert idx.env_radius == 6
    want, _ = lb.envelope(db[:110], 6)
    np.testing.assert_allclose(np.asarray(u2), np.asarray(want), rtol=1e-6)


def test_envelope_precompute_does_not_change_results(db, index):
    """Cascade decisions with cached envelopes == computed per block."""
    bare = SSHIndex.build(db, PARAMS)          # no envelope cache
    for qid in QIDS[:3]:
        r_env = ssh_search(db[qid], index, topk=10, top_c=128, band=8,
                           backend="jnp")
        r_bare = ssh_search(db[qid], bare, topk=10, top_c=128, band=8,
                            backend="jnp")
        np.testing.assert_array_equal(r_env.ids, r_bare.ids)
        assert r_env.n_candidates == r_bare.n_candidates


# ---------------------------------------------------------------------------
# stats plumbing
# ---------------------------------------------------------------------------

def test_search_stats_partition(db, index):
    """Cascade counters partition the candidate set exactly:
    n_in == pruned_kim + pruned_keogh + pruned_keogh2 + pruned_improved
    + n_dtw, with the abandoned lanes a subset of the DTW stage."""
    for qid in QIDS[:4]:
        s = ssh_search(db[qid], index, topk=10, top_c=128, band=8).stats
        assert isinstance(s, SearchStats)
        assert s.n_in == s.pruned_kim + s.pruned_keogh + s.pruned_keogh2 \
            + s.pruned_improved + s.n_dtw
        assert 0.0 <= s.lb_pruned_frac <= 1.0
        assert 0 <= s.dtw_abandoned <= s.n_dtw
    res = ssh_search_batch(db[jnp.asarray(QIDS)], index, topk=10,
                           top_c=128, band=8)
    s = res.stats
    assert s.n_in == s.pruned_kim + s.pruned_keogh + s.pruned_keogh2 \
        + s.pruned_improved + s.n_dtw
    assert s.n_dtw == res.dtw_evals


def test_serving_metrics_report_lb_pruning(db, index):
    from repro.db import BatchPolicy, SearchConfig
    from repro.serving import ServingEngine
    engine = ServingEngine(index, SearchConfig(
        topk=5, top_c=64, band=8, backend="jnp",
        batch_policy=BatchPolicy(max_batch=4)))
    engine.search_batch(db[jnp.asarray(QIDS[:4])])
    snap = engine.metrics.snapshot()
    assert "lb_pruned_frac_mean" in snap
    assert 0.0 <= snap["lb_pruned_frac_mean"] <= 1.0


# ---------------------------------------------------------------------------
# property-based kernel/bound correctness (hypothesis)
# ---------------------------------------------------------------------------

if st is None:
    @pytest.mark.kernels
    def test_wavefront_equiv_property():
        pytest.importorskip("hypothesis")

    @pytest.mark.kernels
    def test_wavefront_pairs_equiv_property():
        pytest.importorskip("hypothesis")

    def test_lower_bounds_sound_property():
        pytest.importorskip("hypothesis")
else:
    @pytest.mark.kernels
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 140), st.integers(8, 48), st.integers(1, 64),
           st.integers(0, 2 ** 31 - 1))
    def test_wavefront_equiv_property(c, m, band, seed):
        """dtw_wavefront (interpret) ≡ the dtw_batch oracle over random
        candidate counts (incl. >128, i.e. multi-lane-block grids and
        non-multiple-of-128 remainders), lengths, and band radii
        (incl. radii ≥ m, the unconstrained case)."""
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=m).astype(np.float32))
        cands = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
        got = dtw_wavefront(q, cands, band, interpret=True)
        want = dtw_batch(q, cands, band=min(band, m - 1))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)

    @pytest.mark.kernels
    @settings(max_examples=12, deadline=None)
    @given(st.integers(1, 140), st.integers(8, 40), st.integers(1, 8),
           st.integers(0, 2 ** 31 - 1))
    def test_wavefront_pairs_equiv_property(p, m, band, seed):
        """Row-aligned pairs kernel (interpret) ≡ per-row dtw oracle."""
        rng = np.random.default_rng(seed)
        qs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
        cs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
        got = dtw_wavefront_pairs(qs, cs, band, interpret=True)
        want = ref.dtw_pairs_ref(qs, cs, band=band)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(8, 48), st.integers(1, 6),
           st.integers(0, 2 ** 31 - 1))
    def test_lower_bounds_sound_property(m, r, seed):
        """Every cascade bound lower-bounds banded DTW (soundness — what
        makes the cascade exact), and the staged masks agree with the
        reference cascade.  NOTE: the bounds are *not* totally ordered
        (LB_Kim compares endpoints against the query itself while
        LB_Keogh relaxes them through the envelope, so lb_kim > lb_keogh
        happens on ~half of random inputs); only `lb ≤ dtw` is an
        invariant, and that is what exactness rests on."""
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=m).astype(np.float32))
        x = jnp.asarray(rng.normal(size=(3, m)).astype(np.float32))
        d = np.asarray(dtw_batch(q, x, band=r))
        u, low = lb.envelope(q, r)
        eps = 1e-3
        assert np.all(np.asarray(lb.lb_kim(q, x)) <= d + eps)
        assert np.all(np.asarray(lb.lb_keogh(u, low, x)) <= d + eps)
        assert np.all(np.asarray(lb.lb_keogh2(q, x, r)) <= d + eps)
        best = jnp.asarray(np.float32(rng.uniform(0.1, 20.0)))
        k1, k2, k3 = lb.cascade_staged(q, x, r, best)
        np.testing.assert_array_equal(
            np.asarray(k1 & k2 & k3), np.asarray(lb.cascade(q, x, r, best)))


# ---------------------------------------------------------------------------
# dispatch plumbing
# ---------------------------------------------------------------------------

def test_resolve_backend():
    assert ops.resolve_backend("auto") is None
    assert ops.resolve_backend("pallas") is True
    assert ops.resolve_backend("jnp") is False
    with pytest.raises(ValueError, match="backend"):
        ops.resolve_backend("cuda")
    assert ops.backend_name(False) == "jnp"
    assert ops.backend_name(True) == "pallas"


@pytest.mark.kernels
def test_dtw_rerank_pairs_dispatch(rng):
    """ops.dtw_rerank_pairs: interpret path == ref, band=None maps to the
    unconstrained radius on the kernel path."""
    qs = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
    cs = jnp.asarray(rng.normal(size=(5, 24)).astype(np.float32))
    for band in (3, None):
        got = ops.dtw_rerank_pairs(qs, cs, band, use_pallas=True,
                                   interpret=True)
        want = ref.dtw_pairs_ref(qs, cs, band=band)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-4)


def test_dtw_pairs_chunked_matches_unchunked(rng):
    """The fixed-shape chunking (pad + PAIR_CHUNK/PAIR_CHUNK_SMALL split)
    is transparent: values equal the direct per-row oracle at every
    survivor count, including non-multiples of the chunk sizes."""
    m = 20
    for p in (1, 31, 32, 33, 70):
        qs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
        cs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
        got = rr.dtw_pairs_chunked(qs, cs, 4, backend="jnp")
        want = np.asarray(ref.dtw_pairs_ref(qs, cs, band=4))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
