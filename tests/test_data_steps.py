"""Data pipeline + step/spec plumbing tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.data.timeseries import (extract_subsequences, make_benchmark_db,
                                   random_walk, synthetic_ecg, warp_series)
from repro.launch import steps


def test_generators_deterministic():
    np.testing.assert_array_equal(random_walk(100, seed=5),
                                  random_walk(100, seed=5))
    np.testing.assert_array_equal(synthetic_ecg(500, seed=5),
                                  synthetic_ecg(500, seed=5))
    assert not np.array_equal(random_walk(100, seed=5),
                              random_walk(100, seed=6))


def test_extract_subsequences_shapes():
    s = random_walk(1000)
    d = extract_subsequences(s, 128, stride=4)
    assert d.shape == ((1000 - 128) // 4 + 1, 128)
    np.testing.assert_array_equal(d[3], s[12:140])
    z = extract_subsequences(s, 64, stride=64, znorm=True)
    np.testing.assert_allclose(z.mean(1), 0, atol=1e-5)


def test_warp_series():
    x = np.sin(np.linspace(0, 10, 200)).astype(np.float32)
    w = warp_series(x, shift=5, stretch=1.02, noise=0.0)
    assert w.shape == x.shape
    # shifted copy correlates strongly but isn't identical
    assert 0.8 < np.corrcoef(x, w)[0, 1] < 1.0
    assert not np.array_equal(x, w)


def test_make_benchmark_db():
    db = make_benchmark_db("randomwalk", 100, 64, seed=1)
    assert db.shape == (100, 64)


def test_ecg_quasi_periodic():
    """The ECG generator must produce repeating beats (SSH's use case)."""
    s = synthetic_ecg(4000, seed=0)
    # autocorrelation at one beat period (~208 samples) is high
    period = int(250 * 60 / 72)
    a = s[:-period] - s[:-period].mean()
    b = s[period:] - s[period:].mean()
    corr = float((a * b).sum() / np.sqrt((a * a).sum() * (b * b).sum()))
    assert corr > 0.5


@pytest.mark.parametrize("name", list_archs())
def test_abstract_state_covers_all_cells(name):
    """Every (arch × shape) cell builds abstract args + a step fn."""
    arch = get_arch(name)
    for shape in arch.shapes:
        kind, state = steps.abstract_state(arch, shape)
        fn = steps.make_step(arch, shape, kind)
        assert callable(fn)
        assert all(hasattr(l, "shape") for l in jax.tree.leaves(state))


def test_abstract_state_matches_smoke_params():
    """Smoke and full params share tree structure (checkpoint compat)."""
    arch = get_arch("granite-3-2b")
    full = steps.abstract_params(arch, "train_4k")
    smoke = jax.eval_shape(steps.init_fn(arch, "train_4k", smoke=True))
    assert jax.tree.structure(full) == jax.tree.structure(smoke)
