"""Infrastructure: optimizer, grad compression, checkpointing, sharding
rules, HLO executed-cost parser."""
import os
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (Checkpointer, latest_step, restore_checkpoint,
                              save_checkpoint)
from repro.checkpoint.checkpointer import all_steps
from repro.train.grad_compress import (compress_with_feedback, init_residual,
                                       int8_dequantize, int8_quantize,
                                       topk_compress)
from repro.train.optimizer import AdamW


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------

def test_adamw_converges_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0, warmup_steps=1, grad_clip=None)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    target = jnp.asarray([1.0, 2.0])
    for _ in range(200):
        g = {"w": 2 * (params["w"] - target)}
        params, state, _ = opt.update(params, state, g)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_adamw_mixed_precision_master():
    """bf16 params with fp32 master: tiny updates must not be lost."""
    opt = AdamW(lr=1e-4, weight_decay=0.0, warmup_steps=1, grad_clip=None)
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    state = opt.init(params)
    assert state.master["w"].dtype == jnp.float32
    for _ in range(10):
        params, state, _ = opt.update(params, state,
                                      {"w": jnp.ones((4,), jnp.float32)})
    # master moved even though each bf16 step may round
    assert float(state.master["w"][0]) < 1.0


def test_grad_clip_bounds_update():
    opt = AdamW(lr=1.0, grad_clip=1e-3, warmup_steps=1, weight_decay=0.0)
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    _, _, metrics = opt.update(params, state, {"w": jnp.full((3,), 1e6)})
    assert float(metrics["grad_norm"]) > 1e5      # reported pre-clip


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def test_topk_keeps_largest(rng):
    g = jnp.asarray(rng.normal(size=100), jnp.float32)
    out = np.asarray(topk_compress(g, 0.1))
    kept = np.nonzero(out)[0]
    assert len(kept) >= 10
    thresh = np.sort(np.abs(np.asarray(g)))[-10]
    assert np.all(np.abs(np.asarray(g)[kept]) >= thresh - 1e-6)


def test_int8_roundtrip_error(rng):
    g = jnp.asarray(rng.normal(size=256), jnp.float32)
    q, s = int8_quantize(g, jax.random.PRNGKey(0))
    back = int8_dequantize(q, s)
    assert float(jnp.max(jnp.abs(back - g))) <= float(s) * 1.01


def test_error_feedback_unbiased_over_time(rng):
    """With error feedback, the mean of sent updates converges to the true
    gradient: the deviation is bounded by residual/T, and the residual for
    any coordinate is bounded by ~(1/frac)·|g| between sends."""
    true = jnp.asarray(rng.normal(size=64), jnp.float32)
    frac, rounds = 0.05, 400
    residual = init_residual({"w": true})
    sent_total = jnp.zeros_like(true)
    for i in range(rounds):
        sent, residual = compress_with_feedback(
            {"w": true}, residual, scheme="topk", topk_frac=frac)
        sent_total = sent_total + sent["w"]
    avg = np.asarray(sent_total / rounds)
    bound = 2.0 * float(jnp.max(jnp.abs(true))) / (frac * rounds)
    np.testing.assert_allclose(avg, np.asarray(true), atol=bound)
    # and the bias shrinks with more rounds (sanity on the trend)
    assert np.abs(avg - np.asarray(true)).max() < 0.5


def test_compression_schemes_run(rng):
    grads = {"a": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)}
    res = init_residual(grads)
    for scheme in ("topk", "int8", "none"):
        sent, res2 = compress_with_feedback(
            grads, res, scheme=scheme, key=jax.random.PRNGKey(1))
        assert sent["a"].shape == (8, 8)


# --------------------------------------------------------------------------
# checkpointing / fault tolerance
# --------------------------------------------------------------------------

def _tree(rng):
    return {"layer": {"w": rng.normal(size=(8, 4)).astype(np.float32),
                      "b": rng.normal(size=4).astype(np.float32)},
            "step_count": np.asarray(3)}


def test_checkpoint_roundtrip(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(tmp_path, 7, tree)
    assert latest_step(tmp_path) == 7
    step, restored = restore_checkpoint(tmp_path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree))
    assert step == 7
    np.testing.assert_allclose(np.asarray(restored["layer"]["w"]),
                               tree["layer"]["w"])


def test_checkpoint_retention_and_atomicity(tmp_path, rng):
    tree = _tree(rng)
    for s in range(6):
        save_checkpoint(tmp_path, s, tree, keep=3)
    assert all_steps(tmp_path) == [3, 4, 5]
    assert not [p for p in Path(tmp_path).iterdir()
                if p.name.startswith(".tmp")]


def test_checkpoint_sharded_save_restore(tmp_path, rng):
    """n_shards>1 emulates per-host shard files; restore reassembles."""
    tree = {"table": rng.normal(size=(16, 4)).astype(np.float32)}
    save_checkpoint(tmp_path, 1, tree, n_shards=4)
    files = list((Path(tmp_path) / "step_0000000001").glob("shard_*.npz"))
    assert len(files) == 4
    _, restored = restore_checkpoint(tmp_path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree))
    np.testing.assert_allclose(np.asarray(restored["table"]), tree["table"])


def test_checkpointer_async(tmp_path, rng):
    ck = Checkpointer(tmp_path, async_save=True)
    tree = _tree(rng)
    ck.save(2, tree)
    ck.wait()
    assert latest_step(tmp_path) == 2
    step, restored = ck.restore_latest(jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree))
    assert step == 2


def test_restart_resume_protocol(tmp_path, rng):
    """Crash/restart: trainer discovers latest step and resumes from it."""
    tree = _tree(rng)
    save_checkpoint(tmp_path, 10, tree)
    save_checkpoint(tmp_path, 20, jax.tree.map(lambda x: x * 2, tree))
    step = latest_step(tmp_path)
    assert step == 20
    _, restored = restore_checkpoint(tmp_path, jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(np.shape(x), np.asarray(x).dtype),
        tree), step=step)
    np.testing.assert_allclose(np.asarray(restored["layer"]["w"]),
                               tree["layer"]["w"] * 2)


# --------------------------------------------------------------------------
# sharding rules (mesh faked — only .shape / .axis_names are consulted)
# --------------------------------------------------------------------------

class FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_spec_divisibility_fallback():
    from repro.distributed.sharding import spec_for
    mesh = FakeMesh({"data": 16, "model": 16})
    # divisible: sharded
    assert spec_for(("batch", None), (256, 4), mesh)[0] == "data"
    # non-divisible: replicated
    assert spec_for(("batch", None), (100, 4), mesh)[0] is None
    # heads=8 cannot take model=16; head_dim=128 can
    s = spec_for(("heads", "head_dim"), (8, 128), mesh)
    assert s[0] is None and s[1] == "model"


def test_spec_no_double_axis_use():
    from repro.distributed.sharding import spec_for
    mesh = FakeMesh({"data": 16, "model": 16})
    s = spec_for(("ff", "vocab"), (1600, 1600), mesh)
    # both want 'model'; only the first gets it
    assert s[0] == "model" and s[1] is None


def test_dp_axes_multipod():
    from repro.distributed.sharding import spec_for
    mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    s = spec_for(("batch", None), (64, 4), mesh)
    assert s[0] == ("pod", "data")


# --------------------------------------------------------------------------
# HLO executed-cost parser (validated-exact cases)
# --------------------------------------------------------------------------

def test_hlo_executed_flops_exact():
    from repro.launch.hlo_graph import executed_costs
    L, B, D = 5, 32, 64

    def f(x, ws):
        def body(c, w):
            return jnp.tanh(c @ w), ()
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    x = jax.ShapeDtypeStruct((B, D), jnp.float32)
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    want = L * 2 * B * D * D
    got = executed_costs(jax.jit(f).lower(x, ws).compile().as_text()).dot_flops
    assert got == pytest.approx(want, rel=1e-6)
    got3 = executed_costs(jax.jit(jax.grad(
        lambda x, ws: f(x, ws), argnums=1)).lower(x, ws).compile()
        .as_text()).dot_flops
    assert got3 == pytest.approx(3 * want, rel=1e-6)
