"""repro.encoders: IndexSpec validation/round-trip, registry, the three
built-in encoders behind one facade, fused multiprobe equality, compile
caching, persistence spec-mismatch refusal, and the SSHParams shim.

Acceptance (ISSUE 4): ``TimeSeriesDB.build(spec=IndexSpec(encoder="srp"))``
and ``encoder="ssh-multires"`` save/load round-trip bit-identically across
all four searchers, and the default ``"ssh"`` spec reproduces
pre-refactor signatures exactly (golden values captured from the
pre-encoder ``SSHParams``/``_signature_one`` code path).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSHParams, srp_search
from repro.core.index import SSHFunctions, SSHIndex, build_signatures
from repro.core.srp import make_srp, srp_bits
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import SearchConfig, TimeSeriesDB
from repro.encoders import (IndexSpec, available_encoders, make_encoder,
                            register_encoder)
from repro.encoders.base import Encoder

pytestmark = pytest.mark.encoders

SMOKE = dict(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)
SPECS = {
    "ssh": IndexSpec(encoder="ssh", params=SMOKE),
    "srp": IndexSpec(encoder="srp"),
    "ssh-multires": IndexSpec(
        encoder="ssh-multires",
        params=dict(window=24, step=3, ngrams=(6, 8), num_hashes=40,
                    num_tables=20)),
}


def _waves(m, b=3):
    t = np.arange(m, dtype=np.float32)
    rows = [np.sin(t * 0.1 * (i + 1)) + 0.3 * np.cos(t * 0.037 * (i + 2))
            for i in range(b)]
    return jnp.asarray(np.stack(rows).astype(np.float32))


@pytest.fixture(scope="module")
def series():
    stream = synthetic_ecg(2000, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))   # ~469 series


# ---------------------------------------------------------------------------
# IndexSpec
# ---------------------------------------------------------------------------

def test_spec_validate_and_replace():
    spec = IndexSpec(encoder="ssh", params=SMOKE).validate()
    assert spec.replace(seed=11).seed == 11
    assert spec.with_params(ngram=10).params["ngram"] == 10
    with pytest.raises(ValueError, match="unknown encoder"):
        IndexSpec(encoder="warp-drive").validate()
    with pytest.raises(ValueError, match="divisible"):
        IndexSpec(encoder="ssh", params=dict(num_hashes=13,
                                             num_tables=5)).validate()
    with pytest.raises(ValueError, match="unknown params"):
        IndexSpec(encoder="ssh", params=dict(windw=24)).validate()
    with pytest.raises(ValueError, match="resolution"):
        IndexSpec(encoder="ssh-multires", params=dict(ngrams=())).validate()
    with pytest.raises(ValueError, match="divisible"):
        IndexSpec(encoder="srp", params=dict(num_hashes=10,
                                             num_tables=3)).validate()


def test_spec_dict_roundtrip_and_json():
    for spec in SPECS.values():
        # through json, as persistence stores it (tuples become lists
        # and are normalised back)
        again = IndexSpec.from_dict(json.loads(json.dumps(spec.to_dict())))
        assert again == spec
    with pytest.warns(RuntimeWarning, match="unknown"):
        got = IndexSpec.from_dict({**SPECS["ssh"].to_dict(), "new": 1})
    assert got == SPECS["ssh"]


def test_registry_roundtrip_same_signatures(series):
    """to_dict → from_dict → make_encoder reproduces signatures exactly
    for every built-in."""
    assert set(available_encoders()) >= {"ssh", "srp", "ssh-multires"}
    m = int(series.shape[1])
    for name, spec in SPECS.items():
        enc = make_encoder(spec, length=m)
        enc2 = make_encoder(IndexSpec.from_dict(spec.to_dict()), length=m)
        np.testing.assert_array_equal(
            np.asarray(enc.encode_batch(series[:16])),
            np.asarray(enc2.encode_batch(series[:16])), err_msg=name)


def test_register_out_of_tree_encoder(series):
    """Any registered Encoder serves through make_encoder and the facade
    (the DESIGN.md §7 extension contract)."""
    from repro.encoders.srp import SRPEncoder

    @register_encoder("test-srp-alias")
    class AliasEncoder(SRPEncoder):
        pass

    enc = make_encoder(IndexSpec(encoder="test-srp-alias"),
                       length=int(series.shape[1]))
    assert enc.materialized and enc.num_hashes == 64


# ---------------------------------------------------------------------------
# golden: the "ssh" encoder is bit-identical to the pre-refactor path
# ---------------------------------------------------------------------------

# Captured from the pre-encoder code (SSHFunctions.create +
# _signature_one) at commit 65ed0a2: paper-default SSHParams() on a
# deterministic length-256 wave, and the smoke params on length 128.
GOLDEN_DEFAULT_256 = [
    [32736, 15, 32704, 1023, 32256, 8184, 31, 32736, 2046, 127, 16391, 15,
     127, 1023, 63, 63, 24579, 30720, 8184, 31],
    [4033, 31775, 3971, 24824, 31775, 1985, 28798, 24828, 7943, 30783,
     1008, 1985, 7943, 4033, 496, 28798, 30783, 1985, 3971, 3971],
    [1822, 28897, 1822, 30833, 25031, 15416, 15416, 14449, 17295, 15416,
     14448, 14576, 7288, 3854, 1806, 18311, 25027, 25031, 3854, 14576]]
GOLDEN_SMOKE_128_ROW0 = [
    254, 255, 255, 254, 252, 240, 252, 128, 192, 255, 1, 63, 127, 240,
    255, 248, 255, 192, 255, 127, 255, 255, 252, 255, 192, 127, 255, 255,
    255, 255, 7, 63, 31, 3, 31, 255, 3, 1, 255, 0]
GOLDEN_SMOKE_KEYS_ROW0 = [
    3167855509, 518545684, 400133464, 400133352, 255942612, 333821994,
    858512673, 518545694, 518545750, 518545559, 518545687, 400133481,
    255942228, 518545687, 518545687, 2023939067, 4165311659, 4165311919,
    3698660204, 518545430]


def test_golden_default_spec_reproduces_prerefactor_signatures():
    enc = make_encoder(IndexSpec())          # encoder="ssh", all defaults
    sigs = enc.encode_chunked(_waves(256))
    np.testing.assert_array_equal(np.asarray(sigs),
                                  np.asarray(GOLDEN_DEFAULT_256, np.int32))


def test_golden_smoke_signatures_and_band_keys():
    enc = make_encoder(IndexSpec(encoder="ssh", params=SMOKE))
    sigs = enc.encode_chunked(_waves(128))
    np.testing.assert_array_equal(
        np.asarray(sigs)[0], np.asarray(GOLDEN_SMOKE_128_ROW0, np.int32))
    keys = np.asarray(enc.band_keys(sigs)).astype(np.int64)
    np.testing.assert_array_equal(
        keys[0], np.asarray(GOLDEN_SMOKE_KEYS_ROW0, np.int64))


# ---------------------------------------------------------------------------
# the SSHParams deprecation shim
# ---------------------------------------------------------------------------

def test_sshparams_shim_warns_and_is_bit_identical(series):
    params = SSHParams(**SMOKE)
    with pytest.warns(DeprecationWarning, match="SSHParams"):
        legacy = SSHIndex.build(series, params)
    modern = SSHIndex.build(series, spec=params.to_spec())
    np.testing.assert_array_equal(np.asarray(legacy.signatures),
                                  np.asarray(modern.signatures))
    np.testing.assert_array_equal(np.asarray(legacy.keys),
                                  np.asarray(modern.keys))
    # the legacy module fn agrees too (no jit re-wrap regression risk)
    np.testing.assert_array_equal(
        np.asarray(build_signatures(series[:32],
                                    SSHFunctions.create(params))),
        np.asarray(modern.signatures)[:32])
    with pytest.raises(TypeError, match="not both"):
        SSHIndex.build(series, params, spec=params.to_spec())
    with pytest.warns(DeprecationWarning, match="SSHParams"):
        TimeSeriesDB.build(series, params,
                           SearchConfig(topk=3, band=8, top_c=32))


def test_legacy_fns_index_materialises_same_encoder(series):
    """An SSHIndex constructed the historical way (fns only) lazily
    builds an encoder from the SAME arrays — queries are bit-identical
    to a spec-built index."""
    params = SSHParams(**SMOKE)
    fns = SSHFunctions.create(params)
    sigs = build_signatures(series, fns)
    legacy = SSHIndex(fns=fns, signatures=sigs,
                      keys=legacy_keys(sigs, params), series=series)
    modern = SSHIndex.build(series, spec=params.to_spec())
    q = series[7]
    np.testing.assert_array_equal(np.asarray(legacy.query_signature(q)),
                                  np.asarray(modern.query_signature(q)))
    np.testing.assert_array_equal(np.asarray(legacy.query_keys(q)),
                                  np.asarray(modern.query_keys(q)))


def legacy_keys(sigs, params):
    from repro.core.index import band_keys
    return band_keys(sigs, params)


# ---------------------------------------------------------------------------
# "srp" via the facade ≡ the legacy one-off path
# ---------------------------------------------------------------------------

def test_srp_facade_matches_legacy_srp_search(series):
    planes = make_srp(jax.random.PRNGKey(0), 64, int(series.shape[1]))
    db_bits = srp_bits(series, planes)
    cfg = SearchConfig(topk=10, top_c=10, use_lb_cascade=False,
                       searcher="local")
    db = TimeSeriesDB.build(series, spec=IndexSpec(encoder="srp", seed=0),
                            config=cfg)
    np.testing.assert_array_equal(
        np.asarray(db.index.enc.arrays()["planes"]), np.asarray(planes))
    for qid in (3, 100, 250):
        legacy = srp_search(series[qid], series, planes, db_bits, topk=10)
        got = db.search(series[qid])
        # same top-k candidate set; the facade re-orders it by DTW
        assert (set(np.asarray(legacy.ids).tolist())
                == set(np.asarray(got.ids).tolist())), qid


def test_srp_has_no_multiprobe(series):
    enc = make_encoder(IndexSpec(encoder="srp"),
                       length=int(series.shape[1]))
    with pytest.raises(ValueError, match="shift-alignment"):
        enc.encode_multiprobe(series[0], 3)
    # the facade clamps rather than letting every search raise after a
    # completed build: a multiprobe config on an srp index folds to 1
    cfg = SearchConfig(topk=3, band=8, top_c=32, multiprobe_offsets=3)
    db = TimeSeriesDB.build(series[:64], spec=IndexSpec(encoder="srp"),
                            config=cfg)
    assert db.config.multiprobe_offsets == 1
    assert db.search(series[5]).ids[0] == 5
    assert db.reconfigure(multiprobe_offsets=3) \
        .config.multiprobe_offsets == 1


# ---------------------------------------------------------------------------
# fused multiprobe: one program, bit-identical to per-offset hashing
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["ssh", "ssh-multires"])
def test_multiprobe_fused_equals_per_offset(series, name):
    enc = make_encoder(SPECS[name])
    q = series[11]
    offsets = 3
    fused = enc.encode_multiprobe(q, offsets)
    for o in range(offsets):
        np.testing.assert_array_equal(
            np.asarray(fused[o]), np.asarray(enc.encode(q[o:])),
            err_msg=f"{name} offset {o}")
    batched = enc.encode_batch_multiprobe(series[3:6], offsets)
    for b in range(3):
        for o in range(offsets):
            np.testing.assert_array_equal(
                np.asarray(batched[b, o]),
                np.asarray(enc.encode(series[3 + b, o:])),
                err_msg=f"{name} b={b} o={o}")


def test_multiprobe_backends_agree_and_short_queries_raise(series):
    """The fused multiprobe honours the backend knob (Pallas sketch,
    same signatures) and rejects queries whose last offset cannot hold a
    full shingle — matching encode(q[o:]), which would raise."""
    enc = make_encoder(SPECS["ssh"])
    q = series[0]
    np.testing.assert_array_equal(
        np.asarray(enc.encode_multiprobe(q, 3, backend="pallas")),
        np.asarray(enc.encode_multiprobe(q, 3, backend="jnp")))
    np.testing.assert_array_equal(
        np.asarray(enc.encode_batch_multiprobe(series[3:6], 3,
                                               backend="pallas")),
        np.asarray(enc.encode_batch_multiprobe(series[3:6], 3,
                                               backend="jnp")))
    # window=24, step=3, ngram=8: m=48 gives 9 bits at offset 0 but only
    # 7 < ngram at offset 5 — must raise, not hash an empty histogram
    with pytest.raises(ValueError, match="shingle"):
        enc.encode_multiprobe(series[0][:48], 6)


def test_multiprobe_compiles_once_for_all_offsets(series):
    """The fused path traces ONE program per (shape, offsets) — not one
    per offset length as the historical q[o:] slicing did."""
    enc = make_encoder(SPECS["ssh"])
    q = series[0]
    enc.encode_multiprobe(q, 3)
    n = enc.trace_counts["multiprobe"]
    enc.encode_multiprobe(q, 3)
    enc.encode_multiprobe(series[1], 3)     # same shape: cached
    assert enc.trace_counts["multiprobe"] == n


def test_chunked_build_and_insert_reuse_compiled_fn(series):
    """Satellite: the batch encode path compiles once per chunk shape;
    chunked builds and streaming inserts stop paying retrace cost."""
    enc = make_encoder(SPECS["ssh"])
    enc.encode_chunked(series[:128], batch=64)    # two chunks, one shape
    n = enc.trace_counts["batch"]
    assert n == 1
    enc.encode_chunked(series[:128], batch=64)
    assert enc.trace_counts["batch"] == n
    idx = SSHIndex.build(series[:128], spec=SPECS["ssh"], batch=64)
    n = idx.enc.trace_counts["batch"]
    idx.insert(series[128:160])               # new chunk shape: one trace
    idx.insert(series[160:192])               # same shape again: cached
    assert idx.enc.trace_counts["batch"] == n + 1


def test_encode_backends_agree(series):
    """backend="pallas" (interpret off-TPU) and "jnp" produce the same
    integer signatures — the knob changes kernels, not answers."""
    for name in ("ssh", "ssh-multires"):
        enc = make_encoder(SPECS[name])
        np.testing.assert_array_equal(
            np.asarray(enc.encode_batch(series[:16], backend="pallas")),
            np.asarray(enc.encode_batch(series[:16], backend="jnp")),
            err_msg=name)
    with pytest.raises(ValueError, match="backend"):
        make_encoder(SPECS["ssh"]).encode_batch(series[:4], backend="cuda")


# ---------------------------------------------------------------------------
# acceptance: srp + ssh-multires round-trip bit-identically, 4 searchers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["srp", "ssh-multires"])
def test_build_save_load_roundtrip_all_searchers(series, name, tmp_path):
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    n_usable = (int(series.shape[0]) // jax.device_count()) \
        * jax.device_count()
    sub = series[:n_usable]
    cfg = SearchConfig(topk=5, band=8, top_c=64)
    db = TimeSeriesDB.build(sub, spec=SPECS[name], config=cfg)
    out = tmp_path / "db"
    db.save(out)
    for searcher in ("local", "batched", "distributed", "engine"):
        c2 = cfg.replace(searcher=searcher)
        with db.with_config(c2) as before, \
                TimeSeriesDB.load(out, c2, mesh=mesh) as after:
            before.mesh = mesh
            for qid in (3, 250):
                want = before.search(sub[qid])
                got = after.search(sub[qid])
                np.testing.assert_array_equal(
                    want.ids, got.ids,
                    err_msg=f"{name}/{searcher} qid={qid}")
                np.testing.assert_array_equal(
                    np.asarray(want.dists), np.asarray(got.dists),
                    err_msg=f"{name}/{searcher} qid={qid}")
    loaded = TimeSeriesDB.load(out)
    assert loaded.spec == SPECS[name]
    # streaming add after load keeps hashing with the same functions
    loaded.add(sub[:2] * 1.01)
    assert len(loaded) == n_usable + 2


def test_load_refuses_spec_artifact_mismatch(series, tmp_path):
    """Tampering the persisted spec (a *valid* spec that disagrees with
    the stored arrays) must refuse to load, not silently mis-hash."""
    cfg = SearchConfig(topk=3, band=8, top_c=32)
    db = TimeSeriesDB.build(series[:64], spec=SPECS["ssh"], config=cfg)
    out = tmp_path / "db"
    db.save(out)
    meta_path = out / "ssh_db.json"
    meta = json.loads(meta_path.read_text())
    meta["spec"]["params"]["num_hashes"] = 20     # valid spec, wrong arrays
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="match IndexSpec"):
        TimeSeriesDB.load(out)
    # a tampered table count passes spec validation AND the encoder
    # state shapes (they depend only on K and dim) — the band-key width
    # check must still refuse it
    meta = json.loads((out / "ssh_db.json").read_text())
    meta["spec"]["params"]["num_hashes"] = 40
    meta["spec"]["params"]["num_tables"] = 8      # divides 40
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="spec/artifact mismatch"):
        TimeSeriesDB.load(out)
    # a different registered encoder is refused too
    meta["spec"] = IndexSpec(encoder="srp").to_dict()
    meta_path.write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="match IndexSpec"):
        TimeSeriesDB.load(out)


def test_load_arrays_names_missing_and_unknown_leaves(series):
    """Regression (ISSUE 6 satellite): load_arrays used to report a bare
    sorted-list mismatch — a state missing a leaf (or carrying a stray
    one) must name the offending leaf, not make the user diff lists."""
    from repro.encoders import encoder_class
    arrays = make_encoder(SPECS["ssh"]).arrays()
    missing = {k: v for k, v in arrays.items() if k != "filters"}
    with pytest.raises(ValueError,
                       match=r"missing encoder array leaf.*'filters'"):
        encoder_class("ssh")(SPECS["ssh"]).load_arrays(missing)
    extra = dict(arrays, rogue=np.zeros(3, np.float32))
    with pytest.raises(ValueError,
                       match=r"unrecognised encoder array leaf.*'rogue'"):
        encoder_class("ssh")(SPECS["ssh"]).load_arrays(extra)
    # the non-pipeline encoder shares the refusal (and its leaf naming)
    srp = encoder_class("srp")(IndexSpec(encoder="srp"))
    with pytest.raises(ValueError,
                       match=r"missing encoder array leaf.*'planes'"):
        srp.load_arrays({})
    planes = make_encoder(IndexSpec(encoder="srp"),
                          length=int(series.shape[1])).arrays()
    with pytest.raises(ValueError,
                       match=r"unrecognised encoder array leaf.*'bias'"):
        srp.load_arrays(dict(planes, bias=np.zeros(2, np.float32)))


def test_unmaterialized_encoder_raises():
    from repro.encoders import encoder_class
    enc = encoder_class("ssh")(SPECS["ssh"])
    with pytest.raises(RuntimeError, match="not materialized"):
        enc.encode(jnp.zeros(128))
    with pytest.raises(ValueError, match="length"):
        make_encoder(IndexSpec(encoder="srp"))    # srp needs length
