"""Fault-tolerance policies + DDP grad-compression training loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import ShardPlan, StragglerPolicy


def test_shard_plan_deterministic():
    p1 = ShardPlan(8, ["w1", "w0", "w2"])
    p2 = ShardPlan(8, ["w2", "w0", "w1"])
    assert p1.assignment == p2.assignment     # order-independent


def test_shard_plan_failure_migration():
    p = ShardPlan(9, ["w0", "w1", "w2"])
    before = dict(p.assignment)
    moved = p.fail("w1")
    assert moved == [s for s, w in before.items() if w == "w1"]
    assert all(w in ("w0", "w2") for w in p.assignment.values())
    # balanced within 1
    loads = [len(p.shards_of(w)) for w in p.workers]
    assert max(loads) - min(loads) <= 1


def test_shard_plan_elastic_resize():
    p = ShardPlan(12, ["w0", "w1"])
    moved = p.resize(["w0", "w1", "w2", "w3"])
    assert moved > 0
    loads = [len(p.shards_of(w)) for w in p.workers]
    assert max(loads) - min(loads) <= 1
    assert p.fail("w3")                        # downscale still works


def test_straggler_detection():
    pol = StragglerPolicy(threshold=1.5, patience=2)
    for step in range(5):
        for w in ("a", "b", "c"):
            pol.observe(w, 1.0)
        pol.observe("slow", 3.0)
        slow_flagged = pol.check("slow")
    assert slow_flagged
    assert not pol.check("a")
    assert "slow" in pol.stragglers()


def test_straggler_recovers():
    pol = StragglerPolicy(threshold=1.5, patience=2, alpha=1.0)
    for w in ("a", "b", "c", "d"):
        pol.observe(w, 1.0)
    pol.observe("d", 5.0)
    pol.check("d")
    pol.observe("d", 1.0)                      # recovered
    assert not pol.check("d")
    assert pol.strikes["d"] == 0


def test_shard_plan_resize_moves_minimally():
    """Stable placement: a ±1-worker resize moves at most
    ceil(n_shards / n_workers) shards (the old round-robin re-deal
    reshuffled nearly all of them)."""
    import math
    p = ShardPlan(12, ["w0", "w1", "w2"])
    up = p.resize(["w0", "w1", "w2", "w3"])
    assert 0 < up <= math.ceil(12 / 4)
    loads = [len(p.shards_of(w)) for w in p.workers]
    assert max(loads) - min(loads) <= 1
    down = p.resize(["w0", "w1", "w2"])
    assert 0 < down <= math.ceil(12 / 3)
    loads = [len(p.shards_of(w)) for w in p.workers]
    assert max(loads) - min(loads) <= 1


def test_straggler_reads_are_pure():
    """Regression: ``stragglers()``/``is_straggler()`` must not advance
    strike counters — the old combined ``check()`` double-counted a
    step when the caller both checked a worker and listed stragglers."""
    pol = StragglerPolicy(threshold=1.5, patience=3)
    for w in ("a", "b", "c"):
        pol.observe(w, 1.0)
    pol.observe("slow", 9.0)
    pol.step("slow")                     # one observed step -> one strike
    assert pol.strikes["slow"] == 1
    for _ in range(10):                  # reads at any frequency: pure
        assert not pol.is_straggler("slow")
        assert pol.stragglers() == []
    assert pol.strikes["slow"] == 1
    pol.step("slow")
    pol.step("slow")                     # third strike = patience
    assert pol.is_straggler("slow")
    assert pol.stragglers() == ["slow"]
    assert pol.strikes["slow"] == 3      # still exactly one per step()


def test_ddp_training_with_compression_converges():
    """Least-squares with top-k + error-feedback compressed 'all-reduce'
    (single process, two synthetic data shards) — training still converges."""
    from repro.train.grad_compress import (compress_with_feedback,
                                           init_residual)
    rng = np.random.default_rng(0)
    x = rng.normal(size=(64, 8)).astype(np.float32)
    true_w = rng.normal(size=(8,)).astype(np.float32)
    y = x @ true_w
    shards = [(jnp.asarray(x[:32]), jnp.asarray(y[:32])),
              (jnp.asarray(x[32:]), jnp.asarray(y[32:]))]
    w = {"w": jnp.zeros(8)}
    residuals = [init_residual(w) for _ in shards]

    def grad_fn(w, xs, ys):
        return jax.grad(lambda w: jnp.mean((xs @ w["w"] - ys) ** 2))(w)

    for step in range(300):
        sent = []
        for i, (xs, ys) in enumerate(shards):
            g = grad_fn(w, xs, ys)
            s, residuals[i] = compress_with_feedback(
                g, residuals[i], scheme="topk", topk_frac=0.25)
            sent.append(s)
        mean_g = jax.tree.map(lambda *gs: sum(gs) / len(gs), *sent)
        w = jax.tree.map(lambda p, g: p - 0.1 * g, w, mean_g)
    np.testing.assert_allclose(np.asarray(w["w"]), true_w, atol=0.05)
