"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the real single CPU device; only the dry-run (separate process) forces
512 host devices."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
