"""repro.streaming (ISSUE 6, DESIGN.md §9): count-sketch shingler,
shard-parallel StreamIngestor, sketch persistence, heavy hitters.

The acceptance contract: an ``"ssh-cs"`` index built via two shard-local
``StreamIngestor``s + one ``merge()`` answers top-k identically to the
same data ingested on a single shard, round-trips through save/load with
its sketch aggregate, and keeps ingesting after the reload.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import SearchConfig, TimeSeriesDB
from repro.encoders import IndexSpec, make_encoder
from repro.kernels import ops
from repro.kernels.ref import cs_tables_ref
from repro.streaming import StreamIngestor, count_sketch as cs

pytestmark = pytest.mark.streaming

SMOKE = dict(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)
SPEC_CS = IndexSpec(encoder="ssh-cs",
                    params=dict(**SMOKE, rows=4, width=1024, base_bits=4))
SPEC_SSH = IndexSpec(encoder="ssh", params=SMOKE)
CFG = SearchConfig(topk=10, top_c=512, band=6, multiprobe_offsets=3,
                   searcher="local")


@pytest.fixture(scope="module")
def series():
    stream = synthetic_ecg(4000, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))   # ~969 series


@pytest.fixture(scope="module")
def queries():
    """Windows at offsets off the database's stride-4 grid."""
    stream = synthetic_ecg(4000, seed=5)
    out = []
    for off in (13, 201, 555, 901, 1337, 1601, 2222, 3001):
        q = np.asarray(stream[off:off + 128], np.float32)
        out.append(jnp.asarray((q - q.mean()) / (q.std() + 1e-8)))
    return out


@pytest.fixture(scope="module")
def db_cs(series):
    return TimeSeriesDB.build(series, spec=SPEC_CS, config=CFG)


@pytest.fixture(scope="module")
def db_ssh(series):
    return TimeSeriesDB.build(series, spec=SPEC_SSH, config=CFG)


# ---------------------------------------------------------------------------
# golden: "ssh-cs" agrees with exact "ssh" on top-k
# ---------------------------------------------------------------------------

def test_golden_sshcs_topk_matches_exact(db_cs, db_ssh, queries):
    """The sketch stage changes memory, not answers: precision@10 of
    "ssh-cs" against exact "ssh" stays ≥ 0.9 on the smoke workload."""
    precisions = []
    for q in queries:
        want = set(np.asarray(db_ssh.search(q).ids).tolist())
        got = set(np.asarray(db_cs.search(q).ids).tolist())
        precisions.append(len(want & got) / CFG.topk)
    assert float(np.mean(precisions)) >= 0.9, precisions


def test_sshcs_backends_agree(db_cs, series):
    """backend="pallas" (interpret off-TPU) and "jnp" produce identical
    signatures AND identical sketch contributions for "ssh-cs"."""
    enc = db_cs.index.enc
    np.testing.assert_array_equal(
        np.asarray(enc.encode_batch(series[:8], backend="pallas")),
        np.asarray(enc.encode_batch(series[:8], backend="jnp")))
    np.testing.assert_array_equal(
        np.asarray(enc.sketch_batch(series[:8], backend="pallas")),
        np.asarray(enc.sketch_batch(series[:8], backend="jnp")))


def test_cs_tables_kernel_matches_ref(rng):
    """The Pallas one-hot scatter kernel (interpret mode) reproduces the
    reference table build, including −1 (invalid) bucket sentinels."""
    b, r, s, width = 3, 4, 300, 256
    bkt = rng.integers(-1, width, (b, r, s)).astype(np.int32)
    sgn = np.where(bkt < 0, 0.0,
                   rng.choice([-1.0, 1.0], (b, r, s))).astype(np.float32)
    got = ops.cs_tables(jnp.asarray(bkt), jnp.asarray(sgn), width,
                        use_pallas=True, interpret=True)
    want = cs_tables_ref(jnp.asarray(bkt), jnp.asarray(sgn), width)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# count-sketch properties: merge is an associative, commutative combine
# and ≡ sketching the concatenated stream
# ---------------------------------------------------------------------------

_PARAMS = cs.make_cs_params(jax.random.PRNGKey(42), levels=3, rows=3)
_WIDTH, _BASE_BITS = 128, 4


def _sketch_of(ids_list):
    ids = np.full(48, -1, np.int32)          # fixed shape: one trace
    ids[:len(ids_list)] = ids_list
    agg = jnp.zeros((3, 3, _WIDTH), jnp.float32)
    return cs.update(agg, jnp.asarray(ids), _PARAMS,
                     base_bits=_BASE_BITS)


if st is None:
    def test_merge_is_associative_commutative_and_concat():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=20, deadline=None)
    @given(*(st.lists(st.integers(0, 2 ** 12 - 1), max_size=48)
             for _ in range(3)))
    def test_merge_is_associative_commutative_and_concat(a, b, c):
        sa, sb, sc = _sketch_of(a), _sketch_of(b), _sketch_of(c)
        ab = cs.merge(sa, sb)
        np.testing.assert_array_equal(          # commutative
            np.asarray(ab), np.asarray(cs.merge(sb, sa)))
        np.testing.assert_array_equal(          # associative
            np.asarray(cs.merge(ab, sc)),
            np.asarray(cs.merge(sa, cs.merge(sb, sc))))
        # merge(a, b) ≡ sketching the concatenated stream, bit-identical
        # (f32 sums of ±1 below 2^24 are exact and order-independent) —
        # so every query over the merged sketch answers as if the shards
        # had never been split
        both = _sketch_of(list(a) + list(b))
        np.testing.assert_array_equal(np.asarray(ab), np.asarray(both))
        probe_np = np.full(48, -1, np.int32)
        vals = (a + b + [0, 7])[:48]
        probe_np[:len(vals)] = vals
        probe = jnp.asarray(probe_np)
        np.testing.assert_array_equal(
            np.asarray(cs.estimate(ab, probe, _PARAMS,
                                   base_bits=_BASE_BITS)),
            np.asarray(cs.estimate(both, probe, _PARAMS,
                                   base_bits=_BASE_BITS)))


def test_estimate_tracks_true_counts():
    """Planted frequencies are recovered within count-sketch error."""
    rng = np.random.default_rng(3)
    stream = np.concatenate([np.full(200, 137), np.full(90, 9),
                             rng.integers(0, 2 ** 12, 400)])
    agg = _sketch_of([])  # zeros
    agg = cs.update(agg, jnp.asarray(stream, jnp.int32), _PARAMS,
                    base_bits=_BASE_BITS)
    est = np.asarray(cs.estimate(
        agg, jnp.asarray([137, 9], jnp.int32), _PARAMS,
        base_bits=_BASE_BITS))
    assert abs(est[0] - 200) <= 20 and abs(est[1] - 90) <= 20


def test_find_heavy_hitters_recovers_planted_ids(db_cs):
    """Hierarchical top-down recovery through the encoder surface."""
    enc = make_encoder(SPEC_CS)
    assert enc.find_heavy_hitters(10.0)[0].size == 0   # fresh: empty
    id_space = 1 << enc.shingler.id_bits
    ids = np.concatenate([np.full(300, 137), np.full(150, 201),
                          np.random.default_rng(0).integers(
                              0, id_space, 500)])
    enc.absorb_sketch(enc.shingler.update(
        enc.empty_sketch(), jnp.asarray(ids, jnp.int32)))
    hot, ests = enc.find_heavy_hitters(100.0)
    assert 137 in hot.tolist() and 201 in hot.tolist()
    assert list(ests) == sorted(ests, reverse=True)


# ---------------------------------------------------------------------------
# StreamIngestor: shard-parallel fold ≡ single-shard ingest
# ---------------------------------------------------------------------------

def test_out_of_order_appends_fold_in_seq_order(db_cs, series):
    """Any arrival order yields the same artifacts — ordering comes from
    the seq tags, resolved once at fold time."""
    enc = db_cs.index.enc
    a = StreamIngestor(enc)
    a.append(series[:4], seq=1)
    a.append(series[4:8], seq=0)
    b = StreamIngestor(enc)
    b.append(series[4:8], seq=0)
    b.append(series[:4], seq=1)
    fa, fb = a.artifacts(), b.artifacts()
    np.testing.assert_array_equal(fa.series, fb.series)
    np.testing.assert_array_equal(fa.signatures, fb.signatures)
    np.testing.assert_array_equal(fa.keys, fb.keys)
    np.testing.assert_array_equal(np.asarray(fa.sketch),
                                  np.asarray(fb.sketch))
    np.testing.assert_array_equal(fa.series[:4], np.asarray(series[4:8]))


def test_merge_mismatched_specs_and_empty_fold_raise(db_cs, db_ssh):
    ing = StreamIngestor(db_cs.index.enc)
    with pytest.raises(ValueError, match="different specs"):
        ing.merge(StreamIngestor(db_ssh.index.enc))
    with pytest.raises(ValueError, match="no appended series"):
        ing.artifacts()
    with pytest.raises(ValueError, match="'ssh-cs'"):
        StreamIngestor(db_ssh.index.enc).heavy_hitters(1.0)


def test_ssh_ingestor_has_no_sketch(db_ssh, series):
    """The exact encoder streams too — artifacts just carry no sketch."""
    ing = StreamIngestor(db_ssh.index.enc)
    ing.append(series[:4])
    assert ing.sketch is None and ing.artifacts().sketch is None


def test_acceptance_two_shard_merge_save_load(series, queries, tmp_path):
    """The ISSUE 6 acceptance path end to end: shard-parallel ingest ≡
    single-shard, bit-identical through save/load, still ingesting."""
    base, stream = series[:64], series[64:128]
    blocks = [stream[i:i + 16] for i in range(0, 64, 16)]

    db_one = TimeSeriesDB.build(base, spec=SPEC_CS, config=CFG)
    for i, blk in enumerate(blocks):
        db_one.add_stream(blk, seq=i)
    db_one.flush()

    db_two = TimeSeriesDB.build(base, spec=SPEC_CS, config=CFG)
    enc = db_two.index.enc
    sh0 = StreamIngestor(enc, shard="edge0",
                         backend=db_two.index.build_backend)
    sh1 = StreamIngestor(enc, shard="edge1",
                         backend=db_two.index.build_backend)
    sh0.append(blocks[0], seq=0)
    sh0.append(blocks[1], seq=1)
    sh1.append(blocks[2], seq=2)
    sh1.append(blocks[3], seq=3)
    db_two.apply_stream(sh0.merge(sh1))

    assert len(db_one) == len(db_two) == 128
    np.testing.assert_array_equal(
        np.asarray(db_one.index.enc.aggregate_sketch()),
        np.asarray(db_two.index.enc.aggregate_sketch()))
    for q in queries[:4]:
        one, two = db_one.search(q), db_two.search(q)
        np.testing.assert_array_equal(one.ids, two.ids)
        np.testing.assert_array_equal(np.asarray(one.dists),
                                      np.asarray(two.dists))

    out = tmp_path / "db"
    db_two.save(out)
    loaded = TimeSeriesDB.load(out)
    np.testing.assert_array_equal(            # sketch survived the disk
        np.asarray(loaded.index.enc.aggregate_sketch()),
        np.asarray(db_two.index.enc.aggregate_sketch()))
    for q in queries[:4]:
        np.testing.assert_array_equal(loaded.search(q).ids,
                                      db_two.search(q).ids)
    loaded.add_stream(series[128:144])        # keeps ingesting
    loaded.flush()
    assert len(loaded) == 144
    assert not np.array_equal(
        np.asarray(loaded.index.enc.aggregate_sketch()),
        np.asarray(db_two.index.enc.aggregate_sketch()))


def test_save_flushes_pending_stream(series, tmp_path):
    """save() folds pending add_stream appends first — every mutation
    that returned before save() is in the snapshot."""
    db = TimeSeriesDB.build(series[:64], spec=SPEC_CS, config=CFG)
    db.add_stream(series[64:80])
    db.save(tmp_path / "db")
    assert len(db) == 80
    assert len(TimeSeriesDB.load(tmp_path / "db")) == 80


def test_apply_stream_spec_mismatch_raises(db_cs, db_ssh, series):
    ing = StreamIngestor(db_ssh.index.enc)
    ing.append(series[:2])
    with pytest.raises(ValueError, match="cannot fold"):
        db_cs.apply_stream(ing)


def test_stream_fold_through_live_searchers(series, queries):
    """apply_stream routes through a live searcher (engine drains its
    insert queue under the serve lock; batched re-slices) and the folded
    rows answer identically to a batch-built database."""
    want = TimeSeriesDB.build(series[:96], spec=SPEC_CS, config=CFG)
    for searcher in ("batched", "engine"):
        cfg = CFG.replace(searcher=searcher)
        with TimeSeriesDB.build(series[:64], spec=SPEC_CS,
                                config=cfg) as db:
            db.search(queries[0])             # searcher goes live
            db.add_stream(series[64:96])
            db.flush()
            assert len(db) == 96
            got = db.search(queries[1])
            np.testing.assert_array_equal(
                got.ids, want.search(queries[1]).ids, err_msg=searcher)


# ---------------------------------------------------------------------------
# index_bytes gauge
# ---------------------------------------------------------------------------

def test_index_bytes_on_stats_and_metrics(db_cs, db_ssh, queries, series):
    """SearchStats.index_bytes == SSHIndex.nbytes on the sequential path;
    the serving metrics expose the same gauge; the sketch encoder's
    state is smaller than the exact encoder's (the memory story)."""
    res = db_cs.search(queries[0])
    assert res.stats.index_bytes == db_cs.index.nbytes() > 0

    def state_bytes(enc):
        return sum(int(a.size) * a.dtype.itemsize
                   for a in enc.state().values())
    # the memory claim needs a paper-scale shingle space: at ngram=15
    # the exact CWS fields span F·2^15 bins per hash, the sketch stage
    # stays at rows·width no matter how large the vocabulary grows
    big = dict(window=24, step=3, ngram=15, num_hashes=4, num_tables=2)
    exact = make_encoder(IndexSpec(encoder="ssh", params=big))
    sketchy = make_encoder(IndexSpec(
        encoder="ssh-cs",
        params=dict(**big, rows=4, width=1024, base_bits=4)))
    assert state_bytes(sketchy) < state_bytes(exact) / 4

    cfg = CFG.replace(searcher="engine")
    with TimeSeriesDB.build(series[:64], spec=SPEC_CS, config=cfg) as db:
        db.search(queries[0])
        snap = db.engine.metrics.snapshot()
        assert snap["index_bytes"] == db.index.nbytes() > 0
