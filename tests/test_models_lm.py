"""Transformer family: decode/forward equivalence, training signal,
chunked-attention vs plain attention, MoE routing invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.ref import flash_attention_ref
from repro.models import layers
from repro.models.moe import MoEConfig, capacity, gating, moe_ffn
from repro.models.transformer import (LMConfig, decode_step, forward,
                                      init_cache, init_params, loss_fn)

TINY = LMConfig(name="tiny", n_layers=3, d_model=64, n_heads=8, n_kv_heads=4,
                d_ff=128, vocab=101, q_chunk=16, kv_chunk=16, dtype="float32")
TINY_MLA_MOE = LMConfig(
    name="tmm", n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=97, moe=True, n_experts=4, top_k=2, n_shared=1, moe_d_ff=32,
    moe_group_size=64, mla=True, kv_lora_rank=16, qk_nope_dim=16,
    qk_rope_dim=8, v_head_dim=16, q_chunk=16, kv_chunk=16, dtype="float32",
    # high capacity so decode (2-token groups) and full-seq routing drop the
    # same (zero) tokens — otherwise equality cannot hold by construction
    capacity_factor=8.0)


def test_chunked_attention_matches_plain(rng):
    b, s, h, hk, d = 2, 37, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hk, d)), jnp.float32)
    out = layers.chunked_attention(q, k, v, causal=True, q_chunk=8,
                                   kv_chunk=8)
    # reference with repeated kv heads
    g = h // hk
    kr = jnp.repeat(k, g, axis=2).transpose(0, 2, 1, 3)
    vr = jnp.repeat(v, g, axis=2).transpose(0, 2, 1, 3)
    qr = q.transpose(0, 2, 1, 3).reshape(b, hk, g, s, d)
    qq = q.transpose(0, 2, 1, 3)
    want = flash_attention_ref(qq, kr, vr, causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_decode_attention_matches_chunked(rng):
    b, t, h, hk, d = 3, 29, 8, 4, 16
    q = jnp.asarray(rng.normal(size=(b, 1, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, t, hk, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, hk, d)), jnp.float32)
    valid = jnp.asarray([t, t - 5, 7])
    out = layers.decode_attention(q, k, v, kv_valid=valid)
    for i in range(b):
        want = layers.chunked_attention(
            q[i:i + 1], k[i:i + 1, :int(valid[i])],
            v[i:i + 1, :int(valid[i])], causal=False, q_chunk=1, kv_chunk=8)
        np.testing.assert_allclose(np.asarray(out[i]), np.asarray(want[0]),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("cfg", [TINY, TINY_MLA_MOE], ids=["gqa", "mla_moe"])
def test_decode_matches_forward(cfg, rng):
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 7)), jnp.int32)
    full, _ = forward(params, toks, cfg)
    cache = init_cache(cfg, 2, 12)
    outs = []
    for i in range(7):
        lg, cache = decode_step(params, cache, toks[:, i:i + 1], cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=5e-3, atol=5e-3)


def test_loss_decreases(rng):
    """A few AdamW steps on one batch must reduce the loss (learning sanity)."""
    from repro.train.optimizer import AdamW
    cfg = TINY
    params = init_params(cfg, jax.random.PRNGKey(1))
    opt = AdamW(lr=3e-3, warmup_steps=1)
    state = opt.init(params)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (4, 24)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(params, state):
        (l, _), g = jax.value_and_grad(
            lambda p: loss_fn(p, batch, cfg), has_aux=True)(params)
        params, state, _ = opt.update(params, state, g)
        return params, state, l

    losses = []
    for _ in range(8):
        params, state, l = step(params, state)
        losses.append(float(l))
    assert losses[-1] < losses[0] - 0.1


def test_rope_relative_property(rng):
    """RoPE: <q_i, k_j> depends only on i - j (verified via shifted pos)."""
    q = jnp.asarray(rng.normal(size=(1, 4, 1, 32)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 4, 1, 32)), jnp.float32)
    p1 = jnp.arange(4)
    p2 = jnp.arange(4) + 11
    s1 = jnp.einsum("bshd,bthd->st", layers.apply_rope(q, p1),
                    layers.apply_rope(k, p1))
    s2 = jnp.einsum("bshd,bthd->st", layers.apply_rope(q, p2),
                    layers.apply_rope(k, p2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-4,
                               atol=1e-4)


def test_moe_capacity_and_combine(rng):
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32,
                    group_size=32, capacity_factor=1.25)
    logits = jnp.asarray(rng.normal(size=(2, 32, 4)), jnp.float32)
    dispatch, combine, aux = gating(logits, cfg, 32)
    c = capacity(cfg, 32)
    assert dispatch.shape == (2, 32, 4, c)
    # each expert slot holds at most one token
    slot_load = np.asarray(dispatch).sum(axis=1)          # (G, E, C)
    assert slot_load.max() <= 1.0 + 1e-6
    # each token contributes at most top_k combine mass rows
    tok_disp = np.asarray(dispatch).sum(axis=(2, 3))
    assert tok_disp.max() <= cfg.top_k + 1e-6
    assert float(aux) > 0


def test_moe_ffn_shapes_and_grads(rng):
    cfg = MoEConfig(n_experts=4, top_k=2, d_model=16, d_ff=32, group_size=16)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    router = jax.random.normal(ks[0], (16, 4))
    wg = jax.random.normal(ks[1], (4, 16, 32)) * 0.1
    wu = jax.random.normal(ks[2], (4, 16, 32)) * 0.1
    wd = jax.random.normal(ks[3], (4, 32, 16)) * 0.1
    x = jnp.asarray(rng.normal(size=(2, 17, 16)), jnp.float32)  # ragged tail

    def f(x):
        y, aux = moe_ffn(x, router, wg, wu, wd, cfg)
        return jnp.sum(y * y) + aux
    g = jax.grad(f)(x)
    assert g.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(g)))
