"""SSH stages 1-3 unit + property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.core import minhash, shingle, sketch


def test_sketch_shapes(rng):
    x = jnp.asarray(rng.normal(size=(3, 100)).astype(np.float32))
    filt = sketch.make_filter(jax.random.PRNGKey(0), 20, 2)
    bits = sketch.sketch_bits(x, filt, 4)
    assert bits.shape == (3, (100 - 20) // 4 + 1, 2)
    assert set(np.unique(np.asarray(bits))) <= {0, 1}


def test_sketch_scale_invariance(rng):
    """sign(r·(a x)) == sign(r·x) for a > 0 — sketches ignore amplitude."""
    x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    filt = sketch.make_filter(jax.random.PRNGKey(1), 16, 1)
    b1 = sketch.sketch_bits(x, filt, 2)
    b2 = sketch.sketch_bits(3.7 * x, filt, 2)
    np.testing.assert_array_equal(np.asarray(b1), np.asarray(b2))


def test_shift_by_step_shifts_bits(rng):
    """Paper §4.2: a shift by δ produces the same bits, offset by one —
    the mechanism behind shingle shift-invariance."""
    step = 4
    x = rng.normal(size=256).astype(np.float32)
    filt = sketch.make_filter(jax.random.PRNGKey(2), 16, 1)
    b = np.asarray(sketch.sketch_bits(jnp.asarray(x)[None], filt, step))[0, :, 0]
    b_shift = np.asarray(sketch.sketch_bits(
        jnp.asarray(x[step:])[None], filt, step))[0, :, 0]
    np.testing.assert_array_equal(b[1:len(b_shift) + 1], b_shift)


def test_pack_ngrams_known():
    bits = jnp.asarray([[1, 0, 1, 1]], dtype=jnp.uint8)
    ids = shingle.pack_ngrams(bits, 2)
    # windows: (1,0)->1, (0,1)->2, (1,1)->3  (bit j contributes <<j)
    np.testing.assert_array_equal(np.asarray(ids)[0], [1, 2, 3])


def test_histogram_counts(rng):
    bits = jnp.asarray(rng.integers(0, 2, (40, 1)), jnp.uint8)
    h = shingle.shingle_histogram(bits, 3)
    assert h.shape == (8,)
    assert int(jnp.sum(h)) == 40 - 3 + 1


def test_weighted_jaccard_props(rng):
    a = jnp.asarray(rng.integers(0, 5, 32), jnp.float32)
    assert float(shingle.weighted_jaccard(a, a)) == pytest.approx(1.0)
    z = jnp.zeros_like(a)
    assert float(shingle.weighted_jaccard(a, z)) == 0.0


if st is None:
    def test_cws_collision_estimates_jaccard():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1))
    def test_cws_collision_estimates_jaccard(seed):
        """Pr[h(x)=h(y)] ≈ J_w(x,y) (paper eq. 3) — the core LSH property."""
        rng = np.random.default_rng(seed)
        d = 64
        x = rng.integers(0, 4, d).astype(np.float32)
        y = np.where(rng.uniform(size=d) < 0.7, x,
                     rng.integers(0, 4, d)).astype(np.float32)
        true_j = float(np.minimum(x, y).sum() / np.maximum(x, y).sum())
        params = minhash.make_cws(jax.random.PRNGKey(seed % 1000), 400, d)
        hx = minhash.cws_hash(jnp.asarray(x), params)
        hy = minhash.cws_hash(jnp.asarray(y), params)
        est = float(jnp.mean((hx == hy).astype(jnp.float32)))
        assert est == pytest.approx(true_j, abs=0.12)


def test_cws_batch_matches_single(rng):
    d, k = 32, 8
    params = minhash.make_cws(jax.random.PRNGKey(3), k, d)
    w = jnp.asarray(rng.integers(0, 3, (5, d)), jnp.float32)
    batch = minhash.cws_hash_batch(w, params, chunk=2)
    for i in range(5):
        np.testing.assert_array_equal(
            np.asarray(batch[i]), np.asarray(minhash.cws_hash(w[i], params)))


def test_combine_bands(rng):
    sigs = jnp.asarray(rng.integers(0, 100, (6, 8)), jnp.int32)
    keys = minhash.combine_bands(sigs, 4)
    assert keys.shape == (6, 4)
    # deterministic + sensitive to any hash change
    keys2 = minhash.combine_bands(sigs.at[0, 0].add(1), 4)
    assert int(keys2[0, 0]) != int(keys[0, 0])
    np.testing.assert_array_equal(np.asarray(keys[1:]), np.asarray(keys2[1:]))


def test_bbit_packing_roundtrip(rng):
    from repro.core.minhash import pack_signatures, packed_collisions
    sigs = jnp.asarray(rng.integers(0, 1 << 15, (6, 8)), jnp.int32)
    packed = pack_signatures(sigs, bits=8)
    assert packed.shape == (6, 2)
    # agreement counts under packing == agreement of low-8-bit lanes
    q = sigs[0]
    want = jnp.sum((sigs & 255) == (q & 255)[None, :], axis=-1)
    got = packed_collisions(packed[0], packed, bits=8)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(got[0]) == 8          # self-collision is full


def test_bbit_ranking_quality(rng):
    """b-bit packed agreement preserves the collision-count ranking well
    enough for candidate generation (4x smaller index)."""
    from repro.core.minhash import pack_signatures, packed_collisions
    d, k, n = 64, 40, 200
    params = minhash.make_cws(jax.random.PRNGKey(5), k, d)
    base = rng.integers(0, 4, d).astype(np.float32)
    sims, rows = [], []
    for i in range(n):
        keep = rng.uniform(size=d) < rng.uniform(0.2, 1.0)
        w = np.where(keep, base, rng.integers(0, 4, d)).astype(np.float32)
        rows.append(w)
    sigs = minhash.cws_hash_batch(jnp.asarray(np.stack(rows)), params)
    qsig = minhash.cws_hash(jnp.asarray(base), params)
    full = np.asarray(jnp.sum(sigs == qsig[None, :], axis=-1))
    packed = pack_signatures(sigs, bits=8)
    qp = pack_signatures(qsig[None, :], bits=8)[0]
    pk = np.asarray(packed_collisions(qp, packed, bits=8))
    top_full = set(np.argsort(-full)[:20].tolist())
    top_pack = set(np.argsort(-pk)[:20].tolist())
    assert len(top_full & top_pack) >= 14     # >=70% overlap at top-20
