"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps in interpret
mode (kernel bodies execute in Python on CPU; same code paths as TPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the sweep tests still run
    given = settings = st = None

from repro.kernels import ops, ref
from repro.kernels.collision_count import collision_count
from repro.kernels.dtw_wavefront import dtw_wavefront, dtw_wavefront_pairs
from repro.kernels.sketch_conv import sketch_conv

# every test here exercises a Pallas kernel body in interpret mode —
# CI runs them as a dedicated `pytest -m kernels` job (junit upload)
pytestmark = pytest.mark.kernels


@pytest.mark.parametrize("b,m,w,f,step", [
    (4, 256, 40, 1, 3),      # paper ECG-ish
    (3, 130, 30, 2, 5),      # paper random-walk-ish, 2 filters
    (9, 515, 80, 4, 7),      # ragged sizes, filter bank
    (1, 64, 16, 1, 1),       # stride 1
    (8, 128, 128, 3, 2),     # window == tile
])
def test_sketch_conv_vs_ref(b, m, w, f, step, rng):
    x = jnp.asarray(rng.normal(size=(b, m)).astype(np.float32))
    filt = jnp.asarray(rng.normal(size=(w, f)).astype(np.float32))
    got = sketch_conv(x, filt, step, interpret=True)
    want = ref.sketch_conv_ref(x, filt, step)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sketch_conv_dtypes(dtype, rng):
    x = jnp.asarray(rng.normal(size=(2, 96)), dtype)
    filt = jnp.asarray(rng.normal(size=(16, 1)), dtype)
    got = sketch_conv(x, filt, 4, interpret=True)
    want = ref.sketch_conv_ref(x.astype(jnp.float32),
                               filt.astype(jnp.float32), 4)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("c,m,band", [
    (5, 32, 4), (130, 48, 8), (3, 40, 39), (7, 33, 5), (1, 16, 2),
    (256, 24, 3),
])
def test_dtw_wavefront_vs_ref(c, m, band, rng):
    q = jnp.asarray(rng.normal(size=m).astype(np.float32))
    cands = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
    got = dtw_wavefront(q, cands, band, interpret=True)
    want = ref.dtw_wavefront_ref(q, cands, band=band)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


if st is None:
    def test_dtw_wavefront_property():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=10, deadline=None)
    @given(st.integers(1, 40), st.integers(8, 40), st.integers(1, 8),
           st.integers(0, 2 ** 31 - 1))
    def test_dtw_wavefront_property(c, m, band, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=m).astype(np.float32))
        cands = jnp.asarray(rng.normal(size=(c, m)).astype(np.float32))
        got = dtw_wavefront(q, cands, band, interpret=True)
        want = ref.dtw_wavefront_ref(q, cands, band=band)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("p,m,band", [
    (5, 32, 4), (130, 24, 8), (3, 40, 39), (1, 16, 2), (256, 24, 3),
])
def test_dtw_wavefront_pairs_vs_ref(p, m, band, rng):
    """Row-aligned pairs kernel (one query per lane) == per-row oracle."""
    qs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
    cs = jnp.asarray(rng.normal(size=(p, m)).astype(np.float32))
    got = dtw_wavefront_pairs(qs, cs, band, interpret=True)
    want = ref.dtw_pairs_ref(qs, cs, band=band)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_dtw_wavefront_pairs_lane_invariance(rng):
    """A pair's value equals the single-query kernel's value for the same
    (query, candidate) — the per-lane DP is lane-position independent,
    which is what makes batched and sequential re-ranks bit-identical."""
    m, band = 28, 5
    q = jnp.asarray(rng.normal(size=m).astype(np.float32))
    cs = jnp.asarray(rng.normal(size=(7, m)).astype(np.float32))
    single = dtw_wavefront(q, cs, band, interpret=True)
    pairs = dtw_wavefront_pairs(jnp.broadcast_to(q, cs.shape), cs, band,
                                interpret=True)
    np.testing.assert_array_equal(np.asarray(single), np.asarray(pairs))


@pytest.mark.parametrize("n,k", [(300, 20), (128, 7), (1000, 40), (64, 64)])
def test_collision_count_vs_ref(n, k, rng):
    db = jnp.asarray(rng.integers(0, 5, size=(n, k)), jnp.int32)
    qk = jnp.asarray(rng.integers(0, 5, size=(k,)), jnp.int32)
    got = collision_count(qk, db, interpret=True)
    want = ref.collision_count_ref(qk, db)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,n,k", [
    (1, 300, 20), (4, 128, 7), (8, 1000, 40), (3, 64, 64), (16, 256, 24),
])
def test_collision_count_batch_vs_ref(b, n, k, rng):
    """Fused batched-probe kernel == per-row reference counts."""
    from repro.kernels.collision_count import collision_count_batch
    db = jnp.asarray(rng.integers(0, 5, size=(n, k)), jnp.int32)
    qk = jnp.asarray(rng.integers(0, 5, size=(b, k)), jnp.int32)
    got = collision_count_batch(qk, db, interpret=True)
    want = ref.collision_count_batch_ref(qk, db)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # each row also equals the single-query kernel
    row0 = collision_count(qk[0], db, interpret=True)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(row0))


def test_ops_dispatch_cpu_uses_ref(rng):
    """On CPU backend the default path must be the jnp reference."""
    x = jnp.asarray(rng.normal(size=(2, 64)).astype(np.float32))
    filt = jnp.asarray(rng.normal(size=(16, 1)).astype(np.float32))
    out = ops.sketch_conv(x, filt, 4)              # use_pallas=None on CPU
    want = ref.sketch_conv_ref(x, filt, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6)
    bits = ops.sketch_bits(x, filt, 4)
    assert set(np.unique(np.asarray(bits))) <= {0, 1}


def test_ops_dtw_and_collision_dispatch(rng):
    q = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    np.testing.assert_allclose(
        np.asarray(ops.dtw_rerank(q, c, 4)),
        np.asarray(ref.dtw_wavefront_ref(q, c, band=4)), rtol=1e-5)
    db = jnp.asarray(rng.integers(0, 3, (50, 8)), jnp.int32)
    qk = jnp.asarray(rng.integers(0, 3, (8,)), jnp.int32)
    np.testing.assert_array_equal(
        np.asarray(ops.collision_count(qk, db)),
        np.asarray(ref.collision_count_ref(qk, db)))
