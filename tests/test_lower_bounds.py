"""Lower-bound soundness: every LB must lower-bound banded DTW (that is
what makes the UCR cascade exact), and LB_Improved must dominate
LB_Keogh (that is what pays for its second pass)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.core import lower_bounds as lb
from repro.core.dtw import dtw, dtw_batch


def _naive_envelope(x, r):
    m = len(x)
    u = np.array([x[max(0, i - r):i + r + 1].max() for i in range(m)])
    l = np.array([x[max(0, i - r):i + r + 1].min() for i in range(m)])
    return u, l


def test_envelope_matches_naive(rng):
    x = rng.normal(size=64).astype(np.float32)
    for r in (1, 4, 9):
        u, l = lb.envelope(jnp.asarray(x), r)
        nu, nl = _naive_envelope(x, r)
        np.testing.assert_allclose(np.asarray(u), nu, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(l), nl, rtol=1e-6)


if st is None:
    def test_bounds_below_dtw():
        pytest.importorskip("hypothesis")

    def test_improved_chain_below_dtw():
        pytest.importorskip("hypothesis")

    def test_improved_batch_sound_any_lane_count():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(8, 48), st.integers(1, 6),
           st.integers(0, 2 ** 31 - 1))
    def test_bounds_below_dtw(m, r, seed):
        rng = np.random.default_rng(seed)
        q = rng.normal(size=m).astype(np.float32)
        x = rng.normal(size=m).astype(np.float32)
        d = float(dtw(jnp.asarray(q), jnp.asarray(x), band=r))
        u, low = lb.envelope(jnp.asarray(q), r)
        assert float(lb.lb_kim(jnp.asarray(q), jnp.asarray(x))) <= d + 1e-3
        assert float(lb.lb_keogh(u, low, jnp.asarray(x))) <= d + 1e-3
        assert float(lb.lb_keogh2(jnp.asarray(q), jnp.asarray(x)[None],
                                  r)[0]) <= d + 1e-3

    @settings(max_examples=25, deadline=None)
    @given(st.integers(8, 48), st.integers(1, 6),
           st.integers(0, 2 ** 31 - 1))
    def test_improved_chain_below_dtw(m, r, seed):
        """LB_Keogh <= LB_Improved <= DTW, and LB_Kim <= DTW.

        Note LB_Kim is deliberately NOT chained under LB_Keogh: Kim
        charges the first/last cells which Keogh's envelope may cover
        for free, so neither dominates the other — the cascade needs
        each bound sound against DTW, not mutually ordered.
        """
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=m).astype(np.float32))
        x = jnp.asarray(rng.normal(size=m).astype(np.float32))
        d = float(dtw(q, x, band=r))
        u, low = lb.envelope(q, r)
        keogh = float(lb.lb_keogh(u, low, x))
        improved = float(lb.lb_improved(q, x[None], r)[0])
        assert float(lb.lb_kim(q, x)) <= d + 1e-3
        assert keogh <= improved + 1e-3
        assert improved <= d + 1e-3
        # precomputed-envelope form must match the self-computed one
        improved2 = float(lb.lb_improved(q, x[None], r, u, low)[0])
        assert improved2 == pytest.approx(improved, rel=1e-6, abs=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(1, 37), st.integers(8, 40), st.integers(1, 5),
           st.integers(0, 2 ** 31 - 1))
    def test_improved_batch_sound_any_lane_count(n, m, r, seed):
        """Batched LB_Improved is sound lane-by-lane for candidate
        counts that are not multiples of the TPU lane width, and the
        pairs form (per-row query envelopes) agrees with the batch
        form when every row shares one query."""
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.normal(size=m).astype(np.float32))
        c = jnp.asarray(rng.normal(size=(n, m)).astype(np.float32))
        d = np.asarray(dtw_batch(q, c, band=r))
        lbi = np.asarray(lb.lb_improved(q, c, r))
        u, low = lb.envelope(q, r)
        keogh = np.asarray(lb.lb_keogh(u, low, c))
        assert np.all(keogh <= lbi + 1e-3)
        assert np.all(lbi <= d + 1e-3)
        pairs = np.asarray(lb.lb_improved_pairs(
            jnp.broadcast_to(q, (n, m)), c, r))
        np.testing.assert_allclose(pairs, lbi, rtol=1e-5, atol=1e-5)


def test_improved_tight_where_keogh_is_blind(rng):
    """A family where LB_Keogh is uninformative but LB_Improved is
    exactly DTW: a constant candidate inside the query's range at full
    band.  The query envelope then spans [min q, max q] everywhere, so
    pass 1 is 0; pass 2 charges (q_i - c)^2 per point, which equals the
    DTW against a constant series.  (The ISSUE's broader claim — that
    LB_Improved is tight at full band in general — is false: warping
    can beat the two-pass charge; this family is the provable case.)"""
    m = 32
    q = rng.normal(size=m).astype(np.float32)
    const = np.float32((q.min() + q.max()) / 2)
    c = np.full((1, m), const, np.float32)
    r = m - 1
    d = float(dtw(jnp.asarray(q), jnp.asarray(c[0]), band=r))
    u, low = lb.envelope(jnp.asarray(q), r)
    keogh = float(lb.lb_keogh(u, low, jnp.asarray(c[0])))
    improved = float(lb.lb_improved(jnp.asarray(q), jnp.asarray(c), r)[0])
    assert keogh == 0.0
    assert improved == pytest.approx(d, rel=1e-5)
    assert d > 0.0


def test_cascade_never_prunes_true_topk(rng):
    """Exactness: survivors of the cascade (vs kth-best bound) must contain
    the true top-k."""
    from repro.core.dtw import dtw_batch
    q = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    r, k = 4, 5
    d = dtw_batch(q, c, band=r)
    kth = jnp.sort(d)[k - 1]
    keep = lb.cascade(q, c, r, kth + 1e-6)
    true_topk = set(np.argsort(np.asarray(d))[:k].tolist())
    survivors = set(np.nonzero(np.asarray(keep))[0].tolist())
    assert true_topk <= survivors


def test_cascade_stats_fractions(rng):
    q = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    stats = lb.cascade_stats(q, c, 4, jnp.asarray(1.0))
    for v in stats.values():
        assert 0.0 <= float(v) <= 1.0
