"""Lower-bound soundness: every LB must lower-bound banded DTW (that is
what makes the UCR cascade exact)."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the rest still run
    given = settings = st = None

from repro.core import lower_bounds as lb
from repro.core.dtw import dtw


def _naive_envelope(x, r):
    m = len(x)
    u = np.array([x[max(0, i - r):i + r + 1].max() for i in range(m)])
    l = np.array([x[max(0, i - r):i + r + 1].min() for i in range(m)])
    return u, l


def test_envelope_matches_naive(rng):
    x = rng.normal(size=64).astype(np.float32)
    for r in (1, 4, 9):
        u, l = lb.envelope(jnp.asarray(x), r)
        nu, nl = _naive_envelope(x, r)
        np.testing.assert_allclose(np.asarray(u), nu, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(l), nl, rtol=1e-6)


if st is None:
    def test_bounds_below_dtw():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=25, deadline=None)
    @given(st.integers(8, 48), st.integers(1, 6),
           st.integers(0, 2 ** 31 - 1))
    def test_bounds_below_dtw(m, r, seed):
        rng = np.random.default_rng(seed)
        q = rng.normal(size=m).astype(np.float32)
        x = rng.normal(size=m).astype(np.float32)
        d = float(dtw(jnp.asarray(q), jnp.asarray(x), band=r))
        u, low = lb.envelope(jnp.asarray(q), r)
        assert float(lb.lb_kim(jnp.asarray(q), jnp.asarray(x))) <= d + 1e-3
        assert float(lb.lb_keogh(u, low, jnp.asarray(x))) <= d + 1e-3
        assert float(lb.lb_keogh2(jnp.asarray(q), jnp.asarray(x)[None],
                                  r)[0]) <= d + 1e-3


def test_cascade_never_prunes_true_topk(rng):
    """Exactness: survivors of the cascade (vs kth-best bound) must contain
    the true top-k."""
    from repro.core.dtw import dtw_batch
    q = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(64, 32)).astype(np.float32))
    r, k = 4, 5
    d = dtw_batch(q, c, band=r)
    kth = jnp.sort(d)[k - 1]
    keep = lb.cascade(q, c, r, kth + 1e-6)
    true_topk = set(np.argsort(np.asarray(d))[:k].tolist())
    survivors = set(np.nonzero(np.asarray(keep))[0].tolist())
    assert true_topk <= survivors


def test_cascade_stats_fractions(rng):
    q = jnp.asarray(rng.normal(size=32).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(32, 32)).astype(np.float32))
    stats = lb.cascade_stats(q, c, 4, jnp.asarray(1.0))
    for v in stats.values():
        assert 0.0 <= float(v) <= 1.0
