"""repro.bench: BENCH_*.json schema round-trips, regression detection on
synthetic trajectories, and stage-timing sanity on the instrumented hot
path (DESIGN.md §8).  All tests carry the ``bench`` marker (CI runs them
as a dedicated job step)."""
import os
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.bench import (STAGES, BenchCase, BenchReport, BenchResult,
                         BenchRunner, SchemaError, StageTimer,
                         compare_reports, failures,
                         has_full_stage_breakdown, load_report,
                         validate_report)
from repro.core import SSHParams, SSHIndex, ssh_search
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import SearchConfig
from repro.serving import ssh_search_batch

pytestmark = pytest.mark.bench

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(4200, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS.to_spec())


def _result(name="table3/ecg/len128", us=1500.0, **kw):
    base = dict(
        name=name, us_per_query=us, us_p50=us, us_p95=us * 1.2,
        stage_us={"encode": us * 0.1, "probe": us * 0.2, "lb": us * 0.3,
                  "lb_improved": us * 0.1, "dtw": us * 0.3},
        lb_pruned_frac=0.9, precision_at_k=0.8, build_s=1.0,
        case=BenchCase(dataset="ecg", length=128, n_database=1000,
                       spec=PARAMS.to_spec().to_dict(),
                       config=SearchConfig(band=8).to_dict()))
    base.update(kw)
    return BenchResult(**base)


def _report(results=None, **kw):
    base = dict(name="table3_query_time", scale="smoke", git_sha="abc123",
                results=results if results is not None else [_result()],
                host={"platform": "test"}, created_unix=1.0)
    base.update(kw)
    return BenchReport(**base)


# ---------------------------------------------------------------------------
# schema
# ---------------------------------------------------------------------------

class TestSchema:
    def test_round_trip(self, tmp_path):
        report = _report()
        path = tmp_path / "BENCH_table3_query_time.json"
        from repro.bench import dump_report
        dump_report(report, path)
        back = load_report(path)
        assert back.to_dict() == report.to_dict()
        r = back.results[0]
        assert r.case.dataset == "ecg"
        assert r.stage_us["lb"] == pytest.approx(450.0)
        assert r.stage_us["lb_improved"] == pytest.approx(150.0)

    def test_validate_accepts_minimal(self):
        validate_report(_report(results=[BenchResult(
            name="x", us_per_query=0.0)]).to_dict())

    @pytest.mark.parametrize("mutate", [
        lambda d: d.update(schema_version=99),
        lambda d: d.update(scale="galactic"),
        lambda d: d.update(name=""),
        lambda d: d.update(results=[]),
        lambda d: d["results"][0].update(us_per_query=-1.0),
        lambda d: d["results"][0].update(us_per_query=float("nan")),
        lambda d: d["results"][0].update(stage_us={"warp": 1.0}),
        lambda d: d["results"][0].update(name=""),
    ])
    def test_validate_rejects(self, mutate):
        doc = _report().to_dict()
        mutate(doc)
        with pytest.raises(SchemaError):
            validate_report(doc)

    def test_rejects_duplicate_entry_names(self):
        doc = _report(results=[_result(), _result()]).to_dict()
        with pytest.raises(SchemaError, match="duplicate"):
            validate_report(doc)

    def test_full_stage_breakdown_detection(self):
        assert has_full_stage_breakdown(_report().to_dict())
        partial = _result(stage_us={"dtw": 1.0})
        assert not has_full_stage_breakdown(
            _report(results=[partial]).to_dict())


# ---------------------------------------------------------------------------
# regression detection on synthetic trajectories
# ---------------------------------------------------------------------------

class TestRegression:
    def _pair(self, base_us, cur_us, **result_kw):
        return (_report(results=[_result(us=cur_us, **result_kw)]),
                _report(results=[_result(us=base_us, **result_kw)]))

    def test_regression_detected(self):
        cur, base = self._pair(1000.0, 3000.0)
        found = compare_reports(cur, base, rel_threshold=1.0)
        assert [f.kind for f in found] == ["regression"]
        assert failures(found)
        assert found[0].metric == "us_per_query"

    def test_improvement_is_not_failure(self):
        cur, base = self._pair(3000.0, 1000.0)
        found = compare_reports(cur, base, rel_threshold=1.0)
        assert [f.kind for f in found] == ["improvement"]
        assert not failures(found)

    def test_within_noise_passes(self):
        cur, base = self._pair(1000.0, 1800.0)
        assert compare_reports(cur, base, rel_threshold=1.0) == []

    def test_sub_noise_floor_timings_ignored(self):
        # 10x slower but under min_us on the baseline side: not compared
        cur, base = self._pair(100.0, 1000.0)
        assert compare_reports(cur, base, rel_threshold=1.0,
                               min_us=200.0) == []

    def test_precision_drop_is_regression(self):
        cur, base = self._pair(1000.0, 1000.0)
        cur.results[0].precision_at_k = 0.5
        base.results[0].precision_at_k = 0.9
        found = compare_reports(cur, base, precision_tol=0.15)
        assert [(f.kind, f.metric) for f in found] == \
            [("regression", "precision_at_k")]

    def test_missing_entry_fails_new_entry_does_not(self):
        cur = _report(results=[_result(name="a"), _result(name="c")])
        base = _report(results=[_result(name="a"), _result(name="b")])
        found = compare_reports(cur, base)
        kinds = {f.entry: f.kind for f in found}
        assert kinds == {"b": "missing", "c": "new"}
        assert [f.entry for f in failures(found)] == ["b"]

    def test_scale_mismatch_fails_instead_of_bogus_regressions(self):
        cur, base = self._pair(1000.0, 1000.0)
        cur.scale = "small"          # same entries, incomparable workload
        found = compare_reports(cur, base)
        assert [(f.kind, f.metric) for f in found] == \
            [("mismatch", "scale")]
        assert failures(found)


# ---------------------------------------------------------------------------
# runner + gate plumbing
# ---------------------------------------------------------------------------

class TestRunner:
    def test_runner_writes_validated_report(self, tmp_path):
        runner = BenchRunner(scale="smoke", out_dir=tmp_path, sha="deadbeef")
        runner.start_module("table3_query_time")
        runner.record(_result())
        path = runner.finish_module()
        assert path == tmp_path / "BENCH_table3_query_time.json"
        report = load_report(path)
        assert report.git_sha == "deadbeef"
        assert report.scale == "smoke"
        assert report.results[0].name == "table3/ecg/len128"

    def test_empty_module_writes_nothing(self, tmp_path):
        runner = BenchRunner(scale="smoke", out_dir=tmp_path, sha="")
        runner.start_module("empty")
        assert runner.finish_module() is None
        assert list(tmp_path.iterdir()) == []

    def test_compare_dirs_module_filter_and_missing(self, tmp_path):
        from repro.bench import compare_dirs, dump_report
        base_dir, cur_dir = tmp_path / "base", tmp_path / "cur"
        for mod, us in (("m1", 1000.0), ("m2", 1000.0)):
            dump_report(_report(name=mod, results=[_result(us=us)]),
                        base_dir / f"BENCH_{mod}.json")
        # m1 regressed 5x; m2 never emitted by the current run
        dump_report(_report(name="m1", results=[_result(us=5000.0)]),
                    cur_dir / "BENCH_m1.json")
        found, missing = compare_dirs(cur_dir, base_dir, rel_threshold=1.0)
        assert [f.kind for f in found] == ["regression"]
        assert missing == ["BENCH_m2.json"]
        # a partial run (--only m1) must not flag the unran m2
        found, missing = compare_dirs(cur_dir, base_dir, modules=["m1"],
                                      rel_threshold=1.0)
        assert missing == []
        # a module with no baseline yet is skipped, not failed
        _, missing = compare_dirs(cur_dir, base_dir,
                                  modules=["brand_new"])
        assert missing == []

    def test_run_py_only_unmatched_errors(self, capsys):
        sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
        try:
            from benchmarks.run import main
        finally:
            sys.path.pop(0)
        assert main(["--only", "no_such_bench"]) == 2
        err = capsys.readouterr().err
        assert "matches no benchmark module" in err
        assert "table3_query_time" in err   # lists the valid names


# ---------------------------------------------------------------------------
# stage-timing sanity on the real hot path
# ---------------------------------------------------------------------------

class TestStageTiming:
    CFG = SearchConfig(topk=10, top_c=128, band=8, searcher="local")

    def test_timer_accumulates_and_disables(self):
        t = StageTimer(enabled=True, prefill=STAGES)
        with t.stage("dtw") as sync:
            assert sync(jnp.ones(3)).shape == (3,)
        with t.stage("dtw"):
            pass
        assert set(t.timings) == set(STAGES)
        assert t.timings["dtw"] > 0 and t.timings["encode"] == 0.0
        off = StageTimer(enabled=False)
        with off.stage("dtw") as sync:
            assert sync("x") == "x"
        assert off.timings == {}

    def test_sequential_all_stages_present_sum_le_total(self, db, index):
        res = ssh_search(db[3], index, config=self.CFG)
        assert res.stats.stage_seconds is not None
        assert set(res.stats.stage_seconds) == set(STAGES)
        assert all(v >= 0.0 for v in res.stats.stage_seconds.values())
        assert sum(res.stats.stage_seconds.values()) <= res.wall_seconds
        assert res.stats.stage_us["dtw"] == pytest.approx(
            res.stats.stage_seconds["dtw"] * 1e6)

    def test_batched_all_stages_present_sum_le_total(self, db, index):
        res = ssh_search_batch(db[jnp.asarray([3, 9, 14])], index,
                               config=self.CFG.replace(searcher="batched"))
        assert set(res.stats.stage_seconds) == set(STAGES)
        assert sum(res.stats.stage_seconds.values()) <= res.wall_seconds

    def test_disabled_timings_do_not_change_results(self, db, index):
        on = ssh_search(db[7], index, config=self.CFG)
        off = ssh_search(db[7], index,
                         config=self.CFG.replace(stage_timings=False))
        assert off.stats.stage_seconds is None
        np.testing.assert_array_equal(on.ids, off.ids)
        np.testing.assert_allclose(on.dists, off.dists)

    def test_engine_metrics_surface_stage_means(self, db, index):
        from repro.serving import ServingEngine
        engine = ServingEngine(index, self.CFG.replace(searcher="batched"))
        engine.search_batch(db[jnp.asarray([3, 9])])
        snap = engine.metrics.snapshot()
        for s in STAGES:
            assert snap[f"stage_{s}_us_per_batch_mean"] >= 0.0
        assert 0.0 <= snap["dtw_abandoned_frac_mean"] <= 1.0


# ---------------------------------------------------------------------------
# counter consistency on the instrumented hot path
# ---------------------------------------------------------------------------

class TestCounterConsistency:
    """The pruning/abandon counters must stay a partition of the
    candidate block and the derived fractions must stay probabilities —
    a regression here means a stage miscounts (e.g. double-attributing a
    candidate to two bounds, or counting an abandoned lane twice)."""

    CFG = SearchConfig(topk=10, top_c=128, band=8, searcher="local")

    def _stats(self, db, index, **cfg):
        return ssh_search(db[3], index,
                          config=self.CFG.replace(**cfg)).stats

    def test_counters_partition_and_fracs_bounded(self, db, index):
        st = self._stats(db, index)
        assert st.n_in == (st.pruned_kim + st.pruned_keogh
                           + st.pruned_keogh2 + st.pruned_improved
                           + st.n_dtw)
        assert 0.0 <= st.lb_pruned_frac <= 1.0
        assert 0.0 <= st.dtw_abandoned_frac <= 1.0
        assert 0 <= st.dtw_abandoned <= st.n_dtw
        # the lb_improved stage is timed whenever the cascade ran
        assert st.stage_seconds["lb_improved"] >= 0.0

    def test_abandon_off_zeroes_counter_only(self, db, index):
        on = self._stats(db, index, early_abandon=True)
        off = self._stats(db, index, early_abandon=False)
        assert off.dtw_abandoned == 0 and off.dtw_abandoned_frac == 0.0
        # pruning decisions happen before the DTW stage: identical
        for f in ("n_in", "pruned_kim", "pruned_keogh", "pruned_keogh2",
                  "pruned_improved", "n_dtw", "forced_kept"):
            assert getattr(on, f) == getattr(off, f)

    def test_batched_counters_partition(self, db, index):
        res = ssh_search_batch(db[jnp.asarray([3, 9, 14, 21])], index,
                               config=self.CFG.replace(searcher="batched"))
        st = res.stats
        assert st.n_in == st.lb_pruned + st.n_dtw
        assert 0 <= st.dtw_abandoned <= st.n_dtw
        assert 0.0 <= st.lb_pruned_frac <= 1.0
        assert 0.0 <= st.dtw_abandoned_frac <= 1.0

    def test_disabled_telemetry_counters_identical(self, db, index):
        on = self._stats(db, index, stage_timings=True)
        off = self._stats(db, index, stage_timings=False)
        assert off.stage_seconds is None
        for f in ("n_in", "pruned_improved", "n_dtw", "dtw_abandoned",
                  "forced_kept"):
            assert getattr(on, f) == getattr(off, f)
