"""REQUIRED per-arch smoke tests: every assigned architecture instantiates
its REDUCED config and runs one forward/train step on CPU, asserting
output shapes + no NaNs.  (Full configs are exercised only by the dry-run.)
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch, list_archs
from repro.launch import steps

LM_ARCHS = ["deepseek-v2-lite-16b", "dbrx-132b", "phi3-mini-3.8b",
            "granite-3-8b", "granite-3-2b"]
RECSYS_ARCHS = ["bst", "dlrm-rm2", "mind", "dien"]


def _finite(tree) -> bool:
    return all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(tree)
               if jnp.issubdtype(l.dtype, jnp.floating))


def test_registry_covers_assignment():
    assigned = set(LM_ARCHS + RECSYS_ARCHS + ["nequip"])
    assert assigned <= set(list_archs())


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_train_step(name, rng):
    arch = get_arch(name)
    cfg = arch.smoke_config
    params = steps.init_fn(arch, "train_4k", smoke=True)()
    opt = steps.make_optimizer("lm")
    opt_state = opt.init(params)
    step = steps.make_step(arch, "train_4k", "train", smoke=True)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    batch = {"tokens": toks, "labels": toks}
    new_params, new_opt, metrics = step(params, opt_state, batch)
    assert float(metrics["loss"]) > 0 and np.isfinite(float(metrics["loss"]))
    assert _finite(new_params)
    assert jax.tree.structure(new_params) == jax.tree.structure(params)


@pytest.mark.parametrize("name", LM_ARCHS)
def test_lm_smoke_decode(name, rng):
    from repro.models.transformer import decode_step, init_cache, prefill
    arch = get_arch(name)
    cfg = arch.smoke_config
    params = steps.init_fn(arch, "decode_32k", smoke=True)()
    cache = init_cache(cfg, 2, 16)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    logits, cache = decode_step(params, cache, toks, cfg)
    assert logits.shape == (2, 1, cfg.vocab)
    assert _finite(logits)
    assert int(cache["length"][0]) == 1
    pl = prefill(params, jnp.tile(toks, (1, 8)), cfg)
    assert pl.shape == (2, 1, cfg.vocab)


@pytest.mark.parametrize("name", RECSYS_ARCHS)
def test_recsys_smoke_train_and_serve(name, rng):
    arch = get_arch(name)
    cfg = arch.smoke_config
    params = steps.init_fn(arch, "train_batch", smoke=True)()
    opt = steps.make_optimizer("recsys")
    opt_state = opt.init(params)
    step = steps.make_step(arch, "train_batch", "train", smoke=True)
    b = 8
    if name == "dlrm-rm2":
        batch = {"dense": jnp.asarray(rng.normal(size=(b, 13)), jnp.float32),
                 "sparse": jnp.asarray(rng.integers(0, 100, (b, 26)),
                                       jnp.int32)}
    elif name == "bst":
        batch = {"history": jnp.asarray(rng.integers(0, 100, (b, cfg.seq_len)),
                                        jnp.int32),
                 "target": jnp.asarray(rng.integers(0, 100, b), jnp.int32),
                 "profile": jnp.asarray(rng.integers(0, 100, (b, 8)),
                                        jnp.int32)}
    else:
        batch = {"history": jnp.asarray(rng.integers(0, 100, (b, cfg.seq_len)),
                                        jnp.int32),
                 "target": jnp.asarray(rng.integers(0, 100, b), jnp.int32)}
    batch["labels"] = jnp.asarray(rng.integers(0, 2, b), jnp.float32)
    new_params, _, metrics = step(params, opt_state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert _finite(new_params)

    serve = steps.make_step(arch, "serve_p99", "serve", smoke=True)
    scores = serve(params, {k: v for k, v in batch.items() if k != "labels"})
    assert scores.shape == (b,)
    assert _finite(scores)

    retr = steps.make_step(arch, "retrieval_cand", "retrieval", smoke=True)
    seq_len = getattr(cfg, "seq_len", 8)
    rb = {"dense": jnp.ones((1, 13)), "sparse": jnp.ones((1, 26), jnp.int32),
          "history": jnp.ones((1, seq_len), jnp.int32),
          "cand_ids": jnp.arange(97, dtype=jnp.int32)}
    rb = {k: v for k, v in rb.items()
          if k in dict(arch.input_specs("retrieval_cand")[1])}
    out = retr(params, rb)
    assert out.shape == (97,)


def test_nequip_smoke_train_step(rng):
    from repro.data.graph import random_graph
    arch = get_arch("nequip")
    cfg = arch.smoke_config
    g = random_graph(24, 80, cfg.d_feat, seed=0)
    batch = {k: jnp.asarray(v) for k, v in g.items()}
    params = steps.init_fn(arch, "full_graph_sm", smoke=True)()
    opt = steps.make_optimizer("gnn")
    opt_state = opt.init(params)
    from repro.models.nequip import loss_fn
    step_cfg = cfg

    def train(params, opt_state, batch):
        (l, m), grads = jax.value_and_grad(
            lambda p, b: loss_fn(p, b, step_cfg), has_aux=True)(params, batch)
        params, opt_state, _ = opt.update(params, opt_state, grads)
        return params, opt_state, l

    params2, _, loss = train(params, opt_state, batch)
    assert np.isfinite(float(loss))
    assert _finite(params2)


def test_ssh_arch_smoke(rng):
    arch = get_arch("ssh-ecg")
    params = steps.init_fn(arch, "build_2048", smoke=True)()
    build = steps.make_step(arch, "build_2048", "build", smoke=True)
    series = jnp.asarray(rng.normal(size=(8, 128)), jnp.float32)
    sigs = build(params, {"series": series})
    assert sigs.shape == (8, arch.smoke_config.num_hashes)
    query = steps.make_step(arch, "query_2048", "query", smoke=True)
    out_ids, out_d = query(params, {
        "query": series[0],
        "db_sigs": jnp.tile(sigs, (16, 1)),
        "db_series": jnp.tile(series, (16, 1))})
    assert out_ids.shape == (10,)
    assert float(out_d[0]) == pytest.approx(0.0, abs=1e-3)
