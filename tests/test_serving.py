"""Serving engine: batched-vs-sequential equality, dynamic batching,
streaming inserts, metrics.  The equality contract (acceptance criterion)
is held over a ~1k-series synthetic-ECG database."""
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSHParams, SSHIndex, ssh_search
from repro.core.dtw import znormalize
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db import BatchPolicy, SearchConfig
from repro.serving import (ServingEngine, ServingMetrics,
                           batch_probe, ssh_search_batch)

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(4200, seed=5)
    d = extract_subsequences(stream, 128, stride=4, znorm=True)
    return jnp.asarray(d)                     # ~1k series


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS)


QIDS = [3, 100, 250, 444, 512, 700, 801, 999]


@pytest.mark.parametrize("kw", [
    dict(),
    dict(use_lb_cascade=False),
    dict(multiprobe_offsets=3),
    dict(rank_by_signature=False),
    dict(rank_by_signature=False, multiprobe_offsets=3),
])
def test_batched_identical_to_sequential(db, index, kw):
    """Per-query top-k ids and distances match ssh_search exactly."""
    queries = db[jnp.asarray(QIDS)]
    res = ssh_search_batch(queries, index, topk=10, top_c=128, band=8, **kw)
    for b, qid in enumerate(QIDS):
        seq = ssh_search(db[qid], index, topk=10, top_c=128, band=8, **kw)
        pq = res.per_query(b)
        np.testing.assert_array_equal(pq.ids, seq.ids)
        np.testing.assert_allclose(pq.dists, seq.dists, rtol=1e-5,
                                   atol=1e-5)
        assert pq.n_candidates == seq.n_candidates
        assert pq.pruned_by_hash_frac == pytest.approx(
            seq.pruned_by_hash_frac)


def test_engine_batched_path_matches_sequential(db, index):
    """Acceptance: the ServingEngine batched path == sequential ssh_search
    (same params, rank_by_signature=True) over the synthetic-ECG db."""
    cfg = SearchConfig(topk=10, top_c=128, band=8,
                   batch_policy=BatchPolicy(max_batch=8))
    engine = ServingEngine(index, cfg)
    results = engine.search_batch(db[jnp.asarray(QIDS)])
    for qid, got in zip(QIDS, results):
        want = ssh_search(db[qid], index, topk=10, top_c=128, band=8)
        np.testing.assert_array_equal(got.ids, want.ids)
        np.testing.assert_allclose(got.dists, want.dists, rtol=1e-5,
                                   atol=1e-5)


def test_batch_probe_matches_single_probe(db, index):
    """Batched collision top-C rows == per-query probe_topc."""
    from repro.core.index import probe_topc
    queries = db[jnp.asarray(QIDS[:4])]
    ids, vals = batch_probe(queries, index, 64)
    for b, qid in enumerate(QIDS[:4]):
        sig = index.query_signature(db[qid])
        want_ids, want_vals = probe_topc(sig, index.signatures, 64)
        np.testing.assert_array_equal(np.asarray(ids[b]),
                                      np.asarray(want_ids))
        np.testing.assert_array_equal(np.asarray(vals[b]),
                                      np.asarray(want_vals))


def test_engine_threaded_dynamic_batching(db, index):
    """Queued requests are served in batches; results match sequential."""
    cfg = SearchConfig(topk=5, top_c=64, band=8,
                       batch_policy=BatchPolicy(max_batch=4,
                                                max_wait_ms=50.0))
    engine = ServingEngine(index, cfg)
    # prefill the queue before starting the worker → deterministic batching
    futs = [engine.submit(db[qid]) for qid in QIDS]
    with engine:
        results = [f.result(timeout=120) for f in futs]
    for qid, got in zip(QIDS, results):
        want = ssh_search(db[qid], index, topk=5, top_c=64, band=8)
        assert got.ids[0] == want.ids[0] == qid
    snap = engine.metrics.snapshot()
    assert snap["requests_total"] == len(QIDS)
    assert snap["batches_total"] <= len(QIDS) // 2   # actually batched
    assert snap["batch_size_mean"] >= 2.0
    assert snap["latency_p99_ms"] >= snap["latency_p50_ms"] >= 0.0


def test_engine_insert_visible_to_later_queries(db, index):
    """Streaming insert routes through SSHIndex.insert and is searchable."""
    engine = ServingEngine(index, SearchConfig(
        topk=3, top_c=64, band=8, batch_policy=BatchPolicy(max_batch=4)))
    n0 = int(index.signatures.shape[0])
    novel = znormalize(jnp.asarray(
        np.sin(np.linspace(0, 17, 128)) ** 3, jnp.float32))[None, :]
    with engine:
        engine.insert(novel)
        res = engine.search(novel[0], timeout=120)
    assert int(index.signatures.shape[0]) == n0 + 1
    assert res.ids[0] == n0                       # finds the new series
    assert res.dists[0] == pytest.approx(0.0, abs=1e-4)
    assert res.n_database == n0 + 1


def test_engine_concurrent_submitters(db, index):
    """Many client threads sharing one engine all get correct answers."""
    cfg = SearchConfig(topk=3, top_c=64, band=8,
                       batch_policy=BatchPolicy(max_batch=4,
                                                max_wait_ms=5.0))
    engine = ServingEngine(index, cfg)
    out = {}

    def client(qid):
        out[qid] = engine.search(db[qid], timeout=120)

    with engine:
        threads = [threading.Thread(target=client, args=(q,))
                   for q in QIDS]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for qid in QIDS:
        assert out[qid].ids[0] == qid


def test_engine_survives_failing_insert(db, index):
    """A backend insert error fails the affected batch loudly but leaves
    the worker alive for later requests."""
    cfg = SearchConfig(topk=3, top_c=64, band=8,
                       batch_policy=BatchPolicy(max_batch=2))
    engine = ServingEngine(index, cfg)

    class Boom(RuntimeError):
        pass

    real_insert = engine.searcher.insert
    engine.searcher.insert = lambda s: (_ for _ in ()).throw(Boom("nope"))
    with engine:
        engine.insert(db[0][None, :])
        with pytest.raises(Boom):
            engine.search(db[QIDS[0]], timeout=120)
        engine.searcher.insert = real_insert        # backend recovers
        res = engine.search(db[QIDS[1]], timeout=120)
    assert res.ids[0] == QIDS[1]


def test_submit_after_stop_and_straggler_drain(db, index):
    """stop() resolves every queued future; submit() after stop serves on
    the caller's thread — no request ever hangs around shutdown."""
    cfg = SearchConfig(topk=3, top_c=64, band=8,
                       batch_policy=BatchPolicy(max_batch=4))
    engine = ServingEngine(index, cfg)
    engine.start()
    engine.stop()
    fut = engine.submit(db[QIDS[0]])             # post-stop: pre-resolved
    assert fut.done() and fut.result().ids[0] == QIDS[0]
    # pre-start submits are queued; stop() without a served batch must
    # still resolve them (straggler drain)
    engine2 = ServingEngine(index, cfg)
    futs = [engine2.submit(db[qid]) for qid in QIDS[:3]]
    engine2.start()
    engine2.stop()
    for qid, f in zip(QIDS[:3], futs):
        assert f.result(timeout=120).ids[0] == qid


def test_distributed_searcher_rejects_unsupported_config(index):
    from repro.serving.engine import DistributedSearcher
    with pytest.raises(ValueError, match="band"):
        DistributedSearcher(index, SearchConfig(band=None), mesh=None)
    with pytest.raises(ValueError, match="rank_by_signature"):
        DistributedSearcher(
            index, SearchConfig(band=8, rank_by_signature=False), mesh=None)
    with pytest.raises(ValueError, match="multiprobe"):
        DistributedSearcher(
            index, SearchConfig(band=8, multiprobe_offsets=3), mesh=None)


def test_metrics_percentiles_and_throughput():
    m = ServingMetrics()
    m.on_batch(4, [0.010, 0.020, 0.030, 0.040], [0.001] * 4,
               [0.9] * 4, [0.95] * 4, depth_after=0)
    s = m.snapshot()
    assert s["requests_total"] == 4
    assert s["batches_total"] == 1
    assert 10.0 <= s["latency_p50_ms"] <= 30.0
    assert s["latency_p99_ms"] == pytest.approx(40.0)
    assert s["throughput_qps"] > 0
    assert s["pruned_total_frac_mean"] == pytest.approx(0.95)


def test_engine_filler_rows_trimmed(db, index):
    """Queries with fewer survivors than topk return short results, like
    the sequential path (no -1 filler ids leak out)."""
    res = ssh_search_batch(db[jnp.asarray(QIDS[:2])], index, topk=10,
                           top_c=16, band=8)
    for b in range(2):
        pq = res.per_query(b)
        assert np.all(pq.ids >= 0)
        assert len(pq.ids) == len(pq.dists) <= 10
