"""Flash-attention Pallas kernel vs the plain softmax oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref


@pytest.mark.parametrize("b,h,s,t,d,causal,qb,kvb", [
    (1, 2, 32, 32, 16, True, 16, 16),
    (2, 4, 64, 64, 32, True, 32, 16),
    (1, 1, 40, 40, 16, True, 16, 8),       # padded q blocks
    (2, 2, 32, 32, 16, False, 16, 16),
    (1, 2, 16, 64, 16, True, 16, 16),      # decode-ish: s < t
])
def test_flash_vs_ref(b, h, s, t, d, causal, qb, kvb, rng):
    q = jnp.asarray(rng.normal(size=(b, h, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, h, t, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=kvb,
                          interpret=True)
    want = flash_attention_ref(q, k, v, causal=causal)
    if causal and s < t:
        # kernel uses absolute positions 0..s for q; ref aligns q at the
        # END of the kv window — compare only the overlapping lower rows
        got = got[:, :, : min(s, t)]
        want = flash_attention_ref(q, k[:, :, :s], v[:, :, :s],
                                   causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16(rng):
    q = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 32, 16)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, q_block=16, kv_block=16,
                          interpret=True)
    want = flash_attention_ref(q.astype(jnp.float32),
                               k.astype(jnp.float32),
                               v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=3e-2, atol=3e-2)
