"""DTW oracle tests: the (min,+) column-scan vs the O(m^2) DP, plus
metric properties under hypothesis."""
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # property tests skip below; the oracle tests still run
    given = settings = st = None

from repro.core.dtw import (dtw, dtw_batch, dtw_dp_reference, dtw_pairwise,
                            znormalize)


@pytest.mark.parametrize("mx,my,band", [
    (8, 8, None), (16, 16, None), (16, 16, 3), (33, 47, 6),
    (20, 16, None), (64, 64, 8), (5, 5, 1),
])
def test_matches_dp_reference(mx, my, band, rng):
    x = rng.normal(size=mx).astype(np.float32)
    y = rng.normal(size=my).astype(np.float32)
    got = float(dtw(jnp.asarray(x), jnp.asarray(y), band=band))
    want = dtw_dp_reference(x, y, band=band)
    assert got == pytest.approx(want, rel=1e-4)


def test_self_distance_zero(rng):
    x = jnp.asarray(rng.normal(size=32).astype(np.float32))
    assert float(dtw(x, x)) == pytest.approx(0.0, abs=1e-5)


if st is None:
    def test_symmetry():
        pytest.importorskip("hypothesis")

    def test_band_monotone():
        pytest.importorskip("hypothesis")
else:
    @settings(max_examples=20, deadline=None)
    @given(st.integers(4, 24), st.integers(4, 24),
           st.integers(0, 2 ** 31 - 1))
    def test_symmetry(mx, my, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=mx).astype(np.float32))
        y = jnp.asarray(rng.normal(size=my).astype(np.float32))
        assert float(dtw(x, y)) == pytest.approx(float(dtw(y, x)), rel=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(st.integers(8, 32), st.integers(0, 2 ** 31 - 1))
    def test_band_monotone(m, seed):
        """Widening the Sakoe-Chiba band can only lower the cost."""
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.normal(size=m).astype(np.float32))
        y = jnp.asarray(rng.normal(size=m).astype(np.float32))
        costs = [float(dtw(x, y, band=b)) for b in (1, 3, m - 1)]
        assert costs[0] >= costs[1] - 1e-4
        assert costs[1] >= costs[2] - 1e-4


def test_unbanded_below_euclidean(rng):
    """DTW (free alignment) <= squared Euclidean (the identity alignment)."""
    x = rng.normal(size=40).astype(np.float32)
    y = rng.normal(size=40).astype(np.float32)
    assert float(dtw(jnp.asarray(x), jnp.asarray(y))) <= \
        float(np.sum((x - y) ** 2)) + 1e-4


def test_batch_and_pairwise(rng):
    q = jnp.asarray(rng.normal(size=16).astype(np.float32))
    c = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    d = dtw_batch(q, c, band=4)
    assert d.shape == (5,)
    for i in range(5):
        assert float(d[i]) == pytest.approx(float(dtw(q, c[i], band=4)),
                                            rel=1e-5)
    pw = dtw_pairwise(c[:2], c, band=4)
    assert pw.shape == (2, 5)


def test_shift_invariance_vs_euclidean(rng):
    """The motivating property (paper Fig. 1): a shifted copy stays close
    in DTW while Euclidean blows up."""
    base = np.sin(np.linspace(0, 12 * np.pi, 128)).astype(np.float32)
    shifted = np.roll(base, 3)
    d_dtw = float(dtw(jnp.asarray(base), jnp.asarray(shifted), band=8))
    d_euc = float(np.sum((base - shifted) ** 2))
    assert d_dtw < 0.1 * d_euc


def test_znormalize(rng):
    x = jnp.asarray(rng.normal(2.0, 5.0, size=(3, 64)).astype(np.float32))
    z = znormalize(x)
    np.testing.assert_allclose(np.asarray(jnp.mean(z, -1)), 0, atol=1e-5)
    np.testing.assert_allclose(np.asarray(jnp.std(z, -1)), 1, atol=1e-3)
