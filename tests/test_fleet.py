"""Resilient fleet tier: placement invariants, chaos, drain, elasticity.

The acceptance contract: whatever the fleet survives — killed workers,
injected delays, dropped responses, live drains and resizes — the
returned top-k ids AND distances stay bit-identical to the healthy run,
and no queued query is ever lost.
"""
import math
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import SSHParams, SSHIndex
from repro.data.timeseries import extract_subsequences, synthetic_ecg
from repro.db.config import SearchConfig
from repro.fleet import (FaultInjector, FleetSearcher, ReplicatedShardPlan,
                         ResponseDropped, WorkerKilled)

pytestmark = pytest.mark.fleet

PARAMS = SSHParams(window=24, step=3, ngram=8, num_hashes=40, num_tables=20)


@pytest.fixture(scope="module")
def db():
    stream = synthetic_ecg(2200, seed=5)
    return jnp.asarray(extract_subsequences(stream, 128, stride=4,
                                            znorm=True))    # ~500 series


@pytest.fixture(scope="module")
def index(db):
    return SSHIndex.build(db, PARAMS)


def make_fleet(index, **overrides):
    kw = dict(topk=5, top_c=64, band=8, replication=2, fleet_workers=4)
    kw.update(overrides)
    return FleetSearcher(index, SearchConfig(**kw).validate())


# ---------------------------------------------------------------------------
# placement invariants
# ---------------------------------------------------------------------------

def _assert_invariants(plan):
    for s in range(plan.n_shards):
        ws = plan.replicas(s)
        assert len(ws) == plan.replication          # exactly R replicas
        assert len(set(ws)) == len(ws)              # never co-located
        assert all(w in plan.workers for w in ws)   # all live
    loads = list(plan.loads().values())
    assert max(loads) - min(loads) <= 1             # balanced within 1


def test_placement_invariants_property():
    """Property sweep over (n_shards, n_workers, R) grids plus random
    fail/resize trajectories (hypothesis when available)."""
    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st

        @settings(max_examples=60, deadline=None)
        @given(st.integers(1, 24), st.integers(1, 8), st.integers(1, 4),
               st.randoms(use_true_random=False))
        def prop(n_shards, n_workers, repl, rng):
            repl = min(repl, n_workers)
            names = [f"w{i}" for i in range(n_workers)]
            plan = ReplicatedShardPlan(n_shards, names, replication=repl)
            _assert_invariants(plan)
            for _ in range(3):
                op = rng.choice(["fail", "grow", "shrink"])
                if op == "fail" and len(plan.workers) > repl:
                    plan.fail(rng.choice(plan.workers))
                elif op == "grow":
                    plan.resize(plan.workers
                                + [f"x{rng.randrange(10**6)}"])
                elif op == "shrink" and len(plan.workers) > repl:
                    plan.resize(plan.workers[:-1])
                _assert_invariants(plan)
                assert sorted(plan.assignment) == list(range(n_shards))

        prop()
    except ImportError:                              # hypothesis not baked in
        for n_shards in (1, 5, 12, 24):
            for n_workers in (1, 2, 3, 5, 8):
                for repl in (1, 2, 3):
                    if repl > n_workers:
                        continue
                    names = [f"w{i}" for i in range(n_workers)]
                    plan = ReplicatedShardPlan(n_shards, names,
                                               replication=repl)
                    _assert_invariants(plan)


def test_placement_rejects_colocation():
    with pytest.raises(ValueError, match="replication"):
        ReplicatedShardPlan(4, ["w0", "w1"], replication=3)
    with pytest.raises(ValueError, match="replication"):
        ReplicatedShardPlan(4, ["w0", "w1"], replication=0)


def test_placement_fail_conserves_shards_and_rejects_undershoot():
    plan = ReplicatedShardPlan(10, ["w0", "w1", "w2"], replication=2)
    before = {s: set(plan.replicas(s)) for s in range(10)}
    moved = plan.fail("w1")
    _assert_invariants(plan)
    assert sorted(plan.assignment) == list(range(10))
    # only w1's slots moved; surviving slots stayed put
    for s, new in moved:
        assert new in plan.replicas(s) and new != "w1"
    for s in range(10):
        assert before[s] - {"w1"} <= set(plan.replicas(s))
    # one more loss would leave 1 worker < R=2: refused, plan unchanged
    snap = {s: list(plan.replicas(s)) for s in range(10)}
    with pytest.raises(RuntimeError, match="replication"):
        plan.fail(plan.workers[0])
    assert {s: list(plan.replicas(s)) for s in range(10)} == snap


def test_placement_resize_moves_minimally():
    plan = ReplicatedShardPlan(12, ["w0", "w1", "w2"], replication=2)
    before = {s: set(plan.replicas(s)) for s in range(12)}
    moved = plan.resize(["w0", "w1", "w2", "w3"])
    _assert_invariants(plan)
    # total slots = 24; fair share on 4 workers = 6 -> at most 6 slots move
    assert 0 < len(moved) <= math.ceil(12 * 2 / 4)
    # every move is into the plan; untouched shards kept their replicas
    touched = {s for s, _ in moved}
    for s in range(12):
        if s not in touched:
            assert set(plan.replicas(s)) == before[s]


# ---------------------------------------------------------------------------
# fault injector
# ---------------------------------------------------------------------------

def test_injector_kill_delay_drop():
    inj = FaultInjector()
    inj.before_call("w0")                            # default: no-op
    inj.kill("w0")
    with pytest.raises(WorkerKilled):
        inj.before_call("w0")
    inj.revive("w0")
    inj.before_call("w0")
    inj.drop_every("w1", 3)
    outcomes = []
    for _ in range(9):
        try:
            inj.before_call("w1")
            outcomes.append("ok")
        except ResponseDropped:
            outcomes.append("drop")
    assert outcomes == ["ok", "ok", "drop"] * 3      # exactly every 3rd
    with pytest.raises(ValueError):
        inj.drop_every("w1", 0)
    inj.clear()
    inj.before_call("w1")


# ---------------------------------------------------------------------------
# fleet query path
# ---------------------------------------------------------------------------

def _run(fleet, queries):
    res = fleet.search_batch(queries)
    return np.asarray(res.ids), np.asarray(res.dists), res.stats


def test_fleet_matches_batched_reference(db, index):
    from repro.serving import ssh_search_batch
    queries = db[jnp.asarray([3, 100, 250, 444])]
    fleet = make_fleet(index)
    try:
        ids, dists, stats = _run(fleet, queries)
        ref = ssh_search_batch(
            queries, index,
            config=SearchConfig(topk=5, top_c=64, band=8))
        np.testing.assert_array_equal(ids, np.asarray(ref.ids))
        np.testing.assert_allclose(dists, np.asarray(ref.dists),
                                   rtol=1e-5, atol=1e-5)
        assert stats.failovers == 0
    finally:
        fleet.close()


def test_fleet_chaos_bit_identical(db, index):
    """50 queries under random kill/delay/drop injection answer with
    bit-identical ids AND distances to the no-fault run."""
    rng = np.random.default_rng(7)
    qids = rng.integers(0, db.shape[0], 50)
    queries = db[jnp.asarray(qids)]
    fleet = make_fleet(index, hedge_ms=5.0)
    try:
        healthy_ids, healthy_d, _ = _run(fleet, queries)
        workers = list(fleet.workers)
        total_stats = []
        for lo in range(0, 50, 10):                  # re-roll faults per wave
            fleet.injector.clear()
            # at most R-1 = 1 concurrently-killed worker, plus delays
            # and dropped responses on others
            fleet.injector.kill(rng.choice(workers))
            fleet.injector.delay(rng.choice(workers), 20.0)
            fleet.injector.drop_every(rng.choice(workers), 2)
            ids, d, stats = _run(fleet, queries[lo:lo + 10])
            np.testing.assert_array_equal(ids, healthy_ids[lo:lo + 10])
            np.testing.assert_array_equal(d, healthy_d[lo:lo + 10])
            total_stats.append(stats)
        assert sum(s.failovers for s in total_stats) > 0   # faults were hit
        assert any(s.degraded for s in total_stats)
        assert fleet.failovers_total > 0
    finally:
        fleet.injector.clear()
        fleet.close()


def test_fleet_failover_exhaustion_raises(db, index):
    """Killing every replica of a shard is loud, not silently wrong."""
    fleet = make_fleet(index, replication=2, fleet_workers=2)
    try:
        for w in list(fleet.workers):                # both replicas down
            fleet.injector.kill(w)
        with pytest.raises(RuntimeError, match="replicas"):
            fleet.search_batch(db[jnp.asarray([3])])
    finally:
        fleet.injector.clear()
        fleet.close()


def test_fleet_hedging_recovers_stragglers(db, index):
    """A consistently slow worker triggers hedging; results identical and
    the hedge counter surfaces it."""
    queries = db[jnp.asarray([3, 100, 250])]
    fleet = make_fleet(index, hedge_policy="fixed", hedge_ms=10.0)
    try:
        healthy_ids, healthy_d, _ = _run(fleet, queries)
        fleet.injector.delay(next(iter(fleet.workers)), 200.0)
        ids, d, stats = _run(fleet, queries)
        np.testing.assert_array_equal(ids, healthy_ids)
        np.testing.assert_array_equal(d, healthy_d)
        assert stats.hedged > 0 and stats.degraded
    finally:
        fleet.injector.clear()
        fleet.close()


def test_fleet_live_resize_and_drain(db, index):
    queries = db[jnp.asarray([3, 100, 250, 444])]
    fleet = make_fleet(index)
    try:
        healthy_ids, healthy_d, _ = _run(fleet, queries)
        assert fleet.resize(6) > 0                   # scale out, shards move
        ids, d, _ = _run(fleet, queries)
        np.testing.assert_array_equal(ids, healthy_ids)
        np.testing.assert_array_equal(d, healthy_d)
        moved = fleet.drain(sorted(fleet.workers)[0])
        assert moved > 0
        assert fleet.rebalanced_shards_total > 0
        ids, d, _ = _run(fleet, queries)
        np.testing.assert_array_equal(ids, healthy_ids)
        np.testing.assert_array_equal(d, healthy_d)
        with pytest.raises(RuntimeError, match="drain"):
            while True:                              # drain below R: refused
                fleet.drain(sorted(fleet.workers)[0])
    finally:
        fleet.close()


# ---------------------------------------------------------------------------
# engine integration: drain with zero queued-query loss
# ---------------------------------------------------------------------------

def test_engine_drain_loses_no_queries(db, index):
    from repro.serving import ServingEngine
    from repro.db import BatchPolicy
    cfg = SearchConfig(topk=5, top_c=64, band=8, replication=2,
                       fleet_workers=4,
                       batch_policy=BatchPolicy(
                           max_batch=4, max_wait_ms=1.0)).validate()
    engine = ServingEngine(index, cfg)               # auto-routes to fleet
    assert isinstance(engine.searcher, FleetSearcher)
    rng = np.random.default_rng(3)
    qids = [int(i) for i in rng.integers(0, db.shape[0], 30)]
    with engine:
        engine.search(db[qids[0]])                   # warm the path
        futs = [engine.submit(db[i]) for i in qids]
        drained = threading.Thread(
            target=lambda: engine.drain(sorted(engine.searcher.workers)[0]))
        drained.start()                              # retire mid-stream
        results = [f.result(timeout=120) for f in futs]
        drained.join(timeout=120)
    assert len(results) == len(qids)                 # zero lost queries
    for i, res in zip(qids, results):
        assert int(res.ids[0]) == i                  # each answered, exactly
    snap = engine.metrics.snapshot()
    assert snap["requests_total"] >= len(qids)
    assert snap["rebalanced_shards_total"] > 0


def test_engine_metrics_surface_fleet_counters(db, index):
    from repro.serving import ServingEngine
    cfg = SearchConfig(topk=5, top_c=64, band=8, replication=2,
                       fleet_workers=4).validate()
    engine = ServingEngine(index, cfg)
    queries = db[jnp.asarray([3, 100])]
    engine.search_batch(queries)                     # healthy: zeros
    engine.searcher.injector.kill("w0")
    engine.search_batch(queries)
    snap = engine.metrics.snapshot()
    assert snap["failovers_total"] > 0
    assert snap["degraded_total"] > 0
    engine.searcher.injector.clear()
    engine.searcher.close()


# ---------------------------------------------------------------------------
# facade: registry routing
# ---------------------------------------------------------------------------

def test_registry_routes_distributed_to_fleet_when_replicated(db, index):
    from repro.db.registry import make_searcher
    cfg = SearchConfig(topk=5, top_c=64, band=8, searcher="distributed",
                       replication=2, fleet_workers=4).validate()
    s = make_searcher(index, cfg)
    try:
        assert isinstance(s._inner, FleetSearcher)
        res = s.search(db[3])
        assert res.ids[0] == 3                       # self-match sanity
    finally:
        s.close()


def test_registry_fleet_searcher_contract(db, index):
    from repro.db.registry import make_searcher
    cfg = SearchConfig(topk=5, top_c=64, band=8, searcher="fleet",
                       replication=2, fleet_workers=4).validate()
    s = make_searcher(index, cfg)
    try:
        out = s.search_batch(db[jnp.asarray([3, 100])])
        assert len(out) == 2 and out[0].ids[0] == 3
        s.injector.kill("w0")                        # chaos hook exposed
        out2 = s.search_batch(db[jnp.asarray([3, 100])])
        np.testing.assert_array_equal(out2[0].ids, out[0].ids)
        with pytest.raises(NotImplementedError):
            s.insert(db[:1])
    finally:
        s.injector.clear()
        s.close()
