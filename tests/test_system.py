"""End-to-end system behaviour: the paper's full pipeline at container
scale — build index -> query -> verify speedup-relevant pruning + accuracy
vs exact search, UCR baseline comparison (paper Tables 2-4 in miniature),
plus the distributed shard_map index on a (1-device) mesh."""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (SSHParams, SSHIndex, brute_force_topk,
                        precision_at_k, ssh_search, ucr_search)
from repro.data.timeseries import extract_subsequences, synthetic_ecg


def test_full_pipeline_beats_ucr_on_pruning():
    """Paper's core claim, miniaturised: at longer lengths, SSH prunes far
    more candidates than the LB cascade while keeping accuracy."""
    stream = synthetic_ecg(3000, seed=9)
    db = jnp.asarray(extract_subsequences(stream, 256, stride=1, znorm=True))
    params = SSHParams(window=48, step=3, ngram=10, num_hashes=40,
                       num_tables=20)
    index = SSHIndex.build(db, params)
    q = db[1000]
    ssh = ssh_search(q, index, topk=10, top_c=256, band=12,
                     multiprobe_offsets=3)
    ucr = ucr_search(q, db, topk=10, band=12)
    gold, _ = brute_force_topk(q, db, 10, band=12)
    assert ssh.pruned_total_frac > ucr.pruned_total_frac
    assert ssh.pruned_total_frac > 0.85
    assert precision_at_k(ssh.ids, gold, 10) >= 0.5
    assert precision_at_k(ucr.ids, gold, 10) == 1.0   # exact baseline
    assert ssh.dtw_evals < 0.15 * ucr.dtw_evals + 64


def test_distributed_index_shard_map():
    """shard_map index on the local (1-device) mesh — same collective code
    path the 512-chip dry-run exercises."""
    from repro.core.index import SSHFunctions
    from repro.distributed.dist_index import build_sharded, make_query_fn
    stream = synthetic_ecg(1200, seed=2)
    series = jnp.asarray(extract_subsequences(stream, 128, stride=2,
                                              znorm=True))
    n = (series.shape[0] // 8) * 8
    series = series[:n]
    params = SSHParams(window=24, step=3, ngram=8, num_hashes=20,
                       num_tables=20)
    fns = SSHFunctions.create(params)
    mesh = jax.make_mesh((jax.device_count(),), ("data",))
    sigs = build_sharded(series, fns.filters, fns.cws._asdict(), params,
                         mesh)
    assert sigs.shape == (n, params.num_hashes)
    qfn = make_query_fn(params, mesh, top_c=64, band=8, topk=5,
                        length=128)
    ids, dists = qfn(series, sigs, fns.filters, fns.cws._asdict(),
                     series[37])
    assert int(ids[0]) == 37
    assert float(dists[0]) == pytest.approx(0.0, abs=1e-4)


def test_dryrun_smoke_subprocess():
    """One real dry-run cell (256 fake devices) in a subprocess — proves
    the XLA_FLAGS isolation and the lower/compile path end to end."""
    code = (
        "import repro.launch.dryrun as d;"
        "r = d.run_cell('granite-3-2b', 'decode_32k', False, verbose=False);"
        "assert r['roofline']['dominant'] in "
        "('compute', 'memory', 'collective');"
        "print('cell ok', r['n_chips'])"
    )
    out = subprocess.run([sys.executable, "-c", code],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo", timeout=540)
    assert "cell ok 256" in out.stdout, out.stderr[-2000:]
