"""SSH reproduction — top-level package."""
