"""repro.fleet — the resilience layer of the distributed tier (DESIGN.md §11).

At the paper's massive scale the index outgrows one worker, and once it
is spread over a fleet, shard failure and stragglers are the common
case.  This package makes the distributed tier survive them without
changing a single answer:

* :class:`ReplicatedShardPlan` — R-way replica placement with the
  no-co-location invariant and stable, minimal-movement rebalancing.
* :class:`FleetWorker` — a logical worker holding shard replicas
  received as ``repro.checkpoint`` artifacts (the transfer format).
* :class:`FleetSearcher` — replicated shard fan-out with hedged
  re-issue (``StragglerPolicy``-derived per-shard deadlines), failover
  on error, live ``resize()`` rebalancing and ``drain()`` for zero-loss
  worker retirement.  Results are bit-identical under faults because
  replicas hold identical encoded state and the merge is deterministic.
* :class:`FaultInjector` — kill / delay / drop-every-Nth fault
  injection for tests and ``benchmarks/dist_bench.py``.
"""
from repro.fleet.injector import (FaultInjector, ResponseDropped,
                                  WorkerFault, WorkerKilled)
from repro.fleet.placement import ReplicatedShardPlan
from repro.fleet.searcher import FleetSearcher
from repro.fleet.transfer import fetch_shard, publish_shard
from repro.fleet.worker import FleetWorker, ShardReplica

__all__ = [
    "FaultInjector", "FleetSearcher", "FleetWorker",
    "ReplicatedShardPlan", "ResponseDropped", "ShardReplica",
    "WorkerFault", "WorkerKilled", "fetch_shard", "publish_shard",
]
