"""Shard transfer format — persistence-v2 checkpoints, not pickles.

A shard moves between workers as a ``repro.checkpoint`` artifact (one
atomically-published ``step_*/`` directory of npz shards + manifest),
the same layer index persistence rides.  That buys the fleet tier the
checkpoint layer's guarantees for free: a crashed publisher never
corrupts the previous artifact, and a fetching worker either sees a
complete shard or none.  Replicas fetched from the same artifact hold
bit-identical arrays — the root of the hedging soundness argument
(DESIGN.md §11): any replica's answer for a shard is THE answer.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.fleet.worker import ShardReplica


def _shard_dir(root: str | Path, shard_id: int) -> Path:
    return Path(root) / f"shard_{shard_id:05d}"


def publish_shard(root: str | Path, shard_id: int, series, signatures,
                  row_start: int, version: int = 0) -> Path:
    """Publish one shard's encoded rows as a checkpoint artifact.

    ``version`` is the checkpoint step: re-publishing after a streaming
    fold bumps it, and the previous artifact stays durable until the new
    one is live (``keep=2``).
    """
    return save_checkpoint(
        _shard_dir(root, shard_id), step=version,
        tree={"series": np.asarray(series),
              "signatures": np.asarray(signatures),
              "row_start": np.asarray(row_start, np.int64)},
        keep=2)


def fetch_shard(root: str | Path, shard_id: int,
                version: Optional[int] = None) -> ShardReplica:
    """A worker 'receives' a shard: restore the (latest) artifact."""
    d = _shard_dir(root, shard_id)
    step = latest_step(d) if version is None else version
    if step is None:
        raise FileNotFoundError(f"no published artifact for shard "
                                f"{shard_id} under {root}")
    manifest = json.loads(
        (d / f"step_{step:010d}" / "manifest.json").read_text())
    tree_like = {k: np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
                 for k, info in manifest["arrays"].items()}
    _, tree = restore_checkpoint(d, tree_like, step=step)
    return ShardReplica(series=tree["series"],
                        signatures=tree["signatures"],
                        row_start=int(np.asarray(tree["row_start"])))
