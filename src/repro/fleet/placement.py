"""R-way replicated shard placement with stable minimal-movement moves.

Invariants (property-tested in ``tests/test_fleet.py``):

* every shard is assigned exactly ``replication`` **distinct** live
  workers — no replica pair ever co-locates on one worker;
* replica-slot load is balanced within one slot across the fleet;
* ``fail()``/``resize()`` conserve the shard set and move only the
  minimal slot set — a surviving (shard, worker) assignment is never
  reshuffled just because the worker list changed (unlike round-robin,
  which re-deals nearly every shard when the list shifts by one).

Placement is deterministic: the initial deal and every re-home pick
workers by (load, name) order, so two controllers computing a plan from
the same inputs agree without coordination.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple


def _balance(loads: Dict[str, List[int]],
             holders: Dict[int, List[str]]) -> List[Tuple[int, str]]:
    """Move slots from the most- to the least-loaded worker until the
    load spread is <= 1; returns the moved (shard, new_worker) slots.

    ``loads`` maps worker -> list of held shard ids (mutated in place);
    ``holders`` maps shard -> its replica workers (mutated in place).
    A donor with >= 2 more slots than a receiver always holds a shard
    the receiver lacks (else the receiver would hold a superset and at
    least the donor's load), so the loop always makes progress.
    """
    moved: List[Tuple[int, str]] = []
    while True:
        order = sorted(loads, key=lambda w: (len(loads[w]), w))
        lo, hi = order[0], order[-1]
        if len(loads[hi]) - len(loads[lo]) <= 1:
            return moved
        shard = next(s for s in sorted(loads[hi]) if lo not in holders[s])
        loads[hi].remove(shard)
        loads[lo].append(shard)
        holders[shard][holders[shard].index(hi)] = lo
        moved.append((shard, lo))


@dataclasses.dataclass
class ReplicatedShardPlan:
    """Deterministic assignment of each shard to R distinct workers.

    ``assignment[s]`` lists shard ``s``'s replica holders in preference
    order — index 0 is the primary a query routes to first; hedges and
    failovers walk the rest of the list.
    """

    n_shards: int
    workers: List[str]
    replication: int = 1
    assignment: Dict[int, List[str]] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}")
        if self.replication > len(self.workers):
            raise ValueError(
                f"replication {self.replication} needs at least that many "
                f"workers, got {len(self.workers)}")
        if not self.assignment:
            # deal replica slots round-robin over the sorted worker list:
            # consecutive residues mod W are distinct for R <= W, and the
            # slot stream balances loads within one
            ws = sorted(self.workers)
            w = len(ws)
            self.assignment = {
                s: [ws[(s * self.replication + j) % w]
                    for j in range(self.replication)]
                for s in range(self.n_shards)}

    # -- reads ------------------------------------------------------------
    def replicas(self, shard: int) -> List[str]:
        return list(self.assignment[shard])

    def primary(self, shard: int) -> str:
        return self.assignment[shard][0]

    def shards_of(self, worker: str) -> List[int]:
        return sorted(s for s, ws in self.assignment.items()
                      if worker in ws)

    def loads(self) -> Dict[str, int]:
        return {w: len(self.shards_of(w)) for w in self.workers}

    # -- moves ------------------------------------------------------------
    def _rehome(self, dead: str) -> List[Tuple[int, str]]:
        """Re-place every replica slot held by ``dead`` on the least-
        loaded live worker not already holding that shard."""
        moved: List[Tuple[int, str]] = []
        loads = {w: len(self.shards_of(w)) for w in self.workers}
        for s in sorted(self.assignment):
            ws = self.assignment[s]
            if dead not in ws:
                continue
            candidates = [w for w in self.workers if w not in ws]
            if not candidates:
                raise RuntimeError(
                    f"cannot re-place shard {s}: {len(self.workers)} live "
                    f"workers < replication {self.replication}")
            new = min(sorted(candidates), key=lambda w: loads[w])
            ws[ws.index(dead)] = new
            loads[new] += 1
            moved.append((s, new))
        return moved

    def fail(self, worker: str) -> List[Tuple[int, str]]:
        """Worker died: its replica slots move to the least-loaded
        survivors (never co-locating with a live replica).  Returns the
        moved (shard, new_worker) slots."""
        if worker not in self.workers:
            return []
        self.workers = [w for w in self.workers if w != worker]
        if len(self.workers) < self.replication:
            self.workers.append(worker)      # restore; plan unchanged
            raise RuntimeError(
                f"losing {worker!r} would leave {len(self.workers) - 1} "
                f"workers < replication {self.replication}")
        return self._rehome(worker)

    def resize(self, new_workers: List[str]) -> List[Tuple[int, str]]:
        """Elastic scale up/down with stable minimal movement.

        Surviving (shard, worker) slots stay put; slots on removed
        workers re-home, then slots flow from overloaded to underloaded
        workers (new workers start empty) only until the load spread is
        <= 1.  Returns the moved (shard, new_worker) slots.
        """
        new = list(dict.fromkeys(new_workers))      # dedupe, keep order
        if len(new) < self.replication:
            raise RuntimeError(
                f"{len(new)} workers < replication {self.replication}")
        removed = [w for w in self.workers if w not in new]
        self.workers = new
        moved: List[Tuple[int, str]] = []
        for dead in removed:
            moved.extend(self._rehome(dead))
        loads = {w: self.shards_of(w) for w in self.workers}
        moved.extend(_balance(loads, self.assignment))
        return moved
