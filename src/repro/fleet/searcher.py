"""FleetSearcher — replicated, hedged, elastic shard fan-out.

The resilience layer of the distributed tier (DESIGN.md §11).  The index
rows are partitioned into shards; each shard is published once as a
``repro.checkpoint`` artifact and placed on ``config.replication``
distinct workers by a :class:`~repro.fleet.placement.ReplicatedShardPlan`.
A query encodes once, fans out one shard-local probe per shard
(primary replica first), and merges the per-shard top-C lists into the
global top-k.

Resilience mechanisms, none of which change a single answer:

* **failover** — a shard call that errors (dead worker, dropped
  response) is re-issued to the next replica immediately;
* **hedging** — every shard call carries a deadline derived from the
  per-worker stage-seconds telemetry (``StragglerPolicy`` EWMAs: at
  least ``hedge_ms``, else ``threshold x`` the fleet-median shard time;
  a worker already striking as a straggler is hedged immediately).
  When the deadline lapses the call is re-issued to the next replica
  and the first response wins;
* **drain** — ``drain(worker)`` stops routing new shard calls to the
  worker, waits for its in-flight calls, re-homes its replica slots
  (fetched from the published artifacts) and retires it — zero queued
  queries are lost;
* **elasticity** — ``resize(n)`` moves only the minimal replica-slot
  set (stable placement), fetching moved shards from their artifacts.

Soundness of first-response-wins: replicas restore the *same* published
artifact, the shard-local probe is deterministic in (shard state, sig,
query), and the merge is a stable sort — so hedged, failed-over and
healthy runs return bit-identical ids AND distances (chaos-tested in
``tests/test_fleet.py``, gated in ``benchmarks/dist_bench.py``).
"""
from __future__ import annotations

import tempfile
import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from typing import Dict, List, Optional, Tuple, Union

import jax.numpy as jnp
import numpy as np

from repro.core.rerank import SearchStats
from repro.db.config import SearchConfig
from repro.distributed.fault_tolerance import StragglerPolicy
from repro.fleet.injector import FaultInjector
from repro.fleet.placement import ReplicatedShardPlan
from repro.fleet.transfer import fetch_shard, publish_shard
from repro.fleet.worker import FleetWorker


class FleetSearcher:
    """Resilient distributed searcher over logical in-process workers.

    Serves the serving-internal contract (``search_batch`` ->
    ``BatchSearchResult``) so it can replace the mesh fan-out behind the
    ``ServingEngine`` and the ``repro.db`` registry unchanged.
    """

    def __init__(self, index, config: SearchConfig, *,
                 n_workers: Optional[int] = None,
                 injector: Optional[FaultInjector] = None):
        if config.band is None:
            raise ValueError("FleetSearcher requires a band radius")
        if not config.rank_by_signature or config.multiprobe_offsets > 1:
            raise ValueError(
                "FleetSearcher supports only rank_by_signature=True "
                "and multiprobe_offsets=1 (same probe as the shard_map "
                "fan-out)")
        self.index = index
        self.config = config
        self.replication = max(1, config.replication)
        w = n_workers or config.fleet_workers or max(2, self.replication)
        if self.replication > w:
            raise ValueError(
                f"replication {self.replication} > fleet of {w} workers")
        n = int(index.signatures.shape[0])
        self.n_shards = max(1, min(w, n // max(1, config.topk)))
        names = [f"w{i}" for i in range(w)]
        self.workers: Dict[str, FleetWorker] = \
            {name: FleetWorker(name) for name in names}
        self.plan = ReplicatedShardPlan(self.n_shards, names,
                                        replication=self.replication)
        self.policy = StragglerPolicy()
        self.injector = injector if injector is not None else FaultInjector()
        # fleet-level observability (ServingMetrics mirrors these)
        self.hedged_total = 0
        self.failovers_total = 0
        self.degraded_total = 0
        self.rebalanced_shards_total = 0

        self._route_lock = threading.RLock()
        self._policy_lock = threading.Lock()
        self._cond = threading.Condition()
        self._inflight: Dict[str, int] = {}
        self._draining: set = set()
        self._version = 0
        self._tmp = tempfile.TemporaryDirectory(prefix="ssh-fleet-")
        self.artifact_root = self._tmp.name
        # spare threads beyond one-per-worker so hedges never starve
        # behind a sleeping straggler
        self._pool = ThreadPoolExecutor(
            max_workers=max(8, 4 * w), thread_name_prefix="ssh-fleet")
        self._closed = False
        self._publish_and_place()

    # -- placement / transfer ---------------------------------------------
    def _partition(self) -> List[Tuple[int, int]]:
        """Row ranges [(start, stop)) of each shard, in shard order."""
        n = int(self.index.signatures.shape[0])
        bounds = np.linspace(0, n, self.n_shards + 1).astype(int)
        return [(int(bounds[i]), int(bounds[i + 1]))
                for i in range(self.n_shards)]

    def _publish_and_place(self) -> None:
        """Publish every shard artifact and hand replicas to the
        assigned workers (initial build, or full re-place after a
        streaming fold changed the row partition)."""
        with self._route_lock:
            series = np.asarray(self.index.series)
            sigs = np.asarray(self.index.signatures)
            for s, (lo, hi) in enumerate(self._partition()):
                publish_shard(self.artifact_root, s, series[lo:hi],
                              sigs[lo:hi], lo, version=self._version)
                for name in self.plan.replicas(s):
                    self.workers[name].receive_shard(
                        s, fetch_shard(self.artifact_root, s))

    # -- hedging policy ---------------------------------------------------
    def _deadline_s(self, worker: str) -> Optional[float]:
        """Seconds to wait on ``worker`` before hedging (None = never)."""
        cfg = self.config
        if cfg.hedge_policy == "off":
            return None
        if cfg.hedge_policy == "fixed":
            return cfg.hedge_ms / 1e3
        with self._policy_lock:
            if self.policy.is_straggler(worker):
                return 0.0                 # known straggler: hedge now
            med = self.policy.median()
        if med <= 0:
            return None                    # no telemetry yet
        return max(cfg.hedge_ms / 1e3, self.policy.threshold * med)

    def _route(self, shard: int) -> List[str]:
        """Replica attempt order: plan order, draining workers last."""
        ws = self.plan.replicas(shard)
        return ([w for w in ws if w not in self._draining]
                + [w for w in ws if w in self._draining])

    # -- shard call -------------------------------------------------------
    def _guarded_call(self, name: str, shard: int, sig, q):
        with self._cond:
            self._inflight[name] = self._inflight.get(name, 0) + 1
        t0 = time.perf_counter()
        try:
            from repro.kernels import ops
            worker = self.workers[name]          # may raise post-retire
            cfg = self.config
            out = worker.query_shard(
                shard, sig, q,
                local_c=max(cfg.topk, cfg.top_c // self.n_shards),
                topk=cfg.topk, band=cfg.band,
                use_pallas=ops.resolve_backend(cfg.backend),
                abandon=cfg.use_lb_cascade and cfg.early_abandon,
                injector=self.injector)
            dt = time.perf_counter() - t0
            with self._policy_lock:
                # the per-shard stage telemetry IS the straggler signal:
                # every completed call feeds the worker's EWMA and
                # advances its strike counter once
                self.policy.observe(name, dt)
                self.policy.step(name)
            return out
        finally:
            with self._cond:
                self._inflight[name] -= 1
                self._cond.notify_all()

    def _launch(self, name: str, shard: int, sig, q):
        return (name, self._pool.submit(
            self._guarded_call, name, shard, sig, q), time.perf_counter())

    def _resolve_shard(self, shard: int, routes: List[str], sig, q,
                       first=None):
        """First-response-wins over the replica chain.

        ``first`` is the already-launched primary attempt (the query fans
        out every shard's primary before resolving any of them, so one
        shard's failover/hedge waits overlap the other shards' work).
        Returns ((gids, dists), hedged, failovers, non_primary)."""
        primary = routes[0]
        remaining = list(routes)
        hedged = failovers = 0
        pending = []                        # [(worker, future, t_submit)]

        def launch():
            pending.append(self._launch(remaining.pop(0), shard, sig, q))

        if first is not None:
            remaining.pop(0)
            pending.append(first)
        else:
            launch()
        while True:
            for entry in list(pending):
                name, fut, _ = entry
                if not fut.done():
                    continue
                exc = fut.exception()
                if exc is None:
                    return fut.result(), hedged, failovers, name != primary
                pending.remove(entry)
                failovers += 1
                if remaining:
                    launch()
            if not pending:
                raise RuntimeError(
                    f"all {len(routes)} replicas of shard {shard} failed "
                    f"({routes}); raise config.replication or revive a "
                    "worker")
            timeout = None
            if remaining:
                now = time.perf_counter()
                cutoffs = [t0 + d for name, _, t0 in pending
                           if (d := self._deadline_s(name)) is not None]
                if cutoffs:
                    timeout = min(cutoffs) - now
                    if timeout <= 0:
                        hedged += 1
                        launch()
                        continue
            wait([f for _, f, _ in pending], timeout=timeout,
                 return_when=FIRST_COMPLETED)

    # -- queries ----------------------------------------------------------
    def _query_one(self, q: jnp.ndarray):
        cfg = self.config
        sig = self.index.enc.encode(q, backend=cfg.backend)
        with self._route_lock:
            routes = {s: self._route(s) for s in range(self.n_shards)}
        # fan out every shard's primary before resolving any shard: a
        # failover or hedge wait on one shard overlaps the others' work
        primaries = {s: self._launch(routes[s][0], s, sig, q)
                     for s in range(self.n_shards)}
        per_shard = {}
        hedged = failovers = 0
        degraded = False
        for s in range(self.n_shards):
            out, h, f, non_primary = self._resolve_shard(
                s, routes[s], sig, q, first=primaries[s])
            per_shard[s] = out
            hedged += h
            failovers += f
            degraded = degraded or f > 0 or non_primary
        all_i = np.concatenate([per_shard[s][0]
                                for s in range(self.n_shards)])
        all_d = np.concatenate([per_shard[s][1]
                                for s in range(self.n_shards)])
        k = min(cfg.topk, all_d.shape[0])
        order = np.argsort(all_d, kind="stable")[:k]
        return all_i[order], all_d[order], hedged, failovers, degraded

    def search_batch(self, queries: jnp.ndarray):
        from repro.bench.timing import StageTimer
        from repro.kernels import ops
        from repro.serving.batched import BatchSearchResult
        t0 = time.perf_counter()
        cfg = self.config
        timer = StageTimer(enabled=cfg.stage_timings)
        queries = jnp.asarray(queries)
        b = int(queries.shape[0])
        n = int(self.index.signatures.shape[0])
        ids, dists = [], []
        hedged = failovers = degraded = 0
        for i in range(b):
            # host-orchestrated fan-out: one unsplittable span per query,
            # reported under the same "fused" key as the shard_map path
            with timer.stage("fused"):
                gid, d, h, f, deg = self._query_one(queries[i])
            ids.append(gid)
            dists.append(d)
            hedged += h
            failovers += f
            degraded += int(deg)
        with self._route_lock:
            self.hedged_total += hedged
            self.failovers_total += failovers
            self.degraded_total += degraded
        stats = SearchStats(
            backend=ops.backend_name(ops.resolve_backend(cfg.backend)),
            hedged=hedged, failovers=failovers, degraded=degraded > 0)
        if timer.enabled:
            stats.stage_seconds = dict(timer.timings)
        top_c = cfg.top_c
        return BatchSearchResult(
            ids=np.stack(ids).astype(np.int64),
            dists=np.stack(dists).astype(np.float32),
            n_queries=b, n_database=n, n_union=min(top_c, n),
            n_candidates=np.full(b, min(top_c, n), np.int64),
            pruned_by_hash_frac=np.full(b, 1.0 - min(top_c, n) / n),
            pruned_total_frac=np.full(b, 1.0 - min(top_c, n) / n),
            wall_seconds=time.perf_counter() - t0, stats=stats)

    # -- mutation ---------------------------------------------------------
    def insert(self, series: jnp.ndarray) -> None:
        raise NotImplementedError(
            "streaming inserts into the fleet require a re-place; stream "
            "through a StreamIngestor and fold with apply_artifacts()")

    def apply_artifacts(self, artifacts) -> None:
        """Fold pre-encoded streaming artifacts, then republish: the row
        partition changes, so every shard artifact is re-published at a
        new version and replicas re-fetch."""
        self.index.insert_encoded(artifacts.series, artifacts.signatures,
                                  artifacts.keys)
        with self._route_lock:
            self._version += 1
            self._publish_and_place()

    # -- elasticity -------------------------------------------------------
    def resize(self, workers: Union[int, List[str]]) -> int:
        """Live rebalance onto a new worker set; moves ONLY the minimal
        replica-slot set (stable placement).  Returns the number of
        distinct shards that moved."""
        with self._route_lock:
            names = ([f"w{i}" for i in range(workers)]
                     if isinstance(workers, int) else list(workers))
            moved = self.plan.resize(names)
            for name in names:
                if name not in self.workers:
                    self.workers[name] = FleetWorker(name)
            for s, name in moved:
                self.workers[name].receive_shard(
                    s, fetch_shard(self.artifact_root, s))
            live = set(names)
            for name in list(self.workers):
                if name not in live:
                    del self.workers[name]
                    continue
                keep = set(self.plan.shards_of(name))
                for s in self.workers[name].shard_ids():
                    if s not in keep:
                        self.workers[name].drop_shard(s)
            n_moved = len({s for s, _ in moved})
            self.rebalanced_shards_total += n_moved
            return n_moved

    def fail_worker(self, worker: str) -> int:
        """Abrupt permanent loss: re-home the worker's replica slots from
        the published artifacts (no waiting).  Returns shards moved."""
        with self._route_lock:
            if worker not in self.workers:
                return 0
            moved = self.plan.fail(worker)
            for s, name in moved:
                self.workers[name].receive_shard(
                    s, fetch_shard(self.artifact_root, s))
            del self.workers[worker]
            self._draining.discard(worker)
            n_moved = len({s for s, _ in moved})
            self.rebalanced_shards_total += n_moved
            return n_moved

    def drain(self, worker: str) -> int:
        """Graceful retirement: stop routing to ``worker``, let its
        in-flight shard calls finish (their responses still count —
        nothing queued is lost), then re-home its replica slots and
        retire it.  Returns the number of shards moved."""
        with self._route_lock:
            if worker not in self.workers:
                return 0
            if len(self.plan.workers) - 1 < self.replication:
                raise RuntimeError(
                    f"cannot drain {worker!r}: "
                    f"{len(self.plan.workers) - 1} workers would be left "
                    f"for replication {self.replication}")
            self._draining.add(worker)
        with self._cond:
            while self._inflight.get(worker, 0):
                self._cond.wait(timeout=0.05)
        return self.fail_worker(worker)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._pool.shutdown(wait=False, cancel_futures=True)
        try:
            self._tmp.cleanup()
        except OSError:                     # pragma: no cover - best effort
            pass
