"""FleetWorker — one logical worker's shard replicas + shard-local query.

The shard-local math mirrors ``distributed.dist_index._make_query_core``
exactly (collision scan over raw signatures → local top-C/S → shard-seed
threshold → banded early-abandoning DTW), so the fleet tier preserves
SSH's sub-linear DTW count per shard.  Workers holding replicas of the
same shard fetched the same checkpoint artifact, so the same (sig, q)
input yields bit-identical (ids, dists) on every replica — which is why
hedged / failed-over queries answer identically to the healthy run.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class ShardReplica:
    """One shard's encoded rows as held by a worker."""
    series: jnp.ndarray        # (n_s, m)
    signatures: jnp.ndarray    # (n_s, K)
    row_start: int             # global id of local row 0

    @property
    def n_rows(self) -> int:
        return int(self.signatures.shape[0])

    def nbytes(self) -> int:
        return int(np.asarray(self.series).nbytes
                   + np.asarray(self.signatures).nbytes)


class FleetWorker:
    """A logical worker: named, holds shard replicas, answers shard
    queries.  Thread-safe for the fleet's concurrent fan-out (shard
    loads/drops take the lock; queries read a stable snapshot)."""

    def __init__(self, name: str):
        self.name = name
        self._lock = threading.Lock()
        self._shards: Dict[int, ShardReplica] = {}

    # -- shard custody ----------------------------------------------------
    def receive_shard(self, shard_id: int, replica: ShardReplica) -> None:
        with self._lock:
            self._shards[shard_id] = replica

    def drop_shard(self, shard_id: int) -> None:
        with self._lock:
            self._shards.pop(shard_id, None)

    def shard_ids(self):
        with self._lock:
            return sorted(self._shards)

    def nbytes(self) -> int:
        with self._lock:
            return sum(r.nbytes() for r in self._shards.values())

    # -- the shard-local query (dist_index schedule, host-orchestrated) ---
    def query_shard(self, shard_id: int, sig: jnp.ndarray, q: jnp.ndarray,
                    *, local_c: int, topk: int, band: int,
                    use_pallas: Optional[bool], abandon: bool,
                    injector=None) -> Tuple[np.ndarray, np.ndarray]:
        """(global ids, dists) of this shard's local top-``local_c``.

        Deterministic in (shard state, sig, q): any replica of the same
        artifact returns bit-identical arrays.  ``injector`` (a
        ``FaultInjector``) gates the call for chaos tests/benchmarks.
        """
        if injector is not None:
            injector.before_call(self.name)
        with self._lock:
            try:
                rep = self._shards[shard_id]
            except KeyError:
                raise KeyError(f"worker {self.name!r} holds no replica "
                               f"of shard {shard_id}") from None
        from repro.kernels import ops
        c = min(local_c, rep.n_rows)
        coll = jnp.sum((rep.signatures == sig[None, :]).astype(jnp.int32),
                       axis=-1)
        _, cand = jax.lax.top_k(coll, c)
        cand_series = jnp.take(rep.series, cand, axis=0)
        thr = None
        if abandon and c > topk:
            # shard-local seed threshold (same soundness argument as the
            # shard_map path): the global k-th best is <= this shard's
            # k-th best over its first topk hash hits, so a lane the
            # threshold abandons can never reach the merged top-k
            seed = ops.dtw_rerank(q, cand_series[:topk], band,
                                  use_pallas=use_pallas)
            thr = jnp.sort(seed)[topk - 1]
        d = ops.dtw_rerank(q, cand_series, band, use_pallas=use_pallas,
                           threshold=thr)
        gids = np.asarray(cand, np.int64) + rep.row_start
        return gids, np.asarray(d, np.float32)
