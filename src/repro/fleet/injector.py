"""Fault injection for the fleet tier — kill, delay, drop-every-Nth.

Usable from tests and benchmarks (``benchmarks/dist_bench.py`` injects
one dead and one 10x-slow worker and gates p99 *under failure*).  The
injector gates every shard call a worker executes:

* ``kill(worker)`` — every call raises :class:`WorkerKilled` until
  ``revive(worker)``;
* ``delay(worker, ms)`` — every call sleeps ``ms`` first (straggler);
* ``drop_every(worker, n)`` — every n-th call raises
  :class:`ResponseDropped` (lossy network / overloaded RPC server).

All mutators are thread-safe; a default-constructed injector is a
no-op, so the production path pays one dict lookup per shard call.
"""
from __future__ import annotations

import threading
import time
from typing import Dict, Optional


class WorkerFault(RuntimeError):
    """Base class of injected worker failures (callers fail over)."""


class WorkerKilled(WorkerFault):
    """The worker is dead: every call fails until ``revive()``."""


class ResponseDropped(WorkerFault):
    """This call's response was dropped (every-Nth-call injection)."""


class FaultInjector:
    """Per-worker fault switchboard consulted before every shard call."""

    def __init__(self):
        self._lock = threading.Lock()
        self._killed: set = set()
        self._delay_ms: Dict[str, float] = {}
        self._drop_every: Dict[str, int] = {}
        self._calls: Dict[str, int] = {}

    # -- switches ---------------------------------------------------------
    def kill(self, worker: str) -> None:
        with self._lock:
            self._killed.add(worker)

    def revive(self, worker: str) -> None:
        with self._lock:
            self._killed.discard(worker)

    def delay(self, worker: str, ms: Optional[float]) -> None:
        with self._lock:
            if ms is None or ms <= 0:
                self._delay_ms.pop(worker, None)
            else:
                self._delay_ms[worker] = float(ms)

    def drop_every(self, worker: str, n: Optional[int]) -> None:
        if n is not None and n < 1:
            raise ValueError(f"drop_every needs n >= 1, got {n}")
        with self._lock:
            if n is None:
                self._drop_every.pop(worker, None)
                self._calls.pop(worker, None)
            else:
                self._drop_every[worker] = int(n)
                self._calls.setdefault(worker, 0)

    def clear(self) -> None:
        with self._lock:
            self._killed.clear()
            self._delay_ms.clear()
            self._drop_every.clear()
            self._calls.clear()

    # -- the gate (called by FleetWorker.query_shard) ---------------------
    def before_call(self, worker: str) -> None:
        """Raise/sleep according to the faults armed for ``worker``."""
        with self._lock:
            if worker in self._killed:
                raise WorkerKilled(f"worker {worker!r} is down")
            sleep_ms = self._delay_ms.get(worker, 0.0)
            drop = False
            if worker in self._drop_every:
                self._calls[worker] += 1
                drop = self._calls[worker] % self._drop_every[worker] == 0
        if sleep_ms:
            time.sleep(sleep_ms / 1e3)
        if drop:
            raise ResponseDropped(
                f"worker {worker!r} dropped this response")
