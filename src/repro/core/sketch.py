"""Step 1 of SSH — sliding-window bit-profile (sketch) extraction (§4.1).

A random Gaussian filter ``r`` (length W) slides over the series with step
δ; each window contributes ``sign(<window, r>)`` — one bit.  This is a
signed random projection of every local window, i.e. a 1-bit LSH of the
local profile.

TPU adaptation (DESIGN.md §3): the windows×filter product is a strided
matvec; generalised to a bank of F filters it becomes a (N_B, W) x (W, F)
matmul that feeds the MXU — ``repro.kernels.sketch_conv`` implements the
tiled Pallas version.  F=1 reproduces the paper exactly.

Bits are stored as uint8 in {0, 1} (1 ⇔ projection >= 0, matching the
paper's +1; 0 ⇔ the paper's -1).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def num_sketch_bits(m: int, window: int, step: int) -> int:
    """N_B = floor((m - W) / δ) + 1 (number of full windows)."""
    if m < window:
        raise ValueError(f"series length {m} < filter window {window}")
    return (m - window) // step + 1


def make_filter(key: jax.Array, window: int, num_filters: int = 1
                ) -> jnp.ndarray:
    """Spherically-symmetric random filter bank r ~ N(0,1), (W, F)."""
    return jax.random.normal(key, (window, num_filters), jnp.float32)


@functools.partial(jax.jit, static_argnames=("step",))
def sketch_projections(x: jnp.ndarray, filters: jnp.ndarray, step: int
                       ) -> jnp.ndarray:
    """Raw sliding-window projections (pre-sign). x: (..., m) -> (..., N_B, F).

    Windows are gathered with a static index grid (strided im2col); the
    contraction runs on the last axis.
    """
    window, _ = filters.shape
    m = x.shape[-1]
    n_b = num_sketch_bits(m, window, step)
    idx = jnp.arange(n_b)[:, None] * step + jnp.arange(window)[None, :]
    windows = x[..., idx]                     # (..., N_B, W)
    return windows @ filters                  # (..., N_B, F)


@functools.partial(jax.jit, static_argnames=("step",))
def sketch_bits(x: jnp.ndarray, filters: jnp.ndarray, step: int
                ) -> jnp.ndarray:
    """Bit-profile B_X: (..., m) -> (..., N_B, F) uint8 in {0,1}."""
    proj = sketch_projections(x, filters, step)
    return (proj >= 0).astype(jnp.uint8)


def sketch_shape(m: int, window: int, step: int, num_filters: int
                 ) -> Tuple[int, int]:
    return num_sketch_bits(m, window, step), num_filters
