"""SSH index — signatures, hash tables, and the device-side probe path.

Two interchangeable probe backends:

* **Host buckets** (`HostBuckets`): exact emulation of the paper's d hash
  tables as Python dicts — reference semantics, used by tests and the
  small-scale benchmarks.
* **Device scan** (`signature_collisions` / `probe_topc`): the TPU-native
  replacement — the (N, L) band-key matrix stays on device; probing is a
  vectorised equality-count + top-C.  This is what shards across pods
  (see `repro.distributed.dist_index`) and what the `collision_count`
  Pallas kernel accelerates.

Pipeline (paper Fig. 5): series → sketch bits → shingle histogram → CWS
signature (K hashes) → L band keys → tables.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import minhash, shingle, sketch


@dataclasses.dataclass(frozen=True)
class SSHParams:
    """SSH hyper-parameters (paper §4.5 / §5.5)."""
    window: int = 80          # W — filter length
    step: int = 3             # δ — slide stride
    ngram: int = 15           # n — shingle length
    num_filters: int = 1      # F — filter-bank size (F=1 == paper)
    num_hashes: int = 20      # K — total CWS hashes
    num_tables: int = 20      # L — hash tables (bands); rows = K / L
    seed: int = 7

    @property
    def shingle_dim(self) -> int:
        return shingle.shingle_space(self.ngram, self.num_filters)

    def validate(self) -> None:
        if self.num_hashes % self.num_tables:
            raise ValueError("num_hashes must be divisible by num_tables")
        if self.ngram > 20:
            raise ValueError("shingle space 2^n exceeds 1M bins; use n<=20")

    def to_spec(self):
        """Lower to the modern ``repro.encoders.IndexSpec`` — the
        ``"ssh"`` encoder built from the resulting spec is bit-identical
        to the historical ``SSHParams`` path (same key schedule, same
        stage functions; pinned by ``tests/test_encoders.py``)."""
        from repro.encoders import IndexSpec
        return IndexSpec(
            encoder="ssh",
            params=dict(window=self.window, step=self.step,
                        ngram=self.ngram, num_filters=self.num_filters,
                        num_hashes=self.num_hashes,
                        num_tables=self.num_tables),
            seed=self.seed)


def _spec_from_legacy(params, caller: str, stacklevel: int = 3):
    """Deprecation shim: fold a legacy ``SSHParams`` (or pass through an
    ``IndexSpec``) into the one spec type every build path consumes.
    ``caller``/``stacklevel`` keep the warning pointing at the user's
    call site for every shimmed entry point."""
    from repro.encoders import IndexSpec
    if isinstance(params, IndexSpec):
        return params
    if isinstance(params, SSHParams):
        import warnings
        warnings.warn(
            f"passing SSHParams to {caller}() is deprecated; pass "
            "spec=repro.encoders.IndexSpec(encoder='ssh', params={...}) "
            "instead (results are identical)",
            DeprecationWarning, stacklevel=stacklevel)
        return params.to_spec()
    raise TypeError(f"{caller}() needs an IndexSpec (spec=...) or a "
                    f"legacy SSHParams, got {type(params).__name__}")


@dataclasses.dataclass
class SSHFunctions:
    """Materialised random functions (filter bank + CWS fields)."""
    params: SSHParams
    filters: jnp.ndarray      # (W, F)
    cws: minhash.CWSParams    # fields over (K, F·2^n)

    @classmethod
    def create(cls, params: SSHParams) -> "SSHFunctions":
        params.validate()
        key = jax.random.PRNGKey(params.seed)
        kf, kc = jax.random.split(key)
        filters = sketch.make_filter(kf, params.window, params.num_filters)
        cws = minhash.make_cws(kc, params.num_hashes, params.shingle_dim)
        return cls(params=params, filters=filters, cws=cws)


@functools.partial(jax.jit, static_argnames=("step", "ngram"))
def _signature_one(x, filters, cws, *, step: int, ngram: int):
    bits = sketch.sketch_bits(x, filters, step)          # (N_B, F)
    counts = shingle.shingle_histogram(bits, ngram)      # (F·2^n,)
    return minhash.cws_hash(counts, cws)                 # (K,)


@functools.partial(jax.jit, static_argnames=("step", "ngram"))
def _signature_batch(xs, filters, cws, *, step: int, ngram: int):
    """(B, m) -> (B, K) — one fused dispatch for a query block."""
    return jax.vmap(lambda x: _signature_one(x, filters, cws,
                                             step=step, ngram=ngram))(xs)


def build_signatures(series: jnp.ndarray, fns: SSHFunctions,
                     batch: int = 256) -> jnp.ndarray:
    """(N, m) -> (N, K) int32 CWS signatures, chunked over the database.

    Routes through the module-level jitted ``_signature_batch`` so
    repeated calls (chunked builds, streaming inserts) hit the compile
    cache instead of re-wrapping ``jax.jit`` around a fresh closure per
    call — the historical retrace-per-call bug.
    """
    p = fns.params
    n = series.shape[0]
    out = []
    for lo in range(0, n, batch):
        out.append(np.asarray(_signature_batch(
            series[lo:lo + batch], fns.filters, fns.cws,
            step=p.step, ngram=p.ngram)))
    return jnp.asarray(np.concatenate(out, axis=0))


def band_keys(signatures: jnp.ndarray, params: SSHParams) -> jnp.ndarray:
    """(N, K) -> (N, L) uint32 bucket keys."""
    return minhash.combine_bands(signatures, params.num_tables)


@jax.jit
def signature_collisions(query_keys: jnp.ndarray, db_keys: jnp.ndarray
                         ) -> jnp.ndarray:
    """Number of tables in which query and candidate share a bucket.

    query_keys: (L,), db_keys: (N, L) -> (N,) int32.
    """
    return jnp.sum((db_keys == query_keys[None, :]).astype(jnp.int32),
                   axis=-1)


@functools.partial(jax.jit, static_argnames=("top_c",))
def probe_topc(query_keys: jnp.ndarray, db_keys: jnp.ndarray, top_c: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-C candidates by collision count. Returns (ids, counts)."""
    counts = signature_collisions(query_keys, db_keys)
    vals, idx = jax.lax.top_k(counts, top_c)
    return idx, vals


@jax.jit
def signature_collisions_batch(query_keys: jnp.ndarray,
                               db_keys: jnp.ndarray) -> jnp.ndarray:
    """Batched collision counts: (B, L) x (N, L) -> (B, N) int32.

    Readable jnp reference for the batched probe math.  The serving
    engine does NOT route through here — it calls
    ``repro.kernels.ops.collision_count_batch`` (Pallas on TPU, a
    CPU-friendly fori_loop formulation in ``kernels.ref`` elsewhere);
    ``tests/test_index_search.py``/``tests/test_kernels.py`` hold the
    three implementations equal.
    """
    return jax.vmap(lambda q: signature_collisions(q, db_keys))(query_keys)


@functools.partial(jax.jit, static_argnames=("top_c",))
def probe_topc_batch(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                     top_c: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query top-C by collision count: (B, L) x (N, L) -> (B, top_c)."""
    counts = signature_collisions_batch(query_keys, db_keys)
    vals, idx = jax.lax.top_k(counts, top_c)
    return idx, vals


class HostBuckets:
    """Paper-faithful d hash tables (Python dicts), for reference/tests.

    Accepts the table count directly (encoder-agnostic) or a legacy
    ``SSHParams`` for compatibility.
    """

    def __init__(self, num_tables):
        self.num_tables = (num_tables if isinstance(num_tables, int)
                           else num_tables.num_tables)
        self.tables: List[Dict[int, List[int]]] = [
            defaultdict(list) for _ in range(self.num_tables)]

    def insert(self, keys: np.ndarray, base_id: int = 0) -> None:
        """keys: (N, L) uint32."""
        keys = np.asarray(keys)
        for i in range(keys.shape[0]):
            for t in range(self.num_tables):
                self.tables[t][int(keys[i, t])].append(base_id + i)

    def probe(self, query_keys: np.ndarray) -> np.ndarray:
        """Bucket members across tables (paper Alg. 2 lines 7-9), ranked by
        how many tables they collide in (most-promising first)."""
        from collections import Counter
        query_keys = np.asarray(query_keys)
        counts: Counter = Counter()
        for t in range(self.num_tables):
            counts.update(self.tables[t].get(int(query_keys[t]), ()))
        if not counts:
            return np.empty(0, np.int64)
        ranked = [i for i, _ in counts.most_common()]
        return np.asarray(ranked, dtype=np.int64)


@dataclasses.dataclass
class SSHIndex:
    """End-to-end index over a database of fixed-length series.

    The hashing itself lives on ``encoder`` (a ``repro.encoders.Encoder``
    — ``"ssh"``, ``"srp"``, ``"ssh-multires"``, or any registered
    out-of-tree encoder); the index owns the derived artifacts
    (signatures, band keys, raw series) plus the probe structures.
    ``fns`` remains as the legacy ``SSHFunctions`` view for the ``"ssh"``
    encoder (``None`` otherwise); indexes constructed the historical way
    (``fns=`` only) materialise their encoder lazily from it, adopting
    the *same* arrays — bit-identical hashing either way.

    ``env_upper``/``env_lower`` cache the Sakoe-Chiba envelopes of every
    database series at radius ``env_radius`` (DESIGN.md §3): the re-rank
    cascade's LB_Keogh2 needs *candidate* envelopes, and precomputing them
    at build/insert time turns that bound from O(C·m·r) per query into an
    O(C·m) gather+compare.  ``candidate_envelopes`` computes them lazily
    (and re-computes on a radius change); ``insert`` keeps them aligned.
    """
    fns: Optional[SSHFunctions]
    signatures: jnp.ndarray            # (N, K)
    keys: jnp.ndarray                  # (N, L)
    series: Optional[jnp.ndarray]      # (N, m) — kept for re-ranking
    host_buckets: Optional[HostBuckets] = None
    env_radius: Optional[int] = None
    env_upper: Optional[jnp.ndarray] = None    # (N, m) at env_radius
    env_lower: Optional[jnp.ndarray] = None
    encoder: Optional[object] = None   # repro.encoders.Encoder
    # kernel knob for query/insert encoding; defaults to "jnp" because a
    # directly-constructed (legacy fns-only) index holds signatures from
    # the historical jnp-only build — modern build()/load() set it
    # explicitly so queries always hash with the build-time kernel
    build_backend: str = "jnp"
    # lazily-created LRU of encoded query signatures keyed by (series
    # content hash, IndexSpec, backend, variant) — repeated queries skip
    # encode; values are bit-identical so results are unchanged
    sig_cache: Optional[object] = None

    @classmethod
    def build(cls, series: jnp.ndarray, params=None,
              with_host_buckets: bool = False, batch: int = 256,
              envelope_band: Optional[int] = None, *,
              spec=None, backend: str = "auto") -> "SSHIndex":
        """Build from an ``IndexSpec`` (``spec=``, canonical) or a legacy
        ``SSHParams`` (positional; deprecation shim, identical results).
        ``backend`` routes the signature build through the Pallas
        ``sketch_conv`` kernel ("pallas"), the jnp reference ("jnp"), or
        picks by platform ("auto")."""
        from repro.encoders import make_encoder
        from repro.kernels import ops
        if spec is not None:
            if params is not None:
                raise TypeError(
                    "SSHIndex.build() takes spec= or a legacy SSHParams, "
                    "not both")
        else:
            spec = _spec_from_legacy(params, "SSHIndex.build")
        # pin the *resolved* backend so queries/inserts — including after
        # a save/load onto a different platform — always hash with the
        # kernel the database was built with ("auto" resolves per host)
        backend = ops.backend_name(ops.resolve_backend(backend))
        enc = make_encoder(spec, length=int(series.shape[1]))
        sigs = enc.encode_chunked(series, batch=batch, backend=backend)
        keys = enc.band_keys(sigs)
        fns = (enc.legacy_functions()
               if hasattr(enc, "legacy_functions") else None)
        hb = None
        if with_host_buckets:
            hb = HostBuckets(enc.num_tables)
            hb.insert(np.asarray(keys))
        idx = cls(fns=fns, signatures=sigs, keys=keys, series=series,
                  host_buckets=hb, encoder=enc, build_backend=backend)
        if envelope_band is not None:
            idx.candidate_envelopes(envelope_band)
        return idx

    # -- encoder access ---------------------------------------------------
    @property
    def enc(self):
        """The index's encoder; legacy ``fns``-only indexes materialise
        an ``"ssh"`` encoder from the stored arrays on first use."""
        if self.encoder is None:
            if self.fns is None:
                raise ValueError("SSHIndex has neither encoder nor fns")
            from repro.encoders import make_encoder
            enc = make_encoder(self.fns.params.to_spec(), materialize=False)
            arrays = {"filters": np.asarray(self.fns.filters)}
            arrays.update({f"cws/{f}": np.asarray(getattr(self.fns.cws, f))
                           for f in self.fns.cws._fields})
            self.encoder = enc.load_arrays(arrays)
        return self.encoder

    @property
    def num_hashes(self) -> int:
        return self.enc.num_hashes

    @property
    def num_tables(self) -> int:
        return self.enc.num_tables

    def candidate_envelopes(self, radius: int):
        """(upper, lower) envelopes of every database series at ``radius``.

        Cached; recomputed when the radius changes.  Chunked over the
        database so the (chunk, m, 2r+1) intermediate stays small.
        """
        if self.series is None:
            raise ValueError("candidate envelopes require stored series")
        n = int(self.series.shape[0])
        stale = (self.env_radius != radius or self.env_upper is None
                 or int(self.env_upper.shape[0]) != n)
        if stale:
            self.env_upper, self.env_lower = _envelopes_chunked(
                self.series, radius)
            self.env_radius = radius
        return self.env_upper, self.env_lower

    def query_signature(self, q: jnp.ndarray) -> jnp.ndarray:
        # queries hash with the SAME kernel backend the database was
        # built (and is streamed) with — signature identity is a
        # build-time property, so a "jnp"-built index is never probed
        # with Pallas-hashed queries (a sign-edge projection could flip)
        return self.enc.encode(q, backend=self.build_backend)

    def query_signatures_multiprobe(self, q: jnp.ndarray,
                                    offsets: int) -> jnp.ndarray:
        """Signatures of ``offsets`` shifted copies of the query.

        Beyond-paper refinement: the shingle grid only aligns for shifts
        ≡ 0 (mod δ); hashing the query at each residue offset recovers the
        other δ-1 alignment classes at query time (the database is
        untouched).  Returns (offsets, K).  One fused program serves all
        offsets (masked fixed-length slices — bit-identical to hashing
        each ``q[o:]`` separately, without a compile per offset length).
        """
        return self.enc.encode_multiprobe(q, offsets,
                                          backend=self.build_backend)

    def query_keys(self, q: jnp.ndarray) -> jnp.ndarray:
        return self.enc.band_keys(self.query_signature(q))

    # -- signature cache (repeated-query traffic skips encode) -----------
    def _sig_cache(self):
        if self.sig_cache is None:
            from repro.encoders.sigcache import SignatureCache
            self.sig_cache = SignatureCache()
        return self.sig_cache

    def _cached_encode(self, q: jnp.ndarray, variant: str, compute):
        """(value, hit) — LRU lookup by query content before encoding.

        A hit returns the previously-encoded array (bit-identical by
        construction: same content, spec, backend, variant); a miss
        computes and populates.  Cost on miss is one blake2b over the
        query bytes — noise next to the encode it guards.
        """
        cache = self._sig_cache()
        key = cache.key(np.asarray(q), self.enc.spec, self.build_backend,
                        variant)
        val = cache.get(key)
        if val is not None:
            return jnp.asarray(val), True
        val = compute()
        cache.put(key, np.asarray(val))
        return val, False

    def query_signature_cached(self, q: jnp.ndarray):
        """(signature, cache_hit) — `query_signature` behind the LRU."""
        return self._cached_encode(q, "sig",
                                   lambda: self.query_signature(q))

    def query_keys_cached(self, q: jnp.ndarray):
        """(band keys, cache_hit) — `query_keys` behind the LRU."""
        return self._cached_encode(q, "keys", lambda: self.query_keys(q))

    def query_signatures_multiprobe_cached(self, q: jnp.ndarray,
                                           offsets: int):
        """(per-offset signatures, cache_hit) behind the LRU."""
        return self._cached_encode(
            q, f"mp{offsets}",
            lambda: self.query_signatures_multiprobe(q, offsets))

    def query_signatures_batch(self, qs: jnp.ndarray) -> jnp.ndarray:
        """(B, m) query block -> (B, K) signatures, one dispatch."""
        return self.enc.encode_batch(qs, backend=self.build_backend)

    def query_signatures_batch_multiprobe(self, qs: jnp.ndarray,
                                          offsets: int) -> jnp.ndarray:
        """Batched multiprobe signatures: (B, m) -> (B, offsets, K).

        Offset o hashes qs[:, o:] — same per-query semantics as
        ``query_signatures_multiprobe`` (δ-residue alignment classes).
        """
        return self.enc.encode_batch_multiprobe(qs, offsets,
                                                backend=self.build_backend)

    def insert(self, series: jnp.ndarray) -> None:
        """Streaming insert (data-independent hashing ⇒ no retraining)."""
        sigs = self.enc.encode_chunked(series, backend=self.build_backend)
        keys = self.enc.band_keys(sigs)
        base = int(self.signatures.shape[0])
        self.signatures = jnp.concatenate([self.signatures, sigs], axis=0)
        self.keys = jnp.concatenate([self.keys, keys], axis=0)
        if self.series is not None:
            self.series = jnp.concatenate([self.series, series], axis=0)
        if self.host_buckets is not None:
            self.host_buckets.insert(np.asarray(keys), base_id=base)
        if self.env_radius is not None and self.env_upper is not None:
            u, l = _envelopes_chunked(series, self.env_radius)
            self.env_upper = jnp.concatenate([self.env_upper, u], axis=0)
            self.env_lower = jnp.concatenate([self.env_lower, l], axis=0)

    def insert_encoded(self, series: Optional[jnp.ndarray],
                       signatures: jnp.ndarray,
                       keys: jnp.ndarray) -> None:
        """Fold pre-encoded rows (a ``StreamIngestor`` fold) into the
        index — the streaming ingest path (DESIGN.md §9).  No re-hashing:
        the artifacts carry signatures + band keys computed shard-side;
        only the probe structures and envelope caches extend here.
        """
        sigs = jnp.asarray(signatures)
        keys = jnp.asarray(keys)
        if int(sigs.shape[-1]) != self.num_hashes:
            raise ValueError(
                f"artifact signatures have K={int(sigs.shape[-1])}, "
                f"index expects K={self.num_hashes}")
        if int(keys.shape[-1]) != self.num_tables:
            raise ValueError(
                f"artifact keys have L={int(keys.shape[-1])}, "
                f"index expects L={self.num_tables}")
        base = int(self.signatures.shape[0])
        self.signatures = jnp.concatenate([self.signatures, sigs], axis=0)
        self.keys = jnp.concatenate([self.keys, keys], axis=0)
        if self.series is not None:
            if series is None:
                raise ValueError(
                    "index stores raw series for re-ranking; artifacts "
                    "must include them")
            self.series = jnp.concatenate(
                [self.series, jnp.asarray(series)], axis=0)
        if self.host_buckets is not None:
            self.host_buckets.insert(np.asarray(keys), base_id=base)
        if (self.env_radius is not None and self.env_upper is not None
                and series is not None):
            u, l = _envelopes_chunked(jnp.asarray(series), self.env_radius)
            self.env_upper = jnp.concatenate([self.env_upper, u], axis=0)
            self.env_lower = jnp.concatenate([self.env_lower, l], axis=0)

    def nbytes(self) -> int:
        """Resident bytes of the index: derived artifacts (signatures,
        keys, stored series, envelope caches) plus the encoder's
        materialised state.  The encoder term is where the sketch-vs-
        exact memory story lives — CWS fields are sized to the shingle
        dimensionality (F·2^n exact vs rows·width for ``"ssh-cs"``).
        """
        arrays = [self.signatures, self.keys, self.series,
                  self.env_upper, self.env_lower]
        total = sum(int(a.size) * a.dtype.itemsize
                    for a in arrays if a is not None)
        enc = self.enc
        if enc.materialized:
            total += sum(int(a.size) * a.dtype.itemsize
                         for a in enc.state().values())
        return total


def _envelopes_chunked(series: jnp.ndarray, radius: int,
                       chunk: int = 512):
    """Database envelopes in row chunks (bounds the (chunk, m, 2r+1)
    shifted-copy intermediate of the vectorised envelope)."""
    from repro.core import lower_bounds as lb
    n = int(series.shape[0])
    ups, los = [], []
    for lo in range(0, n, chunk):
        u, l = lb.envelope(series[lo:lo + chunk], radius)
        ups.append(np.asarray(u))
        los.append(np.asarray(l))
    return (jnp.asarray(np.concatenate(ups, axis=0)),
            jnp.asarray(np.concatenate(los, axis=0)))
