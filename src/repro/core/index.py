"""SSH index — signatures, hash tables, and the device-side probe path.

Two interchangeable probe backends:

* **Host buckets** (`HostBuckets`): exact emulation of the paper's d hash
  tables as Python dicts — reference semantics, used by tests and the
  small-scale benchmarks.
* **Device scan** (`signature_collisions` / `probe_topc`): the TPU-native
  replacement — the (N, L) band-key matrix stays on device; probing is a
  vectorised equality-count + top-C.  This is what shards across pods
  (see `repro.distributed.dist_index`) and what the `collision_count`
  Pallas kernel accelerates.

Pipeline (paper Fig. 5): series → sketch bits → shingle histogram → CWS
signature (K hashes) → L band keys → tables.
"""
from __future__ import annotations

import dataclasses
import functools
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import minhash, shingle, sketch


@dataclasses.dataclass(frozen=True)
class SSHParams:
    """SSH hyper-parameters (paper §4.5 / §5.5)."""
    window: int = 80          # W — filter length
    step: int = 3             # δ — slide stride
    ngram: int = 15           # n — shingle length
    num_filters: int = 1      # F — filter-bank size (F=1 == paper)
    num_hashes: int = 20      # K — total CWS hashes
    num_tables: int = 20      # L — hash tables (bands); rows = K / L
    seed: int = 7

    @property
    def shingle_dim(self) -> int:
        return shingle.shingle_space(self.ngram, self.num_filters)

    def validate(self) -> None:
        if self.num_hashes % self.num_tables:
            raise ValueError("num_hashes must be divisible by num_tables")
        if self.ngram > 20:
            raise ValueError("shingle space 2^n exceeds 1M bins; use n<=20")


@dataclasses.dataclass
class SSHFunctions:
    """Materialised random functions (filter bank + CWS fields)."""
    params: SSHParams
    filters: jnp.ndarray      # (W, F)
    cws: minhash.CWSParams    # fields over (K, F·2^n)

    @classmethod
    def create(cls, params: SSHParams) -> "SSHFunctions":
        params.validate()
        key = jax.random.PRNGKey(params.seed)
        kf, kc = jax.random.split(key)
        filters = sketch.make_filter(kf, params.window, params.num_filters)
        cws = minhash.make_cws(kc, params.num_hashes, params.shingle_dim)
        return cls(params=params, filters=filters, cws=cws)


@functools.partial(jax.jit, static_argnames=("step", "ngram"))
def _signature_one(x, filters, cws, *, step: int, ngram: int):
    bits = sketch.sketch_bits(x, filters, step)          # (N_B, F)
    counts = shingle.shingle_histogram(bits, ngram)      # (F·2^n,)
    return minhash.cws_hash(counts, cws)                 # (K,)


@functools.partial(jax.jit, static_argnames=("step", "ngram"))
def _signature_batch(xs, filters, cws, *, step: int, ngram: int):
    """(B, m) -> (B, K) — one fused dispatch for a query block."""
    return jax.vmap(lambda x: _signature_one(x, filters, cws,
                                             step=step, ngram=ngram))(xs)


def build_signatures(series: jnp.ndarray, fns: SSHFunctions,
                     batch: int = 256) -> jnp.ndarray:
    """(N, m) -> (N, K) int32 CWS signatures, chunked over the database."""
    p = fns.params
    n = series.shape[0]
    sig_fn = jax.jit(jax.vmap(
        lambda x: _signature_one(x, fns.filters, fns.cws,
                                 step=p.step, ngram=p.ngram)))
    out = []
    for lo in range(0, n, batch):
        out.append(np.asarray(sig_fn(series[lo:lo + batch])))
    return jnp.asarray(np.concatenate(out, axis=0))


def band_keys(signatures: jnp.ndarray, params: SSHParams) -> jnp.ndarray:
    """(N, K) -> (N, L) uint32 bucket keys."""
    return minhash.combine_bands(signatures, params.num_tables)


@jax.jit
def signature_collisions(query_keys: jnp.ndarray, db_keys: jnp.ndarray
                         ) -> jnp.ndarray:
    """Number of tables in which query and candidate share a bucket.

    query_keys: (L,), db_keys: (N, L) -> (N,) int32.
    """
    return jnp.sum((db_keys == query_keys[None, :]).astype(jnp.int32),
                   axis=-1)


@functools.partial(jax.jit, static_argnames=("top_c",))
def probe_topc(query_keys: jnp.ndarray, db_keys: jnp.ndarray, top_c: int
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-C candidates by collision count. Returns (ids, counts)."""
    counts = signature_collisions(query_keys, db_keys)
    vals, idx = jax.lax.top_k(counts, top_c)
    return idx, vals


@jax.jit
def signature_collisions_batch(query_keys: jnp.ndarray,
                               db_keys: jnp.ndarray) -> jnp.ndarray:
    """Batched collision counts: (B, L) x (N, L) -> (B, N) int32.

    Readable jnp reference for the batched probe math.  The serving
    engine does NOT route through here — it calls
    ``repro.kernels.ops.collision_count_batch`` (Pallas on TPU, a
    CPU-friendly fori_loop formulation in ``kernels.ref`` elsewhere);
    ``tests/test_index_search.py``/``tests/test_kernels.py`` hold the
    three implementations equal.
    """
    return jax.vmap(lambda q: signature_collisions(q, db_keys))(query_keys)


@functools.partial(jax.jit, static_argnames=("top_c",))
def probe_topc_batch(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                     top_c: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query top-C by collision count: (B, L) x (N, L) -> (B, top_c)."""
    counts = signature_collisions_batch(query_keys, db_keys)
    vals, idx = jax.lax.top_k(counts, top_c)
    return idx, vals


class HostBuckets:
    """Paper-faithful d hash tables (Python dicts), for reference/tests."""

    def __init__(self, params: SSHParams):
        self.params = params
        self.tables: List[Dict[int, List[int]]] = [
            defaultdict(list) for _ in range(params.num_tables)]

    def insert(self, keys: np.ndarray, base_id: int = 0) -> None:
        """keys: (N, L) uint32."""
        keys = np.asarray(keys)
        for i in range(keys.shape[0]):
            for t in range(self.params.num_tables):
                self.tables[t][int(keys[i, t])].append(base_id + i)

    def probe(self, query_keys: np.ndarray) -> np.ndarray:
        """Bucket members across tables (paper Alg. 2 lines 7-9), ranked by
        how many tables they collide in (most-promising first)."""
        from collections import Counter
        query_keys = np.asarray(query_keys)
        counts: Counter = Counter()
        for t in range(self.params.num_tables):
            counts.update(self.tables[t].get(int(query_keys[t]), ()))
        if not counts:
            return np.empty(0, np.int64)
        ranked = [i for i, _ in counts.most_common()]
        return np.asarray(ranked, dtype=np.int64)


@dataclasses.dataclass
class SSHIndex:
    """End-to-end SSH index over a database of fixed-length series.

    ``env_upper``/``env_lower`` cache the Sakoe-Chiba envelopes of every
    database series at radius ``env_radius`` (DESIGN.md §3): the re-rank
    cascade's LB_Keogh2 needs *candidate* envelopes, and precomputing them
    at build/insert time turns that bound from O(C·m·r) per query into an
    O(C·m) gather+compare.  ``candidate_envelopes`` computes them lazily
    (and re-computes on a radius change); ``insert`` keeps them aligned.
    """
    fns: SSHFunctions
    signatures: jnp.ndarray            # (N, K)
    keys: jnp.ndarray                  # (N, L)
    series: Optional[jnp.ndarray]      # (N, m) — kept for re-ranking
    host_buckets: Optional[HostBuckets] = None
    env_radius: Optional[int] = None
    env_upper: Optional[jnp.ndarray] = None    # (N, m) at env_radius
    env_lower: Optional[jnp.ndarray] = None

    @classmethod
    def build(cls, series: jnp.ndarray, params: SSHParams,
              with_host_buckets: bool = False, batch: int = 256,
              envelope_band: Optional[int] = None) -> "SSHIndex":
        fns = SSHFunctions.create(params)
        sigs = build_signatures(series, fns, batch=batch)
        keys = band_keys(sigs, params)
        hb = None
        if with_host_buckets:
            hb = HostBuckets(params)
            hb.insert(np.asarray(keys))
        idx = cls(fns=fns, signatures=sigs, keys=keys, series=series,
                  host_buckets=hb)
        if envelope_band is not None:
            idx.candidate_envelopes(envelope_band)
        return idx

    def candidate_envelopes(self, radius: int):
        """(upper, lower) envelopes of every database series at ``radius``.

        Cached; recomputed when the radius changes.  Chunked over the
        database so the (chunk, m, 2r+1) intermediate stays small.
        """
        if self.series is None:
            raise ValueError("candidate envelopes require stored series")
        n = int(self.series.shape[0])
        stale = (self.env_radius != radius or self.env_upper is None
                 or int(self.env_upper.shape[0]) != n)
        if stale:
            self.env_upper, self.env_lower = _envelopes_chunked(
                self.series, radius)
            self.env_radius = radius
        return self.env_upper, self.env_lower

    def query_signature(self, q: jnp.ndarray) -> jnp.ndarray:
        p = self.fns.params
        return _signature_one(q, self.fns.filters, self.fns.cws,
                              step=p.step, ngram=p.ngram)

    def query_signatures_multiprobe(self, q: jnp.ndarray,
                                    offsets: int) -> jnp.ndarray:
        """Signatures of ``offsets`` shifted copies of the query.

        Beyond-paper refinement: the shingle grid only aligns for shifts
        ≡ 0 (mod δ); hashing the query at each residue offset recovers the
        other δ-1 alignment classes at query time (the database is
        untouched).  Returns (offsets, K).
        """
        p = self.fns.params
        sigs = [self.query_signature(q[o:]) for o in range(offsets)]
        return jnp.stack(sigs, axis=0)

    def query_keys(self, q: jnp.ndarray) -> jnp.ndarray:
        sig = self.query_signature(q)
        return minhash.combine_bands(sig, self.fns.params.num_tables)

    def query_signatures_batch(self, qs: jnp.ndarray) -> jnp.ndarray:
        """(B, m) query block -> (B, K) signatures, one dispatch."""
        p = self.fns.params
        return _signature_batch(qs, self.fns.filters, self.fns.cws,
                                step=p.step, ngram=p.ngram)

    def query_signatures_batch_multiprobe(self, qs: jnp.ndarray,
                                          offsets: int) -> jnp.ndarray:
        """Batched multiprobe signatures: (B, m) -> (B, offsets, K).

        Offset o hashes qs[:, o:] — same per-query semantics as
        ``query_signatures_multiprobe`` (δ-residue alignment classes).
        """
        sigs = [self.query_signatures_batch(qs[:, o:])
                for o in range(offsets)]
        return jnp.stack(sigs, axis=1)

    def insert(self, series: jnp.ndarray) -> None:
        """Streaming insert (data-independent hashing ⇒ no retraining)."""
        sigs = build_signatures(series, self.fns)
        keys = band_keys(sigs, self.fns.params)
        base = int(self.signatures.shape[0])
        self.signatures = jnp.concatenate([self.signatures, sigs], axis=0)
        self.keys = jnp.concatenate([self.keys, keys], axis=0)
        if self.series is not None:
            self.series = jnp.concatenate([self.series, series], axis=0)
        if self.host_buckets is not None:
            self.host_buckets.insert(np.asarray(keys), base_id=base)
        if self.env_radius is not None and self.env_upper is not None:
            u, l = _envelopes_chunked(series, self.env_radius)
            self.env_upper = jnp.concatenate([self.env_upper, u], axis=0)
            self.env_lower = jnp.concatenate([self.env_lower, l], axis=0)


def _envelopes_chunked(series: jnp.ndarray, radius: int,
                       chunk: int = 512):
    """Database envelopes in row chunks (bounds the (chunk, m, 2r+1)
    shifted-copy intermediate of the vectorised envelope)."""
    from repro.core import lower_bounds as lb
    n = int(series.shape[0])
    ups, los = [], []
    for lo in range(0, n, chunk):
        u, l = lb.envelope(series[lo:lo + chunk], radius)
        ups.append(np.asarray(u))
        los.append(np.asarray(l))
    return (jnp.asarray(np.concatenate(ups, axis=0)),
            jnp.asarray(np.concatenate(los, axis=0)))
