"""DTW lower bounds (the branch-and-bound machinery of the UCR suite).

Paper §3: the UCR suite cascades LB_Kim (O(1)), LB_Keogh (O(m)) and
LB_Keogh2 (O(m)) before paying O(m^2) for exact DTW.  The paper's Table 1
shows these bounds collapse for long series — we reproduce that in
``benchmarks/table1_lb_pruning.py``.  On top of the UCR trio sits
Lemire's two-pass LB_Improved (arXiv 0811.3301): strictly tighter than
LB_Keogh at O(m·r) per candidate, applied to cascade *survivors* in
``repro.core.rerank`` (DESIGN.md §3).

TPU adaptation: the UCR suite applies bounds *sequentially per candidate*
with early exit.  Scalar early-exit control flow is hostile to SPMD and to
the VPU, so we compute every bound as a *batched masked op* over all
candidates and prune by boolean mask (identical pruning decisions, data
parallel execution).  All bounds here are on *squared* costs so they are
directly comparable with ``repro.core.dtw.dtw`` (squared-sum convention).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("radius",))
def envelope(x: jnp.ndarray, radius: int):
    """Upper/lower envelope of ``x`` within a Sakoe-Chiba band.

    U_i = max(x[i-r : i+r+1]),  L_i = min(x[i-r : i+r+1]).

    Implemented as a max/min over 2r+1 shifted copies — O(m·r) work but
    fully vectorised (Lemire's O(m) deque does not vectorise).  x: (..., m).
    """
    m = x.shape[-1]
    pads = [(0, 0)] * (x.ndim - 1) + [(radius, radius)]
    hi = jnp.pad(x, pads, constant_values=-jnp.inf)
    lo = jnp.pad(x, pads, constant_values=jnp.inf)
    idx = jnp.arange(m)[:, None] + jnp.arange(2 * radius + 1)[None, :]
    upper = jnp.max(hi[..., idx], axis=-1)
    lower = jnp.min(lo[..., idx], axis=-1)
    return upper, lower


@jax.jit
def lb_kim(query: jnp.ndarray, candidates: jnp.ndarray) -> jnp.ndarray:
    """LB_Kim (first/last-point variant used by the UCR suite), squared.

    query: (m,), candidates: (..., m) -> (...,)
    """
    first = (candidates[..., 0] - query[0]) ** 2
    last = (candidates[..., -1] - query[-1]) ** 2
    return first + last


@jax.jit
def lb_keogh(upper: jnp.ndarray, lower: jnp.ndarray,
             candidates: jnp.ndarray) -> jnp.ndarray:
    """LB_Keogh: distance of candidates to the query envelope, squared.

    upper/lower: (m,) envelopes of the *query*; candidates: (..., m).
    """
    above = jnp.where(candidates > upper, (candidates - upper) ** 2, 0.0)
    below = jnp.where(candidates < lower, (lower - candidates) ** 2, 0.0)
    return jnp.sum(above + below, axis=-1)


@jax.jit
def lb_keogh_env(query: jnp.ndarray, cand_upper: jnp.ndarray,
                 cand_lower: jnp.ndarray) -> jnp.ndarray:
    """LB_Keogh2 from *precomputed* candidate envelopes, squared.

    cand_upper/cand_lower: (..., m) envelopes of the candidates (e.g. the
    rows cached on ``SSHIndex`` at build time); query: (m,).  Identical
    math to ``lb_keogh2`` minus the per-call envelope computation.
    """
    above = jnp.where(query > cand_upper, (query - cand_upper) ** 2, 0.0)
    below = jnp.where(query < cand_lower, (cand_lower - query) ** 2, 0.0)
    return jnp.sum(above + below, axis=-1)


@functools.partial(jax.jit, static_argnames=("radius",))
def lb_keogh2(query: jnp.ndarray, candidates: jnp.ndarray,
              radius: int) -> jnp.ndarray:
    """LB_Keogh with roles swapped: query against *candidate* envelopes."""
    upper, lower = envelope(candidates, radius)
    return lb_keogh_env(query, upper, lower)


@functools.partial(jax.jit, static_argnames=("radius",))
def lb_improved(query: jnp.ndarray, candidates: jnp.ndarray, radius: int,
                upper: jnp.ndarray = None,
                lower: jnp.ndarray = None) -> jnp.ndarray:
    """Lemire's two-pass LB_Improved (arXiv 0811.3301), squared costs.

    Pass 1 is LB_Keogh of the candidate against the query envelope.
    Pass 2 projects the candidate onto that envelope — H = clip(c, L, U),
    Lemire's H(c, q) — and adds LB_Keogh of the *query* against the
    envelope of H.  Soundness for squared costs: for any aligned pair
    (c_i, q_j), (c_i - q_j)² >= (c_i - h_i)² + (h_i - q_j)² (h_i lies
    between c_i and q_j whenever the pass-1 term is nonzero, so the cross
    term has the right sign), and a warping path covers every i and every
    j — the two passes charge disjoint parts of every cell cost, hence
    LB_Keogh <= LB_Improved <= DTW.

    The reverse pass needs a per-candidate envelope of H, so unlike
    LB_Keogh2 it cannot be precomputed at build time — O(m·r) per
    candidate, which is why it runs *after* the cheap cascade, on
    survivors only.  ``upper``/``lower`` take a precomputed query
    envelope (shared with the pass the caller already ran).

    query (m,), candidates (..., m) -> (...,).
    """
    if upper is None:
        upper, lower = envelope(query, radius)
    pass1 = lb_keogh(upper, lower, candidates)
    h = jnp.clip(candidates, lower, upper)
    h_upper, h_lower = envelope(h, radius)
    return pass1 + lb_keogh_env(query, h_upper, h_lower)


@functools.partial(jax.jit, static_argnames=("radius",))
def lb_improved_pairs(q_rows: jnp.ndarray, c_rows: jnp.ndarray,
                      radius: int) -> jnp.ndarray:
    """Row-aligned LB_Improved: (P, m) x (P, m) -> (P,) — the flattened
    survivor-pair shape of the batched re-rank (each pair may have a
    different query, so the query envelope is per-row)."""
    return jax.vmap(lambda q, c: lb_improved(q, c, radius)
                    )(q_rows, c_rows)


@functools.partial(jax.jit, static_argnames=("radius",))
def cascade(query: jnp.ndarray, candidates: jnp.ndarray, radius: int,
            best_so_far: jnp.ndarray) -> jnp.ndarray:
    """Vectorised UCR-suite cascade. Returns the survivor mask.

    A candidate survives iff *every* lower bound is below ``best_so_far``
    (same decisions as the sequential cascade; evaluation is batched).
    """
    u, l = envelope(query, radius)
    lb1 = lb_kim(query, candidates)
    lb2 = lb_keogh(u, l, candidates)
    lb3 = lb_keogh2(query, candidates, radius)
    lb = jnp.maximum(jnp.maximum(lb1, lb2), lb3)
    return lb < best_so_far


@functools.partial(jax.jit, static_argnames=("radius",))
def cascade_staged(query: jnp.ndarray, candidates: jnp.ndarray, radius: int,
                   best_so_far: jnp.ndarray,
                   cand_upper: jnp.ndarray = None,
                   cand_lower: jnp.ndarray = None):
    """Per-bound survivor masks, cheapest bound first (Lemire two-pass
    ordering: LB_Kim O(1) → LB_Keogh O(m) → LB_Keogh2 O(m·r), the last
    collapsing to O(m) when candidate envelopes are precomputed).

    Returns ``(keep_kim, keep_keogh, keep_keogh2)`` booleans per
    candidate; the cascade survivor mask is their conjunction — identical
    decisions to ``cascade`` — while the staged masks let callers count
    which bound fired first (``repro.core.rerank.SearchStats``).
    """
    u, l = envelope(query, radius)
    lb1 = lb_kim(query, candidates)
    lb2 = lb_keogh(u, l, candidates)
    if cand_upper is None:
        cand_upper, cand_lower = envelope(candidates, radius)
    lb3 = lb_keogh_env(query, cand_upper, cand_lower)
    return (lb1 < best_so_far, lb2 < best_so_far, lb3 < best_so_far)


@functools.partial(jax.jit, static_argnames=("radius",))
def cascade_stats(query: jnp.ndarray, candidates: jnp.ndarray, radius: int,
                  best_so_far: jnp.ndarray):
    """Per-bound pruning fractions (paper Table 1 reproduction)."""
    u, l = envelope(query, radius)
    lb1 = lb_kim(query, candidates)
    lb2 = lb_keogh(u, l, candidates)
    lb3 = lb_keogh2(query, candidates, radius)
    lb4 = lb_improved(query, candidates, radius, u, l)
    n = candidates.shape[0]
    frac = lambda m: jnp.sum(m) / n  # noqa: E731
    pruned_kim = frac(lb1 >= best_so_far)
    pruned_keogh = frac(lb2 >= best_so_far)
    pruned_keogh2 = frac(lb3 >= best_so_far)
    pruned_improved = frac(lb4 >= best_so_far)
    all_lb = jnp.maximum(jnp.maximum(lb1, lb2), jnp.maximum(lb3, lb4))
    combined = frac(all_lb >= best_so_far)
    return dict(kim=pruned_kim, keogh=pruned_keogh, keogh2=pruned_keogh2,
                improved=pruned_improved, combined=combined)
