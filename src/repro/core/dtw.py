"""Dynamic Time Warping in JAX.

The paper (§2.1) uses squared-difference DTW:

    DTW(X, Y) = min_P sqrt( sum_k w_k ),   w_k = (x_i - y_j)^2 along path P

with the standard monotone/contiguous warping-path constraints and an
optional Sakoe-Chiba band of radius ``band`` (|i - j| <= band).

Implementation notes
--------------------
The textbook DP has a 2-D dependency (D[i,j] needs D[i-1,j], D[i,j-1],
D[i-1,j-1]).  We scan over *columns* of the DP matrix and resolve the
within-column dependency with the (min,+)-algebra identity:

    D[i] = c_i + min(e_i, D[i-1])            (e_i = min of the two
                                               previous-column entries)
         = C_i + min_{k<=i} (e_k - C_{k-1})  (C = inclusive cumsum of c)
         = C_i + cummin(e - shift(C, 1))

so each column update is a cumsum + cummin — fully parallel on the VPU —
and the whole DTW is a single ``lax.scan`` of length ``m_y``.  This is the
pure-jnp oracle; the TPU hot path is ``repro.kernels.dtw_wavefront``
(anti-diagonal wavefront, candidates on the lane axis).

Band masking is applied *after* the column update (on D, never inside the
cumsum) so the cumulative sums only ever contain real costs — masking with
a BIG constant inside the cumsum would cause catastrophic cancellation for
paths re-entering the band.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Large-but-finite "infinity".  float32 max is ~3.4e38; BIG must survive a
# few additions of itself without overflowing.
BIG = jnp.float32(1e30)


def znormalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-8) -> jnp.ndarray:
    """Z-normalise a time series (UCR-suite convention)."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    sd = jnp.std(x, axis=axis, keepdims=True)
    return (x - mu) / (sd + eps)


def _column_update(carry_col: jnp.ndarray, cost_col: jnp.ndarray,
                   first_col_mask: jnp.ndarray) -> jnp.ndarray:
    """One DP column via the cumsum/cummin (min,+) identity.

    carry_col: (m_x,) previous column D[:, j-1]  (BIG outside band)
    cost_col:  (m_x,) squared costs c[:, j]      (real values everywhere)
    first_col_mask: scalar bool — True when j == 0 (no left neighbour).
    """
    prev_shift = jnp.concatenate([jnp.full((1,), BIG, carry_col.dtype),
                                  carry_col[:-1]])
    # e_i = min(D[i, j-1], D[i-1, j-1]); for row 0 only the left neighbour.
    e = jnp.minimum(carry_col, prev_shift)
    # For the very first column there is no left neighbour at all:
    # D[i,0] = cumsum(c[:i,0]) — emulate with e_0 = 0, e_i>0 = BIG.
    e0 = jnp.concatenate([jnp.zeros((1,), carry_col.dtype),
                          jnp.full((carry_col.shape[0] - 1,), BIG,
                                   carry_col.dtype)])
    e = jnp.where(first_col_mask, e0, e)
    csum = jnp.cumsum(cost_col)
    shifted = jnp.concatenate([jnp.zeros((1,), csum.dtype), csum[:-1]])
    # D[i] = C_i + cummin_k<=i (e_k - C_{k-1})
    run = jax.lax.associative_scan(jnp.minimum, e - shifted)
    col = csum + run
    return jnp.minimum(col, BIG)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw(x: jnp.ndarray, y: jnp.ndarray,
        band: Optional[int] = None) -> jnp.ndarray:
    """Exact (optionally Sakoe-Chiba banded) squared-DTW cost.

    Args:
      x: (m_x,) float array.
      y: (m_y,) float array.
      band: Sakoe-Chiba radius; ``None`` = unconstrained.  For rectangular
        problems the band is measured around the scaled diagonal.

    Returns:
      scalar: min over warping paths of the summed squared differences.
      (Take ``jnp.sqrt`` for the paper's distance; ranking is identical.)
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    m_x, m_y = x.shape[0], y.shape[0]
    rows = jnp.arange(m_x)

    if band is None:
        in_band_fn = lambda j: jnp.ones((m_x,), bool)  # noqa: E731
    else:
        slope = m_x / m_y

        def in_band_fn(j):
            center = j * slope
            return jnp.abs(rows - center) <= jnp.maximum(band, 1.0 * abs(m_x - m_y) + band)

    def step(carry, j):
        cost_col = (x - y[j]) ** 2
        col = _column_update(carry, cost_col, j == 0)
        col = jnp.where(in_band_fn(j), col, BIG)
        return col, ()

    init = jnp.full((m_x,), BIG, jnp.float32)
    final_col, _ = jax.lax.scan(step, init, jnp.arange(m_y))
    return final_col[-1]


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_batch(query: jnp.ndarray, candidates: jnp.ndarray,
              band: Optional[int] = None) -> jnp.ndarray:
    """DTW of one query against a batch of candidates: (C, m) -> (C,)."""
    return jax.vmap(lambda c: dtw(query, c, band=band))(candidates)


# ---------------------------------------------------------------------------
# banded window DP (equal lengths) — the CPU analogue of the wavefront
# kernel's O(m·band) cell count, plus threshold-based early abandoning
# ---------------------------------------------------------------------------

def _banded_column(x, y_j, j, W_prev, r, m):
    """One column of the window DP: W[u] = D[j - r + u, j], u in [0, 2r+1).

    Same (min,+) cumsum/cummin identity as ``_column_update``, applied to
    the (2r+1)-wide band window instead of the full column — O(m·band)
    total cells, matching the Pallas wavefront's work bound.  Window
    algebra: D[i, j-1] sits at slot u+1 of the previous column's window,
    D[i-1, j-1] at slot u.  Out-of-matrix slots carry BIG; their (index-
    clamped) costs inside the cumsum cancel exactly because the valid
    slots of a window are contiguous (C_i - C_{k-1} only ever spans valid
    slots for a valid (k, i) pair).
    """
    w = 2 * r + 1
    u = jnp.arange(w)
    i = j - r + u                               # row index of window slot u
    cost = (x[jnp.clip(i, 0, m - 1)] - y_j) ** 2
    up_shift = jnp.concatenate([W_prev[1:],
                                jnp.full((1,), BIG, W_prev.dtype)])
    e = jnp.minimum(W_prev, up_shift)           # min(D[i-1,j-1], D[i,j-1])
    # j == 0: no left column at all; the path starts at (0, 0) = slot r
    e0 = jnp.where(u == r, 0.0, BIG)
    e = jnp.where(j == 0, e0, e)
    csum = jnp.cumsum(cost)
    shifted = jnp.concatenate([jnp.zeros((1,), csum.dtype), csum[:-1]])
    run = jax.lax.associative_scan(jnp.minimum, e - shifted)
    col = jnp.minimum(csum + run, BIG)
    return jnp.where((i >= 0) & (i < m), col, BIG)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_banded(x: jnp.ndarray, y: jnp.ndarray, band: int,
               threshold: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Equal-length banded squared-DTW via a (2r+1)-wide window DP.

    Value-equivalent to ``dtw(x, y, band=band)`` (up to float summation
    order) at O(m·band) instead of O(m²) work.  ``threshold`` enables
    early abandoning: the column minimum is a sound lower bound on the
    final cost (every monotone warping path visits every column), so the
    scan stops once it exceeds ``threshold`` and the contract becomes
    *exact value if DTW <= threshold, else BIG* — same as the
    threshold-aware Pallas wavefront.  ``None`` runs all columns and
    returns the exact value.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    m = x.shape[0]
    assert y.shape[0] == m, "dtw_banded requires equal lengths"
    r = min(band, m - 1)
    thr = jnp.float32(BIG) if threshold is None \
        else jnp.asarray(threshold, jnp.float32)

    def cond(carry):
        j, W = carry
        return (j < m) & ((j == 0) | (jnp.min(W) <= thr))

    def body(carry):
        j, W = carry
        return j + 1, _banded_column(x, y[j], j, W, r, m)

    _, W = jax.lax.while_loop(
        cond, body, (0, jnp.full((2 * r + 1,), BIG, jnp.float32)))
    out = W[r]                                  # D[m-1, m-1]
    if threshold is None:
        return out
    return jnp.where(out > thr, BIG, out)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_banded_batch(query: jnp.ndarray, candidates: jnp.ndarray, band: int,
                     threshold: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Banded window-DP DTW of one query vs a batch: (C, m) -> (C,).

    ``threshold`` broadcasts over lanes (scalar or (C,)).  Under vmap the
    while_loop runs until every lane is done or abandoned, so a block of
    hopeless candidates exits after a prefix of the columns.
    """
    if threshold is None:
        return jax.vmap(lambda c: dtw_banded(query, c, band))(candidates)
    thr = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32),
                           candidates.shape[:1])
    return jax.vmap(lambda c, t: dtw_banded(query, c, band, t)
                    )(candidates, thr)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_banded_pairs(queries: jnp.ndarray, candidates: jnp.ndarray, band: int,
                     threshold: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Row-aligned banded window-DP DTW: (P, m) x (P, m) -> (P,)."""
    if threshold is None:
        return jax.vmap(lambda q, c: dtw_banded(q, c, band)
                        )(queries, candidates)
    thr = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32),
                           candidates.shape[:1])
    return jax.vmap(lambda q, c, t: dtw_banded(q, c, band, t)
                    )(queries, candidates, thr)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_pairwise(xs: jnp.ndarray, ys: jnp.ndarray,
                 band: Optional[int] = None) -> jnp.ndarray:
    """All-pairs DTW: xs (A, m), ys (B, m) -> (A, B)."""
    return jax.vmap(lambda q: dtw_batch(q, ys, band=band))(xs)


def dtw_distance(x: jnp.ndarray, y: jnp.ndarray,
                 band: Optional[int] = None) -> jnp.ndarray:
    """Paper-convention distance: sqrt of the summed squared path cost."""
    return jnp.sqrt(dtw(x, y, band=band))


def dtw_dp_reference(x, y, band=None):
    """O(m^2) numpy-style DP, for tests only (the 'obviously correct' DTW)."""
    import numpy as np
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m_x, m_y = len(x), len(y)
    D = np.full((m_x, m_y), np.inf)
    slope = m_x / m_y
    for j in range(m_y):
        for i in range(m_x):
            if band is not None:
                width = max(band, abs(m_x - m_y) + band)
                if abs(i - j * slope) > width:
                    continue
            c = (x[i] - y[j]) ** 2
            if i == 0 and j == 0:
                D[i, j] = c
            else:
                best = np.inf
                if i > 0:
                    best = min(best, D[i - 1, j])
                if j > 0:
                    best = min(best, D[i, j - 1])
                if i > 0 and j > 0:
                    best = min(best, D[i - 1, j - 1])
                D[i, j] = c + best
    return D[-1, -1]
