"""Dynamic Time Warping in JAX.

The paper (§2.1) uses squared-difference DTW:

    DTW(X, Y) = min_P sqrt( sum_k w_k ),   w_k = (x_i - y_j)^2 along path P

with the standard monotone/contiguous warping-path constraints and an
optional Sakoe-Chiba band of radius ``band`` (|i - j| <= band).

Implementation notes
--------------------
The textbook DP has a 2-D dependency (D[i,j] needs D[i-1,j], D[i,j-1],
D[i-1,j-1]).  We scan over *columns* of the DP matrix and resolve the
within-column dependency with the (min,+)-algebra identity:

    D[i] = c_i + min(e_i, D[i-1])            (e_i = min of the two
                                               previous-column entries)
         = C_i + min_{k<=i} (e_k - C_{k-1})  (C = inclusive cumsum of c)
         = C_i + cummin(e - shift(C, 1))

so each column update is a cumsum + cummin — fully parallel on the VPU —
and the whole DTW is a single ``lax.scan`` of length ``m_y``.  This is the
pure-jnp oracle; the TPU hot path is ``repro.kernels.dtw_wavefront``
(anti-diagonal wavefront, candidates on the lane axis).

Band masking is applied *after* the column update (on D, never inside the
cumsum) so the cumulative sums only ever contain real costs — masking with
a BIG constant inside the cumsum would cause catastrophic cancellation for
paths re-entering the band.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

# Large-but-finite "infinity".  float32 max is ~3.4e38; BIG must survive a
# few additions of itself without overflowing.
BIG = jnp.float32(1e30)


def znormalize(x: jnp.ndarray, axis: int = -1, eps: float = 1e-8) -> jnp.ndarray:
    """Z-normalise a time series (UCR-suite convention)."""
    mu = jnp.mean(x, axis=axis, keepdims=True)
    sd = jnp.std(x, axis=axis, keepdims=True)
    return (x - mu) / (sd + eps)


def _column_update(carry_col: jnp.ndarray, cost_col: jnp.ndarray,
                   first_col_mask: jnp.ndarray) -> jnp.ndarray:
    """One DP column via the cumsum/cummin (min,+) identity.

    carry_col: (m_x,) previous column D[:, j-1]  (BIG outside band)
    cost_col:  (m_x,) squared costs c[:, j]      (real values everywhere)
    first_col_mask: scalar bool — True when j == 0 (no left neighbour).
    """
    prev_shift = jnp.concatenate([jnp.full((1,), BIG, carry_col.dtype),
                                  carry_col[:-1]])
    # e_i = min(D[i, j-1], D[i-1, j-1]); for row 0 only the left neighbour.
    e = jnp.minimum(carry_col, prev_shift)
    # For the very first column there is no left neighbour at all:
    # D[i,0] = cumsum(c[:i,0]) — emulate with e_0 = 0, e_i>0 = BIG.
    e0 = jnp.concatenate([jnp.zeros((1,), carry_col.dtype),
                          jnp.full((carry_col.shape[0] - 1,), BIG,
                                   carry_col.dtype)])
    e = jnp.where(first_col_mask, e0, e)
    csum = jnp.cumsum(cost_col)
    shifted = jnp.concatenate([jnp.zeros((1,), csum.dtype), csum[:-1]])
    # D[i] = C_i + cummin_k<=i (e_k - C_{k-1})
    run = jax.lax.associative_scan(jnp.minimum, e - shifted)
    col = csum + run
    return jnp.minimum(col, BIG)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw(x: jnp.ndarray, y: jnp.ndarray,
        band: Optional[int] = None) -> jnp.ndarray:
    """Exact (optionally Sakoe-Chiba banded) squared-DTW cost.

    Args:
      x: (m_x,) float array.
      y: (m_y,) float array.
      band: Sakoe-Chiba radius; ``None`` = unconstrained.  For rectangular
        problems the band is measured around the scaled diagonal.

    Returns:
      scalar: min over warping paths of the summed squared differences.
      (Take ``jnp.sqrt`` for the paper's distance; ranking is identical.)
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    m_x, m_y = x.shape[0], y.shape[0]
    rows = jnp.arange(m_x)

    if band is None:
        in_band_fn = lambda j: jnp.ones((m_x,), bool)  # noqa: E731
    else:
        slope = m_x / m_y

        def in_band_fn(j):
            center = j * slope
            return jnp.abs(rows - center) <= jnp.maximum(band, 1.0 * abs(m_x - m_y) + band)

    def step(carry, j):
        cost_col = (x - y[j]) ** 2
        col = _column_update(carry, cost_col, j == 0)
        col = jnp.where(in_band_fn(j), col, BIG)
        return col, ()

    init = jnp.full((m_x,), BIG, jnp.float32)
    final_col, _ = jax.lax.scan(step, init, jnp.arange(m_y))
    return final_col[-1]


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_batch(query: jnp.ndarray, candidates: jnp.ndarray,
              band: Optional[int] = None) -> jnp.ndarray:
    """DTW of one query against a batch of candidates: (C, m) -> (C,)."""
    return jax.vmap(lambda c: dtw(query, c, band=band))(candidates)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_pairwise(xs: jnp.ndarray, ys: jnp.ndarray,
                 band: Optional[int] = None) -> jnp.ndarray:
    """All-pairs DTW: xs (A, m), ys (B, m) -> (A, B)."""
    return jax.vmap(lambda q: dtw_batch(q, ys, band=band))(xs)


def dtw_distance(x: jnp.ndarray, y: jnp.ndarray,
                 band: Optional[int] = None) -> jnp.ndarray:
    """Paper-convention distance: sqrt of the summed squared path cost."""
    return jnp.sqrt(dtw(x, y, band=band))


def dtw_dp_reference(x, y, band=None):
    """O(m^2) numpy-style DP, for tests only (the 'obviously correct' DTW)."""
    import numpy as np
    x = np.asarray(x, np.float64)
    y = np.asarray(y, np.float64)
    m_x, m_y = len(x), len(y)
    D = np.full((m_x, m_y), np.inf)
    slope = m_x / m_y
    for j in range(m_y):
        for i in range(m_x):
            if band is not None:
                width = max(band, abs(m_x - m_y) + band)
                if abs(i - j * slope) > width:
                    continue
            c = (x[i] - y[j]) ** 2
            if i == 0 and j == 0:
                D[i, j] = c
            else:
                best = np.inf
                if i > 0:
                    best = min(best, D[i - 1, j])
                if j > 0:
                    best = min(best, D[i, j - 1])
                if i > 0 and j > 0:
                    best = min(best, D[i - 1, j - 1])
                D[i, j] = c + best
    return D[-1, -1]
