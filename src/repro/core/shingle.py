"""Step 2 of SSH — shingle (n-gram) generation over the bit-profile (§4.2).

The bit string B_X is turned into a *weighted set* S_X: every length-n
substring (shingle), with its occurrence count as weight.  Shift-invariance
of the final similarity comes from this step: a motif occurring at a
different offset contributes the same shingles.

TPU adaptation: a hash-map of n-gram counts is replaced by a dense
histogram over the full shingle space 2^n (n=15 → 32768 bins — a few KB of
VMEM per series).  Shingle ids are bit-packed with static rolling shifts;
counting is a ``segment_sum``-style scatter-add, the canonical TPU
reduction-by-key.

With a filter bank (F > 1), each filter column produces its own bit string
and histogram; the weighted set is the concatenation (size F · 2^n), which
preserves the per-filter n-gram statistics.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def shingle_space(n: int, num_filters: int = 1) -> int:
    return num_filters * (1 << n)


@functools.partial(jax.jit, static_argnames=("n",))
def pack_ngrams(bits: jnp.ndarray, n: int) -> jnp.ndarray:
    """Bit-pack all length-n windows of a bit string.

    bits: (..., N_B) uint8 in {0,1} -> ids (..., N_B - n + 1) int32,
    id_i = sum_j bits[i+j] << j.
    """
    n_b = bits.shape[-1]
    if n_b < n:
        raise ValueError(f"bit string length {n_b} < shingle length {n}")
    out = n_b - n + 1
    acc = jnp.zeros(bits.shape[:-1] + (out,), jnp.int32)
    for j in range(n):  # static unroll: n is a hyper-parameter (~15)
        acc = acc + (bits[..., j:j + out].astype(jnp.int32) << j)
    return acc


@functools.partial(jax.jit, static_argnames=("n",))
def shingle_histogram(bits: jnp.ndarray, n: int) -> jnp.ndarray:
    """Weighted set S_X as a dense histogram.

    bits: (N_B, F) uint8 -> counts (F * 2^n,) int32.
    """
    n_b, f = bits.shape
    ids = pack_ngrams(bits.T, n)                      # (F, N_B - n + 1)
    offsets = (jnp.arange(f, dtype=jnp.int32) << n)[:, None]
    flat = (ids + offsets).reshape(-1)
    counts = jnp.zeros((f << n,), jnp.int32).at[flat].add(1)
    return counts


@functools.partial(jax.jit, static_argnames=("n",))
def shingle_histogram_masked(bits: jnp.ndarray, n: int,
                             valid_rows: jnp.ndarray) -> jnp.ndarray:
    """Histogram over only the shingles fully inside the first
    ``valid_rows`` bits of each filter column.

    The fused multiprobe path evaluates every δ-offset of a query as a
    fixed-length shifted slice whose bit-profile has trailing garbage
    rows; masking the histogram down to the ``valid_rows`` real bits
    makes the result bit-identical to hashing the shorter series
    directly.  ``valid_rows`` may be traced (one program serves all
    offsets).  bits: (N_B, F) uint8 -> counts (F * 2^n,) int32.
    """
    n_b, f = bits.shape
    ids = pack_ngrams(bits.T, n)                      # (F, out)
    out = n_b - n + 1
    offsets = (jnp.arange(f, dtype=jnp.int32) << n)[:, None]
    flat = (ids + offsets).reshape(-1)
    # shingle i spans bit rows [i, i+n); valid iff i + n <= valid_rows
    valid = jnp.arange(out, dtype=jnp.int32) < (valid_rows - n + 1)
    maskf = jnp.broadcast_to(valid[None, :], (f, out)).reshape(-1)
    dim = f << n
    tgt = jnp.where(maskf, flat, dim)                 # invalid -> dump bin
    return jnp.zeros((dim + 1,), jnp.int32).at[tgt].add(1)[:dim]


@functools.partial(jax.jit, static_argnames=("n",))
def shingle_histogram_batch(bits: jnp.ndarray, n: int) -> jnp.ndarray:
    """(B, N_B, F) -> (B, F * 2^n) via one batched 2-D scatter-add.

    Batch-parallel (B stays a shardable leading axis) — the building block
    of the distributed index build.
    """
    b, n_b, f = bits.shape
    ids = pack_ngrams(bits.transpose(0, 2, 1), n)          # (B, F, out)
    offsets = (jnp.arange(f, dtype=jnp.int32) << n)[None, :, None]
    flat = (ids + offsets).reshape(b, -1)                  # (B, F*out)
    # vmapped 1-D scatter-add: the batch dim is an explicit scatter batch
    # dim, so GSPMD keeps the histogram shard-local (a flat 2-D scatter
    # with iota row indices all-reduced the full (B, 2^n) matrix — 8.6 GB
    # per build step at the paper's scale; EXPERIMENTS.md §Perf).
    dim = f << n

    def row_hist(row_ids):
        return jnp.zeros((dim,), jnp.int32).at[row_ids].add(1)

    return jax.vmap(row_hist)(flat)


def weighted_jaccard(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Generalised weighted Jaccard J(a,b) = Σ min / Σ max (paper eq. 2)."""
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    num = jnp.sum(jnp.minimum(a, b), axis=-1)
    den = jnp.sum(jnp.maximum(a, b), axis=-1)
    return jnp.where(den > 0, num / den, 0.0)
