"""Step 3 of SSH — weighted minwise hashing of the shingle set (§4.3).

We implement Ioffe's Consistent Weighted Sampling (ICDM'10) and use its
0-bit variant (Li, KDD'15 — the paper's reference [29]): the hash value is
just the argmin element index, which empirically collides with probability
≈ the weighted Jaccard similarity and removes the (index, t) pair bookkeeping.

The CWS random fields r, c ~ Gamma(2,1), β ~ U(0,1) are precomputed per
hash function over the full shingle space (K, D) — data *independent*, so
the index supports streaming inserts and distribution drift (the paper's
core argument vs learned hashing).

Collision property (paper eq. 3):  Pr[h(x) = h(y)] = J_w(x, y).
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class CWSParams(NamedTuple):
    """Random fields for K independent CWS hashes over a D-dim space."""
    log_r: jnp.ndarray   # (K, D) — actually r itself; see make_cws
    r: jnp.ndarray       # (K, D) Gamma(2,1)
    log_c: jnp.ndarray   # (K, D) log of Gamma(2,1)
    beta: jnp.ndarray    # (K, D) U(0,1)

    @property
    def num_hashes(self) -> int:
        return self.r.shape[0]

    @property
    def dim(self) -> int:
        return self.r.shape[1]


def make_cws(key: jax.Array, num_hashes: int, dim: int) -> CWSParams:
    """Sample the CWS random fields.  Gamma(2,1) = -log(u1) - log(u2)."""
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    shape = (num_hashes, dim)
    u1 = jax.random.uniform(k1, shape, jnp.float32, 1e-12, 1.0)
    u2 = jax.random.uniform(k2, shape, jnp.float32, 1e-12, 1.0)
    r = -jnp.log(u1) - jnp.log(u2)
    v1 = jax.random.uniform(k3, shape, jnp.float32, 1e-12, 1.0)
    v2 = jax.random.uniform(k4, shape, jnp.float32, 1e-12, 1.0)
    c = -jnp.log(v1) - jnp.log(v2)
    beta = jax.random.uniform(k5, shape, jnp.float32)
    return CWSParams(log_r=jnp.log(r), r=r, log_c=jnp.log(c), beta=beta)


@jax.jit
def cws_hash(weights: jnp.ndarray, params: CWSParams) -> jnp.ndarray:
    """0-bit CWS signature of one weighted set.

    weights: (D,) non-negative -> (K,) int32 argmin indices.

    ln a_i = ln c_i - r_i (t_i - β_i) - r_i,
    t_i = floor(ln w_i / r_i + β_i);  elements with w_i = 0 are excluded.
    """
    w = weights.astype(jnp.float32)
    logw = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), 0.0)
    t = jnp.floor(logw[None, :] / params.r + params.beta)       # (K, D)
    ln_a = params.log_c - params.r * (t - params.beta) - params.r
    ln_a = jnp.where(w[None, :] > 0, ln_a, jnp.inf)
    return jnp.argmin(ln_a, axis=1).astype(jnp.int32)


@jax.jit
def cws_hash_dense_batch(weights: jnp.ndarray, params: CWSParams
                         ) -> jnp.ndarray:
    """Batch-parallel 0-bit CWS: (B, D) -> (B, K).

    Scans over the K hash functions (K is small, ~20-64) so the *batch*
    axis stays a single shardable dimension — unlike a per-series map,
    which lowers to a while loop XLA cannot partition (this was the 255×
    replication found in the ssh build_2048 baseline; EXPERIMENTS.md
    §Perf).  Peak temp per scan step is one (B, D) f32 tile.
    """
    w = weights.astype(jnp.float32)
    logw = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), 0.0)
    active = w > 0

    def one_hash(_, fields):
        r, log_c, beta = fields
        t = jnp.floor(logw / r[None, :] + beta[None, :])
        ln_a = log_c[None, :] - r[None, :] * (t - beta[None, :]) - r[None, :]
        ln_a = jnp.where(active, ln_a, jnp.inf)
        return _, jnp.argmin(ln_a, axis=1).astype(jnp.int32)

    _, sigs = jax.lax.scan(one_hash, None,
                           (params.r, params.log_c, params.beta))
    return sigs.T                                    # (B, K)


@functools.partial(jax.jit, static_argnames=("chunk",))
def cws_hash_batch(weights: jnp.ndarray, params: CWSParams,
                   chunk: int = 64) -> jnp.ndarray:
    """(B, D) -> (B, K), evaluated in chunks to bound the (chunk, K, D) temp."""
    b = weights.shape[0]
    pad = (-b) % chunk
    wpad = jnp.pad(weights, ((0, pad), (0, 0)))
    blocks = wpad.reshape(-1, chunk, weights.shape[1])

    def body(blk):
        return jax.vmap(lambda w: cws_hash(w, params))(blk)

    sigs = jax.lax.map(body, blocks).reshape(-1, params.num_hashes)
    return sigs[:b]


def combine_bands(signatures: jnp.ndarray, num_tables: int) -> jnp.ndarray:
    """Group K hashes into L bands and mix each band into one bucket key.

    signatures: (..., K) int32, K = L * rows  ->  (..., L) uint32.
    Polynomial rolling hash per band (Carter–Wegman style).
    """
    k = signatures.shape[-1]
    if k % num_tables:
        raise ValueError(f"K={k} not divisible by L={num_tables}")
    rows = k // num_tables
    bands = signatures.reshape(signatures.shape[:-1] + (num_tables, rows))
    bands = bands.astype(jnp.uint32)
    mult = jnp.uint32(0x9E3779B1)  # golden-ratio odd multiplier
    acc = jnp.zeros(bands.shape[:-1], jnp.uint32)
    for i in range(rows):  # static: rows is a config constant
        acc = (acc * mult) ^ (bands[..., i] + jnp.uint32(0x85EBCA6B))
        acc = acc ^ (acc >> 15)
    return acc


def collision_probability_estimate(sig_a: jnp.ndarray, sig_b: jnp.ndarray
                                   ) -> jnp.ndarray:
    """Fraction of agreeing hashes — unbiased estimator of weighted Jaccard."""
    return jnp.mean((sig_a == sig_b).astype(jnp.float32), axis=-1)


# --------------------------------------------------------------------------
# b-bit signature packing (Li & König — the paper's ref [30]) — beyond-paper
# storage/bandwidth optimisation: keep only the low b bits of each CWS hash,
# 32/b hashes per int32 word.  Collision probability becomes
# J + (1 - J)/2^b; ranking by agreement count is preserved (the +1/2^b
# offset is rank-monotone), while index bytes shrink 32/b-fold — the probe
# is HBM-bound (see §Roofline), so bandwidth is the win.
# --------------------------------------------------------------------------

def pack_signatures(signatures: jnp.ndarray, bits: int = 8) -> jnp.ndarray:
    """(..., K) int32 -> (..., K*bits/32) int32 packed b-bit sketches."""
    if 32 % bits:
        raise ValueError("bits must divide 32")
    per_word = 32 // bits
    k = signatures.shape[-1]
    if k % per_word:
        raise ValueError(f"K={k} not divisible by {per_word} hashes/word")
    mask = jnp.int32((1 << bits) - 1)
    s = (signatures & mask).reshape(signatures.shape[:-1]
                                    + (k // per_word, per_word))
    shifts = (jnp.arange(per_word, dtype=jnp.int32) * bits)
    return jnp.sum(s << shifts, axis=-1).astype(jnp.int32)


def packed_collisions(query_packed: jnp.ndarray, db_packed: jnp.ndarray,
                      bits: int = 8) -> jnp.ndarray:
    """Agreement counts over b-bit lanes. query (W,), db (N, W) -> (N,)."""
    per_word = 32 // bits
    mask = jnp.int32((1 << bits) - 1)
    x = jnp.bitwise_xor(db_packed, query_packed[None, :])     # (N, W)
    total = jnp.zeros(db_packed.shape[:-1], jnp.int32)
    for i in range(per_word):                                 # static unroll
        lane = (x >> (i * bits)) & mask
        total = total + jnp.sum((lane == 0).astype(jnp.int32), axis=-1)
    return total
