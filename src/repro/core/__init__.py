"""SSH (Sketch, Shingle, & Hash) — the paper's core contribution.

Public API:
  SSHParams, SSHFunctions, SSHIndex   — index construction
  ssh_search / ucr_search / srp_search / brute_force_topk — query paths
  dtw, dtw_batch, znormalize          — similarity measure
"""
from repro.core.dtw import (dtw, dtw_batch, dtw_pairwise, dtw_distance,
                            znormalize)
from repro.core.index import (SSHParams, SSHFunctions, SSHIndex,
                              build_signatures, band_keys,
                              signature_collisions, probe_topc,
                              signature_collisions_batch, probe_topc_batch)
# NOTE: the rerank *functions* stay namespaced (repro.core.rerank.rerank)
# so the submodule attribute isn't shadowed by a same-named function.
from repro.core.rerank import SearchStats
from repro.core.search import (SearchResult, hash_probe, ssh_search,
                               ucr_search, srp_search, brute_force_topk,
                               precision_at_k, ndcg_at_k)

__all__ = [
    "dtw", "dtw_batch", "dtw_pairwise", "dtw_distance", "znormalize",
    "SSHParams", "SSHFunctions", "SSHIndex", "build_signatures",
    "band_keys", "signature_collisions", "probe_topc",
    "signature_collisions_batch", "probe_topc_batch",
    "SearchStats",
    "SearchResult", "hash_probe", "ssh_search", "ucr_search", "srp_search",
    "brute_force_topk", "precision_at_k", "ndcg_at_k",
]
