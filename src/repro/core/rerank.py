"""Unified DTW re-rank pipeline (DESIGN.md §3) — the hot path of Alg. 2.

Every query path that turns hash candidates into a top-k — sequential
``core.search.ssh_search``, batched ``serving.batched.ssh_search_batch``,
and the shard-local re-rank of ``distributed.dist_index`` — funnels
through this module, so the three stay decision-identical by
construction:

  1. **Seed DTW** over the first ``topk`` hash hits gives a per-query
     best-so-far (Lemire's two-pass idea: one cheap DTW pass buys a tight
     pruning threshold for the bound pass).
  2. **LB cascade** (``lower_bounds.cascade_staged``), cheapest bound
     first — LB_Kim O(1) → LB_Keogh O(m) → LB_Keogh2, the last fed by
     the candidate envelopes precomputed on ``SSHIndex`` when available
     (gather+compare instead of an O(m·r) envelope per query).  The
     cascade statically thins the top-C block to a survivor block; which
     bound fired first is counted into ``SearchStats``.
  3. **Banded DTW** over the survivors through one backend knob
     (``backend="pallas" | "jnp" | "auto"``, the same tri-state as the
     collision-count kernel): the Pallas anti-diagonal wavefront
     (``kernels.dtw_wavefront``) on TPU — lane-axis padding and the
     transposed/time-reversed layout live in the kernel wrapper — and the
     ``dtw_batch`` scan oracle on CPU.  Band-bounded DP replaces the UCR
     suite's data-dependent early abandoning (PrunedDTW line): the work
     bound is static, which is what lets the survivor block run as one
     dependence-free vector program.

Equality contract: for the same inputs the "jnp" and "pallas" backends
return identical top-k ids (the kernels are tested value-equal to the
oracle), and the batched entry point returns per-query results identical
to the sequential one — ``tests/test_rerank.py`` holds both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.timing import DISABLED, StageTimer
from repro.core import lower_bounds as lb
from repro.core.index import SSHIndex
from repro.kernels import ops

BIG = np.float32(1e30)

PAIR_CHUNK = 256        # survivor pairs per DTW dispatch (lane stability)
PAIR_CHUNK_SMALL = 32   # remainder granularity (bounds padding waste)


@dataclasses.dataclass
class SearchStats:
    """Re-rank pruning counters (paper Tables 1/4 instrumentation).

    The stage counters attribute each pruned candidate to the *first*
    bound that fired (cascade order: Kim → Keogh → Keogh2 → Improved),
    with the seeded candidates — which are exempt from pruning — never
    counted, so ``n_in == pruned_kim + pruned_keogh + pruned_keogh2 +
    pruned_improved + n_dtw``.  ``dtw_abandoned`` counts the survivors
    the threshold-aware DTW stage then abandoned mid-kernel (early-
    abandoning PrunedDTW) — a subset of ``n_dtw``, since those lanes
    still entered the DTW stage but stopped before the final diagonal.

    ``stage_seconds`` holds the per-stage wall clock of the whole query
    (``repro.bench.timing.STAGES``: encode → probe → lb → lb_improved →
    dtw, device-synchronized at each boundary; the ``lb`` stage includes
    the seed DTW that buys the pruning threshold).  ``None`` when
    telemetry was off (``SearchConfig(stage_timings=False)``); the
    distributed fan-out reports its unsplittable shard_map program under
    the single ``"fused"`` key instead.
    """
    n_in: int = 0            # candidates entering the re-rank stage
    pruned_kim: int = 0      # first pruned by LB_Kim
    pruned_keogh: int = 0    # survived Kim, pruned by LB_Keogh
    pruned_keogh2: int = 0   # survived both, pruned by LB_Keogh2
    pruned_improved: int = 0  # survived the trio, pruned by LB_Improved
    forced_kept: int = 0     # seeds kept despite a bound firing
    n_dtw: int = 0           # survivors that entered the DTW stage
    dtw_abandoned: int = 0   # of those, abandoned over the threshold
    backend: str = "jnp"     # resolved DTW backend ("pallas" | "jnp")
    stage_seconds: Optional[Dict[str, float]] = None
    # resident bytes of the index that served this query (artifacts +
    # encoder state — ``SSHIndex.nbytes``); makes the sketch-vs-exact
    # memory claim machine-readable next to the latency it bought
    index_bytes: Optional[int] = None
    # queries in this search whose encode was served from the signature
    # LRU (repro.encoders.sigcache) — 0/1 sequentially, up to B batched
    sig_cache_hit: int = 0
    # fleet resilience counters (repro.fleet; 0/False outside it):
    # shard calls re-issued to a replica on a lapsed hedging deadline,
    # shard calls re-issued after a worker fault, and whether any shard
    # of this search was answered by a non-primary replica — results
    # are bit-identical either way, the flags only mark that the fleet
    # was coping
    hedged: int = 0
    failovers: int = 0
    degraded: bool = False
    # sliding windows probed when the query ran against a subsequence
    # index (repro.subseq); 0 for whole-series search.  Subsequence
    # stats also carry the extra "encode_amortized" stage key: the
    # build-side rolling encode seconds divided over the indexed
    # windows — the per-window cost this query's probe amortises
    n_windows: int = 0

    @property
    def lb_pruned(self) -> int:
        return (self.pruned_kim + self.pruned_keogh + self.pruned_keogh2
                + self.pruned_improved)

    @property
    def lb_pruned_frac(self) -> float:
        return self.lb_pruned / self.n_in if self.n_in else 0.0

    @property
    def dtw_abandoned_frac(self) -> float:
        """Fraction of DTW-stage lanes abandoned over the threshold."""
        return self.dtw_abandoned / self.n_dtw if self.n_dtw else 0.0

    @property
    def stage_us(self) -> Optional[Dict[str, float]]:
        """``stage_seconds`` in microseconds (None when telemetry off)."""
        if self.stage_seconds is None:
            return None
        return {k: v * 1e6 for k, v in self.stage_seconds.items()}


# ---------------------------------------------------------------------------
# backend-dispatched DTW primitives
# ---------------------------------------------------------------------------

def dtw_candidates(query: jnp.ndarray, candidates: jnp.ndarray,
                   band: Optional[int], backend: str = "auto",
                   threshold=None) -> jnp.ndarray:
    """One query vs a candidate block, (m,) x (C, m) -> (C,).

    ``threshold`` (scalar) enables the early-abandon contract: lanes
    whose exact cost exceeds it return BIG instead (see ``kernels.ops``).
    """
    return ops.dtw_rerank(query, candidates, band,
                          use_pallas=ops.resolve_backend(backend),
                          threshold=threshold)


def dtw_pairs_chunked(q_rows: jnp.ndarray, c_rows: jnp.ndarray,
                      band: Optional[int], backend: str = "auto",
                      threshold=None) -> np.ndarray:
    """Row-aligned pair DTW in fixed-shape chunks: (P, m) x (P, m) -> (P,).

    Full PAIR_CHUNK blocks first, then the remainder at PAIR_CHUNK_SMALL
    granularity — two compiled programs serve every batch size and
    survivor count, the working set per dispatch stays cache-sized, and
    padding waste is bounded by PAIR_CHUNK_SMALL - 1 evaluations.

    ``threshold`` (scalar or (P,)) applies the per-lane early-abandon
    contract; padding lanes repeat row 0's threshold so they abandon with
    it instead of holding a chunk alive.
    """
    use_pallas = ops.resolve_backend(backend)
    p = int(q_rows.shape[0])
    pad = (-p) % PAIR_CHUNK_SMALL
    # padding is host-side numpy: P is data-dependent, and a device
    # concat on a fresh (P, m) shape compiles per distinct P (see the
    # union-table comment in rerank_batch) — only the fixed-shape
    # chunks below may touch the device
    q_rows = np.asarray(q_rows)
    c_rows = np.asarray(c_rows)
    thr = None
    if threshold is not None:
        thr = np.broadcast_to(
            np.asarray(threshold, np.float32).reshape(-1), (p,))
    if pad:
        q_rows = np.concatenate([q_rows, q_rows[:1].repeat(pad, 0)], 0)
        c_rows = np.concatenate([c_rows, c_rows[:1].repeat(pad, 0)], 0)
        if thr is not None:
            thr = np.concatenate([thr, thr[:1].repeat(pad, 0)], 0)
    out, i, total = [], 0, p + pad
    for chunk in (PAIR_CHUNK, PAIR_CHUNK_SMALL):
        while total - i >= chunk:
            out.append(np.asarray(ops.dtw_rerank_pairs(
                q_rows[i:i + chunk], c_rows[i:i + chunk], band,
                use_pallas=use_pallas,
                threshold=None if thr is None else thr[i:i + chunk])))
            i += chunk
    return np.concatenate(out)[:p]


def lb_improved_pairs_chunked(q_rows: jnp.ndarray, c_rows: jnp.ndarray,
                              band: int) -> np.ndarray:
    """Row-aligned LB_Improved in the same fixed-shape chunks as
    ``dtw_pairs_chunked``: (P, m) x (P, m) -> (P,).

    The survivor-pair count P is data-dependent, so jitting on the raw
    (P, m) shape compiles a fresh executable for nearly every live batch
    — under real traffic (distinct queries per batch) the re-rank spends
    ~10x its compute in XLA compilation, invisible to the device-synced
    stage timers.  Chunking caps the shape set at two programs.  The
    bound is lane-independent, so padding lanes (row 0 repeated) never
    change the first P values.
    """
    p = int(q_rows.shape[0])
    if not p:
        return np.zeros(0, np.float32)
    pad = (-p) % PAIR_CHUNK_SMALL
    q_rows = np.asarray(q_rows)        # host-side pad: see dtw_pairs_chunked
    c_rows = np.asarray(c_rows)
    if pad:
        q_rows = np.concatenate([q_rows, q_rows[:1].repeat(pad, 0)], 0)
        c_rows = np.concatenate([c_rows, c_rows[:1].repeat(pad, 0)], 0)
    out, i, total = [], 0, p + pad
    for chunk in (PAIR_CHUNK, PAIR_CHUNK_SMALL):
        while total - i >= chunk:
            out.append(np.asarray(lb.lb_improved_pairs(
                q_rows[i:i + chunk], c_rows[i:i + chunk], band)))
            i += chunk
    return np.concatenate(out)[:p]


# ---------------------------------------------------------------------------
# cascade thinning
# ---------------------------------------------------------------------------

def _staged_keep(query: jnp.ndarray, cands: jnp.ndarray, band: int,
                 best: jnp.ndarray,
                 cand_env: Optional[Tuple[jnp.ndarray, jnp.ndarray]]):
    """(keep1, keep2, keep3) numpy masks for one query's candidate block."""
    if cand_env is not None:
        k1, k2, k3 = lb.cascade_staged(query, cands, band, best,
                                       cand_env[0], cand_env[1])
    else:
        k1, k2, k3 = lb.cascade_staged(query, cands, band, best)
    return np.asarray(k1), np.asarray(k2), np.asarray(k3)


def _count_stages(k1: np.ndarray, k2: np.ndarray, k3: np.ndarray,
                  forced: np.ndarray) -> Tuple[np.ndarray, int, int, int,
                                               int]:
    """Survivor mask + first-bound-fired counters, seeds exempt.

    ``forced`` rows are kept regardless, and excluded from the stage
    counters, so counters partition the non-forced candidates exactly.
    """
    k1f, k2f, k3f = k1 | forced, k2 | forced, k3 | forced
    keep = k1f & k2f & k3f
    pruned_kim = int(np.sum(~k1f))
    pruned_keogh = int(np.sum(k1f & ~k2f))
    pruned_keogh2 = int(np.sum(k1f & k2f & ~k3f))
    forced_kept = int(np.sum(forced & ~(k1 & k2 & k3)))
    return keep, pruned_kim, pruned_keogh, pruned_keogh2, forced_kept


def _gathered_env(index: SSHIndex, ids, band: int):
    """Candidate envelope rows when the index has them cached at ``band``
    (build-time precompute); None otherwise (computed per block)."""
    if index.env_radius == band and index.env_upper is not None \
            and int(index.env_upper.shape[0]) == int(index.series.shape[0]):
        gid = jnp.asarray(ids)
        return index.env_upper[gid], index.env_lower[gid]
    return None


# ---------------------------------------------------------------------------
# sequential entry point (used by core.search.ssh_search)
# ---------------------------------------------------------------------------

def rerank(query: jnp.ndarray, cand_ids: jnp.ndarray, index: SSHIndex,
           topk: int, band: Optional[int], *, use_lb_cascade: bool = True,
           backend: str = "auto", seed_size: Optional[int] = None,
           early_abandon: bool = True, timer: StageTimer = DISABLED):
    """Candidate ids -> (global ids, dists, stats), best first.

    Stage 2+3 of Alg. 2 for one query: seed DTW → LB cascade →
    LB_Improved over the survivors → threshold-aware survivor DTW, every
    DTW through the ``backend`` knob.  ``seed_size`` widens the seeded
    set beyond ``topk`` (``None`` — the default — seeds exactly
    ``topk``): the threshold becomes the topk-th best of a larger
    sample, i.e. tighter, buying more pruning for more up-front DTW.
    ``early_abandon`` threads that same threshold into the final DTW as
    well (lanes provably over it stop early and report BIG).  Top-k
    results are unchanged by any of these knobs — the threshold is
    always a valid upper bound on the final k-th distance, so a pruned
    or abandoned candidate can never belong to the answer set.

    An enabled ``timer`` (shared with ``hash_probe`` so one dict carries
    all five stages) records seed DTW + cascade as ``lb``, the survivor
    LB_Improved pass as ``lb_improved``, and the survivor DTW + top-k as
    ``dtw``; the accumulated timings are published on
    ``stats.stage_seconds``.
    """
    backend_used = ops.backend_name(ops.resolve_backend(backend))
    cands = index.series[cand_ids]
    n_hash = int(cand_ids.shape[0])
    stats = SearchStats(n_in=n_hash, backend=backend_used)
    thr = None

    if use_lb_cascade and band is not None and n_hash > topk:
        with timer.stage("lb") as sync:
            # best-so-far: topk-th best DTW over the seeded best-hash
            # hits.  The seed is clamped to >= topk (validate() also
            # enforces it): a smaller seed would make the threshold an
            # upper bound on a better-than-kth distance, unsoundly
            # pruning true answers.
            s = min(max(seed_size or 0, topk), n_hash)
            seed = dtw_candidates(query, cands[:s], band, backend)
            best = jnp.sort(seed)[min(topk, s) - 1]
            env = _gathered_env(index, cand_ids, band)
            k1, k2, k3 = _staged_keep(query, cands, band, best, env)
            forced = np.zeros(n_hash, bool)
            forced[:s] = True                 # never drop the seeded set
            keep, p1, p2, p3, fk = _count_stages(k1, k2, k3, forced)
            stats.pruned_kim, stats.pruned_keogh, stats.pruned_keogh2 = \
                p1, p2, p3
            stats.forced_kept = fk
            keep_j = jnp.asarray(keep)
            cand_ids = sync(cand_ids[keep_j])
            cands = sync(cands[keep_j])
        with timer.stage("lb_improved") as sync:
            # Lemire's two-pass bound over cascade survivors only: it
            # needs the candidate-side envelope, which cannot be cached
            # at build time (it depends on the query via the clipped
            # series H), so the O(m·r) pass is paid after the cheap
            # bounds have thinned the block.
            lbi = np.asarray(sync(lb.lb_improved(query, cands, band)))
            forced_surv = forced[keep]
            pass123_surv = (k1 & k2 & k3)[keep]
            keep2 = (lbi < np.float32(best)) | forced_surv
            stats.pruned_improved = int(np.sum(~keep2))
            stats.forced_kept += int(np.sum(
                forced_surv & pass123_surv & (lbi >= np.float32(best))))
            keep2_j = jnp.asarray(keep2)
            cand_ids = sync(cand_ids[keep2_j])
            cands = sync(cands[keep2_j])
        if early_abandon:
            thr = best
    stats.n_dtw = int(cands.shape[0])

    with timer.stage("dtw") as sync:
        d = dtw_candidates(query, cands, band, backend, threshold=thr)
        k = min(topk, int(cands.shape[0]))
        vals, idx = jax.lax.top_k(-d, k)
        ids = np.asarray(cand_ids)[np.asarray(idx)]
        dists = np.asarray(-sync(vals))
    if thr is not None:
        stats.dtw_abandoned = int(np.sum(np.asarray(d) >= BIG * 0.5))
    if timer.enabled:
        stats.stage_seconds = dict(timer.timings)
    return ids, dists, stats


# ---------------------------------------------------------------------------
# batched entry point (used by serving.batched.ssh_search_batch)
# ---------------------------------------------------------------------------

def rerank_batch(queries: jnp.ndarray, ids: np.ndarray, valid: np.ndarray,
                 index: SSHIndex, topk: int, band: Optional[int], *,
                 use_lb_cascade: bool = True, backend: str = "auto",
                 seed_size: Optional[int] = None,
                 early_abandon: bool = True,
                 timer: StageTimer = DISABLED):
    """Batched stage 2+3 over per-query candidate blocks.

    queries (B, m); ids (B, C) int candidate ids; valid (B, C) bool.
    Returns (out_ids (B, k), out_d (B, k), n_final (B,), n_union, stats);
    filler rows (fewer survivors than topk) carry id -1 / dist BIG.

    Per-query decisions identical to ``rerank``: the same seed best-so-far
    feeds the same cascade and the same survivor LB_Improved pass,
    survivors are re-ranked with the same DTW values (pair DTW is
    lane-independent, hence bit-equal to the single-query block DTW), and
    the final ``lax.top_k`` applies the same tie-breaking.  The survivor
    (query, candidate) pairs are flattened through the deduped union
    candidate table and re-ranked in fixed-size chunks — total DTW work
    is exactly the batch's survivor count.  With ``early_abandon`` each
    pair lane carries its row's seed threshold into the DTW; rows whose
    cascade never applied (``n_hash <= topk`` — all pairs forced) get
    +inf so their fully-forced block is never masked.
    """
    backend_used = ops.backend_name(ops.resolve_backend(backend))
    b, c = ids.shape
    n_hash = valid.sum(axis=1)                            # (B,)
    stats = SearchStats(n_in=int(valid.sum()), backend=backend_used)
    k_out = min(topk, c)
    # seed clamped to >= topk for a sound threshold (see rerank())
    seed_k = min(max(seed_size or 0, topk), c)
    cascade_on = use_lb_cascade and band is not None
    thr_rows = None                                       # (B,) or None

    if cascade_on:
        with timer.stage("lb"):
            seed_series = index.series[jnp.asarray(ids[:, :seed_k])]
            seed_d = np.asarray(_seed_dtw_backend(queries, seed_series,
                                                  band, backend))
            if seed_size is not None:
                # a widened seed may overrun a row's valid candidates
                # (only possible when seed_k > topk); mask those slots so
                # the threshold matches the sequential
                # min(seed_size, n_hash)
                col = np.arange(seed_k)[None, :]
                seed_d = np.where(col < n_hash[:, None], seed_d, np.inf)
                kth = np.sort(seed_d, axis=1)[:, min(topk, seed_k) - 1]
                best = jnp.asarray(kth.astype(np.float32))
            else:
                best = jnp.asarray(seed_d.max(axis=1))    # per-query kth-best
            cand_series = index.series[jnp.asarray(ids)]  # (B, C, m)
            env = _gathered_env(index, ids, band)
            if env is not None:
                k1, k2, k3 = _cascade_rows_env(queries, cand_series, band,
                                               best, env[0], env[1])
            else:
                k1, k2, k3 = _cascade_rows(queries, cand_series, band, best)
            k1, k2, k3 = np.asarray(k1), np.asarray(k2), np.asarray(k3)
            # sequential skips the cascade entirely when n_hash <= topk,
            # and never drops the seeded set; the first seed_k slots ARE
            # the first seed_k valid candidates whenever the cascade
            # applies (top_k sorts positive counts first)
            forced = np.zeros((b, c), bool)
            forced[:, :seed_k] = True
            forced[n_hash <= topk] = True
            # stage counters only over valid candidates that entered the
            # cascade (invalid slots never reach DTW; forced exempt)
            enter = valid & ~forced
            stats.pruned_kim = int(np.sum(enter & ~k1))
            stats.pruned_keogh = int(np.sum(enter & k1 & ~k2))
            stats.pruned_keogh2 = int(np.sum(enter & k1 & k2 & ~k3))
            stats.forced_kept = int(np.sum(valid & forced
                                           & ~(k1 & k2 & k3)))
            ok = valid & (forced | (k1 & k2 & k3))
            # per-row prune/abandon threshold; rows where the sequential
            # path skips the cascade (n_hash <= topk) are fully forced
            # and their seed kth may not upper-bound anything — +inf
            # exempts them from both LB_Improved and early abandoning
            thr_rows = np.where(np.asarray(n_hash) > topk,
                                np.asarray(best, np.float32),
                                np.float32(np.inf)).astype(np.float32)
    else:
        ok = valid

    # flattened survivor pairs, through the deduped union table (built
    # here so the LB_Improved pass and the DTW reuse one gather).  All
    # pair bookkeeping stays host-side numpy: the pair count P is data-
    # dependent, and every eager device op on a fresh (P, m) shape — a
    # gather, a boolean mask, a pad concat — compiles its own tiny
    # executable.  One compile is cheap; under live traffic (new P every
    # batch) they dominate the re-rank wall clock.  Devices only see the
    # fixed-shape chunks inside the LB/DTW dispatchers.
    rows_idx, cols_idx = np.nonzero(ok)                   # (P,) row-major
    pair_ids = ids[rows_idx, cols_idx]
    union = np.unique(pair_ids)                           # (U,) sorted
    series_np = np.asarray(index.series)   # zero-copy view on CPU jax
    union_series = series_np[union]                       # (U, m)
    pos = np.searchsorted(union, pair_ids)
    c_rows = union_series[pos]                            # (P, m)
    q_rows = np.asarray(queries)[rows_idx]                # (P, m)

    if cascade_on:
        with timer.stage("lb_improved") as sync:
            # survivor-only two-pass bound, same values as sequential
            # (per-row vmap of the identical elementwise program)
            lbi = sync(lb_improved_pairs_chunked(q_rows, c_rows, band))
            forced_pair = forced[rows_idx, cols_idx]
            pass123_pair = (k1 & k2 & k3)[rows_idx, cols_idx]
            thr_pair = thr_rows[rows_idx]
            keep_pair = (lbi < thr_pair) | forced_pair
            stats.pruned_improved = int(np.sum(~keep_pair))
            stats.forced_kept += int(np.sum(
                forced_pair & pass123_pair & ~(lbi < thr_pair)))
            ok[rows_idx[~keep_pair], cols_idx[~keep_pair]] = False
            rows_idx = rows_idx[keep_pair]
            cols_idx = cols_idx[keep_pair]
            q_rows = q_rows[keep_pair]
            c_rows = c_rows[keep_pair]
    n_final = ok.sum(axis=1)                              # (B,)

    with timer.stage("dtw") as sync:
        thr_pairs = (thr_rows[rows_idx]
                     if (cascade_on and early_abandon) else None)
        pair_d = dtw_pairs_chunked(q_rows, c_rows, band, backend,
                                   threshold=thr_pairs)   # (P,)
        stats.n_dtw = int(pair_d.shape[0])
        if thr_pairs is not None:
            stats.dtw_abandoned = int(np.sum(pair_d >= BIG * 0.5))

        # per-query top-k (lax.top_k for sequential-identical tie-breaks)
        cand_d = np.full((b, c), BIG, np.float32)         # candidate order
        cand_d[rows_idx, cols_idx] = pair_d
        neg, idx = jax.lax.top_k(-jnp.asarray(cand_d), k_out)
        idx = np.asarray(idx)
        out_ids = np.take_along_axis(ids, idx, axis=1)
        out_d = -np.asarray(sync(neg))
    # rows with fewer than k_out survivors: mark the filler tail (fixed
    # output shapes; callers trim these, matching sequential lengths)
    out_ids = np.where(out_d < BIG * 0.5, out_ids, -1)
    if timer.enabled:
        stats.stage_seconds = dict(timer.timings)
    return (out_ids.astype(np.int64), out_d.astype(np.float32),
            n_final.astype(np.int64), int(union.shape[0]), stats)


def _seed_dtw_backend(queries: jnp.ndarray, seed_series: jnp.ndarray,
                      band: Optional[int], backend: str) -> jnp.ndarray:
    """(B, m) x (B, s, m) -> (B, s) per-query seed DTW, via pair rows so
    the values are bit-identical to the flattened survivor-pair path."""
    b, s, m = seed_series.shape
    q_rows = jnp.repeat(queries, s, axis=0)               # (B·s, m)
    c_rows = seed_series.reshape(b * s, m)
    d = dtw_pairs_chunked(q_rows, c_rows, band, backend)
    return jnp.asarray(d.reshape(b, s))


def _cascade_rows(queries, cand_series, band, best):
    """vmap'd staged cascade: (B, m) x (B, C, m) -> three (B, C) masks."""
    fn = jax.vmap(lambda q, cs, b_: lb.cascade_staged(q, cs, band, b_))
    return fn(queries, cand_series, best)


def _cascade_rows_env(queries, cand_series, band, best, env_u, env_l):
    fn = jax.vmap(lambda q, cs, b_, u, l:
                  lb.cascade_staged(q, cs, band, b_, u, l))
    return fn(queries, cand_series, best, env_u, env_l)
