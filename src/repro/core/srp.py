"""Signed Random Projection baseline (paper §5.2).

The paper's sanity-check baseline: treat the whole series as one long
vector and hash with K signed random projections (cosine-similarity LSH).
SRP has no alignment mechanism, so it fails on warped series — reproduced
in ``benchmarks/table2_precision.py``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def make_srp(key: jax.Array, num_hashes: int, dim: int) -> jnp.ndarray:
    return jax.random.normal(key, (dim, num_hashes), jnp.float32)


@jax.jit
def srp_bits(x: jnp.ndarray, planes: jnp.ndarray) -> jnp.ndarray:
    """x: (..., m) -> (..., K) uint8 sign bits."""
    return ((x @ planes) >= 0).astype(jnp.uint8)


@jax.jit
def hamming_similarity(query_bits: jnp.ndarray, db_bits: jnp.ndarray
                       ) -> jnp.ndarray:
    """Fraction of matching bits: (K,), (N, K) -> (N,)."""
    return jnp.mean((query_bits[None, :] == db_bits).astype(jnp.float32),
                    axis=-1)


@functools.partial(jax.jit, static_argnames=("topk",))
def srp_topk(query_bits: jnp.ndarray, db_bits: jnp.ndarray, topk: int):
    sim = hamming_similarity(query_bits, db_bits)
    vals, idx = jax.lax.top_k(sim, topk)
    return idx, vals
