"""End-to-end search pipelines: SSH (paper Alg. 2), UCR-suite baseline, SRP.

All three return ``SearchResult`` with pruning statistics so the paper's
Tables 1–4 can be produced from one code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.timing import DISABLED, STAGES, StageTimer
from repro.core.dtw import dtw_batch
from repro.core import lower_bounds as lb
from repro.core import rerank as rr
from repro.core import srp as srp_mod
from repro.core.index import SSHIndex
from repro.core.rerank import SearchStats
from repro.db.config import SearchConfig, config_from_legacy_kwargs
from repro.kernels import ops
from repro.kernels import ref as kref


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray              # (k,) database ids, best first
    dists: np.ndarray            # (k,) squared DTW costs
    n_candidates: int            # candidates that reached the DTW stage
    n_database: int
    pruned_by_hash_frac: float   # paper Table 4 row "Pruned by Hashing alone"
    pruned_total_frac: float     # paper Table 4 row "SSH Algorithm (Full)"
    wall_seconds: float
    stats: Optional[SearchStats] = None   # re-rank cascade counters

    @property
    def dtw_evals(self) -> int:
        return self.n_candidates


def hash_probe(query: jnp.ndarray, index: SSHIndex, top_c: int,
               rank_by_signature: bool = True,
               multiprobe_offsets: int = 1,
               use_host_buckets: bool = False,
               topk: int = 10,
               backend: str = "auto",
               timer: StageTimer = DISABLED,
               probe_stats: Optional[dict] = None) -> jnp.ndarray:
    """Stage 1 of Alg. 2: candidate ids ranked by hash collisions.

    Returns at most ``top_c`` candidate ids with a positive collision
    count, most-promising first; falls back to the first ``top_c`` ids when
    nothing collides.  The batched counterpart lives in
    ``repro.serving.batched`` (identical per-query decisions).  The
    ``backend`` knob routes the collision count through the Pallas kernel
    or the jnp reference — integer counts, so candidate sets are identical
    either way.  An enabled ``timer`` records the query signature build
    as the ``encode`` stage and the collision scan + top-C as ``probe``.

    Encodes go through the index's signature LRU (keyed by query
    content + spec + backend — bit-identical on hit, so candidate sets
    are unchanged); a caller-supplied ``probe_stats`` dict receives
    ``{"sig_cache_hit": 0|1}`` for telemetry.
    """
    n = int(index.keys.shape[0])
    use_pallas = ops.resolve_backend(backend)
    hit = False
    if use_host_buckets and index.host_buckets is not None:
        with timer.stage("encode") as sync:
            qkeys, hit = index.query_keys_cached(query)
            qkeys = sync(qkeys)
        with timer.stage("probe") as sync:
            cand_ids = index.host_buckets.probe(np.asarray(qkeys))
            cand_ids = jnp.asarray(cand_ids[: max(top_c, topk)], jnp.int32)
    elif multiprobe_offsets > 1:
        # one probe row per δ-offset, combined by per-candidate max —
        # same qk/db selection as the batched batch_probe
        from repro.core import minhash
        with timer.stage("encode") as sync:
            qsigs, hit = index.query_signatures_multiprobe_cached(
                query, multiprobe_offsets)
            if rank_by_signature:
                qk, db = qsigs, index.signatures
            else:
                qk = minhash.combine_bands(qsigs, index.num_tables)
                db = index.keys
            qk = sync(qk)
        with timer.stage("probe") as sync:
            counts_max = jnp.max(jnp.stack(
                [ops.collision_count(row, db, use_pallas=use_pallas)
                 for row in qk]), axis=0)
            vals, ids = jax.lax.top_k(counts_max, min(top_c, n))
            cand_ids = sync(ids[vals > 0])
    else:
        with timer.stage("encode") as sync:
            if rank_by_signature:
                qk, hit = index.query_signature_cached(query)
                db = index.signatures
            else:
                qk, hit = index.query_keys_cached(query)
                db = index.keys
            qk = sync(qk)
        with timer.stage("probe") as sync:
            counts = ops.collision_count(qk, db, use_pallas=use_pallas)
            vals, ids = jax.lax.top_k(counts, min(top_c, n))
            cand_ids = sync(ids[vals > 0])
    if probe_stats is not None:
        probe_stats["sig_cache_hit"] = int(hit)
    if cand_ids.shape[0] == 0:           # degenerate: fall back to top_c ids
        cand_ids = jnp.arange(min(top_c, n), dtype=jnp.int32)
    return cand_ids


def ssh_search(query: jnp.ndarray, index: SSHIndex,
               config: Optional[SearchConfig] = None,
               **legacy_kwargs) -> SearchResult:
    """Paper Algorithm 2: hash-probe candidates, then DTW re-rank.

    Canonical form: ``ssh_search(q, index, config=SearchConfig(...))`` —
    every knob (topk, top_c, band, cascade, multiprobe, host buckets,
    seed size, kernel backend) lives on the one frozen config consumed
    by all entry points; see ``repro.db.SearchConfig`` for semantics.
    The ``TimeSeriesDB`` facade routes here for ``searcher="local"``.

    Deprecation shim (one release): the historical loose-kwarg form
    ``ssh_search(q, index, topk=..., top_c=..., band=..., ...)`` still
    works — the kwargs are folded into a ``SearchConfig`` (identical
    results) under a ``DeprecationWarning``.
    """
    if config is not None and not isinstance(config, SearchConfig):
        # legacy positional call ssh_search(q, index, 10): the third
        # parameter used to be topk — fold it into the kwarg shim
        legacy_kwargs["topk"] = config
        config = None
    if config is None:
        config = config_from_legacy_kwargs("ssh_search", legacy_kwargs)
    elif legacy_kwargs:
        raise TypeError("ssh_search() takes either config= or legacy "
                        "search kwargs, not both: "
                        f"{sorted(legacy_kwargs)}")
    t0 = time.perf_counter()
    timer = StageTimer(enabled=config.stage_timings, prefill=STAGES)
    n = int(index.keys.shape[0])
    probe_stats: dict = {}
    cand_ids = hash_probe(query, index, config.top_c,
                          rank_by_signature=config.rank_by_signature,
                          multiprobe_offsets=config.multiprobe_offsets,
                          use_host_buckets=config.use_host_buckets,
                          topk=config.topk, backend=config.backend,
                          timer=timer, probe_stats=probe_stats)
    n_hash = int(cand_ids.shape[0])

    ids, dists, stats = rr.rerank(query, cand_ids, index, config.topk,
                                  config.band,
                                  use_lb_cascade=config.use_lb_cascade,
                                  backend=config.backend,
                                  seed_size=config.seed_size,
                                  early_abandon=config.early_abandon,
                                  timer=timer)
    n_final = stats.n_dtw
    stats.index_bytes = index.nbytes()
    stats.sig_cache_hit = probe_stats.get("sig_cache_hit", 0)
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=ids, dists=dists,
        n_candidates=n_final, n_database=n,
        pruned_by_hash_frac=1.0 - n_hash / n,
        pruned_total_frac=1.0 - n_final / n,
        wall_seconds=wall, stats=stats)


def ucr_search(query: jnp.ndarray, series: jnp.ndarray, topk: int = 10,
               band: Optional[int] = None, seed_size: int = 64,
               backend: str = "auto") -> SearchResult:
    """Vectorised UCR-suite: exact top-k via LB cascade + DTW on survivors.

    Decision-equivalent to the sequential suite: the LB cascade prunes
    against a best-so-far obtained from a seed subset, survivors get exact
    DTW (through the shared backend-dispatched re-rank primitive).
    (Exactness: a candidate is only dropped if some lower bound exceeds a
    *valid* upper bound on the k-th best distance.)
    """
    t0 = time.perf_counter()
    n = series.shape[0]
    seed = rr.dtw_candidates(query, series[:seed_size], band, backend)
    kth = jnp.sort(seed)[min(topk, seed_size) - 1]
    if band is None:
        # envelope bounds at a finite radius do NOT lower-bound the
        # unconstrained DTW (a path may align outside the window); only
        # LB_Kim (first/last point, forced by any warping path) is sound
        keep = lb.lb_kim(query, series) < kth
    else:
        keep = lb.cascade(query, series, band, kth)
    keep = keep.at[:seed_size].set(True)
    survivors = jnp.nonzero(keep, size=n, fill_value=n)[0]
    n_surv = int(jnp.sum(keep))
    cands = series[survivors[:n_surv]]
    d = rr.dtw_candidates(query, cands, band, backend)
    k = min(topk, int(cands.shape[0]))
    vals, idx = jax.lax.top_k(-d, k)
    ids = np.asarray(survivors[:n_surv])[np.asarray(idx)]
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=ids, dists=np.asarray(-vals), n_candidates=n_surv,
        n_database=n, pruned_by_hash_frac=0.0,
        pruned_total_frac=1.0 - n_surv / n, wall_seconds=wall)


def brute_force_topk(query: jnp.ndarray, series: jnp.ndarray, topk: int,
                     band: Optional[int] = None):
    """Gold standard (paper §5.3): exact DTW over the whole database.

    Routed through the shared jnp reference (``kernels.ref``) so the
    gold distances use the same arithmetic — including the banded
    window-DP's summation order — as the production re-rank, keeping
    exactness tests ulp-comparable.
    """
    d = kref.dtw_wavefront_ref(query, series, band=band)
    vals, idx = jax.lax.top_k(-d, topk)
    return np.asarray(idx), np.asarray(-vals)


def srp_search(query: jnp.ndarray, series: jnp.ndarray, planes: jnp.ndarray,
               db_bits: jnp.ndarray, topk: int = 10) -> SearchResult:
    """SRP baseline: rank by sign-bit Hamming similarity (no alignment)."""
    t0 = time.perf_counter()
    qb = srp_mod.srp_bits(query, planes)
    ids, _ = srp_mod.srp_topk(qb, db_bits, topk)
    d = dtw_batch(query, series[ids])
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=np.asarray(ids), dists=np.asarray(d),
        n_candidates=topk, n_database=series.shape[0],
        pruned_by_hash_frac=1.0 - topk / series.shape[0],
        pruned_total_frac=1.0 - topk / series.shape[0],
        wall_seconds=wall)


def precision_at_k(pred_ids: np.ndarray, gold_ids: np.ndarray, k: int
                   ) -> float:
    """Paper §5.3: |top-k ∩ gold top-k| / k."""
    return len(set(pred_ids[:k].tolist()) & set(gold_ids[:k].tolist())) / k


def ndcg_at_k(pred_ids: np.ndarray, gold_ids: np.ndarray, k: int) -> float:
    """Paper §5.3 NDCG with graded relevance R_i = k - rank_gold(i)."""
    rel = {int(g): k - r for r, g in enumerate(gold_ids[:k].tolist())}
    dcg = sum(rel.get(int(p), 0) / np.log2(i + 2)
              for i, p in enumerate(pred_ids[:k].tolist()))
    idcg = sum((k - i) / np.log2(i + 2) for i in range(k))
    return float(dcg / idcg) if idcg > 0 else 0.0
