"""End-to-end search pipelines: SSH (paper Alg. 2), UCR-suite baseline, SRP.

All three return ``SearchResult`` with pruning statistics so the paper's
Tables 1–4 can be produced from one code path.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dtw import dtw_batch
from repro.core import lower_bounds as lb
from repro.core import srp as srp_mod
from repro.core.index import SSHIndex, probe_topc


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray              # (k,) database ids, best first
    dists: np.ndarray            # (k,) squared DTW costs
    n_candidates: int            # candidates that reached the DTW stage
    n_database: int
    pruned_by_hash_frac: float   # paper Table 4 row "Pruned by Hashing alone"
    pruned_total_frac: float     # paper Table 4 row "SSH Algorithm (Full)"
    wall_seconds: float

    @property
    def dtw_evals(self) -> int:
        return self.n_candidates


def _dtw_rerank(query: jnp.ndarray, cands: jnp.ndarray, topk: int,
                band: Optional[int]):
    d = dtw_batch(query, cands, band=band)
    k = min(topk, cands.shape[0])
    vals, idx = jax.lax.top_k(-d, k)
    return idx, -vals


def hash_probe(query: jnp.ndarray, index: SSHIndex, top_c: int,
               rank_by_signature: bool = True,
               multiprobe_offsets: int = 1,
               use_host_buckets: bool = False,
               topk: int = 10) -> jnp.ndarray:
    """Stage 1 of Alg. 2: candidate ids ranked by hash collisions.

    Returns at most ``top_c`` candidate ids with a positive collision
    count, most-promising first; falls back to the first ``top_c`` ids when
    nothing collides.  The batched counterpart lives in
    ``repro.serving.batched`` (identical per-query decisions).
    """
    n = int(index.keys.shape[0])
    if use_host_buckets and index.host_buckets is not None:
        qkeys = index.query_keys(query)
        cand_ids = index.host_buckets.probe(np.asarray(qkeys))
        cand_ids = jnp.asarray(cand_ids[: max(top_c, topk)], jnp.int32)
    elif multiprobe_offsets > 1:
        # one probe row per δ-offset, combined by per-candidate max —
        # same qk/db selection as the batched batch_probe
        from repro.core import minhash
        from repro.core.index import signature_collisions
        qsigs = index.query_signatures_multiprobe(query, multiprobe_offsets)
        if rank_by_signature:
            qk, db = qsigs, index.signatures
        else:
            qk = minhash.combine_bands(qsigs, index.fns.params.num_tables)
            db = index.keys
        counts_max = jnp.max(jnp.stack(
            [signature_collisions(row, db) for row in qk]), axis=0)
        vals, ids = jax.lax.top_k(counts_max, min(top_c, n))
        cand_ids = ids[vals > 0]
    elif rank_by_signature:
        qsig = index.query_signature(query)
        ids, counts = probe_topc(qsig, index.signatures, min(top_c, n))
        cand_ids = ids[counts > 0]
    else:
        qkeys = index.query_keys(query)
        ids, counts = probe_topc(qkeys, index.keys, min(top_c, n))
        cand_ids = ids[counts > 0]
    if cand_ids.shape[0] == 0:           # degenerate: fall back to top_c ids
        cand_ids = jnp.arange(min(top_c, n), dtype=jnp.int32)
    return cand_ids


def ssh_search(query: jnp.ndarray, index: SSHIndex, topk: int = 10,
               top_c: int = 256, band: Optional[int] = None,
               use_lb_cascade: bool = True,
               use_host_buckets: bool = False,
               rank_by_signature: bool = True,
               multiprobe_offsets: int = 1) -> SearchResult:
    """Paper Algorithm 2: hash-probe candidates, then DTW re-rank.

    ``use_lb_cascade`` enables the extra UCR-style pruning of hash
    candidates (Alg. 2 line 10).  ``top_c`` bounds the candidate set for
    the device-scan backend (DESIGN.md §3).  ``rank_by_signature`` ranks
    candidates by agreement over all K raw CWS hashes instead of the L
    banded bucket keys — strictly finer collision granularity (beyond-paper
    refinement; set False for the paper-faithful band-key probe).
    """
    t0 = time.perf_counter()
    n = int(index.keys.shape[0])
    cand_ids = hash_probe(query, index, top_c,
                          rank_by_signature=rank_by_signature,
                          multiprobe_offsets=multiprobe_offsets,
                          use_host_buckets=use_host_buckets, topk=topk)
    n_hash = int(cand_ids.shape[0])

    cands = index.series[cand_ids]
    if use_lb_cascade and band is not None and n_hash > topk:
        # best-so-far from an initial DTW over the top-``topk`` hash hits
        seed = dtw_batch(query, cands[:topk], band=band)
        best = jnp.max(jax.lax.top_k(-seed, min(topk, n_hash))[0] * -1)
        keep = lb.cascade(query, cands, band, best)
        keep = keep.at[:topk].set(True)   # never drop the seeded set
        cand_ids = cand_ids[keep]
        cands = cands[keep]
    n_final = int(cands.shape[0])

    idx, dists = _dtw_rerank(query, cands, topk, band)
    ids = np.asarray(cand_ids)[np.asarray(idx)]
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=ids, dists=np.asarray(dists),
        n_candidates=n_final, n_database=n,
        pruned_by_hash_frac=1.0 - n_hash / n,
        pruned_total_frac=1.0 - n_final / n,
        wall_seconds=wall)


def ucr_search(query: jnp.ndarray, series: jnp.ndarray, topk: int = 10,
               band: Optional[int] = None, seed_size: int = 64
               ) -> SearchResult:
    """Vectorised UCR-suite: exact top-k via LB cascade + DTW on survivors.

    Decision-equivalent to the sequential suite: the LB cascade prunes
    against a best-so-far obtained from a seed subset, survivors get exact
    DTW.  (Exactness: a candidate is only dropped if some lower bound
    exceeds a *valid* upper bound on the k-th best distance.)
    """
    t0 = time.perf_counter()
    n = series.shape[0]
    radius = band if band is not None else max(1, query.shape[0] // 20)
    seed = dtw_batch(query, series[:seed_size], band=band)
    kth = jnp.sort(seed)[min(topk, seed_size) - 1]
    keep = lb.cascade(query, series, radius, kth)
    keep = keep.at[:seed_size].set(True)
    survivors = jnp.nonzero(keep, size=n, fill_value=n)[0]
    n_surv = int(jnp.sum(keep))
    cands = series[survivors[:n_surv]]
    idx, dists = _dtw_rerank(query, cands, topk, band)
    ids = np.asarray(survivors[:n_surv])[np.asarray(idx)]
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=ids, dists=np.asarray(dists), n_candidates=n_surv,
        n_database=n, pruned_by_hash_frac=0.0,
        pruned_total_frac=1.0 - n_surv / n, wall_seconds=wall)


def brute_force_topk(query: jnp.ndarray, series: jnp.ndarray, topk: int,
                     band: Optional[int] = None):
    """Gold standard (paper §5.3): exact DTW over the whole database."""
    d = dtw_batch(query, series, band=band)
    vals, idx = jax.lax.top_k(-d, topk)
    return np.asarray(idx), np.asarray(-vals)


def srp_search(query: jnp.ndarray, series: jnp.ndarray, planes: jnp.ndarray,
               db_bits: jnp.ndarray, topk: int = 10) -> SearchResult:
    """SRP baseline: rank by sign-bit Hamming similarity (no alignment)."""
    t0 = time.perf_counter()
    qb = srp_mod.srp_bits(query, planes)
    ids, _ = srp_mod.srp_topk(qb, db_bits, topk)
    d = dtw_batch(query, series[ids])
    wall = time.perf_counter() - t0
    return SearchResult(
        ids=np.asarray(ids), dists=np.asarray(d),
        n_candidates=topk, n_database=series.shape[0],
        pruned_by_hash_frac=1.0 - topk / series.shape[0],
        pruned_total_frac=1.0 - topk / series.shape[0],
        wall_seconds=wall)


def precision_at_k(pred_ids: np.ndarray, gold_ids: np.ndarray, k: int
                   ) -> float:
    """Paper §5.3: |top-k ∩ gold top-k| / k."""
    return len(set(pred_ids[:k].tolist()) & set(gold_ids[:k].tolist())) / k


def ndcg_at_k(pred_ids: np.ndarray, gold_ids: np.ndarray, k: int) -> float:
    """Paper §5.3 NDCG with graded relevance R_i = k - rank_gold(i)."""
    rel = {int(g): k - r for r, g in enumerate(gold_ids[:k].tolist())}
    dcg = sum(rel.get(int(p), 0) / np.log2(i + 2)
              for i, p in enumerate(pred_ids[:k].tolist()))
    idcg = sum((k - i) / np.log2(i + 2) for i in range(k))
    return float(dcg / idcg) if idcg > 0 else 0.0
