from repro.data.timeseries import (random_walk, synthetic_ecg,
                                   extract_subsequences, warp_series,
                                   make_benchmark_db)

__all__ = ["random_walk", "synthetic_ecg", "extract_subsequences",
           "warp_series", "make_benchmark_db"]
