"""Graph data pipeline: synthetic graph generators + a real neighbor
sampler (numpy CSR, host-side — the standard production split: sampling on
CPU hosts, compute on accelerators).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Sequence, Tuple

import numpy as np


def random_graph(n_nodes: int, n_edges: int, d_feat: int, seed: int = 0,
                 positions_scale: float = 3.0) -> Dict[str, np.ndarray]:
    """Random graph with positions (NequIP needs geometry; DESIGN.md §4)."""
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    return {
        "node_feat": rng.normal(0, 1, (n_nodes, d_feat)).astype(np.float32),
        "positions": (rng.normal(0, positions_scale, (n_nodes, 3))
                      .astype(np.float32)),
        "edge_src": src,
        "edge_dst": dst,
        "node_targets": rng.normal(0, 1, n_nodes).astype(np.float32),
    }


def molecule_batch(n_graphs: int, nodes_per: int, edges_per: int,
                   d_feat: int, seed: int = 0) -> Dict[str, np.ndarray]:
    """Disjoint union of small molecular graphs (radius-graph edges)."""
    rng = np.random.default_rng(seed)
    n = n_graphs * nodes_per
    pos = rng.normal(0, 2.0, (n, 3)).astype(np.float32)
    srcs, dsts = [], []
    for g in range(n_graphs):
        lo = g * nodes_per
        # nearest-neighbour-ish random edges within the molecule
        s = rng.integers(0, nodes_per, edges_per) + lo
        d = rng.integers(0, nodes_per, edges_per) + lo
        srcs.append(s)
        dsts.append(d)
    return {
        "node_feat": rng.normal(0, 1, (n, d_feat)).astype(np.float32),
        "positions": pos,
        "edge_src": np.concatenate(srcs).astype(np.int32),
        "edge_dst": np.concatenate(dsts).astype(np.int32),
        "graph_ids": np.repeat(np.arange(n_graphs), nodes_per
                               ).astype(np.int32),
        "energy": rng.normal(0, 1, n_graphs).astype(np.float32),
    }


@dataclasses.dataclass
class CSRGraph:
    """Compressed sparse row adjacency for host-side neighbor sampling."""
    indptr: np.ndarray      # (N+1,)
    indices: np.ndarray     # (E,)

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray, n_nodes: int
                   ) -> "CSRGraph":
        order = np.argsort(dst, kind="stable")
        s = src[order]
        counts = np.bincount(dst, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        return cls(indptr=indptr, indices=s.astype(np.int32))

    def degree(self, node: int) -> int:
        return int(self.indptr[node + 1] - self.indptr[node])


def sample_neighbors(graph: CSRGraph, seeds: np.ndarray,
                     fanouts: Sequence[int], seed: int = 0
                     ) -> Dict[str, np.ndarray]:
    """GraphSAGE-style uniform k-hop neighbor sampling (with replacement).

    Returns a *padded, fixed-shape* subgraph (required for jit):
      nodes  (n_total,) original node ids, layer-by-layer
      edge_src/edge_dst (n_edges,) indices INTO ``nodes``
      Padded edges are self-loops on node 0 (masked inside the model by the
      zero-length-edge rule).
    """
    rng = np.random.default_rng(seed)
    layers = [seeds.astype(np.int32)]
    srcs, dsts = [], []
    offset = 0
    for fanout in fanouts:
        frontier = layers[-1]
        n_f = len(frontier)
        sampled = np.zeros(n_f * fanout, np.int32)
        for i, node in enumerate(frontier):
            lo, hi = graph.indptr[node], graph.indptr[node + 1]
            if hi > lo:
                sampled[i * fanout:(i + 1) * fanout] = rng.choice(
                    graph.indices[lo:hi], fanout, replace=True)
            else:
                sampled[i * fanout:(i + 1) * fanout] = node  # self-pad
        new_offset = offset + n_f
        srcs.append(np.arange(n_f * fanout, dtype=np.int32) + new_offset)
        dsts.append(np.repeat(np.arange(n_f, dtype=np.int32) + offset,
                              fanout))
        layers.append(sampled)
        offset = new_offset
    nodes = np.concatenate(layers)
    return {
        "nodes": nodes,
        "edge_src": np.concatenate(srcs),
        "edge_dst": np.concatenate(dsts),
        "layer_sizes": np.asarray([len(l) for l in layers], np.int32),
    }


def sampled_subgraph_shape(batch_nodes: int, fanouts: Sequence[int]
                           ) -> Tuple[int, int]:
    """(n_nodes, n_edges) of the padded sampled subgraph."""
    n_nodes, n_edges = batch_nodes, 0
    frontier = batch_nodes
    for f in fanouts:
        n_edges += frontier * f
        frontier = frontier * f
        n_nodes += frontier
    return n_nodes, n_edges
