"""Synthetic recsys batches (Criteo/Amazon-like statistics)."""
from __future__ import annotations

from typing import Dict

import numpy as np


def dlrm_batch(batch: int, n_dense: int = 13, n_sparse: int = 26,
               vocab: int = 1_000_000, seed: int = 0
               ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    # log-normal dense features, zipfian sparse ids (realistic skew)
    dense = rng.lognormal(0, 1, (batch, n_dense)).astype(np.float32)
    sparse = (rng.zipf(1.2, (batch, n_sparse)) % vocab).astype(np.int32)
    labels = (rng.uniform(size=batch) < 0.25).astype(np.float32)
    return {"dense": dense, "sparse": sparse, "labels": labels}


def seq_batch(batch: int, seq_len: int, vocab: int = 2_000_000,
              n_profile: int = 8, seed: int = 0) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "history": (rng.zipf(1.2, (batch, seq_len)) % vocab
                    ).astype(np.int32),
        "target": (rng.zipf(1.2, batch) % vocab).astype(np.int32),
        "profile": rng.integers(0, 1000, (batch, n_profile)
                                ).astype(np.int32),
        "labels": (rng.uniform(size=batch) < 0.3).astype(np.float32),
    }


def retrieval_batch(n_candidates: int, seq_len: int, vocab: int = 2_000_000,
                    n_dense: int = 13, n_sparse: int = 26, seed: int = 0
                    ) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng(seed)
    return {
        "dense": rng.lognormal(0, 1, (1, n_dense)).astype(np.float32),
        "sparse": rng.integers(0, vocab, (1, n_sparse)).astype(np.int32),
        "history": rng.integers(0, vocab, (1, seq_len)).astype(np.int32),
        "cand_ids": rng.integers(0, vocab, n_candidates).astype(np.int32),
    }
