"""Time-series data pipeline (paper §5.1).

The paper evaluates on two public series — 22h of ECG (20.14M points) and
a random-walk benchmark — sliced into overlapping subsequences
S_i = (s_i, ..., s_{i+t-1}).  The originals are not redistributable here,
so we generate series with matching statistics:

* random_walk  — x_t = x_{t-1} + N(0,1): the standard benchmark generator
  (identical in distribution to the published one).
* synthetic_ecg — sum-of-Gaussians PQRST template with beat-rate and
  amplitude jitter + baseline wander (McSharry-style dynamical ECG,
  simplified), which reproduces the quasi-periodic motif structure that
  makes SSH's alignment property matter.

Subsequence extraction is stride-able so a 20M-point stream becomes the
paper's ~20M-subsequence database (stride 1) or a deduplicated database
(stride t) for container-scale tests.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def random_walk(n_points: int, seed: int = 0, scale: float = 1.0
                ) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return np.cumsum(rng.normal(0.0, scale, n_points)).astype(np.float32)


def _pqrst_beat(t: np.ndarray) -> np.ndarray:
    """One heartbeat on t ∈ [0,1): P, Q, R, S, T Gaussian bumps."""
    # widths follow physiological durations at 250 Hz (QRS ≈ 0.1 s ≈ 10
    # samples) — narrower spikes alias under the stride-δ sketch grid.
    centers = np.array([0.18, 0.36, 0.40, 0.44, 0.70])
    widths = np.array([0.060, 0.022, 0.030, 0.022, 0.080])
    amps = np.array([0.15, -0.18, 1.20, -0.25, 0.30])
    out = np.zeros_like(t)
    for c, w, a in zip(centers, widths, amps):
        out += a * np.exp(-0.5 * ((t - c) / w) ** 2)
    return out


def synthetic_ecg(n_points: int, seed: int = 0, hz: int = 250,
                  bpm: float = 72.0, noise: float = 0.03) -> np.ndarray:
    """ECG-like stream: jittered beats + baseline wander + sensor noise."""
    rng = np.random.default_rng(seed)
    out = np.zeros(n_points, np.float32)
    samples_per_beat = int(hz * 60.0 / bpm)
    pos = 0
    while pos < n_points:
        jitter = rng.normal(1.0, 0.05)
        amp = rng.normal(1.0, 0.08)
        nb = max(16, int(samples_per_beat * jitter))
        t = np.arange(nb) / nb
        seg = amp * _pqrst_beat(t)
        end = min(pos + nb, n_points)
        out[pos:end] += seg[: end - pos].astype(np.float32)
        pos += nb
    # baseline wander (respiration ~0.25 Hz) + white noise
    tt = np.arange(n_points) / hz
    out += 0.08 * np.sin(2 * np.pi * 0.25 * tt).astype(np.float32)
    out += rng.normal(0.0, noise, n_points).astype(np.float32)
    return out


def extract_subsequences(stream: np.ndarray, length: int,
                         stride: int = 1, max_count: Optional[int] = None,
                         znorm: bool = False) -> np.ndarray:
    """D = {S_i} sliding windows (paper §5.1). -> (N, length) float32."""
    n = (len(stream) - length) // stride + 1
    if max_count is not None:
        n = min(n, max_count)
    idx = np.arange(n)[:, None] * stride + np.arange(length)[None, :]
    out = stream[idx].astype(np.float32)
    if znorm:
        mu = out.mean(axis=1, keepdims=True)
        sd = out.std(axis=1, keepdims=True) + 1e-8
        out = (out - mu) / sd
    return out


def warp_series(x: np.ndarray, shift: int = 0, stretch: float = 1.0,
                seed: int = 0, noise: float = 0.0) -> np.ndarray:
    """Apply the misalignments SSH must be invariant to (shift/warp/noise)."""
    rng = np.random.default_rng(seed)
    m = len(x)
    src = np.clip(np.arange(m) * stretch + shift, 0, m - 1)
    lo = np.floor(src).astype(int)
    hi = np.minimum(lo + 1, m - 1)
    frac = src - lo
    out = x[lo] * (1 - frac) + x[hi] * frac
    if noise > 0:
        out = out + rng.normal(0, noise, m)
    return out.astype(np.float32)


def make_benchmark_db(kind: str, n_series: int, length: int, seed: int = 0,
                      stride: Optional[int] = None) -> np.ndarray:
    """Container-scale stand-in for the paper's 20M-subsequence databases."""
    stride = stride if stride is not None else max(1, length // 8)
    n_points = n_series * stride + length
    if kind == "ecg":
        stream = synthetic_ecg(n_points, seed=seed)
    elif kind == "randomwalk":
        stream = random_walk(n_points, seed=seed)
    else:
        raise ValueError(f"unknown dataset kind: {kind}")
    return extract_subsequences(stream, length, stride=stride,
                                max_count=n_series)
