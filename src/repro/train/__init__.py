"""Training utilities."""
