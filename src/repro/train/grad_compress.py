"""Gradient compression for the data-parallel all-reduce.

Two schemes, both with *error feedback* (residual accumulation) so the
compression bias vanishes over steps (Karimireddy et al., 2019):

  * top-k sparsification — keep the k largest-|g| entries per tensor,
    all-reduce only those (dense emulation via masking under SPMD; on a
    real fabric the sparse payload is k indices + k values).
  * int8 stochastic quantisation — per-tensor scale, stochastic rounding.

Used by the ``shard_map`` DDP trainer (`repro.train.ddp`) where the
all-reduce is explicit; the pjit path leaves gradients uncompressed (XLA
owns that collective).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def topk_compress(g: jnp.ndarray, frac: float) -> jnp.ndarray:
    """Zero out all but the largest-|g| ``frac`` of entries."""
    flat = g.reshape(-1)
    k = max(1, int(flat.shape[0] * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(g) >= thresh, g, 0.0)


def int8_quantize(g: jnp.ndarray, key: jax.Array
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    noise = jax.random.uniform(key, g.shape, g.dtype, -0.5, 0.5)
    q = jnp.clip(jnp.round(g / scale + noise), -127, 127).astype(jnp.int8)
    return q, scale


def int8_dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads: Any, residual: Any, *, scheme: str,
                           topk_frac: float = 0.01,
                           key: jax.Array | None = None
                           ) -> Tuple[Any, Any]:
    """Returns (compressed grads to all-reduce, new residual)."""
    corrected = jax.tree.map(lambda g, r: g.astype(jnp.float32) + r,
                             grads, residual)
    if scheme == "topk":
        sent = jax.tree.map(
            functools.partial(topk_compress, frac=topk_frac), corrected)
    elif scheme == "int8":
        leaves, treedef = jax.tree.flatten(corrected)
        keys = jax.random.split(key, len(leaves))
        sent = treedef.unflatten(
            [int8_dequantize(*int8_quantize(g, k))
             for g, k in zip(leaves, keys)])
    elif scheme == "none":
        sent = corrected
    else:
        raise ValueError(scheme)
    new_residual = jax.tree.map(lambda c, s: c - s, corrected, sent)
    return sent, new_residual


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
