"""AdamW with mixed-precision state (built here — no optax dependency).

State: fp32 first/second moments + fp32 master params when the model
weights are bf16 (the production mixed-precision recipe).  The state tree
mirrors the parameter tree, so parameter sharding rules apply verbatim —
i.e. the optimizer is automatically ZeRO-sharded wherever params are
FSDP-sharded.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any          # fp32 params (or None-like empty dict if fp32 model)


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float = 1e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0
    warmup_steps: int = 100

    def init(self, params: Any) -> AdamWState:
        zeros = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                          v=jax.tree.map(jnp.copy, zeros), master=master)

    def schedule(self, step: jnp.ndarray) -> jnp.ndarray:
        warm = jnp.minimum(1.0, (step + 1) / max(self.warmup_steps, 1))
        return self.lr * warm

    def update(self, params: Any, state: AdamWState, grads: Any
               ) -> Tuple[Any, AdamWState, Dict[str, jnp.ndarray]]:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        gnorm = jnp.sqrt(sum(jnp.vdot(g, g).real
                             for g in jax.tree.leaves(grads)) + 1e-20)
        if self.grad_clip is not None:
            scale = jnp.minimum(1.0, self.grad_clip / gnorm)
            grads = jax.tree.map(lambda g: g * scale, grads)

        step = state.step + 1
        lr = self.schedule(step)
        b1c = 1.0 - self.b1 ** step.astype(jnp.float32)
        b2c = 1.0 - self.b2 ** step.astype(jnp.float32)

        new_m = jax.tree.map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state.m, grads)
        new_v = jax.tree.map(
            lambda v, g: self.b2 * v + (1 - self.b2) * g * g,
            state.v, grads)

        def upd(master, m, v):
            mh = m / b1c
            vh = v / b2c
            return master - lr * (mh / (jnp.sqrt(vh) + self.eps)
                                  + self.weight_decay * master)

        new_master = jax.tree.map(upd, state.master, new_m, new_v)
        new_params = jax.tree.map(
            lambda mp, p: mp.astype(p.dtype), new_master, params)
        metrics = {"grad_norm": gnorm, "lr": lr}
        return new_params, AdamWState(step, new_m, new_v, new_master), metrics
