"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: ``use_pallas=None`` (default) picks the Pallas kernel on
TPU backends and the pure-jnp reference elsewhere; tests force both paths
(``interpret=True`` executes the kernel body in Python on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.collision_count import collision_count as _collision_pallas
from repro.kernels.collision_count import \
    collision_count_batch as _collision_batch_pallas
from repro.kernels.dtw_wavefront import dtw_wavefront as _dtw_pallas
from repro.kernels.sketch_conv import sketch_conv as _sketch_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sketch_conv(x: jnp.ndarray, filters: jnp.ndarray, step: int,
                use_pallas: Optional[bool] = None,
                interpret: bool = False) -> jnp.ndarray:
    """Sliding-window projections (B, m) x (W, F) -> (B, N_B, F)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _sketch_pallas(x, filters, step,
                              interpret=interpret or not _on_tpu())
    return ref.sketch_conv_ref(x, filters, step)


def sketch_bits(x: jnp.ndarray, filters: jnp.ndarray, step: int,
                **kw) -> jnp.ndarray:
    return (sketch_conv(x, filters, step, **kw) >= 0).astype(jnp.uint8)


def dtw_rerank(query: jnp.ndarray, candidates: jnp.ndarray, band: int,
               use_pallas: Optional[bool] = None,
               interpret: bool = False) -> jnp.ndarray:
    """Banded squared-DTW of query vs candidate batch -> (C,)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _dtw_pallas(query, candidates, band,
                           interpret=interpret or not _on_tpu())
    return ref.dtw_wavefront_ref(query, candidates, band=band)


def collision_count(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Signature agreement counts (L,) x (N, L) -> (N,)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _collision_pallas(query_keys, db_keys,
                                 interpret=interpret or not _on_tpu())
    return ref.collision_count_ref(query_keys, db_keys)


def collision_count_batch(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                          use_pallas: Optional[bool] = None,
                          interpret: bool = False) -> jnp.ndarray:
    """Batched signature agreement counts (B, L) x (N, L) -> (B, N)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _collision_batch_pallas(query_keys, db_keys,
                                       interpret=interpret or not _on_tpu())
    return ref.collision_count_batch_ref(query_keys, db_keys)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused attention (B, H, S, D) — Pallas on TPU, oracle elsewhere."""
    from repro.kernels.flash_attention import flash_attention as _fa
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _fa(q, k, v, causal=causal,
                   interpret=interpret or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)
