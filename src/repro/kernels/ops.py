"""Jit'd public wrappers around the Pallas kernels.

Dispatch policy: ``use_pallas=None`` (default) picks the Pallas kernel on
TPU backends and the pure-jnp reference elsewhere; tests force both paths
(``interpret=True`` executes the kernel body in Python on CPU).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.collision_count import collision_count as _collision_pallas
from repro.kernels.collision_count import \
    collision_count_batch as _collision_batch_pallas
from repro.kernels.count_sketch import cs_tables as _cs_tables_pallas
from repro.kernels.dtw_wavefront import dtw_wavefront as _dtw_pallas
from repro.kernels.dtw_wavefront import \
    dtw_wavefront_pairs as _dtw_pairs_pallas
from repro.kernels.sketch_conv import sketch_conv as _sketch_pallas

BACKENDS = ("auto", "pallas", "jnp")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> Optional[bool]:
    """Map the public ``backend`` knob to the ``use_pallas`` tri-state.

    ``"auto"`` → None (Pallas on TPU, jnp reference elsewhere);
    ``"pallas"`` → True (interpret mode off-TPU — same kernel body);
    ``"jnp"`` → False.  One knob drives every kernel of the query path
    (collision count, LB filter gathers, DTW re-rank).
    """
    if backend not in BACKENDS:
        raise ValueError(f"backend must be one of {BACKENDS}, "
                         f"got {backend!r}")
    return {"auto": None, "pallas": True, "jnp": False}[backend]


def backend_name(use_pallas: Optional[bool]) -> str:
    """The backend actually executing for a ``use_pallas`` tri-state."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    return "pallas" if use_pallas else "jnp"


def sketch_conv(x: jnp.ndarray, filters: jnp.ndarray, step: int,
                use_pallas: Optional[bool] = None,
                interpret: bool = False) -> jnp.ndarray:
    """Sliding-window projections (B, m) x (W, F) -> (B, N_B, F)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _sketch_pallas(x, filters, step,
                              interpret=interpret or not _on_tpu())
    return ref.sketch_conv_ref(x, filters, step)


def sketch_bits(x: jnp.ndarray, filters: jnp.ndarray, step: int,
                **kw) -> jnp.ndarray:
    return (sketch_conv(x, filters, step, **kw) >= 0).astype(jnp.uint8)


def sketch_bits_stream(stream: jnp.ndarray, filters: jnp.ndarray,
                       stride: int, use_pallas: Optional[bool] = None,
                       interpret: bool = False) -> jnp.ndarray:
    """Rolling-sketch primitive: sign bits of every stride-``stride``
    filter projection of ONE long stream, (n,) x (W, F) -> (P, F) with
    P = (n - W)//stride + 1.

    The projection at absolute stream position p, <x[p:p+W], f>, does not
    depend on which sliding window reads it — so a subsequence index
    (``repro.subseq``) calls ``sketch_conv`` ONCE over the whole stream
    at the gcd stride and gathers each window's taps from the shared
    grid: O(N·W) filter work for all windows instead of O(N·L·W/h).
    Each projection contracts exactly the same operand values as the
    per-window call, so the gathered bits are bit-identical to sketching
    every window separately.
    """
    return sketch_bits(stream[None, :], filters, stride,
                       use_pallas=use_pallas, interpret=interpret)[0]


def dtw_rerank(query: jnp.ndarray, candidates: jnp.ndarray,
               band: Optional[int],
               use_pallas: Optional[bool] = None,
               interpret: bool = False,
               threshold=None) -> jnp.ndarray:
    """Banded squared-DTW of query vs candidate batch -> (C,).

    ``band=None`` (unconstrained) maps to radius m-1 on the Pallas path —
    equivalent for equal-length series (|i-j| <= m-1 always holds).
    ``threshold`` (scalar or (C,)) enables early-abandoning PrunedDTW on
    both backends under one contract: lanes whose exact cost is
    <= threshold return it exactly, all others return BIG — so the two
    backends stay value-comparable and top-k decisions are unchanged
    whenever the threshold upper-bounds the k-th best distance.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        r = band if band is not None else candidates.shape[1] - 1
        return _dtw_pallas(query, candidates, r,
                           interpret=interpret or not _on_tpu(),
                           threshold=threshold)
    return ref.dtw_wavefront_ref(query, candidates, band=band,
                                 threshold=threshold)


def dtw_rerank_pairs(queries: jnp.ndarray, candidates: jnp.ndarray,
                     band: Optional[int],
                     use_pallas: Optional[bool] = None,
                     interpret: bool = False,
                     threshold=None) -> jnp.ndarray:
    """Row-aligned pair DTW (P, m) x (P, m) -> (P,) — the batched
    re-rank's survivor-pair shape.  ``band=None`` (unconstrained) maps to
    radius m-1 on the Pallas path: for equal-length series |i-j| <= m-1
    always holds, so the banded kernel computes the unconstrained DP.
    ``threshold`` (scalar or (P,)): early-abandon contract as in
    :func:`dtw_rerank`.
    """
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        r = band if band is not None else candidates.shape[1] - 1
        return _dtw_pairs_pallas(queries, candidates, r,
                                 interpret=interpret or not _on_tpu(),
                                 threshold=threshold)
    return ref.dtw_pairs_ref(queries, candidates, band=band,
                             threshold=threshold)


def collision_count(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Signature agreement counts (L,) x (N, L) -> (N,)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _collision_pallas(query_keys, db_keys,
                                 interpret=interpret or not _on_tpu())
    return ref.collision_count_ref(query_keys, db_keys)


def collision_count_batch(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                          use_pallas: Optional[bool] = None,
                          interpret: bool = False) -> jnp.ndarray:
    """Batched signature agreement counts (B, L) x (N, L) -> (B, N)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _collision_batch_pallas(query_keys, db_keys,
                                       interpret=interpret or not _on_tpu())
    return ref.collision_count_batch_ref(query_keys, db_keys)


def cs_tables(bucket: jnp.ndarray, sign: jnp.ndarray, width: int,
              use_pallas: Optional[bool] = None,
              interpret: bool = False) -> jnp.ndarray:
    """Count-sketch table accumulation (B, R, S) -> (B, R, width)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _cs_tables_pallas(bucket, sign, width,
                                 interpret=interpret or not _on_tpu())
    return ref.cs_tables_ref(bucket, sign, width)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True,
                    use_pallas: Optional[bool] = None,
                    interpret: bool = False) -> jnp.ndarray:
    """Fused attention (B, H, S, D) — Pallas on TPU, oracle elsewhere."""
    from repro.kernels.flash_attention import flash_attention as _fa
    if use_pallas is None:
        use_pallas = _on_tpu()
    if use_pallas or interpret:
        return _fa(q, k, v, causal=causal,
                   interpret=interpret or not _on_tpu())
    return ref.flash_attention_ref(q, k, v, causal=causal)
