"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` is the semantic ground truth the kernels are tested
against (tests sweep shapes/dtypes and assert_allclose).  They are also
the CPU/GPU fallback used when ``use_pallas=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dtw import dtw_batch as _dtw_batch
from repro.core.sketch import sketch_projections as _sketch_projections


@functools.partial(jax.jit, static_argnames=("step",))
def sketch_conv_ref(x: jnp.ndarray, filters: jnp.ndarray, step: int
                    ) -> jnp.ndarray:
    """Sliding-window projections. x (B, m), filters (W, F) -> (B, N_B, F)."""
    return _sketch_projections(x, filters, step)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_wavefront_ref(query: jnp.ndarray, candidates: jnp.ndarray,
                      band: Optional[int] = None) -> jnp.ndarray:
    """Banded squared-DTW. query (m,), candidates (C, m) -> (C,)."""
    return _dtw_batch(query, candidates, band=band)


@jax.jit
def collision_count_ref(query_keys: jnp.ndarray, db_keys: jnp.ndarray
                        ) -> jnp.ndarray:
    """query (L,), db (N, L) int32 -> (N,) int32 per-row match counts."""
    return jnp.sum((db_keys == query_keys[None, :]).astype(jnp.int32),
                   axis=-1)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = False) -> jnp.ndarray:
    """Plain softmax attention. q (B,H,S,D), k/v (B,H,T,D) -> (B,H,S,D)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    if causal:
        s, t = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)
