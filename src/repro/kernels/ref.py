"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``<name>_ref`` is the semantic ground truth the kernels are tested
against (tests sweep shapes/dtypes and assert_allclose).  They are also
the CPU/GPU fallback used when ``use_pallas=False``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.dtw import BIG as _BIG
from repro.core.dtw import dtw as _dtw
from repro.core.dtw import dtw_banded_batch as _dtw_banded_batch
from repro.core.dtw import dtw_banded_pairs as _dtw_banded_pairs
from repro.core.dtw import dtw_batch as _dtw_batch
from repro.core.sketch import sketch_projections as _sketch_projections


@functools.partial(jax.jit, static_argnames=("step",))
def sketch_conv_ref(x: jnp.ndarray, filters: jnp.ndarray, step: int
                    ) -> jnp.ndarray:
    """Sliding-window projections. x (B, m), filters (W, F) -> (B, N_B, F)."""
    return _sketch_projections(x, filters, step)


def _banded_wins(band: Optional[int], m: int) -> bool:
    """True when the window DP does less work than the full-column scan
    (the band window, padded to its DP width, is narrower than a column)."""
    return band is not None and 2 * band + 1 < m


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_wavefront_ref(query: jnp.ndarray, candidates: jnp.ndarray,
                      band: Optional[int] = None,
                      threshold=None) -> jnp.ndarray:
    """Banded squared-DTW. query (m,), candidates (C, m) -> (C,).

    Narrow bands route through the O(m·band) window DP
    (``core.dtw.dtw_banded_batch``) — the CPU analogue of the wavefront
    kernel's banded cell count.  ``threshold`` applies the shared
    early-abandon contract (exact if <= threshold, else BIG); on the
    window-DP path hopeless lanes also stop the column scan early.
    """
    if _banded_wins(band, int(candidates.shape[1])):
        return _dtw_banded_batch(query, candidates, band,
                                 threshold=threshold)
    d = _dtw_batch(query, candidates, band=band)
    if threshold is None:
        return d
    thr = jnp.asarray(threshold, jnp.float32)
    return jnp.where(d > thr, _BIG, d)


@functools.partial(jax.jit, static_argnames=("band",))
def dtw_pairs_ref(queries: jnp.ndarray, candidates: jnp.ndarray,
                  band: Optional[int] = None,
                  threshold=None) -> jnp.ndarray:
    """Row-aligned banded squared-DTW: (P, m) x (P, m) -> (P,).

    Same band/threshold routing as :func:`dtw_wavefront_ref`.
    """
    if _banded_wins(band, int(candidates.shape[1])):
        return _dtw_banded_pairs(queries, candidates, band,
                                 threshold=threshold)
    d = jax.vmap(lambda q, c: _dtw(q, c, band=band))(queries, candidates)
    if threshold is None:
        return d
    thr = jnp.asarray(threshold, jnp.float32)
    return jnp.where(d > thr, _BIG, d)


@jax.jit
def collision_count_ref(query_keys: jnp.ndarray, db_keys: jnp.ndarray
                        ) -> jnp.ndarray:
    """query (L,), db (N, L) int32 -> (N,) int32 per-row match counts."""
    return jnp.sum((db_keys == query_keys[None, :]).astype(jnp.int32),
                   axis=-1)


@jax.jit
def collision_count_batch_ref(query_keys: jnp.ndarray, db_keys: jnp.ndarray
                              ) -> jnp.ndarray:
    """queries (B, L), db (N, L) int32 -> (B, N) per-row match counts.

    Accumulated key-by-key over the transposed database so each step is a
    contiguous (B, N) broadcast compare — the database row streams once
    for the whole query block (the vmap-of-rowwise-sum formulation
    degrades with B on CPU instead of amortising).
    """
    db_t = db_keys.T                                      # (L, N)
    b, n = query_keys.shape[0], db_keys.shape[0]

    def body(l, acc):
        hit = db_t[l][None, :] == query_keys[:, l][:, None]
        return acc + hit.astype(jnp.int32)

    return jax.lax.fori_loop(0, db_keys.shape[1], body,
                             jnp.zeros((b, n), jnp.int32))


@functools.partial(jax.jit, static_argnames=("width",))
def cs_tables_ref(bucket: jnp.ndarray, sign: jnp.ndarray, width: int
                  ) -> jnp.ndarray:
    """Signed count-sketch tables. bucket (B, R, S) int32 (−1 invalid),
    sign (B, R, S) f32 -> (B, R, width) f32.

    Invalid buckets route to a dump bin at index ``width`` that is sliced
    off — a raw ``.at[-1]`` would wrap to the last real bin.
    """
    b, r, s = bucket.shape
    tgt = jnp.where(bucket >= 0, bucket, width)

    def one_table(t, sg):
        return jnp.zeros((width + 1,), jnp.float32).at[t].add(sg)[:width]

    tables = jax.vmap(one_table)(tgt.reshape(b * r, s),
                                 sign.astype(jnp.float32).reshape(b * r, s))
    return tables.reshape(b, r, width)


@functools.partial(jax.jit, static_argnames=("causal",))
def flash_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        causal: bool = False) -> jnp.ndarray:
    """Plain softmax attention. q (B,H,S,D), k/v (B,H,T,D) -> (B,H,S,D)."""
    scale = q.shape[-1] ** -0.5
    logits = jnp.einsum("bhsd,bhtd->bhst", q, k) * scale
    if causal:
        s, t = q.shape[2], k.shape[2]
        mask = jnp.tril(jnp.ones((s, t), bool), k=t - s)
        logits = jnp.where(mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    return jnp.einsum("bhst,bhtd->bhsd", probs.astype(v.dtype), v)
