"""Pallas TPU kernel — fused flash attention (forward) for the LM cells.

The jnp chunked attention in `repro.models.layers` is what the dry-run
lowers (it shards cleanly under GSPMD); this kernel is the on-chip
replacement for real TPU runs (`use_pallas=True` in ops.dispatch): one
grid cell owns a (q_block × head) tile, loops over KV blocks with the
online-softmax recurrence entirely in VMEM, and writes the normalised
output once — no (S, T) logits ever reach HBM.

Grid: (B·H, S/q_block).  Blocks:
  q   (1, q_block, D)   — index (bh, i)
  k/v (1, T, D)         — whole KV row for the head (VMEM: T·D·4 B;
                          32k × 128 f32 = 16 MB/2 at bf16 — for longer T,
                          extend the grid with a KV ring; documented)
  out (1, q_block, D)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int, t: int,
            causal: bool, scale: float, q_block: int):
    i = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale          # (qb, D)
    qb, d = q.shape
    n_kv = t // kv_block

    def body(j, carry):
        m, l, acc = carry
        # leading axis as a length-1 ds slice: bare int indices are
        # rejected by the interpret-mode discharge rule on current JAX
        k_blk = pl.load(k_ref, (pl.ds(0, 1), pl.ds(j * kv_block, kv_block),
                                slice(None)))[0].astype(jnp.float32)
        v_blk = pl.load(v_ref, (pl.ds(0, 1), pl.ds(j * kv_block, kv_block),
                                slice(None)))[0].astype(jnp.float32)
        logits = q @ k_blk.T                          # (qb, kvb)
        if causal:
            q_pos = i * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 0)
            k_pos = j * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (qb, kv_block), 1)
            logits = jnp.where(q_pos >= k_pos, logits, NEG_INF)
        m2 = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m2[:, None])
        corr = jnp.exp(m - m2)
        l2 = l * corr + jnp.sum(p, axis=-1)
        acc2 = acc * corr[:, None] + p @ v_blk
        return m2, l2, acc2

    init = (jnp.full((qb,), NEG_INF, jnp.float32),
            jnp.zeros((qb,), jnp.float32),
            jnp.zeros((qb, d), jnp.float32))
    m, l, acc = jax.lax.fori_loop(0, n_kv, body, init)
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("causal", "q_block", "kv_block",
                                    "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    causal: bool = True, q_block: int = 128,
                    kv_block: int = 128,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, H, S, D), k/v (B, H, T, D) -> (B, H, S, D).

    MQA/GQA callers repeat KV heads before the call (cheap view).
    """
    b, h, s, d = q.shape
    t = k.shape[2]
    scale = d ** -0.5
    qb = min(q_block, s)
    kvb = min(kv_block, t)
    sp, tp = (-s) % qb, (-t) % kvb
    # pad: padded K positions get masked by causality only if causal;
    # for the non-causal case pad K with -inf-producing zeros + mask via
    # extra causal-style bound — simplest: require divisibility after pad
    # and mask padded keys through position comparison below.
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, sp), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, tp), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, tp), (0, 0)))
    if tp and not causal:
        raise ValueError("non-causal flash requires T divisible by "
                         f"kv_block (got T={t}, kv_block={kvb})")
    sq, st = s + sp, t + tp

    q3 = qp.reshape(b * h, sq, d)
    k3 = kp.reshape(b * h, st, d)
    v3 = vp.reshape(b * h, st, d)

    out = pl.pallas_call(
        functools.partial(_kernel, kv_block=kvb, t=st, causal=causal,
                          scale=scale, q_block=qb),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        grid=(b * h, sq // qb),
        in_specs=[
            pl.BlockSpec((1, qb, d), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((1, st, d), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((1, st, d), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, qb, d), lambda bh, i: (bh, i, 0)),
        interpret=interpret,
    )(q3, k3, v3)
    return out.reshape(b, h, sq, d)[:, :, :s]
