"""Pallas TPU kernel — banded DTW re-rank, anti-diagonal wavefront.

The DTW re-rank (paper Alg. 2 line 10) is the compute hot-spot of an SSH
query: O(C · m · band) after hashing prunes N → C.  GPU implementations
assign one thread per DP cell along the wavefront; the TPU adaptation maps

  * candidates → the 128-wide lane axis (one DTW per lane),
  * the Sakoe-Chiba band offset → the sublane axis,
  * anti-diagonals → a sequential fori_loop (2m-1 steps).

All cells of an anti-diagonal depend only on the previous two diagonals,
so every loop step is one dependence-free (B_w, 128) vector op — no
scalar DP.  Without a threshold the loop is a static fori over the band
bound; with one it becomes the early-abandoning PrunedDTW while_loop
(``_kernel_thr``): per-lane data dependence stays banished, the only
data-dependent control is the whole-block exit test (DESIGN.md §3).

Index algebra (r = band radius, u ∈ [0, 2r+2) the band offset):
  diagonal d holds cells (i, j = d - i); we store them at
  u = i - offset_d with offset_d = floor(d/2) - r.  Then
    D[i-1, j]   ← prev1[u]   (d even) / prev1[u-1] (d odd)
    D[i, j-1]   ← prev1[u+1] (d even) / prev1[u]   (d odd)
    D[i-1, j-1] ← prev2[u]   (always)
  and the answer sits at u = r on the final diagonal d = 2m-2.

To avoid in-kernel reversed loads, the wrapper passes candidates
time-REVERSED (and transposed to (time, lane)): x[j] = x_rev[m-1-j] turns
the j-descending gather into a contiguous ascending slice.

VMEM per block: query (m_pad, 1) + candidates (m_pad, 128) + two carry
tiles (B_w, 128)  ≈ 4·(m·129 + 2·B_w·128) bytes — ~1.2 MB at m=2048,
r=128; well inside the ~16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BIG = 1e30  # python float: pallas kernels must not capture device constants


def _make_step(q_ref, x_ref, *, m: int, r: int, b_w: int, pad: int):
    """The shared anti-diagonal update: (d, prev1, prev2) -> diagonal d.

    Used verbatim by both kernels — the unconditional fori_loop program
    and the threshold-aware while_loop program — so their per-diagonal
    arithmetic (and hence every completed lane's value) is identical.
    """
    u = jax.lax.broadcasted_iota(jnp.int32, (b_w, LANES), 0)

    def shift_down(a):  # element u <- a[u-1]
        return jnp.concatenate(
            [jnp.full((1, LANES), BIG, a.dtype), a[:-1, :]], axis=0)

    def shift_up(a):    # element u <- a[u+1]
        return jnp.concatenate(
            [a[1:, :], jnp.full((1, LANES), BIG, a.dtype)], axis=0)

    def step(d, prev1, prev2):
        offset = d // 2 - r
        i = offset + u                      # query index of cell u
        j = d - i                           # candidate index of cell u
        # q[i] for u ascending — contiguous slice of the padded query
        q_vals = pl.load(q_ref, (pl.ds(offset + pad, b_w), slice(None)))
        # x[j] = x_rev[m-1-j]; ascending in u — contiguous slice
        x_vals = pl.load(x_ref, (pl.ds(m - 1 - d + offset + pad, b_w),
                                 slice(None)))
        cost = (q_vals - x_vals) ** 2       # (b_w, LANES)

        even = (d % 2) == 0
        top = jnp.where(even, prev1, shift_down(prev1))
        left = jnp.where(even, shift_up(prev1), prev1)
        best = jnp.minimum(jnp.minimum(top, left), prev2)
        best = jnp.where((i == 0) & (j == 0), 0.0, best)
        valid = (i >= 0) & (i < m) & (j >= 0) & (j < m) & \
                (jnp.abs(i - j) <= r)
        return jnp.where(valid, jnp.minimum(cost + best, BIG), BIG)

    return step


def _kernel(q_ref, x_ref, o_ref, *, m: int, r: int, b_w: int, pad: int):
    step = _make_step(q_ref, x_ref, m=m, r=r, b_w=b_w, pad=pad)

    def body(d, carry):
        prev1, prev2 = carry
        return (step(d, prev1, prev2), prev1)

    init = (jnp.full((b_w, LANES), BIG, jnp.float32),
            jnp.full((b_w, LANES), BIG, jnp.float32))
    final1, _ = jax.lax.fori_loop(0, 2 * m - 1, body, init)
    o_ref[...] = final1[r, :][None, :]


def _kernel_thr(q_ref, x_ref, t_ref, o_ref, *, m: int, r: int, b_w: int,
                pad: int):
    """Threshold-aware variant: early-abandoning PrunedDTW (arXiv
    2010.05371) on the wavefront.

    Per lane, the minimum over the last *two* anti-diagonals is a sound
    lower bound on the final cost: cell costs are nonnegative and every
    monotone warping path crosses at least one cell of any two adjacent
    anti-diagonals (a diagonal move skips exactly one).  The while_loop
    exits as soon as every lane's bound exceeds its threshold; the
    output applies the shared contract *exact value if DTW <= threshold,
    else BIG* (strict >, so a lane landing exactly on the threshold is
    returned exactly — abandoning can then never drop a top-k member
    whose distance equals the seeded k-th best).
    """
    step = _make_step(q_ref, x_ref, m=m, r=r, b_w=b_w, pad=pad)
    thr = t_ref[...]                        # (1, LANES) per-lane threshold

    def cond(carry):
        d, prev1, prev2 = carry
        bound = jnp.minimum(jnp.min(prev1, axis=0, keepdims=True),
                            jnp.min(prev2, axis=0, keepdims=True))
        # d < 1: the carries still hold the BIG init, not real diagonals
        return (d < 2 * m - 1) & ((d < 1) | jnp.any(bound <= thr))

    def body(carry):
        d, prev1, prev2 = carry
        return (d + 1, step(d, prev1, prev2), prev1)

    init = (0, jnp.full((b_w, LANES), BIG, jnp.float32),
            jnp.full((b_w, LANES), BIG, jnp.float32))
    _, final1, _ = jax.lax.while_loop(cond, body, init)
    # on early exit final1[r] is a mid-DP cell of a dead lane: >= the
    # lane's bound > thr, so the mask below sends it to BIG as required
    out = final1[r, :][None, :]
    o_ref[...] = jnp.where(out > thr, BIG, out)


def _thr_lanes(threshold, n: int, pad_n: int) -> jnp.ndarray:
    """Per-lane thresholds as a (1, n + pad_n) row.

    Padding lanes get -1.0 — every DP bound is >= 0, so they are dead
    from the first check and can never hold a whole block alive past its
    real lanes' abandon point (with +inf padding a block would always
    run all 2m-1 diagonals).
    """
    thr = jnp.broadcast_to(jnp.asarray(threshold, jnp.float32), (n,))
    return jnp.pad(thr[None, :], ((0, 0), (0, pad_n)),
                   constant_values=-1.0)


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def dtw_wavefront(query: jnp.ndarray, candidates: jnp.ndarray,
                  band: int, interpret: bool = False,
                  threshold=None) -> jnp.ndarray:
    """Banded squared-DTW: query (m,), candidates (C, m) -> (C,) float32.

    ``band`` is the Sakoe-Chiba radius (use m-1 for unconstrained).
    ``threshold`` (scalar or (C,), broadcast per lane) switches to the
    early-abandoning kernel: lanes return their exact cost when it is
    <= threshold and BIG otherwise, and a 128-lane block stops looping as
    soon as all its lanes are provably over threshold.  ``None`` runs
    the original unconditional program (bit-identical to before).
    """
    c, m = candidates.shape
    assert query.shape[0] == m, "query/candidate lengths must match"
    r = min(band, m - 1)
    b_w = 2 * r + 2
    b_w += (-b_w) % 8                       # sublane alignment
    pad = b_w + 2                           # slack so every ds() is in-bounds

    cp = (-c) % LANES
    # time-reversed, (time, lane) layout, padded both ends
    x_rev = candidates.astype(jnp.float32)[:, ::-1].T       # (m, C)
    x_rev = jnp.pad(x_rev, ((pad, pad), (0, cp)))
    q_pad = jnp.pad(query.astype(jnp.float32)[:, None], ((pad, pad), (0, 0)))

    q_spec = pl.BlockSpec((m + 2 * pad, 1), lambda g: (0, 0))
    x_spec = pl.BlockSpec((m + 2 * pad, LANES), lambda g: (0, g))
    o_spec = pl.BlockSpec((1, LANES), lambda g: (0, g))
    if threshold is None:
        out = pl.pallas_call(
            functools.partial(_kernel, m=m, r=r, b_w=b_w, pad=pad),
            out_shape=jax.ShapeDtypeStruct((1, c + cp), jnp.float32),
            grid=((c + cp) // LANES,),
            in_specs=[q_spec, x_spec],
            out_specs=o_spec,
            interpret=interpret,
        )(q_pad, x_rev)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_thr, m=m, r=r, b_w=b_w, pad=pad),
            out_shape=jax.ShapeDtypeStruct((1, c + cp), jnp.float32),
            grid=((c + cp) // LANES,),
            in_specs=[q_spec, x_spec, o_spec],
            out_specs=o_spec,
            interpret=interpret,
        )(q_pad, x_rev, _thr_lanes(threshold, c, cp))
    return out[0, :c]


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def dtw_wavefront_pairs(queries: jnp.ndarray, candidates: jnp.ndarray,
                        band: int, interpret: bool = False,
                        threshold=None) -> jnp.ndarray:
    """Row-aligned banded squared-DTW: (P, m) x (P, m) -> (P,) float32.

    Pair ``p`` gets DTW(queries[p], candidates[p]) — the layout the
    batched re-rank's flattened survivor-pair list needs (each pair may
    have a *different* query).  Reuses the single-query kernel body
    verbatim: the query tile is simply (b_w, LANES) instead of (b_w, 1)
    broadcast, i.e. one query per lane alongside its candidate.  All
    per-lane arithmetic is independent, so pair values are bit-identical
    to ``dtw_wavefront`` with the same (query, candidate) in any lane.
    ``threshold`` (scalar or (P,)) selects the early-abandoning kernel
    with the same exact-or-BIG contract as ``dtw_wavefront``.
    """
    p, m = candidates.shape
    assert queries.shape == candidates.shape, "row-aligned pairs required"
    r = min(band, m - 1)
    b_w = 2 * r + 2
    b_w += (-b_w) % 8                       # sublane alignment
    pad = b_w + 2                           # slack so every ds() is in-bounds

    pp = (-p) % LANES
    # candidates time-reversed, queries in natural time; both (time, lane)
    x_rev = candidates.astype(jnp.float32)[:, ::-1].T       # (m, P)
    x_rev = jnp.pad(x_rev, ((pad, pad), (0, pp)))
    q_t = jnp.pad(queries.astype(jnp.float32).T, ((pad, pad), (0, pp)))

    qx_spec = pl.BlockSpec((m + 2 * pad, LANES), lambda g: (0, g))
    o_spec = pl.BlockSpec((1, LANES), lambda g: (0, g))
    if threshold is None:
        out = pl.pallas_call(
            functools.partial(_kernel, m=m, r=r, b_w=b_w, pad=pad),
            out_shape=jax.ShapeDtypeStruct((1, p + pp), jnp.float32),
            grid=((p + pp) // LANES,),
            in_specs=[qx_spec, qx_spec],
            out_specs=o_spec,
            interpret=interpret,
        )(q_t, x_rev)
    else:
        out = pl.pallas_call(
            functools.partial(_kernel_thr, m=m, r=r, b_w=b_w, pad=pad),
            out_shape=jax.ShapeDtypeStruct((1, p + pp), jnp.float32),
            grid=((p + pp) // LANES,),
            in_specs=[qx_spec, qx_spec, o_spec],
            out_specs=o_spec,
            interpret=interpret,
        )(q_t, x_rev, _thr_lanes(threshold, p, pp))
    return out[0, :p]
