"""Pallas TPU kernel — banded DTW re-rank, anti-diagonal wavefront.

The DTW re-rank (paper Alg. 2 line 10) is the compute hot-spot of an SSH
query: O(C · m · band) after hashing prunes N → C.  GPU implementations
assign one thread per DP cell along the wavefront; the TPU adaptation maps

  * candidates → the 128-wide lane axis (one DTW per lane),
  * the Sakoe-Chiba band offset → the sublane axis,
  * anti-diagonals → a sequential fori_loop (2m-1 steps).

All cells of an anti-diagonal depend only on the previous two diagonals,
so every loop step is one dependence-free (B_w, 128) vector op — no
scalar DP, no data-dependent control flow (early-abandoning is replaced
by the band bound, see DESIGN.md §3).

Index algebra (r = band radius, u ∈ [0, 2r+2) the band offset):
  diagonal d holds cells (i, j = d - i); we store them at
  u = i - offset_d with offset_d = floor(d/2) - r.  Then
    D[i-1, j]   ← prev1[u]   (d even) / prev1[u-1] (d odd)
    D[i, j-1]   ← prev1[u+1] (d even) / prev1[u]   (d odd)
    D[i-1, j-1] ← prev2[u]   (always)
  and the answer sits at u = r on the final diagonal d = 2m-2.

To avoid in-kernel reversed loads, the wrapper passes candidates
time-REVERSED (and transposed to (time, lane)): x[j] = x_rev[m-1-j] turns
the j-descending gather into a contiguous ascending slice.

VMEM per block: query (m_pad, 1) + candidates (m_pad, 128) + two carry
tiles (B_w, 128)  ≈ 4·(m·129 + 2·B_w·128) bytes — ~1.2 MB at m=2048,
r=128; well inside the ~16 MB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BIG = 1e30  # python float: pallas kernels must not capture device constants


def _kernel(q_ref, x_ref, o_ref, *, m: int, r: int, b_w: int, pad: int):
    u = jax.lax.broadcasted_iota(jnp.int32, (b_w, LANES), 0)

    def shift_down(a):  # element u <- a[u-1]
        return jnp.concatenate(
            [jnp.full((1, LANES), BIG, a.dtype), a[:-1, :]], axis=0)

    def shift_up(a):    # element u <- a[u+1]
        return jnp.concatenate(
            [a[1:, :], jnp.full((1, LANES), BIG, a.dtype)], axis=0)

    def body(d, carry):
        prev1, prev2 = carry
        offset = d // 2 - r
        i = offset + u                      # query index of cell u
        j = d - i                           # candidate index of cell u
        # q[i] for u ascending — contiguous slice of the padded query
        q_vals = pl.load(q_ref, (pl.ds(offset + pad, b_w), slice(None)))
        # x[j] = x_rev[m-1-j]; ascending in u — contiguous slice
        x_vals = pl.load(x_ref, (pl.ds(m - 1 - d + offset + pad, b_w),
                                 slice(None)))
        cost = (q_vals - x_vals) ** 2       # (b_w, LANES)

        even = (d % 2) == 0
        top = jnp.where(even, prev1, shift_down(prev1))
        left = jnp.where(even, shift_up(prev1), prev1)
        best = jnp.minimum(jnp.minimum(top, left), prev2)
        best = jnp.where((i == 0) & (j == 0), 0.0, best)
        valid = (i >= 0) & (i < m) & (j >= 0) & (j < m) & \
                (jnp.abs(i - j) <= r)
        d_new = jnp.where(valid, jnp.minimum(cost + best, BIG), BIG)
        return (d_new, prev1)

    init = (jnp.full((b_w, LANES), BIG, jnp.float32),
            jnp.full((b_w, LANES), BIG, jnp.float32))
    final1, _ = jax.lax.fori_loop(0, 2 * m - 1, body, init)
    o_ref[...] = final1[r, :][None, :]


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def dtw_wavefront(query: jnp.ndarray, candidates: jnp.ndarray,
                  band: int, interpret: bool = False) -> jnp.ndarray:
    """Banded squared-DTW: query (m,), candidates (C, m) -> (C,) float32.

    ``band`` is the Sakoe-Chiba radius (use m-1 for unconstrained).
    """
    c, m = candidates.shape
    assert query.shape[0] == m, "query/candidate lengths must match"
    r = min(band, m - 1)
    b_w = 2 * r + 2
    b_w += (-b_w) % 8                       # sublane alignment
    pad = b_w + 2                           # slack so every ds() is in-bounds

    cp = (-c) % LANES
    # time-reversed, (time, lane) layout, padded both ends
    x_rev = candidates.astype(jnp.float32)[:, ::-1].T       # (m, C)
    x_rev = jnp.pad(x_rev, ((pad, pad), (0, cp)))
    q_pad = jnp.pad(query.astype(jnp.float32)[:, None], ((pad, pad), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_kernel, m=m, r=r, b_w=b_w, pad=pad),
        out_shape=jax.ShapeDtypeStruct((1, c + cp), jnp.float32),
        grid=((c + cp) // LANES,),
        in_specs=[
            pl.BlockSpec((m + 2 * pad, 1), lambda g: (0, 0)),
            pl.BlockSpec((m + 2 * pad, LANES), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda g: (0, g)),
        interpret=interpret,
    )(q_pad, x_rev)
    return out[0, :c]


@functools.partial(jax.jit, static_argnames=("band", "interpret"))
def dtw_wavefront_pairs(queries: jnp.ndarray, candidates: jnp.ndarray,
                        band: int, interpret: bool = False) -> jnp.ndarray:
    """Row-aligned banded squared-DTW: (P, m) x (P, m) -> (P,) float32.

    Pair ``p`` gets DTW(queries[p], candidates[p]) — the layout the
    batched re-rank's flattened survivor-pair list needs (each pair may
    have a *different* query).  Reuses the single-query kernel body
    verbatim: the query tile is simply (b_w, LANES) instead of (b_w, 1)
    broadcast, i.e. one query per lane alongside its candidate.  All
    per-lane arithmetic is independent, so pair values are bit-identical
    to ``dtw_wavefront`` with the same (query, candidate) in any lane.
    """
    p, m = candidates.shape
    assert queries.shape == candidates.shape, "row-aligned pairs required"
    r = min(band, m - 1)
    b_w = 2 * r + 2
    b_w += (-b_w) % 8                       # sublane alignment
    pad = b_w + 2                           # slack so every ds() is in-bounds

    pp = (-p) % LANES
    # candidates time-reversed, queries in natural time; both (time, lane)
    x_rev = candidates.astype(jnp.float32)[:, ::-1].T       # (m, P)
    x_rev = jnp.pad(x_rev, ((pad, pad), (0, pp)))
    q_t = jnp.pad(queries.astype(jnp.float32).T, ((pad, pad), (0, pp)))

    out = pl.pallas_call(
        functools.partial(_kernel, m=m, r=r, b_w=b_w, pad=pad),
        out_shape=jax.ShapeDtypeStruct((1, p + pp), jnp.float32),
        grid=((p + pp) // LANES,),
        in_specs=[
            pl.BlockSpec((m + 2 * pad, LANES), lambda g: (0, g)),
            pl.BlockSpec((m + 2 * pad, LANES), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda g: (0, g)),
        interpret=interpret,
    )(q_t, x_rev)
    return out[0, :p]
