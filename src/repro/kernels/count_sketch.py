"""Pallas TPU kernel — batched count-sketch table accumulation.

The streaming shingler ("ssh-cs") replaces the exact F·2^n histogram with
``rows`` signed tables of ``width`` bins; building them is the signature
hot path's scatter.  TPUs have no native scatter-add, so the kernel uses
the one-hot formulation: for a chunk of shingle buckets it materialises
``lane_index == bucket`` over the full table width and reduces the ±1
signs down the sublane axis — a (CHUNK, width) f32 compare+sum that maps
straight onto the VPU, the same trick the collision kernel uses for
sentinel rows.

Hashing stays OUTSIDE the kernel (multiply-shift in uint32 is a handful
of elementwise jnp ops; the scatter is the part worth fusing), so inputs
are pre-hashed buckets (B, R, S) int32 with −1 for padding/masked
shingles and their signs (B, R, S) f32 with 0 at the same slots — the
one-hot compare drops −1 for free since lane indices are non-negative.

Grid: (B, R, S_pad / CHUNK), chunks innermost so each (1, 1, width)
output block stays VMEM-resident while its shingle stream walks through;
``@pl.when(step == 0)`` zero-initialises per (b, r).  VMEM: the one-hot
block at the default width 4096 is (128, 4096) f32 = 2 MiB — comfortable
against the ~16 MiB budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CHUNK = 128


def _kernel(b_ref, s_ref, o_ref):
    step = pl.program_id(2)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    width = o_ref.shape[-1]
    bkt = b_ref[...].reshape(CHUNK, 1)               # (CHUNK, 1) int32
    sgn = s_ref[...].reshape(CHUNK, 1)               # (CHUNK, 1) f32
    lanes = jax.lax.broadcasted_iota(jnp.int32, (CHUNK, width), 1)
    hits = jnp.where(lanes == bkt, sgn, 0.0)         # one-hot ±1
    o_ref[...] += jnp.sum(hits, axis=0).reshape(1, 1, width)


@functools.partial(jax.jit, static_argnames=("width", "interpret"))
def cs_tables(bucket: jnp.ndarray, sign: jnp.ndarray, width: int,
              interpret: bool = False) -> jnp.ndarray:
    """bucket (B, R, S) int32 (−1 invalid), sign (B, R, S) f32 (0 at −1)
    -> (B, R, width) f32 signed count-sketch tables."""
    b, r, s = bucket.shape
    sp = (-s) % CHUNK
    bkt = jnp.pad(bucket.astype(jnp.int32), ((0, 0), (0, 0), (0, sp)),
                  constant_values=-1)
    sgn = jnp.pad(sign.astype(jnp.float32), ((0, 0), (0, 0), (0, sp)))

    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, r, width), jnp.float32),
        grid=(b, r, (s + sp) // CHUNK),
        in_specs=[
            pl.BlockSpec((1, 1, CHUNK), lambda i, j, c: (i, j, c)),
            pl.BlockSpec((1, 1, CHUNK), lambda i, j, c: (i, j, c)),
        ],
        out_specs=pl.BlockSpec((1, 1, width), lambda i, j, c: (i, j, 0)),
        interpret=interpret,
    )(bkt, sgn)
