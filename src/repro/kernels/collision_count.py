"""Pallas TPU kernel — LSH signature collision counting (index probe).

The device-side probe replaces hash-bucket pointer chasing (DESIGN.md §3):
for a query signature (K int32 hashes) and the database signature matrix
(N, K), count per-row agreements.  Bandwidth-bound: N·K int32 reads per
probe, so the kernel keeps the query resident and streams the database
through VMEM with candidates on the lane axis.

Layout: the wrapper transposes signatures to (K, N) so each block is
(K_pad, 128) — K on sublanes, candidates on lanes; the count is a sublane
reduction.  Padding rows use disjoint sentinels so they never match.

Grid: (N / 128,).

``collision_count_batch`` is the fused batched-probe variant for the
serving engine (DESIGN.md §4): B query signatures against the same
database in one kernel.  Grid: (N / 128, B) with queries innermost, so a
database block stays VMEM-resident while every query row scans it — the
database streams from HBM once per batch instead of once per query.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
_DB_SENTINEL = jnp.int32(-2147483648)
_Q_SENTINEL = jnp.int32(2147483647)


def _kernel(q_ref, db_ref, o_ref):
    q = q_ref[...]                                   # (K_pad, 1)
    db = db_ref[...]                                 # (K_pad, LANES)
    eq = (db == q).astype(jnp.int32)
    o_ref[...] = jnp.sum(eq, axis=0, keepdims=True)  # (1, LANES)


@functools.partial(jax.jit, static_argnames=("interpret",))
def collision_count(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                    interpret: bool = False) -> jnp.ndarray:
    """query (L,), db (N, L) int32 -> (N,) int32 match counts."""
    n, k = db_keys.shape
    kp = (-k) % 8
    np_ = (-n) % LANES
    db = jnp.pad(db_keys.astype(jnp.int32).T, ((0, kp), (0, np_)),
                 constant_values=_DB_SENTINEL)         # (K_pad, N_pad)
    q = jnp.pad(query_keys.astype(jnp.int32)[:, None], ((0, kp), (0, 0)),
                constant_values=_Q_SENTINEL)           # (K_pad, 1)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((1, n + np_), jnp.int32),
        grid=((n + np_) // LANES,),
        in_specs=[
            pl.BlockSpec((k + kp, 1), lambda g: (0, 0)),
            pl.BlockSpec((k + kp, LANES), lambda g: (0, g)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda g: (0, g)),
        interpret=interpret,
    )(q, db)
    return out[0, :n]


@functools.partial(jax.jit, static_argnames=("interpret",))
def collision_count_batch(query_keys: jnp.ndarray, db_keys: jnp.ndarray,
                          interpret: bool = False) -> jnp.ndarray:
    """queries (B, L), db (N, L) int32 -> (B, N) int32 match counts.

    Same block layout as ``collision_count`` (keys on sublanes, candidates
    on lanes); the batch adds a second grid axis that walks query columns
    of the transposed (K_pad, B) query matrix.
    """
    b, k = query_keys.shape
    n, k2 = db_keys.shape
    assert k == k2, "query/db key widths must match"
    kp = (-k) % 8
    np_ = (-n) % LANES
    db = jnp.pad(db_keys.astype(jnp.int32).T, ((0, kp), (0, np_)),
                 constant_values=_DB_SENTINEL)          # (K_pad, N_pad)
    q = jnp.pad(query_keys.astype(jnp.int32).T, ((0, kp), (0, 0)),
                constant_values=_Q_SENTINEL)            # (K_pad, B)

    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((b, n + np_), jnp.int32),
        grid=((n + np_) // LANES, b),     # queries innermost: db block reused
        in_specs=[
            pl.BlockSpec((k + kp, 1), lambda g, i: (0, i)),
            pl.BlockSpec((k + kp, LANES), lambda g, i: (0, g)),
        ],
        out_specs=pl.BlockSpec((1, LANES), lambda g, i: (i, g)),
        interpret=interpret,
    )(q, db)
    return out[:, :n]
