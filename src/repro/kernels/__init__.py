"""Pallas TPU kernels for the SSH hot-spots (validated via interpret=True).

  sketch_conv      — SSH step 1: sliding-window random projections
  dtw_wavefront    — banded DTW re-rank (anti-diagonal wavefront)
  collision_count  — LSH signature probe (agreement counting)

``ops`` holds the dispatching wrappers, ``ref`` the pure-jnp oracles.
"""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
