"""Pallas TPU kernel — SSH step 1: sliding-window random projections.

The paper slides one Gaussian filter r (length W, stride δ) over each
series and keeps sign bits.  On TPU we tile the series batch over the
sublane axis and the output (window) positions over the lane axis.

Stride-δ windows would need strided VMEM loads (hostile to Mosaic), so the
wrapper performs a **phase decomposition**: the series is laid out as
(B, δ, L) with ``xp[b, p, i] = x[b, i*δ + p]``.  Filter tap w = a·δ + p of
output position t then reads the *contiguous* lane slice
``xp[:, p, t + a : t + a + TN]`` — every tap becomes a shifted
fused-multiply-add on a (TB, TN) tile, unrolled over the W taps (W is a
hyper-parameter, ~30–80).  Arithmetic intensity: W FLOPs per output
element, all operands VMEM-resident.

Grid: (B / TB, N_B / TN).  Blocks:
  xp      (TB, δ, L)     — whole phase-decomposed row, index (i, 0, 0)
  filters (W, F)         — resident, index (0, 0)
  out     (F, TB, TN)    — index (0, i, j)   (transposed back by wrapper)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TB = 8     # series rows per tile (sublane)
TN = 128   # window positions per tile (lane)


def _kernel(x_ref, f_ref, o_ref, *, step: int, window: int, num_f: int):
    j = pl.program_id(1)
    base = j * TN
    filt = f_ref[...]                          # (W, F)
    acc = jnp.zeros((num_f, TB, TN), jnp.float32)
    for w in range(window):                    # static unroll over taps
        a, p = divmod(w, step)
        # p as a length-1 ds slice: bare int indices are rejected by the
        # interpret-mode discharge rule on current JAX
        taps = pl.load(x_ref, (slice(None), pl.ds(p, 1), pl.ds(base + a, TN)))
        acc = acc + filt[w][:, None, None] * taps[:, 0, :][None, :, :]
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("step", "interpret"))
def sketch_conv(x: jnp.ndarray, filters: jnp.ndarray, step: int,
                interpret: bool = False) -> jnp.ndarray:
    """Sliding-window projections via Pallas. x (B, m), filters (W, F).

    Returns (B, N_B, F) float32 with N_B = (m - W)//step + 1.
    """
    b, m = x.shape
    window, num_f = filters.shape
    n_b = (m - window) // step + 1

    bp = (-b) % TB
    n_bp = n_b + ((-n_b) % TN)
    # phase decomposition: xp[b, p, i] = x[b, i*step + p]
    l = n_bp + (window - 1) // step + 1
    xflat = jnp.pad(x.astype(jnp.float32),
                    ((0, bp), (0, l * step - m)))
    xp = xflat.reshape(b + bp, l, step).transpose(0, 2, 1)   # (B, δ, L)

    out = pl.pallas_call(
        functools.partial(_kernel, step=step, window=window, num_f=num_f),
        out_shape=jax.ShapeDtypeStruct((num_f, b + bp, n_bp), jnp.float32),
        grid=((b + bp) // TB, n_bp // TN),
        in_specs=[
            pl.BlockSpec((TB, step, l), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((window, num_f), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((num_f, TB, TN), lambda i, j: (0, i, j)),
        interpret=interpret,
    )(xp, filters.astype(jnp.float32))
    return out.transpose(1, 2, 0)[:b, :n_b, :]
