"""repro.subseq — sliding-window subsequence search over long streams.

Build once with a rolling encode (shared sketch grid + sparse CWS,
DESIGN.md §10), search with the standard probe → cascade → DTW pipeline
over lazily-gathered windows, grow with ``extend_stream``::

    from repro.subseq import SubsequenceIndex
    idx = SubsequenceIndex.build(stream, spec, length=128, hop=4)
    res = idx.search(query, config)     # res.offsets — match positions

The facade entry points live on ``repro.db.TimeSeriesDB``
(``build_stream`` / ``search_subsequence`` / ``extend_stream``).
"""
from repro.subseq.index import SubsequenceIndex, SubsequenceResult
from repro.subseq.persistence import (is_subseq_dir, load_subseq,
                                      save_subseq)
from repro.subseq.rolling import (delta_histograms, global_shingle_ids,
                                  num_windows, rolling_signatures,
                                  rolling_sketch_bits)

__all__ = [
    "SubsequenceIndex", "SubsequenceResult",
    "rolling_signatures", "rolling_sketch_bits", "global_shingle_ids",
    "delta_histograms", "num_windows",
    "save_subseq", "load_subseq", "is_subseq_dir",
]
