"""Subsequence-index persistence — save/load a ``SubsequenceIndex``.

Mirrors :mod:`repro.db.persistence` (same checkpoint layer, same
spec-pinning discipline) for stream-built databases::

    <dir>/subseq_db.json     # IndexSpec, window geometry, array manifest
    <dir>/index/step_*/      # repro.checkpoint shard(s) + manifest

Everything needed for bit-identical answers — and for continuing to
``extend_stream`` — is stored: the raw stream, the per-window
signatures + band keys, and the encoder's materialised random state.
``load`` rebuilds the encoder through the registry and REFUSES a
spec/artifact mismatch (foreign array shapes, signature widths that
disagree with the spec's K/L, or a window count that disagrees with the
stored stream geometry), so a tampered or mixed-up directory fails
loudly instead of answering from inconsistent hash functions.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.core.index import SSHIndex
from repro.db.config import SearchConfig
from repro.encoders import IndexSpec, encoder_class
from repro.kernels import ops
from repro.subseq.rolling import num_windows

FORMAT_VERSION = 1
META_FILE = "subseq_db.json"
ARRAYS_SUBDIR = "index"
_ENC_PREFIX = "encoder/"


def save_subseq(directory, index, config: Optional[SearchConfig] = None,
                n_shards: int = 1) -> Path:
    """Persist ``index`` (and optionally a ``config``) under ``directory``.

    Atomic at both levels (checkpoint publish + meta ``os.replace``),
    monotonic-step + keep=2 like the sequence-level saver, so re-saving
    into a live directory never corrupts the previous database.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    arrays: Dict[str, np.ndarray] = {
        "stream": np.asarray(index.stream, np.float32),
        "signatures": np.asarray(index.inner.signatures),
        "keys": np.asarray(index.inner.keys),
    }
    for name, arr in index.inner.enc.arrays().items():
        arrays[f"{_ENC_PREFIX}{name}"] = np.asarray(arr)

    prev = latest_step(directory / ARRAYS_SUBDIR)
    step = 0 if prev is None else prev + 1
    save_checkpoint(directory / ARRAYS_SUBDIR, step=step, tree=arrays,
                    keep=2, n_shards=n_shards)

    meta: Dict[str, Any] = {
        "format_version": FORMAT_VERSION,
        "checkpoint_step": step,
        "spec": index.inner.enc.spec.to_dict(),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "length": int(index.length),
        "hop": int(index.hop),
        "n_windows": index.num_windows,
        "stream_length": int(index.stream.shape[0]),
        "build_backend": index.inner.build_backend,
        "encode_seconds": float(index.encode_seconds),
        "config": config.to_dict() if config is not None else None,
    }
    tmp = directory / f".{META_FILE}.tmp{os.getpid()}"
    tmp.write_text(json.dumps(meta, indent=1))
    os.replace(tmp, directory / META_FILE)
    return directory


def load_subseq(directory) -> Tuple["SubsequenceIndex",
                                    Optional[SearchConfig]]:
    """Inverse of :func:`save_subseq` — ``(index, config)``.

    The loaded index answers queries bit-identically to the saved one
    (same signatures, same encoder arrays, same pinned build backend)
    and keeps accepting ``extend_stream`` (the raw stream and encoder
    state are both restored).
    """
    from repro.subseq.index import SubsequenceIndex
    directory = Path(directory)
    meta_path = directory / META_FILE
    if not meta_path.exists():
        raise FileNotFoundError(f"no subsequence database at {directory} "
                                f"(missing {META_FILE})")
    meta = json.loads(meta_path.read_text())
    if meta.get("format_version") != FORMAT_VERSION:
        raise ValueError(
            f"unsupported subsequence database format_version "
            f"{meta.get('format_version')!r} (this release reads "
            f"{FORMAT_VERSION})")

    tree_like = {k: np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
                 for k, info in meta["arrays"].items()}
    _, arrays = restore_checkpoint(directory / ARRAYS_SUBDIR, tree_like,
                                   step=meta.get("checkpoint_step"))

    spec = IndexSpec.from_dict(meta["spec"])
    enc = encoder_class(spec.encoder)(spec.validate())
    enc.load_arrays({k[len(_ENC_PREFIX):]: v for k, v in arrays.items()
                     if k.startswith(_ENC_PREFIX)})
    if int(np.shape(arrays["signatures"])[-1]) != enc.num_hashes:
        raise ValueError(
            f"saved signatures have "
            f"K={int(np.shape(arrays['signatures'])[-1])} but the saved "
            f"spec implies K={enc.num_hashes} — spec/artifact mismatch")
    if int(np.shape(arrays["keys"])[-1]) != enc.num_tables:
        raise ValueError(
            f"saved band keys have "
            f"L={int(np.shape(arrays['keys'])[-1])} but the saved spec "
            f"implies L={enc.num_tables} — spec/artifact mismatch")
    length, hop = int(meta["length"]), int(meta["hop"])
    stream = np.ascontiguousarray(np.asarray(arrays["stream"],
                                             np.float32))
    nw = int(np.shape(arrays["signatures"])[0])
    if num_windows(stream.shape[0], length, hop) != nw:
        raise ValueError(
            f"saved stream of {stream.shape[0]} points implies "
            f"{num_windows(stream.shape[0], length, hop)} windows at "
            f"L={length}, h={hop}, but {nw} signatures are stored — "
            "geometry/artifact mismatch")

    build_backend = meta.get("build_backend", "jnp")
    if build_backend == "pallas" and \
            ops.backend_name(ops.resolve_backend("auto")) != "pallas":
        import warnings
        warnings.warn(
            "this subsequence database was built with the Pallas kernel "
            "backend; on a non-TPU host its queries/extends run the "
            "kernel in interpret mode (orders of magnitude slower). "
            "Rebuild with backend='jnp' for CPU serving.",
            RuntimeWarning, stacklevel=3)

    inner = SSHIndex(fns=None, signatures=arrays["signatures"],
                     keys=arrays["keys"], series=None, encoder=enc,
                     build_backend=build_backend)
    index = SubsequenceIndex(
        inner=inner, stream=stream, length=length, hop=hop,
        encode_seconds=float(meta.get("encode_seconds", 0.0)))
    config = (SearchConfig.from_dict(meta["config"])
              if meta.get("config") else None)
    return index, config


def is_subseq_dir(directory) -> bool:
    """True when ``directory`` holds a saved subsequence database."""
    return (Path(directory) / META_FILE).exists()
