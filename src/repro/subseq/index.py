"""Subsequence index — every sliding window of a long stream (DESIGN.md §10).

A :class:`SubsequenceIndex` ingests a stream once through the rolling
encoder (:mod:`repro.subseq.rolling` — shared sketch grid + sparse CWS,
O(N·W) total filter work) and stores only the per-window signatures and
band keys next to the raw stream: the windows themselves are never
materialised.  Search runs the standard three stages —

  1. cached query signature (the index's LRU, same as ``SSHIndex``),
  2. device collision probe over the (nw, K) window signatures,
  3. the unified re-rank (``repro.core.rerank``) with survivor windows
     *gathered lazily* from the stream (a survivor costs one (C, L)
     slice, not an up-front (nw, L) copy) —

followed by UCR-style trivial-match suppression: returned offsets are
pairwise at least ``exclusion_zone`` apart (default L//2), selected
greedily from a DTW-ranked oversampled pool.  Matching is on the RAW
windows (no per-window z-normalisation) — that is what makes the rolling
encode bit-identical to encoding each window separately, and what the
exactness tests compare against (brute-force DTW over raw windows).

``extend_stream`` appends tail points and rolls signatures for exactly
the new windows (the suffix re-encode starts at the first new window's
offset, so every projection sees the same operand values as a full
rebuild — signatures are bit-identical to rebuilding from scratch),
folding them in through the streaming ingest path.
"""
from __future__ import annotations

import dataclasses
import time
from types import SimpleNamespace
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.timing import STAGES, StageTimer
from repro.core import rerank as rr
from repro.core.index import SSHIndex, SSHParams
from repro.core.search import SearchResult, hash_probe
from repro.db.config import SearchConfig
from repro.kernels import ops
from repro.subseq.rolling import num_windows, rolling_signatures


@dataclasses.dataclass
class SubsequenceResult(SearchResult):
    """Sequence-level ``SearchResult`` plus subsequence coordinates.

    ``ids`` are window indices (offset = id · hop); ``offsets`` are the
    matches' start positions in the stream, best first.
    """
    offsets: Optional[np.ndarray] = None   # (k,) stream start positions
    n_windows: int = 0
    stream_length: int = 0


class _LazyWindows:
    """Duck-typed ``index.series`` for the re-rank: row j is the stream
    slice [j·h, j·h + L), gathered only when indexed — the re-rank's one
    ``series[cand_ids]`` gather is the only window materialisation a
    query pays."""

    def __init__(self, stream: jnp.ndarray, length: int, hop: int):
        self.stream = stream
        self.length = length
        self.hop = hop
        self.shape = (num_windows(int(stream.shape[0]), length, hop),
                      length)

    def __getitem__(self, ids) -> jnp.ndarray:
        idx = jnp.asarray(ids)
        pos = idx[..., None] * self.hop + jnp.arange(self.length)
        return self.stream[pos]


@dataclasses.dataclass
class SubsequenceIndex:
    """Sliding-window index over one long stream.

    ``inner`` is a stock :class:`SSHIndex` whose rows are the stream's
    windows (``series=None`` — raw data lives on ``stream``), so the
    probe, signature cache, persistence-shape validation, and streaming
    fold all reuse the sequence-level machinery unchanged.
    """
    inner: SSHIndex
    stream: np.ndarray            # (n,) float32 — the raw data
    length: int                   # window length L
    hop: int                      # window start spacing h
    encode_seconds: float = 0.0   # cumulative rolling-encode wall clock

    # -- construction ------------------------------------------------------
    @classmethod
    def build(cls, stream, spec, *, length: int, hop: int = 1,
              backend: str = "auto") -> "SubsequenceIndex":
        """Index every length-``length`` window (starts 0, h, 2h, …) of
        ``stream`` via one rolling encode.  ``spec`` is an ``IndexSpec``
        (an ``SSHParams`` lowers via ``to_spec()``); ``backend`` pins the
        resolved sketch kernel, exactly like ``SSHIndex.build``."""
        from repro.encoders import make_encoder
        if isinstance(spec, SSHParams):
            spec = spec.to_spec()
        stream = np.ascontiguousarray(np.asarray(stream,
                                                 np.float32).ravel())
        nw = num_windows(stream.shape[0], length, hop)
        if nw == 0:
            raise ValueError(
                f"stream of {stream.shape[0]} points holds no window of "
                f"length {length}")
        resolved = ops.backend_name(ops.resolve_backend(backend))
        enc = make_encoder(spec, length=length)
        t0 = time.perf_counter()
        sigs = rolling_signatures(jnp.asarray(stream), enc, length, hop,
                                  backend=resolved)
        keys = jax.block_until_ready(enc.band_keys(sigs))
        dt = time.perf_counter() - t0
        inner = SSHIndex(fns=None, signatures=sigs, keys=keys,
                         series=None, encoder=enc, build_backend=resolved)
        return cls(inner=inner, stream=stream, length=length, hop=hop,
                   encode_seconds=dt)

    # -- views -------------------------------------------------------------
    @property
    def num_windows(self) -> int:
        return int(self.inner.signatures.shape[0])

    def __len__(self) -> int:
        return self.num_windows

    @property
    def encoder(self):
        return self.inner.enc

    @property
    def build_backend(self) -> str:
        return self.inner.build_backend

    def offsets(self) -> np.ndarray:
        """(nw,) stream start position of every indexed window."""
        return np.arange(self.num_windows, dtype=np.int64) * self.hop

    def window(self, j: int) -> np.ndarray:
        """Window ``j``'s raw points (a stream view, not a copy)."""
        lo = int(j) * self.hop
        return self.stream[lo:lo + self.length]

    def nbytes(self) -> int:
        """Signatures + keys + encoder state + the raw stream itself."""
        return self.inner.nbytes() + int(self.stream.nbytes)

    # -- search ------------------------------------------------------------
    def search(self, query, config: Optional[SearchConfig] = None
               ) -> SubsequenceResult:
        """Top-k non-trivially-overlapping windows by banded DTW.

        The probe + re-rank run at an oversampled ``topk`` (enough DTW'd
        survivors to fill k picks even when near-duplicate shifted
        windows dominate the ranking), then matches are selected greedily
        best-first, skipping any window within ``exclusion_zone`` points
        of an already-picked offset.  Rank 1 equals the brute-force DTW
        argmin (pinned by tests); deeper ranks are the standard UCR-style
        approximation (exact within the DTW'd pool).
        """
        config = SearchConfig() if config is None else config
        config.validate()
        if config.subseq_window is not None \
                and config.subseq_window != self.length:
            raise ValueError(
                f"config.subseq_window={config.subseq_window} does not "
                f"match the indexed window length {self.length}")
        t0 = time.perf_counter()
        query = jnp.asarray(query, jnp.float32)
        if query.shape != (self.length,):
            raise ValueError(
                f"query must be one window of shape ({self.length},), "
                f"got {tuple(query.shape)}")
        nw = self.num_windows
        excl = (self.length // 2 if config.exclusion_zone is None
                else int(config.exclusion_zone))
        oversample = (max(2, excl // max(self.hop, 1) + 1)
                      if excl > 0 else 1)

        timer = StageTimer(enabled=config.stage_timings,
                           prefill=STAGES + ("encode_amortized",))
        probe_stats: dict = {}
        cand_ids = hash_probe(query, self.inner, config.top_c,
                              rank_by_signature=config.rank_by_signature,
                              multiprobe_offsets=config.multiprobe_offsets,
                              topk=config.topk, backend=config.backend,
                              timer=timer, probe_stats=probe_stats)
        n_hash = int(cand_ids.shape[0])
        topk_eff = min(n_hash, config.topk * oversample)

        if timer.enabled:
            # the per-query share of the build-side rolling encode — the
            # amortised stage a per-window encoder would pay at query
            # time; published through stats.stage_seconds by rerank
            timer.timings["encode_amortized"] = \
                self.encode_seconds / max(nw, 1)
        adapter = SimpleNamespace(
            series=_LazyWindows(jnp.asarray(self.stream), self.length,
                                self.hop),
            env_radius=None, env_upper=None, env_lower=None)
        ids, dists, stats = rr.rerank(query, cand_ids, adapter, topk_eff,
                                      config.band,
                                      use_lb_cascade=config.use_lb_cascade,
                                      backend=config.backend,
                                      seed_size=config.seed_size,
                                      early_abandon=config.early_abandon,
                                      timer=timer)

        # UCR-style exclusion zone: greedy best-first, skip overlaps
        picked: list = []
        sel: list = []
        for i in range(ids.shape[0]):
            off = int(ids[i]) * self.hop
            if all(abs(off - p) >= excl for p in picked):
                sel.append(i)
                picked.append(off)
                if len(sel) == config.topk:
                    break
        sel_a = np.asarray(sel, np.int64)
        out_ids = np.asarray(ids)[sel_a]
        out_dists = np.asarray(dists)[sel_a]

        stats.n_windows = nw
        stats.sig_cache_hit = probe_stats.get("sig_cache_hit", 0)
        stats.index_bytes = self.nbytes()
        wall = time.perf_counter() - t0
        return SubsequenceResult(
            ids=out_ids, dists=out_dists,
            n_candidates=stats.n_dtw, n_database=nw,
            pruned_by_hash_frac=1.0 - n_hash / nw,
            pruned_total_frac=1.0 - stats.n_dtw / nw,
            wall_seconds=wall, stats=stats,
            offsets=out_ids * self.hop, n_windows=nw,
            stream_length=int(self.stream.shape[0]))

    # -- growth ------------------------------------------------------------
    def extend_stream(self, tail) -> int:
        """Append points; index exactly the windows they complete.

        Only the stream suffix from the first new window's offset is
        re-encoded — every projection there contracts the same operand
        values a full rebuild would, so the appended signatures are
        bit-identical to rebuilding over the whole extended stream.
        Returns the number of new windows.
        """
        from repro.streaming.ingest import StreamIngestor
        tail = np.asarray(tail, np.float32).ravel()
        if tail.size == 0:
            return 0
        new_stream = np.ascontiguousarray(
            np.concatenate([self.stream, tail]))
        nw_old = self.num_windows
        n_new = num_windows(new_stream.shape[0], self.length,
                            self.hop) - nw_old
        if n_new > 0:
            first_off = nw_old * self.hop
            t0 = time.perf_counter()
            sigs = rolling_signatures(
                jnp.asarray(new_stream[first_off:]), self.inner.enc,
                self.length, self.hop, backend=self.inner.build_backend)
            keys = jax.block_until_ready(self.inner.enc.band_keys(sigs))
            self.encode_seconds += time.perf_counter() - t0
            # fold through the streaming ingest path (seq-ordered,
            # width-validated), series-less: the stream IS the raw data
            ing = StreamIngestor(self.inner.enc, shard="subseq",
                                 backend=self.inner.build_backend)
            ing.append_encoded(np.asarray(sigs), np.asarray(keys))
            art = ing.artifacts()
            self.inner.insert_encoded(art.series, art.signatures,
                                      art.keys)
        self.stream = new_stream
        return max(n_new, 0)

    # -- persistence -------------------------------------------------------
    def save(self, directory, config: Optional[SearchConfig] = None,
             n_shards: int = 1):
        from repro.subseq.persistence import save_subseq
        return save_subseq(directory, self, config, n_shards=n_shards)

    @classmethod
    def load(cls, directory):
        """(index, config) — see :func:`repro.subseq.persistence.load_subseq`."""
        from repro.subseq.persistence import load_subseq
        return load_subseq(directory)
