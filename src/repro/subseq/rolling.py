"""Rolling sketch + sparse window signatures (DESIGN.md §10).

A subsequence index encodes EVERY sliding window (length L, hop h) of a
long stream.  Encoding the windows independently costs O(N·L·W/h)
filter work plus a dense O(K·D) CWS per window; the rolling path shares
three stages across overlapping windows:

* **sketch** — the strided projection at absolute stream position p,
  ``<x[p:p+W], f>``, is window-independent: window j's i-th sketch tap
  reads position j·h + i·δ.  All taps live on the stride-g grid with
  g = gcd(h, δ), so ONE ``sketch_conv`` pass over the stream at stride g
  (O(N·W) total) followed by an index gather yields every window's
  bit-profile — bit-identical to sketching each window separately,
  because each projection contracts exactly the same operand values.
* **shingle ids** — when h % δ == 0 every window lies on one stride-δ
  bit grid, so n-gram packing runs once over the global bit string and
  window j takes the column slice [j·h/δ, j·h/δ + S): consecutive
  windows share all but h/δ shingles.  That is the *delta-histogram
  invariant* — window j+1's histogram is window j's minus the h/δ
  outgoing shingles plus the h/δ incoming ones — pinned by
  :func:`delta_histograms` (a ``lax.scan`` carrying one dense
  histogram, the reference the sparse fast path is tested against).
* **sparse CWS** — a window holds only S = N_B − n + 1 shingle slots
  per filter (≪ the 2^n histogram space), so the CWS argmin runs over
  the active bins only (params gathered per id, slot weight = the
  slot's multiplicity) instead of the dense D-bin grid: O(K·S) per
  window instead of O(K·D).  Slots are sorted by id so exact value
  ties resolve to the smallest bin index, matching the dense
  ``cws_hash`` argmin tie-break bit for bit.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import minhash, shingle
from repro.encoders.pipeline import (CWSHasher, GaussianFilterSketcher,
                                     NgramShingler, PipelineEncoder)
from repro.kernels import ops

#: Window rows per compiled chunk on the sparse path — bounds the
#: (K, chunk, S') gathered-param temporaries while keeping one traced
#: program for arbitrarily long streams (the tail chunk edge-pads).
SPARSE_CHUNK = 2048
#: Dense-fallback chunk (materialises (chunk, D) histograms).
DENSE_CHUNK = 256


def num_windows(stream_len: int, length: int, hop: int) -> int:
    """Sliding-window count: 0 when the stream is shorter than one
    window, else (n − L)//h + 1."""
    if length < 1 or hop < 1:
        raise ValueError(f"length and hop must be >= 1, got length="
                         f"{length}, hop={hop}")
    if stream_len < length:
        return 0
    return (stream_len - length) // hop + 1


def rolling_sketch_bits(stream: jnp.ndarray, filters: jnp.ndarray,
                        step: int, length: int, hop: int, *,
                        use_pallas: Optional[bool] = None,
                        interpret: bool = False) -> jnp.ndarray:
    """Bit-profiles of every sliding window via one shared projection.

    stream (n,), filters (W, F) -> (num_windows, N_B, F) uint8, with
    N_B = (L − W)//δ + 1 — bit-identical to
    ``ops.sketch_bits(windows, filters, step)`` over the materialised
    windows (same backend), at O(N·W) total filter work.
    """
    stream = jnp.asarray(stream)
    if stream.ndim != 1:
        raise ValueError(f"stream must be 1-D, got shape {stream.shape}")
    w = int(filters.shape[0])
    if length < w:
        raise ValueError(f"window length {length} < filter width {w}")
    nw = num_windows(int(stream.shape[0]), length, hop)
    if nw == 0:
        raise ValueError(
            f"stream of {int(stream.shape[0])} points holds no window of "
            f"length {length}")
    n_b = (length - w) // step + 1
    g = math.gcd(hop, step)
    gbits = ops.sketch_bits_stream(stream, filters, g,
                                   use_pallas=use_pallas,
                                   interpret=interpret)      # (P, F)
    # window j's i-th tap sits at absolute position j·h + i·δ — always a
    # multiple of g, and ≤ n − W, so it indexes the shared grid exactly
    grid = (np.arange(nw, dtype=np.int64)[:, None] * hop
            + np.arange(n_b, dtype=np.int64)[None, :] * step) // g
    return gbits[jnp.asarray(grid)]                          # (nw, N_B, F)


def global_shingle_ids(gbits: jnp.ndarray, ngram: int) -> jnp.ndarray:
    """Offset-adjusted n-gram ids of the global bit string.

    gbits (P, F) -> (F, P − n + 1) int32 — filter f's id at column i is
    ``pack(bits[i:i+n, f]) + (f << n)``, the exact flat bin index
    ``shingle_histogram`` scatters into.  Aligned windows (h % δ == 0)
    take contiguous column slices of this array, sharing all but h/δ
    ids with each neighbour.
    """
    ids = shingle.pack_ngrams(gbits.T, ngram)                # (F, P-n+1)
    offs = (jnp.arange(gbits.shape[1], dtype=jnp.int32) << ngram)[:, None]
    return ids + offs


@functools.partial(jax.jit, static_argnames=("s", "shift", "nw", "dim"))
def delta_histograms(global_ids: jnp.ndarray, s: int, shift: int,
                     nw: int, dim: int) -> jnp.ndarray:
    """Reference delta-histogram scan over aligned windows.

    ``global_ids`` (F, P') from :func:`global_shingle_ids`; window j
    covers columns [j·shift, j·shift + s).  Returns (nw, dim) int32
    histograms computed *incrementally*: histogram j equals histogram
    j−1 minus the ``shift`` outgoing columns plus the ``shift`` incoming
    ones — the invariant that lets the production path carry only the
    per-window active slots.  Tests pin this against per-window
    ``shingle_histogram``; production encoding never materialises dense
    histograms (use a small ``dim`` here).
    """
    f = global_ids.shape[0]
    hist0 = jnp.zeros((dim,), jnp.int32).at[
        global_ids[:, :s].reshape(-1)].add(1)

    def step(hist, j):
        out_cols = jax.lax.dynamic_slice(
            global_ids, (0, (j - 1) * shift), (f, shift))
        in_cols = jax.lax.dynamic_slice(
            global_ids, (0, (j - 1) * shift + s), (f, shift))
        hist = hist.at[out_cols.reshape(-1)].add(-1)
        hist = hist.at[in_cols.reshape(-1)].add(1)
        return hist, hist

    if nw == 1:
        return hist0[None]
    _, hists = jax.lax.scan(step, hist0, jnp.arange(1, nw))
    return jnp.concatenate([hist0[None], hists], axis=0)


# ---------------------------------------------------------------------------
# sparse CWS over the active slots
# ---------------------------------------------------------------------------

@jax.jit
def _sparse_cws(ids: jnp.ndarray, r: jnp.ndarray, log_c: jnp.ndarray,
                beta: jnp.ndarray) -> jnp.ndarray:
    """0-bit CWS over each row's active bins only.

    ids (C, S') int32, ascending per row (ties in the argmin then
    resolve to the smallest bin, matching dense ``cws_hash``); CWS
    params (K, D).  Returns (C, K) int32 — the same elementwise
    arithmetic as ``minhash.cws_hash`` evaluated at the active bins,
    with each slot's weight its multiplicity within the row (== the
    dense histogram count at that bin).
    """
    w = jnp.sum(ids[:, :, None] == ids[:, None, :], axis=-1)  # (C, S')
    w = w.astype(jnp.float32)
    logw = jnp.where(w > 0, jnp.log(jnp.maximum(w, 1e-30)), 0.0)
    rg, cg, bg = r[:, ids], log_c[:, ids], beta[:, ids]       # (K, C, S')
    t = jnp.floor(logw[None] / rg + bg)
    ln_a = cg - rg * (t - bg) - rg
    slot = jnp.argmin(ln_a, axis=2)                           # (K, C)
    return jnp.take_along_axis(ids, slot.T, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("ngram",))
def _window_ids_from_bits(bits: jnp.ndarray, ngram: int) -> jnp.ndarray:
    """Per-window sorted flat shingle ids: (C, N_B, F) -> (C, F·S)."""
    c, n_b, f = bits.shape
    ids = shingle.pack_ngrams(bits.transpose(0, 2, 1), ngram)  # (C, F, S)
    offs = (jnp.arange(f, dtype=jnp.int32) << ngram)[None, :, None]
    return jnp.sort((ids + offs).reshape(c, -1), axis=1)


@jax.jit
def _window_ids_from_global(gids: jnp.ndarray, starts: jnp.ndarray,
                            col: jnp.ndarray) -> jnp.ndarray:
    """Aligned-window slice gather: global ids (F, P'), window start
    columns (C,), col offsets (S,) -> sorted (C, F·S)."""
    cols = starts[:, None] + col[None, :]                     # (C, S)
    ids = gids[:, cols]                                       # (F, C, S)
    c = starts.shape[0]
    return jnp.sort(ids.transpose(1, 0, 2).reshape(c, -1), axis=1)


def _chunked(fn, blocks, n, chunk):
    """Run ``fn`` over fixed-size row chunks of each array in ``blocks``
    (edge-padded tail so one traced program serves any n)."""
    out = []
    for lo in range(0, n, chunk):
        hi = min(lo + chunk, n)
        parts = [b[lo:hi] for b in blocks]
        pad = chunk - (hi - lo)
        if pad:
            parts = [jnp.concatenate(
                [p, jnp.broadcast_to(p[-1:], (pad,) + p.shape[1:])])
                for p in parts]
        res = fn(*parts)
        out.append(res[:hi - lo] if pad else res)
    return jnp.concatenate(out, axis=0)


# ---------------------------------------------------------------------------
# the public entry: signatures of every window
# ---------------------------------------------------------------------------

def _check_encoder(encoder) -> None:
    if not isinstance(encoder, PipelineEncoder) \
            or not isinstance(encoder.sketcher, GaussianFilterSketcher):
        raise ValueError(
            "subsequence indexing requires a strided-filter sketch "
            "encoder (PipelineEncoder with a GaussianFilterSketcher); "
            f"got {type(encoder).__name__}")
    if not encoder.materialized:
        raise ValueError("encoder is not materialized")


def rolling_signatures(stream: jnp.ndarray, encoder, length: int,
                       hop: int, *, backend: str = "auto",
                       chunk: int = SPARSE_CHUNK) -> jnp.ndarray:
    """CWS signatures of every sliding window of ``stream``.

    Bit-identical to ``encoder.encode_batch(windows, backend=...)`` over
    the materialised windows, at O(N·W) shared sketch work plus O(K·S)
    sparse CWS per window (S = N_B − n + 1 active slots) instead of
    O(L·W/δ + K·D) per window.  Returns (num_windows, K) int32.

    The sparse path requires the stock n-gram shingler + CWS hasher
    (the ``"ssh"`` encoder family); other stage combinations fall back
    to dense per-window histograms over the shared rolling sketch.
    """
    _check_encoder(encoder)
    stream = jnp.asarray(stream, jnp.float32)
    state = encoder.state()
    sketcher, shingler, hasher = \
        encoder.sketcher, encoder.shingler, encoder.hasher
    step = sketcher.step
    w = sketcher.window
    nw = num_windows(int(stream.shape[0]), length, hop)
    if nw == 0:
        raise ValueError(
            f"stream of {int(stream.shape[0])} points holds no window of "
            f"length {length}")
    n_b = (length - w) // step + 1
    use_pallas = PipelineEncoder._use_pallas(backend)

    fast = type(shingler) is NgramShingler and isinstance(hasher, CWSHasher)
    if not fast:
        bits = rolling_sketch_bits(stream, state["filters"], step, length,
                                   hop, use_pallas=use_pallas)
        fn = getattr(encoder, "_subseq_dense_fn", None)
        if fn is None:
            fn = jax.jit(jax.vmap(
                lambda b: hasher.hash(shingler.histogram(b), state)))
            encoder._subseq_dense_fn = fn
        return _chunked(fn, [bits], nw, min(chunk, DENSE_CHUNK))

    ngram = shingler.ngram
    if n_b < ngram:
        raise ValueError(
            f"window length {length} yields only {n_b} sketch bits — "
            f"fewer than the shingle length {ngram}")
    s = n_b - ngram + 1
    params = CWSHasher.cws_params(state)

    if hop % step == 0:
        # aligned: all windows share one stride-δ bit grid — sketch AND
        # n-gram packing run once over the stream; window j is the
        # column slice [j·h/δ, j·h/δ + S) of the global ids
        gbits = ops.sketch_bits_stream(stream, state["filters"], step,
                                       use_pallas=use_pallas)
        gids = global_shingle_ids(gbits, ngram)               # (F, P')
        starts = jnp.arange(nw, dtype=jnp.int32) * (hop // step)
        col = jnp.arange(s, dtype=jnp.int32)
        ids_fn = functools.partial(_window_ids_from_global, gids, col=col)
        sig_fn = lambda st: _sparse_cws(ids_fn(st), params.r,
                                        params.log_c, params.beta)
        return _chunked(sig_fn, [starts], nw, chunk)

    # unaligned hops still share the stride-gcd sketch grid; packing is
    # per window (cheap: F·S·n static shifts)
    bits = rolling_sketch_bits(stream, state["filters"], step, length,
                               hop, use_pallas=use_pallas)
    sig_fn = lambda b: _sparse_cws(_window_ids_from_bits(b, ngram),
                                   params.r, params.log_c, params.beta)
    return _chunked(sig_fn, [bits], nw, chunk)
