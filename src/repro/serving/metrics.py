"""Serving metrics — latency percentiles, queue depth, throughput, pruning.

Single process, thread-safe.  The engine records into a
``ServingMetrics`` instance; ``snapshot()`` renders a flat dict suitable
for logging or a /metrics endpoint.  Latencies keep a bounded reservoir
(most recent ``window`` samples) so percentiles track the live traffic
rather than the whole process history.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Dict, Optional

# the canonical pipeline stages plus the distributed fan-out's
# unsplittable "fused" program — one definition, owned by the schema
from repro.bench.schema import STAGE_KEYS


class LatencyTracker:
    """Bounded reservoir of recent latencies with percentile readout."""

    def __init__(self, window: int = 4096):
        self._samples: deque = deque(maxlen=window)

    def record(self, seconds: float) -> None:
        self._samples.append(float(seconds))

    def __len__(self) -> int:
        return len(self._samples)

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile over the reservoir (0 when empty)."""
        if not self._samples:
            return 0.0
        xs = sorted(self._samples)
        rank = min(len(xs) - 1, max(0, int(round(p / 100.0 * (len(xs) - 1)))))
        return xs[rank]


class ThroughputTracker:
    """Completions-per-second over a sliding time window.

    The denominator is the observation span — elapsed time since the
    tracker was created, capped at the window — not the span between
    stamps: a single burst of completions must not divide by the
    near-zero gap to the snapshot and report absurd rates.
    """

    def __init__(self, window_seconds: float = 60.0):
        self.window_seconds = window_seconds
        self._stamps: deque = deque()
        self._t0 = time.perf_counter()

    def record(self, n: int = 1, now: Optional[float] = None) -> None:
        now = time.perf_counter() if now is None else now
        for _ in range(n):
            self._stamps.append(now)
        self._trim(now)

    def _trim(self, now: float) -> None:
        horizon = now - self.window_seconds
        while self._stamps and self._stamps[0] < horizon:
            self._stamps.popleft()

    def restart_clock(self, now: Optional[float] = None) -> None:
        """Restart the observation span (e.g. when serving begins, so
        setup/warm-up time does not dilute the rate)."""
        self._t0 = time.perf_counter() if now is None else now

    def rate(self, now: Optional[float] = None) -> float:
        now = time.perf_counter() if now is None else now
        self._trim(now)
        if not self._stamps:
            return 0.0
        span = max(min(now - self._t0, self.window_seconds), 1e-6)
        return len(self._stamps) / span


class RunningMean:
    def __init__(self):
        self.n = 0
        self.total = 0.0

    def record(self, x: float) -> None:
        self.n += 1
        self.total += float(x)

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class ServingMetrics:
    """All engine counters behind one lock."""

    def __init__(self, latency_window: int = 4096,
                 throughput_window_seconds: float = 60.0):
        self._lock = threading.Lock()
        self.latency = LatencyTracker(latency_window)
        self.queue_latency = LatencyTracker(latency_window)
        self.throughput = ThroughputTracker(throughput_window_seconds)
        self.batch_size = RunningMean()
        # adaptive-batching observability: time each batch's head request
        # waited before dispatch, fraction of max_batch actually filled,
        # exact batch-size counts, and a windowed reservoir of queue
        # depths (sampled at every enqueue and batch dispatch) so the
        # load harness reads depth percentiles, not just the last gauge
        self.batch_wait = RunningMean()
        self.batch_occupancy = RunningMean()
        self.batch_sizes: Dict[int, int] = {}
        self.queue_depths = LatencyTracker(latency_window)
        self.pruned_by_hash = RunningMean()
        self.pruned_total = RunningMean()
        self.lb_pruned = RunningMean()     # LB-cascade fraction of top-C
        self.dtw_abandoned = RunningMean()  # early-abandoned DTW lanes
        # per-batch stage wall clock (repro.bench stage telemetry)
        self.stage_seconds = {s: RunningMean() for s in STAGE_KEYS}
        self.requests_total = 0
        self.batches_total = 0
        self.inserts_total = 0
        # queries whose encode was served from the signature LRU
        # (repro.encoders.sigcache) — repeated-query traffic shows up
        # here instead of in the encode stage seconds
        self.sig_cache_hits = 0
        # fleet resilience counters (repro.fleet; stay 0 elsewhere):
        # shard calls re-issued on a lapsed hedging deadline, shard
        # calls re-issued after a worker fault, queries any of whose
        # shards were answered by a non-primary replica, and shards
        # moved by drain/fail/resize rebalances
        self.hedged_total = 0
        self.failovers_total = 0
        self.degraded_total = 0
        self.rebalanced_shards_total = 0
        self.queue_depth = 0
        # resident bytes of the served index (SSHIndex.nbytes) — a gauge,
        # refreshed per batch so streaming inserts/folds show up; the
        # sketch-vs-exact memory claim reads straight off serving_bench
        self.index_bytes = 0

    # -- recording hooks (called by the engine) ---------------------------
    def on_start(self) -> None:
        with self._lock:
            self.throughput.restart_clock()

    def on_enqueue(self, depth: int) -> None:
        with self._lock:
            self.queue_depth = depth
            self.queue_depths.record(depth)

    def on_batch(self, batch_size: int, latencies_s, queue_waits_s,
                 pruned_by_hash_frac, pruned_total_frac,
                 depth_after: int, lb_pruned_frac=(),
                 dtw_abandoned_frac=(),
                 stage_seconds: Optional[Dict[str, float]] = None,
                 sig_cache_hits: int = 0, hedged: int = 0,
                 failovers: int = 0, degraded: int = 0,
                 batch_wait_s: Optional[float] = None,
                 batch_occupancy: Optional[float] = None) -> None:
        with self._lock:
            self.sig_cache_hits += int(sig_cache_hits)
            self.hedged_total += int(hedged)
            self.failovers_total += int(failovers)
            self.degraded_total += int(degraded)
            self.batches_total += 1
            self.requests_total += batch_size
            self.batch_size.record(batch_size)
            self.batch_sizes[batch_size] = \
                self.batch_sizes.get(batch_size, 0) + 1
            if batch_wait_s is not None:
                self.batch_wait.record(batch_wait_s)
            if batch_occupancy is not None:
                self.batch_occupancy.record(batch_occupancy)
            self.queue_depth = depth_after
            self.queue_depths.record(depth_after)
            self.throughput.record(batch_size)
            for s in latencies_s:
                self.latency.record(s)
            for s in queue_waits_s:
                self.queue_latency.record(s)
            for f in pruned_by_hash_frac:
                self.pruned_by_hash.record(f)
            for f in pruned_total_frac:
                self.pruned_total.record(f)
            for f in lb_pruned_frac:
                self.lb_pruned.record(f)
            for f in dtw_abandoned_frac:
                self.dtw_abandoned.record(f)
            for stage, sec in (stage_seconds or {}).items():
                if stage in self.stage_seconds:
                    self.stage_seconds[stage].record(sec)

    def on_insert(self, n_series: int) -> None:
        with self._lock:
            self.inserts_total += n_series

    def on_rebalance(self, n_shards: int) -> None:
        """Shards moved by a drain / fail / resize rebalance."""
        with self._lock:
            self.rebalanced_shards_total += int(n_shards)

    def set_index_bytes(self, n: int) -> None:
        with self._lock:
            self.index_bytes = int(n)

    # -- readout ----------------------------------------------------------
    def batch_histogram(self) -> Dict[int, int]:
        """Exact batch-size → count histogram (copy)."""
        with self._lock:
            return dict(self.batch_sizes)

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            stage_rows = {
                f"stage_{s}_us_per_batch_mean": m.mean * 1e6
                for s, m in self.stage_seconds.items() if m.n}
            return {
                **stage_rows,
                "requests_total": self.requests_total,
                "batches_total": self.batches_total,
                "inserts_total": self.inserts_total,
                "sig_cache_hits_total": self.sig_cache_hits,
                "hedged_total": self.hedged_total,
                "failovers_total": self.failovers_total,
                "degraded_total": self.degraded_total,
                "rebalanced_shards_total": self.rebalanced_shards_total,
                "queue_depth": self.queue_depth,
                "queue_depth_p50": self.queue_depths.percentile(50),
                "queue_depth_p95": self.queue_depths.percentile(95),
                "queue_depth_max": self.queue_depths.percentile(100),
                "index_bytes": self.index_bytes,
                "batch_size_mean": self.batch_size.mean,
                "batch_wait_ms_mean": self.batch_wait.mean * 1e3,
                "batch_occupancy_mean": self.batch_occupancy.mean,
                "latency_p50_ms": self.latency.percentile(50) * 1e3,
                "latency_p95_ms": self.latency.percentile(95) * 1e3,
                "latency_p99_ms": self.latency.percentile(99) * 1e3,
                "queue_wait_p50_ms": self.queue_latency.percentile(50) * 1e3,
                "throughput_qps": self.throughput.rate(),
                "pruned_by_hash_frac_mean": self.pruned_by_hash.mean,
                "pruned_total_frac_mean": self.pruned_total.mean,
                "lb_pruned_frac_mean": self.lb_pruned.mean,
                "dtw_abandoned_frac_mean": self.dtw_abandoned.mean,
            }

    def format(self) -> str:
        s = self.snapshot()
        return (f"req={s['requests_total']:.0f} "
                f"batches={s['batches_total']:.0f} "
                f"avg_batch={s['batch_size_mean']:.1f} "
                f"p50={s['latency_p50_ms']:.1f}ms "
                f"p95={s['latency_p95_ms']:.1f}ms "
                f"p99={s['latency_p99_ms']:.1f}ms "
                f"qps={s['throughput_qps']:.1f} "
                f"pruned={s['pruned_total_frac_mean']:.1%}")
