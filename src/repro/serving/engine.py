"""ServingEngine — dynamic batching on top of the batched SSH search.

Request lifecycle (DESIGN.md §4, §12):

  client -> submit() -> request deque -> batcher thread -> ssh_search_batch
                          (condition var)  |                      |
                                           +--- pending inserts --+-> futures

The batcher pulls the first waiting request, then keeps draining the
queue until the batch closes under the config's ``BatchPolicy``:
``mode="fixed"`` closes at ``max_batch`` requests or ``max_wait_ms``
after the batch opened (the classic two-knob trade-off);
``mode="adaptive"`` computes the wait from the instantaneous queue
depth, EWMAs of per-batch service seconds and inter-submit gaps, and
whether the batch opened from an idle engine
(``BatchPolicy.wait_budget_s`` — drain immediately when the queue
covers the batch, drain at ``min_wait`` when batches open back-to-back
or arrivals are too sparse to coalesce, stretch the wait only from an
idle engine seeing dense arrivals), so the engine rides the
latency/throughput knee without hand-tuning.  Either way the batch is padded up to a *bucketed*
size (powers of two ≤ ``max_batch``) so a steady stream of ragged batch
sizes hits a handful of compiled programs instead of recompiling per
size — and since batching only changes grouping and padding geometry,
answers are bit-identical across policies (enforced by test).

The request queue is a plain deque under one ``threading.Condition``:
``submit()`` wakes the batcher directly, so an idle engine burns no CPU
and wake-on-submit latency is not quantized by any poll interval.

Streaming inserts are routed through ``SSHIndex.insert`` on the batcher
thread, between batches — queries never race an index mutation, and every
query submitted after ``insert()`` returns is served by an index that
contains the new series.

Shard fan-out: ``DistributedSearcher`` answers the same ``search_batch``
contract through ``repro.distributed.dist_index`` (shard_map collision
scan + local DTW + one all_gather per query), so the engine can sit in
front of a multi-chip index unchanged.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.index import SSHIndex
from repro.core.rerank import SearchStats
from repro.core.search import SearchResult
from repro.db.config import SearchConfig
from repro.serving.batched import BatchSearchResult, ssh_search_batch
from repro.serving.metrics import ServingMetrics


class EngineConfig:
    """Removed alias of :class:`repro.db.SearchConfig` (one deprecation
    release, retired).  Constructing it raises with migration guidance —
    the search fields moved to ``SearchConfig`` unchanged, and the
    batcher knobs now live on ``SearchConfig.batch_policy`` as a
    :class:`repro.db.BatchPolicy`.
    """

    def __init__(self, *args, **kwargs):
        raise TypeError(
            "EngineConfig was removed; construct repro.db.SearchConfig "
            "instead (same search fields — batcher knobs now live on "
            "SearchConfig.batch_policy as a repro.db.BatchPolicy)")


class BatchedSearcher:
    """Default backend: the fused local batched path.

    Precomputes the database envelopes at ``config.band`` so every
    serving-path LB_Keogh2 is an O(m) gather+compare instead of an
    O(m·r) per-query envelope (DESIGN.md §3); ``SSHIndex.insert`` keeps
    the cache aligned under streaming inserts.
    """

    def __init__(self, index: SSHIndex, config: SearchConfig):
        self.index = index
        self.config = config
        if config.band is not None and config.use_lb_cascade \
                and index.series is not None:
            index.candidate_envelopes(config.band)

    def search_batch(self, queries: jnp.ndarray) -> BatchSearchResult:
        return ssh_search_batch(queries, self.index, config=self.config)

    def insert(self, series: jnp.ndarray) -> None:
        self.index.insert(series)

    def apply_artifacts(self, artifacts) -> None:
        """Fold pre-encoded streaming artifacts (``StreamArtifacts``)
        into the index — no re-hashing; ``insert_encoded`` keeps the
        envelope cache aligned."""
        self.index.insert_encoded(artifacts.series, artifacts.signatures,
                                  artifacts.keys)


class DistributedSearcher:
    """Shard fan-out backend over ``repro.distributed.dist_index``.

    Signatures and series are row-sharded over the mesh; each query in a
    batch runs the shard_map probe (local collision scan + local top-C/P +
    local DTW, one all_gather of k·2 scalars).  Batching here amortises
    the host dispatch loop; the per-query collective schedule is
    unchanged from the dry-run path.
    """

    def __init__(self, index: SSHIndex, config: SearchConfig, mesh):
        from repro.distributed import dist_index
        if config.band is None:
            raise ValueError("DistributedSearcher requires a band radius")
        # the shard_map probe ranks by raw signatures, single probe —
        # reject configs whose answers would silently differ from it
        # (use_lb_cascade is a pruning-perf knob: results are unchanged)
        if not config.rank_by_signature or config.multiprobe_offsets > 1:
            raise ValueError(
                "DistributedSearcher supports only rank_by_signature=True "
                "and multiprobe_offsets=1")
        self.index = index
        self.config = config
        self.mesh = mesh
        self._put_index_arrays()
        # encoder-generic shard fan-out: the encoder's materialised state
        # rides as a replicated operand; "ssh"/"srp"/"ssh-multires" (and
        # out-of-tree encoders) all serve through the same schedule
        self._state = index.enc.state()
        self._query_fn = dist_index.make_encoder_query_fn(
            index.enc, mesh, config=config)

    def _put_index_arrays(self) -> None:
        """(Re-)place the index rows under the mesh's shardings."""
        import jax
        from repro.distributed import dist_index
        n = int(self.index.signatures.shape[0])
        n_dev = self.mesh.devices.size
        if n % n_dev:
            raise ValueError(
                f"index rows ({n}) must divide the mesh ({n_dev} devices) "
                f"to row-shard; pad the stream to a multiple of {n_dev}")
        sig_sh, series_sh = dist_index.index_shardings(self.mesh)
        self._series = jax.device_put(self.index.series, series_sh)
        self._sigs = jax.device_put(self.index.signatures, sig_sh)

    def search_batch(self, queries: jnp.ndarray) -> BatchSearchResult:
        from repro.bench.timing import StageTimer
        from repro.kernels import ops
        t0 = time.perf_counter()
        timer = StageTimer(enabled=self.config.stage_timings)
        b = int(queries.shape[0])
        n = int(self.index.signatures.shape[0])
        ids, dists = [], []
        for i in range(b):                       # fan-out per query row
            # the shard_map program fuses encode/probe/DTW into one
            # dispatch, so its wall clock lands under the single
            # "fused" stage key (a per-stage split would need an
            # on-device profiler, not host timers)
            with timer.stage("fused") as sync:
                gid, d = sync(self._query_fn(self._series, self._sigs,
                                             self._state, queries[i]))
            ids.append(np.asarray(gid))
            dists.append(np.asarray(d))
        top_c = self.config.top_c
        stats = SearchStats(
            backend=ops.backend_name(ops.resolve_backend(
                self.config.backend)))
        if timer.enabled:
            stats.stage_seconds = dict(timer.timings)
        return BatchSearchResult(
            ids=np.stack(ids).astype(np.int64),
            dists=np.stack(dists).astype(np.float32),
            n_queries=b, n_database=n, n_union=min(top_c, n),
            n_candidates=np.full(b, min(top_c, n), np.int64),
            pruned_by_hash_frac=np.full(b, 1.0 - min(top_c, n) / n),
            pruned_total_frac=np.full(b, 1.0 - min(top_c, n) / n),
            wall_seconds=time.perf_counter() - t0, stats=stats)

    def insert(self, series: jnp.ndarray) -> None:
        raise NotImplementedError(
            "streaming inserts into a sharded index require a reshard; "
            "stream through a StreamIngestor and fold with "
            "apply_artifacts() instead")

    def apply_artifacts(self, artifacts) -> None:
        """Fold pre-encoded streaming artifacts into the sharded index.

        The host-side index extends (signatures/keys/series), then the
        rows re-place under the same shardings — the shards receive
        *encoded* state, never raw series to re-hash.
        """
        self.index.insert_encoded(artifacts.series, artifacts.signatures,
                                  artifacts.keys)
        self._put_index_arrays()

    def resize(self, mesh) -> None:
        """Move the index to a new mesh (elastic shard count).

        Shard moves transfer the already-encoded rows and (via the
        encoder state operand) the sketch aggregate; nothing is
        re-encoded and no raw-series reshuffle happens beyond the
        device_put itself.
        """
        from repro.distributed import dist_index
        self.mesh = mesh
        self._state = self.index.enc.state()
        self._query_fn = dist_index.make_encoder_query_fn(
            self.index.enc, mesh, config=self.config)
        self._put_index_arrays()


def _lb_fracs(res: BatchSearchResult):
    """Batch-aggregate LB-cascade pruning fraction for metrics (empty when
    the backend ran no re-rank cascade, e.g. the distributed fan-out —
    whose stats exist only to carry stage timings, with n_in == 0)."""
    return ([res.stats.lb_pruned_frac]
            if res.stats is not None and res.stats.n_in else [])


def _abandon_fracs(res: BatchSearchResult):
    """Batch-aggregate early-abandoned DTW-lane fraction (empty when no
    lane entered the DTW stage, mirroring ``_lb_fracs``)."""
    return ([res.stats.dtw_abandoned_frac]
            if res.stats is not None and res.stats.n_dtw else [])


def _stage_seconds(res: BatchSearchResult):
    """Per-stage batch wall clock for metrics (None when telemetry off)."""
    return res.stats.stage_seconds if res.stats is not None else None


def _sig_hits(res: BatchSearchResult) -> int:
    """Queries in the batch whose encode came from the signature LRU."""
    return res.stats.sig_cache_hit if res.stats is not None else 0


def _fleet_counters(res: BatchSearchResult) -> dict:
    """Fleet resilience counters of the batch (zeros outside the fleet)."""
    s = res.stats
    if s is None:
        return {}
    return {"hedged": s.hedged, "failovers": s.failovers,
            "degraded": int(s.degraded)}


@dataclasses.dataclass
class _Request:
    query: jnp.ndarray
    future: Future
    t_enqueue: float


class ServingEngine:
    """Dynamic-batching query server over an SSHIndex.

    Usage::

        cfg = SearchConfig(band=8, batch_policy=BatchPolicy(max_batch=8))
        engine = ServingEngine(index, cfg)
        with engine:                       # starts the batcher thread
            fut = engine.submit(q)         # async
            res = engine.search(q)         # sync convenience
        engine.metrics.snapshot()

    (Or behind the facade: ``TimeSeriesDB`` with
    ``SearchConfig(searcher="engine")`` owns one of these.)

    ``search_batch`` bypasses the queue entirely (one caller already holds
    a full batch) but still records metrics — benchmarks use it to measure
    the compute path without batcher timing noise.
    """

    _STOP = object()

    def __init__(self, index: SSHIndex,
                 config: SearchConfig = SearchConfig(),
                 searcher=None, metrics: Optional[ServingMetrics] = None):
        self.index = index
        self.config = config
        if searcher is None:
            if config.replication > 1:
                # resilience requested: serve through the fleet tier
                # (replicated shards, hedged fan-out, drain/resize)
                from repro.fleet import FleetSearcher
                searcher = FleetSearcher(index, config)
            else:
                searcher = BatchedSearcher(index, config)
        self.searcher = searcher
        self.metrics = metrics or ServingMetrics()
        # request queue: a deque under one condition variable — submit()
        # wakes the batcher directly and _collect() reads the exact depth
        # (no polling, no qsize() approximation)
        self._cond = threading.Condition()
        self._pending: deque = deque()
        self._inserts: "queue.Queue" = queue.Queue()
        # EWMA of per-batch service seconds (stage-seconds sum when the
        # config collects them, batch wall clock otherwise) — the
        # adaptive policy's estimate of what one more batch costs
        self._service_ewma_s: Optional[float] = None
        self._arrival_gap_ewma_s: Optional[float] = None
        self._last_enqueue_t: Optional[float] = None
        self._thread: Optional[threading.Thread] = None
        # serializes index mutation vs. serving across the batcher thread
        # and direct search_batch() callers
        self._serve_lock = threading.Lock()
        # serializes submit()/insert() enqueues against stop()'s final
        # drain, so nothing enqueued concurrently with shutdown is lost.
        # States: "new" (pre-start: submits enqueue and are batched once
        # the worker starts), "running", "stopped" (submits serve on the
        # caller's thread).
        self._lifecycle_lock = threading.Lock()
        self._state = "new"

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "ServingEngine":
        if self._thread is not None:
            return self
        with self._lifecycle_lock:
            self._state = "running"
        self.metrics.on_start()
        self._thread = threading.Thread(target=self._worker,
                                        name="ssh-serving-batcher",
                                        daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        with self._cond:
            self._pending.append(self._STOP)
            self._cond.notify_all()
        self._thread.join()
        with self._lifecycle_lock:
            self._state = "stopped"
            self._thread = None
            with self._cond:
                stragglers = [r for r in self._pending
                              if r is not self._STOP]
                self._pending.clear()
        # requests/inserts that raced shutdown: resolve every future
        max_batch = self.config.batch_policy.max_batch
        for lo in range(0, len(stragglers), max_batch):
            chunk = stragglers[lo:lo + max_batch]
            try:
                results = self.search_batch(
                    jnp.stack([r.query for r in chunk], axis=0))
                for r, res in zip(chunk, results):
                    r.future.set_result(res)
            except Exception as exc:
                for r in chunk:
                    r.future.set_exception(exc)
        if not stragglers:
            with self._serve_lock:
                self._drain_inserts()

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- client API -------------------------------------------------------
    def submit(self, query: jnp.ndarray) -> Future:
        """Enqueue one query; resolves to a per-query SearchResult.

        After stop() the query is served synchronously on the caller's
        thread (the future returns already resolved) — submit() never
        leaves a future dangling, even racing stop().
        """
        fut: Future = Future()
        query = jnp.asarray(query)
        with self._lifecycle_lock:
            enqueue = self._state != "stopped"
            if enqueue:
                with self._cond:
                    now = time.perf_counter()
                    if self._last_enqueue_t is not None:
                        gap = now - self._last_enqueue_t
                        alpha = self.config.batch_policy.ewma_alpha
                        prev = self._arrival_gap_ewma_s
                        self._arrival_gap_ewma_s = gap if prev is None \
                            else alpha * gap + (1.0 - alpha) * prev
                    self._last_enqueue_t = now
                    self._pending.append(_Request(query, fut, now))
                    depth = len(self._pending)
                    self._cond.notify_all()
        if enqueue:
            self.metrics.on_enqueue(depth)
        else:
            try:
                fut.set_result(self.search_batch(query[None, :])[0])
            except Exception as exc:
                fut.set_exception(exc)
        return fut

    def search(self, query: jnp.ndarray,
               timeout: Optional[float] = None) -> SearchResult:
        """Synchronous single query (through the batcher when running)."""
        if self._state == "running":
            return self.submit(query).result(timeout=timeout)
        return self.search_batch(jnp.asarray(query)[None, :])[0]

    def search_batch(self, queries: jnp.ndarray) -> List[SearchResult]:
        """Serve a caller-assembled batch directly (no queue)."""
        queries = jnp.asarray(queries)
        t0 = time.perf_counter()
        with self._serve_lock:
            self._drain_inserts()
            res = self.searcher.search_batch(queries)
        wall = time.perf_counter() - t0
        b = int(queries.shape[0])
        self.metrics.set_index_bytes(self.index.nbytes())
        self.metrics.on_batch(
            b, [wall] * b, [0.0] * b,
            list(res.pruned_by_hash_frac[:b]),
            list(res.pruned_total_frac[:b]),
            len(self._pending),
            lb_pruned_frac=_lb_fracs(res),
            dtw_abandoned_frac=_abandon_fracs(res),
            stage_seconds=_stage_seconds(res),
            sig_cache_hits=_sig_hits(res),
            **_fleet_counters(res))
        return [res.per_query(i) for i in range(b)]

    def flush_inserts(self) -> None:
        """Apply queued streaming inserts to the index *now*.

        Normally inserts drain on the batcher thread between batches;
        persistence (``TimeSeriesDB.save``) calls this so a snapshot
        taken right after ``insert()`` returned contains the series.
        """
        with self._serve_lock:
            self._drain_inserts()

    def apply_artifacts(self, artifacts) -> None:
        """Fold pre-encoded streaming artifacts under the serve lock —
        like ``insert`` it never races an in-flight batch, and queued
        plain inserts drain first so index row order stays the arrival
        order."""
        with self._serve_lock:
            self._drain_inserts()
            self.searcher.apply_artifacts(artifacts)

    def drain(self, worker: str) -> int:
        """Gracefully retire a fleet worker while serving.

        Delegates to the fleet searcher's drain protocol: new shard
        calls route away from ``worker`` immediately, its in-flight
        calls finish and count, then its replica slots re-home from the
        published artifacts.  Queries queued in the engine keep flowing
        throughout — the batcher thread never stops, so zero queued
        queries are lost (chaos-tested in ``tests/test_fleet.py``).
        Returns the number of shards moved; raises ``AttributeError``
        when the active searcher has no drain support (not a fleet).
        """
        drain = getattr(self.searcher, "drain", None)
        if drain is None:
            raise AttributeError(
                f"searcher {type(self.searcher).__name__} does not "
                "support drain(); serve with config.replication > 1")
        moved = drain(worker)
        self.metrics.on_rebalance(moved)
        return moved

    def resize(self, workers) -> int:
        """Live fleet rebalance (int worker count or name list); returns
        shards moved.  Fleet-backed engines only."""
        resize = getattr(self.searcher, "resize", None)
        if resize is None:
            raise AttributeError(
                f"searcher {type(self.searcher).__name__} does not "
                "support resize(); serve with config.replication > 1")
        moved = resize(workers)
        self.metrics.on_rebalance(moved)
        return moved

    def insert(self, series: jnp.ndarray) -> None:
        """Streaming insert; visible to all queries submitted afterwards."""
        series = jnp.asarray(series)
        if series.ndim == 1:
            series = series[None, :]
        with self._lifecycle_lock:
            running = self._state == "running"
            if running:
                self._inserts.put(series)
        if not running:
            with self._serve_lock:
                self.searcher.insert(series)
        self.metrics.on_insert(int(series.shape[0]))

    @property
    def service_ewma_s(self) -> Optional[float]:
        """The adaptive policy's live service-time estimate (seconds per
        batch; None until the first batch completes)."""
        return self._service_ewma_s

    @property
    def arrival_gap_ewma_s(self) -> Optional[float]:
        """The adaptive policy's live inter-arrival estimate (seconds
        between submits; None until the second submit)."""
        return self._arrival_gap_ewma_s

    @property
    def queue_depth(self) -> int:
        """Requests waiting in the batcher queue right now."""
        with self._cond:
            return sum(1 for r in self._pending if r is not self._STOP)

    # -- batcher internals ------------------------------------------------
    def _drain_inserts(self) -> None:
        while True:
            try:
                series = self._inserts.get_nowait()
            except queue.Empty:
                return
            self.searcher.insert(series)

    def _pad_batch(self, queries: List[jnp.ndarray]) -> jnp.ndarray:
        """Pad to the next bucket size by repeating the first query."""
        b = len(queries)
        bucket = next(s for s in self.config.buckets() if s >= b)
        block = list(queries) + [queries[0]] * (bucket - b)
        return jnp.stack(block, axis=0)

    def _collect(self, first: _Request,
                 opened_idle: bool = True) -> List[_Request]:
        """Grow a batch around ``first`` under the config's BatchPolicy.

        The wait budget is recomputed from the live batch size and queue
        depth every time the state changes (fixed mode: constant budget),
        so the adaptive policy reacts within one condition-variable
        wake-up.  The budget counts from the moment the batch opened —
        arriving requests extend the batch, never the deadline.
        ``opened_idle`` records whether the worker had to sleep for
        ``first`` (idle engine: the adaptive policy may stretch the
        wait) or found it already queued (busy: drain at ``min_wait``).
        A ``_STOP`` sentinel is left in the deque for ``_worker``'s
        outer loop to consume.
        """
        pol = self.config.batch_policy
        batch = [first]
        t_open = time.perf_counter()
        with self._cond:
            while len(batch) < pol.max_batch:
                while self._pending and len(batch) < pol.max_batch:
                    if self._pending[0] is self._STOP:
                        return batch        # leave the sentinel in place
                    batch.append(self._pending.popleft())
                if len(batch) >= pol.max_batch:
                    break
                budget = pol.wait_budget_s(
                    len(batch), len(self._pending), self._service_ewma_s,
                    engine_idle=opened_idle,
                    arrival_gap_s=self._arrival_gap_ewma_s)
                remaining = t_open + budget - time.perf_counter()
                if remaining <= 0:
                    break
                if not self._cond.wait(timeout=remaining):
                    break                   # budget elapsed, nothing new
        return batch

    def _observe_service(self, res: BatchSearchResult,
                         wall_s: float) -> None:
        """Fold one batch's service time into the adaptive EWMA."""
        stage = _stage_seconds(res)
        sample = sum(stage.values()) if stage else wall_s
        alpha = self.config.batch_policy.ewma_alpha
        prev = self._service_ewma_s
        self._service_ewma_s = sample if prev is None \
            else alpha * sample + (1.0 - alpha) * prev

    def _worker(self) -> None:
        pol = self.config.batch_policy
        while True:
            with self._cond:
                opened_idle = not self._pending
                while not self._pending:
                    self._cond.wait()
                item = self._pending.popleft()
            if item is self._STOP:
                return
            batch = self._collect(item, opened_idle)
            t0 = time.perf_counter()
            try:                 # a failing insert also fails the batch
                with self._serve_lock:       # loudly (and keeps the worker
                    self._drain_inserts()    # alive for later requests)
                    block = self._pad_batch([r.query for r in batch])
                    res = self.searcher.search_batch(block)
            except Exception as exc:
                for r in batch:
                    r.future.set_exception(exc)
                continue
            done = time.perf_counter()
            self._observe_service(res, done - t0)
            for i, r in enumerate(batch):
                r.future.set_result(res.per_query(i))
            self.metrics.set_index_bytes(self.index.nbytes())
            self.metrics.on_batch(
                len(batch),
                [done - r.t_enqueue for r in batch],
                [t0 - r.t_enqueue for r in batch],
                list(res.pruned_by_hash_frac[:len(batch)]),
                list(res.pruned_total_frac[:len(batch)]),
                len(self._pending),
                lb_pruned_frac=_lb_fracs(res),
                dtw_abandoned_frac=_abandon_fracs(res),
                stage_seconds=_stage_seconds(res),
                sig_cache_hits=_sig_hits(res),
                batch_wait_s=t0 - batch[0].t_enqueue,
                batch_occupancy=len(batch) / pol.max_batch,
                **_fleet_counters(res))
