"""Batched query-serving engine for the SSH index (DESIGN.md §4).

Public API:
  ssh_search_batch / batch_probe / BatchSearchResult — batched primitives
  ServingEngine / EngineConfig                       — dynamic batcher
  BatchedSearcher / DistributedSearcher              — compute backends
  ServingMetrics                                     — latency/throughput
"""
from repro.serving.batched import (BatchSearchResult, batch_probe,
                                   ssh_search_batch)
from repro.serving.engine import (BatchedSearcher, DistributedSearcher,
                                  EngineConfig, ServingEngine)
from repro.serving.metrics import ServingMetrics

__all__ = [
    "BatchSearchResult", "batch_probe", "ssh_search_batch",
    "BatchedSearcher", "DistributedSearcher", "EngineConfig",
    "ServingEngine", "ServingMetrics",
]
