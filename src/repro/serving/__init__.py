"""Batched query-serving engine for the SSH index (DESIGN.md §4).

Public API:
  ssh_search_batch / batch_probe / BatchSearchResult — batched primitives
  ServingEngine                                      — dynamic batcher
  BatchedSearcher / DistributedSearcher              — compute backends
  ServingMetrics                                     — latency/throughput
  SearchConfig (re-export of repro.db.SearchConfig)  — all search knobs

The batcher's policy lives on ``SearchConfig.batch_policy`` as a
``repro.db.BatchPolicy`` (fixed or adaptive).  The former
``EngineConfig`` alias is retired — constructing it raises with
migration guidance.  Most callers should reach the engine through the
``repro.db`` facade (``TimeSeriesDB`` + ``SearchConfig(searcher="engine")``).
"""
from repro.db.config import SearchConfig
from repro.serving.batched import (BatchSearchResult, batch_probe,
                                   ssh_search_batch)
from repro.serving.engine import (BatchedSearcher, DistributedSearcher,
                                  ServingEngine)
from repro.serving.metrics import ServingMetrics

__all__ = [
    "BatchSearchResult", "batch_probe", "ssh_search_batch",
    "BatchedSearcher", "DistributedSearcher",
    "SearchConfig", "ServingEngine", "ServingMetrics",
]
