"""Batched query-serving engine for the SSH index (DESIGN.md §4).

Public API:
  ssh_search_batch / batch_probe / BatchSearchResult — batched primitives
  ServingEngine                                      — dynamic batcher
  BatchedSearcher / DistributedSearcher              — compute backends
  ServingMetrics                                     — latency/throughput
  SearchConfig (re-export of repro.db.SearchConfig)  — all search knobs
  EngineConfig                                       — deprecated alias

Most callers should reach the engine through the ``repro.db``
facade (``TimeSeriesDB`` + ``SearchConfig(searcher="engine")``).
"""
from repro.db.config import SearchConfig
from repro.serving.batched import (BatchSearchResult, batch_probe,
                                   ssh_search_batch)
from repro.serving.engine import (BatchedSearcher, DistributedSearcher,
                                  EngineConfig, ServingEngine)
from repro.serving.metrics import ServingMetrics

__all__ = [
    "BatchSearchResult", "batch_probe", "ssh_search_batch",
    "BatchedSearcher", "DistributedSearcher", "EngineConfig",
    "SearchConfig", "ServingEngine", "ServingMetrics",
]
