"""Batched end-to-end SSH search primitives (DESIGN.md §4).

One ``ssh_search_batch`` call serves a (B, m) block of queries through the
same three stages as the sequential ``repro.core.search.ssh_search``:

  1. **Batched signatures** — one vmap'd dispatch for the whole block
     (``SSHIndex.query_signatures_batch``), multiprobe offsets included.
  2. **Batched collision top-C** — a single (B, L) × (N, L) → (B, N) count
     (fused Pallas kernel on TPU, jnp reference elsewhere) + per-row
     ``top_k``, so the database streams from HBM once per *batch*.
  3. **Unified re-rank** (``repro.core.rerank.rerank_batch``) — seed DTW
     for a per-query best-so-far, the staged LB cascade (envelopes
     precomputed on the index when available), and backend-dispatched
     banded DTW over the flattened survivor pairs gathered through the
     deduped *union* candidate table.  Total DTW work is exactly the
     batch's survivor count — sequential-optimal, with no batch-max-width
     padding and one compiled program for every batch size.

Equality contract: per-query top-k (ids and distances) is identical to
sequential ``ssh_search`` with the same parameters — the probe uses the
same integer collision counts and the same ``lax.top_k`` tie-breaking,
and the re-rank is the same ``repro.core.rerank`` pipeline (same
best-so-far, same cascade decisions, same DTW values).
``tests/test_serving.py`` holds this contract over a synthetic-ECG
database; ``tests/test_rerank.py`` additionally holds it across the
"jnp" and "pallas" backends.

Shapes are bucketed (B by the engine, U to the next power of two) so a
steady request stream hits a handful of compiled programs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.bench.timing import DISABLED, STAGES, StageTimer
from repro.core import minhash
from repro.core import rerank as rr
from repro.core.index import SSHIndex
from repro.core.rerank import SearchStats
from repro.core.search import SearchResult
from repro.db.config import SearchConfig, config_from_legacy_kwargs
from repro.kernels import ops


@dataclasses.dataclass
class BatchSearchResult:
    """Per-query top-k plus the pruning statistics of the shared stages."""
    ids: np.ndarray                   # (B, k) database ids, best first
    dists: np.ndarray                 # (B, k) squared DTW costs
    n_queries: int
    n_database: int
    n_union: int                      # distinct candidates gathered per batch
    n_candidates: np.ndarray          # (B,) candidates reaching the DTW stage
    pruned_by_hash_frac: np.ndarray   # (B,)
    pruned_total_frac: np.ndarray     # (B,)
    wall_seconds: float
    stats: Optional[SearchStats] = None   # batch-aggregate rerank counters

    @property
    def dtw_evals(self) -> int:
        """Wavefront evaluations across the batch (re-rank survivors)."""
        return int(self.n_candidates.sum())

    def per_query(self, b: int) -> SearchResult:
        """Adapter: query ``b``'s slice as a sequential SearchResult.

        Filler rows (id −1, only when a query had fewer survivors than
        topk) are trimmed so lengths match the sequential path.  The
        rerank counters are tracked per *batch*, not per query, so
        ``stats`` stays None here (the sequential invariant
        ``stats.n_dtw == n_candidates`` would not hold for a slice);
        read the aggregate from ``BatchSearchResult.stats``.
        """
        k = int(np.sum(self.ids[b] >= 0))
        return SearchResult(
            ids=self.ids[b][:k], dists=self.dists[b][:k],
            n_candidates=int(self.n_candidates[b]),
            n_database=self.n_database,
            pruned_by_hash_frac=float(self.pruned_by_hash_frac[b]),
            pruned_total_frac=float(self.pruned_total_frac[b]),
            wall_seconds=self.wall_seconds)


def batch_probe(queries: jnp.ndarray, index: SSHIndex, top_c: int,
                rank_by_signature: bool = True,
                multiprobe_offsets: int = 1,
                use_pallas: Optional[bool] = None,
                interpret: bool = False,
                timer: StageTimer = DISABLED,
                probe_stats: Optional[dict] = None):
    """Stage 1+2 for a query block: (B, m) -> ids (B, C), counts (B, C).

    Per-row decisions identical to the sequential ``hash_probe``: the same
    collision counts feed the same ``lax.top_k`` (ties → lowest id).
    An enabled ``timer`` records the batched signature build as
    ``encode`` and the collision scan + top-C as ``probe``.

    Rows ride the index's signature LRU: when EVERY row is cached the
    batched encode dispatch is skipped entirely (``probe_stats`` gets
    ``{"sig_cache_hit": B}``); a partial hit re-encodes the whole block
    — one fused dispatch beats per-row gather/encode splicing — and
    populates the cache, reporting 0 (no work was actually skipped).
    Cached rows are the arrays the encoder produced, so candidate
    decisions are unchanged either way.
    """
    b = queries.shape[0]
    top_c = min(top_c, int(index.signatures.shape[0]))
    variant = (f"mp{multiprobe_offsets}" if multiprobe_offsets > 1
               else "sig")
    with timer.stage("encode") as sync:
        cache = index._sig_cache()
        rows = np.asarray(queries)
        keys = [cache.key(rows[i], index.enc.spec, index.build_backend,
                          variant) for i in range(b)]
        cached = [cache.get(k) for k in keys]
        hits = 0
        if all(r is not None for r in cached):
            sigs = jnp.asarray(np.stack(cached))          # (B, K)|(B, O, K)
            hits = b
        elif multiprobe_offsets > 1:
            sigs = index.query_signatures_batch_multiprobe(
                queries, multiprobe_offsets)              # (B, O, K)
        else:
            sigs = index.query_signatures_batch(queries)  # (B, K)
        if not hits:
            sig_rows = np.asarray(sigs)
            for i in range(b):
                cache.put(keys[i], sig_rows[i])
        if probe_stats is not None:
            probe_stats["sig_cache_hit"] = hits
        flat = (sigs.reshape(-1, sigs.shape[-1])
                if multiprobe_offsets > 1 else sigs)      # (B·O, K)
        if rank_by_signature:
            qk, db = flat, index.signatures
        else:
            qk = minhash.combine_bands(flat,
                                       index.num_tables).astype(jnp.int32)
            db = index.keys.astype(jnp.int32)
        qk = sync(qk)
    with timer.stage("probe") as sync:
        counts = ops.collision_count_batch(qk, db, use_pallas=use_pallas,
                                           interpret=interpret)   # (B·O, N)
        if multiprobe_offsets > 1:
            counts = counts.reshape(b, multiprobe_offsets, -1).max(axis=1)
        vals, ids = jax.lax.top_k(counts, top_c)
        ids, vals = sync((ids, vals))
    return ids, vals


def ssh_search_batch(queries: jnp.ndarray, index: SSHIndex,
                     config: Optional[SearchConfig] = None, *,
                     use_pallas: Optional[bool] = None,
                     **legacy_kwargs) -> BatchSearchResult:
    """Batched paper Alg. 2 over a (B, m) query block.

    Canonical form: ``ssh_search_batch(Q, index, config=SearchConfig(...))``
    — the same frozen config every entry point consumes; returns
    per-query top-k identical to ``ssh_search(q, index, config=...)`` for
    every row q (see module docstring for why).  The ``TimeSeriesDB``
    facade routes here for ``searcher="batched"``.

    Deprecation shim (one release): loose kwargs (``topk=..., top_c=...``)
    are folded into a ``SearchConfig`` under a ``DeprecationWarning``.
    ``use_pallas`` stays a probe-only kernel override for tests (defaults
    to the config backend's resolution when unset) — it is an
    implementation toggle, not a search knob, so it lives outside the
    config.
    """
    if config is not None and not isinstance(config, SearchConfig):
        # legacy positional call ssh_search_batch(Q, index, 10): the
        # third parameter used to be topk — fold into the kwarg shim
        legacy_kwargs["topk"] = config
        config = None
    if config is None:
        config = config_from_legacy_kwargs("ssh_search_batch",
                                           legacy_kwargs)
    elif legacy_kwargs:
        raise TypeError("ssh_search_batch() takes either config= or "
                        "legacy search kwargs, not both: "
                        f"{sorted(legacy_kwargs)}")
    t0 = time.perf_counter()
    timer = StageTimer(enabled=config.stage_timings, prefill=STAGES)
    queries = jnp.asarray(queries)
    b, m = queries.shape
    n = int(index.signatures.shape[0])
    c = min(config.top_c, n)
    if use_pallas is None:
        use_pallas = ops.resolve_backend(config.backend)

    # -- stages 1+2: fused probe ------------------------------------------
    probe_stats: dict = {}
    ids_j, vals_j = batch_probe(queries, index, c,
                                rank_by_signature=config.rank_by_signature,
                                multiprobe_offsets=config.multiprobe_offsets,
                                use_pallas=use_pallas, timer=timer,
                                probe_stats=probe_stats)
    ids = np.asarray(ids_j, np.int64)                     # (B, C)
    valid = np.asarray(vals_j) > 0                        # (B, C)
    empty = ~valid.any(axis=1)
    if empty.any():            # degenerate rows: same fallback as sequential
        ids[empty] = np.arange(c, dtype=np.int64)[None, :]
        valid[empty] = True
    n_hash = valid.sum(axis=1)                            # (B,)

    # -- stage 3: unified re-rank (cascade + backend-dispatched DTW) ------
    out_ids, out_d, n_final, n_union, stats = rr.rerank_batch(
        queries, ids, valid, index, config.topk, config.band,
        use_lb_cascade=config.use_lb_cascade, backend=config.backend,
        seed_size=config.seed_size, early_abandon=config.early_abandon,
        timer=timer)
    if stats is not None:
        stats.index_bytes = index.nbytes()
        stats.sig_cache_hit = probe_stats.get("sig_cache_hit", 0)

    wall = time.perf_counter() - t0
    return BatchSearchResult(
        ids=out_ids, dists=out_d,
        n_queries=b, n_database=n, n_union=n_union,
        n_candidates=n_final,
        pruned_by_hash_frac=1.0 - n_hash / n,
        pruned_total_frac=1.0 - n_final / n,
        wall_seconds=wall, stats=stats)
