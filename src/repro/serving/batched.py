"""Batched end-to-end SSH search primitives (DESIGN.md §4).

One ``ssh_search_batch`` call serves a (B, m) block of queries through the
same three stages as the sequential ``repro.core.search.ssh_search``:

  1. **Batched signatures** — one vmap'd dispatch for the whole block
     (``SSHIndex.query_signatures_batch``), multiprobe offsets included.
  2. **Batched collision top-C** — a single (B, L) × (N, L) → (B, N) count
     (fused Pallas kernel on TPU, jnp reference elsewhere) + per-row
     ``top_k``, so the database streams from HBM once per *batch*.
  3. **Batched DTW re-rank over flattened survivor pairs** — the optional
     batched LB cascade (Lemire-style two-pass: seed DTW for a per-query
     best-so-far, then vectorised envelope bounds) marks each query's
     survivors; every surviving (query, candidate) pair becomes one row
     of a flat pair list, gathered through the deduped *union* candidate
     table and re-ranked in fixed-size banded-DTW chunks.  Total DTW work
     is exactly the batch's survivor count — sequential-optimal, with no
     batch-max-width padding and one compiled program for every batch
     size.

Equality contract: per-query top-k (ids and distances) is identical to
sequential ``ssh_search`` with the same parameters — the probe uses the
same integer collision counts and the same ``lax.top_k`` tie-breaking,
the LB cascade prunes with the same per-query best-so-far, and the DTW
values come from the same ``dtw`` scan.  ``tests/test_serving.py`` holds
this contract over a synthetic-ECG database.

Shapes are bucketed (B by the engine, U to the next power of two) so a
steady request stream hits a handful of compiled programs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import lower_bounds as lb
from repro.core.dtw import dtw, dtw_batch
from repro.core.index import SSHIndex
from repro.core.search import SearchResult
from repro.core import minhash
from repro.kernels import ops

BIG = np.float32(1e30)


@dataclasses.dataclass
class BatchSearchResult:
    """Per-query top-k plus the pruning statistics of the shared stages."""
    ids: np.ndarray                   # (B, k) database ids, best first
    dists: np.ndarray                 # (B, k) squared DTW costs
    n_queries: int
    n_database: int
    n_union: int                      # distinct candidates gathered per batch
    n_candidates: np.ndarray          # (B,) candidates reaching the DTW stage
    pruned_by_hash_frac: np.ndarray   # (B,)
    pruned_total_frac: np.ndarray     # (B,)
    wall_seconds: float

    @property
    def dtw_evals(self) -> int:
        """Wavefront evaluations across the batch (re-rank survivors)."""
        return int(self.n_candidates.sum())

    def per_query(self, b: int) -> SearchResult:
        """Adapter: query ``b``'s slice as a sequential SearchResult.

        Filler rows (id −1, only when a query had fewer survivors than
        topk) are trimmed so lengths match the sequential path.
        """
        k = int(np.sum(self.ids[b] >= 0))
        return SearchResult(
            ids=self.ids[b][:k], dists=self.dists[b][:k],
            n_candidates=int(self.n_candidates[b]),
            n_database=self.n_database,
            pruned_by_hash_frac=float(self.pruned_by_hash_frac[b]),
            pruned_total_frac=float(self.pruned_total_frac[b]),
            wall_seconds=self.wall_seconds)


def batch_probe(queries: jnp.ndarray, index: SSHIndex, top_c: int,
                rank_by_signature: bool = True,
                multiprobe_offsets: int = 1,
                use_pallas: Optional[bool] = None,
                interpret: bool = False):
    """Stage 1+2 for a query block: (B, m) -> ids (B, C), counts (B, C).

    Per-row decisions identical to the sequential ``hash_probe``: the same
    collision counts feed the same ``lax.top_k`` (ties → lowest id).
    """
    p = index.fns.params
    b = queries.shape[0]
    top_c = min(top_c, int(index.signatures.shape[0]))
    if multiprobe_offsets > 1:
        sigs = index.query_signatures_batch_multiprobe(
            queries, multiprobe_offsets)                  # (B, O, K)
        flat = sigs.reshape(-1, sigs.shape[-1])           # (B·O, K)
    else:
        sigs = index.query_signatures_batch(queries)      # (B, K)
        flat = sigs
    if rank_by_signature:
        qk, db = flat, index.signatures
    else:
        qk = minhash.combine_bands(flat, p.num_tables).astype(jnp.int32)
        db = index.keys.astype(jnp.int32)
    counts = ops.collision_count_batch(qk, db, use_pallas=use_pallas,
                                       interpret=interpret)   # (B·O, N)
    if multiprobe_offsets > 1:
        counts = counts.reshape(b, multiprobe_offsets, -1).max(axis=1)
    vals, ids = jax.lax.top_k(counts, top_c)
    return ids, vals


@functools.partial(jax.jit, static_argnames=("band",))
def _seed_dtw(queries: jnp.ndarray, seed_series: jnp.ndarray,
              band: Optional[int]) -> jnp.ndarray:
    """(B, m) x (B, s, m) -> (B, s) banded DTW of each query vs its seeds."""
    return jax.vmap(lambda q, s: dtw_batch(q, s, band=band))(queries,
                                                             seed_series)


@functools.partial(jax.jit, static_argnames=("band",))
def _cascade_rows(queries: jnp.ndarray, cand_series: jnp.ndarray,
                  band: int, best: jnp.ndarray) -> jnp.ndarray:
    """(B, m) x (B, C, m) -> (B, C) survivor mask, per-query best-so-far."""
    return jax.vmap(lambda q, cs, b_: lb.cascade(q, cs, band, b_))(
        queries, cand_series, best)


PAIR_CHUNK = 256        # survivor pairs per DTW dispatch (lane stability)
PAIR_CHUNK_SMALL = 32   # remainder granularity (bounds padding waste)


@functools.partial(jax.jit, static_argnames=("band",))
def _pair_dtw(q_rows: jnp.ndarray, c_rows: jnp.ndarray,
              band: Optional[int]) -> jnp.ndarray:
    """Pairwise DTW over aligned rows: (P, m) x (P, m) -> (P,)."""
    return jax.vmap(lambda q, c: dtw(q, c, band=band))(q_rows, c_rows)


def _rerank_pairs(q_rows: jnp.ndarray, c_rows: jnp.ndarray,
                  band: Optional[int]) -> np.ndarray:
    """Chunked pair DTW: fixed-shape (chunk, m) dispatches.

    Full PAIR_CHUNK blocks first, then the remainder at
    PAIR_CHUNK_SMALL granularity — two compiled programs serve every
    batch size and survivor count, the working set per dispatch stays
    cache-sized, and padding waste is bounded by PAIR_CHUNK_SMALL - 1
    evaluations.
    """
    p = int(q_rows.shape[0])
    pad = (-p) % PAIR_CHUNK_SMALL
    if pad:
        q_rows = jnp.concatenate([q_rows, q_rows[:1].repeat(pad, 0)], 0)
        c_rows = jnp.concatenate([c_rows, c_rows[:1].repeat(pad, 0)], 0)
    out, i, total = [], 0, p + pad
    for chunk in (PAIR_CHUNK, PAIR_CHUNK_SMALL):
        while total - i >= chunk:
            out.append(np.asarray(_pair_dtw(q_rows[i:i + chunk],
                                            c_rows[i:i + chunk], band)))
            i += chunk
    return np.concatenate(out)[:p]


def ssh_search_batch(queries: jnp.ndarray, index: SSHIndex,
                     topk: int = 10, top_c: int = 256,
                     band: Optional[int] = None,
                     use_lb_cascade: bool = True,
                     rank_by_signature: bool = True,
                     multiprobe_offsets: int = 1,
                     use_pallas: Optional[bool] = None) -> BatchSearchResult:
    """Batched paper Alg. 2 over a (B, m) query block.

    Returns per-query top-k identical to ``ssh_search(q, index, ...)`` for
    every row q (see module docstring for why).
    """
    t0 = time.perf_counter()
    queries = jnp.asarray(queries)
    b, m = queries.shape
    n = int(index.signatures.shape[0])
    c = min(top_c, n)
    k_out = min(topk, c)

    # -- stages 1+2: fused probe ------------------------------------------
    ids_j, vals_j = batch_probe(queries, index, c,
                                rank_by_signature=rank_by_signature,
                                multiprobe_offsets=multiprobe_offsets,
                                use_pallas=use_pallas)
    ids = np.asarray(ids_j, np.int64)                     # (B, C)
    valid = np.asarray(vals_j) > 0                        # (B, C)
    empty = ~valid.any(axis=1)
    if empty.any():            # degenerate rows: same fallback as sequential
        ids[empty] = np.arange(c, dtype=np.int64)[None, :]
        valid[empty] = True
    n_hash = valid.sum(axis=1)                            # (B,)

    # -- optional batched LB cascade (two-pass: seed DTW, then bounds) ----
    seed_k = min(topk, c)
    if use_lb_cascade and band is not None:
        seed_series = index.series[jnp.asarray(ids[:, :seed_k])]
        seed_d = np.asarray(_seed_dtw(queries, seed_series, band))
        best = jnp.asarray(seed_d.max(axis=1))            # per-query kth-best
        cand_series = index.series[jnp.asarray(ids)]      # (B, C, m)
        keep = np.array(_cascade_rows(queries, cand_series, band, best))
        # sequential skips the cascade entirely when n_hash <= topk ...
        keep[n_hash <= topk] = True
        # ... and never drops the seeded set (keep.at[:topk].set(True));
        # the first seed_k slots ARE the first seed_k valid candidates
        # whenever the cascade applies (top_k sorts positive counts first)
        keep[:, :seed_k] = True
        ok = valid & keep
    else:
        ok = valid
    n_final = ok.sum(axis=1)                              # (B,)

    # -- batched DTW re-rank over the flattened survivor pairs ------------
    # Every (query, survivor) pair becomes one row of a padded pair list,
    # gathered through the deduped union table: total DTW work is exactly
    # the survivor count (sequential-optimal — no batch-max-width padding)
    # and the fixed-size pair chunks keep one compiled program for every
    # batch size.
    rows_idx, cols_idx = np.nonzero(ok)                   # (P,) row-major
    pair_ids = ids[rows_idx, cols_idx]
    union = np.unique(pair_ids)                           # (U,) sorted
    union_series = index.series[jnp.asarray(union)]       # (U, m)
    pos = np.searchsorted(union, pair_ids)
    c_rows = union_series[jnp.asarray(pos)]               # (P, m)
    q_rows = queries[jnp.asarray(rows_idx)]               # (P, m)
    pair_d = _rerank_pairs(q_rows, c_rows, band)          # (P,)

    # -- per-query top-k (lax.top_k for sequential-identical tie-breaks) --
    cand_d = np.full((b, c), BIG, np.float32)             # candidate order
    cand_d[rows_idx, cols_idx] = pair_d
    neg, idx = jax.lax.top_k(-jnp.asarray(cand_d), k_out)
    idx = np.asarray(idx)
    out_ids = np.take_along_axis(ids, idx, axis=1)
    out_d = -np.asarray(neg)
    # rows with fewer than k_out survivors: mark the filler tail (fixed
    # output shapes; per_query() trims these, matching sequential lengths)
    out_ids = np.where(out_d < BIG * 0.5, out_ids, -1)

    wall = time.perf_counter() - t0
    return BatchSearchResult(
        ids=out_ids.astype(np.int64), dists=out_d.astype(np.float32),
        n_queries=b, n_database=n, n_union=int(union.shape[0]),
        n_candidates=n_final.astype(np.int64),
        pruned_by_hash_frac=1.0 - n_hash / n,
        pruned_total_frac=1.0 - n_final / n,
        wall_seconds=wall)
