"""dien [arXiv:1809.03672]: GRU + AUGRU interest evolution — embed 18,
seq 100, GRU 108, MLP 200-80."""
import dataclasses

from repro.configs.base import ArchDef, recsys_shapes
from repro.models.recsys import DIENConfig

CONFIG = DIENConfig(name="dien", embed_dim=18, seq_len=100, gru_dim=108,
                    mlp=(200, 80), vocab=2_000_000)

SMOKE = dataclasses.replace(CONFIG, vocab=1000, seq_len=10)

ARCH = ArchDef(name="dien", family="recsys", config=CONFIG,
               smoke_config=SMOKE, shapes=recsys_shapes())
