"""dbrx-132b [hf:databricks/dbrx-base]: 40L d_model=6144 48H (GQA kv=8),
16 experts top-4, expert d_ff=10752, vocab=100352."""
import dataclasses

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="dbrx-132b", n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab=100352,
    moe=True, n_experts=16, top_k=4, n_shared=0, moe_d_ff=10752,
    moe_group_size=512, rope_theta=5e5)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_ff=128,
    vocab=256, n_experts=4, top_k=2, moe_d_ff=64, moe_group_size=64,
    q_chunk=16, kv_chunk=16)

ARCH = ArchDef(name="dbrx-132b", family="lm", config=CONFIG,
               smoke_config=SMOKE, shapes=lm_shapes())
