"""mind [arXiv:1904.08030]: multi-interest capsule network — embed 64,
4 interests, 3 routing iterations."""
import dataclasses

from repro.configs.base import ArchDef, recsys_shapes
from repro.models.recsys import MINDConfig

CONFIG = MINDConfig(name="mind", embed_dim=64, seq_len=50, n_interests=4,
                    capsule_iters=3, vocab=2_000_000)

SMOKE = dataclasses.replace(CONFIG, vocab=1000, seq_len=12)

ARCH = ArchDef(name="mind", family="recsys", config=CONFIG,
               smoke_config=SMOKE, shapes=recsys_shapes())
