"""SSH on Random Walk (paper §5.1): W=30, δ=5, n=15, 20 tables."""
import dataclasses

from repro.configs.base import ArchDef, ShapeCell
from repro.core.index import SSHParams
from repro.db.config import SearchConfig

CONFIG = SSHParams(window=30, step=5, ngram=15, num_hashes=40,
                   num_tables=20, seed=11)

SMOKE = dataclasses.replace(CONFIG, window=16, step=5, ngram=8,
                            num_hashes=20, num_tables=20)

# Search-time defaults; see ssh_ecg.py — read via ARCH.search_config().
SEARCH = SearchConfig(topk=10, top_c=512, band=6,
                      multiprobe_offsets=CONFIG.step)

SHAPES = {
    "build_2048": ShapeCell("build", {"batch": 65536, "length": 2048}),
    "query_2048": ShapeCell("query", {"length": 2048,
                                      "n_database": 20_971_520,
                                      "top_c": 1024, "band": 102}),
}

ARCH = ArchDef(name="ssh-randomwalk", family="ssh", config=CONFIG,
               smoke_config=SMOKE, shapes=SHAPES,
               search_defaults=SEARCH)
