"""ArchDef — the contract between configs, steps, and the dry-run.

Each config module exports ``ARCH = ArchDef(...)``.  ``input_specs``
returns ShapeDtypeStruct stand-ins for every model input of a given shape
cell (weak-type-correct, shardable, zero allocation), and ``kind`` selects
which step function the cell lowers (train / prefill / decode / serve /
retrieval / build / query).

GNN note: node/edge counts are padded to multiples of 4096 so the arrays
shard evenly on every mesh in play; padding edges are self-loops which the
model masks via the zero-length-edge rule (see repro.models.nequip).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct


def _pad_to(n: int, mult: int) -> int:
    return int(math.ceil(n / mult) * mult)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    kind: str                      # train|prefill|decode|serve|retrieval|build|query
    meta: Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ArchDef:
    name: str
    family: str                    # lm | gnn | recsys | ssh
    config: Any
    smoke_config: Any
    shapes: Dict[str, ShapeCell]
    # optional per-shape config override (e.g. GNN d_feat differs per cell)
    config_for_shape: Optional[Callable[[Any, str], Any]] = None
    # search-time defaults (ssh family): a repro.db.SearchConfig — the one
    # place benchmarks, examples, and serve.py read topk/top_c/band from
    search_defaults: Optional[Any] = None

    def cell_config(self, shape: str) -> Any:
        if self.config_for_shape is not None:
            return self.config_for_shape(self.config, shape)
        return self.config

    def index_spec(self, smoke: bool = False, **params):
        """The arch's build-time ``repro.encoders.IndexSpec`` (ssh
        family): the ``SSHParams`` config lowered to the ``"ssh"``
        encoder spec, with per-call stage-param overrides.  The build
        side twin of :meth:`search_config`."""
        if self.family != "ssh":
            raise ValueError(
                f"arch {self.name!r} (family {self.family!r}) has no "
                "index spec; index_spec() is for ssh arches")
        cfg = self.smoke_config if smoke else self.config
        spec = cfg.to_spec()
        return spec.with_params(**params) if params else spec

    def search_config(self, length: Optional[int] = None, **overrides):
        """The arch's ``SearchConfig``, optionally adapted to a series
        length (UCR-suite 5% band convention: ``max(4, length // 20)``)
        with per-call overrides.  Raises for arches without search
        defaults (non-ssh families)."""
        if self.search_defaults is None:
            raise ValueError(
                f"arch {self.name!r} (family {self.family!r}) defines no "
                "search defaults; search_config() is for ssh arches")
        cfg = self.search_defaults
        if length is not None:
            cfg = dataclasses.replace(cfg, band=max(4, length // 20))
        return cfg.replace(**overrides) if overrides else cfg

    def input_specs(self, shape: str) -> Tuple[str, Dict[str, Any]]:
        cell = self.shapes[shape]
        cfg = self.cell_config(shape)
        builder = _SPEC_BUILDERS[self.family]
        return cell.kind, builder(cfg, cell)


# --------------------------------------------------------------------------
# per-family spec builders
# --------------------------------------------------------------------------

def lm_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    m = cell.meta
    b, s = m["batch"], m["seq"]
    if cell.kind == "train":
        return {"tokens": SDS((b, s), jnp.int32),
                "labels": SDS((b, s), jnp.int32)}
    if cell.kind == "prefill":
        return {"tokens": SDS((b, s), jnp.int32)}
    if cell.kind == "decode":
        from repro.models.transformer import init_cache
        cache = jax.eval_shape(lambda: init_cache(cfg, b, s))
        return {"tokens": SDS((b, 1), jnp.int32), "cache": cache}
    raise ValueError(cell.kind)


def gnn_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    m = cell.meta
    n = _pad_to(m["n_nodes"], 4096)
    e = _pad_to(m["n_edges"], 4096)
    specs = {
        "node_feat": SDS((n, m["d_feat"]), jnp.float32),
        "positions": SDS((n, 3), jnp.float32),
        "edge_src": SDS((e,), jnp.int32),
        "edge_dst": SDS((e,), jnp.int32),
    }
    if m.get("n_graphs"):
        specs["graph_ids"] = SDS((n,), jnp.int32)
        specs["energy"] = SDS((m["n_graphs"],), jnp.float32)
    else:
        specs["node_targets"] = SDS((n,), jnp.float32)
    return specs


def recsys_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    m = cell.meta
    b = m["batch"]
    name = cfg.name
    if cell.kind == "retrieval":
        nc = m["n_candidates"]
        if name.startswith("dlrm"):
            return {"dense": SDS((1, cfg.n_dense), jnp.float32),
                    "sparse": SDS((1, cfg.n_sparse), jnp.int32),
                    "cand_ids": SDS((nc,), jnp.int32)}
        return {"history": SDS((1, cfg.seq_len), jnp.int32),
                "cand_ids": SDS((nc,), jnp.int32)}
    # train / serve share the batch structure (train adds labels)
    if name.startswith("dlrm"):
        specs = {"dense": SDS((b, cfg.n_dense), jnp.float32),
                 "sparse": SDS((b, cfg.n_sparse), jnp.int32)}
    elif name.startswith("bst"):
        specs = {"history": SDS((b, cfg.seq_len), jnp.int32),
                 "target": SDS((b,), jnp.int32),
                 "profile": SDS((b, cfg.n_profile), jnp.int32)}
    else:  # mind / dien
        specs = {"history": SDS((b, cfg.seq_len), jnp.int32),
                 "target": SDS((b,), jnp.int32)}
    if cell.kind == "train":
        specs["labels"] = SDS((b,), jnp.float32)
    return specs


def ssh_specs(cfg, cell: ShapeCell) -> Dict[str, Any]:
    m = cell.meta
    if cell.kind == "build":
        return {"series": SDS((m["batch"], m["length"]), jnp.float32)}
    if cell.kind == "query":
        return {
            "query": SDS((m["length"],), jnp.float32),
            "db_sigs": SDS((m["n_database"], cfg.num_hashes), jnp.int32),
            "db_series": SDS((m["n_database"], m["length"]), jnp.float32),
        }
    raise ValueError(cell.kind)


_SPEC_BUILDERS = {"lm": lm_specs, "gnn": gnn_specs, "recsys": recsys_specs,
                  "ssh": ssh_specs}


# canonical LM shape set (assignment block)
def lm_shapes() -> Dict[str, ShapeCell]:
    return {
        "train_4k": ShapeCell("train", {"seq": 4096, "batch": 256}),
        "prefill_32k": ShapeCell("prefill", {"seq": 32768, "batch": 32}),
        "decode_32k": ShapeCell("decode", {"seq": 32768, "batch": 128}),
        "long_500k": ShapeCell("decode", {"seq": 524288, "batch": 1}),
    }


def recsys_shapes(seq_len: int = 0) -> Dict[str, ShapeCell]:
    return {
        "train_batch": ShapeCell("train", {"batch": 65536}),
        "serve_p99": ShapeCell("serve", {"batch": 512}),
        "serve_bulk": ShapeCell("serve", {"batch": 262144}),
        "retrieval_cand": ShapeCell("retrieval",
                                    {"batch": 1, "n_candidates": 1_000_000}),
    }
