"""bst [arXiv:1905.06874]: Behavior Sequence Transformer — embed 32,
seq 20, 1 block, 8 heads, MLP 1024-512-256."""
import dataclasses

from repro.configs.base import ArchDef, recsys_shapes
from repro.models.recsys import BSTConfig

CONFIG = BSTConfig(name="bst", embed_dim=32, seq_len=20, n_heads=8,
                   n_blocks=1, mlp=(1024, 512, 256), vocab=2_000_000)

SMOKE = dataclasses.replace(CONFIG, vocab=1000, mlp=(64, 32))

ARCH = ArchDef(name="bst", family="recsys", config=CONFIG,
               smoke_config=SMOKE, shapes=recsys_shapes())
