"""phi3-mini-3.8b [arXiv:2404.14219]: 32L d_model=3072 32H (MHA kv=32),
d_ff=8192, vocab=32064, RoPE SwiGLU."""
import dataclasses

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="phi3-mini-3.8b", n_layers=32, d_model=3072, n_heads=32,
    n_kv_heads=32, d_ff=8192, vocab=32064, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, q_chunk=16, kv_chunk=16)

ARCH = ArchDef(name="phi3-mini-3.8b", family="lm", config=CONFIG,
               smoke_config=SMOKE, shapes=lm_shapes())
