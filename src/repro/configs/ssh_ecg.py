"""SSH on ECG (paper §5.1): W=80, δ=3, n=15, 20 tables.

Dry-run cells exercise the distributed index at the paper's 20M-series
scale: ``build`` hashes a series batch; ``query`` probes sharded
signatures + banded-DTW re-ranks the hash candidates.
"""
import dataclasses

from repro.configs.base import ArchDef, ShapeCell
from repro.core.index import SSHParams
from repro.db.config import SearchConfig

CONFIG = SSHParams(window=80, step=3, ngram=15, num_hashes=40,
                   num_tables=20, seed=7)

SMOKE = dataclasses.replace(CONFIG, window=24, step=3, ngram=8,
                            num_hashes=20, num_tables=20)

# Search-time defaults (paper §5.3 evaluation setting): benchmarks,
# examples, and serve.py read these via ARCH.search_config(length=...)
# instead of hand-copying topk/top_c/band tuples.  band=6 is the 5%
# convention at the serving length 128; search_config(length=L) rescales.
SEARCH = SearchConfig(topk=10, top_c=512, band=6,
                      multiprobe_offsets=CONFIG.step)

SHAPES = {
    "build_2048": ShapeCell("build", {"batch": 65536, "length": 2048}),
    "query_128": ShapeCell("query", {"length": 128,
                                     "n_database": 20_971_520,
                                     "top_c": 1024, "band": 6}),
    "query_2048": ShapeCell("query", {"length": 2048,
                                      "n_database": 20_971_520,
                                      "top_c": 1024, "band": 102}),
}

ARCH = ArchDef(name="ssh-ecg", family="ssh", config=CONFIG,
               smoke_config=SMOKE, shapes=SHAPES,
               search_defaults=SEARCH)
