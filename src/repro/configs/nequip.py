"""nequip [arXiv:2101.03164]: 5 layers, 32 channels, l_max=2, 8 RBF,
cutoff 5 Å, E(3) tensor products.  d_feat varies per assigned shape cell
(Cora 1433 / Reddit 602 / products 100 / molecule species one-hot)."""
import dataclasses

from repro.configs.base import ArchDef, ShapeCell
from repro.data.graph import sampled_subgraph_shape
from repro.models.nequip import NequIPConfig

CONFIG = NequIPConfig(name="nequip", n_layers=5, channels=32, l_max=2,
                      n_rbf=8, cutoff=5.0, d_feat=1433)

SMOKE = dataclasses.replace(CONFIG, n_layers=2, channels=8, d_feat=8)

_MB_NODES, _MB_EDGES = sampled_subgraph_shape(1024, (15, 10))

SHAPES = {
    "full_graph_sm": ShapeCell("train", {
        "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433}),
    "minibatch_lg": ShapeCell("train", {
        "n_nodes": _MB_NODES, "n_edges": _MB_EDGES, "d_feat": 602,
        "note": "Reddit 233k nodes / 115M edges sampled at fanout 15-10"}),
    "ogb_products": ShapeCell("train", {
        "n_nodes": 2_449_029, "n_edges": 61_859_140, "d_feat": 100}),
    "molecule": ShapeCell("train", {
        "n_nodes": 30 * 128, "n_edges": 64 * 128, "d_feat": 16,
        "n_graphs": 128}),
}


def _config_for_shape(cfg, shape):
    return dataclasses.replace(cfg, d_feat=SHAPES[shape].meta["d_feat"])


ARCH = ArchDef(name="nequip", family="gnn", config=CONFIG,
               smoke_config=SMOKE, shapes=SHAPES,
               config_for_shape=_config_for_shape)
