"""granite-3-8b [hf:ibm-granite]: 40L d_model=4096 32H (GQA kv=8),
d_ff=12800, vocab=49155."""
import dataclasses

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="granite-3-8b", n_layers=40, d_model=4096, n_heads=32,
    n_kv_heads=8, d_ff=12800, vocab=49155, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab=256, q_chunk=16, kv_chunk=16)

ARCH = ArchDef(name="granite-3-8b", family="lm", config=CONFIG,
               smoke_config=SMOKE, shapes=lm_shapes())
