"""--arch registry: maps assignment ids to ArchDefs."""
from __future__ import annotations

import importlib
from typing import Dict, List

_MODULES: Dict[str, str] = {
    "deepseek-v2-lite-16b": "repro.configs.deepseek_v2_lite_16b",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "phi3-mini-3.8b": "repro.configs.phi3_mini_3_8b",
    "granite-3-8b": "repro.configs.granite_3_8b",
    "granite-3-2b": "repro.configs.granite_3_2b",
    "nequip": "repro.configs.nequip",
    "bst": "repro.configs.bst",
    "dlrm-rm2": "repro.configs.dlrm_rm2",
    "mind": "repro.configs.mind",
    "dien": "repro.configs.dien",
    "ssh-ecg": "repro.configs.ssh_ecg",
    "ssh-randomwalk": "repro.configs.ssh_randomwalk",
}


def list_archs() -> List[str]:
    return sorted(_MODULES)


def get_arch(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list_archs()}")
    return importlib.import_module(_MODULES[name]).ARCH
