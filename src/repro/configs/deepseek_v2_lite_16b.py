"""deepseek-v2-lite-16b [arXiv:2405.04434; hf]: 27L d_model=2048 16H,
MLA kv_lora=512, MoE 64 routed top-6 + 2 shared, expert d_ff=1408,
vocab=102400.

Deviation note (DESIGN.md §4): HF uses a dense FFN on layer 0; we use a
uniform 27-layer MoE stack so the layer scan is homogeneous (<1% param
delta).  "160 routed" in the assignment line refers to full V2; the Lite
config (bracketed values used here) has 64 routed experts.
"""
import dataclasses

from repro.configs.base import ArchDef, lm_shapes
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-v2-lite-16b", n_layers=27, d_model=2048, n_heads=16,
    n_kv_heads=16, d_ff=10944, vocab=102400,
    moe=True, n_experts=64, top_k=6, n_shared=2, moe_d_ff=1408,
    moe_group_size=128,   # keeps dispatch-mask overhead ~8% (see moe.py)
    mla=True, kv_lora_rank=512, qk_nope_dim=128, qk_rope_dim=64,
    v_head_dim=128, rope_theta=1e4)

SMOKE = dataclasses.replace(
    CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab=256, n_experts=8, top_k=2, n_shared=1, moe_d_ff=32,
    moe_group_size=64, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16, q_chunk=16, kv_chunk=16)

ARCH = ArchDef(name="deepseek-v2-lite-16b", family="lm", config=CONFIG,
               smoke_config=SMOKE, shapes=lm_shapes())
