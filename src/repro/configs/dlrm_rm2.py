"""dlrm-rm2 [arXiv:1906.00091]: 13 dense + 26 sparse fields, embed 64,
bottom MLP 13-512-256-64, top MLP 512-512-256-1, dot interaction."""
import dataclasses

from repro.configs.base import ArchDef, recsys_shapes
from repro.models.recsys import DLRMConfig

CONFIG = DLRMConfig(name="dlrm-rm2", n_dense=13, n_sparse=26, embed_dim=64,
                    vocab=1_000_000, bot_mlp=(13, 512, 256, 64),
                    top_mlp=(512, 512, 256, 1))

SMOKE = dataclasses.replace(CONFIG, vocab=1000)

ARCH = ArchDef(name="dlrm-rm2", family="recsys", config=CONFIG,
               smoke_config=SMOKE, shapes=recsys_shapes())
