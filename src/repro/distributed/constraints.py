"""Activation sharding constraints (logical-axis annotated, context-scoped).

Without explicit constraints GSPMD resolves FSDP-weight vs batch-sharded
activation conflicts by *replicating activations* ("involuntary full
rematerialization" — measured 4.2× dot-FLOPs and ~1.6 TB/device of
collectives on granite-3-2b train_4k; see EXPERIMENTS.md §Perf iteration
1).  The fix is the MaxText/T5X pattern: pin activations to logical axes
at layer boundaries so the partitioner all-gathers *weights* (ZeRO-3)
instead of activations.

``constrain`` is a no-op unless a mesh context is active, so model code
stays runnable on a single device (tests, smoke configs).
"""
from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding

from repro.distributed.sharding import spec_for

_STATE = threading.local()


@contextmanager
def activation_sharding(mesh: Mesh):
    prev = getattr(_STATE, "mesh", None)
    _STATE.mesh = mesh
    try:
        yield
    finally:
        _STATE.mesh = prev


def active_mesh() -> Optional[Mesh]:
    return getattr(_STATE, "mesh", None)


def constrain(x: jax.Array, *logicals) -> jax.Array:
    """Pin ``x`` to logical axes (one name-or-None per dim).

    Divisibility-checked via the same rule table as parameter sharding —
    a dim that doesn't divide its mesh axis silently replicates.
    """
    mesh = active_mesh()
    if mesh is None or not hasattr(x, "shape") or x.ndim != len(logicals):
        return x
    spec = spec_for(logicals, x.shape, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
