"""Distributed SSH index — the paper's technique as a multi-pod service.

Layout: signatures (N, K) and series (N, m) are row-sharded over EVERY
mesh axis (an index shard per chip).  A query is broadcast; each shard:

  1. counts signature collisions locally           (collision_count kernel)
  2. takes its local top-C/shards candidates       (lax.top_k)
  3. re-ranks them with banded DTW                 (dtw_wavefront kernel)
  4. contributes (dists, global ids) to an all_gather; the global top-k
     is reduced on every chip (k is tiny — replicated reduce is free).

Expressed with ``shard_map`` so the collective schedule is explicit and
auditable: ONE all_gather of k·2 scalars per query — the probe itself is
embarrassingly parallel, preserving SSH's sub-linear DTW count at 512
chips.  Index build is one pass over the local shard (no communication).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.index import SSHParams
from repro.db.config import SearchConfig, config_from_legacy_kwargs

try:                        # jax >= 0.6: public API, replication kw check_vma
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
except AttributeError:      # jax 0.4.x: experimental module, kw check_rep
    from jax.experimental.shard_map import shard_map as _shard_map
    _CHECK_KW = "check_rep"


def shard_map_nocheck(f, mesh: Mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})


def _signature(series: jnp.ndarray, filters: jnp.ndarray, cws: dict,
               params: SSHParams) -> jnp.ndarray:
    from repro.core import minhash, shingle, sketch
    cwsp = minhash.CWSParams(**cws)
    bits = sketch.sketch_bits(series, filters, params.step)
    counts = shingle.shingle_histogram_batch(bits, params.ngram)
    return minhash.cws_hash_dense_batch(counts, cwsp)


def build_sharded(series: jnp.ndarray, filters: jnp.ndarray, cws: dict,
                  params: SSHParams, mesh: Mesh) -> jnp.ndarray:
    """series (N, m) row-sharded -> signatures (N, K) row-sharded."""
    axes = tuple(mesh.axis_names)
    fn = shard_map_nocheck(
        lambda s: _signature(s, filters, cws, params),
        mesh,
        in_specs=P(axes, None),
        out_specs=P(axes, None))
    return fn(series)


def _make_query_core(encode, mesh: Mesh, config: SearchConfig):
    """The ONE shard-local query schedule, parameterised by a pure
    ``encode(q, state) -> (K,)`` signature fn: local collision scan over
    raw signatures + local top-C/P + local banded DTW, ONE all_gather of
    k·2 scalars per query.  Both public factories delegate here so the
    collective schedule cannot diverge between the legacy and encoder
    entry points.
    """
    if config.band is None:
        raise ValueError("the sharded query fn requires a band radius "
                         "(config.band is None)")
    top_c, band, topk = config.top_c, config.band, config.topk
    backend = config.backend
    abandon = config.use_lb_cascade and config.early_abandon
    axes = tuple(mesh.axis_names)
    n_shards = int(mesh.devices.size)
    local_c = max(topk, top_c // n_shards)

    def local_query(series, sigs, state, q):
        from repro.kernels import ops
        sig = encode(q, state)                                # (K,)
        coll = jnp.sum((sigs == sig[None, :]).astype(jnp.int32), axis=-1)
        _, cand = jax.lax.top_k(coll, local_c)                # local ids
        cand_series = jnp.take(series, cand, axis=0)
        thr = None
        if abandon:
            # shard-local seed threshold: topk-th best DTW over the
            # first topk hash hits.  Any lane in the shard's true local
            # top-k is <= this bound, and the global k-th is <= every
            # shard's local k-th, so abandoned lanes (exact > thr) can
            # never reach the gathered global top-k — results identical.
            seed = ops.dtw_rerank(q, cand_series[:topk], band,
                                  use_pallas=ops.resolve_backend(backend))
            thr = jnp.sort(seed)[topk - 1]
        d = ops.dtw_rerank(q, cand_series, band,
                           use_pallas=ops.resolve_backend(backend),
                           threshold=thr)

        shard_id = jax.lax.axis_index(axes)
        n_local = series.shape[0]
        gids = cand + shard_id * n_local
        # gather every shard's (dists, ids); reduce to global top-k
        all_d = jax.lax.all_gather(d, axes, tiled=True)
        all_i = jax.lax.all_gather(gids, axes, tiled=True)
        vals, order = jax.lax.top_k(-all_d, topk)
        return jnp.take(all_i, order), -vals

    return shard_map_nocheck(
        local_query, mesh,
        in_specs=(P(axes, None), P(axes, None), P(), P()),
        out_specs=(P(), P()))


def make_query_fn(params: SSHParams, mesh: Mesh, *, length: int,
                  config: Optional[SearchConfig] = None,
                  top_c: Optional[int] = None, band: Optional[int] = None,
                  topk: Optional[int] = None,
                  backend: Optional[str] = None):
    """Returns query(series_shard, sigs_shard, filters, cws, q) -> (ids, d).

    Canonical form: ``make_query_fn(params, mesh, length=m, config=cfg)``
    — ``cfg.top_c``/``cfg.band``/``cfg.topk`` set the probe and re-rank
    widths, and ``cfg.backend`` selects the shard-local DTW
    implementation via the shared dispatch (``repro.kernels.ops``): the
    Pallas wavefront kernel on TPU, the ``dtw_batch`` scan oracle
    elsewhere — the same knob as the local re-rank pipeline (DESIGN.md
    §3).  A band radius is required (the shard-local re-rank is banded).
    The filter bank and CWS fields stay call-time operands (historical
    signature); the schedule itself is :func:`_make_query_core`.

    Deprecation shim (one release): the loose ``top_c=/band=/topk=/
    backend=`` kwargs still work under a ``DeprecationWarning``.
    """
    if config is None:
        legacy = {k: v for k, v in dict(top_c=top_c, band=band, topk=topk,
                                        backend=backend).items()
                  if v is not None}
        config = config_from_legacy_kwargs("make_query_fn", legacy)
    elif any(v is not None for v in (top_c, band, topk, backend)):
        raise TypeError("make_query_fn() takes either config= or legacy "
                        "top_c/band/topk/backend kwargs, not both")
    if config.band is None:
        raise ValueError("make_query_fn requires a band radius "
                         "(config.band is None)")

    def encode(q, state):
        from repro.core import minhash, shingle, sketch
        from repro.encoders.pipeline import CWSHasher
        cwsp = CWSHasher.cws_params(state)   # one home for the cws/ prefix
        bits = sketch.sketch_bits(q, state["filters"], params.step)
        counts = shingle.shingle_histogram(bits, params.ngram)
        return minhash.cws_hash(counts, cwsp)                 # (K,)

    core = _make_query_core(encode, mesh, config)

    def query(series, sigs, filters, cws, q):
        state = {"filters": filters,
                 **{f"cws/{k}": v for k, v in cws.items()}}
        return core(series, sigs, state, q)

    return query


def make_encoder_query_fn(encoder, mesh: Mesh, *,
                          config: SearchConfig):
    """Encoder-generic twin of :func:`make_query_fn` — the facade path.

    Returns ``query(series_shard, sigs_shard, state, q) -> (ids, dists)``
    where ``state`` is the encoder's materialised array dict
    (``encoder.state()``, replicated).  Any registered encoder whose
    ``pure_encode_fn`` is shard_map-safe serves unchanged — ``"ssh"``,
    ``"srp"``, ``"ssh-multires"``, or out-of-tree.
    """
    return _make_query_core(encoder.pure_encode_fn(), mesh, config)


def index_shardings(mesh: Mesh) -> Tuple[NamedSharding, NamedSharding]:
    axes = tuple(mesh.axis_names)
    return (NamedSharding(mesh, P(axes, None)),
            NamedSharding(mesh, P(axes, None)))
