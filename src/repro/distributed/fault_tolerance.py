"""Fault tolerance + elasticity policies (host-level logic, unit-tested).

On a real multi-pod deployment these run in the launcher/controller; the
device-side counterparts are the atomic checkpoints (repro.checkpoint)
and the deterministic seeded data pipeline (resume = same batches).

* ``ShardPlan`` — deterministic assignment of data shards to workers with
  hot-spare reassignment on failure (node-failure tolerance) and
  re-balancing on resize (elastic scaling).
* ``StragglerPolicy`` — EWMA step-time tracking; a worker is a straggler
  when slower than ``threshold`` × fleet median for ``patience``
  consecutive steps → its shards migrate to the fastest workers (backup-
  task mitigation, MapReduce-style).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class ShardPlan:
    n_shards: int
    workers: List[str]
    assignment: Dict[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.assignment:
            self.rebalance()

    def rebalance(self) -> None:
        """Deterministic round-robin over the sorted worker list."""
        ws = sorted(self.workers)
        self.assignment = {s: ws[s % len(ws)] for s in range(self.n_shards)}

    def shards_of(self, worker: str) -> List[int]:
        return [s for s, w in self.assignment.items() if w == worker]

    def fail(self, worker: str) -> List[int]:
        """Worker died: its shards move to the least-loaded survivors.
        Returns the migrated shard ids."""
        if worker not in self.workers:
            return []
        self.workers = [w for w in self.workers if w != worker]
        if not self.workers:
            raise RuntimeError("no workers left")
        moved = [s for s, w in self.assignment.items() if w == worker]
        for s in moved:
            load = {w: len(self.shards_of(w)) for w in self.workers}
            self.assignment[s] = min(sorted(load), key=lambda w: load[w])
        return moved

    def resize(self, new_workers: List[str]) -> int:
        """Elastic scale up/down; returns number of shards that moved."""
        old = dict(self.assignment)
        self.workers = list(new_workers)
        self.rebalance()
        return sum(1 for s in old if old[s] != self.assignment[s])


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5         # × fleet median
    patience: int = 3
    alpha: float = 0.3             # EWMA smoothing
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)
    strikes: Dict[str, int] = dataclasses.field(default_factory=dict)

    def observe(self, worker: str, step_seconds: float) -> None:
        prev = self.ewma.get(worker, step_seconds)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * \
            step_seconds

    def median(self) -> float:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def check(self, worker: str) -> bool:
        """True when the worker should be treated as a straggler."""
        med = self.median()
        if med <= 0:
            return False
        if self.ewma.get(worker, 0.0) > self.threshold * med:
            self.strikes[worker] = self.strikes.get(worker, 0) + 1
        else:
            self.strikes[worker] = 0
        return self.strikes.get(worker, 0) >= self.patience

    def stragglers(self) -> List[str]:
        return [w for w in list(self.ewma) if self.check(w)]
