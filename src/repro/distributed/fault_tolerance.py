"""Fault tolerance + elasticity policies (host-level logic, unit-tested).

On a real multi-pod deployment these run in the launcher/controller; the
device-side counterparts are the atomic checkpoints (repro.checkpoint)
and the deterministic seeded data pipeline (resume = same batches).

* ``ShardPlan`` — deterministic assignment of data shards to workers with
  hot-spare reassignment on failure (node-failure tolerance) and
  re-balancing on resize (elastic scaling).
* ``StragglerPolicy`` — EWMA step-time tracking; a worker is a straggler
  when slower than ``threshold`` × fleet median for ``patience``
  consecutive steps → its shards migrate to the fastest workers (backup-
  task mitigation, MapReduce-style).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class ShardPlan:
    n_shards: int
    workers: List[str]
    assignment: Dict[int, str] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if not self.assignment:
            self.rebalance()

    def rebalance(self) -> None:
        """Deterministic round-robin over the sorted worker list."""
        ws = sorted(self.workers)
        self.assignment = {s: ws[s % len(ws)] for s in range(self.n_shards)}

    def shards_of(self, worker: str) -> List[int]:
        return [s for s, w in self.assignment.items() if w == worker]

    def fail(self, worker: str) -> List[int]:
        """Worker died: its shards move to the least-loaded survivors.
        Returns the migrated shard ids."""
        if worker not in self.workers:
            return []
        self.workers = [w for w in self.workers if w != worker]
        if not self.workers:
            raise RuntimeError("no workers left")
        moved = [s for s, w in self.assignment.items() if w == worker]
        for s in moved:
            load = {w: len(self.shards_of(w)) for w in self.workers}
            self.assignment[s] = min(sorted(load), key=lambda w: load[w])
        return moved

    def resize(self, new_workers: List[str]) -> int:
        """Elastic scale up/down with stable minimal movement; returns
        the number of shards that moved.

        A shard whose worker survives the resize stays put; shards on
        removed workers re-home to the least-loaded survivor, then
        shards flow from the most- to the least-loaded worker only
        until the load spread is <= 1.  Moves are bounded by
        ``ceil(n_shards / len(new_workers))`` for a one-worker change
        (vs. the old round-robin re-deal, which reshuffled nearly every
        shard whenever the worker list shifted by one).
        """
        old = dict(self.assignment)
        new = list(dict.fromkeys(new_workers))
        if not new:
            raise RuntimeError("no workers left")
        removed = [w for w in self.workers if w not in new]
        self.workers = new
        for dead in removed:
            for s in self.shards_of(dead):
                load = {w: len(self.shards_of(w)) for w in self.workers}
                self.assignment[s] = min(sorted(load),
                                         key=lambda w: load[w])
        while True:
            load = {w: len(self.shards_of(w)) for w in self.workers}
            order = sorted(load, key=lambda w: (load[w], w))
            lo, hi = order[0], order[-1]
            if load[hi] - load[lo] <= 1:
                break
            self.assignment[min(self.shards_of(hi))] = lo
        return sum(1 for s in old if old[s] != self.assignment[s])


@dataclasses.dataclass
class StragglerPolicy:
    threshold: float = 1.5         # × fleet median
    patience: int = 3
    alpha: float = 0.3             # EWMA smoothing
    ewma: Dict[str, float] = dataclasses.field(default_factory=dict)
    strikes: Dict[str, int] = dataclasses.field(default_factory=dict)

    def observe(self, worker: str, step_seconds: float) -> None:
        prev = self.ewma.get(worker, step_seconds)
        self.ewma[worker] = (1 - self.alpha) * prev + self.alpha * \
            step_seconds

    def median(self) -> float:
        vals = sorted(self.ewma.values())
        return vals[len(vals) // 2] if vals else 0.0

    def step(self, worker: str) -> None:
        """Advance the worker's strike counter once for this step.

        The mutating half of the old ``check()``: call exactly once per
        observed step.  Reads (``is_straggler``/``stragglers``) are
        pure, so callers may poll them at any frequency — the old
        combined ``check()`` double-counted strikes when a step was
        inspected twice (e.g. ``check()`` in a loop, then
        ``stragglers()`` for the report).
        """
        med = self.median()
        if med <= 0:
            return
        if self.ewma.get(worker, 0.0) > self.threshold * med:
            self.strikes[worker] = self.strikes.get(worker, 0) + 1
        else:
            self.strikes[worker] = 0

    def is_straggler(self, worker: str) -> bool:
        """Pure read: has the worker struck out ``patience`` times?"""
        return self.strikes.get(worker, 0) >= self.patience

    def check(self, worker: str) -> bool:
        """True when the worker should be treated as a straggler.

        Back-compat combined form: advances the strike counter AND
        reads the verdict.  New callers should pair one ``step()`` per
        observed step with pure ``is_straggler()`` reads.
        """
        self.step(worker)
        return self.is_straggler(worker)

    def stragglers(self) -> List[str]:
        """Pure read of the current straggler set (no strike updates)."""
        return [w for w in list(self.ewma) if self.is_straggler(w)]
