"""Logical-axis sharding rules with divisibility-checked fallbacks.

Every parameter/input dim is tagged with a *logical* axis name; the table
below maps logical axes to (preferred) mesh axes.  A mesh axis is only
assigned when the dim size divides the axis size — otherwise we fall
through to the next candidate or replicate.  This is the MaxText/T5X
"logical axis rules" pattern, made explicit and unit-testable.

Conventions:
  fsdp   — parameter sharding over the data-parallel axes (ZeRO-3 style);
           required for dbrx-132b (264 GB of bf16 weights).
  model  — tensor parallel axis.
"""
from __future__ import annotations

import re
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import dp_axes

# logical axis -> ordered mesh-axis candidates (first divisible wins)
LOGICAL_RULES: Dict[str, Tuple[str, ...]] = {
    "fsdp": ("dp",),          # expands to the mesh's dp axes
    "model": ("model",),
    "batch": ("dp",),
    # families with no tensor-parallel dimension (recsys/gnn/ssh) shard
    # their batch over EVERY mesh axis — leaving 'model' idle replicates
    # 1/mp of the fleet (§Perf iteration: 16× compute-term win)
    "batch_all": ("all", "dp"),
    "experts": ("model",),
    "vocab": ("model",),
    "heads": ("model",),
    "head_dim": ("model",),
    "ff": ("model",),
    "seq": ("dp",),
    "nodes": ("dp",),
    "edges": ("dp",),
    "candidates": ("dp",),
    "table_rows": ("model",),
    "replicated": (),
}


def _resolve_axis(logical: Optional[str], dim: int, mesh: Mesh):
    """Logical name -> concrete mesh axis (or None), divisibility-checked."""
    if logical is None:
        return None
    for cand in LOGICAL_RULES.get(logical, ()):
        if cand in ("dp", "all"):
            axes = (tuple(mesh.axis_names) if cand == "all"
                    else dp_axes(mesh))
            if not axes:
                continue
            size = int(np.prod([mesh.shape[a] for a in axes]))
            if size > 0 and dim % size == 0:
                return axes if len(axes) > 1 else axes[0]
        else:
            if cand in mesh.axis_names and dim % mesh.shape[cand] == 0:
                return cand
    return None


def spec_for(logicals: Sequence[Optional[str]], shape: Sequence[int],
             mesh: Mesh) -> P:
    """Resolve per-dim logical names into a PartitionSpec, avoiding
    assigning the same mesh axis twice."""
    used = set()
    out = []
    for logical, dim in zip(logicals, shape):
        ax = _resolve_axis(logical, dim, mesh)
        key = tuple(ax) if isinstance(ax, tuple) else (ax,)
        if ax is not None and not (set(key) & used):
            out.append(ax)
            used.update(key)
        else:
            out.append(None)
    return P(*out)


def sharding_for(logicals, shape, mesh) -> NamedSharding:
    return NamedSharding(mesh, spec_for(logicals, shape, mesh))


# --------------------------------------------------------------------------
# parameter rules per model family (matched on the param path)
# --------------------------------------------------------------------------

# (regex on "/"-joined path, logical axes for the *trailing* dims).
# Stacked layer params have a leading L dim -> replicated (scan axis).
LM_PARAM_RULES = [
    (r"embed$", ("vocab", "fsdp")),
    (r"head$", ("fsdp", "vocab")),
    (r"ln_.*$", ("replicated",)),
    (r"wq$", ("fsdp", "heads", None)),
    (r"wk$", ("fsdp", "heads", "head_dim")),      # kv_heads may not divide
    (r"wv$", ("fsdp", "heads", "head_dim")),
    (r"wo$", ("heads", None, "fsdp")),
    (r"w_dkv$", ("fsdp", "model")),
    (r"w_uk$", ("fsdp", "heads", None)),
    (r"w_uv$", ("fsdp", "heads", None)),
    (r"router$", ("fsdp", None)),
    (r"we_(gate|up)$", ("experts", "fsdp", None)),
    (r"we_down$", ("experts", None, "fsdp")),
    (r"ws_(gate|up)$", ("fsdp", "ff")),
    (r"ws_down$", ("ff", "fsdp")),
    (r"w_(gate|up)$", ("fsdp", "ff")),
    (r"w_down$", ("ff", "fsdp")),
]

GNN_PARAM_RULES = [
    (r".*", ("replicated",)),          # NequIP params are tiny (<1 MB)
]

RECSYS_PARAM_RULES = [
    (r"tables$", (None, "table_rows", None)),   # (F, V, d) row-sharded
    (r"items$", ("table_rows", None)),
    (r"profile$", ("table_rows", None)),
    (r".*", ("replicated",)),
]

FAMILY_RULES = {"lm": LM_PARAM_RULES, "gnn": GNN_PARAM_RULES,
                "recsys": RECSYS_PARAM_RULES, "ssh": GNN_PARAM_RULES}


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_sharding(params_shapes: Any, mesh: Mesh, family: str,
                   stacked_layer_key: str = "layers",
                   drop_fsdp: bool = False) -> Any:
    """Tree of NamedShardings for a parameter pytree (of ShapeDtypeStruct
    or arrays), using the family rule table.

    ``drop_fsdp=True`` replicates instead of ZeRO-sharding over the data
    axes — the right layout for *inference* when the weights fit HBM
    (no optimizer state; per-layer weight all-gathers disappear).
    """
    rules = FAMILY_RULES[family]

    def leaf_spec(path, leaf):
        pstr = _path_str(path)
        shape = leaf.shape
        stacked = stacked_layer_key in pstr.split("/")
        trailing = shape[1:] if stacked and len(shape) > 1 else shape
        logicals: Tuple[Optional[str], ...] = ()
        for pat, logi in rules:
            if re.search(pat, pstr):
                logicals = logi
                break
        if logicals == ("replicated",):
            logicals = (None,) * len(trailing)
        if drop_fsdp:
            logicals = tuple(None if l == "fsdp" else l for l in logicals)
        if len(logicals) != len(trailing):
            logicals = (None,) * len(trailing)     # arity mismatch: replicate
        spec = spec_for(logicals, trailing, mesh)
        if stacked and len(shape) > 1:
            spec = P(None, *spec)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def batch_sharding(specs: Any, mesh: Mesh, overrides: Optional[Dict[str, P]]
                   = None, batch_logical: str = "batch") -> Any:
    """Default input sharding: first dim over the batch axes (when
    divisible); per-key overrides win.  ``batch_logical='batch_all'``
    spreads the batch over every mesh axis (non-TP families)."""
    overrides = overrides or {}

    def leaf(path, s):
        pstr = _path_str(path)
        for k, v in overrides.items():
            if re.search(k, pstr):
                return NamedSharding(mesh, v)
        if not s.shape:
            return NamedSharding(mesh, P())
        logicals = (batch_logical,) + (None,) * (len(s.shape) - 1)
        return sharding_for(logicals, s.shape, mesh)

    return jax.tree_util.tree_map_with_path(leaf, specs)
