"""Distributed SSH index (shard_map fan-out)."""
