"""Index persistence — save/load an ``SSHIndex`` (+ its ``SearchConfig``).

The paper's core argument for data-independent hashing is that the index
never needs retraining; persistence completes that story operationally: a
process restart loads the signatures instead of paying the full O(N)
sketch→shingle→hash build again.

Layout of a saved database directory::

    <dir>/ssh_db.json        # params, array manifest, config, flags
    <dir>/index/step_*/      # repro.checkpoint shard(s) + manifest

Arrays ride the existing :mod:`repro.checkpoint` layer (atomic publish,
shard-splitting, resharding restore), so a crashed ``save()`` never
corrupts the previous database.  Everything needed for bit-identical
answers is stored — signatures, band keys, raw series, cached envelopes,
AND the materialised random functions (filter bank + CWS fields), so a
loaded index hashes queries and streamed inserts exactly like the index
that was saved, independent of any future change to jax's PRNG.
"""
from __future__ import annotations

import dataclasses
import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.core.index import HostBuckets, SSHFunctions, SSHIndex, SSHParams
from repro.core.minhash import CWSParams
from repro.db.config import SearchConfig

FORMAT_VERSION = 1
META_FILE = "ssh_db.json"
ARRAYS_SUBDIR = "index"

#: CWSParams fields, serialised as ``cws/<field>`` array leaves.
_CWS_FIELDS = tuple(CWSParams._fields)


def _index_arrays(index: SSHIndex) -> Dict[str, np.ndarray]:
    """Flat array tree for the checkpoint layer (all leaves host numpy)."""
    arrays: Dict[str, np.ndarray] = {
        "signatures": np.asarray(index.signatures),
        "keys": np.asarray(index.keys),
        "filters": np.asarray(index.fns.filters),
    }
    for name in _CWS_FIELDS:
        arrays[f"cws/{name}"] = np.asarray(getattr(index.fns.cws, name))
    if index.series is not None:
        arrays["series"] = np.asarray(index.series)
    if index.env_radius is not None and index.env_upper is not None:
        arrays["env_upper"] = np.asarray(index.env_upper)
        arrays["env_lower"] = np.asarray(index.env_lower)
    return arrays


def save_database(directory: str | Path, index: SSHIndex,
                  config: Optional[SearchConfig] = None,
                  n_shards: int = 1) -> Path:
    """Persist ``index`` (and optionally its ``config``) under ``directory``.

    Returns the directory path.  Atomic at both levels: arrays go through
    ``repro.checkpoint.save_checkpoint`` (temp dir + rename) and the JSON
    meta is written via temp file + ``os.replace``, so readers only ever
    see a complete database.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    arrays = _index_arrays(index)
    # monotonic step + keep=2 make re-saving into the same directory
    # crash-safe: the new arrays publish atomically while the step the
    # current meta points at is still on disk; the meta then flips to
    # the new step (a crash in between leaves the old pair intact)
    prev = latest_step(directory / ARRAYS_SUBDIR)
    step = 0 if prev is None else prev + 1
    save_checkpoint(directory / ARRAYS_SUBDIR, step=step, tree=arrays,
                    keep=2, n_shards=n_shards)

    meta: Dict[str, Any] = {
        "format_version": FORMAT_VERSION,
        "checkpoint_step": step,
        "params": dataclasses.asdict(index.fns.params),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "n_series": int(index.signatures.shape[0]),
        "has_series": index.series is not None,
        "with_host_buckets": index.host_buckets is not None,
        "env_radius": index.env_radius if "env_upper" in arrays else None,
        "config": config.to_dict() if config is not None else None,
    }
    tmp = directory / f".{META_FILE}.tmp{os.getpid()}"
    tmp.write_text(json.dumps(meta, indent=1))
    os.replace(tmp, directory / META_FILE)
    return directory


def load_database(directory: str | Path
                  ) -> Tuple[SSHIndex, Optional[SearchConfig]]:
    """Inverse of :func:`save_database`.

    Returns ``(index, config)`` — ``config`` is ``None`` when the saver
    did not record one.  The loaded index is bit-identical to the saved
    one (same signatures, keys, series, envelope cache, and random
    functions), so searches answer identically and streaming ``insert``
    continues from the same hash functions.
    """
    directory = Path(directory)
    meta_path = directory / META_FILE
    if not meta_path.exists():
        raise FileNotFoundError(f"no SSH database at {directory} "
                                f"(missing {META_FILE})")
    meta = json.loads(meta_path.read_text())
    version = meta.get("format_version")
    if version != FORMAT_VERSION:
        raise ValueError(f"unsupported database format_version {version!r} "
                         f"(this release reads {FORMAT_VERSION})")

    tree_like = {k: np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
                 for k, info in meta["arrays"].items()}
    _, arrays = restore_checkpoint(directory / ARRAYS_SUBDIR, tree_like,
                                   step=meta.get("checkpoint_step"))

    params = SSHParams(**meta["params"])
    fns = SSHFunctions(
        params=params, filters=arrays["filters"],
        cws=CWSParams(**{n: arrays[f"cws/{n}"] for n in _CWS_FIELDS}))

    host_buckets = None
    if meta["with_host_buckets"]:
        host_buckets = HostBuckets(params)
        host_buckets.insert(np.asarray(arrays["keys"]))

    env_radius = meta.get("env_radius")
    index = SSHIndex(
        fns=fns,
        signatures=arrays["signatures"],
        keys=arrays["keys"],
        series=arrays.get("series"),
        host_buckets=host_buckets,
        env_radius=env_radius,
        env_upper=arrays.get("env_upper"),
        env_lower=arrays.get("env_lower"))

    config = (SearchConfig.from_dict(meta["config"])
              if meta.get("config") else None)
    return index, config


def is_database_dir(directory: str | Path) -> bool:
    """True when ``directory`` holds a complete saved database."""
    return (Path(directory) / META_FILE).exists()
