"""Index persistence — save/load an ``SSHIndex`` (+ its ``SearchConfig``).

The paper's core argument for data-independent hashing is that the index
never needs retraining; persistence completes that story operationally: a
process restart loads the signatures instead of paying the full O(N)
sketch→shingle→hash build again.

Layout of a saved database directory::

    <dir>/ssh_db.json        # IndexSpec, array manifest, config, flags
    <dir>/index/step_*/      # repro.checkpoint shard(s) + manifest

Arrays ride the existing :mod:`repro.checkpoint` layer (atomic publish,
shard-splitting, resharding restore), so a crashed ``save()`` never
corrupts the previous database.  Everything needed for bit-identical
answers is stored — signatures, band keys, raw series, cached envelopes,
AND the encoder's materialised random state (under ``encoder/<leaf>``),
so a loaded index hashes queries and streamed inserts exactly like the
index that was saved, independent of any future change to jax's PRNG.

Format v2 records the ``repro.encoders.IndexSpec``; ``load`` rebuilds
the encoder through the registry and REFUSES a spec/artifact mismatch
(array names or shapes that disagree with what the spec implies, or a
signature width that disagrees with the encoder's K).  v1 directories
(pre-encoder, ``"ssh"`` only) remain loadable: their ``params`` block
lowers to ``IndexSpec(encoder="ssh", ...)``.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import numpy as np

from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint
from repro.core.index import HostBuckets, SSHIndex
from repro.db.config import SearchConfig
from repro.encoders import IndexSpec, encoder_class
from repro.kernels import ops

FORMAT_VERSION = 2
META_FILE = "ssh_db.json"
ARRAYS_SUBDIR = "index"
_ENC_PREFIX = "encoder/"


def _index_arrays(index: SSHIndex) -> Dict[str, np.ndarray]:
    """Flat array tree for the checkpoint layer (all leaves host numpy)."""
    arrays: Dict[str, np.ndarray] = {
        "signatures": np.asarray(index.signatures),
        "keys": np.asarray(index.keys),
    }
    for name, arr in index.enc.arrays().items():
        arrays[f"{_ENC_PREFIX}{name}"] = np.asarray(arr)
    if index.series is not None:
        arrays["series"] = np.asarray(index.series)
    if index.env_radius is not None and index.env_upper is not None:
        arrays["env_upper"] = np.asarray(index.env_upper)
        arrays["env_lower"] = np.asarray(index.env_lower)
    return arrays


def save_database(directory: str | Path, index: SSHIndex,
                  config: Optional[SearchConfig] = None,
                  n_shards: int = 1) -> Path:
    """Persist ``index`` (and optionally its ``config``) under ``directory``.

    Returns the directory path.  Atomic at both levels: arrays go through
    ``repro.checkpoint.save_checkpoint`` (temp dir + rename) and the JSON
    meta is written via temp file + ``os.replace``, so readers only ever
    see a complete database.
    """
    directory = Path(directory)
    directory.mkdir(parents=True, exist_ok=True)
    arrays = _index_arrays(index)
    # monotonic step + keep=2 make re-saving into the same directory
    # crash-safe: the new arrays publish atomically while the step the
    # current meta points at is still on disk; the meta then flips to
    # the new step (a crash in between leaves the old pair intact)
    prev = latest_step(directory / ARRAYS_SUBDIR)
    step = 0 if prev is None else prev + 1
    save_checkpoint(directory / ARRAYS_SUBDIR, step=step, tree=arrays,
                    keep=2, n_shards=n_shards)

    meta: Dict[str, Any] = {
        "format_version": FORMAT_VERSION,
        "checkpoint_step": step,
        "spec": index.enc.spec.to_dict(),
        "arrays": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "n_series": int(index.signatures.shape[0]),
        "has_series": index.series is not None,
        "with_host_buckets": index.host_buckets is not None,
        "env_radius": index.env_radius if "env_upper" in arrays else None,
        "build_backend": index.build_backend,
        "config": config.to_dict() if config is not None else None,
    }
    tmp = directory / f".{META_FILE}.tmp{os.getpid()}"
    tmp.write_text(json.dumps(meta, indent=1))
    os.replace(tmp, directory / META_FILE)
    return directory


def _spec_and_encoder_arrays(meta: Dict[str, Any],
                             arrays: Dict[str, np.ndarray]):
    """(spec, encoder leaf dict) for either format version."""
    if meta["format_version"] >= 2:
        spec = IndexSpec.from_dict(meta["spec"])
        enc_arrays = {k[len(_ENC_PREFIX):]: v for k, v in arrays.items()
                      if k.startswith(_ENC_PREFIX)}
        return spec, enc_arrays
    # v1: pre-encoder layout — SSHParams fields + filters/cws/* leaves
    from repro.core.index import SSHParams
    spec = SSHParams(**meta["params"]).to_spec()
    enc_arrays = {k: v for k, v in arrays.items()
                  if k == "filters" or k.startswith("cws/")}
    return spec, enc_arrays


def load_database(directory: str | Path
                  ) -> Tuple[SSHIndex, Optional[SearchConfig]]:
    """Inverse of :func:`save_database`.

    Returns ``(index, config)`` — ``config`` is ``None`` when the saver
    did not record one.  The encoder is reconstructed from the persisted
    ``IndexSpec`` through the registry and adopts the persisted random
    state — a spec/artifact mismatch (tampered meta, wrong-encoder
    arrays, foreign signature width) raises ``ValueError`` instead of
    silently answering from inconsistent hash functions.
    """
    directory = Path(directory)
    meta_path = directory / META_FILE
    if not meta_path.exists():
        raise FileNotFoundError(f"no SSH database at {directory} "
                                f"(missing {META_FILE})")
    meta = json.loads(meta_path.read_text())
    version = meta.get("format_version")
    if version not in (1, FORMAT_VERSION):
        raise ValueError(f"unsupported database format_version {version!r} "
                         f"(this release reads 1 and {FORMAT_VERSION})")

    tree_like = {k: np.zeros(info["shape"], dtype=np.dtype(info["dtype"]))
                 for k, info in meta["arrays"].items()}
    _, arrays = restore_checkpoint(directory / ARRAYS_SUBDIR, tree_like,
                                   step=meta.get("checkpoint_step"))

    spec, enc_arrays = _spec_and_encoder_arrays(meta, arrays)
    enc = encoder_class(spec.encoder)(spec.validate())
    enc.load_arrays(enc_arrays)          # raises on spec/artifact mismatch
    sig_width = int(np.shape(arrays["signatures"])[-1])
    if sig_width != enc.num_hashes:
        raise ValueError(
            f"saved signatures have K={sig_width} but the saved spec "
            f"implies K={enc.num_hashes} — spec/artifact mismatch")
    key_width = int(np.shape(arrays["keys"])[-1])
    if key_width != enc.num_tables:
        raise ValueError(
            f"saved band keys have L={key_width} but the saved spec "
            f"implies L={enc.num_tables} — spec/artifact mismatch")
    fns = (enc.legacy_functions()
           if hasattr(enc, "legacy_functions") else None)

    host_buckets = None
    if meta["with_host_buckets"]:
        host_buckets = HostBuckets(enc.num_tables)
        host_buckets.insert(np.asarray(arrays["keys"]))

    build_backend = meta.get("build_backend", "jnp")
    if build_backend == "pallas" and \
            ops.backend_name(ops.resolve_backend("auto")) != "pallas":
        import warnings
        warnings.warn(
            "this database was built with the Pallas kernel backend; on "
            "a non-TPU host its queries/inserts run the kernel in "
            "interpret mode (orders of magnitude slower). Rebuild with "
            "backend='jnp' for CPU serving.", RuntimeWarning,
            stacklevel=3)

    env_radius = meta.get("env_radius")
    index = SSHIndex(
        fns=fns,
        signatures=arrays["signatures"],
        keys=arrays["keys"],
        series=arrays.get("series"),
        host_buckets=host_buckets,
        env_radius=env_radius,
        env_upper=arrays.get("env_upper"),
        env_lower=arrays.get("env_lower"),
        encoder=enc,
        # v1 metas predate the knob; their signatures are jnp-built
        build_backend=build_backend)

    config = (SearchConfig.from_dict(meta["config"])
              if meta.get("config") else None)
    return index, config


def is_database_dir(directory: str | Path) -> bool:
    """True when ``directory`` holds a complete saved database."""
    return (Path(directory) / META_FILE).exists()
