"""TimeSeriesDB — the FAISS-style facade over the SSH pipeline.

One object, five verbs::

    from repro.db import SearchConfig, TimeSeriesDB

    cfg = SearchConfig(band=8, searcher="batched")
    db = TimeSeriesDB.build(series, SSHParams(...), cfg)   # Alg. 1
    res = db.search(query)                                 # Alg. 2
    ress = db.search_batch(queries)                        # fused batch
    db.add(new_series)                                     # streaming
    db.save("/data/ssh_ecg")                               # persist

    db2 = TimeSeriesDB.load("/data/ssh_ecg")               # restart
    assert np.array_equal(db2.search(query).ids, res.ids)  # bit-identical

Routing is a config knob, not an API choice: the same ``TimeSeriesDB``
serves through the sequential re-rank (``searcher="local"``), the fused
batched path (``"batched"``, default), shard fan-out over a mesh
(``"distributed"``), or the dynamic-batching engine (``"engine"``) —
see ``repro.db.registry``.  Legacy entry points (``ssh_search`` kwargs,
flat ``SearchConfig(max_batch=..., max_wait_ms=...)`` batcher kwargs)
remain as deprecation shims for one release; batcher policy lives on
``SearchConfig.batch_policy`` (a ``BatchPolicy``).
"""
from __future__ import annotations

from concurrent.futures import Future
from pathlib import Path
from typing import List, Optional

import jax.numpy as jnp

from repro.core.index import SSHIndex, SSHParams
from repro.core.search import SearchResult
from repro.db import persistence, registry
from repro.db.config import SearchConfig


class TimeSeriesDB:
    """An SSH index plus the search policy that answers queries over it.

    The searcher backend is constructed lazily on first use and owned by
    the database (``close()`` — or the context manager — releases it;
    only the "engine" backend holds a thread).  ``mesh`` is forwarded to
    mesh-aware searchers ("distributed"); others ignore it.
    """

    def __init__(self, index: SSHIndex,
                 config: Optional[SearchConfig] = None, *, mesh=None):
        self.index = index
        self.config = self._fit_config(
            index, (config if config is not None
                    else SearchConfig()).validate())
        self.mesh = mesh
        self._searcher = None
        self._ingestor = None      # lazy shard-local StreamIngestor
        self._subseq = None        # set by build_stream / subseq load

    @staticmethod
    def _fit_config(index: SSHIndex, config: SearchConfig) -> SearchConfig:
        """Clamp knobs the index's encoder cannot honour: an encoder
        without shift-alignment classes (``"srp"``) has nothing to
        multiprobe, so ``multiprobe_offsets`` folds to 1 instead of
        every search raising after a completed O(N) build."""
        if (config.multiprobe_offsets > 1
                and not index.enc.supports_multiprobe):
            config = config.replace(multiprobe_offsets=1)
        return config

    # -- construction -----------------------------------------------------
    @classmethod
    def build(cls, series: jnp.ndarray, params=None,
              config: Optional[SearchConfig] = None, *, spec=None,
              mesh=None, batch: int = 256) -> "TimeSeriesDB":
        """Paper Alg. 1 behind the facade.

        Canonical form: ``TimeSeriesDB.build(series, spec=IndexSpec(...),
        config=SearchConfig(...))`` — the frozen ``IndexSpec`` names the
        encoder (``"ssh"``, ``"srp"``, ``"ssh-multires"``, or any
        registered encoder) and its stage params; a legacy ``SSHParams``
        in the ``params`` slot still works as a one-release deprecation
        shim with identical results.  ``config.backend`` drives the
        signature-build kernels too (Pallas ``sketch_conv`` on the
        "pallas"/TPU-auto path).

        Host buckets are built when the config probes them, and the
        database envelopes are precomputed at ``config.band`` when the
        LB cascade will consume them (turns every serving-path LB_Keogh2
        into a gather+compare — DESIGN.md §3).
        """
        config = (config if config is not None else SearchConfig()) \
            .validate()
        if spec is None and params is not None:
            # lower here so the DeprecationWarning names THIS entry point
            # and points at the user's call site
            from repro.core.index import _spec_from_legacy
            spec = _spec_from_legacy(params, "TimeSeriesDB.build")
            params = None
        env_band = config.band if config.use_lb_cascade else None
        index = SSHIndex.build(
            jnp.asarray(series), params, spec=spec,
            with_host_buckets=config.use_host_buckets, batch=batch,
            envelope_band=env_band, backend=config.backend)
        return cls(index, config, mesh=mesh)

    @classmethod
    def build_stream(cls, stream, params=None,
                     config: Optional[SearchConfig] = None, *, spec=None,
                     mesh=None) -> "TimeSeriesDB":
        """Index every sliding window of ONE long stream (repro.subseq).

        ``config.subseq_window`` (required) is the window length L;
        ``config.subseq_hop`` the start spacing.  The build encodes the
        stream once through the rolling sketch (O(N·W) total filter
        work, windows never materialised); queries go through
        :meth:`search_subsequence`, growth through :meth:`extend_stream`
        — the fixed-length verbs (``search``/``add``) raise on a
        stream-built database.
        """
        config = (config if config is not None else SearchConfig()) \
            .validate()
        if config.subseq_window is None:
            raise ValueError(
                "build_stream needs config.subseq_window (the sliding-"
                "window length L to index)")
        if spec is None and params is not None:
            from repro.core.index import _spec_from_legacy
            spec = _spec_from_legacy(params, "TimeSeriesDB.build_stream")
        if spec is None:
            raise TypeError("TimeSeriesDB.build_stream() needs spec= "
                            "(an IndexSpec) or a legacy SSHParams")
        from repro.subseq import SubsequenceIndex
        sub = SubsequenceIndex.build(
            stream, spec, length=config.subseq_window,
            hop=config.subseq_hop, backend=config.backend)
        db = cls(sub.inner, config, mesh=mesh)
        db._subseq = sub
        return db

    # -- search policy ----------------------------------------------------
    @property
    def searcher(self):
        """The active searcher backend (created on first access)."""
        if self._searcher is None:
            self._searcher = registry.make_searcher(
                self.index, self.config, mesh=self.mesh)
        return self._searcher

    def reconfigure(self, **changes) -> "TimeSeriesDB":
        """Swap search-time knobs in place (closes the old searcher).

        ``db.reconfigure(band=8, searcher="engine")`` — the index is
        untouched; only the policy object changes.  Returns ``self``.
        """
        new = self._fit_config(self.index, self.config.replace(**changes))
        if self._searcher is not None:
            self._searcher.close()
            self._searcher = None
        self.config = new
        return self

    def with_config(self, config: SearchConfig) -> "TimeSeriesDB":
        """A second facade over the *same* index with a different policy
        (shares storage; each facade owns its own searcher)."""
        return TimeSeriesDB(self.index, config, mesh=self.mesh)

    # -- stream/fixed-length mode guards ----------------------------------
    def _reject_subseq(self, verb: str) -> None:
        if self._subseq is not None:
            raise ValueError(
                f"{verb}() serves fixed-length databases; this one "
                "indexes sliding windows of a single stream — use "
                "search_subsequence() to query it and extend_stream() "
                "to grow it")

    def _require_subseq(self, verb: str):
        if self._subseq is None:
            raise ValueError(
                f"{verb}() needs a stream-built database "
                "(TimeSeriesDB.build_stream); this one indexes "
                "fixed-length series — use search()/add()")
        return self._subseq

    @property
    def subseq(self):
        """The underlying ``SubsequenceIndex`` (stream-built only)."""
        return self._require_subseq("subseq")

    # -- queries ----------------------------------------------------------
    def search(self, query: jnp.ndarray) -> SearchResult:
        """Top-k for one query through the configured searcher."""
        self._reject_subseq("search")
        return self.searcher.search(jnp.asarray(query))

    def search_batch(self, queries: jnp.ndarray) -> List[SearchResult]:
        """Per-query top-k for a (B, m) block; results identical to
        ``search`` on each row (serving equality contract)."""
        self._reject_subseq("search_batch")
        return self.searcher.search_batch(jnp.asarray(queries))

    def search_subsequence(self, query: jnp.ndarray,
                           config: Optional[SearchConfig] = None):
        """Top-k non-overlapping stream windows by banded DTW
        (stream-built databases; see ``repro.subseq``).

        Returns a ``SubsequenceResult`` — ``.offsets`` holds the match
        start positions, pairwise at least ``config.exclusion_zone``
        apart (default L//2).  ``config`` overrides the database's
        search policy per call.
        """
        sub = self._require_subseq("search_subsequence")
        return sub.search(query, config if config is not None
                          else self.config)

    def submit(self, query: jnp.ndarray) -> Future:
        """Async search; a real queue on the "engine" backend, an
        immediately-resolved future elsewhere."""
        return self.searcher.submit(jnp.asarray(query))

    # -- mutation ---------------------------------------------------------
    def add(self, series: jnp.ndarray) -> None:
        """Streaming insert (data-independent hashing ⇒ no retraining).

        Routed through the searcher when one is live — the engine
        backend serialises inserts against in-flight batches — else
        straight into the index.  Accepts (m,) or (B, m).
        """
        self._reject_subseq("add")
        series = jnp.asarray(series)
        if series.ndim == 1:
            series = series[None, :]
        if self._searcher is not None:
            self._searcher.insert(series)
        else:
            self.index.insert(series)

    def extend_stream(self, tail) -> int:
        """Append points to a stream-built database; exactly the windows
        they complete are rolling-encoded and folded in (signatures
        bit-identical to a full rebuild).  Returns the number of new
        windows now searchable."""
        return self._require_subseq("extend_stream").extend_stream(tail)

    def add_stream(self, series: jnp.ndarray, *, seq: Optional[int] = None,
                   shard: str = "local") -> None:
        """Continuous ingest: encode *now*, fold into the searchable
        index on :meth:`flush` (DESIGN.md §9).

        Appends may arrive out of order — tag each with its stream
        position ``seq`` (auto-increment when omitted); the fold orders
        rows by seq, so any arrival order yields the same index.  With
        the ``"ssh-cs"`` encoder the pending sketch aggregate rides
        along and merges into the persisted ``cs/agg`` at flush time.
        Accepts ``(m,)`` or ``(B, m)``.
        """
        self._reject_subseq("add_stream")
        if self._ingestor is None:
            from repro.streaming import StreamIngestor
            self._ingestor = StreamIngestor(
                self.index.enc, shard=shard,
                backend=self.index.build_backend)
        self._ingestor.append(series, seq=seq)

    def flush(self) -> None:
        """Fold pending :meth:`add_stream` appends into the index (no-op
        when nothing is pending).  Queries see the rows afterwards."""
        ingestor, self._ingestor = self._ingestor, None
        if ingestor is not None and len(ingestor):
            self.apply_stream(ingestor)

    def apply_stream(self, ingestor) -> None:
        """Fold a (possibly merged, possibly remote-shard)
        ``StreamIngestor`` into this database — the global half of a
        shard-parallel build: no raw series is re-encoded; the rows land
        in the ingestor's seq order and the shard's sketch aggregate
        merges into the encoder's persisted one."""
        if ingestor.encoder.spec != self.spec:
            raise ValueError(
                f"cannot fold a stream ingested under "
                f"{ingestor.encoder.spec!r} into a database built from "
                f"{self.spec!r}")
        arts = ingestor.artifacts()
        if self._searcher is not None:
            self._searcher.flush()
            self._searcher.apply_artifacts(arts)
        else:
            self.index.insert_encoded(arts.series, arts.signatures,
                                      arts.keys)
        if arts.sketch is not None and hasattr(self.index.enc,
                                               "absorb_sketch"):
            self.index.enc.absorb_sketch(arts.sketch)

    # -- persistence ------------------------------------------------------
    def save(self, directory: str | Path) -> Path:
        """Persist index + config; ``load`` restores bit-identically.

        Pending streamed ``add()``s (queued by the engine searcher
        between batches) and pending ``add_stream()`` appends are
        flushed into the index first, so every mutation that returned
        before ``save()`` is in the snapshot — including the ``"ssh-cs"``
        sketch aggregate, which persists under ``encoder/cs/agg`` so the
        reloaded database keeps ingesting where this one stopped.
        """
        if self._subseq is not None:
            return self._subseq.save(directory, self.config)
        self.flush()
        if self._searcher is not None:
            self._searcher.flush()
        return persistence.save_database(directory, self.index, self.config)

    @classmethod
    def load(cls, directory: str | Path,
             config: Optional[SearchConfig] = None, *, mesh=None
             ) -> "TimeSeriesDB":
        """Restore a saved database.

        ``config`` overrides the saved search policy (the saved one is
        used when omitted; defaults when the saver recorded none).  The
        restored index answers bit-identical top-k to the pre-save index
        and still accepts streaming ``add()``.
        """
        from repro.subseq import is_subseq_dir
        if is_subseq_dir(directory):
            from repro.subseq import SubsequenceIndex
            sub, saved_cfg = SubsequenceIndex.load(directory)
            db = cls(sub.inner,
                     config if config is not None else saved_cfg,
                     mesh=mesh)
            db._subseq = sub
            return db
        index, saved_cfg = persistence.load_database(directory)
        cfg = config if config is not None else saved_cfg
        db = cls(index, cfg, mesh=mesh)
        # a host-bucket config without persisted buckets: rebuild locally
        if db.config.use_host_buckets and index.host_buckets is None:
            from repro.core.index import HostBuckets
            import numpy as np
            index.host_buckets = HostBuckets(index.num_tables)
            index.host_buckets.insert(np.asarray(index.keys))
        return db

    # -- lifecycle / introspection ---------------------------------------
    def close(self) -> None:
        """Stop the searcher backend (engine thread); idempotent."""
        if self._searcher is not None:
            self._searcher.close()
            self._searcher = None

    def __enter__(self) -> "TimeSeriesDB":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    @property
    def engine(self):
        """The underlying ``ServingEngine`` (engine searcher only)."""
        searcher = self.searcher
        if not hasattr(searcher, "engine"):
            raise AttributeError(
                f"searcher {self.config.searcher!r} has no engine; "
                "use SearchConfig(searcher='engine')")
        return searcher.engine

    @property
    def spec(self):
        """The frozen ``repro.encoders.IndexSpec`` this index was built
        from (what persistence records and ``load`` reconstructs by)."""
        return self.index.enc.spec

    @property
    def params(self) -> Optional[SSHParams]:
        """Legacy ``SSHParams`` view (``None`` for non-ssh encoders)."""
        return self.index.fns.params if self.index.fns is not None else None

    @property
    def length(self) -> int:
        """Series length m — the indexed window length L on a
        stream-built database (what a query must measure either way)."""
        if self._subseq is not None:
            return int(self._subseq.length)
        return int(self.index.series.shape[1])

    def __len__(self) -> int:
        return int(self.index.signatures.shape[0])

    def __repr__(self) -> str:
        return (f"TimeSeriesDB(n={len(self)}, "
                f"encoder={self.spec.encoder!r}, "
                f"K={self.index.num_hashes}, L={self.index.num_tables}, "
                f"searcher={self.config.searcher!r}, "
                f"backend={self.config.backend!r})")
