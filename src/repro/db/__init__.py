"""repro.db — FAISS-style database facade for the SSH index (DESIGN.md §6).

Public API:
  SearchConfig                       — every search-time knob, one object
  BatchPolicy                        — the dynamic batcher's policy
                                       (SearchConfig.batch_policy)
  TimeSeriesDB                       — build / add / search / search_batch
                                       / save / load facade
  register_searcher / available_searchers / make_searcher
                                     — pluggable searcher backends
  save_database / load_database      — index persistence primitives

``SearchConfig`` is imported eagerly (it sits below the legacy entry
points in the import graph — ``repro.core.search`` and
``repro.serving`` shim through it); everything touching the pipeline is
loaded lazily via PEP 562 so ``from repro.db.config import SearchConfig``
never drags the whole serving stack in.
"""
from repro.db.config import BatchPolicy, SearchConfig

_LAZY = {
    "IndexSpec": ("repro.encoders.base", "IndexSpec"),
    "TimeSeriesDB": ("repro.db.database", "TimeSeriesDB"),
    "register_searcher": ("repro.db.registry", "register_searcher"),
    "available_searchers": ("repro.db.registry", "available_searchers"),
    "make_searcher": ("repro.db.registry", "make_searcher"),
    "save_database": ("repro.db.persistence", "save_database"),
    "load_database": ("repro.db.persistence", "load_database"),
    "is_database_dir": ("repro.db.persistence", "is_database_dir"),
}

__all__ = ["BatchPolicy", "SearchConfig", *_LAZY]


def __getattr__(name):
    if name in _LAZY:
        import importlib
        module, attr = _LAZY[name]
        value = getattr(importlib.import_module(module), attr)
        globals()[name] = value          # cache for subsequent lookups
        return value
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


def __dir__():
    return sorted(__all__)
