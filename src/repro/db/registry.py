"""Searcher-backend registry — how a ``TimeSeriesDB`` answers queries.

A *searcher* turns (index, config) into answers.  Five ship built in,
all serving the same contract (``search`` → ``SearchResult``,
``search_batch`` → list of per-query ``SearchResult``, ``insert``):

* ``"local"``   — sequential ``ssh_search`` per query; the only backend
  that honours ``use_host_buckets`` (paper-faithful dict tables).
* ``"batched"`` — the fused batched path (``ssh_search_batch``): one
  signature dispatch, one collision-count kernel pass, union-gathered
  pair DTW.  Per-query results identical to ``"local"`` by the serving
  equality contract.  The default.
* ``"distributed"`` — shard fan-out over a jax mesh through
  ``repro.distributed.dist_index`` (row-sharded index, one all_gather of
  k·2 scalars per query).
* ``"engine"`` — the dynamic-batching ``ServingEngine`` (bucketed
  padding, streaming inserts, background batcher thread); adds
  ``submit()`` for async clients.
* ``"fleet"``  — the resilient tier (``repro.fleet``): R-way replicated
  shard placement, hedged fan-out with failover, live drain/resize.
  ``"distributed"`` also routes here automatically when
  ``config.replication > 1`` (the mesh shard_map path is one fused
  program and cannot hedge or survive a shard loss).

``register_searcher`` lets downstream code plug in new backends (e.g. a
GPU-resident or RPC-fronted searcher) without touching the facade:
``SearchConfig.searcher`` names any registered factory.
"""
from __future__ import annotations

from concurrent.futures import Future
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp

from repro.db.config import SearchConfig

_FACTORIES: Dict[str, Callable] = {}


def register_searcher(name: str) -> Callable:
    """Decorator: register ``factory(index, config, *, mesh=None)`` under
    ``name`` (overwrites a prior registration, latest wins)."""
    def deco(factory: Callable) -> Callable:
        _FACTORIES[name] = factory
        return factory
    return deco


def available_searchers() -> List[str]:
    return sorted(_FACTORIES)


def make_searcher(index, config: SearchConfig, *, mesh=None):
    """Instantiate the searcher named by ``config.searcher``."""
    try:
        factory = _FACTORIES[config.searcher]
    except KeyError:
        raise ValueError(
            f"unknown searcher {config.searcher!r}; registered: "
            f"{available_searchers()}") from None
    return factory(index, config, mesh=mesh)


class _SearcherBase:
    """Shared plumbing: configs, insert routing, no-op close.

    ``mesh`` is accepted (and ignored) by every factory so the registry
    can pass it uniformly; only the distributed searcher consumes it.
    """

    def __init__(self, index, config: SearchConfig, *, mesh=None):
        self.index = index
        self.config = config

    def insert(self, series: jnp.ndarray) -> None:
        self.index.insert(series)

    def apply_artifacts(self, artifacts) -> None:
        """Fold a ``StreamIngestor`` fold (``StreamArtifacts``) into the
        live index without re-hashing.  Backends with device-resident or
        serialised state override (engine: under the serve lock;
        distributed: re-places the sharded rows)."""
        self.index.insert_encoded(artifacts.series, artifacts.signatures,
                                  artifacts.keys)

    def flush(self) -> None:
        """Make pending inserts visible in the index (no-op for
        synchronous backends; the engine drains its insert queue)."""

    def close(self) -> None:
        """Release background resources (threads); idempotent."""

    def submit(self, query: jnp.ndarray) -> Future:
        """Async convenience: synchronous backends resolve immediately."""
        fut: Future = Future()
        try:
            fut.set_result(self.search(query))
        except Exception as exc:            # pragma: no cover - passthrough
            fut.set_exception(exc)
        return fut


@register_searcher("local")
class LocalSearcher(_SearcherBase):
    """Sequential re-rank: one ``ssh_search`` per query."""

    def search(self, query: jnp.ndarray):
        from repro.core.search import ssh_search
        return ssh_search(query, self.index, config=self.config)

    def search_batch(self, queries: jnp.ndarray) -> List:
        return [self.search(q) for q in jnp.asarray(queries)]


@register_searcher("batched")
class BatchedSearcher(_SearcherBase):
    """Fused batched path — wraps ``serving.engine.BatchedSearcher``
    (which precomputes the candidate envelopes at ``config.band`` so
    every LB_Keogh2 is a gather+compare, DESIGN.md §3) behind the
    per-query facade contract."""

    def __init__(self, index, config: SearchConfig, *, mesh=None):
        super().__init__(index, config)
        from repro.serving.engine import BatchedSearcher as _Batched
        self._inner = _Batched(index, config)

    def search_batch(self, queries: jnp.ndarray) -> List:
        queries = jnp.asarray(queries)
        res = self._inner.search_batch(queries)
        return [res.per_query(i) for i in range(int(queries.shape[0]))]

    def search(self, query: jnp.ndarray):
        return self.search_batch(jnp.asarray(query)[None, :])[0]

    def insert(self, series: jnp.ndarray) -> None:
        self._inner.insert(series)


@register_searcher("distributed")
class DistributedSearcher(_SearcherBase):
    """Shard fan-out over a mesh (defaults to one axis over every local
    device).  The shard_map probe requires ``band`` set, single-probe,
    signature ranking — ``serving.engine.DistributedSearcher`` validates."""

    def __init__(self, index, config: SearchConfig, *, mesh=None):
        super().__init__(index, config)
        if config.replication > 1:
            # replication needs per-shard dispatch the fused shard_map
            # program cannot express — serve through the fleet tier
            from repro.fleet import FleetSearcher
            self._inner = FleetSearcher(index, config)
            self.mesh = None
            return
        if mesh is None:
            import jax
            mesh = jax.make_mesh((jax.device_count(),), ("data",))
        from repro.serving.engine import DistributedSearcher as _Dist
        self._inner = _Dist(index, config, mesh)
        self.mesh = mesh

    def search_batch(self, queries: jnp.ndarray) -> List:
        queries = jnp.asarray(queries)
        res = self._inner.search_batch(queries)
        return [res.per_query(i) for i in range(int(queries.shape[0]))]

    def search(self, query: jnp.ndarray):
        return self.search_batch(jnp.asarray(query)[None, :])[0]

    def insert(self, series: jnp.ndarray) -> None:
        self._inner.insert(series)          # raises: reshard required

    def apply_artifacts(self, artifacts) -> None:
        self._inner.apply_artifacts(artifacts)

    def resize(self, mesh) -> None:
        """Elastic shard move: re-place the encoded rows + encoder state
        under a new mesh — no raw series is re-encoded or reshuffled.
        When serving through the fleet tier, pass an int worker count or
        name list instead of a mesh (live minimal-movement rebalance)."""
        if self.mesh is None:
            self._inner.resize(mesh)        # fleet: int or name list
            return
        self.mesh = mesh
        self._inner.resize(mesh)

    def close(self) -> None:
        close = getattr(self._inner, "close", None)
        if close is not None:
            close()


@register_searcher("fleet")
class FleetRegistrySearcher(_SearcherBase):
    """The resilient tier behind the facade: replicated shard placement,
    hedged fan-out, failover, and live drain/resize (``repro.fleet``).
    Exposes the fleet's ``injector`` for chaos tests and the fleet
    counters for observability."""

    def __init__(self, index, config: SearchConfig, *, mesh=None):
        super().__init__(index, config)
        from repro.fleet import FleetSearcher
        self._inner = FleetSearcher(index, config)

    @property
    def injector(self):
        return self._inner.injector

    @property
    def fleet(self):
        return self._inner

    def search_batch(self, queries: jnp.ndarray) -> List:
        queries = jnp.asarray(queries)
        res = self._inner.search_batch(queries)
        return [res.per_query(i) for i in range(int(queries.shape[0]))]

    def search(self, query: jnp.ndarray):
        return self.search_batch(jnp.asarray(query)[None, :])[0]

    def insert(self, series: jnp.ndarray) -> None:
        self._inner.insert(series)          # raises: stream + fold instead

    def apply_artifacts(self, artifacts) -> None:
        self._inner.apply_artifacts(artifacts)

    def resize(self, workers) -> int:
        return self._inner.resize(workers)

    def drain(self, worker: str) -> int:
        return self._inner.drain(worker)

    def fail_worker(self, worker: str) -> int:
        return self._inner.fail_worker(worker)

    def close(self) -> None:
        self._inner.close()


@register_searcher("engine")
class EngineSearcher(_SearcherBase):
    """Dynamic-batching ``ServingEngine`` behind the facade.

    The batcher thread starts lazily on the first query (or explicitly
    via ``start()``); ``close()`` drains and stops it.  ``submit``
    exposes the async path; ``metrics`` the engine's counters.
    """

    def __init__(self, index, config: SearchConfig, *, mesh=None):
        super().__init__(index, config)
        from repro.serving.engine import ServingEngine
        self.engine = ServingEngine(index, config)

    @property
    def metrics(self):
        return self.engine.metrics

    def start(self) -> "EngineSearcher":
        self.engine.start()
        return self

    def search(self, query: jnp.ndarray):
        self._ensure_started()
        return self.engine.search(jnp.asarray(query))

    def search_batch(self, queries: jnp.ndarray) -> List:
        self._ensure_started()
        return self.engine.search_batch(jnp.asarray(queries))

    def submit(self, query: jnp.ndarray) -> Future:
        self._ensure_started()
        return self.engine.submit(jnp.asarray(query))

    def insert(self, series: jnp.ndarray) -> None:
        self.engine.insert(series)

    def apply_artifacts(self, artifacts) -> None:
        self.engine.apply_artifacts(artifacts)

    def flush(self) -> None:
        self.engine.flush_inserts()

    def close(self) -> None:
        self.engine.stop()

    def _ensure_started(self) -> None:
        if self.engine._thread is None and self.engine._state != "stopped":
            self.engine.start()
