"""SearchConfig — the one object holding every search-time knob.

FAISS-style split (DESIGN.md §6): *build-time* structure lives in
``SSHParams`` (window, stride, shingle length, number of hashes/tables —
what the index *is*), while everything a query can vary lives here (what
a query *does*).  Every entry point — ``ssh_search``,
``ssh_search_batch``, the ``ServingEngine``, the distributed
``make_query_fn``, and the ``TimeSeriesDB`` facade — consumes this one
frozen dataclass, so a config tuned in a benchmark can be handed to the
serving engine verbatim and mean the same thing.

This module is import-light on purpose (no ``repro.core``/``repro.serving``
imports): the legacy entry points shim through it, so it must sit below
them in the import graph.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional

#: Searcher backends shipped with :mod:`repro.db.registry`.  Third-party
#: registrations extend the registry at runtime; ``validate()`` only
#: rejects names when the registry is importable and disagrees.
BUILTIN_SEARCHERS = ("local", "batched", "distributed", "engine", "fleet")

_KERNEL_BACKENDS = ("auto", "pallas", "jnp")

_HEDGE_POLICIES = ("off", "fixed", "adaptive")

_BATCH_MODES = ("fixed", "adaptive")


@dataclasses.dataclass(frozen=True)
class BatchPolicy:
    """Dynamic-batcher policy of the ``ServingEngine`` (DESIGN.md §12).

    ``mode="fixed"`` is the classic two-knob batcher: the batch closes at
    ``max_batch`` requests or ``max_wait_ms`` after it opened, whichever
    comes first.  ``mode="adaptive"`` replaces the fixed wait with a
    closed-loop budget computed from the instantaneous queue depth and an
    EWMA of per-batch service seconds (:meth:`wait_budget_s`), so the
    engine tracks the latency/throughput knee without hand-tuning.

    Either mode changes only *which requests share a dispatch* and the
    padding geometry — never the per-query answers (the engine's batched
    path is bit-identical to sequential search for every batch
    composition; enforced in ``tests/test_loadgen.py``).

    * ``max_batch`` — requests per dispatch ceiling (also bounds the
      compiled bucket shapes, :meth:`buckets`).
    * ``max_wait_ms`` — wait ceiling: the fixed-mode deadline, and the
      hard upper clamp on the adaptive budget.
    * ``min_wait_ms`` — adaptive lower clamp (never spin below this
      unless the queue already covers the batch).
    * ``gain`` — fraction of the EWMA batch service time worth spending
      on waiting for more arrivals (marginal-gain knob: a batch amortises
      its fixed dispatch cost, which scales with the service time).
    * ``ewma_alpha`` — smoothing of the per-batch service-seconds EWMA
      (weight of the newest observation).
    """

    mode: str = "fixed"
    max_batch: int = 8
    max_wait_ms: float = 2.0
    min_wait_ms: float = 0.05
    gain: float = 0.5
    ewma_alpha: float = 0.3

    def validate(self) -> "BatchPolicy":
        if self.mode not in _BATCH_MODES:
            raise ValueError(f"batch mode must be one of {_BATCH_MODES}, "
                             f"got {self.mode!r}")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if not 0 <= self.min_wait_ms <= self.max_wait_ms:
            raise ValueError(
                f"min_wait_ms ({self.min_wait_ms}) must be within "
                f"[0, max_wait_ms={self.max_wait_ms}]")
        if self.gain <= 0:
            raise ValueError(f"gain must be > 0, got {self.gain}")
        if not 0 < self.ewma_alpha <= 1:
            raise ValueError(
                f"ewma_alpha must be in (0, 1], got {self.ewma_alpha}")
        return self

    def replace(self, **changes: Any) -> "BatchPolicy":
        """``dataclasses.replace`` + ``validate`` in one step."""
        return dataclasses.replace(self, **changes).validate()

    def buckets(self) -> List[int]:
        """Padded batch sizes for the dynamic batcher: powers of two up
        to ``max_batch`` (bounds the number of compiled programs)."""
        out, b = [], 1
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return out

    def wait_budget_s(self, have: int, depth: int,
                      service_ewma_s: Optional[float],
                      engine_idle: bool = True,
                      arrival_gap_s: Optional[float] = None) -> float:
        """Seconds the batcher should keep a ``have``-request batch open
        given ``depth`` queued behind it (the adaptive control law).

        Fixed mode ignores the load signals and returns the classic
        deadline.  Adaptive mode::

            queue covers the batch     ->  0             (drain)
            batch opened while busy    ->  min_wait_ms   (keep draining)
            no EWMA yet                ->  max_wait_ms   (fixed fallback)
            arrivals sparser than the
            justified wait             ->  min_wait_ms   (nothing to
                                                          coalesce)
            else (idle engine, dense
            arrivals)                  ->  clip(gain * S * (1 - fill),
                                              min_wait, max_wait)

        with ``fill = (have + depth) / max_batch``, ``S`` the EWMA of
        per-batch service seconds, and ``arrival_gap_s`` the EWMA of
        inter-submit gaps.  The marginal gain of waiting is amortising
        the per-batch fixed cost (which scales with ``S``) over one
        more request; the marginal cost is every request in hand
        waiting.  Two discriminators keep that trade honest:
        ``engine_idle`` — a batch opened back-to-back with the previous
        one means the engine is the bottleneck, waiting cannot raise
        throughput and only inflates tail latency, so drain at the
        floor (batch size comes from whatever queued during the last
        service); and ``arrival_gap_s`` — a stretch shorter than the
        typical inter-arrival gap coalesces nothing, it is pure added
        latency, so it must cover at least one expected arrival to be
        worth paying.  Only an idle engine seeing arrivals denser than
        the justified wait stretches (up to the ceiling).
        """
        if self.mode == "fixed":
            return self.max_wait_ms / 1e3
        if have + depth >= self.max_batch:
            return 0.0                      # queue covers the batch: drain
        if not engine_idle:
            return self.min_wait_ms / 1e3   # busy: draining beats waiting
        if service_ewma_s is None:
            return self.max_wait_ms / 1e3   # no telemetry yet
        fill = (have + depth) / self.max_batch
        budget = min(max(self.gain * service_ewma_s * (1.0 - fill),
                         self.min_wait_ms / 1e3),
                     self.max_wait_ms / 1e3)
        if arrival_gap_s is not None and arrival_gap_s > budget:
            return self.min_wait_ms / 1e3   # too sparse to coalesce
        return budget

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BatchPolicy":
        """Tolerant inverse of ``to_dict`` (unknown keys dropped with a
        warning, mirroring ``SearchConfig.from_dict``)."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = sorted(set(d) - known)
        if extra:
            warnings.warn(f"BatchPolicy.from_dict: ignoring unknown "
                          f"fields {extra}", RuntimeWarning, stacklevel=2)
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Every search-time knob of the SSH pipeline, in one place.

    Candidate generation (paper Alg. 2 lines 5-9):

    * ``topk`` — results returned per query.
    * ``top_c`` — hash candidates kept for the re-rank stage (device-scan
      probe width; DESIGN.md §3).
    * ``rank_by_signature`` — rank candidates by agreement over all K raw
      CWS hashes instead of the L banded keys (finer granularity;
      beyond-paper refinement — set False for the paper-faithful probe).
    * ``multiprobe_offsets`` — hash the query at each δ-residue shift and
      take the per-candidate max collision count (recovers the shingle
      alignment classes the database grid cannot see).
    * ``use_host_buckets`` — probe the paper-faithful Python dict tables
      instead of the device scan (reference semantics; ``local`` searcher
      only).

    Re-rank (Alg. 2 line 10, ``repro.core.rerank``):

    * ``band`` — Sakoe-Chiba radius for the banded DTW; ``None`` means
      unconstrained DTW and disables the envelope bounds.
    * ``use_lb_cascade`` — staged LB_Kim → LB_Keogh → LB_Keogh2 pruning
      of hash candidates before paying full DTW (results unchanged; the
      bounds are sound).
    * ``seed_size`` — how many best-hash hits are seed-DTW'd to obtain
      the pruning threshold (Lemire's two-pass lower-bound idea,
      arXiv:0811.3301).  ``None`` seeds exactly ``topk`` candidates; a
      larger seed buys a tighter threshold for more cascade pruning at
      the cost of more up-front DTW.  Top-k results are unaffected
      either way (the threshold stays a valid upper bound).
    * ``early_abandon`` — thread the seeded threshold into the DTW stage
      itself (early-abandoning PrunedDTW, arXiv:2010.05371): survivor
      lanes whose running anti-diagonal minimum exceeds the threshold
      stop early and report BIG.  Returned top-k is bit-identical with
      the knob on or off (an abandoned lane is provably worse than the
      k-th seeded distance); off disables the in-kernel threshold for
      A/B timing.  Only active together with ``use_lb_cascade`` and a
      ``band`` (those supply the threshold).

    Execution:

    * ``backend`` — kernel implementation for every device stage
      (collision count + DTW): "pallas" | "jnp" | "auto" (Pallas on TPU).
      Results are backend-independent.
    * ``searcher`` — which registered searcher serves the queries:
      "local" (sequential re-rank), "batched" (fused batched path),
      "distributed" (shard fan-out over a mesh), "engine" (dynamic
      batcher), "fleet" (replicated hedged fan-out with failover and
      live elasticity).  See ``repro.db.registry``.
    * ``batch_policy`` — the dynamic batcher's :class:`BatchPolicy`
      ("engine" searcher and ``ServingEngine`` only): fixed
      ``max_batch``/``max_wait_ms`` two-knob batching, or
      ``mode="adaptive"`` closed-loop waits driven by queue depth and
      the service-time EWMA (DESIGN.md §12).  The flat ``max_batch=`` /
      ``max_wait_ms=`` constructor kwargs remain as one-release
      ``DeprecationWarning`` shims that populate the policy.

    Resilience (``repro.fleet``, "fleet" searcher; DESIGN.md §11):

    * ``replication`` — replicas per shard (R).  1 = no redundancy;
      R >= 2 lets the fleet survive R-1 concurrent worker losses per
      shard and hedge stragglers, with bit-identical results.
    * ``fleet_workers`` — fleet size W; ``None`` sizes the fleet to
      ``max(2, replication)``.  Must be >= ``replication`` (R distinct
      workers per shard cannot co-locate).
    * ``hedge_policy`` — "off" (never hedge; failover on errors only),
      "fixed" (hedge after ``hedge_ms``), or "adaptive" (hedge after
      ``max(hedge_ms, StragglerPolicy.threshold × fleet-median shard
      time)``, immediately for a worker already striking as a
      straggler; never before telemetry exists).
    * ``hedge_ms`` — hedging deadline floor in milliseconds.

    Telemetry:

    * ``stage_timings`` — record per-stage wall clock (encode → probe →
      LB cascade → banded DTW) into ``SearchStats.stage_seconds``,
      device-synchronized at each stage boundary
      (``repro.bench.timing.StageTimer``).  Results are unaffected; the
      stage barriers cost nothing on CPU (the pipeline already syncs at
      those points) but serialize overlapping dispatch on accelerators —
      set False on a latency-critical TPU deployment.

    Subsequence search (``repro.subseq``, stream-built databases only):

    * ``subseq_window`` — sliding-window length L indexed over the
      stream; ``None`` everywhere except ``TimeSeriesDB.build_stream``,
      which requires it.
    * ``subseq_hop`` — window start spacing h (windows start at 0, h,
      2h, …).  Hops divisible by the sketch stride δ take the fully
      shared rolling-encode path.
    * ``exclusion_zone`` — minimum offset separation between two
      returned matches (UCR-style trivial-match suppression); ``None``
      defaults to L//2 at query time, 0 disables deduplication.
    """

    topk: int = 10
    top_c: int = 256
    band: Optional[int] = None
    use_lb_cascade: bool = True
    rank_by_signature: bool = True
    multiprobe_offsets: int = 1
    use_host_buckets: bool = False
    seed_size: Optional[int] = None
    early_abandon: bool = True
    backend: str = "auto"
    searcher: str = "batched"
    batch_policy: BatchPolicy = BatchPolicy()
    replication: int = 1
    fleet_workers: Optional[int] = None
    hedge_policy: str = "adaptive"
    hedge_ms: float = 30.0
    stage_timings: bool = True
    subseq_window: Optional[int] = None
    subseq_hop: int = 1
    exclusion_zone: Optional[int] = None
    # One-release deprecation shims: the historical flat batcher knobs.
    # Init-only (not part of the dataclass schema): when passed they warn
    # and fold into ``batch_policy``, results identical.  They are NOT
    # readable back — read ``cfg.batch_policy.max_batch`` instead
    # (``dataclasses.replace`` re-feeds InitVar defaults through
    # ``getattr``, so a read alias here would silently overwrite an
    # explicitly-passed new policy).
    max_batch: dataclasses.InitVar[Optional[int]] = None
    max_wait_ms: dataclasses.InitVar[Optional[float]] = None

    def __post_init__(self, max_batch, max_wait_ms):
        if max_batch is None and max_wait_ms is None:
            return
        warnings.warn(
            "SearchConfig(max_batch=..., max_wait_ms=...) flat batcher "
            "kwargs are deprecated; pass "
            "batch_policy=repro.db.BatchPolicy(...) instead",
            DeprecationWarning, stacklevel=3)
        changes = {}
        if max_batch is not None:
            changes["max_batch"] = max_batch
        if max_wait_ms is not None:
            changes["max_wait_ms"] = max_wait_ms
        object.__setattr__(self, "batch_policy",
                           dataclasses.replace(self.batch_policy,
                                               **changes))

    # -- validation -------------------------------------------------------
    def validate(self) -> "SearchConfig":
        """Raise ``ValueError`` on inconsistent knobs; returns ``self``
        so call sites can chain (``config.validate()`` at every facade
        boundary)."""
        if self.topk < 1:
            raise ValueError(f"topk must be >= 1, got {self.topk}")
        if self.top_c < 1:
            raise ValueError(f"top_c must be >= 1, got {self.top_c}")
        if self.top_c < self.topk:
            raise ValueError(
                f"top_c ({self.top_c}) must be >= topk ({self.topk}); "
                "the hash stage must supply at least topk candidates")
        if self.band is not None and self.band < 1:
            raise ValueError(f"band must be None or >= 1, got {self.band}")
        if self.multiprobe_offsets < 1:
            raise ValueError("multiprobe_offsets must be >= 1, got "
                             f"{self.multiprobe_offsets}")
        if self.seed_size is not None and self.seed_size < self.topk:
            raise ValueError(
                f"seed_size ({self.seed_size}) must be None or >= topk "
                f"({self.topk}): the cascade threshold is the topk-th "
                "best of the seeded set, so a smaller seed would prune "
                "true top-k members")
        if self.backend not in _KERNEL_BACKENDS:
            raise ValueError(f"backend must be one of {_KERNEL_BACKENDS}, "
                             f"got {self.backend!r}")
        if not isinstance(self.searcher, str) or not self.searcher:
            raise ValueError(f"searcher must be a non-empty string, "
                             f"got {self.searcher!r}")
        if self.use_host_buckets and self.searcher != "local":
            raise ValueError(
                "use_host_buckets is only served by the 'local' searcher "
                f"(got searcher={self.searcher!r}); the batched/"
                "distributed paths probe the device-side key matrix")
        if not isinstance(self.batch_policy, BatchPolicy):
            raise ValueError(f"batch_policy must be a BatchPolicy, "
                             f"got {type(self.batch_policy).__name__}")
        self.batch_policy.validate()
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}")
        if self.fleet_workers is not None and self.fleet_workers < 1:
            raise ValueError(f"fleet_workers must be None or >= 1, "
                             f"got {self.fleet_workers}")
        if (self.fleet_workers is not None
                and self.replication > self.fleet_workers):
            raise ValueError(
                f"replication ({self.replication}) > fleet_workers "
                f"({self.fleet_workers}): each shard needs that many "
                "distinct workers (replicas never co-locate)")
        if self.hedge_policy not in _HEDGE_POLICIES:
            raise ValueError(f"hedge_policy must be one of "
                             f"{_HEDGE_POLICIES}, got {self.hedge_policy!r}")
        if self.hedge_ms <= 0:
            raise ValueError(
                f"hedge_ms must be > 0, got {self.hedge_ms}")
        if self.subseq_window is not None and self.subseq_window < 1:
            raise ValueError(f"subseq_window must be None or >= 1, "
                             f"got {self.subseq_window}")
        if self.subseq_hop < 1:
            raise ValueError(f"subseq_hop must be >= 1, "
                             f"got {self.subseq_hop}")
        if self.exclusion_zone is not None and self.exclusion_zone < 0:
            raise ValueError(f"exclusion_zone must be None or >= 0, "
                             f"got {self.exclusion_zone}")
        return self

    # -- derived ----------------------------------------------------------
    def replace(self, **changes: Any) -> "SearchConfig":
        """``dataclasses.replace`` + ``validate`` in one step."""
        return dataclasses.replace(self, **changes).validate()

    def buckets(self) -> List[int]:
        """Padded batch sizes for the dynamic batcher (delegates to
        ``batch_policy.buckets()``)."""
        return self.batch_policy.buckets()

    # -- (de)serialisation (index persistence carries the config) ---------
    def to_dict(self) -> Dict[str, Any]:
        """JSON-ready dict; ``batch_policy`` nests as its own dict."""
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SearchConfig":
        """Tolerant inverse of ``to_dict``: unknown keys (a config written
        by a newer release) are dropped with a warning instead of failing
        the load.  Configs persisted before the ``BatchPolicy`` surface
        carried flat ``max_batch``/``max_wait_ms`` keys — those fold into
        the policy silently (a saved database keeps loading, identical
        behaviour, no deprecation noise for an on-disk artifact).
        """
        d = dict(d)
        policy = d.get("batch_policy")
        policy = (BatchPolicy.from_dict(policy) if isinstance(policy, dict)
                  else policy if policy is not None else BatchPolicy())
        legacy = {k: d.pop(k) for k in ("max_batch", "max_wait_ms")
                  if k in d}
        legacy = {k: v for k, v in legacy.items() if v is not None}
        if legacy:
            policy = dataclasses.replace(policy, **legacy)
        d["batch_policy"] = policy
        known = {f.name for f in dataclasses.fields(cls)}
        extra = sorted(set(d) - known)
        if extra:
            warnings.warn(f"SearchConfig.from_dict: ignoring unknown "
                          f"fields {extra}", RuntimeWarning, stacklevel=2)
        return cls(**{k: v for k, v in d.items() if k in known})


def config_from_legacy_kwargs(caller: str, kwargs: Dict[str, Any],
                              base: Optional[SearchConfig] = None
                              ) -> SearchConfig:
    """Build a ``SearchConfig`` from a legacy loose-kwarg call site.

    Shared by the deprecation shims (``ssh_search``, ``ssh_search_batch``,
    ``make_query_fn``): warns once per call site that the kwarg form is
    deprecated, rejects unknown names loudly (a typo'd knob must not be
    silently dropped), and overlays the kwargs on ``base`` (defaults when
    None) so shim results are bit-identical to the config form.
    """
    # the flat batcher knobs ride through their own InitVar shims
    known = {f.name for f in dataclasses.fields(SearchConfig)} \
        | {"max_batch", "max_wait_ms"}
    unknown = sorted(set(kwargs) - known)
    if unknown:
        raise TypeError(f"{caller}() got unexpected keyword arguments "
                        f"{unknown}; known search knobs: {sorted(known)}")
    if kwargs:
        warnings.warn(
            f"passing loose search kwargs to {caller}() is deprecated; "
            f"pass config=repro.db.SearchConfig(...) instead",
            DeprecationWarning, stacklevel=3)
    base = base if base is not None else SearchConfig()
    kwargs = dict(kwargs)
    # fold flat batcher knobs into the policy here (already warned above
    # — going through the InitVar shim would warn a second time)
    flat = {k: kwargs.pop(k) for k in ("max_batch", "max_wait_ms")
            if k in kwargs}
    if flat:
        kwargs["batch_policy"] = dataclasses.replace(base.batch_policy,
                                                     **flat)
    return dataclasses.replace(base, **kwargs)
