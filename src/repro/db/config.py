"""SearchConfig — the one object holding every search-time knob.

FAISS-style split (DESIGN.md §6): *build-time* structure lives in
``SSHParams`` (window, stride, shingle length, number of hashes/tables —
what the index *is*), while everything a query can vary lives here (what
a query *does*).  Every entry point — ``ssh_search``,
``ssh_search_batch``, the ``ServingEngine``, the distributed
``make_query_fn``, and the ``TimeSeriesDB`` facade — consumes this one
frozen dataclass, so a config tuned in a benchmark can be handed to the
serving engine verbatim and mean the same thing.

This module is import-light on purpose (no ``repro.core``/``repro.serving``
imports): the legacy entry points shim through it, so it must sit below
them in the import graph.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, List, Optional

#: Searcher backends shipped with :mod:`repro.db.registry`.  Third-party
#: registrations extend the registry at runtime; ``validate()`` only
#: rejects names when the registry is importable and disagrees.
BUILTIN_SEARCHERS = ("local", "batched", "distributed", "engine", "fleet")

_KERNEL_BACKENDS = ("auto", "pallas", "jnp")

_HEDGE_POLICIES = ("off", "fixed", "adaptive")


@dataclasses.dataclass(frozen=True)
class SearchConfig:
    """Every search-time knob of the SSH pipeline, in one place.

    Candidate generation (paper Alg. 2 lines 5-9):

    * ``topk`` — results returned per query.
    * ``top_c`` — hash candidates kept for the re-rank stage (device-scan
      probe width; DESIGN.md §3).
    * ``rank_by_signature`` — rank candidates by agreement over all K raw
      CWS hashes instead of the L banded keys (finer granularity;
      beyond-paper refinement — set False for the paper-faithful probe).
    * ``multiprobe_offsets`` — hash the query at each δ-residue shift and
      take the per-candidate max collision count (recovers the shingle
      alignment classes the database grid cannot see).
    * ``use_host_buckets`` — probe the paper-faithful Python dict tables
      instead of the device scan (reference semantics; ``local`` searcher
      only).

    Re-rank (Alg. 2 line 10, ``repro.core.rerank``):

    * ``band`` — Sakoe-Chiba radius for the banded DTW; ``None`` means
      unconstrained DTW and disables the envelope bounds.
    * ``use_lb_cascade`` — staged LB_Kim → LB_Keogh → LB_Keogh2 pruning
      of hash candidates before paying full DTW (results unchanged; the
      bounds are sound).
    * ``seed_size`` — how many best-hash hits are seed-DTW'd to obtain
      the pruning threshold (Lemire's two-pass lower-bound idea,
      arXiv:0811.3301).  ``None`` seeds exactly ``topk`` candidates; a
      larger seed buys a tighter threshold for more cascade pruning at
      the cost of more up-front DTW.  Top-k results are unaffected
      either way (the threshold stays a valid upper bound).
    * ``early_abandon`` — thread the seeded threshold into the DTW stage
      itself (early-abandoning PrunedDTW, arXiv:2010.05371): survivor
      lanes whose running anti-diagonal minimum exceeds the threshold
      stop early and report BIG.  Returned top-k is bit-identical with
      the knob on or off (an abandoned lane is provably worse than the
      k-th seeded distance); off disables the in-kernel threshold for
      A/B timing.  Only active together with ``use_lb_cascade`` and a
      ``band`` (those supply the threshold).

    Execution:

    * ``backend`` — kernel implementation for every device stage
      (collision count + DTW): "pallas" | "jnp" | "auto" (Pallas on TPU).
      Results are backend-independent.
    * ``searcher`` — which registered searcher serves the queries:
      "local" (sequential re-rank), "batched" (fused batched path),
      "distributed" (shard fan-out over a mesh), "engine" (dynamic
      batcher), "fleet" (replicated hedged fan-out with failover and
      live elasticity).  See ``repro.db.registry``.
    * ``max_batch`` / ``max_wait_ms`` — dynamic-batcher policy
      (latency/throughput trade-off; "engine" searcher and
      ``ServingEngine`` only).

    Resilience (``repro.fleet``, "fleet" searcher; DESIGN.md §11):

    * ``replication`` — replicas per shard (R).  1 = no redundancy;
      R >= 2 lets the fleet survive R-1 concurrent worker losses per
      shard and hedge stragglers, with bit-identical results.
    * ``fleet_workers`` — fleet size W; ``None`` sizes the fleet to
      ``max(2, replication)``.  Must be >= ``replication`` (R distinct
      workers per shard cannot co-locate).
    * ``hedge_policy`` — "off" (never hedge; failover on errors only),
      "fixed" (hedge after ``hedge_ms``), or "adaptive" (hedge after
      ``max(hedge_ms, StragglerPolicy.threshold × fleet-median shard
      time)``, immediately for a worker already striking as a
      straggler; never before telemetry exists).
    * ``hedge_ms`` — hedging deadline floor in milliseconds.

    Telemetry:

    * ``stage_timings`` — record per-stage wall clock (encode → probe →
      LB cascade → banded DTW) into ``SearchStats.stage_seconds``,
      device-synchronized at each stage boundary
      (``repro.bench.timing.StageTimer``).  Results are unaffected; the
      stage barriers cost nothing on CPU (the pipeline already syncs at
      those points) but serialize overlapping dispatch on accelerators —
      set False on a latency-critical TPU deployment.

    Subsequence search (``repro.subseq``, stream-built databases only):

    * ``subseq_window`` — sliding-window length L indexed over the
      stream; ``None`` everywhere except ``TimeSeriesDB.build_stream``,
      which requires it.
    * ``subseq_hop`` — window start spacing h (windows start at 0, h,
      2h, …).  Hops divisible by the sketch stride δ take the fully
      shared rolling-encode path.
    * ``exclusion_zone`` — minimum offset separation between two
      returned matches (UCR-style trivial-match suppression); ``None``
      defaults to L//2 at query time, 0 disables deduplication.
    """

    topk: int = 10
    top_c: int = 256
    band: Optional[int] = None
    use_lb_cascade: bool = True
    rank_by_signature: bool = True
    multiprobe_offsets: int = 1
    use_host_buckets: bool = False
    seed_size: Optional[int] = None
    early_abandon: bool = True
    backend: str = "auto"
    searcher: str = "batched"
    max_batch: int = 8
    max_wait_ms: float = 2.0
    replication: int = 1
    fleet_workers: Optional[int] = None
    hedge_policy: str = "adaptive"
    hedge_ms: float = 30.0
    stage_timings: bool = True
    subseq_window: Optional[int] = None
    subseq_hop: int = 1
    exclusion_zone: Optional[int] = None

    def __post_init__(self):
        """Subclass hook (the deprecated ``EngineConfig`` warns here)."""

    # -- validation -------------------------------------------------------
    def validate(self) -> "SearchConfig":
        """Raise ``ValueError`` on inconsistent knobs; returns ``self``
        so call sites can chain (``config.validate()`` at every facade
        boundary)."""
        if self.topk < 1:
            raise ValueError(f"topk must be >= 1, got {self.topk}")
        if self.top_c < 1:
            raise ValueError(f"top_c must be >= 1, got {self.top_c}")
        if self.top_c < self.topk:
            raise ValueError(
                f"top_c ({self.top_c}) must be >= topk ({self.topk}); "
                "the hash stage must supply at least topk candidates")
        if self.band is not None and self.band < 1:
            raise ValueError(f"band must be None or >= 1, got {self.band}")
        if self.multiprobe_offsets < 1:
            raise ValueError("multiprobe_offsets must be >= 1, got "
                             f"{self.multiprobe_offsets}")
        if self.seed_size is not None and self.seed_size < self.topk:
            raise ValueError(
                f"seed_size ({self.seed_size}) must be None or >= topk "
                f"({self.topk}): the cascade threshold is the topk-th "
                "best of the seeded set, so a smaller seed would prune "
                "true top-k members")
        if self.backend not in _KERNEL_BACKENDS:
            raise ValueError(f"backend must be one of {_KERNEL_BACKENDS}, "
                             f"got {self.backend!r}")
        if not isinstance(self.searcher, str) or not self.searcher:
            raise ValueError(f"searcher must be a non-empty string, "
                             f"got {self.searcher!r}")
        if self.use_host_buckets and self.searcher != "local":
            raise ValueError(
                "use_host_buckets is only served by the 'local' searcher "
                f"(got searcher={self.searcher!r}); the batched/"
                "distributed paths probe the device-side key matrix")
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_wait_ms < 0:
            raise ValueError(
                f"max_wait_ms must be >= 0, got {self.max_wait_ms}")
        if self.replication < 1:
            raise ValueError(
                f"replication must be >= 1, got {self.replication}")
        if self.fleet_workers is not None and self.fleet_workers < 1:
            raise ValueError(f"fleet_workers must be None or >= 1, "
                             f"got {self.fleet_workers}")
        if (self.fleet_workers is not None
                and self.replication > self.fleet_workers):
            raise ValueError(
                f"replication ({self.replication}) > fleet_workers "
                f"({self.fleet_workers}): each shard needs that many "
                "distinct workers (replicas never co-locate)")
        if self.hedge_policy not in _HEDGE_POLICIES:
            raise ValueError(f"hedge_policy must be one of "
                             f"{_HEDGE_POLICIES}, got {self.hedge_policy!r}")
        if self.hedge_ms <= 0:
            raise ValueError(
                f"hedge_ms must be > 0, got {self.hedge_ms}")
        if self.subseq_window is not None and self.subseq_window < 1:
            raise ValueError(f"subseq_window must be None or >= 1, "
                             f"got {self.subseq_window}")
        if self.subseq_hop < 1:
            raise ValueError(f"subseq_hop must be >= 1, "
                             f"got {self.subseq_hop}")
        if self.exclusion_zone is not None and self.exclusion_zone < 0:
            raise ValueError(f"exclusion_zone must be None or >= 0, "
                             f"got {self.exclusion_zone}")
        return self

    # -- derived ----------------------------------------------------------
    def replace(self, **changes: Any) -> "SearchConfig":
        """``dataclasses.replace`` + ``validate`` in one step."""
        return dataclasses.replace(self, **changes).validate()

    def buckets(self) -> List[int]:
        """Padded batch sizes for the dynamic batcher: powers of two up
        to ``max_batch`` (bounds the number of compiled programs)."""
        out, b = [], 1
        while b < self.max_batch:
            out.append(b)
            b *= 2
        out.append(self.max_batch)
        return out

    # -- (de)serialisation (index persistence carries the config) ---------
    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "SearchConfig":
        """Tolerant inverse of ``to_dict``: unknown keys (a config written
        by a newer release) are dropped with a warning instead of failing
        the load."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = sorted(set(d) - known)
        if extra:
            warnings.warn(f"SearchConfig.from_dict: ignoring unknown "
                          f"fields {extra}", RuntimeWarning, stacklevel=2)
        return cls(**{k: v for k, v in d.items() if k in known})


def config_from_legacy_kwargs(caller: str, kwargs: Dict[str, Any],
                              base: Optional[SearchConfig] = None
                              ) -> SearchConfig:
    """Build a ``SearchConfig`` from a legacy loose-kwarg call site.

    Shared by the deprecation shims (``ssh_search``, ``ssh_search_batch``,
    ``make_query_fn``): warns once per call site that the kwarg form is
    deprecated, rejects unknown names loudly (a typo'd knob must not be
    silently dropped), and overlays the kwargs on ``base`` (defaults when
    None) so shim results are bit-identical to the config form.
    """
    known = {f.name for f in dataclasses.fields(SearchConfig)}
    unknown = sorted(set(kwargs) - known)
    if unknown:
        raise TypeError(f"{caller}() got unexpected keyword arguments "
                        f"{unknown}; known search knobs: {sorted(known)}")
    if kwargs:
        warnings.warn(
            f"passing loose search kwargs to {caller}() is deprecated; "
            f"pass config=repro.db.SearchConfig(...) instead",
            DeprecationWarning, stacklevel=3)
    base = base if base is not None else SearchConfig()
    return dataclasses.replace(base, **kwargs)
