"""CLI: validate BENCH_*.json files against the schema.

  PYTHONPATH=src python -m repro.bench.validate reports/bench/BENCH_*.json

Exits nonzero on the first invalid (or stage-breakdown-less) report —
the CI ``bench-smoke`` job runs this over the artifacts it uploads, so a
schema drift or a module that stopped reporting stage timings fails the
producing PR.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.bench.schema import (SchemaError, has_full_stage_breakdown,
                                validate_report)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("files", nargs="+", help="BENCH_*.json paths")
    ap.add_argument("--no-require-stages", action="store_true",
                    help="skip the full-stage-breakdown requirement")
    args = ap.parse_args(argv)
    rc = 0
    for path in args.files:
        try:
            with open(path) as f:
                doc = json.load(f)
            validate_report(doc)
        except (OSError, json.JSONDecodeError, SchemaError) as exc:
            print(f"INVALID {path}: {exc}")
            rc = 1
            continue
        if not args.no_require_stages and not has_full_stage_breakdown(doc):
            print(f"INVALID {path}: no result carries the full "
                  "encode/probe/lb/dtw stage breakdown")
            rc = 1
            continue
        print(f"ok      {path}: {len(doc['results'])} results "
              f"(scale={doc['scale']}, sha={doc['git_sha'][:9] or 'n/a'})")
    return rc


if __name__ == "__main__":
    sys.exit(main())
