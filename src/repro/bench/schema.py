"""Versioned benchmark result schema (DESIGN.md §8).

One ``BENCH_<module>.json`` file per benchmark module, written by
:class:`repro.bench.BenchRunner`:

* :class:`BenchCase` — the frozen workload identity of one measurement
  (dataset, length, database size, batch, ``IndexSpec``/``SearchConfig``
  dicts, resolved kernel backend).  Two runs with equal cases are
  comparable; the baseline diff matches entries by name and assumes the
  case is part of that name's contract.
* :class:`BenchResult` — one measured row: latency (``us_per_query``
  plus p50/p95 when multiple samples exist), the per-stage breakdown in
  microseconds (``stage_us``: encode/probe/lb/dtw), pruning and quality
  (``lb_pruned_frac``, ``precision_at_k``), build time, and a free-form
  ``derived`` dict holding the historical CSV payload.
* :class:`BenchReport` — the file: schema version, module name, git sha,
  scale, host fingerprint, and the result rows.

``validate_report`` is the single gate every emitted file passes (the
runner validates before writing; CI validates the artifacts again), so a
schema drift fails loudly in the producing PR instead of corrupting the
trajectory read by later PRs.
"""
from __future__ import annotations

import dataclasses
import json
import math
from pathlib import Path
from typing import Any, Dict, List, Optional

from repro.bench.timing import STAGES

SCHEMA_VERSION = 1

SCALES = ("smoke", "small", "full")

#: stage_us may carry the canonical stages plus "fused" (the
#: distributed fan-out cannot split its shard_map program) and
#: "encode_amortized" (subsequence search: the build-side rolling
#: encode seconds divided over the indexed windows — the per-window
#: encode cost each query's probe amortises, constant per query).
STAGE_KEYS = STAGES + ("fused", "encode_amortized")


class SchemaError(ValueError):
    """A BENCH_*.json document violates the schema."""


@dataclasses.dataclass(frozen=True)
class BenchCase:
    """Frozen workload identity of one benchmark measurement."""

    dataset: str                      # "ecg" | "randomwalk" | "synthetic"
    length: int                       # series length m
    n_database: int                   # indexed series N
    batch: int = 1                    # queries per dispatch
    spec: Optional[Dict[str, Any]] = None     # IndexSpec.to_dict()
    config: Optional[Dict[str, Any]] = None   # SearchConfig.to_dict()
    backend: str = "jnp"              # resolved kernel backend

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchCase":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in known})


@dataclasses.dataclass
class BenchResult:
    """One measured benchmark row."""

    name: str                         # e.g. "table3/ecg/len128"
    us_per_query: float               # headline latency (mean)
    us_p50: Optional[float] = None
    us_p95: Optional[float] = None
    stage_us: Optional[Dict[str, float]] = None
    lb_pruned_frac: Optional[float] = None
    precision_at_k: Optional[float] = None
    build_s: Optional[float] = None
    case: Optional[BenchCase] = None
    derived: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["case"] = self.case.to_dict() if self.case is not None else None
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchResult":
        known = {f.name for f in dataclasses.fields(cls)}
        kw = {k: v for k, v in d.items() if k in known}
        if kw.get("case") is not None:
            kw["case"] = BenchCase.from_dict(kw["case"])
        return cls(**kw)


@dataclasses.dataclass
class BenchReport:
    """One BENCH_<name>.json document."""

    name: str                         # benchmark module, e.g. "table3_query_time"
    scale: str
    git_sha: str
    results: List[BenchResult]
    host: Dict[str, Any] = dataclasses.field(default_factory=dict)
    created_unix: float = 0.0
    schema_version: int = SCHEMA_VERSION

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema_version": self.schema_version,
            "name": self.name,
            "scale": self.scale,
            "git_sha": self.git_sha,
            "created_unix": self.created_unix,
            "host": self.host,
            "results": [r.to_dict() for r in self.results],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "BenchReport":
        return cls(
            name=d["name"], scale=d["scale"], git_sha=d.get("git_sha", ""),
            results=[BenchResult.from_dict(r) for r in d.get("results", [])],
            host=d.get("host", {}),
            created_unix=d.get("created_unix", 0.0),
            schema_version=d.get("schema_version", SCHEMA_VERSION))

    def result(self, name: str) -> Optional[BenchResult]:
        for r in self.results:
            if r.name == name:
                return r
        return None


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------

def _finite_nonneg(x, where: str) -> None:
    if not isinstance(x, (int, float)) or isinstance(x, bool) \
            or not math.isfinite(x) or x < 0:
        raise SchemaError(f"{where}: expected finite number >= 0, got {x!r}")


def validate_report(doc: Dict[str, Any]) -> None:
    """Raise :class:`SchemaError` unless ``doc`` is a valid v1 report."""
    if not isinstance(doc, dict):
        raise SchemaError(f"report must be a JSON object, got {type(doc)}")
    if doc.get("schema_version") != SCHEMA_VERSION:
        raise SchemaError("schema_version must be "
                          f"{SCHEMA_VERSION}, got {doc.get('schema_version')!r}")
    for key in ("name", "scale", "git_sha"):
        if not isinstance(doc.get(key), str) or (key != "git_sha"
                                                 and not doc.get(key)):
            raise SchemaError(f"{key} must be a non-empty string")
    if doc["scale"] not in SCALES:
        raise SchemaError(f"scale must be one of {SCALES}, "
                          f"got {doc['scale']!r}")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        raise SchemaError("results must be a non-empty list")
    seen = set()
    for i, r in enumerate(results):
        where = f"results[{i}]"
        if not isinstance(r, dict):
            raise SchemaError(f"{where}: expected object")
        name = r.get("name")
        if not isinstance(name, str) or not name:
            raise SchemaError(f"{where}.name must be a non-empty string")
        if name in seen:
            raise SchemaError(f"duplicate result name {name!r}")
        seen.add(name)
        _finite_nonneg(r.get("us_per_query"), f"{where}.us_per_query")
        for opt in ("us_p50", "us_p95", "lb_pruned_frac",
                    "precision_at_k", "build_s"):
            if r.get(opt) is not None:
                _finite_nonneg(r[opt], f"{where}.{opt}")
        stage_us = r.get("stage_us")
        if stage_us is not None:
            if not isinstance(stage_us, dict):
                raise SchemaError(f"{where}.stage_us must be an object")
            unknown = sorted(set(stage_us) - set(STAGE_KEYS))
            if unknown:
                raise SchemaError(f"{where}.stage_us has unknown stages "
                                  f"{unknown}; known: {list(STAGE_KEYS)}")
            for k, v in stage_us.items():
                _finite_nonneg(v, f"{where}.stage_us[{k!r}]")


def has_full_stage_breakdown(doc: Dict[str, Any]) -> bool:
    """True when some result row carries all four canonical stages."""
    return any(set(STAGES) <= set(r.get("stage_us") or ())
               for r in doc.get("results", ()))


# ---------------------------------------------------------------------------
# file IO
# ---------------------------------------------------------------------------

def load_report(path: str | Path) -> BenchReport:
    with open(path) as f:
        doc = json.load(f)
    validate_report(doc)
    return BenchReport.from_dict(doc)


def dump_report(report: BenchReport, path: str | Path) -> Path:
    doc = report.to_dict()
    validate_report(doc)
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
        f.write("\n")
    return path
