"""BenchRunner — collects BenchResults and writes BENCH_<module>.json.

The benchmark modules stay importable, printable scripts (their
``run()`` still emits the historical CSV lines); when a runner is
installed (``benchmarks.run --json``), ``benchmarks.common.report``
additionally records a :class:`BenchResult` into the runner's current
module bucket, and the driver flushes one schema-validated
``BENCH_<module>.json`` per module — the machine-readable perf
trajectory that the CI gate and later PRs diff against.
"""
from __future__ import annotations

import platform
import subprocess
import time
from pathlib import Path
from typing import Dict, List, Optional

from repro.bench.schema import (BenchReport, BenchResult, dump_report,
                                load_report)
from repro.bench import regression as reg


def git_sha(repo_dir: Optional[str] = None) -> str:
    """Current commit sha ("" outside a git checkout / without git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=repo_dir,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() if out.returncode == 0 else ""
    except (OSError, subprocess.TimeoutExpired):
        return ""


def host_fingerprint() -> Dict[str, str]:
    import jax
    return {
        "platform": platform.platform(),
        "python": platform.python_version(),
        "jax": jax.__version__,
        "jax_backend": jax.default_backend(),
        "device_count": str(jax.device_count()),
    }


class BenchRunner:
    """Accumulates results per benchmark module; writes one report each.

    ``write_json=False`` keeps the reports in memory only (the CSV-only
    historical behaviour of ``benchmarks.run`` without ``--json``) while
    still letting tests inspect ``reports``.
    """

    def __init__(self, scale: str, out_dir: str | Path = ".",
                 write_json: bool = True, sha: Optional[str] = None):
        self.scale = scale
        self.out_dir = Path(out_dir)
        self.write_json = write_json
        self.sha = git_sha() if sha is None else sha
        self.host = host_fingerprint()
        self.reports: Dict[str, BenchReport] = {}
        self._module: Optional[str] = None
        self._results: List[BenchResult] = []

    # -- module lifecycle (driven by benchmarks/run.py) -------------------
    def start_module(self, name: str) -> None:
        if self._module is not None:
            raise RuntimeError(f"module {self._module!r} still open")
        self._module = name
        self._results = []

    def record(self, result: BenchResult) -> None:
        if self._module is None:
            raise RuntimeError("record() outside start_module/finish_module")
        self._results.append(result)

    def finish_module(self) -> Optional[Path]:
        """Validate + (optionally) write the open module's report.

        Returns the written path, or None when nothing was recorded or
        JSON output is off.
        """
        name, results = self._module, self._results
        self._module, self._results = None, []
        if not results:
            return None
        report = BenchReport(
            name=name, scale=self.scale, git_sha=self.sha,
            results=results, host=self.host, created_unix=time.time())
        self.reports[name] = report
        if not self.write_json:
            return None
        return dump_report(report, self.out_dir / f"BENCH_{name}.json")


# ---------------------------------------------------------------------------
# baseline diffing over report directories
# ---------------------------------------------------------------------------

def compare_dirs(current_dir: str | Path, baseline_dir: str | Path, *,
                 modules: Optional[List[str]] = None,
                 rel_threshold: float = reg.DEFAULT_REL_THRESHOLD,
                 min_us: float = reg.DEFAULT_MIN_US,
                 precision_tol: float = reg.DEFAULT_PRECISION_TOL):
    """Diff baseline ``BENCH_*.json`` files against their counterparts.

    The ONE home of the gate's file-level semantics (``benchmarks/run.py
    --baseline`` calls straight through here).  ``modules`` restricts
    the diff to ``BENCH_<module>.json`` names (a partial ``--only`` run
    must not flag the unran modules); ``None`` diffs every baseline
    file.  Returns ``(findings, missing_reports)`` where
    ``missing_reports`` names baseline files with no counterpart in
    ``current_dir`` (a vanished benchmark module must fail the gate
    just like a vanished entry).  Baseline-less current reports are
    ignored — a brand-new benchmark has no trajectory yet.
    """
    current_dir, baseline_dir = Path(current_dir), Path(baseline_dir)
    if modules is None:
        names = sorted(p.name for p in baseline_dir.glob("BENCH_*.json"))
    else:
        names = [f"BENCH_{m}.json" for m in modules
                 if (baseline_dir / f"BENCH_{m}.json").exists()]
    findings, missing = [], []
    for name in names:
        cur_path = current_dir / name
        if not cur_path.exists():
            missing.append(name)
            continue
        findings.extend(reg.compare_reports(
            load_report(cur_path), load_report(baseline_dir / name),
            rel_threshold=rel_threshold, min_us=min_us,
            precision_tol=precision_tol))
    return findings, missing
