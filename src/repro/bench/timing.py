"""Stage timers for the search hot path (DESIGN.md §8).

The pipeline's five stages — ``encode`` (query signature build),
``probe`` (collision count + top-C), ``lb`` (seed DTW for the pruning
threshold + the staged LB cascade), ``lb_improved`` (Lemire's two-pass
bound over cascade survivors), ``dtw`` (banded, early-abandoning DTW
over the survivors) — are timed with a :class:`StageTimer` threaded through
``hash_probe``/``rerank`` and their batched twins.  Accumulated seconds
land in ``SearchStats.stage_seconds`` so every entry point
(``ssh_search``, ``ssh_search_batch``, the ``ServingEngine``) surfaces
the same breakdown to ``repro.bench`` and ``ServingMetrics``.

Timing asynchronous dispatch honestly requires a device sync at each
stage boundary: the context manager yields a ``sync`` callable that the
instrumented code applies to the stage's output value
(``jax.block_until_ready``) before the clock stops.  A disabled timer
(``StageTimer(enabled=False)``, or the module default used when no
timer is passed) makes ``sync`` the identity and records nothing, so
the production path pays no extra barriers when telemetry is off
(``SearchConfig(stage_timings=False)``).
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import Dict

import jax

#: Canonical hot-path stages, pipeline order.  ``SearchStats``
#: carries exactly these keys when telemetry is on; the distributed
#: fan-out — whose shard_map program fuses all four — reports the
#: extra ``"fused"`` key instead (see ``serving.engine``).
STAGES = ("encode", "probe", "lb", "lb_improved", "dtw")


def _sync(value):
    """Block until ``value`` (any pytree) is computed; returns it."""
    return jax.block_until_ready(value)


def _identity(value):
    return value


class StageTimer:
    """Accumulates per-stage wall-clock seconds.

    Usage::

        timer = StageTimer()
        with timer.stage("encode") as sync:
            sig = sync(index.query_signature(q))
        timer.timings  # {"encode": 0.0012, ...}

    ``sync`` blocks on device values so the recorded span covers the
    stage's actual compute, not just its dispatch.  Re-entering a stage
    accumulates (the batched re-rank visits ``dtw`` once per chunk).
    """

    def __init__(self, enabled: bool = True, prefill=()):
        self.enabled = enabled
        self.timings: Dict[str, float] = \
            {s: 0.0 for s in prefill} if enabled else {}

    @contextmanager
    def stage(self, name: str):
        if not self.enabled:
            yield _identity
            return
        t0 = time.perf_counter()
        try:
            yield _sync
        finally:
            self.timings[name] = (self.timings.get(name, 0.0)
                                  + time.perf_counter() - t0)


#: Shared disabled timer — the default for un-instrumented callers, so
#: hot-path signatures can take ``timer=DISABLED`` without allocating.
DISABLED = StageTimer(enabled=False)
