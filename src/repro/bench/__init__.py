"""repro.bench — perf telemetry + benchmark trajectory subsystem.

Three layers (DESIGN.md §8):

* ``timing`` — :class:`StageTimer`, the synchronized per-stage clock the
  search hot path records into (``SearchStats.stage_seconds``).  Leaf
  module: importable from ``repro.core`` without cycles.
* ``schema`` — the versioned ``BENCH_*.json`` document model
  (:class:`BenchCase`/:class:`BenchResult`/:class:`BenchReport`) and its
  validator.
* ``runner``/``regression`` — :class:`BenchRunner` (writes one validated
  report per benchmark module) and the baseline diff that the CI
  ``bench-smoke`` gate exits nonzero on.

``python -m repro.bench.validate FILE...`` validates emitted reports
standalone (the CI artifact check).
"""
from repro.bench.timing import DISABLED, STAGES, StageTimer
from repro.bench.schema import (SCHEMA_VERSION, BenchCase, BenchReport,
                                BenchResult, SchemaError,
                                has_full_stage_breakdown, load_report,
                                dump_report, validate_report)
from repro.bench.regression import (Finding, compare_reports, failures)
from repro.bench.runner import BenchRunner, compare_dirs, git_sha

__all__ = [
    "DISABLED", "STAGES", "StageTimer",
    "SCHEMA_VERSION", "BenchCase", "BenchReport", "BenchResult",
    "SchemaError", "has_full_stage_breakdown", "load_report",
    "dump_report", "validate_report",
    "Finding", "compare_reports", "failures",
    "BenchRunner", "compare_dirs", "git_sha",
]
