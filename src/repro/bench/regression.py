"""Perf-regression detection over BENCH_*.json trajectories.

The comparison is deliberately conservative about CPU noise: a timing
entry only counts as a regression when it is slower than the baseline by
more than ``rel_threshold`` (a *fraction* — 1.0 means "2x the baseline")
AND both sides are above ``min_us`` (sub-noise-floor timings flap on
shared runners, and their absolute cost is irrelevant).  Quality metrics
(``precision_at_k``) are compared with an absolute tolerance — they are
deterministic for fixed seeds, but top-k tie-breaks can flip across
BLAS/jax versions, so the tolerance is not zero.

``compare_reports`` returns every finding (regressions, improvements,
entries missing from either side); callers decide severity —
``benchmarks/run.py --baseline`` exits nonzero on regressions and
missing-from-current entries, and merely prints improvements and
new entries.
"""
from __future__ import annotations

import dataclasses
from typing import List

from repro.bench.schema import BenchReport

#: Default noise allowance for cross-run timing comparison.  Generous on
#: purpose: CI runners differ from the machine that recorded the
#: committed baseline, so only multiple-of-baseline slowdowns gate.
DEFAULT_REL_THRESHOLD = 1.0
#: Timings below this (on either side) are never compared.
DEFAULT_MIN_US = 200.0
#: Absolute allowed drop in precision_at_k.
DEFAULT_PRECISION_TOL = 0.15


@dataclasses.dataclass
class Finding:
    kind: str    # "regression" | "improvement" | "missing" | "new" | "mismatch"
    entry: str   # result name, e.g. "table3/ecg/len128" (or report name)
    metric: str  # "us_per_query" | "precision_at_k" | "scale" | ""
    baseline: float | str = 0.0
    current: float | str = 0.0

    @property
    def is_failure(self) -> bool:
        return self.kind in ("regression", "missing", "mismatch")

    def __str__(self) -> str:
        if self.kind == "missing":
            return f"MISSING   {self.entry} (present in baseline, not in run)"
        if self.kind == "new":
            return f"NEW       {self.entry} (no baseline entry)"
        if self.kind == "mismatch":
            return (f"MISMATCH  {self.entry} {self.metric}: baseline "
                    f"{self.baseline!r} vs current {self.current!r} — "
                    "not comparable")
        ratio = self.current / self.baseline if self.baseline else float("inf")
        return (f"{self.kind.upper():<9} {self.entry} {self.metric}: "
                f"baseline {self.baseline:.1f} -> current {self.current:.1f} "
                f"({ratio:.2f}x)")


def compare_reports(current: BenchReport, baseline: BenchReport, *,
                    rel_threshold: float = DEFAULT_REL_THRESHOLD,
                    min_us: float = DEFAULT_MIN_US,
                    precision_tol: float = DEFAULT_PRECISION_TOL
                    ) -> List[Finding]:
    """All findings from diffing ``current`` against ``baseline``.

    A scale mismatch makes timings incomparable by construction (the
    workloads differ), so it short-circuits into a single failing
    ``mismatch`` finding instead of a wall of bogus regressions.
    """
    if current.scale != baseline.scale:
        return [Finding("mismatch", current.name, "scale",
                        baseline.scale, current.scale)]
    findings: List[Finding] = []
    cur_names = {r.name for r in current.results}
    for base in baseline.results:
        cur = current.result(base.name)
        if cur is None:
            findings.append(Finding("missing", base.name, ""))
            continue
        # -- latency ------------------------------------------------------
        b_us, c_us = base.us_per_query, cur.us_per_query
        if min(b_us, c_us) >= min_us:
            if c_us > b_us * (1.0 + rel_threshold):
                findings.append(Finding("regression", base.name,
                                        "us_per_query", b_us, c_us))
            elif b_us > c_us * (1.0 + rel_threshold):
                findings.append(Finding("improvement", base.name,
                                        "us_per_query", b_us, c_us))
        # -- quality ------------------------------------------------------
        if base.precision_at_k is not None \
                and cur.precision_at_k is not None:
            if cur.precision_at_k < base.precision_at_k - precision_tol:
                findings.append(Finding(
                    "regression", base.name, "precision_at_k",
                    base.precision_at_k, cur.precision_at_k))
    for name in sorted(cur_names - {r.name for r in baseline.results}):
        findings.append(Finding("new", name, ""))
    return findings


def failures(findings: List[Finding]) -> List[Finding]:
    return [f for f in findings if f.is_failure]
