import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST precede any jax import (device count locks on first jax init).

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, with zero real allocation (ShapeDtypeStruct
inputs):
  * compiled.memory_analysis()  — proves the cell fits per-device HBM
  * compiled.cost_analysis()    — FLOPs / bytes for §Roofline
  * collective payload bytes    — parsed from the partitioned HLO
and writes a JSON report consumed by launch/roofline.py.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-8b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_arch, list_archs
from repro.configs.base import ArchDef
from repro.distributed.sharding import batch_sharding, param_sharding
from repro.launch import hlo_analysis, steps
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.train.optimizer import AdamWState

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def _dp(mesh):
    axes = dp_axes(mesh)
    return axes if len(axes) > 1 else (axes[0] if axes else None)


def _dp_size(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in dp_axes(mesh)]))


def cache_sharding(cache_spec, mesh, batch: int):
    """LM decode cache: batch over dp when divisible, else seq over dp;
    the trailing latent/head dim shards over 'model' when divisible."""
    dp = _dp(mesh)
    dps = _dp_size(mesh)
    mp = mesh.shape.get("model", 1)

    def leaf(path, s):
        name = str(path[-1].key) if hasattr(path[-1], "key") else ""
        if name == "length":
            return NamedSharding(mesh, P())
        dims = [None] * len(s.shape)          # (L, B, T, ...) layouts
        if batch % dps == 0 and batch >= dps:
            dims[1] = dp
        elif s.shape[2] % dps == 0:
            dims[2] = dp
        if s.shape[-1] % mp == 0:
            dims[-1] = "model"
        return NamedSharding(mesh, P(*dims))

    return jax.tree_util.tree_map_with_path(leaf, cache_spec)


def opt_sharding(opt_spec: AdamWState, params_sh) -> AdamWState:
    mesh = jax.tree.leaves(params_sh)[0].mesh
    return AdamWState(step=NamedSharding(mesh, P()), m=params_sh,
                      v=params_sh, master=params_sh)


def _param_bytes(params_spec) -> float:
    return sum(np.prod(l.shape) * l.dtype.itemsize
               for l in jax.tree.leaves(params_spec))


def shardings_for(arch: ArchDef, shape: str, kind: str, state, mesh):
    # inference replicates weights over the data axes when they fit HBM
    # (<= 8 GB/device after model-axis sharding) — ZeRO all-gathers are a
    # training-only cost (§Perf iteration: granite prefill collectives)
    mp = mesh.shape.get("model", 1)
    drop_fsdp = (kind != "train"
                 and _param_bytes(state[0]) / mp <= 8e9)
    params_sh = param_sharding(state[0], mesh, arch.family,
                               drop_fsdp=drop_fsdp)
    # non-TP families spread the batch over EVERY mesh axis — leaving the
    # 'model' axis idle replicates compute mp-fold (§Perf iteration)
    batch_logical = "batch" if arch.family == "lm" else "batch_all"
    if kind == "train":
        opt_sh = opt_sharding(state[1], params_sh)
        overrides = {"^query$": P()} if arch.family == "ssh" else {}
        batch_sh = batch_sharding(state[2], mesh, overrides,
                                  batch_logical=batch_logical)
        return (params_sh, opt_sh, batch_sh), (params_sh, opt_sh, None), (0, 1)
    if kind == "decode":
        b = arch.shapes[shape].meta["batch"]
        cache_sh = cache_sharding(state[1], mesh, b)
        tok_sh = batch_sharding(state[2], mesh)
        return (params_sh, cache_sh, tok_sh), (None, cache_sh), (1,)
    # single-batch-arg kinds
    overrides = {}
    if arch.family == "ssh":
        overrides = {"query": P()}
    batch_sh = batch_sharding(state[1], mesh, overrides,
                              batch_logical=batch_logical)
    return (params_sh, batch_sh), None, ()


def run_cell(arch_name: str, shape: str, multi_pod: bool,
             report_dir: Path = REPORT_DIR, verbose: bool = True) -> dict:
    arch = get_arch(arch_name)
    mesh_name = "multi" if multi_pod else "single"
    out_path = report_dir / f"{arch_name}__{shape}__{mesh_name}.json"
    out_path.parent.mkdir(parents=True, exist_ok=True)

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    kind, state = steps.abstract_state(arch, shape)
    step = steps.make_step(arch, shape, kind)
    in_sh, out_sh, donate = shardings_for(arch, shape, kind, state, mesh)

    from repro.distributed.constraints import activation_sharding
    with mesh, activation_sharding(mesh):
        jitted = jax.jit(step, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*state)
        compiled = lowered.compile()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):     # jax 0.4.x: one dict per device
        cost = cost[0] if cost else {}
    # NOTE: XLA cost_analysis counts while bodies once and is per-device —
    # the executed_costs parser multiplies loop trip counts (validated
    # exact on hand-countable programs; see tests/test_hlo_graph.py).
    raw_flops = float(cost.get("flops", 0.0))
    bytes_accessed = float(cost.get("bytes accessed", 0.0))

    from repro.launch.hlo_graph import executed_costs
    hlo = compiled.as_text()
    execd = executed_costs(hlo)
    n_chips = int(mesh.devices.size)
    flops_total = execd.dot_flops * n_chips      # whole-mesh dot FLOPs
    coll_bytes = execd.total_coll_bytes          # per-device payload bytes
    terms = hlo_analysis.roofline_terms(flops_total, bytes_accessed * n_chips,
                                        coll_bytes, n_chips)

    report = {
        "arch": arch_name, "shape": shape, "mesh": mesh_name,
        "kind": kind, "n_chips": n_chips,
        "compile_seconds": round(time.time() - t0, 1),
        "flops": flops_total,
        "flops_per_device": execd.dot_flops,
        "cost_analysis_raw_flops": raw_flops,
        "bytes_accessed_per_device": bytes_accessed,
        "collectives": {k: {"bytes": execd.coll_bytes[k],
                            "count": execd.coll_counts[k]}
                        for k in execd.coll_bytes},
        "collective_bytes": coll_bytes,
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
            "output_bytes": getattr(mem, "output_size_in_bytes", 0),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
            "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                           + getattr(mem, "temp_size_in_bytes", 0)),
        },
        "roofline": terms,
    }
    out_path.write_text(json.dumps(report, indent=2))
    if verbose:
        print(f"[OK] {arch_name}/{shape}/{mesh_name}: "
              f"compile={report['compile_seconds']}s "
              f"flops={flops_total:.3e} bytes={bytes_accessed:.3e} "
              f"coll={coll_bytes:.3e}B dominant={terms['dominant']}")
        print(f"     memory_analysis: {mem}")
    return report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--report-dir", type=str, default=str(REPORT_DIR))
    args = ap.parse_args()

    cells = []
    if args.all:
        for name in list_archs():
            arch = get_arch(name)
            for shape in arch.shapes:
                cells.append((name, shape))
    else:
        arch = get_arch(args.arch)
        shapes = [args.shape] if args.shape else list(arch.shapes)
        cells = [(args.arch, s) for s in shapes]

    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    rdir = Path(args.report_dir)
    failures = []
    for name, shape in cells:
        for mp in meshes:
            tag = f"{name}__{shape}__{'multi' if mp else 'single'}"
            if args.skip_existing and (rdir / f"{tag}.json").exists():
                print(f"[skip] {tag}")
                continue
            try:
                run_cell(name, shape, mp, rdir)
            except Exception as e:  # noqa: BLE001 — report and continue
                failures.append(tag)
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}")
                traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)
    print("\nAll dry-run cells compiled.")


if __name__ == "__main__":
    main()
