"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module constant) so importing
this module never touches jax device state — the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 *before* any jax
initialisation and only then builds meshes.

Topology: TPU v5e pods of 16×16 = 256 chips; the multi-pod mesh prepends a
``pod`` axis (2 pods = 512 chips) used for an outer data-parallel replica
group (cross-pod traffic = gradient all-reduce only, matching DCN-class
bandwidth between pods).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh(model_parallel: int = 1) -> jax.sharding.Mesh:
    """Mesh over whatever devices exist (tests / single host)."""
    n = jax.device_count()
    mp = max(1, min(model_parallel, n))
    return jax.make_mesh((n // mp, mp), ("data", "model"))


def dp_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """The data-parallel axes of a mesh ('pod' folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def mp_axis(mesh: jax.sharding.Mesh) -> Optional[str]:
    return "model" if "model" in mesh.axis_names else None


def axis_size(mesh: jax.sharding.Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def describe(mesh: jax.sharding.Mesh) -> str:
    return (f"mesh axes={dict(mesh.shape)} devices={mesh.devices.size} "
            f"dp={axis_size(mesh, dp_axes(mesh))} "
            f"mp={axis_size(mesh, mp_axis(mesh))}")
