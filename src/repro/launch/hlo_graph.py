"""Executed-cost analysis of partitioned HLO text.

XLA's ``cost_analysis()`` counts every ``while`` body ONCE and reports
per-device numbers — useless for a roofline over scanned layers.  This
module parses the HLO text into its computation graph, extracts loop trip
counts from ``while`` conditions, and propagates costs bottom-up:

    cost(comp) = Σ own ops + Σ_{while} trip · cost(body)
               + Σ_{fusion/call/cond} cost(callee)

Costs tracked per computation:
  * dot FLOPs    = 2 · |result| · ∏(lhs contracting dims)   (exact)
  * collective payload bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute)
  * elementwise-ish FLOPs are ignored (dot-dominated workloads; noted in
    EXPERIMENTS.md)

Everything is *per device*; multiply FLOPs by n_chips for the whole mesh.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# params may be tuple-typed (nested parens) — match only the name
_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9]+"
    r"\[[0-9,]*\](?:\{[^}]*\})?))\s+([a-z0-9\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_OPERANDS_RE = re.compile(r"\(([^)]*)\)")
_CONST_RE = re.compile(r"constant\((\d+)\)")


def _shape_dims(shape_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(shape_str):
        out.append((dt, [int(d) for d in dims.split(",")] if dims else []))
    return out


def _bytes_of(shape_str: str) -> int:
    total = 0
    for dt, dims in _shape_dims(shape_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result_shape: str
    operands: List[str]
    attrs: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: Dict[str, Op]
    order: List[str]


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        stripped = line.strip()
        if cur is None:
            if stripped.endswith("{") and "->" in stripped:
                m = _HEADER_RE.match(stripped)
                if m:
                    cur = Computation(m.group(1), {}, [])
                    if stripped.startswith("ENTRY"):
                        entry = cur.name
            continue
        if stripped.startswith("}"):        # may carry a trailing comment
            comps[cur.name] = cur
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, shape, opcode = m.group(1), m.group(2), m.group(3)
        rest = line[m.end() - 1:]
        ops_m = _OPERANDS_RE.match(rest)
        operands = []
        if ops_m:
            inner = ops_m.group(1)
            # operands may carry a type prefix ("f32[2,3]{1,0} %x") whose
            # shape commas break naive splitting — pull the %names directly
            operands = re.findall(r"%([\w.\-]+)", inner)
            if not operands:
                operands = [t.strip() for t in inner.split(",") if t.strip()]
        # attrs keeps the FULL rest (incl. operand text) — constants like
        # `constant(40)` live inside the "operand" parens
        attrs = rest
        cur.ops[name] = Op(name, opcode, shape, operands, attrs)
        cur.order.append(name)
    return comps, entry


_REF_ATTRS = re.compile(
    r"(?:to_apply|body|condition|true_computation|false_computation|calls)="
    r"%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CDIMS = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def _trip_count(cond: Computation) -> int:
    """Max integer constant in the loop condition ≈ the trip count
    (jax scans compare an s32 counter LT bound)."""
    best = 1
    for op in cond.ops.values():
        if op.opcode == "constant" and op.result_shape.startswith("s32"):
            m = re.search(r"\((\d+)\)", op.attrs)
            if m:
                best = max(best, int(m.group(1)))
    return best


@dataclasses.dataclass
class Cost:
    dot_flops: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})
    coll_counts: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in COLLECTIVES})

    def add(self, other: "Cost", mult: float = 1.0) -> None:
        self.dot_flops += other.dot_flops * mult
        for k in COLLECTIVES:
            self.coll_bytes[k] += other.coll_bytes[k] * mult
            self.coll_counts[k] += other.coll_counts[k] * mult

    @property
    def total_coll_bytes(self) -> float:
        return sum(self.coll_bytes.values())


def _dot_flops(op: Op, comp: Computation) -> float:
    res = _shape_dims(op.result_shape)
    if not res:
        return 0.0
    n_out = 1
    for d in res[0][1]:
        n_out *= d
    lhs_name = op.operands[0] if op.operands else None
    lhs = comp.ops.get(lhs_name)
    if lhs is None:
        return 0.0
    lhs_dims = _shape_dims(lhs.result_shape)
    if not lhs_dims:
        return 0.0
    m = _LHS_CDIMS.search(op.attrs)
    k = 1
    if m and m.group(1):
        for idx in m.group(1).split(","):
            di = int(idx)
            if di < len(lhs_dims[0][1]):
                k *= lhs_dims[0][1][di]
    return 2.0 * n_out * k


def executed_costs(text: str) -> Cost:
    comps, entry = parse_hlo(text)
    memo: Dict[str, Cost] = {}

    def cost_of(name: str, stack=()) -> Cost:
        if name in memo:
            return memo[name]
        comp = comps.get(name)
        c = Cost()
        if comp is None or name in stack:
            return c
        for op in comp.ops.values():
            if op.opcode == "dot":
                c.dot_flops += _dot_flops(op, comp)
            elif op.opcode in COLLECTIVES or any(
                    op.opcode.startswith(k + "-") for k in COLLECTIVES):
                kind = next(k for k in COLLECTIVES
                            if op.opcode == k or op.opcode.startswith(k + "-"))
                b = _bytes_of(op.result_shape)
                c.coll_bytes[kind] += b
                c.coll_counts[kind] += 1
            if op.opcode == "while":
                refs = dict(re.findall(
                    r"(body|condition)=%?([\w.\-]+)", op.attrs))
                body, cond = refs.get("body"), refs.get("condition")
                # XLA annotates scans with a known trip count; fall back to
                # the max s32 constant in the condition computation.
                m = re.search(r'"known_trip_count":\s*\{"n":"?(\d+)',
                              op.attrs)
                if m:
                    trip = int(m.group(1))
                elif cond in comps:
                    trip = _trip_count(comps[cond])
                else:
                    trip = 1
                if body:
                    c.add(cost_of(body, stack + (name,)), trip)
            else:
                for ref in _REF_ATTRS.findall(op.attrs):
                    if ref in comps and ref != name:
                        c.add(cost_of(ref, stack + (name,)))
                bm = _BRANCHES.search(op.attrs)
                if bm:
                    for b in bm.group(1).split(","):
                        b = b.strip().lstrip("%")
                        if b in comps:
                            c.add(cost_of(b, stack + (name,)))
        memo[name] = c
        return c

    if entry is None:
        return Cost()
    return cost_of(entry)
