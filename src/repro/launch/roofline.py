"""Roofline aggregation: merge dry-run reports + analytic MODEL_FLOPS into
the §Roofline table (markdown -> reports/roofline.md).

Terms (TPU v5e: 197 TF/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI):
  compute_s    = HLO_executed_FLOPs / (chips · peak)
  memory_s     = HLO bytes-accessed / (chips · HBM_bw)   [see caveat]
  collective_s = per-device collective payload bytes / link_bw

Caveats recorded in EXPERIMENTS.md:
  * executed FLOPs = dot FLOPs × loop trip counts (parser validated exact);
    elementwise FLOPs excluded (dot-dominated cells; SSH query is the
    exception — its DTW is min/add, covered by MODEL_FLOPS).
  * bytes-accessed comes from XLA's CPU-backend cost analysis (per device,
    loop bodies once) × a trip-count correction is NOT applied — treat the
    memory term as a lower bound; the dominant-term call is made on the
    corrected compute/collective terms vs this bound.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import Dict, List

from repro.configs import get_arch, list_archs
from repro.launch.hlo_analysis import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"
OUT = Path(__file__).resolve().parents[3] / "reports" / "roofline.md"


def load_reports(report_dir: Path = REPORT_DIR) -> List[Dict]:
    out = []
    for p in sorted(report_dir.glob("*.json")):
        out.append(json.loads(p.read_text()))
    return out


def enrich(rep: Dict) -> Dict:
    from repro.launch.analytic import model_flops
    arch = get_arch(rep["arch"])
    mf = model_flops(arch, rep["shape"])
    n = rep["n_chips"]
    compute_s = rep["flops"] / (n * PEAK_FLOPS_BF16)
    memory_s = (rep["bytes_accessed_per_device"]) / HBM_BW
    collective_s = rep["collective_bytes"] / ICI_BW
    dominant = max(("compute", compute_s), ("memory", memory_s),
                   ("collective", collective_s), key=lambda kv: kv[1])
    step_s = max(compute_s, memory_s, collective_s)
    rep.update({
        "model_flops": mf,
        "useful_ratio": mf / rep["flops"] if rep["flops"] else float("nan"),
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant[0],
        # fraction of peak FLOP/s the step would sustain if it ran at the
        # max(term) time — the roofline score
        "roofline_frac": (mf / (n * PEAK_FLOPS_BF16)) / step_s
        if step_s > 0 else 0.0,
    })
    return rep


def fmt_s(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def table(reports: List[Dict], mesh: str = "single") -> str:
    rows = [r for r in reports if r["mesh"] == mesh]
    rows.sort(key=lambda r: (r["arch"], r["shape"]))
    lines = [
        f"### Roofline — {mesh}-pod mesh "
        f"({'512' if mesh == 'multi' else '256'} chips, v5e)",
        "",
        "| arch | shape | kind | HLO FLOPs | MODEL FLOPs | useful | "
        "compute | memory* | collective | dominant | roofline frac |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['kind']} "
            f"| {r['flops']:.2e} | {r['model_flops']:.2e} "
            f"| {min(r['useful_ratio'], 9.99):.2f} "
            f"| {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | {r['dominant']} "
            f"| {r['roofline_frac']:.3f} |")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--report-dir", default=str(REPORT_DIR))
    ap.add_argument("--out", default=str(OUT))
    args = ap.parse_args()
    reports = [enrich(r) for r in load_reports(Path(args.report_dir))]
    md = [table(reports, "single"), "", table(reports, "multi")]
    Path(args.out).write_text("\n".join(md) + "\n")
    print("\n".join(md))
    print(f"\nwrote {args.out} ({len(reports)} cells)")


if __name__ == "__main__":
    main()
