"""Launchers: build, serve, train, dry-run."""
