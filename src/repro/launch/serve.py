"""Serving launcher — SSH query serving (paper Alg. 2) or LM decode.

SSH arches serve through the ``repro.db`` facade: one ``TimeSeriesDB``
whose ``SearchConfig`` (read from the arch registry — no hand-plumbed
knob tuples) routes to the dynamic-batching engine by default, the
sequential re-rank with ``--sequential``, or a saved database with
``--db-dir`` (skipping the O(N) rebuild the paper's retraining-free
hashing makes avoidable).

    PYTHONPATH=src python -m repro.launch.serve --arch ssh-ecg --requests 32
    PYTHONPATH=src python -m repro.launch.serve --arch ssh-ecg --sequential
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch import steps as steps_mod

SERVE_LENGTH = 128


def _ssh_db(arch, config, db_dir=None):
    """(queries pool, TimeSeriesDB) — loaded from ``db_dir`` when it holds
    a saved database, else built from the synthetic smoke stream.

    A loaded database keeps its *saved* search knobs (topk/top_c/band
    were chosen for its series length); only the serving-policy fields
    of ``config`` (searcher, backend, batcher) are overlaid.  The query
    pool is generated at the database's length either way.
    """
    from repro.data.timeseries import extract_subsequences, synthetic_ecg
    from repro.db import TimeSeriesDB, is_database_dir
    if db_dir:
        if not is_database_dir(db_dir):
            raise FileNotFoundError(
                f"--db-dir {db_dir}: no saved TimeSeriesDB there "
                "(build one with repro.launch.build_index)")
        tsdb = TimeSeriesDB.load(db_dir)
        overlay = dict(
            searcher=config.searcher, backend=config.backend,
            batch_policy=config.batch_policy,
            replication=config.replication,
            fleet_workers=config.fleet_workers,
            hedge_policy=config.hedge_policy, hedge_ms=config.hedge_ms)
        if config.replication > 1:
            overlay["multiprobe_offsets"] = 1    # fleet is single-probe
        tsdb = tsdb.with_config(tsdb.config.replace(**overlay))
        length = tsdb.length
        print(f"loaded database ({len(tsdb)} series of length {length}) "
              f"from {db_dir}")
    else:
        length = SERVE_LENGTH
        tsdb = None
    stream = synthetic_ecg(8000, seed=5)
    series = jnp.asarray(extract_subsequences(stream, length,
                                              stride=1, znorm=True))
    if tsdb is None:
        tsdb = TimeSeriesDB.build(series, spec=arch.index_spec(smoke=True),
                                  config=config)
    return series, tsdb


def serve_ssh(arch, requests: int, batch_size: int, wait_ms: float,
              backend: str = "auto", db_dir=None, replication: int = 1,
              fleet_workers=None, hedge_ms: float = 30.0,
              batch_mode: str = "fixed"):
    """Engine-based serving: dynamic batching + batched probe/re-rank.

    ``batch_mode="adaptive"`` lets the batcher set its own wait from the
    queue depth and the service-time EWMA (DESIGN.md §12); answers are
    bit-identical to fixed batching either way.  ``replication >= 2``
    serves through the resilient fleet tier (replicated shards, hedged
    fan-out, failover — DESIGN.md §11) behind the same engine."""
    from repro.db import BatchPolicy
    policy = BatchPolicy(mode=batch_mode, max_batch=batch_size,
                         max_wait_ms=wait_ms)
    cfg = arch.search_config(length=SERVE_LENGTH, searcher="engine",
                             backend=backend, batch_policy=policy,
                             replication=replication,
                             fleet_workers=fleet_workers,
                             hedge_ms=hedge_ms)
    if replication > 1 and cfg.multiprobe_offsets > 1:
        # the fleet shard probe matches the shard_map fan-out, which is
        # single-probe; drop the arch's multiprobe rather than refuse
        print(f"replication={replication}: fleet serving is single-probe "
              f"(overriding arch multiprobe_offsets="
              f"{cfg.multiprobe_offsets})")
        cfg = cfg.replace(multiprobe_offsets=1)
    db, tsdb = _ssh_db(arch, cfg, db_dir)
    engine = tsdb.engine
    rng = np.random.default_rng(0)
    qids = rng.integers(0, db.shape[0], requests)

    # warm every padded bucket size outside the measured window (through
    # the engine's searcher directly so metrics only cover real requests)
    # — the dynamic batcher may form any bucket depending on arrival timing
    for size in cfg.buckets():
        engine.searcher.search_batch(db[jnp.asarray(np.resize(qids, size))])

    t0 = time.perf_counter()
    with tsdb:
        futs = [(int(i), tsdb.submit(db[int(i)])) for i in qids]
        for i, fut in futs:
            res = fut.result()
            print(f"req {i}: top1={res.ids[0]} pruned="
                  f"{res.pruned_total_frac:.1%}")
        wall = time.perf_counter() - t0
        snap = engine.metrics.snapshot()
    print(f"engine: {engine.metrics.format()}")
    if replication > 1:
        print(f"fleet: hedged={snap['hedged_total']:.0f} "
              f"failovers={snap['failovers_total']:.0f} "
              f"degraded={snap['degraded_total']:.0f} "
              f"rebalanced={snap['rebalanced_shards_total']:.0f}")
    print(f"served {requests} requests in {wall:.2f}s "
          f"({requests / wall:.1f} qps end-to-end, "
          f"avg batch {snap['batch_size_mean']:.1f})")


def serve_ssh_sequential(arch, requests: int, backend: str = "auto",
                         db_dir=None):
    """Pre-engine baseline: the sequential ``local`` searcher."""
    cfg = arch.search_config(length=SERVE_LENGTH, searcher="local",
                             backend=backend)
    db, tsdb = _ssh_db(arch, cfg, db_dir)
    rng = np.random.default_rng(0)
    lat = []
    for i in rng.integers(0, db.shape[0], requests):
        t0 = time.perf_counter()
        res = tsdb.search(db[int(i)])
        lat.append(time.perf_counter() - t0)
        print(f"req {i}: top1={res.ids[0]} pruned="
              f"{res.pruned_total_frac:.1%} {lat[-1]*1e3:.0f}ms")
    lat = sorted(lat)
    print(f"p50={lat[len(lat)//2]*1e3:.0f}ms "
          f"p99={lat[-1]*1e3:.0f}ms over {requests} requests "
          f"({requests / sum(lat):.1f} qps)")


def serve_lm(arch, requests: int, smoke: bool):
    from repro.models.transformer import decode_step, init_cache, prefill
    cfg = arch.smoke_config if smoke else arch.config
    params = steps_mod.init_fn(arch, "decode_32k", smoke=smoke)()
    b, prompt_len, gen_len = 2, 16, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)),
                       jnp.int32)
    cache = init_cache(cfg, b, prompt_len + gen_len)
    decode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    # prefill by stepping (simple serving loop; batched prefill also works)
    t0 = time.perf_counter()
    for i in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, i:i + 1])
    out = []
    for _ in range(gen_len):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, cache = decode(params, cache, nxt)
    gen = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * (prompt_len + gen_len) / dt:.1f} tok/s); "
          f"sample: {np.asarray(gen[0])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="dynamic batcher max batch (ssh only)")
    ap.add_argument("--wait-ms", type=float, default=2.0,
                    help="dynamic batcher max wait (ssh only)")
    ap.add_argument("--batch-mode", default="fixed",
                    choices=("fixed", "adaptive"),
                    help="batcher policy: fixed two-knob deadline or "
                         "queue-depth/EWMA adaptive wait (ssh only)")
    ap.add_argument("--sequential", action="store_true",
                    help="bypass the engine; one ssh_search per request")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "jnp"),
                    help="kernel backend for the ssh query path "
                         "(collision count + DTW re-rank)")
    ap.add_argument("--db-dir", default=None,
                    help="serve a TimeSeriesDB saved here instead of "
                         "rebuilding the index (ssh only)")
    ap.add_argument("--replication", type=int, default=1,
                    help="replicas per shard; >= 2 serves through the "
                         "resilient fleet tier (ssh engine only)")
    ap.add_argument("--fleet-workers", type=int, default=None,
                    help="fleet size (default max(2, replication))")
    ap.add_argument("--hedge-ms", type=float, default=30.0,
                    help="hedging deadline floor in ms (fleet only)")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if arch.family == "ssh":
        if args.sequential:
            serve_ssh_sequential(arch, args.requests, backend=args.backend,
                                 db_dir=args.db_dir)
        else:
            serve_ssh(arch, args.requests, args.batch_size, args.wait_ms,
                      backend=args.backend, db_dir=args.db_dir,
                      replication=args.replication,
                      fleet_workers=args.fleet_workers,
                      hedge_ms=args.hedge_ms, batch_mode=args.batch_mode)
    elif arch.family == "lm":
        serve_lm(arch, args.requests, args.smoke)
    else:
        raise SystemExit(f"serving loop not defined for {arch.family}")


if __name__ == "__main__":
    main()
