"""Serving launcher — SSH query serving (paper Alg. 2) or LM decode.

    PYTHONPATH=src python -m repro.launch.serve --arch ssh-ecg --requests 8
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch import steps as steps_mod


def serve_ssh(arch, requests: int):
    from repro.core import SSHParams, SSHIndex, ssh_search
    from repro.data.timeseries import extract_subsequences, synthetic_ecg
    params = arch.smoke_config
    stream = synthetic_ecg(8000, seed=5)
    db = jnp.asarray(extract_subsequences(stream, 128, stride=1,
                                          znorm=True))
    index = SSHIndex.build(db, params)
    rng = np.random.default_rng(0)
    lat = []
    for i in rng.integers(0, db.shape[0], requests):
        t0 = time.time()
        res = ssh_search(db[int(i)], index, topk=10, top_c=256, band=6,
                         multiprobe_offsets=params.step)
        lat.append(time.time() - t0)
        print(f"req {i}: top1={res.ids[0]} pruned="
              f"{res.pruned_total_frac:.1%} {lat[-1]*1e3:.0f}ms")
    lat = sorted(lat)
    print(f"p50={lat[len(lat)//2]*1e3:.0f}ms "
          f"p99={lat[-1]*1e3:.0f}ms over {requests} requests")


def serve_lm(arch, requests: int, smoke: bool):
    from repro.models.transformer import decode_step, init_cache, prefill
    cfg = arch.smoke_config if smoke else arch.config
    params = steps_mod.init_fn(arch, "decode_32k", smoke=smoke)()
    b, prompt_len, gen_len = 2, 16, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)),
                       jnp.int32)
    cache = init_cache(cfg, b, prompt_len + gen_len)
    decode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    # prefill by stepping (simple serving loop; batched prefill also works)
    t0 = time.time()
    for i in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, i:i + 1])
    out = []
    for _ in range(gen_len):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, cache = decode(params, cache, nxt)
    gen = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * (prompt_len + gen_len) / dt:.1f} tok/s); "
          f"sample: {np.asarray(gen[0])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if arch.family == "ssh":
        serve_ssh(arch, args.requests)
    elif arch.family == "lm":
        serve_lm(arch, args.requests, args.smoke)
    else:
        raise SystemExit(f"serving loop not defined for {arch.family}")


if __name__ == "__main__":
    main()
