"""Serving launcher — SSH query serving (paper Alg. 2) or LM decode.

SSH arches run on the batched serving engine (``repro.serving``):
requests stream through the dynamic batcher, which pads to bucketed batch
sizes and serves each block via the fused batched probe + union DTW
re-rank.  ``--sequential`` keeps the old one-query-at-a-time loop for
comparison.

    PYTHONPATH=src python -m repro.launch.serve --arch ssh-ecg --requests 32
    PYTHONPATH=src python -m repro.launch.serve --arch ssh-ecg --sequential
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b --smoke
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.launch import steps as steps_mod


def _ssh_fixture(arch):
    from repro.core import SSHIndex
    from repro.data.timeseries import extract_subsequences, synthetic_ecg
    params = arch.smoke_config
    stream = synthetic_ecg(8000, seed=5)
    db = jnp.asarray(extract_subsequences(stream, 128, stride=1,
                                          znorm=True))
    return db, SSHIndex.build(db, params), params


def serve_ssh(arch, requests: int, batch_size: int, wait_ms: float,
              backend: str = "auto"):
    """Engine-based serving: dynamic batching + batched probe/re-rank."""
    from repro.serving import EngineConfig, ServingEngine
    db, index, params = _ssh_fixture(arch)
    cfg = EngineConfig(topk=10, top_c=256, band=6,
                       multiprobe_offsets=params.step,
                       backend=backend,
                       max_batch=batch_size, max_wait_ms=wait_ms)
    engine = ServingEngine(index, cfg)
    rng = np.random.default_rng(0)
    qids = rng.integers(0, db.shape[0], requests)

    # warm every padded bucket size outside the measured window (through
    # the searcher directly so engine metrics only cover real requests) —
    # the dynamic batcher may form any bucket depending on arrival timing
    for size in cfg.buckets():
        engine.searcher.search_batch(db[jnp.asarray(np.resize(qids, size))])

    t0 = time.perf_counter()
    with engine:
        futs = [(int(i), engine.submit(db[int(i)])) for i in qids]
        for i, fut in futs:
            res = fut.result()
            print(f"req {i}: top1={res.ids[0]} pruned="
                  f"{res.pruned_total_frac:.1%}")
    wall = time.perf_counter() - t0
    snap = engine.metrics.snapshot()
    print(f"engine: {engine.metrics.format()}")
    print(f"served {requests} requests in {wall:.2f}s "
          f"({requests / wall:.1f} qps end-to-end, "
          f"avg batch {snap['batch_size_mean']:.1f})")


def serve_ssh_sequential(arch, requests: int, backend: str = "auto"):
    """Pre-engine baseline: one ssh_search per request."""
    from repro.core import ssh_search
    db, index, params = _ssh_fixture(arch)
    rng = np.random.default_rng(0)
    lat = []
    for i in rng.integers(0, db.shape[0], requests):
        t0 = time.perf_counter()
        res = ssh_search(db[int(i)], index, topk=10, top_c=256, band=6,
                         multiprobe_offsets=params.step, backend=backend)
        lat.append(time.perf_counter() - t0)
        print(f"req {i}: top1={res.ids[0]} pruned="
              f"{res.pruned_total_frac:.1%} {lat[-1]*1e3:.0f}ms")
    lat = sorted(lat)
    print(f"p50={lat[len(lat)//2]*1e3:.0f}ms "
          f"p99={lat[-1]*1e3:.0f}ms over {requests} requests "
          f"({requests / sum(lat):.1f} qps)")


def serve_lm(arch, requests: int, smoke: bool):
    from repro.models.transformer import decode_step, init_cache, prefill
    cfg = arch.smoke_config if smoke else arch.config
    params = steps_mod.init_fn(arch, "decode_32k", smoke=smoke)()
    b, prompt_len, gen_len = 2, 16, 8
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (b, prompt_len)),
                       jnp.int32)
    cache = init_cache(cfg, b, prompt_len + gen_len)
    decode = jax.jit(lambda p, c, t: decode_step(p, c, t, cfg))
    # prefill by stepping (simple serving loop; batched prefill also works)
    t0 = time.perf_counter()
    for i in range(prompt_len):
        logits, cache = decode(params, cache, toks[:, i:i + 1])
    out = []
    for _ in range(gen_len):
        nxt = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        out.append(nxt)
        logits, cache = decode(params, cache, nxt)
    gen = jnp.concatenate(out, axis=1)
    dt = time.perf_counter() - t0
    print(f"generated {gen.shape} tokens in {dt:.2f}s "
          f"({b * (prompt_len + gen_len) / dt:.1f} tok/s); "
          f"sample: {np.asarray(gen[0])}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--batch-size", type=int, default=8,
                    help="dynamic batcher max batch (ssh only)")
    ap.add_argument("--wait-ms", type=float, default=2.0,
                    help="dynamic batcher max wait (ssh only)")
    ap.add_argument("--sequential", action="store_true",
                    help="bypass the engine; one ssh_search per request")
    ap.add_argument("--backend", default="auto",
                    choices=("auto", "pallas", "jnp"),
                    help="kernel backend for the ssh query path "
                         "(collision count + DTW re-rank)")
    ap.add_argument("--smoke", action="store_true", default=True)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    if arch.family == "ssh":
        if args.sequential:
            serve_ssh_sequential(arch, args.requests, backend=args.backend)
        else:
            serve_ssh(arch, args.requests, args.batch_size, args.wait_ms,
                      backend=args.backend)
    elif arch.family == "lm":
        serve_lm(arch, args.requests, args.smoke)
    else:
        raise SystemExit(f"serving loop not defined for {arch.family}")


if __name__ == "__main__":
    main()
