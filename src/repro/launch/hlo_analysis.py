"""Post-SPMD HLO analysis: collective bytes, op census, roofline terms.

``cost_analysis()`` has FLOPs and memory traffic but NOT collective bytes
— we parse the partitioned HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
Parsing is line-streamed (compiled dbrx HLO runs to ~100 MB).
"""
from __future__ import annotations

import re
from typing import Dict, Iterable

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[16,512,128]{2,1,0:T(8,128)}"  or  "f32[] "
_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^=]*\)|[a-z0-9]+\[[0-9,]*\]"
    r"(?:\{[^}]*\})?)\s+([a-z0-9\-]+)")


def shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _result_bytes(line: str) -> int:
    """Bytes of the op RESULT (the '=' left side shapes)."""
    lhs = line.split("=", 1)[0] + "=" + line.split("=", 1)[1]
    # take shapes appearing before the op name's '(' — i.e. result shapes
    head = line.split("(", 1)[0]
    return sum(shape_bytes(d, s) for d, s in _SHAPE_RE.findall(head))


def collective_stats(hlo_text_lines: Iterable[str]) -> Dict[str, Dict]:
    """Per-collective-kind {count, bytes} from partitioned HLO lines.

    Bytes = result-shape bytes (the payload each device receives) — the
    standard convention for link-bandwidth roofline accounting.
    """
    out: Dict[str, Dict] = {k: {"count": 0, "bytes": 0}
                            for k in _COLLECTIVES}
    for line in hlo_text_lines:
        s = line.lstrip()
        if "=" not in s:
            continue
        m = _OP_RE.match(s)
        if not m:
            continue
        op = m.group(1)
        for kind in _COLLECTIVES:
            if op == kind or op.startswith(kind + "-"):
                b = _result_bytes(s)
                out[kind]["count"] += 1
                out[kind]["bytes"] += b
                break
    return out


def op_census(hlo_text_lines: Iterable[str], ops=("fusion", "custom-call",
                                                  "while", "dot",
                                                  "convolution")) -> Dict:
    counts: Dict[str, int] = {}
    for line in hlo_text_lines:
        s = line.lstrip()
        m = _OP_RE.match(s)
        if m:
            op = m.group(1)
            if op in ops:
                counts[op] = counts.get(op, 0) + 1
    return counts


# --------------------------------------------------------------------------
# roofline terms (TPU v5e constants, per chip)
# --------------------------------------------------------------------------

PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link (≈ aggregate per chip per dir)


def roofline_terms(total_flops: float, total_bytes: float,
                   collective_bytes: float, n_chips: int) -> Dict[str, float]:
    """The three roofline times (seconds) for one step, whole mesh.

    FLOPs/bytes from cost_analysis are whole-program (all devices); the
    collective bytes from the partitioned HLO are per-device payloads.
    """
    compute_t = total_flops / (n_chips * PEAK_FLOPS_BF16)
    memory_t = total_bytes / (n_chips * HBM_BW)
    collective_t = collective_bytes / ICI_BW
    dominant = max(("compute", compute_t), ("memory", memory_t),
                   ("collective", collective_t), key=lambda kv: kv[1])[0]
    return {"compute_s": compute_t, "memory_s": memory_t,
            "collective_s": collective_t, "dominant": dominant}
