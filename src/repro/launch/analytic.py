"""Analytic MODEL_FLOPS per cell — the 'useful compute' numerator.

MODEL_FLOPS counts only the mathematically necessary work of one step:
  * LM train: 3 × (2·N_active·D + causal attention)  — 6·N·D convention
    (N_active = activated params: attn + top-k experts + shared + head)
  * LM prefill: the forward third of the above
  * LM decode: 2·N_active·B + 4·B·H·hd·T attention reads of the cache
  * GNN: tensor-product + radial + self-interaction einsum MACs × 3 (train)
  * recsys: MLP/interaction MACs × 3 (train) or × 1 (serve); embedding
    gathers are bandwidth, not FLOPs
  * SSH build: sketch matmuls; SSH query: collision compare + banded-DTW
    cell updates (3 flops/cell — min+min+add; not dot-shaped, so the
    executed-HLO dot census intentionally misses them)

The ratio MODEL_FLOPS / HLO_executed_FLOPs in §Roofline surfaces remat,
full-vs-causal attention, and MoE dispatch overhead.
"""
from __future__ import annotations

from typing import Dict

from repro.configs.base import ArchDef


def _lm_active_params(cfg) -> Dict[str, float]:
    d, hd, hq = cfg.d_model, cfg.hd, cfg.n_heads
    if cfg.mla:
        attn = (d * hq * (cfg.qk_nope_dim + cfg.qk_rope_dim)     # wq
                + d * (cfg.kv_lora_rank + cfg.qk_rope_dim)       # w_dkv
                + cfg.kv_lora_rank * hq * cfg.qk_nope_dim        # w_uk
                + cfg.kv_lora_rank * hq * cfg.v_head_dim         # w_uv
                + hq * cfg.v_head_dim * d)                       # wo
    else:
        attn = d * hq * hd + 2 * d * cfg.n_kv_heads * hd + hq * hd * d
    if cfg.moe:
        ffn_active = 3 * d * cfg.moe_d_ff * cfg.top_k
        ffn_active += 3 * d * cfg.moe_d_ff * cfg.n_shared
        ffn_active += d * cfg.n_experts            # router
    else:
        ffn_active = 3 * d * cfg.d_ff
    per_layer = attn + ffn_active
    head = d * cfg.vocab
    return {"per_layer": per_layer, "head": head,
            "total": per_layer * cfg.n_layers + head}


def lm_model_flops(cfg, kind: str, batch: int, seq: int) -> float:
    p = _lm_active_params(cfg)
    if kind == "decode":
        tokens = batch
        matmul = 2.0 * p["total"] * tokens
        dv = cfg.v_head_dim if cfg.mla else cfg.hd
        attn = (2.0 * batch * cfg.n_heads * (cfg.hd + dv) * seq
                * cfg.n_layers)
        return matmul + attn
    tokens = batch * seq
    matmul_fwd = 2.0 * p["total"] * tokens
    hd_qk = (cfg.qk_nope_dim + cfg.qk_rope_dim) if cfg.mla else cfg.hd
    dv = cfg.v_head_dim if cfg.mla else cfg.hd
    attn_fwd = (2.0 * batch * seq * seq * cfg.n_heads * (hd_qk + dv)
                * 0.5 * cfg.n_layers)              # causal half
    fwd = matmul_fwd + attn_fwd
    return fwd if kind == "prefill" else 3.0 * fwd


def gnn_model_flops(cfg, meta: Dict) -> float:
    e = meta["n_edges"]
    n = meta["n_nodes"]
    c = cfg.channels
    tp = 0.0
    for (l1, l2, l3) in cfg.paths:
        tp += e * c * (2 * l1 + 1) * (2 * l2 + 1) * (2 * l3 + 1)
    radial = e * (cfg.n_rbf * cfg.radial_hidden
                  + cfg.radial_hidden * len(cfg.paths) * c)
    self_mix = sum(n * c * c * (2 * l + 1) for l in range(cfg.l_max + 1))
    per_layer = 2.0 * (tp + radial + self_mix)
    embed = 2.0 * n * cfg.d_feat * c
    readout = 2.0 * n * (c * cfg.readout_hidden + cfg.readout_hidden)
    fwd = per_layer * cfg.n_layers + embed + readout
    return 3.0 * fwd                                  # train cells


def _mlp_macs(dims, batch):
    return sum(dims[i] * dims[i + 1] for i in range(len(dims) - 1)) * batch


def recsys_model_flops(cfg, kind: str, meta: Dict) -> float:
    b = meta.get("batch", 1)
    name = cfg.name.split("-")[0]
    if kind == "retrieval":
        nc = meta["n_candidates"]
        dim = getattr(cfg, "embed_dim", 64)
        k = getattr(cfg, "n_interests", 1)
        return 2.0 * nc * dim * k
    if name == "dlrm":
        macs = _mlp_macs(cfg.bot_mlp, b)
        f = cfg.n_sparse + 1
        macs += b * f * f * cfg.embed_dim                 # dot interaction
        n_pairs = (f) * (f - 1) // 2
        macs += _mlp_macs((cfg.embed_dim + n_pairs,) + cfg.top_mlp[1:], b)
    elif name == "bst":
        d, s = cfg.embed_dim, cfg.seq_len + 1
        macs = b * (4 * s * d * d + 2 * s * s * d + 2 * s * d * cfg.d_ff)
        macs += _mlp_macs((s * d + cfg.n_profile * d,) + cfg.mlp + (1,), b)
    elif name == "mind":
        d, s, k = cfg.embed_dim, cfg.seq_len, cfg.n_interests
        macs = b * (s * d * d + cfg.capsule_iters * 2 * k * s * d)
        macs += _mlp_macs((d,) + cfg.mlp + (d,), b * k) + b * k * d
    else:  # dien
        d, g, s = cfg.embed_dim, cfg.gru_dim, cfg.seq_len
        macs = b * s * 3 * ((d + g) * g)                  # GRU1
        macs += b * s * 3 * ((g + g) * g)                 # AUGRU
        macs += b * s * (g + d)                           # attention
        macs += _mlp_macs((g + 2 * d,) + cfg.mlp + (1,), b)
    flops = 2.0 * macs
    return 3.0 * flops if kind == "train" else flops


def ssh_model_flops(cfg, kind: str, meta: Dict) -> float:
    if kind == "build":
        b, m = meta["batch"], meta["length"]
        n_b = (m - cfg.window) // cfg.step + 1
        return 2.0 * b * n_b * cfg.window * cfg.num_filters
    # query: signature + collision scan + banded DTW re-rank
    m = meta["length"]
    n = meta["n_database"]
    n_b = (m - cfg.window) // cfg.step + 1
    sig = 2.0 * n_b * cfg.window
    coll = 2.0 * n * cfg.num_hashes
    band = 2 * meta["band"] + 1
    dtw = 3.0 * meta["top_c"] * m * band
    return sig + coll + dtw


def model_flops(arch: ArchDef, shape: str) -> float:
    cell = arch.shapes[shape]
    cfg = arch.cell_config(shape)
    if arch.family == "lm":
        return lm_model_flops(cfg, cell.kind, cell.meta["batch"],
                              cell.meta["seq"])
    if arch.family == "gnn":
        return gnn_model_flops(cfg, cell.meta)
    if arch.family == "recsys":
        return recsys_model_flops(cfg, cell.kind, cell.meta)
    if arch.family == "ssh":
        return ssh_model_flops(cfg, cell.kind, cell.meta)
    raise ValueError(arch.family)
