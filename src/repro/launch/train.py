"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b \
        --shape train_4k --steps 100 --smoke          # CPU-runnable
    ... --mesh single                                  # 256-chip pjit run

Features: pjit + logical-axis shardings, AdamW with fp32 master, remat,
checkpoint/restart (atomic, resharding restore), deterministic seeded data
with step-offset resume, straggler-aware shard assignment (see
repro.distributed.fault_tolerance), optional gradient compression in the
shard_map DDP path.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.launch import steps as steps_mod


def synthetic_batch(arch, shape, smoke: bool, step_idx: int):
    """Deterministic per-step batch (seed = step) — restartable pipeline."""
    rng = np.random.default_rng(step_idx)
    kind, spec = arch.input_specs(shape)
    cfg = arch.smoke_config if smoke else arch.cell_config(shape)

    def reduced(s, dtype):
        shp = tuple(min(d, 64) if i == 0 else min(d, 128)
                    for i, d in enumerate(s)) if smoke else s
        if np.issubdtype(dtype, np.integer):
            hi = getattr(cfg, "vocab", 100)
            return jnp.asarray(rng.integers(0, hi, shp), dtype)
        return jnp.asarray(rng.normal(size=shp), dtype)

    return jax.tree.map(lambda s: reduced(s.shape, s.dtype), spec)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=1)
    args = ap.parse_args()

    arch = get_arch(args.arch)
    shape = args.shape or next(s for s, c in arch.shapes.items()
                               if c.kind == "train")
    params = steps_mod.init_fn(arch, shape, smoke=args.smoke)()
    opt = steps_mod.make_optimizer(arch.family)
    opt_state = opt.init(params)
    # no donation here: freshly-initialised zero biases may share one
    # deduplicated constant buffer, and donating an aliased buffer twice
    # is an XLA error.  (The dry-run still donates — it never executes.)
    train_step = jax.jit(steps_mod.make_step(arch, shape, "train",
                                             smoke=args.smoke))

    ck = None
    start = 0
    if args.ckpt_dir:
        ck = Checkpointer(args.ckpt_dir, keep=3, async_save=True)
        latest, restored = ck.restore_latest(
            {"params": params, "opt": opt_state})
        if latest is not None:
            params, opt_state = restored["params"], restored["opt"]
            start = latest
            print(f"resumed from checkpoint step {start}")

    for i in range(start, args.steps):
        batch = synthetic_batch(arch, shape, args.smoke, i)
        t0 = time.time()
        params, opt_state, metrics = train_step(params, opt_state, batch)
        loss = float(metrics["loss"])
        if i % args.log_every == 0:
            print(f"step {i}: loss={loss:.4f} "
                  f"({time.time() - t0:.2f}s)", flush=True)
        if ck and (i + 1) % args.ckpt_every == 0:
            ck.save(i + 1, {"params": params, "opt": opt_state})
    if ck:
        ck.save(args.steps, {"params": params, "opt": opt_state})
        ck.wait()
    print("training done")


if __name__ == "__main__":
    main()
