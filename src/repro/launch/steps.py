"""Step-function builders — one lowering target per (family × kind).

``abstract_state`` builds the full argument pytree as ShapeDtypeStructs
(params via jax.eval_shape — zero allocation), and ``make_step`` returns
the jit-able callable.  Used by the dry-run, the trainers and the tests,
so there is exactly one definition of every step in the codebase.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchDef
from repro.train.optimizer import AdamW, AdamWState

PyTree = Any


def make_optimizer(family: str) -> AdamW:
    if family == "lm":
        return AdamW(lr=3e-4, weight_decay=0.1)
    return AdamW(lr=1e-3, weight_decay=1e-4)


# --------------------------------------------------------------------------
# init / abstract state
# --------------------------------------------------------------------------

def init_fn(arch: ArchDef, shape: str, smoke: bool = False) -> Callable:
    """Returns a () -> params initialiser for the cell's config."""
    cfg = (arch.smoke_config if smoke else arch.cell_config(shape))
    if arch.family == "lm":
        from repro.models.transformer import init_params
        return lambda key=jax.random.PRNGKey(0): init_params(cfg, key)
    if arch.family == "gnn":
        from repro.models.nequip import init_params
        return lambda key=jax.random.PRNGKey(0): init_params(cfg, key)
    if arch.family == "recsys":
        from repro.models import recsys as R
        init = {"dlrm": R.dlrm_init, "bst": R.bst_init, "mind": R.mind_init,
                "dien": R.dien_init}[cfg.name.split("-")[0]]
        return lambda key=jax.random.PRNGKey(0): init(cfg, key)
    if arch.family == "ssh":
        from repro.core.index import SSHFunctions
        def make():
            fns = SSHFunctions.create(cfg)
            return {"filters": fns.filters,
                    "cws": fns.cws._asdict()}
        return make
    raise ValueError(arch.family)


def abstract_params(arch: ArchDef, shape: str, smoke: bool = False):
    return jax.eval_shape(init_fn(arch, shape, smoke))


def abstract_opt_state(arch: ArchDef, params_spec) -> AdamWState:
    opt = make_optimizer(arch.family)
    return jax.eval_shape(opt.init, params_spec)


def abstract_state(arch: ArchDef, shape: str
                   ) -> Tuple[str, Tuple[PyTree, ...]]:
    """(kind, full argument tuple of ShapeDtypeStructs) for a cell."""
    kind, batch = arch.input_specs(shape)
    params = abstract_params(arch, shape)
    if kind == "train":
        opt = abstract_opt_state(arch, params)
        return kind, (params, opt, batch)
    if kind == "decode":
        return kind, (params, batch["cache"], batch["tokens"])
    if kind in ("prefill", "serve", "retrieval"):
        return kind, (params, batch)
    if kind == "build":
        return kind, (params, batch)
    if kind == "query":
        return kind, (params, batch)
    raise ValueError(kind)


# --------------------------------------------------------------------------
# step functions
# --------------------------------------------------------------------------

def _loss_for(arch: ArchDef, shape: str, smoke: bool) -> Callable:
    cfg = arch.smoke_config if smoke else arch.cell_config(shape)
    if arch.family == "lm":
        from repro.models.transformer import loss_fn
        return lambda p, b: loss_fn(p, b, cfg)
    if arch.family == "gnn":
        from repro.models.nequip import loss_fn
        meta = arch.shapes[shape].meta
        n_graphs = meta.get("n_graphs")
        return lambda p, b: loss_fn(p, b, cfg, n_graphs=n_graphs)
    if arch.family == "recsys":
        from repro.models import recsys as R
        fwd = _recsys_forward(cfg)
        def loss(p, b):
            feats = {k: v for k, v in b.items() if k != "labels"}
            logits = fwd(p, feats)
            l = R.bce_loss(logits, b["labels"])
            return l, {"bce": l}
        return loss
    raise ValueError(arch.family)


def _recsys_forward(cfg) -> Callable:
    from repro.models import recsys as R
    fwd = {"dlrm": R.dlrm_forward, "bst": R.bst_forward,
           "mind": R.mind_forward,
           "dien": R.dien_forward}[cfg.name.split("-")[0]]
    return functools.partial(fwd, cfg=cfg)


def _recsys_retrieval(cfg) -> Callable:
    from repro.models import recsys as R
    return functools.partial(
        {"dlrm": R.dlrm_retrieval, "bst": R.bst_retrieval,
         "mind": R.mind_retrieval,
         "dien": R.dien_retrieval}[cfg.name.split("-")[0]], cfg=cfg)


def make_step(arch: ArchDef, shape: str, kind: str, smoke: bool = False
              ) -> Callable:
    cfg = arch.smoke_config if smoke else arch.cell_config(shape)

    if kind == "train":
        loss = _loss_for(arch, shape, smoke)
        opt = make_optimizer(arch.family)

        def train_step(params, opt_state, batch):
            (l, metrics), grads = jax.value_and_grad(
                loss, has_aux=True)(params, batch)
            params, opt_state, opt_metrics = opt.update(
                params, opt_state, grads)
            metrics = dict(metrics, loss=l, **opt_metrics)
            return params, opt_state, metrics
        return train_step

    if arch.family == "lm":
        from repro.models import transformer as T
        if kind == "prefill":
            return lambda params, batch: T.prefill(params, batch["tokens"],
                                                   cfg)
        if kind == "decode":
            return lambda params, cache, tokens: T.decode_step(
                params, cache, tokens, cfg)

    if arch.family == "recsys":
        if kind == "serve":
            fwd = _recsys_forward(cfg)
            return lambda params, batch: fwd(params, batch)
        if kind == "retrieval":
            ret = _recsys_retrieval(cfg)
            return lambda params, batch: ret(params, batch)

    if arch.family == "ssh":
        if kind == "build":
            return _make_ssh_build(cfg)
        if kind == "query":
            meta = arch.shapes[shape].meta
            return _make_ssh_query(cfg, top_c=meta["top_c"],
                                   band=meta["band"], topk=10)

    raise ValueError(f"no step for {arch.family}/{kind}")


# --------------------------------------------------------------------------
# SSH distributed steps (the paper's technique as a serving workload)
# --------------------------------------------------------------------------

def _make_ssh_build(cfg):
    from repro.core import minhash, shingle, sketch

    def build_step(params, batch):
        """Hash a shard of the database: series (B, m) -> signatures (B, K).

        Fully batch-parallel (§Perf iteration: the original per-series
        lax.map lowered to an unshardable while loop — 255× replicated
        compute at 256 chips).
        """
        from repro.distributed.constraints import constrain
        cws = minhash.CWSParams(**params["cws"])
        bits = sketch.sketch_bits(batch["series"], params["filters"],
                                  cfg.step)                   # (B, N_B, F)
        counts = shingle.shingle_histogram_batch(bits, cfg.ngram)
        # keep the histogram row-sharded into the CWS scan — otherwise the
        # partitioner all-gathers the full (B, 2^n) matrix (8.6 GB/step)
        counts = constrain(counts, "batch_all", None)
        sigs = minhash.cws_hash_dense_batch(counts, cws)
        return constrain(sigs, "batch_all", None)
    return build_step


def _make_ssh_query(cfg, top_c: int, band: int, topk: int):
    from repro.core import minhash, shingle, sketch
    from repro.core.dtw import dtw_batch

    def query_step(params, batch):
        """Probe sharded signatures, gather candidates, banded-DTW re-rank."""
        cws = minhash.CWSParams(**params["cws"])
        q = batch["query"]
        bits = sketch.sketch_bits(q, params["filters"], cfg.step)
        counts = shingle.shingle_histogram(bits, cfg.ngram)
        sig = minhash.cws_hash(counts, cws)                   # (K,)

        collisions = jnp.sum(
            (batch["db_sigs"] == sig[None, :]).astype(jnp.int32), axis=-1)
        _, cand_ids = jax.lax.top_k(collisions,
                                    min(top_c, collisions.shape[0]))
        cands = jnp.take(batch["db_series"], cand_ids, axis=0)
        d = dtw_batch(q, cands, band=band)
        vals, idx = jax.lax.top_k(-d, topk)
        return jnp.take(cand_ids, idx), -vals
    return query_step
