"""SSH index-build launcher (the paper's preprocessing stage, Alg. 1).

    PYTHONPATH=src python -m repro.launch.build_index \
        --dataset ecg --points 50000 --length 256 --out /tmp/ssh_db

Sharded, checkpointed, restartable: the stream is hashed in fixed-size
batches; each batch checkpoint is atomic, so a crashed build resumes at
the last completed batch (node-failure tolerance for the 20M-series run).
The finished index is published as a ``repro.db`` database directory —
``TimeSeriesDB.load(out)`` (or ``serve.py --db-dir out``) then answers
queries without ever paying the O(N) signature build again, which is the
operational payoff of the paper's retraining-free hashing.

Hyper-parameters come from the arch registry (``ssh-ecg`` /
``ssh-randomwalk``), including the search-time defaults persisted next
to the index.
"""
from __future__ import annotations

import argparse
import shutil
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.core.index import SSHFunctions, SSHIndex, band_keys
from repro.data.timeseries import extract_subsequences, random_walk, \
    synthetic_ecg
from repro.db import TimeSeriesDB
from repro.launch.steps import _make_ssh_build

_GENERATORS = {"ecg": synthetic_ecg, "randomwalk": random_walk}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["ecg", "randomwalk"],
                    default="ecg")
    ap.add_argument("--points", type=int, default=50_000)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--out", type=str, default="/tmp/ssh_db")
    args = ap.parse_args()

    stream = _GENERATORS[args.dataset](args.points, seed=3)
    series = extract_subsequences(stream, args.length, stride=1, znorm=True)
    n = series.shape[0]

    arch = get_arch(f"ssh-{args.dataset}")
    params = arch.config
    fns = SSHFunctions.create(params)
    build = _make_ssh_build(params)
    p = {"filters": fns.filters, "cws": fns.cws._asdict()}

    # batch-checkpointed signature build (scratch space; the published
    # database below is what readers load)
    ck = Checkpointer(f"{args.out}.build_ckpt", keep=2)
    latest, restored = ck.restore_latest(
        {"sigs": jnp.zeros((n, params.num_hashes), jnp.int32),
         "done": jnp.zeros((), jnp.int32)})
    sigs = np.asarray(restored["sigs"]).copy()
    done = int(restored["done"]) if latest is not None else 0
    if done:
        print(f"resuming at series {done}/{n}")

    t0 = time.time()
    for lo in range(done, n, args.batch):
        hi = min(lo + args.batch, n)
        out = build(p, {"series": jnp.asarray(series[lo:hi])})
        sigs[lo:hi] = np.asarray(out)
        ck.save(hi, {"sigs": jnp.asarray(sigs),
                     "done": jnp.asarray(hi, jnp.int32)})
        rate = (hi - done) / max(time.time() - t0, 1e-9)
        print(f"hashed {hi}/{n} ({rate:.0f} series/s)", flush=True)

    config = arch.search_config(length=args.length)
    index = SSHIndex(fns=fns, signatures=jnp.asarray(sigs),
                     keys=band_keys(jnp.asarray(sigs), params),
                     series=jnp.asarray(series))
    if config.use_lb_cascade and config.band is not None:
        index.candidate_envelopes(config.band)   # persisted with the index
    db = TimeSeriesDB(index, config)
    db.save(args.out)
    # database published durably — the batch-restart scratch (a full
    # (N, K) signature copy per retained checkpoint) is now waste
    shutil.rmtree(f"{args.out}.build_ckpt", ignore_errors=True)
    print(f"index built: {n} series, {params.num_hashes} hashes, "
          f"{params.num_tables} tables in {time.time() - t0:.1f}s; "
          f"database saved to {args.out} "
          f"(TimeSeriesDB.load / serve.py --db-dir)")


if __name__ == "__main__":
    main()
