"""SSH index-build launcher (the paper's preprocessing stage, Alg. 1).

    PYTHONPATH=src python -m repro.launch.build_index \
        --dataset ecg --points 50000 --length 256 --out /tmp/ssh_db

Sharded, checkpointed, restartable: the stream is hashed in fixed-size
batches; each batch checkpoint is atomic, so a crashed build resumes at
the last completed batch (node-failure tolerance for the 20M-series run).
The finished index is published as a ``repro.db`` database directory —
``TimeSeriesDB.load(out)`` (or ``serve.py --db-dir out``) then answers
queries without ever paying the O(N) signature build again, which is the
operational payoff of the paper's retraining-free hashing.

The build is spec-driven: ``--encoder`` names any registered
``repro.encoders`` encoder (default: the arch registry's ``"ssh"`` spec,
hyper-parameters from ``ssh-ecg`` / ``ssh-randomwalk``), the persisted
``IndexSpec`` travels with the database, and ``--backend`` routes the
signature build through the Pallas ``sketch_conv`` kernel or the jnp
reference.
"""
from __future__ import annotations

import argparse
import shutil
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs import get_arch
from repro.core.index import SSHIndex
from repro.data.timeseries import extract_subsequences, random_walk, \
    synthetic_ecg
from repro.db import TimeSeriesDB
from repro.encoders import IndexSpec, make_encoder

_GENERATORS = {"ecg": synthetic_ecg, "randomwalk": random_walk}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["ecg", "randomwalk"],
                    default="ecg")
    ap.add_argument("--points", type=int, default=50_000)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--out", type=str, default="/tmp/ssh_db")
    ap.add_argument("--encoder", type=str, default=None,
                    help="registered encoder name (default: the arch's "
                         "'ssh' spec; 'srp'/'ssh-multires' use their "
                         "documented defaults)")
    ap.add_argument("--backend", choices=["auto", "pallas", "jnp"],
                    default="auto",
                    help="signature-build kernel backend (Pallas "
                         "sketch_conv vs jnp reference)")
    args = ap.parse_args()

    stream = _GENERATORS[args.dataset](args.points, seed=3)
    series = extract_subsequences(stream, args.length, stride=1, znorm=True)
    n = series.shape[0]

    arch = get_arch(f"ssh-{args.dataset}")
    if args.encoder in (None, "ssh"):
        spec = arch.index_spec()
    else:
        spec = IndexSpec(encoder=args.encoder)
    enc = make_encoder(spec, length=args.length)
    # pin the resolved backend (as SSHIndex.build does) so the persisted
    # database queries with the kernel it was built with on any host
    from repro.kernels import ops
    args.backend = ops.backend_name(ops.resolve_backend(args.backend))

    # batch-checkpointed signature build (scratch space; the published
    # database below is what readers load)
    ck = Checkpointer(f"{args.out}.build_ckpt", keep=2)
    latest, restored = ck.restore_latest(
        {"sigs": jnp.zeros((n, enc.num_hashes), jnp.int32),
         "done": jnp.zeros((), jnp.int32)})
    sigs = np.asarray(restored["sigs"]).copy()
    done = int(restored["done"]) if latest is not None else 0
    if done:
        print(f"resuming at series {done}/{n}")

    t0 = time.time()
    for lo in range(done, n, args.batch):
        hi = min(lo + args.batch, n)
        out = enc.encode_batch(jnp.asarray(series[lo:hi]),
                               backend=args.backend)
        sigs[lo:hi] = np.asarray(out)
        ck.save(hi, {"sigs": jnp.asarray(sigs),
                     "done": jnp.asarray(hi, jnp.int32)})
        rate = (hi - done) / max(time.time() - t0, 1e-9)
        print(f"hashed {hi}/{n} ({rate:.0f} series/s)", flush=True)

    # (TimeSeriesDB clamps knobs the encoder cannot honour, e.g.
    # multiprobe for "srp")
    config = arch.search_config(length=args.length)
    index = SSHIndex(fns=(enc.legacy_functions()
                          if hasattr(enc, "legacy_functions") else None),
                     signatures=jnp.asarray(sigs),
                     keys=enc.band_keys(jnp.asarray(sigs)),
                     series=jnp.asarray(series), encoder=enc,
                     build_backend=args.backend)
    if config.use_lb_cascade and config.band is not None:
        index.candidate_envelopes(config.band)   # persisted with the index
    db = TimeSeriesDB(index, config)
    db.save(args.out)
    # database published durably — the batch-restart scratch (a full
    # (N, K) signature copy per retained checkpoint) is now waste
    shutil.rmtree(f"{args.out}.build_ckpt", ignore_errors=True)
    print(f"index built: {n} series, encoder {spec.encoder!r}, "
          f"{enc.num_hashes} hashes, {enc.num_tables} tables in "
          f"{time.time() - t0:.1f}s; database saved to {args.out} "
          f"(TimeSeriesDB.load / serve.py --db-dir)")


if __name__ == "__main__":
    main()
