"""SSH index-build launcher (the paper's preprocessing stage, Alg. 1).

    PYTHONPATH=src python -m repro.launch.build_index \
        --dataset ecg --points 50000 --length 256 --out /tmp/ssh_index

Sharded, checkpointed, restartable: the stream is hashed in fixed-size
batches; each batch checkpoint is atomic, so a crashed build resumes at
the last completed batch (node-failure tolerance for the 20M-series run).
"""
from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.core.index import SSHFunctions, SSHParams, band_keys
from repro.data.timeseries import extract_subsequences, random_walk, \
    synthetic_ecg
from repro.launch.steps import _make_ssh_build


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", choices=["ecg", "randomwalk"],
                    default="ecg")
    ap.add_argument("--points", type=int, default=50_000)
    ap.add_argument("--length", type=int, default=256)
    ap.add_argument("--batch", type=int, default=4096)
    ap.add_argument("--out", type=str, default="/tmp/ssh_index")
    args = ap.parse_args()

    gen = synthetic_ecg if args.dataset == "ecg" else random_walk
    stream = gen(args.points, seed=3)
    series = extract_subsequences(stream, args.length, stride=1, znorm=True)
    n = series.shape[0]

    params = (SSHParams(window=80, step=3, ngram=15, num_hashes=40,
                        num_tables=20) if args.dataset == "ecg" else
              SSHParams(window=30, step=5, ngram=15, num_hashes=40,
                        num_tables=20))
    fns = SSHFunctions.create(params)
    build = _make_ssh_build(params)
    p = {"filters": fns.filters, "cws": fns.cws._asdict()}

    ck = Checkpointer(args.out, keep=2)
    latest, restored = ck.restore_latest(
        {"sigs": jnp.zeros((n, params.num_hashes), jnp.int32),
         "done": jnp.zeros((), jnp.int32)})
    sigs = np.asarray(restored["sigs"]).copy()
    done = int(restored["done"]) if latest is not None else 0
    if done:
        print(f"resuming at series {done}/{n}")

    t0 = time.time()
    for lo in range(done, n, args.batch):
        hi = min(lo + args.batch, n)
        out = build(p, {"series": jnp.asarray(series[lo:hi])})
        sigs[lo:hi] = np.asarray(out)
        ck.save(hi, {"sigs": jnp.asarray(sigs),
                     "done": jnp.asarray(hi, jnp.int32)})
        rate = (hi - done) / max(time.time() - t0, 1e-9)
        print(f"hashed {hi}/{n} ({rate:.0f} series/s)", flush=True)
    keys = band_keys(jnp.asarray(sigs), params)
    ck.save(n + 1, {"sigs": jnp.asarray(sigs),
                    "done": jnp.asarray(n, jnp.int32)})
    print(f"index built: {n} series, {params.num_hashes} hashes, "
          f"{keys.shape[1]} tables in {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
