"""LRU query-signature cache — repeated queries skip encode entirely.

Encode is recomputed per query and is ~25 % of query time at smoke
scale; repeated-query traffic (monitoring dashboards re-issuing the
same probe, canary queries, retry storms) pays it for nothing.  A
:class:`SignatureCache` memoises encoded signatures keyed by the query's
*content* — a blake2b digest of its raw float32 bytes (+ shape/dtype) —
together with the :class:`~repro.encoders.base.IndexSpec`, the build
backend, and an encode-variant tag (plain / keys / multiprobe-o), so a
hit is guaranteed to return exactly the array the encoder would have
produced: cached values are bit-identical, results are unchanged.

One cache instance lives per index (lazily, see
``SSHIndex.query_signature_cached``) and is bounded LRU; the hit/miss
counters surface as ``SearchStats.sig_cache_hit`` and
``ServingMetrics.snapshot()["sig_cache_hits_total"]``.
"""
from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from typing import Hashable, Optional, Tuple

import numpy as np

#: Default per-index capacity — signatures are (K,) int32 / (L,) uint32
#: rows, so even a generous cache is a few hundred KB.
DEFAULT_CAPACITY = 512


def series_digest(series) -> bytes:
    """Content hash of a query series: raw bytes + shape + dtype.

    The array is canonicalised to contiguous float32 first — the same
    normalisation every encode path applies — so a float64 list and the
    equivalent float32 array share one cache line.
    """
    arr = np.ascontiguousarray(np.asarray(series, np.float32))
    h = hashlib.blake2b(digest_size=16)
    h.update(str(arr.shape).encode())
    h.update(arr.tobytes())
    return h.digest()


class SignatureCache:
    """Bounded LRU of encoded query signatures.  Thread-safe (the
    serving engine's batcher thread and direct callers share one index)."""

    def __init__(self, capacity: int = DEFAULT_CAPACITY):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._lock = threading.Lock()
        self._store: "OrderedDict[Tuple, np.ndarray]" = OrderedDict()
        self.hits = 0
        self.misses = 0

    @staticmethod
    def key(series, spec: Hashable, backend: str,
            variant: str = "sig") -> Tuple:
        """Cache key: (content digest, IndexSpec, backend, variant).

        ``spec`` rides in the key directly (IndexSpec is frozen and
        hashable), so indexes sharing one cache could never alias; the
        backend tag keeps pallas/jnp encodes — bit-equal only where the
        sign bits agree — apart; ``variant`` separates plain signatures
        from band keys and per-offset multiprobe blocks.
        """
        return (series_digest(series), spec, backend, variant)

    def get(self, key: Tuple) -> Optional[np.ndarray]:
        with self._lock:
            sig = self._store.get(key)
            if sig is None:
                self.misses += 1
                return None
            self._store.move_to_end(key)
            self.hits += 1
            return sig

    def put(self, key: Tuple, sig) -> None:
        with self._lock:
            self._store[key] = np.asarray(sig)
            self._store.move_to_end(key)
            while len(self._store) > self.capacity:
                self._store.popitem(last=False)

    def __len__(self) -> int:
        with self._lock:
            return len(self._store)
