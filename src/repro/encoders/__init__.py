"""repro.encoders — pluggable encoder pipeline API (DESIGN.md §7).

Public API:
  IndexSpec                          — frozen build-time spec (the twin of
                                       ``repro.db.SearchConfig``)
  Encoder / Sketcher / Shingler / Hasher
                                     — the facade + stage protocols
  register_encoder / available_encoders / encoder_class / make_encoder
                                     — the encoder registry

Built-ins (registered on first registry lookup): ``"ssh"`` (the paper's
sketch→shingle→CWS pipeline, bit-identical to the historical
``SSHParams`` path), ``"srp"`` (signed-random-projection baseline,
subsumes ``core/srp.py``), and ``"ssh-multires"`` (concatenated
multi-resolution shingle histograms — beyond-paper).

``IndexSpec`` and the protocols import eagerly (they are light and sit
below the legacy entry points in the import graph); the stage
implementations load lazily through the registry so
``from repro.encoders import IndexSpec`` never drags the kernel stack in.
"""
from repro.encoders.base import (Encoder, Hasher, IndexSpec, Shingler,
                                 Sketcher)
from repro.encoders.registry import (available_encoders, encoder_class,
                                     make_encoder, register_encoder)

__all__ = [
    "IndexSpec", "Encoder", "Sketcher", "Shingler", "Hasher",
    "register_encoder", "available_encoders", "encoder_class",
    "make_encoder",
]
