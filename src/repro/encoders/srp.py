"""``"srp"`` encoder — Signed Random Projection behind the one facade.

The paper's sanity-check baseline (§5.2): the whole series is one long
vector hashed by K signed random projections (cosine LSH).  Historically
this lived as a parallel one-off path (``core/srp.py`` + ``srp_search``);
registering it as an encoder subsumes that fork — the same
``TimeSeriesDB.build(spec=IndexSpec(encoder="srp"))`` / search / save /
load story as SSH, with collision counts over the K sign bits playing
the role CWS-hash agreement plays for ``"ssh"`` (ranking identical to
the legacy Hamming-similarity ranking: agreement *count* is K times the
agreement *fraction*).

Unlike the SSH family the random state is sized to the series length m,
so ``materialize`` requires ``length`` (the registry forwards it) and
``load_arrays`` recovers it from the persisted planes.  SRP has no
shift-alignment structure — multiprobe raises (use
``multiprobe_offsets=1``).
"""
from __future__ import annotations

from typing import Dict, Mapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import srp as srp_mod
from repro.encoders.base import Encoder, IndexSpec
from repro.encoders.registry import register_encoder
from repro.kernels import ops


@register_encoder("srp")
class SRPEncoder(Encoder):
    """Params: ``num_hashes`` (K sign bits), ``num_tables`` (L bands).

    Defaults match the historical baseline setting (K=64 planes, as in
    ``benchmarks/table2_precision.py``); the planes are sampled from
    ``PRNGKey(seed)`` exactly as ``core.srp.make_srp`` always did, so a
    spec with ``seed=s`` reproduces the legacy ``make_srp(PRNGKey(s))``
    planes bit-for-bit.
    """

    DEFAULTS = dict(num_hashes=64, num_tables=16)

    def __init__(self, spec: IndexSpec):
        super().__init__(spec)
        p = {**self.DEFAULTS, **spec.params}
        self._num_hashes = int(p["num_hashes"])
        self._num_tables_ = int(p["num_tables"])
        self._state: Optional[Dict[str, jnp.ndarray]] = None

    @classmethod
    def validate_params(cls, spec: IndexSpec) -> None:
        cls._check_param_names(spec, cls.DEFAULTS)
        p = {**cls.DEFAULTS, **spec.params}
        if p["num_hashes"] % p["num_tables"]:
            raise ValueError("num_hashes must be divisible by num_tables")

    # -- shape identity ---------------------------------------------------
    @property
    def num_hashes(self) -> int:
        return self._num_hashes

    @property
    def num_tables(self) -> int:
        return self._num_tables_

    @property
    def materialized(self) -> bool:
        return self._state is not None

    # -- lifecycle --------------------------------------------------------
    def materialize(self, length: Optional[int] = None) -> "SRPEncoder":
        if self._state is None:
            if length is None:
                raise ValueError(
                    "the 'srp' encoder's planes are sized to the series "
                    "length; pass length= (make_encoder forwards it)")
            planes = srp_mod.make_srp(jax.random.PRNGKey(self.spec.seed),
                                      self._num_hashes, int(length))
            self._adopt({"planes": planes})
        return self

    def _adopt(self, state: Dict[str, jnp.ndarray]) -> None:
        self._state = {k: jnp.asarray(v) for k, v in state.items()}
        planes = self._state["planes"]

        def one(x):
            return srp_mod.srp_bits(x, planes).astype(jnp.int32)

        self._encode_one = jax.jit(one)
        self._encode_batch = jax.jit(jax.vmap(one))

    def _require_state(self) -> None:
        if self._state is None:
            raise RuntimeError("'srp' encoder is not materialized; call "
                               "materialize(length) or load_arrays() first")

    # -- encoding ---------------------------------------------------------
    def encode(self, x: jnp.ndarray, *, backend: str = "auto"
               ) -> jnp.ndarray:
        self._require_state()
        ops.resolve_backend(backend)     # validate the knob; one matmul —
        return self._encode_one(x)       # XLA already owns this shape

    def encode_batch(self, xs: jnp.ndarray, *, backend: str = "auto"
                     ) -> jnp.ndarray:
        self._require_state()
        ops.resolve_backend(backend)
        return self._encode_batch(xs)

    # -- distributed hooks ------------------------------------------------
    def pure_encode_fn(self):
        def encode(x, state):
            return srp_mod.srp_bits(x, state["planes"]).astype(jnp.int32)
        return encode

    def state(self) -> Dict[str, jnp.ndarray]:
        self._require_state()
        return dict(self._state)

    # -- persistence ------------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        self._require_state()
        return {k: np.asarray(v) for k, v in self._state.items()}

    def load_arrays(self, arrays: Mapping[str, np.ndarray]) -> "SRPEncoder":
        self._check_leaves(arrays, {"planes": None})
        shape = tuple(np.shape(arrays["planes"]))
        if len(shape) != 2 or shape[1] != self._num_hashes:
            raise self._mismatch(
                f"planes shape {shape} incompatible with "
                f"num_hashes={self._num_hashes} (want (length, K))")
        self._adopt(dict(arrays))
        return self
