"""Encoder API core — ``IndexSpec`` and the stage/encoder protocols.

The paper frames SSH as a *composition* of interchangeable LSH stages
(Fig. 5): a sketcher turns a series into a bit-profile, a shingler turns
the bit-profile into a weighted set, a hasher turns the weighted set into
a fixed-width signature.  ``repro.encoders`` makes that composition a
first-class API (DESIGN.md §7):

* **`IndexSpec`** — the frozen *build-time* twin of
  ``repro.db.SearchConfig``: encoder name + stage params + seed.  It is
  what the index *is*; everything a query can vary stays on
  ``SearchConfig``.  Specs serialise (``to_dict``/``from_dict``) and are
  persisted inside every saved database directory so ``load()`` can
  reconstruct the encoder through the registry.
* **Stage protocols** (`Sketcher`, `Shingler`, `Hasher`) — the contract
  each pipeline stage satisfies; `repro.encoders.pipeline` ships the
  paper's implementations and composes them into the ``"ssh"`` and
  ``"ssh-multires"`` encoders.
* **`Encoder`** — the facade every index build/query path consumes:
  ``materialize`` samples the data-independent random functions,
  ``encode``/``encode_batch`` produce ``(K,)`` int32 signatures (with the
  same ``backend="pallas"|"jnp"|"auto"`` knob as the search side),
  ``band_keys`` folds K hashes into L bucket keys, and
  ``arrays``/``load_arrays`` round-trip the materialised state for
  persistence (``load_arrays`` *refuses* artifacts that do not match the
  spec).

This module is import-light (no ``repro.core.index``) so the legacy
entry points can shim through it without cycles.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Iterable, Mapping, Optional, Protocol, \
    runtime_checkable

import jax.numpy as jnp
import numpy as np

from repro.core import minhash


@dataclasses.dataclass(frozen=True)
class IndexSpec:
    """What the index *is*: encoder name + stage params + seed.

    ``params`` holds the encoder's stage hyper-parameters (e.g. for
    ``"ssh"``: window/step/ngram/num_filters/num_hashes/num_tables);
    unset keys take the encoder's documented defaults.  Two indexes
    built from equal specs over equal data are bit-identical — the spec
    plus the materialised arrays is the complete identity of an index,
    which is why persistence stores both and refuses a mismatch.
    """

    encoder: str = "ssh"
    params: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    seed: int = 7

    def __post_init__(self):
        # defensive copy (a caller mutating the dict they passed must not
        # mutate a frozen spec) + normalisation: sequence params become
        # tuples so from_dict(to_dict(spec)) == spec survives the JSON
        # list/tuple round-trip
        params = {k: tuple(v) if isinstance(v, (list, tuple)) else v
                  for k, v in dict(self.params).items()}
        object.__setattr__(self, "params", params)

    # -- validation -------------------------------------------------------
    def validate(self) -> "IndexSpec":
        """Raise ``ValueError`` on an unknown encoder name or
        inconsistent stage params; returns ``self`` for chaining."""
        if not isinstance(self.encoder, str) or not self.encoder:
            raise ValueError(
                f"encoder must be a non-empty string, got {self.encoder!r}")
        from repro.encoders import registry
        cls = registry.encoder_class(self.encoder)     # raises if unknown
        cls.validate_params(self)
        return self

    # -- derived ----------------------------------------------------------
    def replace(self, **changes: Any) -> "IndexSpec":
        """``dataclasses.replace`` + ``validate`` in one step."""
        return dataclasses.replace(self, **changes).validate()

    def with_params(self, **params: Any) -> "IndexSpec":
        """New spec with ``params`` merged over the current stage params."""
        return self.replace(params={**self.params, **params})

    def __hash__(self):
        # the generated frozen-dataclass hash would raise on the dict
        # field; a spec is a value type (cache keys, memoised builds)
        return hash((self.encoder, tuple(sorted(self.params.items())),
                     self.seed))

    # -- (de)serialisation ------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        return {"encoder": self.encoder, "params": dict(self.params),
                "seed": self.seed}

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "IndexSpec":
        """Tolerant inverse of ``to_dict``: unknown top-level keys (a
        spec written by a newer release) are dropped with a warning."""
        known = {f.name for f in dataclasses.fields(cls)}
        extra = sorted(set(d) - known)
        if extra:
            warnings.warn(f"IndexSpec.from_dict: ignoring unknown fields "
                          f"{extra}", RuntimeWarning, stacklevel=2)
        return cls(**{k: v for k, v in d.items() if k in known})


# --------------------------------------------------------------------------
# stage protocols — what pipeline.py composes, what out-of-tree encoders
# implement
# --------------------------------------------------------------------------

@runtime_checkable
class Sketcher(Protocol):
    """Stage 1: series ``(..., m)`` → bit-profile ``(..., N_B, F)``."""

    def materialize(self, key) -> Dict[str, jnp.ndarray]:
        """Sample the stage's random state (e.g. the filter bank)."""

    def sketch(self, x: jnp.ndarray, state: Dict[str, jnp.ndarray]
               ) -> jnp.ndarray:
        """Reference (jnp) bit extraction."""


@runtime_checkable
class Shingler(Protocol):
    """Stage 2: bit-profile ``(N_B, F)`` → weighted set ``(D,)``."""

    @property
    def dim(self) -> int:
        """D — the weighted-set dimensionality the hasher is sized to."""

    @property
    def min_bits(self) -> int:
        """Fewest bit-profile rows that still hold one full shingle."""

    def histogram(self, bits: jnp.ndarray) -> jnp.ndarray: ...

    def histogram_masked(self, bits: jnp.ndarray, valid_bits) -> jnp.ndarray:
        """Histogram counting only shingles fully inside the first
        ``valid_bits`` rows (the fused multiprobe path)."""


@runtime_checkable
class Hasher(Protocol):
    """Stage 3: weighted set ``(D,)`` → signature ``(K,)`` int32."""

    def materialize(self, key, dim: int) -> Dict[str, jnp.ndarray]: ...

    def hash(self, counts: jnp.ndarray, state: Dict[str, jnp.ndarray]
             ) -> jnp.ndarray: ...


# --------------------------------------------------------------------------
# Encoder base
# --------------------------------------------------------------------------

class Encoder:
    """Base class / protocol for index-side encoders.

    Lifecycle: ``cls(spec)`` parses the stage params; ``materialize``
    samples the data-independent random state (idempotent) — or
    ``load_arrays`` restores a persisted state, refusing arrays whose
    shapes disagree with the spec.  After either, ``encode*`` produce
    signatures and ``band_keys`` folds them into bucket keys.
    """

    #: registry name, set by ``@register_encoder``
    name: str = ""

    def __init__(self, spec: IndexSpec):
        self.spec = spec

    # -- registry hooks ---------------------------------------------------
    @classmethod
    def validate_params(cls, spec: IndexSpec) -> None:
        """Raise ``ValueError`` on inconsistent stage params (unknown
        keys, K % L != 0, ...).  Called by ``IndexSpec.validate``."""

    @classmethod
    def _check_param_names(cls, spec: IndexSpec,
                           known: Iterable[str]) -> None:
        unknown = sorted(set(spec.params) - set(known))
        if unknown:
            raise ValueError(
                f"unknown params {unknown} for encoder "
                f"{spec.encoder!r}; known: {sorted(known)}")

    # -- capabilities -----------------------------------------------------
    #: whether the encoder has shift-alignment classes to multiprobe
    #: (``encode_multiprobe`` works); the facade clamps
    #: ``multiprobe_offsets`` to 1 for encoders without them
    supports_multiprobe: bool = False

    # -- shape identity ---------------------------------------------------
    @property
    def num_hashes(self) -> int:
        raise NotImplementedError

    @property
    def num_tables(self) -> int:
        raise NotImplementedError

    @property
    def materialized(self) -> bool:
        raise NotImplementedError

    # -- lifecycle --------------------------------------------------------
    def materialize(self, length: Optional[int] = None) -> "Encoder":
        """Sample the random functions (idempotent).  ``length`` is the
        series length m — required by encoders whose state is sized to
        it (``"srp"``), ignored by the SSH family."""
        raise NotImplementedError

    # -- encoding ---------------------------------------------------------
    def encode(self, x: jnp.ndarray, *, backend: str = "auto"
               ) -> jnp.ndarray:
        """One series ``(m,)`` → signature ``(K,)`` int32."""
        raise NotImplementedError

    def encode_batch(self, xs: jnp.ndarray, *, backend: str = "auto"
                     ) -> jnp.ndarray:
        """Series block ``(B, m)`` → ``(B, K)`` int32, one dispatch."""
        raise NotImplementedError

    def encode_chunked(self, series: jnp.ndarray, *, batch: int = 256,
                       backend: str = "auto") -> jnp.ndarray:
        """Database build: ``(N, m)`` → ``(N, K)`` int32, chunked so the
        per-dispatch working set stays bounded.  Reuses the cached
        compiled batch fn — chunked builds and streaming inserts pay
        trace cost once per chunk *shape*, not once per call."""
        n = int(series.shape[0])
        out = []
        for lo in range(0, n, batch):
            out.append(np.asarray(
                self.encode_batch(series[lo:lo + batch], backend=backend)))
        return jnp.asarray(np.concatenate(out, axis=0))

    def encode_multiprobe(self, q: jnp.ndarray, offsets: int, *,
                          backend: str = "auto") -> jnp.ndarray:
        """Signatures of ``offsets`` shifted copies of ``q`` → (O, K).

        Row o equals ``encode(q[o:])`` bit-for-bit.  Encoders without a
        shift-alignment structure raise ``ValueError``.
        """
        raise ValueError(
            f"encoder {self.spec.encoder!r} has no shift-alignment "
            "classes; use multiprobe_offsets=1")

    def encode_batch_multiprobe(self, qs: jnp.ndarray, offsets: int, *,
                                backend: str = "auto") -> jnp.ndarray:
        """(B, m) → (B, O, K); row [b, o] equals ``encode(qs[b, o:])``."""
        raise ValueError(
            f"encoder {self.spec.encoder!r} has no shift-alignment "
            "classes; use multiprobe_offsets=1")

    def band_keys(self, signatures: jnp.ndarray) -> jnp.ndarray:
        """(..., K) signatures → (..., L) uint32 bucket keys."""
        return minhash.combine_bands(signatures, self.num_tables)

    # -- distributed hooks ------------------------------------------------
    def pure_encode_fn(self):
        """A pure ``fn(x, state) -> (K,) int32`` over the encoder's
        materialised array ``state`` — the form ``shard_map`` needs (the
        random state rides as an explicit replicated operand; the stage
        hyper-parameters are closed over as static Python values)."""
        raise NotImplementedError

    def state(self) -> Dict[str, jnp.ndarray]:
        """Materialised random state as device arrays (what
        ``pure_encode_fn`` consumes)."""
        raise NotImplementedError

    # -- persistence ------------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        """Materialised random state as named host arrays (the leaves
        persistence stores under ``encoder/<name>``)."""
        raise NotImplementedError

    def load_arrays(self, arrays: Mapping[str, np.ndarray]) -> "Encoder":
        """Adopt persisted state.  MUST raise ``ValueError`` when the
        array names/shapes disagree with what the spec implies — the
        spec/artifact-mismatch refusal of the persistence contract."""
        raise NotImplementedError

    def _check_leaves(self, arrays: Mapping[str, np.ndarray],
                      want: Mapping[str, Any]) -> None:
        """Refuse saved states whose leaf set differs from the spec's,
        naming every offending leaf.  Missing leaves mean the artifact
        predates (or lost) part of the encoder's state; unknown leaves
        mean the spec no longer describes the artifact — adopting either
        silently would hand back an encoder that hashes differently than
        the index it came from."""
        missing = sorted(set(want) - set(arrays))
        if missing:
            raise self._mismatch(
                f"saved state is missing encoder array leaf(s) "
                f"{missing}; found only {sorted(arrays)}")
        unknown = sorted(set(arrays) - set(want))
        if unknown:
            raise self._mismatch(
                f"saved state has unrecognised encoder array leaf(s) "
                f"{unknown}; this spec expects exactly {sorted(want)}")

    def _mismatch(self, detail: str) -> "ValueError":
        return ValueError(
            f"saved encoder arrays do not match IndexSpec("
            f"encoder={self.spec.encoder!r}, params={dict(self.spec.params)!r}"
            f"): {detail}")

    def __repr__(self) -> str:
        return (f"{type(self).__name__}(spec={self.spec!r}, "
                f"materialized={self.materialized})")
