"""Sketch → Shingle → Hash stage implementations and their composition.

``PipelineEncoder`` composes a :class:`Sketcher`, a :class:`Shingler`,
and a :class:`Hasher` (the three stages of paper Fig. 5) into one
:class:`repro.encoders.Encoder`.  Two built-ins register here:

* ``"ssh"`` — the paper's pipeline: Gaussian filter-bank sketch (§4.1),
  n-gram shingle histogram (§4.2), 0-bit CWS (§4.3).  Bit-identical to
  the historical ``SSHParams``/``SSHFunctions`` path (same PRNG key
  schedule, same stage functions) — the golden test in
  ``tests/test_encoders.py`` pins this.
* ``"ssh-multires"`` — beyond-paper scenario: the shingle stage emits
  the *concatenation* of n-gram histograms at several resolutions, so
  one signature carries both short-motif and long-motif statistics
  (multi-resolution shingles; CWS hashes the concatenated weighted set,
  which is the weighted-Jaccard of the union — per-resolution evidence
  is averaged with histogram-mass weights).

Backend knob: signature *builds* route the sketch stage through the
Pallas ``kernels.ops.sketch_conv`` kernel when ``backend`` resolves to
Pallas — the (B, N_B·F) strided-matvec is the build hot path — and
through the jnp reference otherwise.  Integer signatures mean results
are backend-independent wherever the sign bits agree (projections are
computed identically up to float reassociation).

Compiled-function caching: every jitted encode path is constructed ONCE
per materialised encoder and cached on the instance, so chunked builds
and streaming inserts stop paying per-call retrace overhead (the
historical ``build_signatures`` re-wrapped ``jax.jit`` on every call).
The fused multiprobe path evaluates every δ-offset inside one program
(shifted fixed-length slices + mask-aware histograms), replacing the
per-offset programs that each compiled against a distinct query length.
"""
from __future__ import annotations

import collections
from typing import Dict, Mapping, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import minhash, shingle, sketch
from repro.encoders.base import Encoder, IndexSpec
from repro.encoders.registry import register_encoder
from repro.kernels import ops


# --------------------------------------------------------------------------
# stages
# --------------------------------------------------------------------------

class GaussianFilterSketcher:
    """§4.1 — sign bits of a strided random filter-bank convolution."""

    def __init__(self, window: int, step: int, num_filters: int = 1):
        if window < 1 or step < 1 or num_filters < 1:
            raise ValueError("window, step, num_filters must be >= 1")
        self.window, self.step, self.num_filters = window, step, num_filters

    def materialize(self, key) -> Dict[str, jnp.ndarray]:
        return {"filters": sketch.make_filter(key, self.window,
                                              self.num_filters)}

    def sketch(self, x: jnp.ndarray, state: Mapping[str, jnp.ndarray]
               ) -> jnp.ndarray:
        return sketch.sketch_bits(x, state["filters"], self.step)

    def sketch_batch_pallas(self, xs: jnp.ndarray,
                            state: Mapping[str, jnp.ndarray]) -> jnp.ndarray:
        """(B, m) → (B, N_B, F) bits through the Pallas strided-matvec
        kernel (interpret mode off-TPU)."""
        return ops.sketch_bits(xs, state["filters"], self.step,
                               use_pallas=True)

    def num_bits(self, o, m: int):
        """Valid window count for a query shifted by ``o`` (traced ok)."""
        return (m - o - self.window) // self.step + 1


class NgramShingler:
    """§4.2 — dense n-gram histogram over the bit-profile."""

    def __init__(self, ngram: int, num_filters: int = 1):
        self.ngram, self.num_filters = ngram, num_filters

    @property
    def dim(self) -> int:
        return shingle.shingle_space(self.ngram, self.num_filters)

    @property
    def min_bits(self) -> int:
        return self.ngram

    def histogram(self, bits: jnp.ndarray) -> jnp.ndarray:
        return shingle.shingle_histogram(bits, self.ngram)

    def histogram_masked(self, bits: jnp.ndarray, valid_bits) -> jnp.ndarray:
        return shingle.shingle_histogram_masked(bits, self.ngram, valid_bits)


class MultiResShingler:
    """Concatenated n-gram histograms at several resolutions.

    The weighted set is the disjoint union of the per-n shingle sets, so
    the CWS collision probability becomes the histogram-mass-weighted
    average of the per-resolution weighted-Jaccard similarities.
    """

    def __init__(self, ngrams: Sequence[int], num_filters: int = 1):
        self.ngrams: Tuple[int, ...] = tuple(int(n) for n in ngrams)
        self.num_filters = num_filters

    @property
    def dim(self) -> int:
        return sum(shingle.shingle_space(n, self.num_filters)
                   for n in self.ngrams)

    @property
    def min_bits(self) -> int:
        return max(self.ngrams)

    def histogram(self, bits: jnp.ndarray) -> jnp.ndarray:
        return jnp.concatenate(
            [shingle.shingle_histogram(bits, n) for n in self.ngrams])

    def histogram_masked(self, bits: jnp.ndarray, valid_bits) -> jnp.ndarray:
        return jnp.concatenate(
            [shingle.shingle_histogram_masked(bits, n, valid_bits)
             for n in self.ngrams])


class CWSHasher:
    """§4.3 — 0-bit Consistent Weighted Sampling, K independent hashes."""

    def __init__(self, num_hashes: int):
        self.num_hashes = num_hashes

    def materialize(self, key, dim: int) -> Dict[str, jnp.ndarray]:
        cws = minhash.make_cws(key, self.num_hashes, dim)
        return {f"cws/{f}": getattr(cws, f) for f in cws._fields}

    @staticmethod
    def cws_params(state: Mapping[str, jnp.ndarray]) -> minhash.CWSParams:
        return minhash.CWSParams(
            **{f: state[f"cws/{f}"] for f in minhash.CWSParams._fields})

    def hash(self, counts: jnp.ndarray, state: Mapping[str, jnp.ndarray]
             ) -> jnp.ndarray:
        return minhash.cws_hash(counts, self.cws_params(state))


# --------------------------------------------------------------------------
# the composed encoder
# --------------------------------------------------------------------------

class PipelineEncoder(Encoder):
    """Sketcher ∘ Shingler ∘ Hasher behind the one Encoder facade.

    Subclasses parse ``spec.params`` into the three stages in
    ``_build_stages``; everything else (materialisation key schedule,
    cached jitted encode paths, fused multiprobe, persistence arrays) is
    shared.
    """

    supports_multiprobe = True       # δ-residue shingle alignment classes

    def __init__(self, spec: IndexSpec):
        super().__init__(spec)
        self.sketcher, self.shingler, self.hasher, self._num_tables = \
            self._build_stages(spec)
        self._state: Optional[Dict[str, jnp.ndarray]] = None

    # subclasses implement -------------------------------------------------
    @classmethod
    def _build_stages(cls, spec: IndexSpec):
        raise NotImplementedError

    # -- shape identity ---------------------------------------------------
    @property
    def num_hashes(self) -> int:
        return self.hasher.num_hashes

    @property
    def num_tables(self) -> int:
        return self._num_tables

    @property
    def materialized(self) -> bool:
        return self._state is not None

    # -- lifecycle --------------------------------------------------------
    def materialize(self, length: Optional[int] = None) -> "PipelineEncoder":
        if self._state is None:
            # key schedule identical to the historical SSHFunctions.create
            key = jax.random.PRNGKey(self.spec.seed)
            kf, kc = jax.random.split(key)
            state = dict(self.sketcher.materialize(kf))
            state.update(self.hasher.materialize(kc, self.shingler.dim))
            if hasattr(self.shingler, "materialize"):
                # stateful shinglers (the streaming count-sketch) get their
                # own fold of the root key — the kf/kc split above is pinned
                # by the "ssh" golden signatures and must never change
                state.update(self.shingler.materialize(
                    jax.random.fold_in(key, 2)))
            self._adopt(state)
        return self

    def _adopt(self, state: Dict[str, jnp.ndarray]) -> None:
        """Install materialised state and build the cached jitted encode
        paths (once per encoder — chunked builds and streaming inserts
        reuse them instead of re-tracing)."""
        self._state = {k: jnp.asarray(v) for k, v in state.items()}
        if hasattr(self.shingler, "adopt"):
            # stateful shinglers pick their hash coefficients out of the
            # adopted state before the closures below trace against them
            self.shingler.adopt(self._state)
        # trace-time counters: incremented when jax (re)traces a path, not
        # on every call — tests pin "compiled once" with these
        self.trace_counts: Dict[str, int] = collections.defaultdict(int)

        def _count(name: str) -> None:
            self.trace_counts[name] += 1

        def one(x):
            _count("one")
            bits = self.sketcher.sketch(x, self._state)
            return self.hasher.hash(self.shingler.histogram(bits),
                                    self._state)

        def batch(xs):
            _count("batch")
            return jax.vmap(one)(xs)

        def batch_pallas(xs):
            _count("batch_pallas")
            bits = self.sketcher.sketch_batch_pallas(xs, self._state)
            if hasattr(self.shingler, "histogram_batch_pallas"):
                # shinglers with their own batched kernel (the count-sketch
                # scatter) keep the whole weighted-set stage on Pallas
                counts = self.shingler.histogram_batch_pallas(bits)
                return jax.vmap(lambda c: self.hasher.hash(
                    c, self._state))(counts)
            return jax.vmap(lambda b: self.hasher.hash(
                self.shingler.histogram(b), self._state))(bits)

        def multiprobe(q, offsets: int):
            # one program for all δ-offsets: offset o encodes the fixed-
            # length shifted slice and masks the histogram down to the
            # shingles of q[o:] — bit-identical to encode(q[o:]) without
            # compiling a distinct program per offset length
            _count("multiprobe")
            m = q.shape[-1]
            qpad = jnp.pad(q, (0, offsets - 1))

            def one_offset(o):
                x = jax.lax.dynamic_slice(qpad, (o,), (m,))
                bits = self.sketcher.sketch(x, self._state)
                v = self.sketcher.num_bits(o, m)
                counts = self.shingler.histogram_masked(bits, v)
                return self.hasher.hash(counts, self._state)

            return jax.vmap(one_offset)(jnp.arange(offsets))

        def batch_multiprobe(qs, offsets: int):
            return jax.vmap(lambda q: multiprobe(q, offsets))(qs)

        def batch_multiprobe_pallas(qs, offsets: int):
            # same fused-offset semantics, sketch stage through the
            # Pallas kernel: all B·O shifted slices ride ONE kernel
            # launch, then mask-aware histograms + CWS per row
            _count("batch_multiprobe_pallas")
            b, m = qs.shape
            qpad = jnp.pad(qs, ((0, 0), (0, offsets - 1)))
            xs = jnp.stack([qpad[:, o:o + m] for o in range(offsets)],
                           axis=1).reshape(b * offsets, m)
            bits = self.sketcher.sketch_batch_pallas(xs, self._state)
            offs = jnp.tile(jnp.arange(offsets), b)

            def one_row(bits_row, o):
                v = self.sketcher.num_bits(o, m)
                counts = self.shingler.histogram_masked(bits_row, v)
                return self.hasher.hash(counts, self._state)

            sigs = jax.vmap(one_row)(bits, offs)
            return sigs.reshape(b, offsets, -1)

        self._encode_one = jax.jit(one)
        self._encode_batch = jax.jit(batch)
        self._encode_batch_pallas = jax.jit(batch_pallas)
        self._encode_multiprobe = jax.jit(
            multiprobe, static_argnames=("offsets",))
        self._encode_batch_multiprobe = jax.jit(
            batch_multiprobe, static_argnames=("offsets",))
        self._encode_batch_multiprobe_pallas = jax.jit(
            batch_multiprobe_pallas, static_argnames=("offsets",))

    def _require_state(self) -> None:
        if self._state is None:
            raise RuntimeError(
                f"encoder {self.spec.encoder!r} is not materialized; call "
                "materialize() or load_arrays() first")

    @staticmethod
    def _use_pallas(backend: str) -> bool:
        return ops.backend_name(ops.resolve_backend(backend)) == "pallas"

    # -- encoding ---------------------------------------------------------
    def encode(self, x: jnp.ndarray, *, backend: str = "auto"
               ) -> jnp.ndarray:
        self._require_state()
        if self._use_pallas(backend):
            return self._encode_batch_pallas(x[None, :])[0]
        return self._encode_one(x)

    def encode_batch(self, xs: jnp.ndarray, *, backend: str = "auto"
                     ) -> jnp.ndarray:
        self._require_state()
        if self._use_pallas(backend):
            return self._encode_batch_pallas(xs)
        return self._encode_batch(xs)

    def encode_multiprobe(self, q: jnp.ndarray, offsets: int, *,
                          backend: str = "auto") -> jnp.ndarray:
        self._require_state()
        self._check_offsets(int(q.shape[-1]), offsets)
        if self._use_pallas(backend):
            return self._encode_batch_multiprobe_pallas(
                q[None, :], offsets=offsets)[0]
        return self._encode_multiprobe(q, offsets=offsets)

    def encode_batch_multiprobe(self, qs: jnp.ndarray, offsets: int, *,
                                backend: str = "auto") -> jnp.ndarray:
        self._require_state()
        self._check_offsets(int(qs.shape[-1]), offsets)
        if self._use_pallas(backend):
            return self._encode_batch_multiprobe_pallas(qs, offsets=offsets)
        return self._encode_batch_multiprobe(qs, offsets=offsets)

    def _check_offsets(self, m: int, offsets: int) -> None:
        if offsets < 1:
            raise ValueError(f"offsets must be >= 1, got {offsets}")
        if m - (offsets - 1) < self.sketcher.window:
            raise ValueError(
                f"query length {m} too short for {offsets} offsets at "
                f"window {self.sketcher.window}")
        # the last offset's bit-profile must still hold a full shingle,
        # matching encode(q[o:]) which would raise — without this a short
        # query silently hashed an all-masked (empty) histogram
        min_bits = self.sketcher.num_bits(offsets - 1, m)
        if min_bits < self.shingler.min_bits:
            raise ValueError(
                f"query length {m} yields only {min_bits} sketch bits at "
                f"offset {offsets - 1} — fewer than the shingle length "
                f"{self.shingler.min_bits}")

    # -- distributed hooks ------------------------------------------------
    def pure_encode_fn(self):
        sketcher, shingler, hasher = self.sketcher, self.shingler, self.hasher

        def encode(x, state):
            bits = sketcher.sketch(x, state)
            return hasher.hash(shingler.histogram(bits), state)

        return encode

    def state(self) -> Dict[str, jnp.ndarray]:
        self._require_state()
        return dict(self._state)

    # -- persistence ------------------------------------------------------
    def arrays(self) -> Dict[str, np.ndarray]:
        self._require_state()
        return {k: np.asarray(v) for k, v in self._state.items()}

    def expected_shapes(self) -> Dict[str, Tuple[int, ...]]:
        """Persistence leaf names/shapes for the DEFAULT stages (Gaussian
        filter sketcher + CWS hasher).  A subclass composing custom
        stages whose ``materialize`` emits different leaves must override
        this (and ``load_arrays`` validates against it)."""
        k, d = self.num_hashes, self.shingler.dim
        shapes = {"filters": (self.sketcher.window,
                              self.sketcher.num_filters)}
        shapes.update({f"cws/{f}": (k, d)
                       for f in minhash.CWSParams._fields})
        if hasattr(self.shingler, "extra_shapes"):
            shapes.update(self.shingler.extra_shapes())
        return shapes

    def load_arrays(self, arrays: Mapping[str, np.ndarray]
                    ) -> "PipelineEncoder":
        want = self.expected_shapes()
        self._check_leaves(arrays, want)
        for name, shape in want.items():
            got = tuple(np.shape(arrays[name]))
            if got != shape:
                raise self._mismatch(
                    f"array {name!r} has shape {got}, spec implies {shape}")
        self._adopt(dict(arrays))
        return self


@register_encoder("ssh")
class SSHEncoder(PipelineEncoder):
    """The paper's encoder (Fig. 5) — sketch, shingle, CWS-hash.

    Params (defaults = historical ``SSHParams`` defaults): ``window``,
    ``step``, ``ngram``, ``num_filters``, ``num_hashes``, ``num_tables``.
    """

    DEFAULTS = dict(window=80, step=3, ngram=15, num_filters=1,
                    num_hashes=20, num_tables=20)

    @classmethod
    def _build_stages(cls, spec: IndexSpec):
        p = {**cls.DEFAULTS, **spec.params}
        sketcher = GaussianFilterSketcher(p["window"], p["step"],
                                          p["num_filters"])
        return (sketcher, NgramShingler(p["ngram"], p["num_filters"]),
                CWSHasher(p["num_hashes"]), p["num_tables"])

    @classmethod
    def validate_params(cls, spec: IndexSpec) -> None:
        cls._check_param_names(spec, cls.DEFAULTS)
        p = {**cls.DEFAULTS, **spec.params}
        if p["num_hashes"] % p["num_tables"]:
            raise ValueError("num_hashes must be divisible by num_tables")
        if p["ngram"] > 20:
            raise ValueError("shingle space 2^n exceeds 1M bins; use n<=20")

    def legacy_functions(self):
        """The materialised state as a historical ``SSHFunctions`` (the
        ``SSHIndex.fns`` compatibility view)."""
        from repro.core.index import SSHFunctions, SSHParams
        self._require_state()
        p = {**self.DEFAULTS, **self.spec.params}
        params = SSHParams(window=p["window"], step=p["step"],
                           ngram=p["ngram"], num_filters=p["num_filters"],
                           num_hashes=p["num_hashes"],
                           num_tables=p["num_tables"], seed=self.spec.seed)
        return SSHFunctions(params=params, filters=self._state["filters"],
                            cws=CWSHasher.cws_params(self._state))


@register_encoder("ssh-multires")
class MultiResSSHEncoder(PipelineEncoder):
    """Beyond-paper: SSH with concatenated multi-resolution shingles.

    Params: ``window``, ``step``, ``ngrams`` (tuple of shingle lengths),
    ``num_filters``, ``num_hashes``, ``num_tables``.
    """

    DEFAULTS = dict(window=80, step=3, ngrams=(10, 15), num_filters=1,
                    num_hashes=20, num_tables=20)

    @classmethod
    def _build_stages(cls, spec: IndexSpec):
        p = {**cls.DEFAULTS, **spec.params}
        sketcher = GaussianFilterSketcher(p["window"], p["step"],
                                          p["num_filters"])
        return (sketcher, MultiResShingler(p["ngrams"], p["num_filters"]),
                CWSHasher(p["num_hashes"]), p["num_tables"])

    @classmethod
    def validate_params(cls, spec: IndexSpec) -> None:
        cls._check_param_names(spec, cls.DEFAULTS)
        p = {**cls.DEFAULTS, **spec.params}
        ngrams = tuple(p["ngrams"])
        if not ngrams:
            raise ValueError("ngrams must name at least one resolution")
        if any(n > 20 for n in ngrams):
            raise ValueError("shingle space 2^n exceeds 1M bins; use n<=20")
        if len(set(ngrams)) != len(ngrams):
            raise ValueError(f"duplicate shingle resolutions in {ngrams}")
        if p["num_hashes"] % p["num_tables"]:
            raise ValueError("num_hashes must be divisible by num_tables")
