"""Encoder registry — ``IndexSpec.encoder`` names how series are hashed.

Mirrors :mod:`repro.db.registry` (the search-side registry): built-ins
register at import of :mod:`repro.encoders`; ``register_encoder`` lets
out-of-tree code plug in new encoders without touching the facade —
``TimeSeriesDB.build(spec=IndexSpec(encoder="my-encoder", ...))`` and
persistence work unchanged once the class is registered.
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Type

from repro.encoders.base import Encoder, IndexSpec

_ENCODERS: Dict[str, Type[Encoder]] = {}
_BUILTINS_LOADED = False


def _ensure_builtins() -> None:
    """Import the built-in encoder modules once, on first lookup — keeps
    ``from repro.encoders import IndexSpec`` free of the kernel stack.
    The flag is only set on success so a failed import surfaces its real
    error on every lookup instead of a misleading empty registry."""
    global _BUILTINS_LOADED
    if not _BUILTINS_LOADED:
        import repro.encoders.pipeline    # noqa: F401  "ssh", "ssh-multires"
        import repro.encoders.srp         # noqa: F401  "srp"
        import repro.streaming.encoder    # noqa: F401  "ssh-cs"
        _BUILTINS_LOADED = True


def register_encoder(name: str) -> Callable[[Type[Encoder]], Type[Encoder]]:
    """Class decorator: register an :class:`Encoder` subclass under
    ``name`` (overwrites a prior registration, latest wins)."""
    def deco(cls: Type[Encoder]) -> Type[Encoder]:
        cls.name = name
        _ENCODERS[name] = cls
        return cls
    return deco


def available_encoders() -> List[str]:
    _ensure_builtins()
    return sorted(_ENCODERS)


def encoder_class(name: str) -> Type[Encoder]:
    _ensure_builtins()
    try:
        return _ENCODERS[name]
    except KeyError:
        raise ValueError(f"unknown encoder {name!r}; registered: "
                         f"{available_encoders()}") from None


def make_encoder(spec: IndexSpec, *, length: Optional[int] = None,
                 materialize: bool = True) -> Encoder:
    """Instantiate (and by default materialise) the encoder named by
    ``spec.encoder``.  ``length`` is the series length m, forwarded to
    encoders whose random state is sized to it (``"srp"``)."""
    spec.validate()
    enc = encoder_class(spec.encoder)(spec)
    if materialize:
        enc.materialize(length)
    return enc
