"""Hierarchical count-sketch over the shingle space (repro.streaming core).

The exact shingle histogram of §4.2 is replaced by a fixed-width
count-sketch (Charikar et al., after the CSH layout — SNIPPETS.md §1):
``rows`` independent (bucket, sign) hash pairs over a ``width``-bin
table.  Per shingle the update is O(rows) — O(1) in the stream length —
and two sketches over disjoint streams combine by plain addition, which
is what turns shard-parallel index builds into associative reductions
(``repro.streaming.ingest``).

Hierarchy: level ``h`` sketches the shingle-id *prefix* ``id >>
(base_bits·h)``; the coarsest level's prefix domain fits the table, so
heavy hitters are recovered top-down — enumerate the coarse prefixes,
keep those whose estimate clears the threshold, refine each survivor by
its ``2^base_bits`` children, descend to level 0 (``find_heavy_hitters``).

Hashing must live in 32-bit space (jax runs with x64 disabled), so the
classical Mersenne-prime polynomial hashes of the reference
implementation are replaced by Dietzfelbinger multiply-shift: with ``a``
odd and ``b`` uniform in uint32, ``(a·x + b) >> (32 − log2 width)`` is
universal over the bucket range, and the top bit of an independent
multiply-shift gives the ±1 sign.  All arithmetic wraps mod 2^32 by
uint32 semantics — no widening needed.

Exactness note: tables are float32 holding sums of ±1 updates.  Integer
values below 2^24 are exact in float32 and addition of exact integers is
order-independent, so ``merge(a, b)`` is *bit-identical* to sketching
the concatenated stream — the property tests in ``tests/test_streaming``
pin this.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CSParams(NamedTuple):
    """Multiply-shift coefficients for ``levels × rows`` hash pairs.

    ``bucket_a``/``sign_a`` are forced odd (multiply-shift universality
    needs an odd multiplier); all four are (levels, rows) uint32.
    """
    bucket_a: jnp.ndarray
    bucket_b: jnp.ndarray
    sign_a: jnp.ndarray
    sign_b: jnp.ndarray

    @property
    def levels(self) -> int:
        return self.bucket_a.shape[0]

    @property
    def rows(self) -> int:
        return self.bucket_a.shape[1]


def num_levels(id_bits: int, width: int, base_bits: int) -> int:
    """Hierarchy depth: enough levels that the coarsest prefix domain
    (``id_bits − base_bits·(levels−1)`` bits) fits the table width."""
    log2w = width.bit_length() - 1
    extra = max(0, id_bits - log2w)
    return 1 + -(-extra // base_bits)          # 1 + ceil(extra / base_bits)


def make_cs_params(key: jax.Array, levels: int, rows: int) -> CSParams:
    """Sample the hash coefficients (data-independent, persisted like the
    filter bank / CWS fields so a reloaded index keeps hashing
    identically regardless of future PRNG changes)."""
    ka, kb, kc, kd = jax.random.split(key, 4)

    def u32(k):
        return jax.random.bits(k, (levels, rows), jnp.uint32)

    return CSParams(bucket_a=u32(ka) | jnp.uint32(1), bucket_b=u32(kb),
                    sign_a=u32(kc) | jnp.uint32(1), sign_b=u32(kd))


def bucket_sign(ids: jnp.ndarray, a, b, sa, sb, width: int
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multiply-shift bucket + ±1 sign for (possibly invalid) ids.

    ``ids`` int32 with −1 marking invalid entries (padding / masked
    multiprobe shingles); coefficients broadcast against it.  Returns
    (bucket int32 with −1 kept invalid, sign float32 with 0 invalid) —
    both downstream paths (scatter reference and Pallas kernel) treat
    bucket −1 as "contributes nothing".
    """
    shift = 32 - (width.bit_length() - 1)
    x = ids.astype(jnp.uint32)
    bkt = ((a * x + b) >> shift).astype(jnp.int32)
    sgn = 1.0 - 2.0 * ((sa * x + sb) >> 31).astype(jnp.float32)
    valid = ids >= 0
    return jnp.where(valid, bkt, -1), jnp.where(valid, sgn, 0.0)


@functools.partial(jax.jit, static_argnames=("base_bits",))
def update(agg: jnp.ndarray, ids: jnp.ndarray, params: CSParams,
           base_bits: int) -> jnp.ndarray:
    """Fold a batch of shingle ids into a hierarchical sketch.

    agg: (levels, rows, width) float32; ids: (...,) int32, −1 invalid.
    Returns the NEW aggregate (functional — callers own the state).
    O(levels·rows) per shingle, independent of how much stream the
    aggregate already holds.
    """
    levels, rows, width = agg.shape
    flat = ids.reshape(-1)
    # level h sketches the prefix id >> (base_bits·h); arithmetic shift
    # keeps −1 (invalid) at −1 for every level
    shifts = base_bits * jnp.arange(levels, dtype=jnp.int32)
    prefixes = flat[None, :] >> shifts[:, None]                # (levels, S)
    bkt, sgn = bucket_sign(
        prefixes[:, None, :], params.bucket_a[:, :, None],
        params.bucket_b[:, :, None], params.sign_a[:, :, None],
        params.sign_b[:, :, None], width)                      # (lv, R, S)
    tgt = jnp.where(bkt >= 0, bkt, width)                      # dump bin

    def one_table(t, s):
        return jnp.zeros((width + 1,), jnp.float32).at[t].add(s)[:width]

    contrib = jax.vmap(one_table)(tgt.reshape(levels * rows, -1),
                                  sgn.reshape(levels * rows, -1))
    return agg + contrib.reshape(levels, rows, width)


def merge(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Combine sketches of disjoint streams — plain addition.

    Associative and commutative; bit-identical to sketching the
    concatenation (float32 sums of small integers are exact).
    """
    return a + b


@functools.partial(jax.jit, static_argnames=("base_bits", "level"))
def estimate(agg: jnp.ndarray, ids: jnp.ndarray, params: CSParams,
             base_bits: int, level: int = 0) -> jnp.ndarray:
    """Median-of-rows frequency estimate for prefix ids at ``level``.

    ``ids`` are already prefix values at that level (i.e. the caller
    shifted; pass raw shingle ids for level 0).  −1 ids estimate 0.
    """
    width = agg.shape[-1]
    a = params.bucket_a[level][:, None]
    bkt, sgn = bucket_sign(ids[None, :], a, params.bucket_b[level][:, None],
                           params.sign_a[level][:, None],
                           params.sign_b[level][:, None], width)  # (R, S)
    reads = jnp.take_along_axis(agg[level], jnp.maximum(bkt, 0), axis=1)
    est = jnp.median(sgn * reads, axis=0)
    return jnp.where(ids >= 0, est, 0.0)


def find_heavy_hitters(agg: jnp.ndarray, params: CSParams, *,
                       base_bits: int, id_bits: int, threshold: float
                       ) -> Tuple[np.ndarray, np.ndarray]:
    """Shingle ids whose estimated frequency clears ``threshold``.

    Top-down refinement: enumerate the coarsest level's prefix domain,
    keep prefixes estimating ≥ threshold, expand each survivor into its
    2^base_bits children one level down, repeat to level 0.  Sound
    because a shingle's frequency lower-bounds every one of its prefix
    frequencies (prefix counts are sums over children).  Returns
    (ids, estimates) sorted by estimate descending — diagnostics, host-
    side by design (the recursion is data-dependent).
    """
    levels = int(agg.shape[0])
    top_bits = max(id_bits - base_bits * (levels - 1), 0)
    cand = np.arange(1 << top_bits, dtype=np.int64)
    for level in range(levels - 1, -1, -1):
        if cand.size == 0:
            return (np.empty(0, np.int64), np.empty(0, np.float32))
        ests = np.asarray(estimate(agg, jnp.asarray(cand, jnp.int32),
                                   params, base_bits=base_bits,
                                   level=level))
        keep = ests >= threshold
        cand, ests = cand[keep], ests[keep]
        if level > 0:
            cand = (cand[:, None] * (1 << base_bits)
                    + np.arange(1 << base_bits, dtype=np.int64)).reshape(-1)
    order = np.argsort(-ests, kind="stable")
    return cand[order], ests[order].astype(np.float32)


def l2_estimate(agg: jnp.ndarray, level: int = 0) -> float:
    """Median-of-rows ‖f‖₂ estimate at ``level`` (stream-mass sanity)."""
    return float(jnp.median(jnp.sqrt(jnp.sum(agg[level] ** 2, axis=-1))))
