"""``"ssh-cs"`` — SSH with a count-sketch shingle stage (repro.streaming).

``CountSketchShingler`` swaps the exact F·2^n shingle histogram (§4.2)
for ``rows`` signed count-sketch tables of ``width`` bins
(``repro.streaming.count_sketch``): the weighted set handed to CWS is the
relu of the level-0 tables, flattened to ``rows·width`` — a *fixed*
dimensionality, so the CWS state is sized to rows·width instead of the
shingle vocabulary.  That is the memory story of the subsystem: at the
paper's n=15 the exact stage needs CWS fields over 32768·F bins, the
sketch stage over rows·width regardless of n, F, or how many streams
ever merged into it.

Why relu: CWS consumes non-negative weights (``cws_hash`` excludes
w ≤ 0).  Count-sketch entries are signed; clamping at zero keeps every
bucket a near-exact count wherever one shingle dominates it and mutes
buckets whose contents cancelled — heavy coordinates (the ones
weighted-Jaccard is driven by) survive with small relative error, which
is why ``"ssh-cs"`` and ``"ssh"`` agree on top-k (the golden test in
``tests/test_streaming.py`` pins precision@10 ≥ 0.9).

The shingler is *stateful* (multiply-shift coefficients + the running
hierarchical aggregate ``cs/agg``); ``PipelineEncoder`` drives it through
four optional hooks — ``materialize``/``adopt``/``extra_shapes``/
``histogram_batch_pallas`` — and persistence picks the extra leaves up
automatically, so a saved streaming index reloads with its sketch and
keeps ingesting.
"""
from __future__ import annotations

from typing import Dict, Mapping, Tuple

import jax
import jax.numpy as jnp

from repro.core import shingle
from repro.encoders.base import IndexSpec
from repro.encoders.pipeline import CWSHasher, GaussianFilterSketcher, \
    PipelineEncoder
from repro.encoders.registry import register_encoder
from repro.kernels import ops
from repro.streaming import count_sketch as cs


class CountSketchShingler:
    """Shingler stage: bit-profile → relu'd count-sketch tables.

    Implements the :class:`repro.encoders.base.Shingler` protocol
    (``dim``/``min_bits``/``histogram``/``histogram_masked``) plus the
    stateful-shingler hooks ``PipelineEncoder`` honours, plus the
    streaming surface (``update``/``merge``/``find_heavy_hitters``) over
    the hierarchical aggregate.
    """

    def __init__(self, ngram: int, num_filters: int = 1, rows: int = 4,
                 width: int = 4096, base_bits: int = 4):
        self.ngram, self.num_filters = int(ngram), int(num_filters)
        self.rows, self.width = int(rows), int(width)
        self.base_bits = int(base_bits)
        # shingle ids live in [0, F·2^n)
        self.id_bits = (self.num_filters * (1 << self.ngram) - 1).bit_length()
        self.levels = cs.num_levels(self.id_bits, self.width, self.base_bits)
        self._params: cs.CSParams = None

    # -- Shingler protocol -------------------------------------------------
    @property
    def dim(self) -> int:
        return self.rows * self.width

    @property
    def min_bits(self) -> int:
        return self.ngram

    def histogram(self, bits: jnp.ndarray) -> jnp.ndarray:
        return self._weights(self.shingle_ids(bits))

    def histogram_masked(self, bits: jnp.ndarray, valid_bits) -> jnp.ndarray:
        return self._weights(self.shingle_ids_masked(bits, valid_bits))

    # -- stateful-shingler hooks (PipelineEncoder) -------------------------
    def materialize(self, key) -> Dict[str, jnp.ndarray]:
        p = cs.make_cs_params(key, self.levels, self.rows)
        leaves = {f"cs/{f}": getattr(p, f) for f in cs.CSParams._fields}
        leaves["cs/agg"] = jnp.zeros(self.sketch_shape, jnp.float32)
        return leaves

    def adopt(self, state: Mapping[str, jnp.ndarray]) -> None:
        self._params = cs.CSParams(
            *(state[f"cs/{f}"] for f in cs.CSParams._fields))

    def extra_shapes(self) -> Dict[str, Tuple[int, ...]]:
        lr = (self.levels, self.rows)
        shapes = {f"cs/{f}": lr for f in cs.CSParams._fields}
        shapes["cs/agg"] = self.sketch_shape
        return shapes

    def histogram_batch_pallas(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(B, N_B, F) bit-profiles → (B, rows·width) weights with the
        table scatter on the Pallas one-hot kernel."""
        ids = self.shingle_ids_batch(bits)                     # (B, S)
        p = self._params
        bkt, sgn = cs.bucket_sign(
            ids[:, None, :], p.bucket_a[0][None, :, None],
            p.bucket_b[0][None, :, None], p.sign_a[0][None, :, None],
            p.sign_b[0][None, :, None], self.width)            # (B, R, S)
        tables = ops.cs_tables(bkt, sgn, self.width, use_pallas=True)
        return jnp.maximum(tables, 0.0).reshape(bits.shape[0], self.dim)

    # -- shingle ids -------------------------------------------------------
    def shingle_ids(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(N_B, F) bit-profile → (S,) int32 shingle ids (all valid)."""
        ids = shingle.pack_ngrams(bits.T, self.ngram)          # (F, out)
        offs = (jnp.arange(bits.shape[1], dtype=jnp.int32) << self.ngram)
        return (ids + offs[:, None]).reshape(-1)

    def shingle_ids_masked(self, bits: jnp.ndarray, valid_bits
                           ) -> jnp.ndarray:
        """Like ``shingle_ids`` but shingles not fully inside the first
        ``valid_bits`` rows become −1 (dropped by every sketch path)."""
        n_b, f = bits.shape
        ids = shingle.pack_ngrams(bits.T, self.ngram)          # (F, out)
        out = n_b - self.ngram + 1
        offs = (jnp.arange(f, dtype=jnp.int32) << self.ngram)[:, None]
        flat = (ids + offs).reshape(-1)
        valid = jnp.arange(out, dtype=jnp.int32) < (valid_bits
                                                    - self.ngram + 1)
        maskf = jnp.broadcast_to(valid[None, :], (f, out)).reshape(-1)
        return jnp.where(maskf, flat, -1)

    def shingle_ids_batch(self, bits: jnp.ndarray) -> jnp.ndarray:
        """(B, N_B, F) → (B, S) int32 shingle ids."""
        b, _, f = bits.shape
        ids = shingle.pack_ngrams(bits.transpose(0, 2, 1), self.ngram)
        offs = (jnp.arange(f, dtype=jnp.int32) << self.ngram)[None, :, None]
        return (ids + offs).reshape(b, -1)

    # -- sketch internals --------------------------------------------------
    @property
    def sketch_shape(self) -> Tuple[int, int, int]:
        return (self.levels, self.rows, self.width)

    def level0_tables(self, ids: jnp.ndarray) -> jnp.ndarray:
        """(S,) shingle ids (−1 invalid) → (rows, width) signed tables."""
        p = self._params
        bkt, sgn = cs.bucket_sign(
            ids[None, :], p.bucket_a[0][:, None], p.bucket_b[0][:, None],
            p.sign_a[0][:, None], p.sign_b[0][:, None], self.width)
        tgt = jnp.where(bkt >= 0, bkt, self.width)
        w = self.width

        def one_row(t, s):
            return jnp.zeros((w + 1,), jnp.float32).at[t].add(s)[:w]

        return jax.vmap(one_row)(tgt, sgn)

    def _weights(self, ids: jnp.ndarray) -> jnp.ndarray:
        return jnp.maximum(self.level0_tables(ids), 0.0).reshape(self.dim)

    def update(self, agg: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
        """Fold shingle ids into a hierarchical aggregate (functional)."""
        return cs.update(agg, ids, self._params, base_bits=self.base_bits)

    def find_heavy_hitters(self, agg: jnp.ndarray, threshold: float):
        return cs.find_heavy_hitters(agg, self._params,
                                     base_bits=self.base_bits,
                                     id_bits=self.id_bits,
                                     threshold=threshold)


@register_encoder("ssh-cs")
class StreamingSSHEncoder(PipelineEncoder):
    """SSH with the count-sketch shingle stage + streaming sketch state.

    Params: the ``"ssh"`` six (``window``/``step``/``ngram``/
    ``num_filters``/``num_hashes``/``num_tables``) plus the sketch
    geometry ``rows``/``width``/``base_bits``.  Signature semantics match
    ``"ssh"`` up to sketch noise; the golden test pins top-k agreement.
    """

    DEFAULTS = dict(window=80, step=3, ngram=15, num_filters=1,
                    num_hashes=20, num_tables=20,
                    rows=4, width=4096, base_bits=4)

    @classmethod
    def _build_stages(cls, spec: IndexSpec):
        p = {**cls.DEFAULTS, **spec.params}
        sketcher = GaussianFilterSketcher(p["window"], p["step"],
                                          p["num_filters"])
        shingler = CountSketchShingler(p["ngram"], p["num_filters"],
                                       p["rows"], p["width"], p["base_bits"])
        return (sketcher, shingler, CWSHasher(p["num_hashes"]),
                p["num_tables"])

    @classmethod
    def validate_params(cls, spec: IndexSpec) -> None:
        cls._check_param_names(spec, cls.DEFAULTS)
        p = {**cls.DEFAULTS, **spec.params}
        if p["num_hashes"] % p["num_tables"]:
            raise ValueError("num_hashes must be divisible by num_tables")
        if p["ngram"] > 20:
            raise ValueError("shingle space 2^n exceeds 1M bins; use n<=20")
        w = p["width"]
        if w < 128 or (w & (w - 1)):
            raise ValueError(
                f"width must be a power of two >= 128 (one TPU lane tile), "
                f"got {w}")
        if p["rows"] < 1:
            raise ValueError("rows must be >= 1")
        if not 1 <= p["base_bits"] <= 16:
            raise ValueError("base_bits must be in [1, 16]")

    # -- streaming sketch state -------------------------------------------
    @property
    def sketch_shape(self) -> Tuple[int, int, int]:
        return self.shingler.sketch_shape

    def empty_sketch(self) -> jnp.ndarray:
        """A zero hierarchical aggregate — the shard-local starting state
        of a :class:`repro.streaming.StreamIngestor`."""
        return jnp.zeros(self.sketch_shape, jnp.float32)

    def sketch_batch(self, xs: jnp.ndarray, *, backend: str = "auto"
                     ) -> jnp.ndarray:
        """(B, m) series → their hierarchical sketch contribution.

        Additive: summing the contributions of any partition of a stream
        equals sketching the whole stream (exact in f32 — see
        ``count_sketch``), which is what makes shard merges reductions.
        """
        self._require_state()
        if self._use_pallas(backend):
            bits = self.sketcher.sketch_batch_pallas(xs, self._state)
        else:
            bits = jax.vmap(
                lambda x: self.sketcher.sketch(x, self._state))(xs)
        ids = self.shingler.shingle_ids_batch(bits)
        return self.shingler.update(self.empty_sketch(), ids)

    def aggregate_sketch(self) -> jnp.ndarray:
        """The persisted global aggregate (leaf ``cs/agg``)."""
        self._require_state()
        return self._state["cs/agg"]

    def absorb_sketch(self, agg: jnp.ndarray) -> None:
        """Fold a shard-local aggregate into the persisted global one.

        Safe to mutate post-trace: the cached encode closures never read
        ``cs/agg`` (signatures depend only on the hash coefficients), so
        the jitted paths stay valid while the aggregate grows.
        """
        self._require_state()
        self._state["cs/agg"] = (self._state["cs/agg"]
                                 + jnp.asarray(agg, jnp.float32))

    def find_heavy_hitters(self, threshold: float):
        """(ids, estimates) of shingles with estimated frequency ≥
        ``threshold`` in the global aggregate — ingest diagnostics."""
        self._require_state()
        return self.shingler.find_heavy_hitters(self._state["cs/agg"],
                                                threshold)
