"""repro.streaming — mergeable count-sketch indexing for continuous ingest.

The subsystem behind the ``"ssh-cs"`` encoder (DESIGN.md §9): shingle
histograms become hierarchical count-sketches with O(1) updates and an
additive ``merge``, so index builds are associative reductions over
shard-local :class:`StreamIngestor` state instead of batch jobs over raw
series.  Entry points:

* ``IndexSpec(encoder="ssh-cs", ...)`` — build/search/save/load through
  the ordinary facade; sketch state persists under ``encoder/cs/*``.
* ``TimeSeriesDB.add_stream()/flush()`` — continuous appends folded into
  the live searchable index.
* :class:`StreamIngestor` — shard-local ingest + the associative merge.
"""
from repro.streaming import count_sketch
from repro.streaming.encoder import CountSketchShingler, StreamingSSHEncoder
from repro.streaming.ingest import StreamArtifacts, StreamIngestor

__all__ = [
    "CountSketchShingler",
    "StreamArtifacts",
    "StreamIngestor",
    "StreamingSSHEncoder",
    "count_sketch",
]
