"""Shard-parallel stream ingest — encode at the edge, merge as a reduction.

A :class:`StreamIngestor` is the shard-local half of a streaming index
build: series are *encoded on append* (signatures + band keys through the
shard's encoder, with the usual backend knob) and retained as seq-tagged
segments, while the shard's hierarchical count-sketch aggregate grows by
O(1)-per-shingle updates.  Nothing global happens until ``merge``:

* ``merge(a, b)`` concatenates segments and *adds* sketches — an
  associative, commutative combine, so any merge tree over any shard
  partition produces the same index (``merge_all`` reduces pairwise).
* ``artifacts()`` emits the folded segments in ``(seq, shard, append
  order)`` order — a total order independent of which shard held what,
  which is why two shard-local ingestors merged once answer queries
  identically to the same appends on a single shard (the acceptance
  test of DESIGN.md §9).

Out-of-order appends are the normal case: callers stamp each append with
its stream position ``seq`` (auto-increment per shard when omitted);
ordering is resolved once, at fold time.  No raw series ever reshuffles
between shards — a shard move hands over segments + one (levels, rows,
width) sketch.
"""
from __future__ import annotations

import dataclasses
from functools import reduce
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StreamArtifacts:
    """What a fold hands the index: pre-encoded rows in global seq order
    plus the combined sketch (``None`` for non-sketching encoders).

    ``series`` is ``None`` when any segment arrived pre-encoded without
    its raw rows (``append_encoded``) — signature-only folds feed indexes
    that do not store series (e.g. the subsequence index, whose raw data
    is the stream itself)."""
    series: Optional[np.ndarray]    # (N, m) float32, or None
    signatures: np.ndarray          # (N, K) int32
    keys: np.ndarray                # (N, L) uint32
    sketch: Optional[jnp.ndarray]

    @property
    def num_series(self) -> int:
        return int(self.signatures.shape[0])


@dataclasses.dataclass(frozen=True)
class _Segment:
    seq: int
    shard: str
    order: int                  # per-shard append counter (tie-break)
    series: Optional[np.ndarray]    # None for pre-encoded appends
    signatures: np.ndarray
    keys: np.ndarray


class StreamIngestor:
    """Shard-local continuous ingest for a materialised encoder.

    ``backend`` should match the target index's ``build_backend`` so the
    appended signatures are bit-identical to what a batch build would
    have produced (``TimeSeriesDB.add_stream`` passes it through).
    """

    def __init__(self, encoder, *, shard: str = "shard0",
                 backend: str = "auto"):
        if not encoder.materialized:
            raise ValueError("StreamIngestor needs a materialized encoder")
        self.encoder = encoder
        self.shard = str(shard)
        self.backend = backend
        self._segments: List[_Segment] = []
        self._order = 0
        self._auto_seq = 0
        # shard-LOCAL sketch: starts at zero regardless of what the
        # encoder's global aggregate already holds — the fold adds it in
        self._sketch = (encoder.empty_sketch()
                        if hasattr(encoder, "empty_sketch") else None)

    # -- appends -----------------------------------------------------------
    def append(self, series, *, seq: Optional[int] = None) -> None:
        """Encode and retain a series (``(m,)``) or block (``(B, m)``).

        ``seq`` is the block's global stream position; appends may arrive
        in any seq order — ``artifacts()`` sorts once at fold time.
        """
        xs = jnp.asarray(series, jnp.float32)
        if xs.ndim == 1:
            xs = xs[None, :]
        if seq is None:
            seq = self._auto_seq
        self._auto_seq = max(self._auto_seq, int(seq) + 1)
        sigs = self.encoder.encode_batch(xs, backend=self.backend)
        keys = self.encoder.band_keys(sigs)
        self._segments.append(_Segment(
            seq=int(seq), shard=self.shard, order=self._order,
            series=np.asarray(xs), signatures=np.asarray(sigs),
            keys=np.asarray(keys)))
        self._order += 1
        if self._sketch is not None:
            self._sketch = self._sketch + self.encoder.sketch_batch(
                xs, backend=self.backend)

    def append_encoded(self, signatures, keys, *, series=None,
                       seq: Optional[int] = None) -> None:
        """Retain a pre-encoded block — signatures (B, K) + band keys
        (B, L) computed elsewhere (e.g. the subsequence index's rolling
        encoder), optionally with the raw rows.

        Widths are validated against this shard's encoder so a fold can
        never mix incompatible signatures.  Raw-series sketch updates
        cannot happen without the rows, so a sketching encoder refuses
        series-less appends rather than silently under-counting.
        """
        sigs = np.asarray(signatures)
        ks = np.asarray(keys)
        if sigs.ndim != 2 or ks.ndim != 2 or sigs.shape[0] != ks.shape[0]:
            raise ValueError("append_encoded needs 2-D signatures/keys "
                             f"with equal rows, got {sigs.shape} vs "
                             f"{ks.shape}")
        k, n_tables = self.encoder.num_hashes, self.encoder.num_tables
        if sigs.shape[1] != k or ks.shape[1] != n_tables:
            raise ValueError(
                f"encoded widths {sigs.shape[1]}x{ks.shape[1]} do not "
                f"match the encoder's K={k}, L={n_tables}")
        if self._sketch is not None and series is None:
            raise ValueError(
                "sketching encoder cannot accept series-less encoded "
                "appends (the shingle aggregate would under-count); "
                "pass series= or use a non-sketching encoder")
        if seq is None:
            seq = self._auto_seq
        self._auto_seq = max(self._auto_seq, int(seq) + 1)
        xs = None if series is None else np.asarray(series, np.float32)
        self._segments.append(_Segment(
            seq=int(seq), shard=self.shard, order=self._order,
            series=xs, signatures=sigs, keys=ks))
        self._order += 1
        if self._sketch is not None:
            self._sketch = self._sketch + self.encoder.sketch_batch(
                jnp.asarray(xs), backend=self.backend)

    def __len__(self) -> int:
        return sum(s.signatures.shape[0] for s in self._segments)

    @property
    def sketch(self) -> Optional[jnp.ndarray]:
        """The shard-local hierarchical aggregate (``None`` when the
        encoder has no sketch state, e.g. ``"ssh"``)."""
        return self._sketch

    def heavy_hitters(self, threshold: float):
        """Shard-local heavy shingles — per-shard ingest diagnostics."""
        if self._sketch is None:
            raise ValueError(
                f"encoder {self.encoder.spec.encoder!r} has no sketch "
                "state; heavy hitters need the 'ssh-cs' encoder")
        return self.encoder.shingler.find_heavy_hitters(self._sketch,
                                                        threshold)

    # -- the associative combine -------------------------------------------
    def merge(self, other: "StreamIngestor") -> "StreamIngestor":
        """Combine two shards' ingest state — segments concatenate,
        sketches add.  Associative and commutative (the folded artifact
        order comes from seq tags, not merge order), so shard topology
        never changes the resulting index."""
        if other.encoder.spec != self.encoder.spec:
            raise ValueError(
                f"cannot merge ingestors over different specs: "
                f"{self.encoder.spec!r} vs {other.encoder.spec!r}")
        out = StreamIngestor(self.encoder, shard=f"{self.shard}+"
                             f"{other.shard}", backend=self.backend)
        out._segments = list(self._segments) + list(other._segments)
        out._auto_seq = max(self._auto_seq, other._auto_seq)
        if self._sketch is not None and other._sketch is not None:
            out._sketch = self._sketch + other._sketch
        return out

    @staticmethod
    def merge_all(ingestors: Sequence["StreamIngestor"]) -> "StreamIngestor":
        """Tree-fold a shard set (any bracketing gives the same result)."""
        if not ingestors:
            raise ValueError("merge_all needs at least one ingestor")
        return reduce(lambda a, b: a.merge(b), ingestors)

    # -- the fold ----------------------------------------------------------
    def artifacts(self) -> StreamArtifacts:
        """Segments in global ``(seq, shard, append order)`` order, ready
        for ``SSHIndex.insert_encoded`` — no re-hashing."""
        if not self._segments:
            raise ValueError("no appended series to fold")
        segs = sorted(self._segments,
                      key=lambda s: (s.seq, s.shard, s.order))
        series = (None if any(s.series is None for s in segs) else
                  np.concatenate([s.series for s in segs], axis=0))
        return StreamArtifacts(
            series=series,
            signatures=np.concatenate([s.signatures for s in segs], axis=0),
            keys=np.concatenate([s.keys for s in segs], axis=0),
            sketch=self._sketch)
