"""Recsys architectures: DLRM-RM2, BST, MIND, DIEN.

Common structure (kernel_taxonomy §RecSys): huge hashed embedding tables →
a feature-interaction op (dot / transformer / capsule routing / AUGRU) →
a small MLP head.  Embedding lookups go through
``repro.models.embedding`` (take + segment_sum — built, not stubbed).

Every model exposes  init_params / forward(params, batch) -> logits  and
loss_fn (binary cross-entropy on click labels), plus
``retrieval_scores`` for the ``retrieval_cand`` shape (1 user vs 10⁶
candidates — a single batched dot, never a loop).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.embedding import embedding_lookup

Params = Dict[str, Any]


def _dense(key, shape, dtype=jnp.float32, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jax.random.normal(key, shape, jnp.float32).astype(dtype) * scale


def _mlp_params(key, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": _dense(ks[i], (dims[i], dims[i + 1]), dtype),
             "b": jnp.zeros((dims[i + 1],), dtype)}
            for i in range(len(dims) - 1)]


def _mlp(params, x, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = jax.nn.relu(x)
    return x


def bce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * labels
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


# ==========================================================================
# DLRM (arXiv:1906.00091) — RM2 sizing
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab: int = 1_000_000           # rows per table (hashed)
    bot_mlp: Tuple[int, ...] = (13, 512, 256, 64)
    top_mlp: Tuple[int, ...] = (512, 512, 256, 1)
    dtype: str = "float32"


def dlrm_init(cfg: DLRMConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    tables = _dense(k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim),
                    jnp.dtype(cfg.dtype), scale=0.01)
    n_pairs = (cfg.n_sparse + 1) * cfg.n_sparse // 2
    top_in = cfg.embed_dim + n_pairs
    return {
        "tables": tables,
        "bot": _mlp_params(k2, list(cfg.bot_mlp)),
        "top": _mlp_params(k3, [top_in] + list(cfg.top_mlp)[1:]),
    }


def dlrm_forward(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: DLRMConfig) -> jnp.ndarray:
    """batch: dense (B, 13) f32, sparse (B, 26) int32 -> logits (B,)."""
    x_d = _mlp(params["bot"], batch["dense"])                 # (B, d)
    # per-field lookup: tables (F, V, d), ids (B, F)
    emb = jax.vmap(embedding_lookup, in_axes=(0, 1), out_axes=1)(
        params["tables"], batch["sparse"])                    # (B, F, d)
    feats = jnp.concatenate([x_d[:, None, :], emb], axis=1)   # (B, F+1, d)
    inter = jnp.einsum("bfd,bgd->bfg", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]                                   # (B, n_pairs)
    top_in = jnp.concatenate([x_d, flat], axis=-1)
    return _mlp(params["top"], top_in)[:, 0]


def dlrm_retrieval(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: DLRMConfig) -> jnp.ndarray:
    """retrieval_cand shape: one user vs n_candidates item ids.

    batch: dense (1, 13), sparse (1, 26), cand_ids (n_cand,) -> (n_cand,).
    User tower = bottom MLP + mean of field embeddings; item tower = row of
    table 0 (standard two-tower projection of DLRM for candidate gen).
    """
    x_d = _mlp(params["bot"], batch["dense"])                 # (1, d)
    emb = jax.vmap(embedding_lookup, in_axes=(0, 1), out_axes=1)(
        params["tables"], batch["sparse"])                    # (1, F, d)
    user = x_d + jnp.mean(emb, axis=1)                        # (1, d)
    items = embedding_lookup(params["tables"][0], batch["cand_ids"])
    return (items @ user[0])                                  # (n_cand,)


# ==========================================================================
# BST — Behavior Sequence Transformer (arXiv:1905.06874)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20
    n_heads: int = 8
    n_blocks: int = 1
    d_ff: int = 128
    mlp: Tuple[int, ...] = (1024, 512, 256)
    vocab: int = 2_000_000
    n_profile: int = 8              # user-profile categorical fields
    dtype: str = "float32"


def bst_init(cfg: BSTConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.embed_dim
    blocks = []
    for i in range(cfg.n_blocks):
        bk = jax.random.split(ks[3 + i], 6)
        blocks.append({
            "wq": _dense(bk[0], (d, d)), "wk": _dense(bk[1], (d, d)),
            "wv": _dense(bk[2], (d, d)), "wo": _dense(bk[3], (d, d)),
            "ff1": _dense(bk[4], (d, cfg.d_ff)),
            "ff2": _dense(bk[5], (cfg.d_ff, d)),
            "ln1": jnp.ones((d,)), "ln2": jnp.ones((d,)),
        })
    mlp_in = (cfg.seq_len + 1) * d + cfg.n_profile * d
    return {
        "items": _dense(ks[0], (cfg.vocab, d), scale=0.01),
        "pos": _dense(ks[1], (cfg.seq_len + 1, d), scale=0.01),
        "profile": _dense(ks[2], (cfg.n_profile * 1000, d), scale=0.01),
        "blocks": blocks,
        "head": _mlp_params(ks[7], [mlp_in] + list(cfg.mlp) + [1]),
    }


def _bst_block(p, x, n_heads):
    b, s, d = x.shape
    hd = d // n_heads

    def heads(t):
        return t.reshape(b, s, n_heads, hd)
    from repro.models.layers import rms_norm
    xn = rms_norm(x, p["ln1"])
    q, k, v = heads(xn @ p["wq"]), heads(xn @ p["wk"]), heads(xn @ p["wv"])
    logits = jnp.einsum("bshd,bthd->bhst", q, k) / np.sqrt(hd)
    probs = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, d)
    x = x + o @ p["wo"]
    xn = rms_norm(x, p["ln2"])
    return x + jax.nn.relu(xn @ p["ff1"]) @ p["ff2"]


def bst_forward(params: Params, batch: Dict[str, jnp.ndarray],
                cfg: BSTConfig) -> jnp.ndarray:
    """batch: history (B, S) int32, target (B,) int32, profile (B, P) int32."""
    hist = embedding_lookup(params["items"], batch["history"])
    tgt = embedding_lookup(params["items"], batch["target"])[:, None, :]
    seq = jnp.concatenate([hist, tgt], axis=1) + params["pos"][None]
    for blk in params["blocks"]:
        seq = _bst_block(blk, seq, cfg.n_heads)
    prof = embedding_lookup(params["profile"], batch["profile"])
    b = seq.shape[0]
    feats = jnp.concatenate([seq.reshape(b, -1), prof.reshape(b, -1)],
                            axis=-1)
    return _mlp(params["head"], feats)[:, 0]


def bst_retrieval(params: Params, batch: Dict[str, jnp.ndarray],
                  cfg: BSTConfig) -> jnp.ndarray:
    """One user history vs candidate ids: mean-pooled history · item."""
    hist = embedding_lookup(params["items"], batch["history"])
    user = jnp.mean(hist, axis=1)                              # (1, d)
    items = embedding_lookup(params["items"], batch["cand_ids"])
    return items @ user[0]


# ==========================================================================
# MIND — multi-interest capsule network (arXiv:1904.08030)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class MINDConfig:
    name: str = "mind"
    embed_dim: int = 64
    seq_len: int = 50
    n_interests: int = 4
    capsule_iters: int = 3
    vocab: int = 2_000_000
    mlp: Tuple[int, ...] = (256, 64)
    dtype: str = "float32"


def mind_init(cfg: MINDConfig, key: jax.Array) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.embed_dim
    return {
        "items": _dense(k1, (cfg.vocab, d), scale=0.01),
        "s_matrix": _dense(k2, (d, d)),      # shared bilinear map (B2I)
        "head": _mlp_params(k3, [d] + list(cfg.mlp) + [d]),
    }


def _squash(x, axis=-1):
    n2 = jnp.sum(x * x, axis=axis, keepdims=True)
    return (n2 / (1 + n2)) * x / jnp.sqrt(n2 + 1e-9)


def mind_interests(params: Params, history: jnp.ndarray,
                   cfg: MINDConfig) -> jnp.ndarray:
    """Dynamic-routing (B2I) capsules: history (B, S) -> (B, K, d)."""
    beh = embedding_lookup(params["items"], history)           # (B, S, d)
    beh_hat = beh @ params["s_matrix"]                         # (B, S, d)
    b, s, d = beh.shape
    k = cfg.n_interests
    logits0 = jnp.zeros((b, k, s), beh.dtype)

    def routing_iter(logits, _):
        w = jax.nn.softmax(logits, axis=1)                     # over K
        caps = _squash(jnp.einsum("bks,bsd->bkd", w,
                                  jax.lax.stop_gradient(beh_hat)))
        upd = jnp.einsum("bkd,bsd->bks", caps,
                         jax.lax.stop_gradient(beh_hat))
        return logits + upd, caps

    logits, caps = jax.lax.scan(routing_iter, logits0,
                                jnp.arange(cfg.capsule_iters))
    caps = _squash(jnp.einsum("bks,bsd->bkd",
                              jax.nn.softmax(logits, axis=1), beh_hat))
    return _mlp(params["head"], caps)                          # (B, K, d)


def mind_forward(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: MINDConfig) -> jnp.ndarray:
    """Label-aware scoring: max over interests of interest·target."""
    interests = mind_interests(params, batch["history"], cfg)  # (B, K, d)
    tgt = embedding_lookup(params["items"], batch["target"])   # (B, d)
    scores = jnp.einsum("bkd,bd->bk", interests, tgt)
    return jnp.max(scores, axis=-1)


def mind_retrieval(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: MINDConfig) -> jnp.ndarray:
    interests = mind_interests(params, batch["history"], cfg)  # (1, K, d)
    items = embedding_lookup(params["items"], batch["cand_ids"])
    return jnp.max(items @ interests[0].T, axis=-1)            # (n_cand,)


# ==========================================================================
# DIEN — GRU + AUGRU interest evolution (arXiv:1809.03672)
# ==========================================================================

@dataclasses.dataclass(frozen=True)
class DIENConfig:
    name: str = "dien"
    embed_dim: int = 18
    seq_len: int = 100
    gru_dim: int = 108
    mlp: Tuple[int, ...] = (200, 80)
    vocab: int = 2_000_000
    dtype: str = "float32"


def _gru_params(key, d_in, d_h):
    ks = jax.random.split(key, 3)
    return {
        "wz": _dense(ks[0], (d_in + d_h, d_h)), "bz": jnp.zeros((d_h,)),
        "wr": _dense(ks[1], (d_in + d_h, d_h)), "br": jnp.zeros((d_h,)),
        "wh": _dense(ks[2], (d_in + d_h, d_h)), "bh": jnp.zeros((d_h,)),
    }


def _gru_cell(p, h, x, att: Optional[jnp.ndarray] = None):
    hx = jnp.concatenate([x, h], axis=-1)
    z = jax.nn.sigmoid(hx @ p["wz"] + p["bz"])
    r = jax.nn.sigmoid(hx @ p["wr"] + p["br"])
    hh = jnp.tanh(jnp.concatenate([x, r * h], axis=-1) @ p["wh"] + p["bh"])
    if att is not None:             # AUGRU: attention scales the update gate
        z = z * att[:, None]
    return (1 - z) * h + z * hh


def dien_init(cfg: DIENConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 5)
    d, g = cfg.embed_dim, cfg.gru_dim
    mlp_in = g + 2 * d
    return {
        "items": _dense(ks[0], (cfg.vocab, d), scale=0.01),
        "gru1": _gru_params(ks[1], d, g),
        "gru2": _gru_params(ks[2], g, g),
        "att_w": _dense(ks[3], (g + d, 1)),
        "head": _mlp_params(ks[4], [mlp_in] + list(cfg.mlp) + [1]),
    }


def dien_forward(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: DIENConfig) -> jnp.ndarray:
    """batch: history (B, S) int32, target (B,) int32 -> logits (B,)."""
    beh = embedding_lookup(params["items"], batch["history"])  # (B, S, d)
    tgt = embedding_lookup(params["items"], batch["target"])   # (B, d)
    b, s, d = beh.shape
    g = cfg.gru_dim

    # interest extraction GRU
    def step1(h, x):
        h = _gru_cell(params["gru1"], h, x)
        return h, h
    _, states = jax.lax.scan(step1, jnp.zeros((b, g), beh.dtype),
                             beh.transpose(1, 0, 2))           # (S, B, g)

    # attention of each interest state vs target
    att_in = jnp.concatenate(
        [states, jnp.broadcast_to(tgt[None], (s, b, d))], axis=-1)
    att = jax.nn.softmax(
        (att_in @ params["att_w"])[..., 0], axis=0)            # (S, B)

    # interest evolution AUGRU
    def step2(h, inp):
        x, a = inp
        h = _gru_cell(params["gru2"], h, x, att=a)
        return h, ()
    h_final, _ = jax.lax.scan(step2, jnp.zeros((b, g), beh.dtype),
                              (states, att))

    feats = jnp.concatenate([h_final, tgt, jnp.mean(beh, axis=1)], axis=-1)
    return _mlp(params["head"], feats)[:, 0]


def dien_retrieval(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: DIENConfig) -> jnp.ndarray:
    beh = embedding_lookup(params["items"], batch["history"])
    b, s, d = beh.shape
    def step1(h, x):
        h = _gru_cell(params["gru1"], h, x)
        return h, ()
    h, _ = jax.lax.scan(step1, jnp.zeros((b, cfg.gru_dim), beh.dtype),
                        beh.transpose(1, 0, 2))
    items = embedding_lookup(params["items"], batch["cand_ids"])
    user = h[0, :d] + jnp.mean(beh[0], axis=0)
    return items @ user
