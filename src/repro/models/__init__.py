"""Model zoo for the non-SSH arches."""
