"""GShard-style capacity-based Mixture-of-Experts (einsum dispatch).

Why einsum dispatch: under GSPMD the (groups, tokens, experts, capacity)
one-hot einsum pattern is the battle-tested lowering (GShard/T5X/MaxText)
— the partitioner turns it into all-to-alls along the expert axis instead
of replicating token state.  Sort/scatter dispatch is leaner on FLOPs but
shards unpredictably at 512 devices.

Tokens are viewed as (G groups, N_g tokens) with G aligned to the data
shards; capacity C = N_g · top_k / E · capacity_factor is *per group*, so
the dispatch tensors stay bounded per device no matter the global batch.

FLOPs accounting (for §Roofline): dispatch+combine cost 2·G·N_g·E·C·d MACs
≈ (2·k·cf/1) · tokens·E_frac… — reported separately by
``moe_dispatch_flops`` so the MODEL_FLOPS/HLO ratio can attribute it.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.distributed.constraints import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                      # per-expert hidden
    n_shared: int = 0              # shared (always-on) experts
    capacity_factor: float = 1.25
    group_size: int = 2048         # tokens per dispatch group


def capacity(cfg: MoEConfig, n_g: int) -> int:
    c = int(n_g * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(c, cfg.top_k)


def gating(logits: jnp.ndarray, cfg: MoEConfig, n_g: int
           ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Top-k gating with per-group capacity (GShard §3.2, aux-loss included).

    logits (G, N_g, E) -> dispatch (G, N_g, E, C) bool,
                          combine  (G, N_g, E, C) f32,
                          aux_loss scalar.
    """
    e, k = cfg.n_experts, cfg.top_k
    c = capacity(cfg, n_g)
    gates = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    topv, topi = jax.lax.top_k(gates, k)                     # (G, N, k)
    topv = topv / jnp.maximum(jnp.sum(topv, -1, keepdims=True), 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(topi, e, dtype=jnp.int32)        # (G, N, k, E)
    flat = onehot.reshape(onehot.shape[0], -1, e)            # (G, N*k, E)
    pos = jnp.cumsum(flat, axis=1) - 1                       # (G, N*k, E)
    pos = pos.reshape(onehot.shape)                          # (G, N, k, E)
    pos = jnp.sum(pos * onehot, axis=-1)                     # (G, N, k)
    expert = topi
    keep = pos < c

    disp = (jax.nn.one_hot(expert, e, dtype=jnp.float32)
            * keep[..., None].astype(jnp.float32))           # (G,N,k,E)
    pos_onehot = jax.nn.one_hot(jnp.where(keep, pos, 0), c,
                                dtype=jnp.float32)           # (G,N,k,C)
    # (G, N, k, E) x (G, N, k, C) -> (G, N, E, C)
    dispatch = jnp.einsum("gnke,gnkc->gnec", disp, pos_onehot)
    combine = jnp.einsum("gnke,gnkc->gnec", disp * topv[..., None],
                         pos_onehot)

    # load-balance auxiliary loss (GShard eq. 4 / Switch §2.2)
    density = jnp.mean(onehot.astype(jnp.float32).sum(2), axis=1)  # (G, E)
    density_proxy = jnp.mean(gates, axis=1)                        # (G, E)
    aux = jnp.mean(density * density_proxy) * (e * e)
    return dispatch, combine, aux


def moe_ffn(x: jnp.ndarray, router_w: jnp.ndarray,
            w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray,
            cfg: MoEConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Routed expert SwiGLU FFN.

    x (B, S, d); router_w (d, E); experts w_gate/w_up (E, d, ff),
    w_down (E, ff, d).  Returns (out (B, S, d), aux_loss).
    """
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    n = tokens.shape[0]
    n_g = min(cfg.group_size, n)
    g = n // n_g
    xt = constrain(tokens[: g * n_g].reshape(g, n_g, d),
                   "batch", None, None)

    logits = jnp.einsum("gnd,de->gne", xt, router_w)
    dispatch, combine, aux = gating(logits, cfg, n_g)

    xe = jnp.einsum("gnec,gnd->gecd", dispatch.astype(x.dtype), xt)
    xe = constrain(xe, "batch", "experts", None, None)
    h = (jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, w_gate))
         * jnp.einsum("gecd,edf->gecf", xe, w_up))
    ye = jnp.einsum("gecf,efd->gecd", h, w_down)
    ye = constrain(ye, "batch", "experts", None, None)
    y = jnp.einsum("gnec,gecd->gnd", combine.astype(x.dtype), ye)

    out = y.reshape(g * n_g, d)
    if g * n_g < n:   # ragged tail (decode batches): route through expert 0
        tail = tokens[g * n_g:]
        th = (jax.nn.silu(tail @ w_gate[0]) * (tail @ w_up[0])) @ w_down[0]
        out = jnp.concatenate([out, th], axis=0)
    return out.reshape(b, s, d), aux


def moe_dispatch_flops(cfg: MoEConfig, n_tokens: int) -> int:
    """MACs spent on the dispatch/combine einsums (overhead accounting)."""
    n_g = min(cfg.group_size, n_tokens)
    g = max(1, n_tokens // n_g)
    c = capacity(cfg, n_g)
    return 2 * g * n_g * cfg.n_experts * c * cfg.d_model


def moe_expert_flops(cfg: MoEConfig, n_tokens: int) -> int:
    """MACs in the expert FFNs actually applied (active-expert compute)."""
    n_g = min(cfg.group_size, n_tokens)
    g = max(1, n_tokens // n_g)
    c = capacity(cfg, n_g)
    return 3 * g * cfg.n_experts * c * cfg.d_model * cfg.d_ff
