"""Sparse-embedding substrate (JAX has no native EmbeddingBag — built here).

* ``embedding_lookup`` — hashed ("quotient" trick) row gather; tables shard
  row-wise over the `model` mesh axis, GSPMD turns the gather into the
  standard all-gather-free distributed lookup.
* ``embedding_bag`` — multi-hot gather + ``segment_sum`` reduce (sum/mean),
  the jnp.take + segment-reduce formulation from kernel_taxonomy §RecSys.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def embedding_lookup(table: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Hashed row gather: table (V, d), ids (...,) any int -> (..., d)."""
    v = table.shape[0]
    idx = jnp.remainder(ids.astype(jnp.int32), v)
    return jnp.take(table, idx, axis=0)


def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, num_segments: int,
                  mode: str = "sum",
                  weights: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """EmbeddingBag: gather rows for ``ids`` and reduce by ``segment_ids``.

    ids (L,), segment_ids (L,) sorted-or-not bag assignment in
    [0, num_segments) -> (num_segments, d).
    """
    rows = embedding_lookup(table, ids)
    if weights is not None:
        rows = rows * weights[:, None]
    bags = jax.ops.segment_sum(rows, segment_ids, num_segments=num_segments)
    if mode == "sum":
        return bags
    if mode == "mean":
        counts = jax.ops.segment_sum(jnp.ones_like(ids, rows.dtype),
                                     segment_ids,
                                     num_segments=num_segments)
        return bags / jnp.maximum(counts, 1.0)[:, None]
    raise ValueError(f"mode {mode}")


def positional_embedding(table: jnp.ndarray, length: int) -> jnp.ndarray:
    """table (max_len, d) -> (length, d)."""
    return table[:length]
