"""NequIP — E(3)-equivariant interatomic potential (arXiv:2101.03164).

TPU-native message passing (DESIGN.md §3 + kernel_taxonomy §GNN): JAX has
no CSR SpMM, so edges are explicit (src, dst) index lists; gathers are
``jnp.take`` and the aggregation is ``jax.ops.segment_sum`` — this IS the
message-passing substrate, not a stub.

Features are direct sums of l = 0..l_max irreps with a common channel
multiplicity: ``{l: (N, C, 2l+1)}``.  An interaction layer does, per
allowed path (l_in, l_f, l_out):

    msg[e, c, m3] = Σ_{m1 m2} CG[m3,m1,m2] · x_{l_in}[src_e, c, m1]
                                           · Y_{l_f}(r̂_e)[m2] · w_path[e, c]

with w_path = MLP(radial Bessel basis · smooth cutoff) — then
segment-sums messages into nodes, mixes channels per l (self-interaction),
gates l > 0 irreps by scalars, and adds the residual.

Equivariance holds by construction against `repro.models.equivariant`'s
numerically-derived real-basis CG/Wigner tables (tested by rotating
inputs).  Parity (O(3) vs SO(3)) is not tracked — see DESIGN.md
§Arch-applicability.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.equivariant import allowed_paths, real_cg

Params = Dict[str, Any]


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32           # d_hidden — multiplicity per l
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    d_feat: int = 16             # input node feature dim (species embed)
    radial_hidden: int = 64
    readout_hidden: int = 32
    dtype: str = "float32"

    @property
    def paths(self) -> List[Tuple[int, int, int]]:
        return allowed_paths(self.l_max)

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)


# --------------------------------------------------------------------------
# geometry: spherical harmonics (jnp mirror of equivariant.real_sh) + RBF
# --------------------------------------------------------------------------

def sh_l(l: int, xyz: jnp.ndarray) -> jnp.ndarray:
    x, y, z = xyz[..., 0], xyz[..., 1], xyz[..., 2]
    if l == 0:
        return jnp.ones(xyz.shape[:-1] + (1,), xyz.dtype)
    if l == 1:
        return jnp.stack([x, y, z], axis=-1)
    if l == 2:
        s3 = jnp.sqrt(3.0).astype(xyz.dtype)
        return jnp.stack([
            x * y, y * z, (3 * z * z - 1.0) / (2 * s3), x * z,
            (x * x - y * y) / 2.0], axis=-1) * s3
    raise NotImplementedError(f"l={l}")


def bessel_rbf(r: jnp.ndarray, n_rbf: int, cutoff: float) -> jnp.ndarray:
    """sin(nπ r / r_c) / r Bessel basis with a smooth polynomial envelope."""
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    rr = jnp.maximum(r, 1e-6)[..., None]
    basis = jnp.sin(n * jnp.pi * rr / cutoff) / rr
    u = jnp.clip(r / cutoff, 0.0, 1.0)
    env = 1.0 - 10.0 * u ** 3 + 15.0 * u ** 4 - 6.0 * u ** 5  # C² cutoff
    return basis * env[..., None]


# --------------------------------------------------------------------------
# params
# --------------------------------------------------------------------------

def init_params(cfg: NequIPConfig, key: jax.Array) -> Params:
    dt = cfg.jdtype
    c = cfg.channels
    n_paths = len(cfg.paths)
    keys = jax.random.split(key, 4 + cfg.n_layers)

    def dense(k, shape, scale=None):
        scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
        return (jax.random.normal(k, shape, jnp.float32) * scale).astype(dt)

    layers_p = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[4 + i], 8)
        layers_p.append({
            "radial_w1": dense(lk[0], (cfg.n_rbf, cfg.radial_hidden)),
            "radial_b1": jnp.zeros((cfg.radial_hidden,), dt),
            "radial_w2": dense(lk[1], (cfg.radial_hidden, n_paths * c)),
            "self_mix": {str(l): dense(lk[2 + l], (c, c))
                         for l in range(cfg.l_max + 1)},
            "gate_w": dense(lk[6], (c, cfg.l_max * c)),
            "gate_b": jnp.zeros((cfg.l_max * c,), dt),
        })
    # stack layers for lax.scan
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers_p)
    return {
        "embed_w": dense(keys[0], (cfg.d_feat, c)),
        "readout_w1": dense(keys[1], (c, cfg.readout_hidden)),
        "readout_b1": jnp.zeros((cfg.readout_hidden,), dt),
        "readout_w2": dense(keys[2], (cfg.readout_hidden, 1)),
        "layers": stacked,
    }


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------

def _interaction(layer_p: Params, feats: Dict[str, jnp.ndarray],
                 src: jnp.ndarray, dst: jnp.ndarray,
                 sh: Dict[str, jnp.ndarray], rbf: jnp.ndarray,
                 edge_mask: jnp.ndarray, n_nodes: int,
                 cfg: NequIPConfig) -> Dict[str, jnp.ndarray]:
    c = cfg.channels
    h = jax.nn.silu(rbf @ layer_p["radial_w1"] + layer_p["radial_b1"])
    w = (h @ layer_p["radial_w2"]).reshape(h.shape[0], len(cfg.paths), c)
    # zero-length edges (self-loops / padding) have no geometry: Y_{l>0}(0)
    # is basis-anisotropic and would silently break equivariance.
    w = w * edge_mask[:, None, None]

    agg = {str(l): jnp.zeros((n_nodes, c, 2 * l + 1), feats["0"].dtype)
           for l in range(cfg.l_max + 1)}
    for p_idx, (l1, l2, l3) in enumerate(cfg.paths):
        cg = jnp.asarray(real_cg(l1, l2, l3), feats["0"].dtype)
        x_src = jnp.take(feats[str(l1)], src, axis=0)       # (E, C, 2l1+1)
        msg = jnp.einsum("oab,eca,eb,ec->eco", cg, x_src, sh[str(l2)],
                         w[:, p_idx, :])
        agg[str(l3)] = agg[str(l3)] + jax.ops.segment_sum(
            msg, dst, num_segments=n_nodes)

    # self-interaction (channel mix per l) + gated nonlinearity + residual
    out = {}
    gates = jax.nn.sigmoid(
        feats["0"][..., 0] @ layer_p["gate_w"] + layer_p["gate_b"])
    gates = gates.reshape(-1, cfg.l_max, c)
    for l in range(cfg.l_max + 1):
        mixed = jnp.einsum("nca,cd->nda", agg[str(l)],
                           layer_p["self_mix"][str(l)])
        if l == 0:
            upd = jax.nn.silu(mixed)
        else:
            upd = mixed * gates[:, l - 1, :, None]
        out[str(l)] = feats[str(l)] + upd
    return out


def forward(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: NequIPConfig, n_graphs: Optional[int] = None
            ) -> jnp.ndarray:
    """Energy prediction.

    batch: node_feat (N, d_feat), positions (N, 3),
           edge_src/edge_dst (E,) int32,
           optional graph_ids (N,) int32 + n_graphs for batched molecules.
    Returns per-graph energies (n_graphs,) or global scalar energy (1,).
    """
    pos = batch["positions"].astype(cfg.jdtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n_nodes = pos.shape[0]

    vec = jnp.take(pos, dst, axis=0) - jnp.take(pos, src, axis=0)
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / jnp.maximum(r, 1e-6)[..., None]
    sh = {str(l): sh_l(l, unit) for l in range(cfg.l_max + 1)}
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    edge_mask = (r > 1e-6).astype(cfg.jdtype)

    feats = {
        "0": (batch["node_feat"].astype(cfg.jdtype)
              @ params["embed_w"])[..., None],             # (N, C, 1)
    }
    for l in range(1, cfg.l_max + 1):
        feats[str(l)] = jnp.zeros((n_nodes, cfg.channels, 2 * l + 1),
                                  cfg.jdtype)

    def body(feats, layer_p):
        return _interaction(layer_p, feats, src, dst, sh, rbf, edge_mask,
                            n_nodes, cfg), ()

    feats, _ = jax.lax.scan(body, feats, params["layers"])
    node_e = _readout(params, feats)
    if n_graphs is not None and "graph_ids" in batch:
        return jax.ops.segment_sum(node_e, batch["graph_ids"],
                                   num_segments=n_graphs)
    return jnp.sum(node_e, keepdims=True)


def _readout(params: Params, feats: Dict[str, jnp.ndarray]) -> jnp.ndarray:
    scalars = feats["0"][..., 0]                            # (N, C)
    h = jax.nn.silu(scalars @ params["readout_w1"] + params["readout_b1"])
    return (h @ params["readout_w2"])[..., 0]               # (N,)


def node_energies(params: Params, batch: Dict[str, jnp.ndarray],
                  cfg: NequIPConfig) -> jnp.ndarray:
    """Per-node energies (node-level regression targets)."""
    pos = batch["positions"].astype(cfg.jdtype)
    src, dst = batch["edge_src"], batch["edge_dst"]
    n_nodes = pos.shape[0]
    vec = jnp.take(pos, dst, axis=0) - jnp.take(pos, src, axis=0)
    r = jnp.linalg.norm(vec + 1e-12, axis=-1)
    unit = vec / jnp.maximum(r, 1e-6)[..., None]
    sh = {str(l): sh_l(l, unit) for l in range(cfg.l_max + 1)}
    rbf = bessel_rbf(r, cfg.n_rbf, cfg.cutoff)
    edge_mask = (r > 1e-6).astype(cfg.jdtype)
    feats = {
        "0": (batch["node_feat"].astype(cfg.jdtype)
              @ params["embed_w"])[..., None],
    }
    for l in range(1, cfg.l_max + 1):
        feats[str(l)] = jnp.zeros((n_nodes, cfg.channels, 2 * l + 1),
                                  cfg.jdtype)

    def body(feats, layer_p):
        return _interaction(layer_p, feats, src, dst, sh, rbf, edge_mask,
                            n_nodes, cfg), ()

    feats, _ = jax.lax.scan(body, feats, params["layers"])
    return _readout(params, feats)


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray],
            cfg: NequIPConfig, n_graphs: Optional[int] = None
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    if "node_targets" in batch:   # node-level regression (full-graph cells)
        node_e = node_energies(params, batch, cfg)
        err = node_e - batch["node_targets"].astype(node_e.dtype)
    else:                         # per-graph energies (molecule cell)
        energies = forward(params, batch, cfg, n_graphs=n_graphs)
        err = energies - batch["energy"].astype(energies.dtype)
    loss = jnp.mean(err * err)
    return loss, {"mse": loss}


def forces(params: Params, batch: Dict[str, jnp.ndarray],
           cfg: NequIPConfig) -> jnp.ndarray:
    """F = -∂E/∂positions (the physically meaningful gradient)."""
    def energy_of(pos):
        b = dict(batch)
        b["positions"] = pos
        return jnp.sum(forward(params, b, cfg))
    return -jax.grad(energy_of)(batch["positions"])
